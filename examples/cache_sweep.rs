//! Fig-7 reproduction driver: GPU-cache hit rate vs expert capacity for
//! MoE-Beyond (learned), MoE-Infinity (EAM), and the LRU-only baseline.
//!
//! ```bash
//! cargo run --release --example cache_sweep [n_test_prompts]
//! ```

use moe_beyond::config::SimConfig;
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::sim::PredictorKind;
use moe_beyond::Result;

fn main() -> Result<()> {
    let n_prompts: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let kinds = [
        PredictorKind::Learned,
        PredictorKind::Eam,
        PredictorKind::None,
        PredictorKind::Oracle,
    ];
    eprintln!("running Fig-7 sweep on {n_prompts} test prompts (first learned pass precomputes predictions; cached for reruns) ...");
    let results = harness::run_fig7(
        &rt,
        &arts,
        &kinds,
        harness::FIG7_FRACS,
        n_prompts,
        SimConfig::default(),
    )?;

    println!("\nFig 7 — cache hit rate (%) vs GPU expert capacity (%)");
    print!("{:>10}", "capacity%");
    for r in &results {
        print!("{:>22}", r.predictor);
    }
    println!();
    for (i, frac) in harness::FIG7_FRACS.iter().enumerate() {
        print!("{:>10.0}", frac * 100.0);
        for r in &results {
            print!("{:>22.1}", r.points[i].hit_rate * 100.0);
        }
        println!();
    }

    // the paper's headline comparison point
    let at10 = |name: &str| {
        results
            .iter()
            .find(|r| r.predictor == name)
            .map(|r| r.points[1].hit_rate * 100.0)
            .unwrap_or(0.0)
    };
    println!(
        "\n@10% capacity: moe-beyond {:.1}% vs moe-infinity {:.1}% (paper: >70% vs 17%)",
        at10("moe-beyond"),
        at10("moe-infinity")
    );
    Ok(())
}
