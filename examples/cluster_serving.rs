//! Edge-cluster serving in ~80 lines: shard one MoE's experts across K
//! small devices, price every remote fetch over the link, and watch how
//! node count, placement, and a node failure move the numbers.
//! Self-contained — synthetic corpora, no artifacts.
//!
//! ```bash
//! cargo run --release --example cluster_serving
//! ```
//!
//! The CLI drives the same machinery end-to-end (wide worlds included —
//! a 160-expert 3-node cluster run is just):
//!
//! ```bash
//! cargo run --release -- serve-sim --experts 160 --nodes 3 \
//!     --predictors eam --loads 1,2 --fracs 0.10 --out cluster.csv
//! ```

use moe_beyond::cluster::{ClusterConfig, FaultPlan, PlacementKind};
use moe_beyond::config::{EamConfig, SimConfig};
use moe_beyond::sim::sweep::{sweep_cluster, PredictorKind, SweepInputs};
use moe_beyond::trace::PromptTrace;
use moe_beyond::util::Rng;

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;

/// Reuse-heavy synthetic prompts: each draws from a ~10-expert band.
fn traces(n: usize, seed: u64) -> Vec<PromptTrace> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let base = rng.below(N_EXPERTS - 10) as u8;
            let mut experts = Vec::new();
            for _ in 0..40 * N_LAYERS {
                let a = base + rng.below(10) as u8;
                let b = base + ((a - base + 1 + rng.below(9) as u8) % 10);
                experts.extend([a, b]);
            }
            PromptTrace {
                prompt_id: i as u32,
                n_layers: N_LAYERS as u16,
                top_k: 2,
                d_emb: 0,
                tokens: vec![0; 40],
                embeddings: vec![],
                experts,
            }
        })
        .collect()
}

fn main() -> moe_beyond::Result<()> {
    let test = traces(16, 81);
    let fit = traces(8, 82);
    let inputs: SweepInputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        compiled: None,
        sim: SimConfig::default(),
        eam: EamConfig::default(),
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };

    // healthy cluster: K x placement at 10% cache per device, 10 Gbps
    let healthy = ClusterConfig::default();
    let pts = sweep_cluster(
        PredictorKind::Eam,
        &[1, 2, 4],
        &PlacementKind::ALL,
        &[10.0],
        &[0.10],
        &inputs,
        &healthy,
    )?;
    println!("== healthy cluster (cache 10%/device, 10 Gbps) ==");
    println!(
        "{:>6} {:>11} {:>7} {:>9} {:>18}",
        "nodes", "placement", "hit%", "remote%", "critical path ms"
    );
    for p in &pts {
        println!(
            "{:>6} {:>11} {:>7.1} {:>9.1} {:>18.1}",
            p.nodes,
            p.placement.id(),
            p.gpu_hit_rate * 100.0,
            p.remote_rate * 100.0,
            p.critical_path_us / 1e3
        );
    }

    // same cluster with node 2 dying mid-run and a 3x straggler link:
    // lookups reroute around the ring, the wire bill goes up, the
    // numbers stay perfectly reproducible
    let degraded = ClusterConfig::default()
        .with_faults(FaultPlan::none().with_failure(2, 200).with_straggler(1, 3.0));
    let faulty = sweep_cluster(
        PredictorKind::Eam,
        &[4],
        &[PlacementKind::RoundRobin],
        &[10.0],
        &[0.10],
        &inputs,
        &degraded,
    )?;
    let (h, f) = (&pts[2 * PlacementKind::ALL.len()], &faulty[0]);
    println!("\n== K=4 round-robin: healthy vs node-2 failure + straggler ==");
    println!(
        "healthy : critical path {:>8.1} ms, failovers {:>4}, wire {:>8.1} ms",
        h.critical_path_us / 1e3,
        h.net.failovers,
        h.net.wire_us / 1e3
    );
    println!(
        "degraded: critical path {:>8.1} ms, failovers {:>4}, wire {:>8.1} ms",
        f.critical_path_us / 1e3,
        f.net.failovers,
        f.net.wire_us / 1e3
    );
    assert!(f.net.failovers > 0, "the injected failure should engage");
    Ok(())
}
