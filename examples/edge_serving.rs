//! End-to-end edge serving (the DESIGN.md "E2E" deliverable): load the
//! AOT backbone + trained predictor, serve batched requests through the
//! coordinator, and report latency/throughput/cache behaviour — all three
//! layers composing, Python nowhere on the path.
//!
//! ```bash
//! cargo run --release --example edge_serving [n_requests] [predictor]
//! ```

use moe_beyond::config::{CacheConfig, ServeConfig, SimConfig};
use moe_beyond::coordinator::{serve_requests, EngineConfig, ModelEngine, Request};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::trace::corpus::{CorpusConfig, PromptSampler};
use moe_beyond::trace::WorldModel;
use moe_beyond::Result;

fn main() -> Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    let predictor = std::env::args().nth(2).unwrap_or_else(|| "learned".into());

    let arts = harness::load_artifacts()?;
    let world = WorldModel::load(arts.path("world.json"))?;
    let (nl, ne) = (arts.world.n_layers as usize, arts.world.n_experts as usize);

    // unseen (test-split) prompts as the serving workload
    let mut sampler = PromptSampler::new(
        &world,
        CorpusConfig {
            test_split: true,
            min_tokens: 40,
            max_tokens: 80,
            ..Default::default()
        },
    );
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| Request::new(i as u64, sampler.sample().tokens, 24))
        .collect();

    let cfg = EngineConfig {
        serve: ServeConfig {
            predictor: predictor.clone(),
            max_new_tokens: 24,
            ..Default::default()
        },
        // the paper's headline operating point: 10% of experts fit
        cache: CacheConfig::default().with_capacity_frac(0.10, nl, ne),
        sim: SimConfig::default(),
        ..Default::default()
    };

    eprintln!(
        "edge-serving {n_requests} requests through the {}-layer backbone (predictor={predictor}, cache=10%) ...",
        nl
    );
    let arts2 = arts.clone();
    let report = serve_requests(
        move || {
            let rt = PjrtRuntime::cpu()?;
            ModelEngine::load(&rt, &arts2, cfg)
        },
        requests,
        16,
        1,
    )?;

    println!("== edge serving report ==");
    println!("requests completed : {}", report.completed);
    println!("tokens generated   : {}", report.total_tokens);
    println!("throughput         : {:.2} tok/s, {:.2} req/s", report.tokens_per_sec, report.requests_per_sec);
    println!("GPU cache hit rate : {:.1}%", report.cache_hit_rate * 100.0);
    println!("request latency    : {}", report.request_latency);
    for r in &report.responses {
        println!(
            "  req {}: {} tokens, hit rate {:.1}%, decode {:.0} ms, predict {:.0} ms, modeled miss {:.1} ms",
            r.id,
            r.tokens.len(),
            r.stats.hit_rate() * 100.0,
            r.stats.decode_time.as_secs_f64() * 1e3,
            r.stats.predict_time.as_secs_f64() * 1e3,
            r.stats.modeled_miss_us / 1e3,
        );
    }
    Ok(())
}
