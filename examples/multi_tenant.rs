//! Multi-tenant contention in ~60 lines: three tenants (chat, bursty
//! agent, batch) share one expert cache while the virtual-time engine
//! interleaves their decode streams; compare scheduler policies by their
//! SLO outcomes.  Self-contained — synthetic corpora, no artifacts.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, WorkloadConfig};
use moe_beyond::memory;
use moe_beyond::sim::PredictorKind;
use moe_beyond::workload::{
    run_workload, synthetic_fit_pool, synthetic_pools, SchedPolicy, WorkloadInputs, WorkloadSpec,
};

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;

fn main() -> moe_beyond::Result<()> {
    let spec = WorkloadSpec::example(3, 7, 10.0).with_load(2.0);
    let pools = synthetic_pools(&spec, 6, N_LAYERS as u16, N_EXPERTS);
    let fit = synthetic_fit_pool(&spec, 4, N_LAYERS as u16, N_EXPERTS);
    let schedule = spec.generate(&pools)?;
    println!(
        "{} requests over {:.0}s of virtual arrivals ({:.2} rps offered)",
        schedule.arrivals.len(),
        spec.horizon_secs,
        schedule.offered_rps
    );

    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    for policy in SchedPolicy::ALL {
        let cfg = WorkloadConfig {
            policy: policy.id().to_string(),
            ..Default::default()
        };
        // 10% flat cache shared by every stream
        let cap = (N_LAYERS * N_EXPERTS) / 10;
        let mem = memory::build(
            "lru",
            &CacheConfig::default().with_capacity(cap),
            None,
            &sim,
            N_EXPERTS,
            cfg.token_compute_us / N_LAYERS as f64,
        )?;
        let inputs: WorkloadInputs = WorkloadInputs {
            spec: &spec,
            schedule: &schedule,
            pools: &pools,
            fit_traces: &fit,
            learned: None,
            cfg: &cfg,
            sim: &sim,
            eam: &eam,
            n_layers: N_LAYERS,
            n_experts: N_EXPERTS,
        };
        let r = run_workload(&inputs, PredictorKind::Eam, mem)?;
        println!(
            "\n== {} ==  ({} completed in {:.1}s virtual, {:.2} rps, hit {:.1}%)",
            policy.id(),
            r.counters.completions,
            r.virtual_secs,
            r.completed_rps,
            r.aggregate.cache.hit_rate() * 100.0
        );
        for t in &r.tenants {
            println!(
                "  {:<10} done {:>3}  ttft p95 {:>8.1} ms  tbt p95 {:>7.1} ms  latency p95 {:>8.1} ms",
                t.name,
                t.completed,
                t.ttft.p95_us / 1e3,
                t.tbt.p95_us / 1e3,
                t.request_latency.p95_us / 1e3
            );
        }
    }
    Ok(())
}
