//! Quickstart: load the artifact tree, run the learned predictor on one
//! test prompt, and compare its predictions against the ground-truth
//! router trace.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! No artifacts handy?  The serving simulators run self-contained on
//! synthetic worlds of up to 256 experts — e.g. a 160-expert model
//! sharded across a 3-node edge cluster:
//!
//! ```bash
//! cargo run --release -- serve-sim --experts 160 --nodes 3 \
//!     --predictors eam --loads 1,2 --fracs 0.10 --out cluster.csv
//! ```

use moe_beyond::eval::{eval_trace, EvalAccumulator};
use moe_beyond::predictor::{learned, LearnedModel};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::trace::store;
use moe_beyond::Result;

fn main() -> Result<()> {
    // 1. discover the artifacts built by `make artifacts`
    let arts = harness::load_artifacts()?;
    println!(
        "world: {} layers x {} experts, top-{} routing (fingerprint {})",
        arts.world.n_layers, arts.world.n_experts, arts.world.top_k, arts.world.fingerprint
    );

    // 2. bring up PJRT and load the trained predictor
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model = LearnedModel::load(&rt, &arts)?;
    println!(
        "predictor loaded: window {}, d_tok {}, batch {}",
        model.window, model.d_tok, model.batch
    );

    // 3. read one unseen test prompt's activation trace
    let traces = store::read_traces(arts.path(&arts.split("test")?.path))?;
    let tr = &traces[0];
    println!(
        "test prompt {}: {} tokens x {} layers",
        tr.prompt_id,
        tr.n_tokens(),
        tr.n_layers
    );

    // 4. predict expert activations for every (token, layer) position
    let preds = learned::precompute(&model, tr, model.window, arts.world.top_k as usize)?;

    // 5. score against the ground truth
    let mut acc = EvalAccumulator::new(arts.world.n_experts as usize);
    eval_trace(&preds, tr, &mut acc);
    println!("accuracy  : {:.2}%", acc.accuracy() * 100.0);
    println!("macro F1  : {:.2}%", acc.macro_f1() * 100.0);
    println!("micro F1  : {:.2}%", acc.micro_f1() * 100.0);

    // 6. peek at one position
    let (t, l) = (tr.n_tokens() / 2, 13);
    println!(
        "token {t} layer {l}: predicted {:?} vs actual {:?}",
        preds.sets[t][l],
        tr.expert_set(t, l)
    );
    Ok(())
}
