//! End-to-end tiered serving: the same edge workload served by the flat
//! VRAM model vs the tiered GPU ↔ host RAM ↔ SSD hierarchy, with
//! per-tier serve counts and the modeled latency gap between them.
//!
//! ```bash
//! cargo run --release --example tiered_serving [n_requests] [host_frac]
//! ```

use moe_beyond::config::{CacheConfig, ServeConfig, SimConfig, TierConfig};
use moe_beyond::coordinator::{EngineConfig, ModelEngine, Request};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::trace::corpus::{CorpusConfig, PromptSampler};
use moe_beyond::trace::WorldModel;
use moe_beyond::Result;

fn main() -> Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let host_frac: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);

    let arts = harness::load_artifacts()?;
    let world = WorldModel::load(arts.path("world.json"))?;
    let (nl, ne) = (arts.world.n_layers as usize, arts.world.n_experts as usize);
    let total = nl * ne;

    let mut sampler = PromptSampler::new(
        &world,
        CorpusConfig {
            test_split: true,
            min_tokens: 40,
            max_tokens: 80,
            ..Default::default()
        },
    );
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| Request::new(i as u64, sampler.sample().tokens, 16))
        .collect();

    let base_cfg = EngineConfig {
        serve: ServeConfig {
            predictor: "learned".into(),
            max_new_tokens: 16,
            ..Default::default()
        },
        // the paper's headline operating point: 10% of experts in VRAM
        cache: CacheConfig::default().with_capacity_frac(0.10, nl, ne),
        sim: SimConfig::default(),
        ..Default::default()
    };
    let tier_cfg = TierConfig::default()
        .with_gpu_capacity(base_cfg.cache.capacity_experts)
        .with_host_capacity(((total as f64 * host_frac).round() as usize).max(1))
        .with_deepest_capacity(total); // flash holds the whole pool

    let rt = PjrtRuntime::cpu()?;
    let mut report = Vec::new();
    for (label, tier) in [("flat", None), ("tiered", Some(tier_cfg.clone()))] {
        let cfg = EngineConfig {
            tier,
            ..base_cfg.clone()
        };
        eprintln!("serving {n_requests} requests ({label}) ...");
        let mut engine = ModelEngine::load(&rt, &arts, cfg)?;
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut miss_us = 0.0;
        let mut stall_us = 0.0;
        for r in &requests {
            let resp = engine.process(r.clone())?;
            hits += resp.stats.cache_hits;
            misses += resp.stats.cache_misses;
            miss_us += resp.stats.modeled_miss_us;
            stall_us += resp.stats.modeled_stall_us;
        }
        let tier_line = engine.tier_stats().map(|ts| {
            let mut s = String::from("served per tier: ");
            for (d, n) in ts.served.iter().enumerate() {
                s.push_str(&format!("[{d}] {n}  "));
            }
            s.push_str(&format!(
                "cold {}  demotions {}  dropped {}",
                ts.cold, ts.demotions, ts.dropped
            ));
            s
        });
        report.push((label, hits, misses, miss_us, stall_us, tier_line));
    }

    println!("\n== flat vs tiered (gpu=10%, host={:.0}%, ssd=rest) ==", host_frac * 100.0);
    for (label, hits, misses, miss_us, stall_us, tier_line) in &report {
        let hr = *hits as f64 / (*hits + *misses).max(1) as f64;
        println!(
            "{label:>7}: hit rate {:.1}%  modeled miss {:.1} ms  stall {:.1} ms",
            hr * 100.0,
            miss_us / 1e3,
            stall_us / 1e3
        );
        if let Some(line) = tier_line {
            println!("         {line}");
        }
    }
    println!(
        "\nThe GPU hit rates match — but the tiered model prices each deep miss by the\n\
         tier that actually served it, which is what an edge deployment experiences."
    );
    Ok(())
}
