//! Figs 1-3 reproduction: the MoE-Infinity sparsity insight on our world
//! (paper §2.2, Contribution 1) — cross-prompt uniformity, single-prompt
//! skew, and the cross-layer reuse heatmap, printed as ASCII.
//!
//! ```bash
//! cargo run --release --example trace_analysis [n_prompts]
//! ```

use moe_beyond::sim::harness;
use moe_beyond::Result;

fn bar(v: u64, max: u64, width: usize) -> String {
    let n = if max == 0 { 0 } else { (v as usize * width) / max as usize };
    "#".repeat(n)
}

fn main() -> Result<()> {
    let n_prompts: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(122); // the paper analyzes 122 prompts

    let arts = harness::load_artifacts()?;
    let rep = harness::run_fig123(&arts, n_prompts, 0)?;

    println!("== Fig 1: aggregated expert activations, layer 1, {n_prompts} prompts ==");
    let m1 = *rep.fig1_histogram.iter().max().unwrap();
    for (e, &c) in rep.fig1_histogram.iter().enumerate() {
        if e % 4 == 0 {
            println!("  e{e:02} {c:>6} {}", bar(c, m1, 40));
        }
    }
    println!(
        "  min {} max {} ratio {:.2} (paper: 800-1400, ~1.75x)",
        rep.fig1_min, rep.fig1_max, rep.fig1_ratio
    );

    println!("\n== Fig 2: single-prompt activations (sparse) ==");
    let m2 = *rep.fig2_histogram.iter().max().unwrap();
    for (e, &c) in rep.fig2_histogram.iter().enumerate() {
        if c > 0 {
            println!("  e{e:02} {c:>6} {}", bar(c, m2, 40));
        }
    }
    println!(
        "  working set: {} / {} experts; peak experts {:?}",
        rep.fig2_working_set, arts.world.n_experts, rep.fig2_peak_experts
    );

    println!("\n== Fig 3: per-layer working sets for the same prompt ==");
    for (l, &ws) in rep.fig3_working_sets.iter().enumerate() {
        println!("  layer {l:02}: {ws:>2} experts {}", "#".repeat(ws));
    }
    println!(
        "  cross-layer (permutation-adjusted) reuse: {:.2}",
        rep.fig3_cross_layer_reuse
    );

    println!("\n== sparsity summary (paper §2.2) ==");
    println!(
        "  mean per-prompt working set {:.1} experts ({:.0}% of pool)",
        rep.sparsity.mean_working_set,
        rep.sparsity.working_set_frac * 100.0
    );
    println!(
        "  per-prompt entropy {:.2} nats << aggregate entropy {:.2} nats",
        rep.sparsity.mean_single_entropy, rep.sparsity.aggregate_entropy
    );
    Ok(())
}
