"""AOT pipeline: world -> traces -> training -> HLO artifacts.

Runs once at `make artifacts`; everything the Rust coordinator needs at
runtime lands in ``artifacts/``:

  world.json / world.bin(.blobs.json)   synthetic world (DESIGN.md §6)
  backbone_weights.bin(.json)           constructed backbone params
  predictor_weights.bin(.json)          TRAINED predictor params
  training_log.json                     per-step metrics (Figs 5-6)
  traces/{train,val,test,backbone_val}.bin   MBTR trace files
  predictor.hlo.txt                     fwd, one (window, layer) pair
  predictor_batch.hlo.txt               fwd, batch of n_layers pairs
  backbone_prefill.hlo.txt              prompt prefill
  backbone_decode.hlo.txt               one decode step
  artifacts.json                        dims + executable signatures

Interchange is HLO **text**: jax>=0.5 serialized HloModuleProto uses
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Env knobs:
  MOEB_FAST=1      tiny everything (CI / pytest)
  MOEB_TRAIN_PROMPTS / MOEB_TEST_PROMPTS / MOEB_EPOCHS   scale overrides
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import tracegen, train as train_mod
from .model import PredictorConfig
from .train import TrainConfig
from .world import World, WorldConfig, build_backbone_params, flatten_params, save_flat


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text (the xla-crate-compatible interchange).

    return_tuple=False and a SINGLE flat f32 result per artifact: the
    xla-crate/xla_extension-0.5.1 CPU client cannot reliably fetch
    tuple-shaped output buffers (ToLiteral hits a CHECK on tuple shapes),
    so every artifact function concatenates its outputs into one f32
    vector the Rust side slices by the known lengths.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_and_write(fn, example_args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    flat, _ = jax.tree.flatten(example_args)
    return {
        "path": os.path.basename(path),
        "num_inputs": len(flat),
        "input_shapes": [list(np.shape(a)) for a in flat],
        "input_dtypes": [str(np.asarray(a).dtype) for a in flat],
        "chars": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=20250710)
    ap.add_argument("--skip-train", action="store_true", help="reuse existing predictor weights")
    args = ap.parse_args()
    out = os.path.abspath(args.outdir)
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "traces"), exist_ok=True)

    fast = os.environ.get("MOEB_FAST", "0") == "1"
    n_train = int(os.environ.get("MOEB_TRAIN_PROMPTS", 8 if fast else 400))
    n_val = int(os.environ.get("MOEB_VAL_PROMPTS", 4 if fast else 60))
    n_test = int(os.environ.get("MOEB_TEST_PROMPTS", 4 if fast else 100))
    n_bval = int(os.environ.get("MOEB_BACKBONE_VAL_PROMPTS", 2 if fast else 24))
    epochs = int(os.environ.get("MOEB_EPOCHS", 1 if fast else 26))
    steps = int(os.environ.get("MOEB_STEPS_PER_EPOCH", 10 if fast else 400))

    t0 = time.time()
    wc = WorldConfig(seed=args.seed)
    world = World(wc)
    print(f"[aot] world fingerprint {world.fingerprint()}")
    world.save(os.path.join(out, "world.json"))

    # ---- backbone weights
    params = build_backbone_params(world)
    flat, man = flatten_params(params)
    save_flat(
        os.path.join(out, "backbone_weights.bin"),
        flat,
        man,
        extra={"fingerprint": world.fingerprint()},
    )
    print(f"[aot] backbone params {flat.size/1e6:.1f}M ({time.time()-t0:.0f}s)")

    # ---- traces (paper contribution 2: the activation-trace dataset)
    splits = {}
    for split, n, mode in [
        ("train", n_train, "analytic"),
        ("val", n_val, "analytic"),
        ("test", n_test, "analytic"),
        ("backbone_val", n_bval, "backbone"),
    ]:
        if n <= 0:
            continue
        path = os.path.join(out, "traces", f"{split}.bin")
        trs = tracegen.generate_split(world, "test" if split == "test" else "train", n, path, mode=mode)
        splits[split] = {
            "prompts": len(trs),
            "trace_points": tracegen.trace_point_count(trs),
            "path": f"traces/{split}.bin",
        }
        print(
            f"[aot] traces/{split}: {len(trs)} prompts, "
            f"{splits[split]['trace_points']/1e6:.2f}M points ({time.time()-t0:.0f}s)"
        )

    # ---- train predictor
    pc = PredictorConfig()
    wpath = os.path.join(out, "predictor_weights.bin")
    if args.skip_train and os.path.exists(wpath):
        print("[aot] --skip-train: reusing predictor weights")
    else:
        tc = TrainConfig(max_epochs=epochs, steps_per_epoch=steps)
        _, tr_traces = tracegen.read_traces(os.path.join(out, "traces", "train.bin"))
        _, va_traces = tracegen.read_traces(os.path.join(out, "traces", "val.bin"))
        print(f"[aot] training predictor ({epochs} epochs x {steps} steps)")
        train_mod.train_predictor(
            pc, tc, tr_traces, va_traces, out, world.fingerprint()
        )
        print(f"[aot] training done ({time.time()-t0:.0f}s)")

    # ---- lower HLO artifacts
    specs = model_mod.predictor_param_specs(pc)
    wlist = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    T = pc.window
    emb_s = jax.ShapeDtypeStruct((T, pc.d_tok), jnp.float32)
    lid_s = jax.ShapeDtypeStruct((T,), jnp.int32)
    msk_s = jax.ShapeDtypeStruct((T,), jnp.float32)

    sigs = {}
    sigs["predictor"] = lower_and_write(
        lambda wl, e, l, m: model_mod.predictor_forward(pc, list(wl), e, l, m),
        (tuple(wlist), emb_s, lid_s, msk_s),
        os.path.join(out, "predictor.hlo.txt"),
    )

    # batch = n_model_layers: one PJRT dispatch scores a window for EVERY
    # layer (the serving refresh needs exactly that; 4x fewer dispatches
    # than the earlier batch-of-8 artifact — EXPERIMENTS.md §Perf)
    B = 9  # 3 dispatches per 27-layer refresh — fastest point measured (§Perf)
    embb = jax.ShapeDtypeStruct((B, T, pc.d_tok), jnp.float32)
    lidb = jax.ShapeDtypeStruct((B, T), jnp.int32)
    mskb = jax.ShapeDtypeStruct((B, T), jnp.float32)
    sigs["predictor_batch"] = lower_and_write(
        lambda wl, e, l, m: jax.vmap(
            lambda ee, ll, mm: model_mod.predictor_forward(pc, list(wl), ee, ll, mm)
        )(e, l, m),
        (tuple(wlist), embb, lidb, mskb),
        os.path.join(out, "predictor_batch.hlo.txt"),
    )

    bspecs = model_mod.backbone_param_specs(wc)
    bwlist = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in bspecs]
    P = wc.max_seq
    toks_s = jax.ShapeDtypeStruct((P,), jnp.int32)
    n_s = jax.ShapeDtypeStruct((), jnp.int32)
    def prefill_flat(wl, t, n):
        kv, ids, x0, logits = model_mod.backbone_prefill(wc, list(wl), t, n)
        return jnp.concatenate(
            [kv.reshape(-1), ids.reshape(-1).astype(jnp.float32),
             x0.reshape(-1), logits.reshape(-1)]
        )

    sigs["backbone_prefill"] = lower_and_write(
        prefill_flat,
        (tuple(bwlist), toks_s, n_s),
        os.path.join(out, "backbone_prefill.hlo.txt"),
    )

    # short-prompt prefill: fixed shapes mean the 160-slot prefill pays
    # for padding compute; most prompts fit 96 slots (§Perf: ~1.7x)
    toks_short = jax.ShapeDtypeStruct((96,), jnp.int32)
    sigs["backbone_prefill_96"] = lower_and_write(
        prefill_flat,
        (tuple(bwlist), toks_short, n_s),
        os.path.join(out, "backbone_prefill_96.hlo.txt"),
    )

    kv_s = jax.ShapeDtypeStruct(
        (wc.n_layers, 2, wc.max_seq, wc.n_heads * wc.d_head), jnp.float32
    )
    tok_s = jax.ShapeDtypeStruct((), jnp.int32)
    # Chained decode state: one flat vector [HEAD | KV] where
    # HEAD = logits(V) + router_ids(L*k, as f32) + embedding(D).  The
    # output has the SAME layout as the state input, so the Rust side can
    # feed the output buffer of step t directly back as the input of step
    # t+1 — the KV cache never crosses the host boundary; only the 17 KB
    # head is fetched per token (EXPERIMENTS.md §Perf).
    head_len = wc.vocab_size + wc.n_layers * wc.top_k + wc.d_model
    kv_len = wc.n_layers * 2 * wc.max_seq * wc.n_heads * wc.d_head
    state_s = jax.ShapeDtypeStruct((head_len + kv_len,), jnp.float32)

    def decode_chained(wl, state, p, t):
        kv = state[head_len:].reshape(
            (wc.n_layers, 2, wc.max_seq, wc.n_heads * wc.d_head)
        )
        kv2, logits, ids, emb = model_mod.backbone_decode_step(wc, list(wl), kv, p, t)
        return jnp.concatenate(
            [logits.reshape(-1), ids.reshape(-1).astype(jnp.float32),
             emb.reshape(-1), kv2.reshape(-1)]
        )

    sigs["backbone_decode"] = lower_and_write(
        decode_chained,
        (tuple(bwlist), state_s, n_s, tok_s),
        os.path.join(out, "backbone_decode.hlo.txt"),
    )

    # head extractor: slices the host-visible head out of the chained
    # decode state ON DEVICE (CopyRawToHost is unimplemented in this PJRT,
    # so partial fetches go through this trivial executable instead)
    sigs["head_extract"] = lower_and_write(
        lambda st: st[:head_len],
        (state_s,),
        os.path.join(out, "head_extract.hlo.txt"),
    )

    meta = {
        "world": world.manifest(),
        "predictor_config": {
            "d_tok": pc.d_tok,
            "n_model_layers": pc.n_model_layers,
            "n_experts": pc.n_experts,
            "d_layer": pc.d_layer,
            "d_model": pc.d_model,
            "n_enc_layers": pc.n_enc_layers,
            "n_heads": pc.n_heads,
            "d_ff": pc.d_ff,
            "window": pc.window,
            "top_k": pc.top_k,
            "batch": B,
        },
        "splits": splits,
        "executables": sigs,
        "fast_mode": fast,
    }
    with open(os.path.join(out, "artifacts.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] all artifacts written to {out} ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
