"""L1 Pallas kernels (interpret mode) + pure-jnp reference oracles."""

from . import attention, expert_mlp, moe_gate, ref  # noqa: F401

__all__ = ["attention", "expert_mlp", "moe_gate", "ref"]
