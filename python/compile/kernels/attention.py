"""Pallas fused multi-head attention — the predictor encoder's hot-spot.

Hardware adaptation (DESIGN.md §3): the paper runs its predictor on a CUDA
GPU where attention would be a warp-tiled kernel over shared memory.  On
TPU the same insight maps to VMEM tiling with BlockSpec:

  * grid = (heads, query tiles): each grid step holds one Q tile
    [BLOCK_T, Dh] plus that head's full K/V [T, Dh] in VMEM,
  * the [BLOCK_T, T] logit tile targets the MXU (fp32 here; bf16 on real
    TPUs), softmax and the PV matmul stay in-register within the step,
  * padded keys are masked with -inf before the softmax so smart padding
    (paper §3.2.1) never leaks across tokens.

VMEM per step = BLOCK_T*Dh + 2*T*Dh + BLOCK_T*T floats — for the default
predictor config (T=32, Dh=16, BLOCK_T=16) about 6 KiB, far under the
~16 MiB VMEM budget; the block shape is chosen by `pick_block_t` to stay
MXU-aligned as T grows.  interpret=True everywhere: CPU PJRT cannot run
Mosaic custom-calls, so this kernel is validated through the interpreter
and its TPU efficiency is *estimated* in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block_t(t: int) -> int:
    """Largest power-of-two query tile <= 128 that divides T."""
    for b in (128, 64, 32, 16, 8, 4, 2, 1):
        if t % b == 0:
            return b
    return 1


def _mha_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, causal: bool, block_t: int):
    # Block views: q [block_t, hpb, Dh]; k/v [T, hpb, Dh]; mask [T].
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]  # [T]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    # [hpb, block_t, T] logits for every head in the block
    logits = jnp.einsum("thd,shd->hts", q, k) * scale
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[None, None, :] > 0, logits, neg)
    if causal:
        qt = pl.program_id(1)
        t_total = k.shape[0]
        qpos = qt * block_t + jnp.arange(block_t)
        kpos = jnp.arange(t_total)
        logits = jnp.where(kpos[None, None, :] <= qpos[None, :, None], logits, neg)
    # numerically-stable softmax fused in the same grid step
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum("hts,shd->thd", p / denom, v).astype(o_ref.dtype)


def _mha_pallas(
    q: jax.Array,     # [T, H, Dh]
    k: jax.Array,     # [T, H, Dh]
    v: jax.Array,     # [T, H, Dh]
    mask: jax.Array,  # [T] float (1 = real, 0 = pad)
    causal: bool = False,
    heads_per_block: int | None = None,
) -> jax.Array:
    """Fused masked MHA via Pallas (interpret mode). -> [T, H, Dh]

    `heads_per_block` sets how many heads share one grid step.  On a real
    TPU you would grid per head (hpb=1) so each step's VMEM stays tiny; in
    interpret mode each grid step pays fixed emulation overhead, so the
    shipped artifacts use hpb=H (all heads per step) — measured 3-8x
    faster under vmap batching with identical numerics (§Perf).
    """
    t, h, dh = q.shape
    block_t = pick_block_t(t)
    hpb = heads_per_block or h
    assert h % hpb == 0, "heads_per_block must divide n_heads"
    grid = (h // hpb, t // block_t)

    def q_map(hh, tt):
        return (tt, hh, 0)

    def kv_map(hh, tt):
        return (0, hh, 0)

    def mask_map(hh, tt):
        return (0,)

    kernel = functools.partial(_mha_kernel, causal=causal, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, hpb, dh), q_map),
            pl.BlockSpec((t, hpb, dh), kv_map),
            pl.BlockSpec((t, hpb, dh), kv_map),
            pl.BlockSpec((t,), mask_map),
        ],
        out_specs=pl.BlockSpec((block_t, hpb, dh), lambda hh, tt: (tt, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)


# ---------------------------------------------------------------------------
# custom VJP: Pallas forward, analytic backward
#
# Pallas interpret-mode kernels do not support reverse-mode autodiff in this
# jaxlib, and the predictor must be *trained* through its attention layers
# (train.py).  The standard pattern applies: the forward pass is the Pallas
# kernel (so inference artifacts contain the fused kernel), the backward
# pass recomputes attention with the pure-jnp reference and differentiates
# that.  test_attention.py asserts fwd(pallas) == fwd(ref), which makes the
# pairing mathematically consistent.
# ---------------------------------------------------------------------------

from . import ref as _ref  # noqa: E402  (late import: avoid cycle at init)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def mha(q, k, v, mask, causal: bool = False):
    return _mha_pallas(q, k, v, mask, causal)


def _mha_fwd(q, k, v, mask, causal):
    return _mha_pallas(q, k, v, mask, causal), (q, k, v, mask)


def _mha_bwd(causal, res, g):
    q, k, v, mask = res
    _, vjp = jax.vjp(lambda a, b, c, m: _ref.mha_ref(a, b, c, m, causal), q, k, v, mask)
    return vjp(g)


mha.defvjp(_mha_fwd, _mha_bwd)
