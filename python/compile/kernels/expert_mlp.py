"""Pallas gated expert-FFN accumulation kernel.

This is the backbone's compute hot-spot and the L1 analogue of the paper's
memory story: on the serving side (L3) whole experts page between host RAM
and GPU VRAM; inside the kernel the same working-set discipline appears as
*one expert's weights resident in VMEM per grid step* while the token tile
stays pinned.

  grid = (experts, token tiles)
  step (e, tt): VMEM holds  h-tile [BLOCK_T, D],  w_in[e] [D, F],
                w_out[e] [F, D],  gate column [BLOCK_T, 1]
  out[tt] += gate[:, e] * relu(h @ w_in[e]) @ w_out[e]

The output block index ignores `e`, so Pallas keeps the accumulator tile
resident across the expert axis (revolving accumulation) — the classical
"stationary output, streaming weights" schedule.  Both matmuls are
MXU-shaped ([BLOCK_T,D]x[D,F] and [BLOCK_T,F]x[F,D]).  VMEM per step for
the default backbone (D=128, F=64, BLOCK_T=64) is ~100 KiB.

A `skip_zero_gate` refinement exploits MoE sparsity inside the kernel:
when a whole token tile has zero gate weight for expert e (the common case
— top-6 of 64), the FLOPs are skipped via lax.cond.  This mirrors the
paper's premise that sparsity, not width, is what makes MoE servable.

interpret=True: see attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_mlp_kernel(h_ref, gate_ref, w_in_ref, w_out_ref, o_ref, *, skip_zero_gate: bool):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...]              # [bt, D]
    g = gate_ref[...][:, 0]     # [bt]
    w_in = w_in_ref[0]          # [D, F]
    w_out = w_out_ref[0]        # [F, D]

    def compute():
        act = jnp.maximum(jnp.dot(h, w_in), 0.0)      # [bt, F]
        return (jnp.dot(act, w_out) * g[:, None]).astype(o_ref.dtype)

    if skip_zero_gate:
        contrib = jax.lax.cond(
            jnp.any(g != 0.0),
            compute,
            lambda: jnp.zeros_like(o_ref[...]),
        )
    else:
        contrib = compute()
    o_ref[...] += contrib


def expert_mlp(
    h: jax.Array,      # [T, D]
    gate: jax.Array,   # [T, E] dense gate (zeros off the top-k)
    w_in: jax.Array,   # [E, D, F]
    w_out: jax.Array,  # [E, F, D]
    block_t: int | None = None,
    skip_zero_gate: bool = True,
) -> jax.Array:
    """Gated expert-FFN mixture via Pallas. -> [T, D]"""
    t, d = h.shape
    e = gate.shape[1]
    f = w_in.shape[2]
    if block_t is None:
        block_t = t if t <= 64 else 64
        while t % block_t:
            block_t //= 2
        block_t = max(block_t, 1)
    grid = (e, t // block_t)
    kernel = functools.partial(_expert_mlp_kernel, skip_zero_gate=skip_zero_gate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ee, tt: (tt, 0)),
            pl.BlockSpec((block_t, 1), lambda ee, tt: (tt, ee)),
            pl.BlockSpec((1, d, f), lambda ee, tt: (ee, 0, 0)),
            pl.BlockSpec((1, f, d), lambda ee, tt: (ee, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda ee, tt: (tt, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), h.dtype),
        interpret=True,
    )(h, gate, w_in, w_out)
