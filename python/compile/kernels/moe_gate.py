"""Pallas fused top-k MoE router gate.

The backbone's router is the operation MoE-Beyond predicts, so we make it a
first-class fused kernel: logits -> (top-k expert ids, softmax-renormalized
gate weights, dense gate matrix) in one pass over VMEM, with no separate
argsort / scatter HLO ops.

TPU mapping: one grid step per token tile; the [BLOCK_T, E] logit tile
lives in VMEM, the k-step iterative argmax runs on the VPU (k is 6 — a
serial scan beats a full sort for E = 64), and the dense gate tile is
emitted in place for the downstream expert-FFN kernel.  E <= 64 keeps a
whole row in one vector register row on real hardware.

interpret=True: see attention.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(logits_ref, ids_ref, w_ref, dense_ref, *, k: int):
    logits = logits_ref[...]  # [bt, E]
    bt, e = logits.shape
    neg = jnp.asarray(-1e30, logits.dtype)

    def body(i, carry):
        work, ids, vals = carry
        j = jnp.argmax(work, axis=-1)  # [bt]
        top = jnp.take_along_axis(work, j[:, None], axis=-1)[:, 0]
        ids = ids.at[:, i].set(j.astype(jnp.int32))
        vals = vals.at[:, i].set(top)
        work = work.at[jnp.arange(bt), j].set(neg)
        return work, ids, vals

    ids0 = jnp.zeros((bt, k), jnp.int32)
    vals0 = jnp.zeros((bt, k), logits.dtype)
    _, ids, vals = jax.lax.fori_loop(0, k, body, (logits, ids0, vals0))

    # softmax over the selected logits (paper: gate renormalization)
    m = jnp.max(vals, axis=-1, keepdims=True)
    p = jnp.exp(vals - m)
    w = p / jnp.sum(p, axis=-1, keepdims=True)

    dense = jnp.zeros((bt, e), logits.dtype)
    rows = jnp.arange(bt)[:, None]
    dense = dense.at[rows, ids].set(w)

    ids_ref[...] = ids
    w_ref[...] = w.astype(w_ref.dtype)
    dense_ref[...] = dense.astype(dense_ref.dtype)


def topk_gate(logits: jax.Array, k: int, block_t: int | None = None):
    """Fused top-k gate. logits [T, E] ->
    (ids [T,k] i32, weights [T,k], dense [T,E])."""
    t, e = logits.shape
    if block_t is None:
        block_t = t if t <= 128 else 128
        while t % block_t:
            block_t //= 2
        block_t = max(block_t, 1)
    grid = (t // block_t,)
    kernel = functools.partial(_gate_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, e), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), jnp.int32),
            jax.ShapeDtypeStruct((t, k), logits.dtype),
            jax.ShapeDtypeStruct((t, e), logits.dtype),
        ],
        interpret=True,
    )(logits)
