"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each kernel's pytest suite sweeps
shapes/dtypes with hypothesis and asserts allclose against these
implementations.  They are deliberately written in the most obvious way —
no tiling, no fusion — so a reviewer can audit them line by line.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(
    q: jax.Array,  # [T, H, Dh]
    k: jax.Array,  # [T, H, Dh]
    v: jax.Array,  # [T, H, Dh]
    mask: jax.Array,  # [T] 1.0 = real token, 0.0 = pad
    causal: bool = False,
) -> jax.Array:
    """Masked multi-head attention, reference implementation. -> [T, H, Dh]"""
    T, H, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, q.dtype))
    # [H, T, T]
    logits = jnp.einsum("thd,shd->hts", q, k) * scale
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[None, None, :] > 0, logits, neg)
    if causal:
        causal_m = jnp.tril(jnp.ones((T, T), bool))
        logits = jnp.where(causal_m[None, :, :], logits, neg)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hts,shd->thd", w, v)


def topk_gate_ref(logits: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k router gate, reference. logits [T, E] -> (ids [T,k] i32,
    weights [T,k] f32 = softmax over the selected logits)."""
    vals, ids = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    return ids.astype(jnp.int32), w.astype(logits.dtype)


def dense_gate_ref(logits: jax.Array, k: int) -> jax.Array:
    """Dense [T, E] gate matrix: softmax-normalized weights on the top-k
    entries of each row, zero elsewhere."""
    ids, w = topk_gate_ref(logits, k)
    T, E = logits.shape
    g = jnp.zeros((T, E), logits.dtype)
    rows = jnp.arange(T)[:, None]
    return g.at[rows, ids].set(w)


def expert_mlp_ref(
    h: jax.Array,      # [T, D]
    gate: jax.Array,   # [T, E] dense gate weights (mostly zero)
    w_in: jax.Array,   # [E, D, F]
    w_out: jax.Array,  # [E, F, D]
) -> jax.Array:
    """Gated mixture of expert FFNs, reference. -> [T, D]

    out[t] = sum_e gate[t,e] * relu(h[t] @ w_in[e]) @ w_out[e]
    """
    # [E, T, F]
    act = jax.nn.relu(jnp.einsum("td,edf->etf", h, w_in))
    per_expert = jnp.einsum("etf,efd->etd", act, w_out)
    return jnp.einsum("te,etd->td", gate, per_expert)


def rmsnorm_ref(x: jax.Array, g: jax.Array, eps: float = 1e-6) -> jax.Array:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g
