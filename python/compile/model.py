"""L2 JAX models: the MoE-Beyond predictor and the MoE backbone.

Two computations live here, both built on the L1 Pallas kernels and both
AOT-lowered (aot.py) to HLO text that the Rust coordinator executes via
PJRT.  Python never runs on the request path.

1. **Predictor** (paper §3.2): a lightweight transformer encoder over
   [token-embedding ∥ layer-embedding] features with a sigmoid multi-label
   head over the 64 experts.  Architecture follows the paper — linear
   input projection, 4 encoder layers, 8 heads, GELU 2-layer MLP head,
   dropout 0.1 (training only) — at configurable width (paper dims:
   d=512/ffn=2048 over 2048-d DeepSeek embeddings; defaults here are
   width-scaled for CPU build-time training, see DESIGN.md §2).

2. **Backbone** (substitute for DeepSeek-V2-Lite, DESIGN.md §6): a
   from-scratch MoE transformer LM with 27 MoE layers × (64 routed +
   2 shared) experts, top-6 routing, whose router weights come from the
   synthetic world model.  Exposed as fixed-shape `prefill` and
   `decode_step` functions so the whole serving loop is AOT-compilable.

All model weights enter as ONE flat f32 vector (sliced internally) so the
Rust side feeds a single opaque literal per model — the manifest JSON maps
names to slices for debugging.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention, expert_mlp, moe_gate, ref
from .world import WorldConfig

# ---------------------------------------------------------------------------
# Predictor
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    """MoE-Beyond predictor hyper-parameters (paper §3.2.1-§3.2.2)."""

    d_tok: int = 128        # token-embedding dim (paper: 2048)
    n_model_layers: int = 27  # layer-id vocabulary (paper: 27)
    n_experts: int = 64     # output labels (paper: 64)
    d_layer: int = 32       # layer-embedding dim (paper: 512)
    d_model: int = 128      # encoder width (paper: 512)
    n_enc_layers: int = 4   # (paper: 4)
    n_heads: int = 8        # (paper: 8)
    d_ff: int = 512         # feedforward width (paper: 2048)
    window: int = 32        # max sequence length fed at once (paper: 512)
    dropout: float = 0.1    # (paper: 0.1)
    top_k: int = 6          # experts selected at eval (paper: 6)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_in(self) -> int:
        return self.d_tok + self.d_layer


PREDICTOR_PARAM_SPECS = None  # filled lazily by param_specs()


def predictor_param_specs(cfg: PredictorConfig) -> list:
    """Ordered (name, shape) list — single source of truth for the flat
    weight layout shared with Rust."""
    c = cfg
    specs = [
        ("layer_emb", (c.n_model_layers, c.d_layer)),
        ("in_proj_w", (c.d_in, c.d_model)),
        ("in_proj_b", (c.d_model,)),
    ]
    for l in range(c.n_enc_layers):
        p = f"enc{l}_"
        specs += [
            (p + "ln1_g", (c.d_model,)),
            (p + "ln1_b", (c.d_model,)),
            (p + "wq", (c.d_model, c.d_model)),
            (p + "wk", (c.d_model, c.d_model)),
            (p + "wv", (c.d_model, c.d_model)),
            (p + "wo", (c.d_model, c.d_model)),
            (p + "ln2_g", (c.d_model,)),
            (p + "ln2_b", (c.d_model,)),
            (p + "ff_w1", (c.d_model, c.d_ff)),
            (p + "ff_b1", (c.d_ff,)),
            (p + "ff_w2", (c.d_ff, c.d_model)),
            (p + "ff_b2", (c.d_model,)),
        ]
    specs += [
        ("head_w1", (c.d_model, c.d_model)),
        ("head_b1", (c.d_model,)),
        ("head_w2", (c.d_model, c.n_experts)),
        ("head_b2", (c.n_experts,)),
    ]
    return specs


def predictor_init(cfg: PredictorConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    # NOTE: initializing the output bias at the base-rate logit looks like
    # the obvious class-imbalance fix but *freezes* training here: with the
    # bias pre-matched and near-constant features at init the BCE gradient
    # field is ~zero and the run never breaks symmetry (measured: loss flat
    # at 0.3111 for 4 epochs).  Plain zero-bias init descends into the
    # base-rate basin and climbs out by ~step 1500.
    params = {}
    for name, shape in predictor_param_specs(cfg):
        if name.endswith(("_b", "_g")) or name.endswith("ln1_b") or name.endswith("ln2_b"):
            params[name] = (
                np.ones(shape, np.float32)
                if name.endswith("_g")
                else np.zeros(shape, np.float32)
            )
        elif name == "layer_emb":
            params[name] = rng.normal(size=shape).astype(np.float32) * 0.02
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            params[name] = (
                rng.normal(size=shape) * np.sqrt(1.0 / fan_in)
            ).astype(np.float32)
    return params


def predictor_flatten(cfg: PredictorConfig, params: dict) -> Tuple[np.ndarray, list]:
    parts, man, off = [], [], 0
    for name, shape in predictor_param_specs(cfg):
        a = np.ascontiguousarray(params[name], np.float32).reshape(-1)
        assert a.size == int(np.prod(shape)), name
        parts.append(a)
        man.append({"name": name, "offset": off, "size": int(a.size), "shape": list(shape)})
        off += a.size
    return np.concatenate(parts), man


def _as_params(cfg: PredictorConfig, w) -> dict:
    """Accept a flat f32 vector, a list of per-param arrays (AOT input
    convention: one literal per manifest entry, in spec order), or an
    already-named dict; return the named dict.

    A single flat vector is convenient in tests; the AOT artifacts use the
    per-param form because XLA materializes `dynamic_slice` of a large
    flat vector as a copy on every call (measured at ~100 ms/step for the
    33 M-param backbone — EXPERIMENTS.md §Perf).
    """
    specs = predictor_param_specs(cfg)
    if isinstance(w, dict):
        return w
    if isinstance(w, (list, tuple)):
        assert len(w) == len(specs)
        return {name: a.reshape(shape) for (name, shape), a in zip(specs, w)}
    params, off = {}, 0
    for name, shape in specs:
        n = int(np.prod(shape))
        params[name] = jax.lax.dynamic_slice(w, (off,), (n,)).reshape(shape)
        off += n
    return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _dropout(x, rate, key, train):
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def predictor_forward(
    cfg: PredictorConfig,
    wflat: jax.Array,       # [NW] flat f32
    emb: jax.Array,         # [T, d_tok] token embeddings
    layer_ids: jax.Array,   # [T] i32
    mask: jax.Array,        # [T] f32, 1 = real token
    *,
    train: bool = False,
    rng: jax.Array | None = None,
) -> jax.Array:
    """Predictor forward pass -> expert logits [T, n_experts]."""
    c = cfg
    p = _as_params(c, wflat)
    le = p["layer_emb"][layer_ids]                   # [T, d_layer]
    x = jnp.concatenate([emb, le], axis=-1)          # [T, d_in]
    x = x @ p["in_proj_w"] + p["in_proj_b"]          # [T, d_model]

    keys = (
        jax.random.split(rng, 2 * c.n_enc_layers + 1)
        if train
        else [None] * (2 * c.n_enc_layers + 1)
    )
    for l in range(c.n_enc_layers):
        pf = f"enc{l}_"
        h = _layernorm(x, p[pf + "ln1_g"], p[pf + "ln1_b"])
        q = (h @ p[pf + "wq"]).reshape(-1, c.n_heads, c.d_head)
        k = (h @ p[pf + "wk"]).reshape(-1, c.n_heads, c.d_head)
        v = (h @ p[pf + "wv"]).reshape(-1, c.n_heads, c.d_head)
        a = attention.mha(q, k, v, mask)             # L1 Pallas kernel
        a = a.reshape(-1, c.d_model) @ p[pf + "wo"]
        a = _dropout(a, c.dropout, keys[2 * l], train)
        x = x + a
        h = _layernorm(x, p[pf + "ln2_g"], p[pf + "ln2_b"])
        f = jax.nn.gelu(h @ p[pf + "ff_w1"] + p[pf + "ff_b1"])
        f = f @ p[pf + "ff_w2"] + p[pf + "ff_b2"]
        f = _dropout(f, c.dropout, keys[2 * l + 1], train)
        x = x + f

    h = jax.nn.gelu(x @ p["head_w1"] + p["head_b1"])
    logits = h @ p["head_w2"] + p["head_b2"]         # [T, n_experts]
    # padded positions predict nothing
    return jnp.where(mask[:, None] > 0, logits, -30.0)


def predictor_forward_all_layers(
    cfg: PredictorConfig,
    wflat: jax.Array,
    emb: jax.Array,    # [T, d_tok]
    mask: jax.Array,   # [T]
) -> jax.Array:
    """Run the predictor for every model layer id at once -> [L, T, E].

    This is the shape the serving-path prefetcher wants: one PJRT call per
    refresh yields predicted activation probabilities for all 27 layers.
    """
    layer_ids = jnp.arange(cfg.n_model_layers, dtype=jnp.int32)

    def one(layer_id):
        lid = jnp.full((emb.shape[0],), layer_id, jnp.int32)
        return predictor_forward(cfg, wflat, emb, lid, mask)

    return jax.vmap(one)(layer_ids)


# ---------------------------------------------------------------------------
# Backbone (DeepSeek-V2-Lite stand-in)
# ---------------------------------------------------------------------------


def backbone_param_specs(wc: WorldConfig) -> list:
    c = wc
    H, Dh = c.n_heads, c.d_head
    return [
        ("tok_emb", (c.vocab_size, c.d_model)),
        ("router_w", (c.n_layers, c.n_experts, c.d_model)),
        ("wq", (c.n_layers, c.d_model, H * Dh)),
        ("wk", (c.n_layers, c.d_model, H * Dh)),
        ("wv", (c.n_layers, c.d_model, H * Dh)),
        ("wo", (c.n_layers, H * Dh, c.d_model)),
        ("ln1", (c.n_layers, c.d_model)),
        ("ln2", (c.n_layers, c.d_model)),
        ("w_in", (c.n_layers, c.n_experts, c.d_model, c.d_expert)),
        ("w_out", (c.n_layers, c.n_experts, c.d_expert, c.d_model)),
        ("ws_in", (c.n_layers, c.n_shared, c.d_model, c.d_shared)),
        ("ws_out", (c.n_layers, c.n_shared, c.d_shared, c.d_model)),
        ("ln_f", (c.d_model,)),
        ("lm_head", (c.d_model, c.vocab_size)),
    ]


def _backbone_as_params(wc: WorldConfig, w) -> dict:
    """Same input-convention shim as `_as_params`, for the backbone."""
    specs = backbone_param_specs(wc)
    if isinstance(w, dict):
        return w
    if isinstance(w, (list, tuple)):
        assert len(w) == len(specs)
        return {name: a.reshape(shape) for (name, shape), a in zip(specs, w)}
    params, off = {}, 0
    for name, shape in specs:
        n = int(np.prod(shape))
        params[name] = jax.lax.dynamic_slice(w, (off,), (n,)).reshape(shape)
        off += n
    return params


def _layer_stack(p: dict) -> dict:
    """Per-layer stacked views for lax.scan."""
    return {
        k: p[k]
        for k in (
            "router_w", "wq", "wk", "wv", "wo", "ln1", "ln2",
            "w_in", "w_out", "ws_in", "ws_out",
        )
    }


def _moe_block(wc: WorldConfig, lp: dict, h: jax.Array, use_pallas_ffn: bool = False):
    """Router + routed experts + shared experts for a [T, D] tile.

    Returns (delta [T, D], topk ids [T, k]).

    The router gate is always the L1 Pallas kernel (the op MoE-Beyond
    predicts).  The expert mix has two lowerings verified equal by pytest:
    the Pallas `expert_mlp` kernel (per-expert VMEM-resident schedule —
    the one you would compile for real TPUs) and a dense einsum.  On this
    CPU testbed interpret-mode grid emulation costs ~0.8 ms/step × 64
    experts, so shipped artifacts default to the einsum lowering
    (EXPERIMENTS.md §Perf records the measurement).
    """
    logits = (h @ lp["router_w"].T) / wc.router_temp          # [T, E]
    ids, _w, dense = moe_gate.topk_gate(logits, wc.top_k)     # L1 kernel
    if use_pallas_ffn:
        routed = expert_mlp.expert_mlp(h, dense, lp["w_in"], lp["w_out"])  # L1
    else:
        routed = ref.expert_mlp_ref(h, dense, lp["w_in"], lp["w_out"])
    shared = jnp.zeros_like(h)
    for s in range(wc.n_shared):
        shared = shared + jnp.maximum(h @ lp["ws_in"][s], 0.0) @ lp["ws_out"][s]
    return routed + shared, ids


def backbone_prefill(
    wc: WorldConfig,
    wflat: jax.Array,
    tokens: jax.Array,   # [P] i32 (padded)
    n: jax.Array,        # scalar i32: number of real tokens
):
    """Prefill P prompt positions in one shot.

    Returns (kv [L, 2, S, H*Dh], router_ids [L, P, k] i32,
             embs [P, D], last_logits [V]).
    """
    c = wc
    p = _backbone_as_params(c, wflat)
    P = tokens.shape[0]
    S = c.max_seq
    D, H, Dh = c.d_model, c.n_heads, c.d_head
    mask = (jnp.arange(P) < n).astype(jnp.float32)

    x0 = p["tok_emb"][tokens]            # [P, D]
    x = x0

    def layer_fn(x, lp):
        h = ref.rmsnorm_ref(x, lp["ln1"])
        q = (h @ lp["wq"]).reshape(P, H, Dh)
        k = (h @ lp["wk"]).reshape(P, H, Dh)
        v = (h @ lp["wv"]).reshape(P, H, Dh)
        a = attention.mha(q, k, v, mask, causal=True)  # L1 Pallas kernel
        x = x + a.reshape(P, H * Dh) @ lp["wo"]
        h2 = ref.rmsnorm_ref(x, lp["ln2"])
        delta, ids = _moe_block(c, lp, h2)
        x = x + delta
        kv_l = jnp.stack(
            [
                jnp.pad(k.reshape(P, H * Dh), ((0, S - P), (0, 0))),
                jnp.pad(v.reshape(P, H * Dh), ((0, S - P), (0, 0))),
            ]
        )  # [2, S, H*Dh]
        return x, (kv_l, ids)

    x, (kv, ids) = jax.lax.scan(layer_fn, x, _layer_stack(p))
    # kv: [L, 2, S, H*Dh]; ids: [L, P, k]
    last = jnp.clip(n - 1, 0, P - 1)
    xf = ref.rmsnorm_ref(x[last], p["ln_f"])
    logits = xf @ p["lm_head"]
    return kv, ids, x0, logits


def _moe_block_sparse(wc: WorldConfig, lp: dict, h: jax.Array):
    """Sparse single-token MoE block: gather ONLY the top-k experts'
    weights and compute their FFNs (what a real MoE serving system does).

    The dense `_moe_block` streams all E=64 experts' weights per token
    (~113 MB of reads across 27 layers) and is memory-bandwidth-bound on
    CPU; gathering the 6 selected experts cuts that 10.7x.  Verified
    equal to the dense path by `test_sparse_decode_matches_dense`.

    h: [D].  Returns (delta [D], ids [k]).
    """
    logits = (lp["router_w"] @ h) / wc.router_temp              # [E]
    ids, w, _dense = moe_gate.topk_gate(logits[None, :], wc.top_k)  # L1 kernel
    ids0, w0 = ids[0], w[0]                                     # [k], [k]
    w_in_sel = jnp.take(lp["w_in"], ids0, axis=0)               # [k, D, F]
    w_out_sel = jnp.take(lp["w_out"], ids0, axis=0)             # [k, F, D]
    act = jnp.maximum(jnp.einsum("d,kdf->kf", h, w_in_sel), 0.0)
    routed = jnp.einsum("kf,kfd->d", act * w0[:, None], w_out_sel)
    shared = jnp.zeros_like(h)
    for s in range(wc.n_shared):
        shared = shared + jnp.maximum(h @ lp["ws_in"][s], 0.0) @ lp["ws_out"][s]
    return routed + shared, ids0


def backbone_decode_step(
    wc: WorldConfig,
    wflat: jax.Array,
    kv: jax.Array,      # [L, 2, S, H*Dh]
    pos: jax.Array,     # scalar i32: index of the token being decoded
    token: jax.Array,   # scalar i32
):
    """One autoregressive decode step with fixed-shape KV state.

    Returns (kv', logits [V], router_ids [L, k] i32, emb [D]).
    """
    c = wc
    p = _backbone_as_params(c, wflat)
    S = c.max_seq
    D, H, Dh = c.d_model, c.n_heads, c.d_head

    x0 = p["tok_emb"][token]             # [D]
    x = x0
    kmask = (jnp.arange(S) <= pos).astype(jnp.float32)  # attend to <= pos

    def layer_fn(carry, inp):
        x = carry
        lp, kv_l = inp
        h = ref.rmsnorm_ref(x, lp["ln1"])
        q = (h @ lp["wq"]).reshape(H, Dh)
        knew = h @ lp["wk"]
        vnew = h @ lp["wv"]
        kv_l = jax.lax.dynamic_update_slice(kv_l, knew[None, None, :], (0, pos, 0))
        kv_l = jax.lax.dynamic_update_slice(kv_l, vnew[None, None, :], (1, pos, 0))
        kk = kv_l[0].reshape(S, H, Dh)
        vv = kv_l[1].reshape(S, H, Dh)
        # single-query attention over the cache (plain jnp: T=1)
        logit = jnp.einsum("hd,shd->hs", q, kk) / jnp.sqrt(float(Dh))
        logit = jnp.where(kmask[None, :] > 0, logit, -1e30)
        w = jax.nn.softmax(logit, axis=-1)
        a = jnp.einsum("hs,shd->hd", w, vv).reshape(H * Dh)
        x = x + a @ lp["wo"]
        h2 = ref.rmsnorm_ref(x, lp["ln2"])
        delta, ids = _moe_block_sparse(c, lp, h2)
        x = x + delta
        return x, (kv_l, ids)

    x, (kv2, ids) = jax.lax.scan(layer_fn, x, (_layer_stack(p), kv))
    xf = ref.rmsnorm_ref(x, p["ln_f"])
    logits = xf @ p["lm_head"]
    return kv2, logits, ids, x0
