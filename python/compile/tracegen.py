"""Expert-activation trace extraction (paper §4.1.2, Contribution 2).

The paper runs 6,994 Puffin prompts (train) and 100 WebGLM-QA prompts
(test) through DeepSeek-V2-Lite and records, per generated token: layer
id, batch number, token, activated expert ids, and the token embedding —
~66 M training trace points.

Here we extract the same schema from the synthetic world (DESIGN.md §2/§6)
in two modes:

  * ``analytic`` (default): sample routing straight from the world model's
    gumbel-perturbed router logits — fast, used for the bulk training set.
  * ``backbone``: run the actual JAX backbone (prefill) and record its
    *real* router decisions — used for an extra validation split proving
    the predictor transfers to genuine model traces.

Traces are written in the MBTR binary format shared with the Rust side
(`rust/src/trace/store.rs` mirrors this layout):

  header:  magic  u32 = 0x4D425452 ("MBTR" LE)
           version u32 = 1
           n_layers u16, n_experts u16, top_k u16, d_emb u16
           n_prompts u32
           flags u32  (bit0: embeddings present)
  per prompt:
           prompt_id u32, n_tokens u32
           tokens      i32 [n_tokens]
           embeddings  f32 [n_tokens, d_emb]          (if flag bit0)
           experts     u8  [n_tokens, n_layers, top_k]
"""

from __future__ import annotations

import os
import struct
from dataclasses import dataclass

import numpy as np

from .world import CorpusConfig, PromptSampler, World

MAGIC = 0x4D425452
VERSION = 1


@dataclass
class PromptTrace:
    prompt_id: int
    tokens: np.ndarray      # [T] i32
    embeddings: np.ndarray  # [T, D] f32
    experts: np.ndarray     # [T, L, K] u8


def sample_prompt_trace(
    world: World, sampler: PromptSampler, prompt_id: int, rng: np.random.Generator
) -> PromptTrace:
    """Analytic mode: routing sampled from the world's router logits."""
    toks, _mix = sampler.sample_prompt()
    emb = world.token_emb[toks]  # [T, D]
    route = world.route_vectors(emb)  # token-embedding/context blend
    L, K = world.cfg.n_layers, world.cfg.top_k
    T = toks.shape[0]
    experts = np.empty((T, L, K), dtype=np.uint8)
    for l in range(L):
        experts[:, l, :] = world.sample_topk(route, l, rng).astype(np.uint8)
    return PromptTrace(prompt_id, toks.astype(np.int32), emb, experts)


def backbone_prompt_trace(
    world: World,
    wlist,
    prefill_fn,
    sampler: PromptSampler,
    prompt_id: int,
) -> PromptTrace:
    """Backbone mode: routing recorded from the real JAX model."""
    import jax.numpy as jnp

    c = world.cfg
    toks, _ = sampler.sample_prompt()
    P = min(len(toks), c.max_seq)
    toks = toks[:P]
    pad = np.zeros(c.max_seq, np.int32)
    pad[:P] = toks
    _kv, ids, x0, _lg = prefill_fn(wlist, jnp.asarray(pad), jnp.int32(P))
    ids = np.asarray(ids)   # [L, maxseq, K]
    x0 = np.asarray(x0)     # [maxseq, D]
    experts = np.transpose(ids[:, :P, :], (1, 0, 2)).astype(np.uint8)  # [T,L,K]
    return PromptTrace(prompt_id, toks.astype(np.int32), x0[:P], experts)


def write_traces(path: str, world: World, traces: "list[PromptTrace]", with_emb: bool = True) -> None:
    c = world.cfg
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(
            struct.pack(
                "<IIHHHHII",
                MAGIC,
                VERSION,
                c.n_layers,
                c.n_experts,
                c.top_k,
                c.d_model,
                len(traces),
                1 if with_emb else 0,
            )
        )
        for tr in traces:
            T = len(tr.tokens)
            f.write(struct.pack("<II", tr.prompt_id, T))
            f.write(np.ascontiguousarray(tr.tokens, "<i4").tobytes())
            if with_emb:
                f.write(np.ascontiguousarray(tr.embeddings, "<f4").tobytes())
            assert tr.experts.shape == (T, c.n_layers, c.top_k)
            f.write(np.ascontiguousarray(tr.experts, np.uint8).tobytes())


def read_traces(path: str) -> "tuple[dict, list[PromptTrace]]":
    with open(path, "rb") as f:
        hdr = struct.unpack("<IIHHHHII", f.read(24))
        magic, version, L, E, K, D, n_prompts, flags = hdr
        assert magic == MAGIC and version == VERSION, "bad trace file"
        meta = dict(
            n_layers=L, n_experts=E, top_k=K, d_emb=D, n_prompts=n_prompts, flags=flags
        )
        out = []
        for _ in range(n_prompts):
            pid, T = struct.unpack("<II", f.read(8))
            toks = np.frombuffer(f.read(4 * T), "<i4")
            emb = (
                np.frombuffer(f.read(4 * T * D), "<f4").reshape(T, D)
                if flags & 1
                else np.zeros((T, D), np.float32)
            )
            ex = np.frombuffer(f.read(T * L * K), np.uint8).reshape(T, L, K)
            out.append(PromptTrace(pid, toks.copy(), emb.copy(), ex.copy()))
    return meta, out


def generate_split(
    world: World,
    split: str,
    n_prompts: int,
    out_path: str,
    corpus_seed: int = 7,
    mode: str = "analytic",
) -> "list[PromptTrace]":
    ccfg = CorpusConfig(seed=corpus_seed, n_prompts=n_prompts, split=("test" if split == "test" else "train"))
    sampler = PromptSampler(world, ccfg)
    rng = np.random.default_rng(world.cfg.seed ^ hash(split) & 0xFFFF_FFFF)

    prefill_fn = None
    wlist = None
    if mode == "backbone":
        import jax
        import jax.numpy as jnp

        from . import model as model_mod
        from .world import build_backbone_params

        params = build_backbone_params(world)
        wlist = [jnp.asarray(params[n]) for n, _ in model_mod.backbone_param_specs(world.cfg)]
        prefill_fn = jax.jit(
            lambda wl, t, n: model_mod.backbone_prefill(world.cfg, wl, t, n)
        )

    traces = []
    base = {"train": 0, "val": 1_000_000, "test": 2_000_000, "backbone_val": 3_000_000}.get(split, 4_000_000)
    for i in range(n_prompts):
        if mode == "backbone":
            tr = backbone_prompt_trace(world, wlist, prefill_fn, sampler, base + i)
        else:
            tr = sample_prompt_trace(world, sampler, base + i, rng)
        traces.append(tr)
    write_traces(out_path, world, traces)
    return traces


def trace_point_count(traces: "list[PromptTrace]") -> int:
    """Number of (token, layer) trace points, the unit the paper counts."""
    return sum(len(t.tokens) for t in traces) * (traces[0].experts.shape[1] if traces else 0)
