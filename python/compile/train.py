"""Build-time training of the MoE-Beyond predictor (paper §3.2.3-§3.2.6).

Runs ONCE under `make artifacts`; the trained weights are exported as a
flat f32 blob + JSON manifest that the Rust runtime feeds to the AOT
predictor executable.  Python never trains (or runs) on the request path.

Faithful to the paper's training protocol:
  * AdamW, betas (0.9, 0.98), L2 weight decay 0.01
  * layer-wise learning rates: input-proj 1e-4, encoder 0.9e-4, head 0.8e-4
  * gradient-norm clipping at 1.0
  * BCE-with-logits multi-label loss over the 64 experts
  * early stopping after 3 epochs without val-loss improvement
  * metrics: element-wise accuracy, macro-F1 over experts, exact-set match
    (the paper's "position-wise accuracy"), logged per step to
    artifacts/training_log.json (the data behind Figs 5-6)

The paper uses PyTorch AMP on A100s (~48 GPU-hours); we train the
width-scaled config in pure JAX on CPU in minutes (DESIGN.md §2).  optax
is not available in this image, so AdamW is hand-rolled below.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_mod
from .model import PredictorConfig
from .tracegen import PromptTrace


@dataclass
class TrainConfig:
    batch_size: int = 16        # paper: 4 (scaled with our smaller window)
    steps_per_epoch: int = 400
    max_epochs: int = 26        # paper: 10 (our steps/epoch are much smaller)
    patience: int = 6           # paper: 3 (lengthened: our plateau-escape takes ~4 epochs)
    # Paper LRs are 1e-4 / 0.9e-4 / 0.8e-4 for its d=512 model on 66M
    # samples; our width-scaled model converges ~10x faster with the same
    # group ratios scaled up (verified by a single-batch overfit probe —
    # at 1e-4 the run stalls at the base-rate plateau for >1.5k steps).
    lr_input: float = 1.0e-3
    lr_encoder: float = 0.9e-3
    lr_head: float = 0.8e-3
    beta1: float = 0.9
    beta2: float = 0.98
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    seed: int = 0
    val_batches: int = 24
    log_every: int = 20


def lr_group(name: str) -> str:
    if name.startswith(("in_proj", "layer_emb")):
        return "input"
    if name.startswith("head"):
        return "head"
    return "encoder"


# ---------------------------------------------------------------------------
# Data pipeline: (prompt, layer) -> token window samples
# ---------------------------------------------------------------------------


class TraceSampler:
    """Samples training batches from prompt traces.

    A sample is a window of up to `window` consecutive tokens of one prompt
    at one model layer: inputs (embeddings, layer id), targets multi-hot
    expert vectors — exactly the paper's §3.2.1 formulation.
    """

    def __init__(self, traces: "list[PromptTrace]", cfg: PredictorConfig, seed: int):
        assert traces, "no traces"
        self.traces = traces
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)

    def batch(self, batch_size: int):
        c = self.cfg
        T = c.window
        B = batch_size
        emb = np.zeros((B, T, c.d_tok), np.float32)
        lids = np.zeros((B, T), np.int32)
        mask = np.zeros((B, T), np.float32)
        y = np.zeros((B, T, c.n_experts), np.float32)
        for b in range(B):
            tr = self.traces[self.rng.integers(len(self.traces))]
            layer = int(self.rng.integers(c.n_model_layers))
            n = len(tr.tokens)
            start = 0 if n <= T else int(self.rng.integers(n - T + 1))
            w = min(T, n - start)
            emb[b, :w] = tr.embeddings[start : start + w]
            lids[b, :] = layer
            mask[b, :w] = 1.0
            ex = tr.experts[start : start + w, layer, :]  # [w, k]
            rows = np.repeat(np.arange(w), ex.shape[1])
            y[b, rows, ex.reshape(-1)] = 1.0
        return emb, lids, mask, y


# ---------------------------------------------------------------------------
# Loss, metrics, optimizer
# ---------------------------------------------------------------------------


def bce_loss(logits, y, mask):
    """Mean BCE-with-logits over real (unmasked) positions."""
    per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    per = per.mean(axis=-1)  # [B, T]
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per * mask).sum() / denom


def batch_metrics(logits, y, mask, top_k: int):
    """(elementwise accuracy, exact top-k set match, tp/fp/fn per expert)."""
    pred = (jax.nn.sigmoid(logits) > 0.5).astype(jnp.float32)
    m3 = mask[..., None]
    correct = ((pred == y).astype(jnp.float32) * m3).sum()
    total = jnp.maximum(m3.sum() * 1.0, 1.0) * logits.shape[-1] / logits.shape[-1]
    total = jnp.maximum((mask.sum() * logits.shape[-1]), 1.0)
    acc = correct / total

    # exact set match via top-k ids (paper's position-wise accuracy)
    k = top_k
    _, pid = jax.lax.top_k(logits, k)
    phot = jnp.zeros_like(y).at[
        jnp.arange(y.shape[0])[:, None, None],
        jnp.arange(y.shape[1])[None, :, None],
        pid,
    ].set(1.0)
    exact = (jnp.abs(phot - y).sum(-1) == 0).astype(jnp.float32)
    exact = (exact * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    tp = (pred * y * m3).sum(axis=(0, 1))
    fp = (pred * (1 - y) * m3).sum(axis=(0, 1))
    fn = ((1 - pred) * y * m3).sum(axis=(0, 1))
    return acc, exact, tp, fp, fn


def macro_f1(tp, fp, fn):
    prec = tp / np.maximum(tp + fp, 1e-9)
    rec = tp / np.maximum(tp + fn, 1e-9)
    f1 = 2 * prec * rec / np.maximum(prec + rec, 1e-9)
    return float(f1.mean())


def adamw_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lrs, tc: TrainConfig):
    """Hand-rolled AdamW with per-param-group LRs + global grad clip."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, tc.clip_norm / jnp.maximum(gnorm, 1e-9))
    t = state["t"] + 1
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** t.astype(jnp.float32)
    bc2 = 1.0 - b2 ** t.astype(jnp.float32)

    new_p, new_m, new_v = {}, {}, {}
    for name, p in params.items():
        g = grads[name] * scale
        m = b1 * state["m"][name] + (1 - b1) * g
        v = b2 * state["v"][name] + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        lr = lrs[name]
        upd = mhat / (jnp.sqrt(vhat) + 1e-8) + tc.weight_decay * p
        new_p[name] = p - lr * upd
        new_m[name] = m
        new_v[name] = v
    return new_p, {"m": new_m, "v": new_v, "t": t}, gnorm


# ---------------------------------------------------------------------------
# Training driver
# ---------------------------------------------------------------------------


def train_predictor(
    pc: PredictorConfig,
    tc: TrainConfig,
    train_traces: "list[PromptTrace]",
    val_traces: "list[PromptTrace]",
    out_dir: str,
    fingerprint: str,
    quiet: bool = False,
):
    """Train, early-stop on val loss, export weights + training log.

    Returns (best_params, log_dict).
    """
    params = {k: jnp.asarray(v) for k, v in model_mod.predictor_init(pc, tc.seed).items()}
    lrs = {
        name: {"input": tc.lr_input, "encoder": tc.lr_encoder, "head": tc.lr_head}[
            lr_group(name)
        ]
        for name in params
    }
    opt = adamw_init(params)
    train_s = TraceSampler(train_traces, pc, tc.seed + 1)
    val_s = TraceSampler(val_traces, pc, tc.seed + 2)
    key = jax.random.PRNGKey(tc.seed)

    def loss_fn(p, emb, lids, mask, y, rng):
        logits = jax.vmap(
            lambda e, l, m: model_mod.predictor_forward(
                pc, p, e, l, m, train=True, rng=rng
            )
        )(emb, lids, mask)
        return bce_loss(logits, y, mask), logits

    @jax.jit
    def train_step(p, opt, emb, lids, mask, y, rng):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, emb, lids, mask, y, rng
        )
        p2, opt2, gnorm = adamw_update(p, grads, opt, lrs, tc)
        acc, exact, tp, fp, fn = batch_metrics(logits, y, mask, pc.top_k)
        return p2, opt2, loss, acc, exact, tp, fp, fn, gnorm

    @jax.jit
    def eval_step(p, emb, lids, mask, y):
        logits = jax.vmap(
            lambda e, l, m: model_mod.predictor_forward(pc, p, e, l, m)
        )(emb, lids, mask)
        loss = bce_loss(logits, y, mask)
        acc, exact, tp, fp, fn = batch_metrics(logits, y, mask, pc.top_k)
        return loss, acc, exact, tp, fp, fn

    # fixed validation set for comparable epoch-to-epoch numbers
    val_batches = [val_s.batch(tc.batch_size) for _ in range(tc.val_batches)]

    log = {
        "train_steps": [],   # {step, loss, acc, f1, exact}
        "val_epochs": [],    # {epoch, loss, acc, f1, exact}
        "config": {"predictor": asdict(pc), "train": asdict(tc)},
    }
    best = {"loss": float("inf"), "params": None, "epoch": -1}
    step = 0
    t_start = time.time()

    for epoch in range(tc.max_epochs):
        ep_tp = np.zeros(pc.n_experts)
        ep_fp = np.zeros(pc.n_experts)
        ep_fn = np.zeros(pc.n_experts)
        for _ in range(tc.steps_per_epoch):
            emb, lids, mask, y = train_s.batch(tc.batch_size)
            key, sub = jax.random.split(key)
            params, opt, loss, acc, exact, tp, fp, fn, gnorm = train_step(
                params, opt, emb, lids, mask, y, sub
            )
            ep_tp += np.asarray(tp); ep_fp += np.asarray(fp); ep_fn += np.asarray(fn)
            if step % tc.log_every == 0:
                f1 = macro_f1(np.asarray(tp), np.asarray(fp), np.asarray(fn))
                log["train_steps"].append(
                    {
                        "step": step,
                        "loss": float(loss),
                        "acc": float(acc),
                        "f1": f1,
                        "exact": float(exact),
                    }
                )
                if not quiet:
                    print(
                        f"  step {step:5d} loss {float(loss):.4f} acc {float(acc):.4f} "
                        f"f1 {f1:.3f} exact {float(exact):.3f}",
                        flush=True,
                    )
            step += 1

        # ---- validation epoch
        v_loss = 0.0
        v_acc = 0.0
        v_exact = 0.0
        v_tp = np.zeros(pc.n_experts)
        v_fp = np.zeros(pc.n_experts)
        v_fn = np.zeros(pc.n_experts)
        for vb in val_batches:
            loss, acc, exact, tp, fp, fn = eval_step(params, *vb)
            v_loss += float(loss); v_acc += float(acc); v_exact += float(exact)
            v_tp += np.asarray(tp); v_fp += np.asarray(fp); v_fn += np.asarray(fn)
        nb = len(val_batches)
        v_loss /= nb; v_acc /= nb; v_exact /= nb
        v_f1 = macro_f1(v_tp, v_fp, v_fn)
        log["val_epochs"].append(
            {"epoch": epoch, "loss": v_loss, "acc": v_acc, "f1": v_f1, "exact": v_exact}
        )
        if not quiet:
            print(
                f"epoch {epoch}: val loss {v_loss:.4f} acc {v_acc:.4f} f1 {v_f1:.3f} "
                f"exact {v_exact:.3f} ({time.time()-t_start:.0f}s)",
                flush=True,
            )
        if v_loss < best["loss"] - 1e-5:
            best = {"loss": v_loss, "params": jax.tree.map(np.asarray, params), "epoch": epoch}
        elif epoch - best["epoch"] >= tc.patience:
            if not quiet:
                print(f"early stop at epoch {epoch} (best epoch {best['epoch']})")
            break

    log["wall_seconds"] = time.time() - t_start
    best_params = best["params"] if best["params"] is not None else jax.tree.map(
        np.asarray, params
    )

    os.makedirs(out_dir, exist_ok=True)
    flat, man = model_mod.predictor_flatten(pc, best_params)
    flat.astype("<f4").tofile(os.path.join(out_dir, "predictor_weights.bin"))
    with open(os.path.join(out_dir, "predictor_weights.bin.json"), "w") as f:
        json.dump(
            {
                "total_f32": int(flat.size),
                "params": man,
                "fingerprint": fingerprint,
                "best_epoch": best["epoch"],
                "best_val_loss": best["loss"],
                "predictor_config": asdict(pc),
            },
            f,
            indent=2,
        )
    with open(os.path.join(out_dir, "training_log.json"), "w") as f:
        json.dump(log, f)
    return best_params, log
