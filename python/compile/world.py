"""Synthetic "semantic world" model for MoE-Beyond reproduction.

The paper extracts expert-activation traces from DeepSeek-V2-Lite (27 MoE
layers, 64 routed experts, top-6 routing) over the Puffin / WebGLM-QA
corpora.  We do not have that model or those corpora; per DESIGN.md §2 we
substitute a seeded *world model* that reproduces the statistical structure
the predictor exploits:

  * K topics; each (topic, layer) has a sparse Dirichlet expert-affinity
    vector (4-8 dominant experts) -> single-prompt skew (paper Fig 2).
  * Topic->expert maps are balanced across the pool -> cross-prompt
    uniformity (paper Fig 1).
  * Affinities at layer l+1 mix layer l's (permuted) affinities with fresh
    draws -> cross-layer reuse bands (paper Fig 3).
  * Prompts draw 1-3 topic mixtures; token embeddings are topic embeddings
    plus noise -> a learnable embedding->experts mapping, which is exactly
    the signal MoE-Beyond's transformer learns.

The same world parameterizes the from-scratch MoE backbone (see model.py):
its router weights are constructed from the topic affinities, so traces
produced by *running the backbone HLO* exhibit the same statistics as
traces sampled analytically from the world.

Everything is derived from a single integer seed and exported to
``artifacts/world.json`` (metadata + RNG seeds) and
``artifacts/backbone_weights.bin`` (constructed backbone params), so the
Rust side can regenerate identical workloads.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorldConfig:
    """Dimensions of the synthetic world + backbone.

    Defaults mirror DeepSeek-V2-Lite's routing topology (27 MoE layers,
    64 routed experts, top-6, 2 shared experts) at reduced width.
    """

    seed: int = 20250710
    n_layers: int = 27          # MoE layers (paper: 27)
    n_experts: int = 64         # routed experts per layer (paper: 64)
    top_k: int = 6              # experts activated per token (paper: 6)
    n_shared: int = 2           # shared (always-active) experts (paper: 2)
    n_topics: int = 40          # latent semantic topics
    d_model: int = 128          # backbone embedding width (paper: 2048)
    vocab_size: int = 4096      # synthetic vocabulary
    working_set: int = 10       # experts per (topic, layer) working set
    weight_alpha: float = 1.2   # Dirichlet for within-working-set weights
    layer_mix: float = 0.62     # fraction of working set carried to next layer
    router_temp: float = 1.0    # router logit temperature
    router_noise: float = 0.5   # gumbel noise scale on analytic router logits
    ctx_alpha: float = 0.75     # EMA coefficient of the routing context
    route_beta: float = 0.6     # token-embedding share of the routing vector
                                # (rest is the EMA context; token-level
                                # idiosyncrasy is the dynamic the learned
                                # predictor captures and heuristics cannot)
    score_floor: float = 1e-4   # affinity floor (sets in/out logit gap)
    topic_tokens_frac: float = 0.75  # fraction of vocab assigned to topics
    # backbone transformer dims
    n_heads: int = 4
    d_head: int = 32
    d_expert: int = 64          # routed expert FFN hidden dim
    d_shared: int = 128         # shared expert FFN hidden dim
    max_seq: int = 160          # KV buffer length in the decode artifact

    def validate(self) -> None:
        assert self.n_experts <= 64, "ExpertSet on the Rust side is a u64 bitset"
        assert self.top_k < self.n_experts
        assert self.n_heads * self.d_head == self.d_model
        assert 0.0 <= self.layer_mix <= 1.0


# ---------------------------------------------------------------------------
# World construction
# ---------------------------------------------------------------------------


class World:
    """Seeded synthetic world: topics, affinities, embeddings, vocab."""

    def __init__(self, cfg: WorldConfig):
        cfg.validate()
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)

        # --- topic -> expert working sets, per layer, with cross-layer carry.
        #
        # Each (topic, layer) owns a *working set* of `working_set` experts
        # with Dirichlet-decaying weights; everything else sits at the
        # score floor.  This is what produces the paper's three phenomena:
        # Fig 2 (single-prompt skew: top-6 routing stays inside the
        # prompt's 10-25-expert topical working set), Fig 3 (reuse bands:
        # `layer_mix` of each working set is carried — through a per-layer
        # expert permutation — to the next layer), and Fig 1 (cross-prompt
        # uniformity: working sets are assigned with greedy load balancing
        # so every expert serves ~W*K/E topics).
        L, K, E = cfg.n_layers, cfg.n_topics, cfg.n_experts
        W = cfg.working_set
        affin = np.full((L, K, E), cfg.score_floor, dtype=np.float64)
        ws = np.zeros((L, K, W), dtype=np.int64)
        self.layer_perm = np.stack([rng.permutation(E) for _ in range(L)], axis=0)
        inv_perm = np.empty_like(self.layer_perm)
        for l in range(L):
            inv_perm[l, self.layer_perm[l]] = np.arange(E)

        n_carry = int(round(cfg.layer_mix * W))
        for l in range(L):
            # `load` tracks expected *weighted* activations per expert so the
            # multi-prompt marginal comes out flat (paper Fig 1's 800-1400
            # band), not just working-set membership counts.
            load = np.zeros(E)
            for t in rng.permutation(K):
                chosen: list[int] = []
                if l > 0:
                    # carry a layer_mix fraction of the previous working set,
                    # relabelled by this layer's expert permutation
                    prev = self.layer_perm[l][ws[l - 1, t]]
                    keep = rng.permutation(W)[:n_carry]
                    chosen = list(dict.fromkeys(prev[keep].tolist()))
                # fill the rest greedily from the least-loaded experts
                free = [e for e in np.argsort(load + rng.uniform(0, 0.05, E)) if e not in chosen]
                chosen = (chosen + [int(e) for e in free])[:W]
                ws[l, t] = np.asarray(chosen)
                # decaying weights; the LARGEST weight goes to the currently
                # least-loaded chosen expert, equalizing *activation*
                # popularity.  Load is incremented by the empirical
                # P(in top-6 | weight rank) for this noise level (measured
                # offline, 20k gumbel trials) — activation probability, not
                # gate weight, is what Fig 1 histograms.
                p_top6 = np.array(
                    [0.984, 0.955, 0.909, 0.834, 0.734, 0.612, 0.444, 0.290, 0.166, 0.068]
                )
                p_rank = np.interp(np.linspace(0, 9, W), np.arange(10), p_top6)
                wgt = np.sort(rng.dirichlet([cfg.weight_alpha] * W))[::-1]
                order = np.argsort(load[ws[l, t]])  # least-loaded first
                assigned = np.empty(W)
                assigned[order] = wgt
                rank_of = np.empty(W, dtype=int)
                rank_of[order] = np.arange(W)       # weight rank per member
                affin[l, t, ws[l, t]] = np.maximum(assigned, cfg.score_floor * 2)
                load[ws[l, t]] += p_rank[rank_of]
        affin /= affin.sum(axis=2, keepdims=True)
        self.affinity = affin.astype(np.float32)
        self.working_sets = ws.astype(np.int32)
        self._popularity = self.affinity.mean(axis=1)  # [L, E]

        # --- topic embeddings: exactly orthonormal (K <= d_model), so a
        # pure-topic token produces zero logit leakage into other topics.
        assert K <= cfg.d_model
        q_mat, _ = np.linalg.qr(rng.normal(size=(cfg.d_model, K)))
        topics = q_mat.T  # [K, D], orthonormal rows
        self.topic_emb = topics.astype(np.float32)

        V = cfg.vocab_size
        n_topic_tok = int(V * cfg.topic_tokens_frac)
        # token -> topic assignment (-1 = common/background token)
        tok_topic = np.full(V, -1, dtype=np.int32)
        tok_topic[:n_topic_tok] = rng.integers(0, K, size=n_topic_tok)
        self.token_topic = tok_topic

        # per-token noise has *norm* ~0.35 (not per-dim std), so topical
        # tokens stay topic-dominated after normalization
        emb = rng.normal(size=(V, cfg.d_model)) * (0.35 / np.sqrt(cfg.d_model))
        for v in range(n_topic_tok):
            emb[v] += topics[tok_topic[v]]
        emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-6)
        self.token_emb = emb.astype(np.float32)

        # --- analytic router weights: logits(l) = W_r[l] @ h ; constructed so
        # a hidden state aligned with topic t yields that topic's (shifted)
        # log-affinities: zero outside the working set, up to
        # -log(score_floor) ~ 9 nats inside it.  Topic rows are orthonormal,
        # so scores superpose cleanly for topic mixtures.
        log_aff = np.log(affin) - np.log(cfg.score_floor)  # >= 0, 0 off-set
        self.router_scores = log_aff.astype(np.float32)    # [L, K, E]
        self.router_w = np.einsum("lte,td->led", log_aff, topics).astype(np.float32)

        self._rng = rng

    # -- analytic routing -------------------------------------------------

    def context_embeddings(self, emb: np.ndarray) -> np.ndarray:
        """EMA context stream over token embeddings (rows), normalized.

        MoE routers condition on the *hidden state*, which carries prompt
        context through attention — not on the raw token embedding.  The
        analytic sampler models that with an exponential moving average:
        ctx_t = a*ctx_{t-1} + (1-a)*emb_t, renormalized.  Non-topical
        (common) tokens thereby route inside the prompt's topical working
        set, exactly like filler words do in a real MoE (paper Fig 2).
        """
        a = self.cfg.ctx_alpha
        out = np.empty_like(emb)
        ctx = emb[0]
        for t in range(emb.shape[0]):
            ctx = a * ctx + (1.0 - a) * emb[t]
            ctx = ctx / max(np.linalg.norm(ctx), 1e-6)
            out[t] = ctx
        return out

    def router_logits(self, emb: np.ndarray, layer: int) -> np.ndarray:
        """Analytic router logits for (context-)embedding rows at ``layer``."""
        return emb @ self.router_w[layer].T / self.cfg.router_temp

    def route_vectors(self, emb: np.ndarray) -> np.ndarray:
        """The vectors routing actually conditions on: a normalized blend
        of the token embedding (token-level dynamics) and the EMA context
        (topical working set) — the residual-stream analogue."""
        b = self.cfg.route_beta
        ctx = self.context_embeddings(emb)
        route = b * emb + (1.0 - b) * ctx
        route /= np.maximum(np.linalg.norm(route, axis=1, keepdims=True), 1e-6)
        return route

    def sample_topk(
        self, emb: np.ndarray, layer: int, rng: np.random.Generator
    ) -> np.ndarray:
        """Sample top-k expert ids (gumbel-perturbed analytic logits)."""
        logits = self.router_logits(emb, layer)
        g = rng.gumbel(size=logits.shape) * self.cfg.router_noise
        pert = logits + g
        k = self.cfg.top_k
        idx = np.argpartition(-pert, k, axis=-1)[..., :k]
        # sort by logit descending for determinism of ordering
        order = np.argsort(-np.take_along_axis(pert, idx, -1), axis=-1)
        return np.take_along_axis(idx, order, -1).astype(np.int32)

    # -- export ------------------------------------------------------------

    def manifest(self) -> dict:
        c = self.cfg
        return {
            "format": "moe-beyond-world-v1",
            "seed": c.seed,
            "n_layers": c.n_layers,
            "n_experts": c.n_experts,
            "top_k": c.top_k,
            "n_shared": c.n_shared,
            "n_topics": c.n_topics,
            "d_model": c.d_model,
            "vocab_size": c.vocab_size,
            "working_set": c.working_set,
            "weight_alpha": c.weight_alpha,
            "score_floor": c.score_floor,
            "layer_mix": c.layer_mix,
            "router_temp": c.router_temp,
            "router_noise": c.router_noise,
            "n_heads": c.n_heads,
            "d_head": c.d_head,
            "d_expert": c.d_expert,
            "d_shared": c.d_shared,
            "max_seq": c.max_seq,
            "fingerprint": self.fingerprint(),
        }

    def fingerprint(self) -> str:
        """Stable fingerprint tying predictor weights to this world."""
        h = np.float64(0.0)
        h += float(np.abs(self.affinity).sum())
        h += float(np.abs(self.token_emb).sum()) * 1e-3
        return f"w{self.cfg.seed}-{h:.6e}"

    def save(self, path: str) -> None:
        """world.json + world.npz (affinities/embeddings for Rust+python)."""
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.manifest(), f, indent=2)
        base = os.path.splitext(path)[0]
        # Raw little-endian blobs: trivially parseable from Rust.
        blobs = {
            "affinity": self.affinity,          # [L,K,E] f32
            "topic_emb": self.topic_emb,        # [K,D]   f32
            "token_emb": self.token_emb,        # [V,D]   f32
            "token_topic": self.token_topic,    # [V]     i32
            "router_w": self.router_w,          # [L,E,D] f32
            "router_scores": self.router_scores,  # [L,K,E] f32
            "working_sets": self.working_sets,  # [L,K,W] i32
            "layer_perm": self.layer_perm.astype(np.int32),  # [L,E]
        }
        man = {}
        off = 0
        with open(base + ".bin", "wb") as f:
            for name, arr in blobs.items():
                raw = np.ascontiguousarray(arr).tobytes()
                f.write(raw)
                man[name] = {
                    "offset": off,
                    "nbytes": len(raw),
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
                off += len(raw)
        with open(base + ".blobs.json", "w") as f:
            json.dump(man, f, indent=2)


# ---------------------------------------------------------------------------
# Prompt corpus ("puffin-syn" train split / "webglm-syn" test split)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    """Synthetic prompt corpus: topic-mixture prompts, multi-turn shaped.

    Train and test splits draw from disjoint topic-mixture distributions
    (test mixes lean on a held-out topic subset) to model the paper's
    Puffin -> WebGLM-QA domain shift.
    """

    seed: int = 7
    n_prompts: int = 600
    min_tokens: int = 48
    max_tokens: int = 200
    max_topics_per_prompt: int = 3
    common_token_prob: float = 0.22
    split: str = "train"        # "train" | "test"
    held_out_frac: float = 0.25  # topics reserved for extra weight in test


class PromptSampler:
    """Samples synthetic prompts (token-id sequences + latent topic mix)."""

    def __init__(self, world: World, cfg: CorpusConfig):
        self.world = world
        self.cfg = cfg
        self.rng = np.random.default_rng(
            (world.cfg.seed * 1_000_003) ^ (cfg.seed * 97 + (0 if cfg.split == "train" else 1))
        )
        K = world.cfg.n_topics
        n_held = max(1, int(K * cfg.held_out_frac))
        self.held_out = np.arange(K - n_held, K)
        self.main = np.arange(0, K - n_held)
        self._deck: list[int] = []

    def _next_from_deck(self) -> int:
        # Primary topics cycle a shuffled deck: main topics appear at fair
        # share (deck-balanced -> the paper's Fig-1 uniformity over the
        # training corpus); held-out topics appear at ~1/3 of fair share —
        # frequent enough for the predictor to identify the router map on
        # their subspace, rare enough that the EAMC holds almost no
        # matching request sketches (the Puffin -> WebGLM-QA shift).
        if not self._deck:
            deck = list(self.main) * 3 + list(self.held_out)
            self.rng.shuffle(deck)
            self._deck = deck
        return int(self._deck.pop())

    def _draw_topics(self) -> np.ndarray:
        cfg, rng = self.cfg, self.rng
        n = int(rng.integers(1, cfg.max_topics_per_prompt + 1))
        if cfg.split == "test":
            # test prompts mix held-out topics EXCLUSIVELY: request-level
            # sketches from training match them poorly, as in the paper
            out = list(rng.choice(self.held_out, size=min(n, len(self.held_out)), replace=False))
            return np.asarray(out)
        primary = self._next_from_deck()
        if n == 1:
            return np.asarray([primary])
        rest = [t for t in range(self.world.cfg.n_topics) if t != primary]
        extra = rng.choice(rest, size=n - 1, replace=False)
        return np.concatenate([[primary], extra])

    def sample_prompt(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (token_ids [T] i32, topic_mix [n_topics] f32)."""
        w, cfg, rng = self.world, self.cfg, self.rng
        topics = self._draw_topics()
        weights = rng.dirichlet([2.0] * len(topics))
        T = int(rng.integers(cfg.min_tokens, cfg.max_tokens + 1))

        tok_topic = w.token_topic
        V = w.cfg.vocab_size
        common_pool = np.nonzero(tok_topic < 0)[0]
        topic_pools = [np.nonzero(tok_topic == t)[0] for t in topics]

        toks = np.empty(T, dtype=np.int32)
        # Multi-turn structure: segments of 8-24 tokens each biased to one
        # topic of the mixture (mimics conversation turns).
        i = 0
        while i < T:
            seg = int(rng.integers(8, 25))
            t_idx = int(rng.choice(len(topics), p=weights))
            pool = topic_pools[t_idx]
            for j in range(i, min(T, i + seg)):
                if rng.random() < cfg.common_token_prob or len(pool) == 0:
                    toks[j] = rng.choice(common_pool)
                else:
                    toks[j] = rng.choice(pool)
            i += seg
        mix = np.zeros(w.cfg.n_topics, dtype=np.float32)
        mix[topics] = weights.astype(np.float32)
        return toks, mix


# ---------------------------------------------------------------------------
# Backbone parameter construction
# ---------------------------------------------------------------------------


def build_backbone_params(world: World) -> "dict[str, np.ndarray]":
    """Construct the from-scratch MoE backbone's parameters.

    Router weights come straight from the world's analytic router; the rest
    (attention, expert FFNs, shared experts, embeddings, LM head) are
    random but small so the residual stream stays dominated by the token
    embedding — that is what keeps *actual* backbone routing statistically
    aligned with the analytic world sampler (DESIGN.md §6).
    """
    c = world.cfg
    rng = np.random.default_rng(c.seed + 0xBACB0)
    L, D, E = c.n_layers, c.d_model, c.n_experts
    H, Dh, F, Fs = c.n_heads, c.d_head, c.d_expert, c.d_shared

    def glorot(*shape, scale=1.0):
        fan = shape[-1] + shape[-2] if len(shape) >= 2 else shape[-1]
        return (rng.normal(size=shape) * scale * np.sqrt(2.0 / fan)).astype(
            np.float32
        )

    # Attention value->output is an (orthogonal, scaled-transpose) pair:
    # wv[l] = Q_l, wo[l] = gamma * Q_l^T.  Attention then *mixes context*
    # (out ~ gamma * attention-weighted average of past hidden states)
    # instead of rotating the residual stream into a random basis.  This
    # keeps rmsnorm(h) topic-aligned at every depth, which is what makes
    # the backbone's REAL router decisions track the world's working sets
    # (test_backbone_routing_tracks_world) — the residual-stream analogue
    # of the analytic sampler's EMA context.
    gamma = 0.55
    wv = np.empty((L, D, H * Dh), dtype=np.float32)
    wo = np.empty((L, H * Dh, D), dtype=np.float32)
    for l in range(L):
        q_mat, _ = np.linalg.qr(rng.normal(size=(D, H * Dh)))
        wv[l] = q_mat
        wo[l] = gamma * q_mat.T

    p = {
        "tok_emb": world.token_emb.copy(),                 # [V, D]
        "router_w": world.router_w.copy(),                 # [L, E, D]
        "wq": glorot(L, D, H * Dh, scale=0.5),
        "wk": glorot(L, D, H * Dh, scale=0.5),
        "wv": wv,
        "wo": wo,
        "ln1": np.ones((L, D), dtype=np.float32),
        "ln2": np.ones((L, D), dtype=np.float32),
        # routed experts: per layer, per expert, two-layer FFN.  Output
        # scales are small so 27 layers of FFN noise never swamp the
        # topical direction of the residual stream.
        "w_in": glorot(L, E, D, F, scale=0.4),             # [L,E,D,F]
        "w_out": glorot(L, E, F, D, scale=0.12),           # [L,E,F,D]
        # shared experts (always active)
        "ws_in": glorot(L, c.n_shared, D, Fs, scale=0.4),
        "ws_out": glorot(L, c.n_shared, Fs, D, scale=0.1),
        "ln_f": np.ones((D,), dtype=np.float32),
        # weight-tied LM head (standard practice): logits = h @ tok_emb^T.
        # Tying keeps greedy generations ON the topical token manifold, so
        # decode-phase routing stays predictable — with a random head the
        # model free-runs into arbitrary token sequences whose routing no
        # predictor could anticipate (E2E ablation in EXPERIMENTS.md).
        "lm_head": (world.token_emb.T * 1.2).astype(np.float32),
    }
    return p


PARAM_ORDER = [
    "tok_emb", "router_w", "wq", "wk", "wv", "wo", "ln1", "ln2",
    "w_in", "w_out", "ws_in", "ws_out", "ln_f", "lm_head",
]


def flatten_params(params: "dict[str, np.ndarray]", order=None) -> Tuple[np.ndarray, list]:
    """Flatten params to one little-endian f32 vector + manifest entries."""
    order = order or PARAM_ORDER
    parts, man, off = [], [], 0
    for name in order:
        arr = np.ascontiguousarray(params[name], dtype=np.float32)
        parts.append(arr.reshape(-1))
        man.append(
            {"name": name, "offset": off, "size": int(arr.size), "shape": list(arr.shape)}
        )
        off += arr.size
    return np.concatenate(parts), man


def save_flat(path: str, flat: np.ndarray, manifest: list, extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    flat.astype("<f4").tofile(path)
    meta = {"total_f32": int(flat.size), "params": manifest}
    meta.update(extra or {})
    with open(path + ".json", "w") as f:
        json.dump(meta, f, indent=2)
