"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes/seeds; assert_allclose against ref.py is the core
correctness signal for the AOT artifacts (the same kernel code lowers into
predictor.hlo.txt / backbone_*.hlo.txt).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention, expert_mlp, moe_gate, ref

jax.config.update("jax_platform_name", "cpu")

SET = dict(max_examples=20, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# attention.mha
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    t=st.sampled_from([4, 8, 16, 32, 48]),
    h=st.sampled_from([1, 2, 4, 8]),
    dh=st.sampled_from([8, 16, 32]),
    n_real=st.integers(1, 48),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_mha_matches_ref(t, h, dh, n_real, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, t, h, dh) for _ in range(3))
    mask = jnp.asarray((np.arange(t) < min(n_real, t)).astype(np.float32))
    got = attention.mha(q, k, v, mask, causal)
    want = ref.mha_ref(q, k, v, mask, causal)
    real = min(n_real, t)
    assert_allclose(np.asarray(got)[:real], np.asarray(want)[:real], rtol=2e-5, atol=2e-5)


def test_mha_single_token():
    rng = np.random.default_rng(0)
    q, k, v = (rand(rng, 1, 2, 8) for _ in range(3))
    mask = jnp.ones((1,), jnp.float32)
    got = attention.mha(q, k, v, mask)
    assert_allclose(np.asarray(got), np.asarray(ref.mha_ref(q, k, v, mask)), rtol=1e-5)


def test_mha_full_pad_columns_ignored():
    """Padded keys must receive zero attention weight."""
    rng = np.random.default_rng(1)
    t = 16
    q, k, v = (rand(rng, t, 2, 8) for _ in range(3))
    mask = jnp.asarray((np.arange(t) < 5).astype(np.float32))
    base = attention.mha(q, k, v, mask)
    v2 = v.at[5:].set(999.0)  # garbage in padded region
    got = attention.mha(q, k, v2, mask)
    assert_allclose(np.asarray(got)[:5], np.asarray(base)[:5], rtol=1e-5)


def test_mha_grad_matches_ref_grad():
    """custom_vjp backward must equal the reference gradient."""
    rng = np.random.default_rng(2)
    t = 8
    q, k, v = (rand(rng, t, 2, 8) for _ in range(3))
    mask = jnp.ones((t,), jnp.float32)

    def loss_pallas(q, k, v):
        return jnp.sum(attention.mha(q, k, v, mask) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.mha_ref(q, k, v, mask) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_pick_block_t():
    assert attention.pick_block_t(32) == 32
    assert attention.pick_block_t(48) == 16
    assert attention.pick_block_t(160) == 32
    assert attention.pick_block_t(7) == 1


# ---------------------------------------------------------------------------
# moe_gate.topk_gate
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    t=st.sampled_from([1, 4, 16, 64]),
    e=st.sampled_from([8, 32, 64]),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_matches_ref(t, e, k, seed):
    k = min(k, e - 1)
    rng = np.random.default_rng(seed)
    logits = rand(rng, t, e, scale=2.0)
    ids, w, dense = moe_gate.topk_gate(logits, k)
    ids_r, w_r = ref.topk_gate_ref(logits, k)
    dense_r = ref.dense_gate_ref(logits, k)
    assert np.array_equal(np.asarray(ids), np.asarray(ids_r))
    assert_allclose(np.asarray(w), np.asarray(w_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(dense), np.asarray(dense_r), rtol=1e-5, atol=1e-6)


def test_gate_weights_sum_to_one():
    rng = np.random.default_rng(3)
    logits = rand(rng, 32, 64)
    _, w, dense = moe_gate.topk_gate(logits, 6)
    assert_allclose(np.asarray(w).sum(-1), np.ones(32), rtol=1e-5)
    assert_allclose(np.asarray(dense).sum(-1), np.ones(32), rtol=1e-5)


def test_gate_ids_sorted_by_logit():
    rng = np.random.default_rng(4)
    logits = rand(rng, 8, 64)
    ids, w, _ = moe_gate.topk_gate(logits, 6)
    ids, w = np.asarray(ids), np.asarray(w)
    ln = np.asarray(logits)
    for t in range(8):
        vals = ln[t, ids[t]]
        assert (np.diff(vals) <= 1e-6).all()
        assert (np.diff(w[t]) <= 1e-6).all()


def test_gate_tie_breaking_prefers_lower_id():
    logits = jnp.zeros((2, 8), jnp.float32)
    ids, _, _ = moe_gate.topk_gate(logits, 3)
    assert np.array_equal(np.asarray(ids), [[0, 1, 2], [0, 1, 2]])


# ---------------------------------------------------------------------------
# expert_mlp.expert_mlp
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    t=st.sampled_from([1, 8, 16, 64]),
    e=st.sampled_from([4, 16, 64]),
    d=st.sampled_from([16, 64, 128]),
    f=st.sampled_from([8, 32, 64]),
    k=st.integers(1, 6),
    skip=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_mlp_matches_ref(t, e, d, f, k, skip, seed):
    k = min(k, e - 1)
    rng = np.random.default_rng(seed)
    h = rand(rng, t, d)
    gate = ref.dense_gate_ref(rand(rng, t, e, scale=2.0), k)
    w_in = rand(rng, e, d, f, scale=0.2)
    w_out = rand(rng, e, f, d, scale=0.2)
    got = expert_mlp.expert_mlp(h, gate, w_in, w_out, skip_zero_gate=skip)
    want = ref.expert_mlp_ref(h, gate, w_in, w_out)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-5)


def test_expert_mlp_zero_gate_gives_zero():
    rng = np.random.default_rng(5)
    h = rand(rng, 8, 32)
    gate = jnp.zeros((8, 16), jnp.float32)
    w_in = rand(rng, 16, 32, 8)
    w_out = rand(rng, 16, 8, 32)
    got = expert_mlp.expert_mlp(h, gate, w_in, w_out)
    assert_allclose(np.asarray(got), np.zeros((8, 32)), atol=1e-7)


def test_expert_mlp_single_expert_equals_plain_ffn():
    rng = np.random.default_rng(6)
    h = rand(rng, 4, 16)
    gate = jnp.ones((4, 1), jnp.float32)
    w_in = rand(rng, 1, 16, 8)
    w_out = rand(rng, 1, 8, 16)
    got = expert_mlp.expert_mlp(h, gate, w_in, w_out)
    want = jnp.maximum(h @ w_in[0], 0.0) @ w_out[0]
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


def test_rmsnorm_unit_scale():
    rng = np.random.default_rng(7)
    x = rand(rng, 4, 32, scale=3.0)
    y = np.asarray(ref.rmsnorm_ref(x, jnp.ones(32)))
    rms = np.sqrt((y**2).mean(-1))
    assert_allclose(rms, np.ones(4), rtol=1e-4)
