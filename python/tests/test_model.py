"""L2 model contracts: predictor + backbone shapes, determinism, training
step behaviour, weight export round-trips, and backbone/world routing
alignment (the property that makes the whole reproduction hang together).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as model_mod
from compile import train as train_mod
from compile import tracegen
from compile.model import PredictorConfig
from compile.world import CorpusConfig, PromptSampler, World, WorldConfig, build_backbone_params

PC = PredictorConfig()


@pytest.fixture(scope="module")
def world():
    return World(WorldConfig())


@pytest.fixture(scope="module")
def bb_wlist(world):
    params = build_backbone_params(world)
    return [jnp.asarray(params[n]) for n, _ in model_mod.backbone_param_specs(world.cfg)]


@pytest.fixture(scope="module")
def pflat():
    return jnp.asarray(model_mod.predictor_flatten(PC, model_mod.predictor_init(PC, 0))[0])


def _inputs(seed=0, t=None):
    t = t or PC.window
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.normal(size=(t, PC.d_tok)), jnp.float32)
    lids = jnp.asarray(rng.integers(0, PC.n_model_layers, t), jnp.int32)
    mask = jnp.asarray((np.arange(t) < t - 3).astype(np.float32))
    return emb, lids, mask


def test_predictor_shapes(pflat):
    emb, lids, mask = _inputs()
    out = model_mod.predictor_forward(PC, pflat, emb, lids, mask)
    assert out.shape == (PC.window, PC.n_experts)
    assert np.isfinite(np.asarray(out)).all()


def test_predictor_deterministic(pflat):
    emb, lids, mask = _inputs()
    a = model_mod.predictor_forward(PC, pflat, emb, lids, mask)
    b = model_mod.predictor_forward(PC, pflat, emb, lids, mask)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_predictor_flat_equals_list_params(pflat):
    """AOT per-param convention must match the flat-vector convention."""
    emb, lids, mask = _inputs()
    specs = model_mod.predictor_param_specs(PC)
    off, wlist = 0, []
    flat = np.asarray(pflat)
    for name, shape in specs:
        n = int(np.prod(shape))
        wlist.append(jnp.asarray(flat[off : off + n].reshape(shape)))
        off += n
    a = model_mod.predictor_forward(PC, pflat, emb, lids, mask)
    b = model_mod.predictor_forward(PC, wlist, emb, lids, mask)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_predictor_padded_positions_suppressed(pflat):
    emb, lids, mask = _inputs()
    out = np.asarray(model_mod.predictor_forward(PC, pflat, emb, lids, mask))
    pad = np.asarray(mask) == 0
    assert (out[pad] <= -29.9).all()


def test_predictor_layer_id_changes_output(pflat):
    emb, _, mask = _inputs()
    a = model_mod.predictor_forward(PC, pflat, emb, jnp.zeros(PC.window, jnp.int32), mask)
    b = model_mod.predictor_forward(PC, pflat, emb, jnp.full((PC.window,), 13, jnp.int32), mask)
    assert np.abs(np.asarray(a) - np.asarray(b)).max() > 1e-4


def test_predictor_all_layers_consistent(pflat):
    emb, _, mask = _inputs()
    allp = model_mod.predictor_forward_all_layers(PC, pflat, emb, mask)
    assert allp.shape == (PC.n_model_layers, PC.window, PC.n_experts)
    one = model_mod.predictor_forward(
        PC, pflat, emb, jnp.full((PC.window,), 5, jnp.int32), mask
    )
    assert_allclose(np.asarray(allp[5]), np.asarray(one), rtol=1e-4, atol=1e-4)


def test_predictor_dropout_train_mode_differs(pflat):
    emb, lids, mask = _inputs()
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    a = model_mod.predictor_forward(PC, pflat, emb, lids, mask, train=True, rng=k1)
    b = model_mod.predictor_forward(PC, pflat, emb, lids, mask, train=True, rng=k2)
    assert np.abs(np.asarray(a) - np.asarray(b)).max() > 1e-4


def test_predictor_weight_export_roundtrip(tmp_path):
    params = model_mod.predictor_init(PC, 3)
    flat, man = model_mod.predictor_flatten(PC, params)
    # round-trip through the binary file format train.py emits
    p = tmp_path / "w.bin"
    flat.astype("<f4").tofile(p)
    back = np.fromfile(p, "<f4")
    assert np.array_equal(back, flat)
    total = sum(m["size"] for m in man)
    assert total == flat.size == sum(
        int(np.prod(s)) for _, s in model_mod.predictor_param_specs(PC)
    )


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_traces(world):
    s = PromptSampler(world, CorpusConfig(n_prompts=6, min_tokens=40, max_tokens=60))
    rng = np.random.default_rng(0)
    return [tracegen.sample_prompt_trace(world, s, i, rng) for i in range(6)]


def test_train_loss_decreases(tiny_traces, tmp_path):
    tc = train_mod.TrainConfig(
        batch_size=8, steps_per_epoch=30, max_epochs=1, val_batches=2, log_every=5
    )
    _, log = train_mod.train_predictor(
        PC, tc, tiny_traces, tiny_traces, str(tmp_path), "test-fp", quiet=True
    )
    losses = [s["loss"] for s in log["train_steps"]]
    assert losses[-1] < losses[0]
    assert (tmp_path / "predictor_weights.bin").exists()
    assert (tmp_path / "training_log.json").exists()


def test_trace_sampler_batch_shapes(tiny_traces):
    s = train_mod.TraceSampler(tiny_traces, PC, 0)
    emb, lids, mask, y = s.batch(4)
    assert emb.shape == (4, PC.window, PC.d_tok)
    assert lids.shape == (4, PC.window)
    assert y.shape == (4, PC.window, PC.n_experts)
    # every real position has exactly top_k active experts
    for b in range(4):
        real = mask[b] > 0
        assert np.allclose(y[b, real].sum(-1), PC.top_k)
        assert (lids[b] == lids[b, 0]).all()  # one layer per sample


def test_macro_f1_perfect_and_zero():
    tp = np.full(64, 10.0)
    assert train_mod.macro_f1(tp, np.zeros(64), np.zeros(64)) == pytest.approx(1.0)
    assert train_mod.macro_f1(np.zeros(64), np.zeros(64), tp) == pytest.approx(0.0)


def test_adamw_moves_toward_minimum():
    tc = train_mod.TrainConfig()
    params = {"w": jnp.asarray([4.0, -2.0])}
    state = train_mod.adamw_init(params)
    lrs = {"w": 0.1}
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, _ = train_mod.adamw_update(params, grads, state, lrs, tc)
    assert np.abs(np.asarray(params["w"])).max() < 0.5


def test_grad_clip_applied():
    tc = train_mod.TrainConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = train_mod.adamw_init(params)
    _, _, gnorm = train_mod.adamw_update(
        params, {"w": jnp.asarray([100.0, 0.0, 0.0])}, state, {"w": 1e-4}, tc
    )
    assert float(gnorm) == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# backbone
# ---------------------------------------------------------------------------


def test_backbone_prefill_shapes(world, bb_wlist):
    c = world.cfg
    P = c.max_seq
    toks = jnp.asarray(np.arange(P) % 50, jnp.int32)
    kv, ids, x0, logits = model_mod.backbone_prefill(c, bb_wlist, toks, jnp.int32(20))
    assert kv.shape == (c.n_layers, 2, c.max_seq, c.n_heads * c.d_head)
    assert ids.shape == (c.n_layers, P, c.top_k)
    assert x0.shape == (P, c.d_model)
    assert logits.shape == (c.vocab_size,)


def test_backbone_decode_step_advances(world, bb_wlist):
    c = world.cfg
    P = c.max_seq
    toks = jnp.asarray(np.arange(P) % 50, jnp.int32)
    kv, _, _, _ = model_mod.backbone_prefill(c, bb_wlist, toks, jnp.int32(10))
    kv2, logits, ids, emb = model_mod.backbone_decode_step(
        c, bb_wlist, kv, jnp.int32(10), jnp.int32(7)
    )
    assert kv2.shape == kv.shape
    assert ids.shape == (c.n_layers, c.top_k)
    # KV written at pos 10
    assert np.abs(np.asarray(kv2[:, :, 10, :])).max() > 0
    # decode ids are valid experts, unique per layer
    ids = np.asarray(ids)
    assert (ids >= 0).all() and (ids < c.n_experts).all()
    for l in range(c.n_layers):
        assert len(set(ids[l].tolist())) == c.top_k


def test_backbone_routing_tracks_world(world, bb_wlist):
    """The constructed backbone's actual routing must stay inside the
    world's topical working sets most of the time — the alignment that
    lets one predictor serve both trace sources (DESIGN.md §6)."""
    c = world.cfg
    s = PromptSampler(world, CorpusConfig(n_prompts=3, min_tokens=60, max_tokens=100))
    hits, total = 0, 0
    for i in range(3):
        toks, mix = s.sample_prompt()
        topics = np.nonzero(mix)[0]
        P = min(len(toks), c.max_seq)
        pad = np.zeros(c.max_seq, np.int32)
        pad[:P] = toks[:P]
        _, ids, _, _ = model_mod.backbone_prefill(
            c, bb_wlist, jnp.asarray(pad), jnp.int32(P)
        )
        ids = np.asarray(ids)  # [L, maxseq, K]
        for l in [2, 13, 25]:
            allowed = set(world.working_sets[l][topics].reshape(-1).tolist())
            got = ids[l, 8:P, :].reshape(-1)  # skip the first few warmup tokens
            hits += sum(1 for e in got if int(e) in allowed)
            total += len(got)
    assert hits / total > 0.55, f"backbone/world routing alignment too weak: {hits/total:.2f}"


def test_sparse_decode_matches_dense(world, bb_wlist):
    """The sparse top-k gather decode path must equal the dense einsum."""
    import jax
    c = world.cfg
    lp = {
        "router_w": bb_wlist[1][5],
        "w_in": bb_wlist[8][5],
        "w_out": bb_wlist[9][5],
        "ws_in": bb_wlist[10][5],
        "ws_out": bb_wlist[11][5],
    }
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(c.d_model,)), jnp.float32)
    d_sparse, ids_sparse = model_mod._moe_block_sparse(c, lp, h)
    d_dense, ids_dense = model_mod._moe_block(c, lp, h[None, :])
    assert np.array_equal(np.asarray(ids_sparse), np.asarray(ids_dense[0]))
    assert_allclose(np.asarray(d_sparse), np.asarray(d_dense[0]), rtol=2e-4, atol=2e-5)
