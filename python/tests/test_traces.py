"""MBTR trace format round-trips + AOT artifact pipeline (fast mode)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import tracegen
from compile.world import CorpusConfig, PromptSampler, World, WorldConfig


@pytest.fixture(scope="module")
def world():
    return World(WorldConfig())


def _mk_traces(world, n=4):
    s = PromptSampler(world, CorpusConfig(n_prompts=n, min_tokens=40, max_tokens=80))
    rng = np.random.default_rng(0)
    return [tracegen.sample_prompt_trace(world, s, i, rng) for i in range(n)]


def test_roundtrip(world, tmp_path):
    traces = _mk_traces(world)
    p = str(tmp_path / "t.bin")
    tracegen.write_traces(p, world, traces)
    meta, back = tracegen.read_traces(p)
    assert meta["n_layers"] == world.cfg.n_layers
    assert meta["n_experts"] == world.cfg.n_experts
    assert meta["top_k"] == world.cfg.top_k
    assert meta["n_prompts"] == len(traces)
    for a, b in zip(traces, back):
        assert a.prompt_id == b.prompt_id
        assert np.array_equal(a.tokens, b.tokens)
        assert np.allclose(a.embeddings, b.embeddings)
        assert np.array_equal(a.experts, b.experts)


def test_roundtrip_without_embeddings(world, tmp_path):
    traces = _mk_traces(world, 2)
    p = str(tmp_path / "t2.bin")
    tracegen.write_traces(p, world, traces, with_emb=False)
    meta, back = tracegen.read_traces(p)
    assert meta["flags"] & 1 == 0
    assert np.array_equal(traces[0].experts, back[0].experts)
    assert np.allclose(back[0].embeddings, 0)


def test_expert_ids_in_range(world):
    for tr in _mk_traces(world):
        assert (tr.experts < world.cfg.n_experts).all()
        # top-k unique per (token, layer)
        T, L, K = tr.experts.shape
        for t in range(0, T, 17):
            for l in range(0, L, 9):
                assert len(set(tr.experts[t, l].tolist())) == K


def test_trace_point_count(world):
    traces = _mk_traces(world, 3)
    n = tracegen.trace_point_count(traces)
    assert n == sum(len(t.tokens) for t in traces) * world.cfg.n_layers


def test_generate_split_reproducible(world, tmp_path):
    a = tracegen.generate_split(world, "test", 3, str(tmp_path / "a.bin"))
    b = tracegen.generate_split(world, "test", 3, str(tmp_path / "b.bin"))
    for x, y in zip(a, b):
        assert np.array_equal(x.tokens, y.tokens)
        assert np.array_equal(x.experts, y.experts)


def test_backbone_mode_trace(world, tmp_path):
    trs = tracegen.generate_split(
        world, "backbone_val", 1, str(tmp_path / "bb.bin"), mode="backbone"
    )
    tr = trs[0]
    assert tr.experts.shape[1] == world.cfg.n_layers
    assert (tr.experts < world.cfg.n_experts).all()
    # embeddings are the backbone's real token embeddings (unit-ish norm)
    norms = np.linalg.norm(tr.embeddings, axis=1)
    assert (norms > 0.5).all() and (norms < 2.0).all()


@pytest.mark.slow
def test_full_fast_aot_pipeline(tmp_path):
    """End-to-end MOEB_FAST aot run produces every artifact."""
    env = dict(os.environ, MOEB_FAST="1")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(tmp_path)],
        check=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    meta = json.load(open(tmp_path / "artifacts.json"))
    for exe in ("predictor", "predictor_batch", "backbone_prefill", "backbone_decode", "head_extract"):
        assert (tmp_path / meta["executables"][exe]["path"]).exists()
    assert (tmp_path / "predictor_weights.bin").exists()
    assert (tmp_path / "backbone_weights.bin").exists()
    assert (tmp_path / "traces" / "train.bin").exists()
    wj = json.load(open(tmp_path / "world.json"))
    pj = json.load(open(tmp_path / "predictor_weights.bin.json"))
    assert wj["fingerprint"] == pj["fingerprint"]
