"""World-model fidelity tests (DESIGN.md §6).

These pin down the statistical phenomena the paper's evaluation rests on:
single-prompt skew (Fig 2), cross-prompt uniformity (Fig 1), cross-layer
reuse (Fig 3), and the train/test domain shift.
"""

import dataclasses

import numpy as np
import pytest

from compile import tracegen
from compile.world import CorpusConfig, PromptSampler, World, WorldConfig, build_backbone_params, flatten_params


@pytest.fixture(scope="module")
def world():
    return World(WorldConfig())


@pytest.fixture(scope="module")
def traces(world):
    s = PromptSampler(world, CorpusConfig(n_prompts=40))
    rng = np.random.default_rng(0)
    return [tracegen.sample_prompt_trace(world, s, i, rng) for i in range(40)]


def test_world_is_deterministic():
    a, b = World(WorldConfig()), World(WorldConfig())
    assert a.fingerprint() == b.fingerprint()
    assert np.array_equal(a.affinity, b.affinity)
    assert np.array_equal(a.token_emb, b.token_emb)


def test_seed_changes_world():
    a = World(WorldConfig())
    b = World(dataclasses.replace(WorldConfig(), seed=1))
    assert a.fingerprint() != b.fingerprint()


def test_affinity_rows_normalized(world):
    s = world.affinity.sum(axis=2)
    assert np.allclose(s, 1.0, atol=1e-5)


def test_working_sets_have_expected_size(world):
    c = world.cfg
    assert world.working_sets.shape == (c.n_layers, c.n_topics, c.working_set)
    for l in range(c.n_layers):
        for t in range(c.n_topics):
            assert len(set(world.working_sets[l, t].tolist())) == c.working_set


def test_topic_embeddings_orthonormal(world):
    g = world.topic_emb @ world.topic_emb.T
    assert np.allclose(g, np.eye(world.cfg.n_topics), atol=1e-5)


def test_single_prompt_skew(world, traces):
    """Fig 2: one prompt touches only a small fraction of the expert pool."""
    sizes = [len(np.unique(tr.experts[:, 13, :])) for tr in traces]
    mean_ws = np.mean(sizes)
    # With token-level routing dynamics (route_beta) the per-prompt union
    # is wider than the paper's DeepSeek traces, but still well below the
    # pool; per-token sparsity stays exactly 6/64.
    assert 6 <= mean_ws <= 46, mean_ws


def test_cross_prompt_uniformity(world, traces):
    """Fig 1: aggregated over many prompts, popularity flattens out."""
    agg = np.zeros(world.cfg.n_experts)
    for tr in traces:
        agg += np.bincount(tr.experts[:, 0, :].reshape(-1), minlength=64)
    assert agg.min() > 0
    # held-out topics appear at 1/3 of fair share in the training corpus
    # (the domain-shift device), which widens the band vs the paper's
    # 1.75; at this small sample (40 prompts) the ratio is noisy — the
    # 122-prompt Fig-1 bench measures ~3.3
    assert agg.max() / agg.min() < 15.0


def test_single_vs_multi_prompt_entropy(world, traces):
    """The core sparsity insight: per-prompt activation entropy is far
    below the aggregate entropy."""

    def entropy(counts):
        p = counts / max(counts.sum(), 1)
        p = p[p > 0]
        return -(p * np.log(p)).sum()

    agg = np.zeros(64)
    singles = []
    for tr in traces:
        c = np.bincount(tr.experts[:, 13, :].reshape(-1), minlength=64).astype(float)
        agg += c
        singles.append(entropy(c))
    assert np.mean(singles) < entropy(agg) - 0.5


def test_cross_layer_reuse(world, traces):
    """Fig 3: adjacent layers reuse (permutation-adjusted) working sets."""
    tr = traces[0]
    reuse = []
    for l in range(world.cfg.n_layers - 1):
        a = np.unique(tr.experts[:, l, :])
        b = set(np.unique(tr.experts[:, l + 1, :]).tolist())
        mapped = set(int(x) for x in world.layer_perm[l + 1][a])
        reuse.append(len(mapped & b) / max(len(b), 1))
    assert np.mean(reuse) > 0.5


def test_test_split_domain_shift(world):
    tr_s = PromptSampler(world, CorpusConfig(n_prompts=10, split="train"))
    te_s = PromptSampler(world, CorpusConfig(n_prompts=10, split="test"))
    K = world.cfg.n_topics
    held = te_s.held_out
    tr_mass = np.mean([tr_s.sample_prompt()[1][held].sum() for _ in range(60)])
    te_mass = np.mean([te_s.sample_prompt()[1][held].sum() for _ in range(60)])
    assert te_mass > tr_mass + 0.2


def test_prompt_token_range(world):
    cfg = CorpusConfig(n_prompts=5, min_tokens=48, max_tokens=200)
    s = PromptSampler(world, cfg)
    for _ in range(10):
        toks, mix = s.sample_prompt()
        assert 48 <= len(toks) <= 200
        assert abs(mix.sum() - 1.0) < 1e-5
        assert (toks >= 0).all() and (toks < world.cfg.vocab_size).all()


def test_backbone_params_flatten_roundtrip(world):
    params = build_backbone_params(world)
    flat, man = flatten_params(params)
    assert flat.dtype == np.float32
    total = sum(m["size"] for m in man)
    assert total == flat.size
    # offsets are contiguous and ordered
    off = 0
    for m in man:
        assert m["offset"] == off
        off += m["size"]
    # router weights inside the blob equal the world's analytic router
    rw = next(m for m in man if m["name"] == "router_w")
    got = flat[rw["offset"] : rw["offset"] + rw["size"]].reshape(rw["shape"])
    assert np.allclose(got, world.router_w)


def test_context_embeddings_normalized(world, traces):
    ctx = world.context_embeddings(traces[0].embeddings)
    norms = np.linalg.norm(ctx, axis=1)
    assert np.allclose(norms, 1.0, atol=1e-4)
