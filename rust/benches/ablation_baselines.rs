//! Ablation bench (§3.1): every baseline the paper discusses, at the
//! memory-starved operating points — DeepSpeed-MoE next-layer-all,
//! BrainStorm global popularity, MoE-Infinity EAM, MoE-Beyond, plus
//! LRU-only and the oracle.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, time_block};

use moe_beyond::config::SimConfig;
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::sim::PredictorKind;

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 24);
    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let kinds = [
        PredictorKind::Learned,
        PredictorKind::Eam,
        PredictorKind::NextLayer,
        PredictorKind::Popularity,
        PredictorKind::None,
        PredictorKind::Oracle,
    ];
    let fracs = [0.05, 0.10, 0.20, 0.40];

    let results = time_block("baseline ablation (6 predictors x 4 capacities)", || {
        harness::run_fig7(&rt, &arts, &kinds, &fracs, n_prompts, SimConfig::default())
    })?;

    println!("\n== baseline ablation: hit rate (%) ==");
    print!("{:>10}", "capacity%");
    for r in &results {
        print!("{:>24}", r.predictor);
    }
    println!();
    for (i, frac) in fracs.iter().enumerate() {
        print!("{:>10.0}", frac * 100.0);
        for r in &results {
            print!("{:>24.1}", r.points[i].hit_rate * 100.0);
        }
        println!();
    }
    println!("\nprediction hit rate @10%:");
    for r in &results {
        println!("  {:>24}: {:.1}%", r.predictor, r.points[1].prediction_hit_rate * 100.0);
    }

    // §3.1 claims: next-layer-all over-fetches (its prediction hit rate is
    // 100% but cache hit collapses under pressure); popularity flattens out
    let learned = &results[0];
    let next_layer = &results[2];
    let popularity = &results[3];
    assert!(learned.points[1].hit_rate > popularity.points[1].hit_rate);
    assert!(learned.points[1].hit_rate > next_layer.points[1].hit_rate);
    println!("\nshape check: PASS");
    Ok(())
}
