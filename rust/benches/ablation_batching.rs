//! Ablation bench (paper §5, first limitation): micro-batching collapses
//! the predictor's discriminative power — interleaved activation streams
//! superpose in the shared cache and in the EAM sketches.
//!
//! Serves the same request set at batch sizes 1/2/4 through the real
//! backbone + coordinator and reports cache hit rate per batch size.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, time_block};

use moe_beyond::config::{CacheConfig, ServeConfig, SimConfig};
use moe_beyond::coordinator::{serve_requests, EngineConfig, ModelEngine, Request};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::trace::corpus::{CorpusConfig, PromptSampler};
use moe_beyond::trace::WorldModel;

fn main() -> moe_beyond::Result<()> {
    let n_requests = env_usize("MOEB_BENCH_REQUESTS", 4);
    let arts = harness::load_artifacts()?;
    let world = WorldModel::load(arts.path("world.json"))?;
    let (nl, ne) = (arts.world.n_layers as usize, arts.world.n_experts as usize);

    let mut rows = Vec::new();
    for &batch in &[1usize, 2, 4] {
        let mut sampler = PromptSampler::new(
            &world,
            CorpusConfig {
                test_split: true,
                min_tokens: 48,
                max_tokens: 64,
                ..Default::default()
            },
        );
        let requests: Vec<Request> = (0..n_requests)
            .map(|i| Request::new(i as u64, sampler.sample().tokens, 24))
            .collect();
        let cfg = EngineConfig {
            serve: ServeConfig {
                predictor: "learned".into(),
                max_new_tokens: 24,
                batch_size: batch,
                ..Default::default()
            },
            cache: CacheConfig::default().with_capacity_frac(0.10, nl, ne),
            sim: SimConfig::default(),
            ..Default::default()
        };
        let arts2 = arts.clone();
        let report = time_block(&format!("serve batch={batch}"), || {
            serve_requests(
                move || {
                    let rt = PjrtRuntime::cpu()?;
                    ModelEngine::load(&rt, &arts2, cfg)
                },
                requests,
                16,
                batch,
            )
        })?;
        let (dh, dm) = report.responses.iter().fold((0u64, 0u64), |(h, m), r| {
            (h + r.stats.decode_cache_hits, m + r.stats.decode_cache_misses)
        });
        let decode_hr = dh as f64 / (dh + dm).max(1) as f64;
        println!(
            "batch {batch}: decode-phase hit rate {:.1}% (whole-request {:.1}%; {} tokens, {:.2} tok/s)",
            decode_hr * 100.0,
            report.cache_hit_rate * 100.0,
            report.total_tokens,
            report.tokens_per_sec
        );
        rows.push((batch, decode_hr));
    }

    // §5 shape: hit rate degrades (or at best stays flat) as streams merge
    assert!(
        rows[0].1 >= rows[2].1 - 0.02,
        "batch-1 hit rate should be >= batch-4"
    );
    println!("\nshape check: PASS");
    Ok(())
}
