//! Ablation: learned-predictor refresh stride and prefetch lookahead.
//!
//! The paper's system predicts one layer ahead (§5, third limitation) and
//! its predictor runs on the critical path; our serving loop amortizes it
//! by refreshing every `predictor_stride` tokens. This bench quantifies
//! the staleness cost: hit rate at 10% capacity as the stride grows, plus
//! the oracle at longer lookahead horizons as the upper-bound analogue.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, time_block};

use moe_beyond::cache::LruCache;
use moe_beyond::config::{CacheConfig, SimConfig};
use moe_beyond::predictor::{learned, CachedPredictor, LearnedModel, OraclePredictor};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::sim::SimEngine;
use moe_beyond::cache::CacheStats;
use moe_beyond::trace::store;

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 8);
    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let model = LearnedModel::load(&rt, &arts)?;
    let test = store::read_traces(arts.path(&arts.split("test")?.path))?;
    let test = &test[..n_prompts.min(test.len())];
    let capacity = (27 * 64) / 10;

    println!("== stride ablation (learned predictor, 10% capacity) ==");
    let mut hit_at_stride = Vec::new();
    for &stride in &[1usize, 2, 4, 8, 16, 32] {
        let mut stats = CacheStats::default();
        time_block(&format!("precompute stride={stride}"), || -> moe_beyond::Result<()> {
            for tr in test {
                let preds = learned::precompute(&model, tr, stride, 6)?;
                let mut p = CachedPredictor::new(&preds);
                let mut engine = SimEngine::flat(
                    Box::new(LruCache::new(capacity)),
                    SimConfig { predictor_stride: stride, ..Default::default() },
                    CacheConfig::default().with_capacity(capacity),
                    64,
                );
                engine.run_prompt(tr, &mut p, &mut stats);
            }
            Ok(())
        })?;
        println!(
            "stride {stride:>2}: hit rate {:.1}%  prediction hit {:.1}%",
            stats.hit_rate() * 100.0,
            stats.prediction_hit_rate() * 100.0
        );
        hit_at_stride.push(stats.hit_rate());
    }

    println!("\n== lookahead-horizon ablation (oracle upper bound) ==");
    for &h in &[1usize, 2, 4, 8] {
        let mut stats = CacheStats::default();
        for tr in test {
            let mut p = OraclePredictor { horizon: h };
            let mut engine = SimEngine::flat(
                Box::new(LruCache::new(capacity)),
                SimConfig::default(),
                CacheConfig::default().with_capacity(capacity),
                64,
            );
            engine.run_prompt(tr, &mut p, &mut stats);
        }
        println!("horizon {h}: hit rate {:.1}%", stats.hit_rate() * 100.0);
    }

    // staleness should cost hit rate monotonically-ish: stride 1 >= stride 32
    assert!(
        hit_at_stride[0] >= *hit_at_stride.last().unwrap() - 0.02,
        "stride-1 should not lose to stride-32"
    );
    println!("\nshape check: PASS");
    Ok(())
}
