//! Tiny shared bench harness (offline build: no criterion).
//!
//! Each bench target is a standalone binary (`harness = false`) that runs
//! one paper experiment end-to-end, prints the paper-style rows, and
//! times its hot sections with `time_block` / `bench_loop`.

use std::time::Instant;

/// Run `f` once, returning (result, seconds).
#[allow(dead_code)] // shared via #[path]; not every bench uses every helper
pub fn time_block<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    eprintln!("[bench] {name}: {:.2}s", t0.elapsed().as_secs_f64());
    out
}

/// Repeat `f` until ~`target_secs` elapsed (at least `min_iters`), print
/// mean/std per iteration in µs, and return mean µs.
#[allow(dead_code)] // shared via #[path]; not every bench uses every helper
pub fn bench_loop(name: &str, min_iters: usize, target_secs: f64, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < target_secs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        if samples.len() > 100_000 {
            break;
        }
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    println!(
        "{name:<44} {mean:>12.2} µs/iter  (±{:>8.2}, n={})",
        var.sqrt(),
        samples.len()
    );
    mean
}

/// Simple env-var knob for bench scale.
#[allow(dead_code)] // shared via #[path]; not every bench uses every helper
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Synthetic reuse-heavy prompts over 64 experts: each prompt draws from
/// a ~10-expert working set (the §2.2 sparsity structure that makes
/// small caches viable at all).  Shared by the self-contained sweep
/// benches so the generators cannot drift.
#[allow(dead_code)]
pub fn mk_reuse_traces(
    n: usize,
    n_tokens: usize,
    n_layers: u16,
    seed: u64,
) -> Vec<moe_beyond::trace::PromptTrace> {
    let mut rng = moe_beyond::util::Rng::new(seed);
    (0..n)
        .map(|i| {
            let base = rng.below(54) as u8;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * n_layers as usize {
                let a = base + rng.below(10) as u8;
                let mut b = base + rng.below(10) as u8;
                if b == a {
                    b = base + ((a - base + 1) % 10);
                }
                experts.push(a);
                experts.push(b);
            }
            moe_beyond::trace::PromptTrace {
                prompt_id: i as u32,
                n_layers,
                top_k: 2,
                d_emb: 0,
                tokens: vec![0; n_tokens],
                embeddings: vec![],
                experts,
            }
        })
        .collect()
}

/// Wide-world variant of [`mk_reuse_traces`]: the same reuse structure,
/// but each prompt's ~10-expert band is placed anywhere in
/// `0..n_experts`, so with `n_experts > 64` the ids routinely cross u64
/// word boundaries (the multi-word `ExpertSet` path under test).
#[allow(dead_code)]
pub fn mk_reuse_traces_wide(
    n: usize,
    n_tokens: usize,
    n_layers: u16,
    seed: u64,
    n_experts: usize,
) -> Vec<moe_beyond::trace::PromptTrace> {
    assert!(
        (11..=moe_beyond::util::MAX_EXPERTS).contains(&n_experts),
        "mk_reuse_traces_wide needs 11..={} experts",
        moe_beyond::util::MAX_EXPERTS
    );
    let mut rng = moe_beyond::util::Rng::new(seed);
    (0..n)
        .map(|i| {
            let base = rng.below(n_experts - 10) as u8;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * n_layers as usize {
                let a = base + rng.below(10) as u8;
                let mut b = base + rng.below(10) as u8;
                if b == a {
                    b = base + ((a - base + 1) % 10);
                }
                experts.push(a);
                experts.push(b);
            }
            moe_beyond::trace::PromptTrace {
                prompt_id: i as u32,
                n_layers,
                top_k: 2,
                d_emb: 0,
                tokens: vec![0; n_tokens],
                embeddings: vec![],
                experts,
            }
        })
        .collect()
}

#[allow(dead_code)]
fn main() {} // not a real bench target; included via #[path] by the others
