//! Bench: the multi-node edge-cluster simulator at scale.
//!
//! Sweeps the K × placement × link-bandwidth × cache-fraction grid
//! (`moe_beyond::sim::sweep_cluster`) over synthetic reuse-heavy
//! corpora and checks the structural guarantees the cluster backend
//! ships with:
//!
//! 1. the K=1 loopback column reproduces the single-node exact-replay
//!    sweep BIT-for-bit (hit rate, every counter, modeled transfer µs),
//! 2. sharding a fixed aggregate cache budget across K nodes keeps the
//!    cluster-wide hit rate in the same regime while remote traffic
//!    appears (and K=1 never crosses the network),
//! 3. link bandwidth moves the modeled critical path, never the hit
//!    rate (the hit-rate-only evaluation blind spot, network edition),
//! 4. R-way replication under the seeded chaos plan: healthy baselines
//!    are clean and availability is monotone non-decreasing in R,
//! 5. the whole grid is byte-identical across two runs (determinism).
//!
//! Self-contained: synthetic traces, no artifacts/PJRT required.
//! `MOEB_BENCH_PROMPTS` scales the workload; `MOEB_CLUSTER_NODES` caps
//! the largest swept node count (default 8).
//!
//! Artifacts for CI upload land in `target/cluster/sweep_cluster.csv`.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, mk_reuse_traces, time_block};

use std::path::Path;

use moe_beyond::cluster::{ClusterConfig, PlacementKind};
use moe_beyond::config::{EamConfig, SimConfig};
use moe_beyond::sim::sweep::{
    chaos_csv, sweep_capacities_replay, sweep_chaos, sweep_cluster, ChaosSweepPoint,
    ClusterSweepPoint, PredictorKind, SweepInputs,
};
use moe_beyond::tier::LinkSpec;

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;

fn csv(points: &[ClusterSweepPoint]) -> String {
    let mut s = String::from(
        "nodes,placement,gbps,cache_frac,capacity_per_node,gpu_hit_rate,remote_rate,\
         critical_path_us,remote_lookups,failovers,promotions,wire_us\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.nodes,
            p.placement.id(),
            p.gbps,
            p.cache_frac,
            p.capacity_per_node,
            p.gpu_hit_rate,
            p.remote_rate,
            p.critical_path_us,
            p.net.remote_lookups,
            p.net.failovers,
            p.net.promotions,
            p.net.wire_us,
        ));
    }
    s
}

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 24);
    let max_nodes = env_usize("MOEB_CLUSTER_NODES", 8).clamp(1, 64);
    let test = mk_reuse_traces(n_prompts, 40, N_LAYERS as u16, 71);
    let fit = mk_reuse_traces(n_prompts * 2, 40, N_LAYERS as u16, 72);
    let inputs: SweepInputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        compiled: None,
        sim: SimConfig::default(),
        eam: EamConfig::default(),
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let base = ClusterConfig::default();
    let fracs = [0.05, 0.1, 0.2];

    // -- 1) K=1 loopback parity against the single-node exact replay ------
    let single = time_block("single-node exact-replay sweep", || {
        sweep_capacities_replay(PredictorKind::Eam, &fracs, &inputs)
    })?;
    let loopback = time_block("K=1 loopback cluster sweep", || {
        sweep_cluster(
            PredictorKind::Eam,
            &[1],
            &[PlacementKind::RoundRobin],
            &[0.0], // <= 0 = infinite bandwidth; loopback stays free
            &fracs,
            &inputs,
            &base,
        )
    })?;
    println!("\n== K=1 loopback parity: GPU hit rate (%) ==");
    println!("{:>10} {:>12} {:>12}", "capacity%", "single", "cluster");
    for (s, c) in single.points.iter().zip(loopback.iter()) {
        println!(
            "{:>10.0} {:>12.2} {:>12.2}",
            s.capacity_frac * 100.0,
            s.hit_rate * 100.0,
            c.gpu_hit_rate * 100.0
        );
        assert_eq!(
            s.hit_rate.to_bits(),
            c.gpu_hit_rate.to_bits(),
            "K=1 loopback drifted from the single-node replay at {}%",
            s.capacity_frac * 100.0
        );
        assert_eq!(s.stats.hits, c.stats.hits);
        assert_eq!(s.stats.misses, c.stats.misses);
        assert_eq!(s.stats.transfer_us.to_bits(), c.stats.transfer_us.to_bits());
        assert_eq!(c.net.remote_lookups, 0, "loopback K=1 must stay local");
    }

    // -- 2) node-count scaling under a fixed per-device budget -------------
    let mut nodes = vec![1usize];
    let mut k = 2;
    while k <= max_nodes {
        nodes.push(k);
        k *= 2;
    }
    let scaling = time_block("K-scaling sweep (node count x placement)", || {
        sweep_cluster(
            PredictorKind::Eam,
            &nodes,
            &PlacementKind::ALL,
            &[10.0],
            &[0.1],
            &inputs,
            &base,
        )
    })?;
    println!("\n== node-count scaling (cache 10%/device, 10 Gbps LAN) ==");
    println!(
        "{:>6} {:>11} {:>10} {:>9} {:>9} {:>18}",
        "nodes", "placement", "cap/node", "hit%", "remote%", "critical path ms"
    );
    for p in &scaling {
        println!(
            "{:>6} {:>11} {:>10} {:>9.1} {:>9.1} {:>18.1}",
            p.nodes,
            p.placement.id(),
            p.capacity_per_node,
            p.gpu_hit_rate * 100.0,
            p.remote_rate * 100.0,
            p.critical_path_us / 1e3
        );
        if p.nodes == 1 {
            assert_eq!(p.remote_rate, 0.0, "K=1 must not cross the network");
        } else {
            assert!(p.remote_rate > 0.0, "K={} saw no remote traffic", p.nodes);
        }
    }
    // sharding a fixed aggregate budget across K partitioned LRUs may
    // shift a few percent (per-node rounding), but must not crater
    let n_place = PlacementKind::ALL.len();
    for (i, p) in scaling.iter().enumerate() {
        let baseline = &scaling[i % n_place];
        assert!(
            p.gpu_hit_rate >= baseline.gpu_hit_rate - 0.10,
            "K={} {} hit rate cratered vs the single-node baseline ({:.3} vs {:.3})",
            p.nodes,
            p.placement.id(),
            p.gpu_hit_rate,
            baseline.gpu_hit_rate
        );
    }

    // -- 3) link bandwidth moves latency, not hit rate ---------------------
    let bw = [0.1, 1.0, 10.0];
    let bw_pts = time_block("bandwidth sweep (K=4)", || {
        sweep_cluster(
            PredictorKind::Eam,
            &[4.min(max_nodes)],
            &[PlacementKind::RoundRobin],
            &bw,
            &[0.1],
            &inputs,
            &base,
        )
    })?;
    println!("\n== link bandwidth sweep (K=4, cache 10%/device) ==");
    println!(
        "{:>8} {:>9} {:>9} {:>18} {:>12}",
        "gbps", "hit%", "remote%", "critical path ms", "wire ms"
    );
    for p in &bw_pts {
        println!(
            "{:>8.1} {:>9.1} {:>9.1} {:>18.1} {:>12.1}",
            p.gbps,
            p.gpu_hit_rate * 100.0,
            p.remote_rate * 100.0,
            p.critical_path_us / 1e3,
            p.net.wire_us / 1e3
        );
    }
    for w in bw_pts.windows(2) {
        assert_eq!(
            w[0].gpu_hit_rate.to_bits(),
            w[1].gpu_hit_rate.to_bits(),
            "bandwidth changed the hit rate"
        );
        assert!(
            w[0].critical_path_us >= w[1].critical_path_us - 1e-9,
            "more bandwidth made the critical path slower"
        );
    }
    if max_nodes > 1 {
        assert!(
            bw_pts[0].critical_path_us > bw_pts[bw_pts.len() - 1].critical_path_us,
            "a 100x bandwidth gap must show up in the critical path"
        );
    }

    // -- 4) replication column: availability under chaos -------------------
    // R-way replicas on a K=3 cluster under the seeded chaos plan: the
    // healthy (intensity 0) baselines are clean, availability is monotone
    // non-decreasing in R (replica rank sets are nested and the fault
    // clock ticks on measured lookups, not on routing), and the whole
    // sweep — R column included — replays byte-identically.
    let mut chaos_points: Option<Vec<ChaosSweepPoint>> = None;
    if max_nodes >= 3 {
        let rs = [1usize, 2, 3];
        let chaos_base = ClusterConfig::default()
            .with_nodes(3)
            .with_link(LinkSpec::new(100.0, 10.0, 5.0));
        let chaos_run = || {
            sweep_chaos(
                PredictorKind::Eam,
                &rs,
                &[1.0],
                &[PlacementKind::RoundRobin],
                0.1,
                &inputs,
                &chaos_base,
            )
        };
        let chaos = time_block("chaos sweep (R x intensity, K=3)", chaos_run)?;
        println!("\n== replication under chaos (K=3, cache 10%/device) ==");
        println!(
            "{:>3} {:>10} {:>13} {:>7} {:>9} {:>9}",
            "R", "intensity", "availability", "hit%", "degraded", "p99 infl"
        );
        for p in &chaos {
            println!(
                "{:>3} {:>10.1} {:>13.4} {:>7.1} {:>9} {:>9.2}",
                p.replicas,
                p.intensity,
                p.availability,
                p.gpu_hit_rate * 100.0,
                p.net.degraded_fetches,
                p.p99_inflation
            );
        }
        // each (R, placement) group leads with its intensity-0 baseline
        for group in chaos.chunks(2) {
            let healthy = &group[0];
            assert_eq!(healthy.intensity, 0.0);
            assert_eq!(
                healthy.availability, 1.0,
                "R={}: healthy baseline must be fully available",
                healthy.replicas
            );
            assert_eq!(healthy.net.degraded_fetches, 0);
            assert_eq!(healthy.net.retries, 0);
            assert_eq!(healthy.p99_inflation, 1.0);
        }
        let faulted: Vec<&ChaosSweepPoint> =
            chaos.iter().filter(|p| p.intensity > 0.0).collect();
        assert_eq!(faulted.len(), rs.len());
        assert!(
            faulted[0].net.degraded_fetches > 0,
            "full-intensity chaos must force degraded fetches at R=1"
        );
        for w in faulted.windows(2) {
            assert!(
                w[1].availability >= w[0].availability,
                "availability regressed with more replicas: R={} {:.4} vs R={} {:.4}",
                w[0].replicas,
                w[0].availability,
                w[1].replicas,
                w[1].availability
            );
        }
        let again = time_block("chaos sweep (replay)", chaos_run)?;
        assert_eq!(
            chaos_csv(&chaos),
            chaos_csv(&again),
            "chaos sweep is not byte-deterministic"
        );
        chaos_points = Some(chaos);
    }

    // -- 5) determinism: the full grid, byte for byte ----------------------
    let grid = || {
        sweep_cluster(
            PredictorKind::Eam,
            &nodes,
            &[PlacementKind::RoundRobin, PlacementKind::LayerHash],
            &[1.0],
            &fracs,
            &inputs,
            &base,
        )
    };
    let a = time_block("determinism grid (run 1)", grid)?;
    let b = time_block("determinism grid (run 2)", grid)?;
    assert_eq!(csv(&a), csv(&b), "cluster sweep is not byte-deterministic");
    println!("\ndeterminism: two full grid runs serialized byte-identically");

    // -- artifacts for CI upload -------------------------------------------
    let out_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("target/cluster");
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("sweep_cluster.csv"), csv(&scaling))?;
    if let Some(chaos) = &chaos_points {
        std::fs::write(out_dir.join("sweep_chaos.csv"), chaos_csv(chaos))?;
    }
    println!("artifacts: {}", out_dir.display());

    println!("\nshape check: PASS");
    Ok(())
}
