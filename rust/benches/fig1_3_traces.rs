//! Bench: regenerate the paper's Figs 1-3 (trace sparsity analysis,
//! §2.2 / Contribution 1) and time the analysis pipeline.
//!
//! Paper reference points (122 Puffin prompts, DeepSeek-V2-Lite):
//!   Fig 1: layer-1 aggregate histogram uniform in an 800-1400 band
//!   Fig 2: single prompt activates a handful of peaked experts
//!   Fig 3: consistent expert reuse across the 27 layers

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, time_block};

use moe_beyond::sim::harness;

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 122);
    let arts = harness::load_artifacts()?;

    let rep = time_block("fig1-3 generate+analyze", || {
        harness::run_fig123(&arts, n_prompts, 0)
    })?;

    println!("\n== FIG 1 (aggregate, layer 1, {n_prompts} prompts) ==");
    println!(
        "counts: min {} max {} mean {:.0}  ratio {:.2}   [paper: 800-1400 band, ratio ~1.75]",
        rep.fig1_min,
        rep.fig1_max,
        rep.fig1_histogram.iter().sum::<u64>() as f64 / rep.fig1_histogram.len() as f64,
        rep.fig1_ratio
    );

    println!("\n== FIG 2 (single prompt) ==");
    println!(
        "working set {} / {} experts; peak experts {:?}   [paper: ~6 peaked experts]",
        rep.fig2_working_set,
        arts.world.n_experts,
        rep.fig2_peak_experts
    );

    println!("\n== FIG 3 (layer-wise heatmap summary) ==");
    println!(
        "mean per-layer working set {:.1}; permutation-adjusted cross-layer reuse {:.2}",
        rep.fig3_working_sets.iter().sum::<usize>() as f64 / rep.fig3_working_sets.len() as f64,
        rep.fig3_cross_layer_reuse
    );

    println!("\n== sparsity summary ==");
    println!(
        "per-prompt entropy {:.2} nats vs aggregate {:.2} nats; working-set frac {:.1}%",
        rep.sparsity.mean_single_entropy,
        rep.sparsity.aggregate_entropy,
        rep.sparsity.working_set_frac * 100.0
    );

    // shape assertions (who wins / roughly what factor)
    assert!(rep.fig1_ratio < 4.0, "Fig 1 uniformity violated");
    assert!(
        (rep.fig2_working_set as f64) < 0.75 * arts.world.n_experts as f64,
        "Fig 2 sparsity violated"
    );
    assert!(rep.sparsity.mean_single_entropy < rep.sparsity.aggregate_entropy);
    println!("\nshape check: PASS");
    Ok(())
}
