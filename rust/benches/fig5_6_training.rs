//! Bench: regenerate the paper's Figs 5-6 (training/validation curves)
//! from the build-time training log.
//!
//! Paper reference points: train acc 0.96 -> 0.989, train F1 0.5 -> 0.86,
//! train loss 0.35 -> 0.131; val acc 0.987, val F1 0.85, val loss 0.133.

use moe_beyond::sim::harness;

fn main() -> moe_beyond::Result<()> {
    let arts = harness::load_artifacts()?;
    let log = harness::load_training_log(&arts)?;

    println!("== FIG 5 (training curves, {} logged steps) ==", log.train_steps.len());
    for s in log.train_steps.iter().step_by((log.train_steps.len() / 12).max(1)) {
        println!(
            "  step {:>5}: loss {:.3} acc {:.3} f1 {:.3} exact {:.3}",
            s.step, s.loss, s.acc, s.f1, s.exact
        );
    }
    let first = log.train_steps.first().expect("empty log");
    let last = log.train_steps.last().unwrap();
    println!(
        "train: loss {:.3}->{:.3} [paper 0.35->0.131], acc {:.3}->{:.3} [paper 0.96->0.989], f1 {:.2}->{:.2} [paper 0.5->0.86]",
        first.loss, last.loss, first.acc, last.acc, first.f1, last.f1
    );

    println!("\n== FIG 6 (validation curves, {} epochs) ==", log.val_epochs.len());
    for e in &log.val_epochs {
        println!(
            "  epoch {:>2}: loss {:.4} acc {:.4} f1 {:.3} exact {:.3}",
            e.epoch, e.loss, e.acc, e.f1, e.exact
        );
    }
    let vlast = log.val_epochs.last().expect("no val epochs");
    println!(
        "val final: loss {:.3} [paper 0.133], acc {:.3} [paper 0.987], f1 {:.3} [paper 0.85]",
        vlast.loss, vlast.acc, vlast.f1
    );

    // shape assertions: curves must move the right way, train/val gap small
    assert!(last.loss < first.loss * 0.7, "training loss did not converge");
    assert!(vlast.f1 > 0.5, "validation F1 too low");
    assert!((last.f1 - vlast.f1).abs() < 0.2, "train/val F1 gap too large");
    println!("\nshape check: PASS (wall {:.0}s)", log.wall_seconds);
    Ok(())
}
