//! Bench: regenerate the paper's Fig 7 — GPU cache hit rate vs expert
//! capacity for MoE-Beyond vs MoE-Infinity (plus LRU-only and the oracle
//! upper bound).
//!
//! Paper reference points: at 10% capacity MoE-Beyond >70% vs
//! MoE-Infinity 17%; MoE-Beyond keeps a 10-25pt lead and converges to
//! 100% faster.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, time_block};

use moe_beyond::config::SimConfig;
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::sim::PredictorKind;

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 40);
    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let kinds = [
        PredictorKind::Learned,
        PredictorKind::Eam,
        PredictorKind::None,
        PredictorKind::Oracle,
    ];

    let results = time_block("fig7 sweep (4 predictors x 9 capacities)", || {
        harness::run_fig7(&rt, &arts, &kinds, harness::FIG7_FRACS, n_prompts, SimConfig::default())
    })?;

    println!("\n== FIG 7: cache hit rate (%) vs GPU expert capacity (%) ==");
    print!("{:>10}", "capacity%");
    for r in &results {
        print!("{:>22}", r.predictor);
    }
    println!();
    for (i, frac) in harness::FIG7_FRACS.iter().enumerate() {
        print!("{:>10.0}", frac * 100.0);
        for r in &results {
            print!("{:>22.1}", r.points[i].hit_rate * 100.0);
        }
        println!();
    }
    println!("\nprediction hit rate @10%:");
    for r in &results {
        println!("  {:>22}: {:.1}%", r.predictor, r.points[1].prediction_hit_rate * 100.0);
    }

    let learned = &results[0];
    let eam = &results[1];
    // shape assertions: learned wins at the memory-starved end and stays
    // >= EAM (within noise) everywhere; both converge at full capacity
    assert!(
        learned.points[1].hit_rate > eam.points[1].hit_rate + 0.05,
        "learned must clearly beat EAM at 10% capacity"
    );
    for i in 0..harness::FIG7_FRACS.len() {
        assert!(
            learned.points[i].hit_rate >= eam.points[i].hit_rate - 0.02,
            "learned fell below EAM at {}%",
            harness::FIG7_FRACS[i] * 100.0
        );
    }
    assert!(learned.points.last().unwrap().hit_rate > 0.95);
    println!("\nshape check: PASS");
    Ok(())
}
