//! Bench: regenerate the paper's Fig 7 — GPU cache hit rate vs expert
//! capacity for MoE-Beyond vs MoE-Infinity (plus LRU-only and the oracle
//! upper bound) — and measure the sweep harness's parallelization
//! (serial vs threaded wall-clock on the same grid, outputs asserted
//! identical).
//!
//! Paper reference points: at 10% capacity MoE-Beyond >70% vs
//! MoE-Infinity 17%; MoE-Beyond keeps a 10-25pt lead and converges to
//! 100% faster.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, mk_reuse_traces, time_block};

use std::time::Instant;

use moe_beyond::config::{EamConfig, SimConfig};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::sim::sweep::{sweep_capacities_threaded, sweep_threads, SweepInputs};
use moe_beyond::sim::PredictorKind;

/// Serial vs threaded sweep on an identical grid of synthetic
/// reuse-heavy prompts (self-contained — no artifacts needed for this
/// section): report the wall-clock speedup and assert the outputs are
/// bit-identical (the determinism guarantee of the grid-indexed
/// write-back).
fn report_sweep_speedup() -> moe_beyond::Result<()> {
    let test = mk_reuse_traces(24, 48, 6, 71);
    let fit = mk_reuse_traces(48, 48, 6, 72);
    let inputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        compiled: None,
        sim: SimConfig::default(),
        eam: EamConfig::default(),
        n_layers: 6,
        n_experts: 64,
    };
    let fracs = harness::FIG7_FRACS;
    let threads = sweep_threads();

    let t0 = Instant::now();
    let serial = sweep_capacities_threaded(PredictorKind::Eam, fracs, &inputs, 1)?;
    let serial_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let threaded = sweep_capacities_threaded(PredictorKind::Eam, fracs, &inputs, threads)?;
    let threaded_s = t1.elapsed().as_secs_f64();

    for (s, p) in serial.points.iter().zip(threaded.points.iter()) {
        assert_eq!(
            s.hit_rate.to_bits(),
            p.hit_rate.to_bits(),
            "threaded sweep diverged from serial at {}%",
            s.capacity_frac * 100.0
        );
        assert_eq!(s.stats.hits, p.stats.hits);
        assert_eq!(s.stats.misses, p.stats.misses);
    }
    println!(
        "sweep parallelization ({} capacities x {} prompts, eam): serial {serial_s:.2}s vs \
         threaded {threaded_s:.2}s on {threads} workers ({:.1}x), outputs identical",
        fracs.len(),
        test.len(),
        serial_s / threaded_s.max(1e-9)
    );
    Ok(())
}

fn main() -> moe_beyond::Result<()> {
    println!("== sweep harness: serial vs threaded ==");
    report_sweep_speedup()?;

    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 40);
    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let kinds = [
        PredictorKind::Learned,
        PredictorKind::Eam,
        PredictorKind::None,
        PredictorKind::Oracle,
    ];

    let results = time_block("fig7 sweep (4 predictors x 9 capacities)", || {
        harness::run_fig7(&rt, &arts, &kinds, harness::FIG7_FRACS, n_prompts, SimConfig::default())
    })?;

    println!("\n== FIG 7: cache hit rate (%) vs GPU expert capacity (%) ==");
    print!("{:>10}", "capacity%");
    for r in &results {
        print!("{:>22}", r.predictor);
    }
    println!();
    for (i, frac) in harness::FIG7_FRACS.iter().enumerate() {
        print!("{:>10.0}", frac * 100.0);
        for r in &results {
            print!("{:>22.1}", r.points[i].hit_rate * 100.0);
        }
        println!();
    }
    println!("\nprediction hit rate @10%:");
    for r in &results {
        println!("  {:>22}: {:.1}%", r.predictor, r.points[1].prediction_hit_rate * 100.0);
    }

    let learned = &results[0];
    let eam = &results[1];
    // shape assertions: learned wins at the memory-starved end and stays
    // >= EAM (within noise) everywhere; both converge at full capacity
    assert!(
        learned.points[1].hit_rate > eam.points[1].hit_rate + 0.05,
        "learned must clearly beat EAM at 10% capacity"
    );
    for i in 0..harness::FIG7_FRACS.len() {
        assert!(
            learned.points[i].hit_rate >= eam.points[i].hit_rate - 0.02,
            "learned fell below EAM at {}%",
            harness::FIG7_FRACS[i] * 100.0
        );
    }
    assert!(learned.points.last().unwrap().hit_rate > 0.95);
    println!("\nshape check: PASS");
    Ok(())
}
