//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): the L3 operations
//! that run per (token, layer) in the simulator/coordinator, plus the
//! PJRT call latencies that bound serving throughput.
//!
//! The L3 section and the observability-overhead gate are fully
//! self-contained; the EAM/replay/PJRT sections need the artifact tree
//! and are skipped (with a notice) when it is absent, so CI can run the
//! obs gate on every push.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench_loop, env_usize, mk_reuse_traces};

use moe_beyond::cache::{CachePolicy, CacheStats, LruCache};
use moe_beyond::config::{CacheConfig, EamConfig, SimConfig};
use moe_beyond::memory::{ExpertMemory, FlatMemory};
use moe_beyond::obs::ObsSink;
use moe_beyond::predictor::{
    DecodeContext, EamPredictor, ExpertPredictor, NoPrefetch, OraclePredictor,
};
use moe_beyond::runtime::{PjrtRuntime, TensorArg};
use moe_beyond::sim::{harness, simulate_prompt, SimEngine};
use moe_beyond::trace::corpus::CorpusConfig;
use moe_beyond::trace::generator::TraceGenerator;
use moe_beyond::trace::{CompiledTrace, PromptTrace, WorldModel};
use moe_beyond::util::{ExpertSet, Rng};

const OBS_GATE_CAP: usize = 32;

/// Bench-local replica of `SimEngine::run_prompt_compiled` with ZERO
/// observability code — not even the Noop branch.  The obs gate compares
/// the real engine (Noop sink) against this to bound what the obs
/// plumbing costs when it is off.
fn replay_no_obs(traces: &[PromptTrace], compiled: &[CompiledTrace]) -> u64 {
    let sim = SimConfig::default();
    let mut memory: Box<dyn ExpertMemory> = Box::new(FlatMemory::new(
        Box::new(LruCache::new(OBS_GATE_CAP)),
        CacheConfig::default().with_capacity(OBS_GATE_CAP),
        64,
        sim.prefetch_budget,
        f64::INFINITY,
    ));
    let mut pred = NoPrefetch;
    let mut stats = CacheStats::default();
    let mut scratch: Vec<ExpertSet> = Vec::new();
    for (trace, ct) in traces.iter().zip(compiled) {
        let n_layers = trace.n_layers as usize;
        let warm = sim.warmup_tokens.min(trace.n_tokens());
        ExpertPredictor::<1>::begin_prompt(&mut pred, trace);
        scratch.clear();
        scratch.resize(n_layers, ExpertSet::EMPTY);
        for t in 0..trace.n_tokens() {
            let ctx = DecodeContext { trace, t };
            let measured = t >= warm;
            if measured {
                pred.predict_layers(&ctx, 0..n_layers, &mut scratch);
            }
            for l in 0..n_layers {
                let truth = ct.set(t, l);
                if measured {
                    let predicted = scratch[l];
                    let pf = memory.prefetch(l, predicted);
                    stats.prefetches += pf.issued;
                    stats.wasted_prefetches += pf.too_late;
                    stats.prediction_total += truth.len() as u64;
                    stats.prediction_hits += truth.overlap(predicted) as u64;
                }
                let batch = memory.lookup_set(l, truth, measured);
                if measured {
                    let hits = batch.hits.len() as u64;
                    stats.hits += hits;
                    stats.misses += truth.len() as u64 - hits;
                    stats.transfer_us += batch.fetch_us;
                }
                memory.end_layer();
                pred.observe(&ctx, l, truth);
            }
        }
        ExpertPredictor::<1>::end_prompt(&mut pred, trace);
    }
    stats.hits + stats.misses
}

/// The real engine over the same traces with the given sink attached.
fn replay_engine(traces: &[PromptTrace], compiled: &[CompiledTrace], obs: &ObsSink) -> u64 {
    let mut engine: SimEngine = SimEngine::flat(
        Box::new(LruCache::new(OBS_GATE_CAP)),
        SimConfig::default(),
        CacheConfig::default().with_capacity(OBS_GATE_CAP),
        64,
    );
    engine.set_obs(obs.clone());
    let mut stats = CacheStats::default();
    for (tr, ct) in traces.iter().zip(compiled) {
        engine.run_prompt_compiled(tr, ct, &mut NoPrefetch, &mut stats);
    }
    stats.hits + stats.misses
}

/// Zero-cost-when-off gate: the Noop-sink engine must stay within
/// `limit`× of the bench-local no-obs baseline (one retry for noise);
/// errors out otherwise so CI fails the bench run.
fn obs_overhead_gate(limit: f64) -> moe_beyond::Result<()> {
    println!("\n== observability overhead (Noop sink vs no-obs baseline) ==");
    let traces = mk_reuse_traces(8, 96, 8, 42);
    let compiled: Vec<CompiledTrace> = traces.iter().map(CompiledTrace::compile).collect();
    // both paths must count the same lookups, or the comparison is void
    assert_eq!(
        replay_no_obs(&traces, &compiled),
        replay_engine(&traces, &compiled, &ObsSink::default())
    );
    let measure = || {
        let base = bench_loop("replay: bench-local baseline (no obs code)", 40, 0.4, || {
            std::hint::black_box(replay_no_obs(&traces, &compiled));
        });
        let noop = bench_loop("replay: SimEngine, Noop sink", 40, 0.4, || {
            std::hint::black_box(replay_engine(&traces, &compiled, &ObsSink::default()));
        });
        noop / base.max(1e-9)
    };
    let mut ratio = measure();
    if ratio > limit {
        // one retry: micro-benches this small see scheduler noise
        println!("ratio {ratio:.3} over the {limit:.2}x gate — retrying once");
        ratio = measure();
    }
    let active = ObsSink::active(1 << 12, "virtual");
    bench_loop("replay: SimEngine, ACTIVE sink (not gated)", 40, 0.4, || {
        std::hint::black_box(replay_engine(&traces, &compiled, &active));
    });
    println!("obs-off overhead ratio: {ratio:.3} (gate {limit:.2}x)");
    anyhow::ensure!(
        ratio <= limit,
        "Noop-sink replay is {ratio:.3}x the no-obs baseline (gate {limit:.2}x)"
    );
    Ok(())
}

fn main() -> moe_beyond::Result<()> {
    println!("== L3 hot paths ==");

    // ExpertSet algebra
    let mut rng = Rng::new(1);
    let sets: Vec<ExpertSet> = (0..1024).map(|_| ExpertSet::from_words([rng.next_u64()])).collect();
    let mut acc = 0u32;
    bench_loop("expert_set: 1k union+overlap", 200, 0.5, || {
        for w in sets.windows(2) {
            acc = acc.wrapping_add(w[0].union(w[1]).len() + w[0].overlap(w[1]));
        }
    });
    std::hint::black_box(acc);

    // LRU ops
    let mut lru = LruCache::new(173);
    let keys: Vec<u32> = (0..4096).map(|_| rng.below(1728) as u32).collect();
    bench_loop("lru: 4k touch+insert", 200, 0.5, || {
        for &k in &keys {
            if !lru.touch(k) {
                lru.insert(k);
            }
        }
    });

    // observability must be free when off: fail the bench if not
    obs_overhead_gate(1.35)?;

    // everything below needs the artifact tree; CI runs without one
    let arts = match harness::load_artifacts() {
        Ok(a) => a,
        Err(e) => {
            println!("\nartifact tree absent — skipping EAM/replay/PJRT sections ({e})");
            return Ok(());
        }
    };

    // EAM cosine match against a full EAMC
    let world = WorldModel::load(arts.path("world.json"))?;
    let mut gen = TraceGenerator::new(&world, CorpusConfig::default(), 3);
    let fit = gen.generate(60);
    let mut eam = EamPredictor::new(EamConfig::default(), 27, 64);
    eam.fit(&fit);
    let probe = gen.generate(1).pop().unwrap();
    ExpertPredictor::<1>::begin_prompt(&mut eam, &probe);
    let ctx = DecodeContext { trace: &probe, t: 4 };
    for l in 0..27 {
        eam.observe(&ctx, l, probe.expert_set(2, l));
    }
    bench_loop("eam: predict (cosine over EAMC)", 500, 0.5, || {
        let s: ExpertSet = eam.predict(&ctx, 13);
        std::hint::black_box(s);
    });

    // whole-prompt simulation throughput
    let tr = gen.generate(1).pop().unwrap();
    bench_loop("sim: full prompt replay (no prefetch)", 50, 1.0, || {
        std::hint::black_box(simulate_prompt(&tr, &mut NoPrefetch, 173, SimConfig::default(), 64));
    });
    bench_loop("sim: full prompt replay (oracle)", 50, 1.0, || {
        std::hint::black_box(simulate_prompt(
            &tr,
            &mut OraclePredictor::new(),
            173,
            SimConfig::default(),
            64,
        ));
    });

    println!("\n== PJRT call latencies ==");
    let rt = PjrtRuntime::cpu()?;
    let model = moe_beyond::predictor::LearnedModel::load(&rt, &arts)?;
    let emb = vec![0.1f32; 32 * 128];
    let layers: Vec<usize> = (0..27).collect();
    bench_loop("predictor: all-layer window refresh", 5, 2.0, || {
        std::hint::black_box(model.predict_window(&emb, 32, &layers).unwrap());
    });

    let bb = moe_beyond::moe::Backbone::load(&rt, &arts)?;
    let tokens: Vec<i32> = (0..48).map(|i| (i * 13) % 200).collect();
    let pre = bb.prefill(&tokens)?;
    bench_loop("backbone: prefill (48-token prompt, adaptive)", 3, 2.0, || {
        std::hint::black_box(bb.prefill(&tokens).unwrap());
    });
    bench_loop("backbone: decode step (host kv roundtrip)", 5, 2.0, || {
        std::hint::black_box(bb.decode_step(&pre.kv, 48, 7).unwrap());
    });
    let mut sess = bb.start_decode(&pre.kv).unwrap();
    let mut pos = 48usize;
    bench_loop("backbone: decode step (device-resident kv)", 5, 2.0, || {
        std::hint::black_box(bb.decode_chained(&mut sess, pos, 7).unwrap());
        pos = (pos + 1).min(150);
    });

    // raw executable dispatch overhead (tiny arg, resident weights)
    let n = env_usize("MOEB_BENCH_DISPATCH", 20);
    let mut probe_exe = rt.load_hlo_text(arts.path("predictor_batch.hlo.txt"))?;
    let blob = moe_beyond::runtime::WeightBlob::load(arts.path("predictor_weights.bin"))?;
    let params: Vec<(&[f32], &[usize])> = blob
        .params
        .iter()
        .map(|p| (&blob.data[p.offset..p.offset + p.size], p.shape.as_slice()))
        .collect();
    probe_exe.set_resident_args(&rt, &params)?;
    let (b, t, d) = (
        arts.predictor.batch as usize,
        arts.predictor.window as usize,
        arts.predictor.d_tok as usize,
    );
    bench_loop("pjrt: batched predictor dispatch", n, 2.0, || {
        std::hint::black_box(
            probe_exe
                .call_flat(&[
                    TensorArg::F32(vec![0.1f32; b * t * d], vec![b, t, d]),
                    TensorArg::I32(vec![0i32; b * t], vec![b, t]),
                    TensorArg::F32(vec![1.0f32; b * t], vec![b, t]),
                ])
                .unwrap(),
        );
    });
    Ok(())
}
