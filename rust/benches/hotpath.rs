//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): the L3 operations
//! that run per (token, layer) in the simulator/coordinator, plus the
//! PJRT call latencies that bound serving throughput.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench_loop, env_usize};

use moe_beyond::cache::{CachePolicy, LruCache};
use moe_beyond::config::{EamConfig, SimConfig};
use moe_beyond::predictor::{EamPredictor, ExpertPredictor, NoPrefetch, OraclePredictor};
use moe_beyond::runtime::{PjrtRuntime, TensorArg};
use moe_beyond::sim::{simulate_prompt, harness};
use moe_beyond::trace::corpus::CorpusConfig;
use moe_beyond::trace::generator::TraceGenerator;
use moe_beyond::trace::WorldModel;
use moe_beyond::util::{ExpertSet, Rng};

fn main() -> moe_beyond::Result<()> {
    println!("== L3 hot paths ==");

    // ExpertSet algebra
    let mut rng = Rng::new(1);
    let sets: Vec<ExpertSet> = (0..1024).map(|_| ExpertSet(rng.next_u64())).collect();
    let mut acc = 0u32;
    bench_loop("expert_set: 1k union+overlap", 200, 0.5, || {
        for w in sets.windows(2) {
            acc = acc.wrapping_add(w[0].union(w[1]).len() + w[0].overlap(w[1]));
        }
    });
    std::hint::black_box(acc);

    // LRU ops
    let mut lru = LruCache::new(173);
    let keys: Vec<u32> = (0..4096).map(|_| rng.below(1728) as u32).collect();
    bench_loop("lru: 4k touch+insert", 200, 0.5, || {
        for &k in &keys {
            if !lru.touch(k) {
                lru.insert(k);
            }
        }
    });

    // EAM cosine match against a full EAMC
    let arts = harness::load_artifacts()?;
    let world = WorldModel::load(arts.path("world.json"))?;
    let mut gen = TraceGenerator::new(&world, CorpusConfig::default(), 3);
    let fit = gen.generate(60);
    let mut eam = EamPredictor::new(EamConfig::default(), 27, 64);
    eam.fit(&fit);
    let probe = gen.generate(1).pop().unwrap();
    eam.begin_prompt(&probe);
    let ctx = moe_beyond::predictor::DecodeContext { trace: &probe, t: 4 };
    for l in 0..27 {
        eam.observe(&ctx, l, probe.expert_set(2, l));
    }
    bench_loop("eam: predict (cosine over EAMC)", 500, 0.5, || {
        std::hint::black_box(eam.predict(&ctx, 13));
    });

    // whole-prompt simulation throughput
    let tr = gen.generate(1).pop().unwrap();
    bench_loop("sim: full prompt replay (no prefetch)", 50, 1.0, || {
        std::hint::black_box(simulate_prompt(&tr, &mut NoPrefetch, 173, SimConfig::default(), 64));
    });
    bench_loop("sim: full prompt replay (oracle)", 50, 1.0, || {
        std::hint::black_box(simulate_prompt(
            &tr,
            &mut OraclePredictor::new(),
            173,
            SimConfig::default(),
            64,
        ));
    });

    println!("\n== PJRT call latencies ==");
    let rt = PjrtRuntime::cpu()?;
    let model = moe_beyond::predictor::LearnedModel::load(&rt, &arts)?;
    let emb = vec![0.1f32; 32 * 128];
    let layers: Vec<usize> = (0..27).collect();
    bench_loop("predictor: all-layer window refresh", 5, 2.0, || {
        std::hint::black_box(model.predict_window(&emb, 32, &layers).unwrap());
    });

    let bb = moe_beyond::moe::Backbone::load(&rt, &arts)?;
    let tokens: Vec<i32> = (0..48).map(|i| (i * 13) % 200).collect();
    let pre = bb.prefill(&tokens)?;
    bench_loop("backbone: prefill (48-token prompt, adaptive)", 3, 2.0, || {
        std::hint::black_box(bb.prefill(&tokens).unwrap());
    });
    bench_loop("backbone: decode step (host kv roundtrip)", 5, 2.0, || {
        std::hint::black_box(bb.decode_step(&pre.kv, 48, 7).unwrap());
    });
    let mut sess = bb.start_decode(&pre.kv).unwrap();
    let mut pos = 48usize;
    bench_loop("backbone: decode step (device-resident kv)", 5, 2.0, || {
        std::hint::black_box(bb.decode_chained(&mut sess, pos, 7).unwrap());
        pos = (pos + 1).min(150);
    });

    // raw executable dispatch overhead (tiny arg, resident weights)
    let n = env_usize("MOEB_BENCH_DISPATCH", 20);
    let mut probe_exe = rt.load_hlo_text(arts.path("predictor_batch.hlo.txt"))?;
    let blob = moe_beyond::runtime::WeightBlob::load(arts.path("predictor_weights.bin"))?;
    let params: Vec<(&[f32], &[usize])> = blob
        .params
        .iter()
        .map(|p| (&blob.data[p.offset..p.offset + p.size], p.shape.as_slice()))
        .collect();
    probe_exe.set_resident_args(&rt, &params)?;
    let (b, t, d) = (
        arts.predictor.batch as usize,
        arts.predictor.window as usize,
        arts.predictor.d_tok as usize,
    );
    bench_loop("pjrt: batched predictor dispatch", n, 2.0, || {
        std::hint::black_box(
            probe_exe
                .call_flat(&[
                    TensorArg::F32(vec![0.1f32; b * t * d], vec![b, t, d]),
                    TensorArg::I32(vec![0i32; b * t], vec![b, t]),
                    TensorArg::F32(vec![1.0f32; b * t], vec![b, t]),
                ])
                .unwrap(),
        );
    });
    Ok(())
}
