//! Bench: replay-core throughput (perf tracking, no paper figure).
//!
//! Self-contained (synthetic reuse-heavy traces, fixed seeds, no
//! artifacts/PJRT).  Two sections:
//!
//! 1. **LRU/no-prefetch capacity sweep** — the exact per-capacity replay
//!    vs the Mattson stack-distance fast path over the same Fig-7
//!    fraction grid.  Outputs are asserted bit-identical and the fast
//!    path must be ≥ 3× faster (`MOEB_REPLAY_MIN_SPEEDUP` overrides the
//!    gate); the structural argument is that the sweep does one corpus
//!    pass instead of one per fraction.
//! 2. **Predictor-driven replay** — the batched `lookup_set` hot path vs
//!    the scalar delegation (`memory::ScalarPath`) on an oracle-driven
//!    replay.  Outputs asserted identical; tokens/sec reported for both
//!    (the gain here is per-expert virtual-call elimination, so it is
//!    reported, not gated).
//!
//! Tokens/sec methodology: one "sweep token" is one decode token of one
//! prompt at one grid point, so a capacity sweep covers
//! `prompts × tokens × fracs` tokens regardless of which path computed
//! it — the fast path is credited with the tokens it made redundant.
//! Per-iteration wall times take the MINIMUM over `MOEB_REPLAY_REPS`
//! repeats (the standard noise-robust estimator).
//!
//! Metrics land in `target/replay/metrics.json`; the CI perf-gate job
//! uploads that file as a workflow artifact next to the workload golden.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, mk_reuse_traces};

use std::time::Instant;

use moe_beyond::cache::{CacheStats, LruCache};
use moe_beyond::config::{CacheConfig, EamConfig, SimConfig};
use moe_beyond::memory::{ExpertMemory, FlatMemory, ScalarPath};
use moe_beyond::predictor::OraclePredictor;
use moe_beyond::sim::harness::FIG7_FRACS;
use moe_beyond::sim::sweep::{
    sweep_capacities_replay_threaded, sweep_capacities_threaded, SweepInputs,
};
use moe_beyond::sim::{PredictorKind, SimEngine};
use moe_beyond::trace::{CompiledCorpus, PromptTrace};

const N_LAYERS: usize = 6;
const N_EXPERTS: usize = 64;

/// Minimum wall-clock seconds of `f` over `reps` runs.
fn min_secs(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn assert_points_identical(a: &moe_beyond::sim::SweepResult, b: &moe_beyond::sim::SweepResult) {
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(b.points.iter()) {
        assert_eq!(x.capacity_experts, y.capacity_experts);
        assert_eq!(x.hit_rate.to_bits(), y.hit_rate.to_bits());
        assert_eq!(x.stats.hits, y.stats.hits);
        assert_eq!(x.stats.misses, y.stats.misses);
        assert_eq!(x.stats.prediction_total, y.stats.prediction_total);
        assert_eq!(x.stats.transfer_us.to_bits(), y.stats.transfer_us.to_bits());
    }
}

fn oracle_replay(
    scalar: bool,
    traces: &[PromptTrace],
    compiled: &CompiledCorpus,
    capacity: usize,
    sim: &SimConfig,
) -> CacheStats {
    let mut stats = CacheStats::default();
    for (tr, ct) in traces.iter().zip(compiled.iter()) {
        let flat = FlatMemory::new(
            Box::new(LruCache::new(capacity)),
            CacheConfig::default().with_capacity(capacity),
            N_EXPERTS,
            sim.prefetch_budget,
            f64::INFINITY,
        );
        let mem: Box<dyn ExpertMemory> = if scalar {
            Box::new(ScalarPath::new(Box::new(flat)))
        } else {
            Box::new(flat)
        };
        let mut engine = SimEngine::new(mem, sim.clone(), N_EXPERTS);
        engine.run_prompt_compiled(tr, ct, &mut OraclePredictor::new(), &mut stats);
    }
    stats
}

fn main() -> moe_beyond::Result<()> {
    let prompts = env_usize("MOEB_REPLAY_PROMPTS", 32);
    let tokens = env_usize("MOEB_REPLAY_TOKENS", 64);
    let reps = env_usize("MOEB_REPLAY_REPS", 10);
    let min_speedup = env_usize("MOEB_REPLAY_MIN_SPEEDUP", 3) as f64;

    let test = mk_reuse_traces(prompts, tokens, N_LAYERS as u16, 91);
    let fit = mk_reuse_traces(8, tokens, N_LAYERS as u16, 92);
    let inputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        sim: SimConfig::default(),
        eam: EamConfig::default(),
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let fracs = FIG7_FRACS;
    let sweep_tokens = (prompts * tokens * fracs.len()) as f64;

    // ---- section 1: no-prefetch capacity sweep, exact vs stack-distance
    println!("== LRU/no-prefetch capacity sweep: exact replay vs stack-distance ==");
    let exact = sweep_capacities_replay_threaded(PredictorKind::None, fracs, &inputs, 1)?;
    let fast = sweep_capacities_threaded(PredictorKind::None, fracs, &inputs, 1)?;
    assert_points_identical(&exact, &fast);

    let time_replay = |reps: usize| {
        min_secs(reps, || {
            let r =
                sweep_capacities_replay_threaded(PredictorKind::None, fracs, &inputs, 1).unwrap();
            std::hint::black_box(r);
        })
    };
    let time_fast = |reps: usize| {
        min_secs(reps, || {
            let r = sweep_capacities_threaded(PredictorKind::None, fracs, &inputs, 1).unwrap();
            std::hint::black_box(r);
        })
    };
    let mut replay_s = time_replay(reps);
    let mut fast_s = time_fast(reps);
    let mut sweep_speedup = replay_s / fast_s.max(1e-12);
    if sweep_speedup < min_speedup {
        // one noise retry before failing the gate: a shared CI runner can
        // starve one side's timing loop; keep each side's best time
        replay_s = replay_s.min(time_replay(reps * 2));
        fast_s = fast_s.min(time_fast(reps * 2));
        sweep_speedup = replay_s / fast_s.max(1e-12);
    }
    println!(
        "  grid: {} prompts x {} tokens x {} fracs ({} sweep tokens)",
        prompts,
        tokens,
        fracs.len(),
        sweep_tokens as u64
    );
    println!(
        "  exact replay:   {:>9.2} ms/sweep  ({:>12.0} tokens/s)",
        replay_s * 1e3,
        sweep_tokens / replay_s
    );
    println!(
        "  stack-distance: {:>9.2} ms/sweep  ({:>12.0} tokens/s)  => {:.1}x",
        fast_s * 1e3,
        sweep_tokens / fast_s,
        sweep_speedup
    );
    assert!(
        sweep_speedup >= min_speedup,
        "stack-distance fast path only {sweep_speedup:.2}x over exact replay (gate: {min_speedup}x)"
    );

    // ---- section 2: predictor-driven replay, scalar vs batched lookups
    println!("\n== predictor-driven replay (oracle): scalar vs batched lookup_set ==");
    let capacity = ((N_LAYERS * N_EXPERTS) as f64 * 0.10).round() as usize;
    let compiled = CompiledCorpus::compile(&test);
    let sim = SimConfig::default();
    let s_scalar = oracle_replay(true, &test, &compiled, capacity, &sim);
    let s_batched = oracle_replay(false, &test, &compiled, capacity, &sim);
    assert_eq!(s_scalar.hits, s_batched.hits);
    assert_eq!(s_scalar.misses, s_batched.misses);
    assert_eq!(s_scalar.prediction_hits, s_batched.prediction_hits);
    assert_eq!(
        s_scalar.transfer_us.to_bits(),
        s_batched.transfer_us.to_bits()
    );

    let replay_tokens = (prompts * tokens) as f64;
    let scalar_s = min_secs(reps, || {
        std::hint::black_box(oracle_replay(true, &test, &compiled, capacity, &sim));
    });
    let batched_s = min_secs(reps, || {
        std::hint::black_box(oracle_replay(false, &test, &compiled, capacity, &sim));
    });
    println!(
        "  scalar path:  {:>9.2} ms/replay  ({:>12.0} tokens/s)",
        scalar_s * 1e3,
        replay_tokens / scalar_s
    );
    println!(
        "  batched path: {:>9.2} ms/replay  ({:>12.0} tokens/s)  => {:.2}x",
        batched_s * 1e3,
        replay_tokens / batched_s,
        scalar_s / batched_s.max(1e-12)
    );

    // ---- metrics artifact for the CI perf-gate job
    let out_dir = std::path::Path::new("target/replay");
    std::fs::create_dir_all(out_dir)?;
    let json = format!(
        "{{\"schema\":1,\"prompts\":{},\"tokens_per_prompt\":{},\"layers\":{},\"fracs\":{},\
         \"replay_sweep_s\":{:.6},\"stackdist_sweep_s\":{:.6},\"stackdist_speedup\":{:.3},\
         \"replay_tokens_per_sec\":{:.0},\"stackdist_tokens_per_sec\":{:.0},\
         \"scalar_replay_s\":{:.6},\"batched_replay_s\":{:.6},\"batched_speedup\":{:.3},\
         \"scalar_tokens_per_sec\":{:.0},\"batched_tokens_per_sec\":{:.0},\"parity\":true}}",
        prompts,
        tokens,
        N_LAYERS,
        fracs.len(),
        replay_s,
        fast_s,
        sweep_speedup,
        sweep_tokens / replay_s,
        sweep_tokens / fast_s,
        scalar_s,
        batched_s,
        scalar_s / batched_s.max(1e-12),
        replay_tokens / scalar_s,
        replay_tokens / batched_s,
    );
    std::fs::write(out_dir.join("metrics.json"), &json)?;
    println!("\nmetrics written to target/replay/metrics.json");
    println!("parity + speedup gate: PASS");
    Ok(())
}
