//! Bench: replay-core throughput (perf tracking, no paper figure).
//!
//! Self-contained (synthetic reuse-heavy traces, fixed seeds, no
//! artifacts/PJRT).  Two sections:
//!
//! 1. **LRU/no-prefetch capacity sweep** — the exact per-capacity replay
//!    vs the Mattson stack-distance fast path over the same Fig-7
//!    fraction grid.  Outputs are asserted bit-identical and the fast
//!    path must be ≥ 3× faster (`MOEB_REPLAY_MIN_SPEEDUP` overrides the
//!    gate); the structural argument is that the sweep does one corpus
//!    pass instead of one per fraction.
//! 2. **Tiered no-prefetch sweep** — the exact per-cell replay vs the
//!    tiered stack-distance evaluation (per-tier band lookups on the
//!    same histogram) over a (gpu × host × ssd) grid.  Outputs asserted
//!    bit-identical and the analytic path must be ≥ 3× faster (same
//!    gate/override/retry policy as section 1); the analytic path reads
//!    every cell off ONE corpus profile.
//! 3. **Predictor-driven replay** — the batched `lookup_set` hot path vs
//!    the scalar delegation (`memory::ScalarPath`) on an oracle-driven
//!    replay.  Outputs asserted identical; tokens/sec reported for both
//!    (the gain here is per-expert virtual-call elimination, so it is
//!    reported, not gated).
//! 4. **Wide-world replay** — the same oracle replay on a 160-expert
//!    world (3-word `ExpertSet`): scalar-vs-batched parity asserted
//!    byte-identical, and the per-token cost gated at ≤ 2.5× the
//!    single-word path (`MOEB_REPLAY_WIDE_MAX_RATIO` overrides).  The
//!    single-word sections double as the N=1 monomorphization gate.
//!
//! Tokens/sec methodology: one "sweep token" is one decode token of one
//! prompt at one grid point, so a capacity sweep covers
//! `prompts × tokens × fracs` tokens regardless of which path computed
//! it — the fast path is credited with the tokens it made redundant.
//! Per-iteration wall times take the MINIMUM over `MOEB_REPLAY_REPS`
//! repeats (the standard noise-robust estimator).
//!
//! Metrics land in `target/replay/metrics.json`; the CI perf-gate job
//! uploads that file as a workflow artifact next to the workload golden.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, mk_reuse_traces};

use std::time::Instant;

use moe_beyond::cache::{CacheStats, LruCache};
use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, TierConfig};
use moe_beyond::memory::{ExpertMemory, FlatMemory, ScalarPath};
use moe_beyond::predictor::OraclePredictor;
use moe_beyond::sim::harness::FIG7_FRACS;
use moe_beyond::sim::sweep::{
    sweep_capacities_replay_threaded, sweep_capacities_threaded, sweep_tiered_replay_threaded,
    sweep_tiered_threaded, SweepInputs, TierSweepPoint,
};
use moe_beyond::sim::{PredictorKind, SimEngine};
use moe_beyond::tier::TierSpec;
use moe_beyond::trace::{CompiledCorpus, PromptTrace};

const N_LAYERS: usize = 6;
const N_EXPERTS: usize = 64;

/// Minimum wall-clock seconds of `f` over `reps` runs.
fn min_secs(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn assert_points_identical(a: &moe_beyond::sim::SweepResult, b: &moe_beyond::sim::SweepResult) {
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(b.points.iter()) {
        assert_eq!(x.capacity_experts, y.capacity_experts);
        assert_eq!(x.hit_rate.to_bits(), y.hit_rate.to_bits());
        assert_eq!(x.stats.hits, y.stats.hits);
        assert_eq!(x.stats.misses, y.stats.misses);
        assert_eq!(x.stats.prediction_total, y.stats.prediction_total);
        assert_eq!(x.stats.transfer_us.to_bits(), y.stats.transfer_us.to_bits());
    }
}

fn oracle_replay<const N: usize>(
    scalar: bool,
    traces: &[PromptTrace],
    compiled: &CompiledCorpus<N>,
    capacity: usize,
    sim: &SimConfig,
    n_experts: usize,
) -> CacheStats {
    let mut stats = CacheStats::default();
    for (tr, ct) in traces.iter().zip(compiled.iter()) {
        let flat = FlatMemory::<N>::new(
            Box::new(LruCache::new(capacity)),
            CacheConfig::default().with_capacity(capacity),
            n_experts,
            sim.prefetch_budget,
            f64::INFINITY,
        );
        let mem: Box<dyn ExpertMemory<N>> = if scalar {
            Box::new(ScalarPath::new(Box::new(flat)))
        } else {
            Box::new(flat)
        };
        let mut engine = SimEngine::new(mem, sim.clone(), n_experts);
        engine.run_prompt_compiled(tr, ct, &mut OraclePredictor::new(), &mut stats);
    }
    stats
}

fn main() -> moe_beyond::Result<()> {
    let prompts = env_usize("MOEB_REPLAY_PROMPTS", 32);
    let tokens = env_usize("MOEB_REPLAY_TOKENS", 64);
    let reps = env_usize("MOEB_REPLAY_REPS", 10);
    let min_speedup = env_usize("MOEB_REPLAY_MIN_SPEEDUP", 3) as f64;

    let test = mk_reuse_traces(prompts, tokens, N_LAYERS as u16, 91);
    let fit = mk_reuse_traces(8, tokens, N_LAYERS as u16, 92);
    let inputs: SweepInputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        compiled: None,
        sim: SimConfig::default(),
        eam: EamConfig::default(),
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let fracs = FIG7_FRACS;
    let sweep_tokens = (prompts * tokens * fracs.len()) as f64;

    // ---- section 1: no-prefetch capacity sweep, exact vs stack-distance
    println!("== LRU/no-prefetch capacity sweep: exact replay vs stack-distance ==");
    let exact = sweep_capacities_replay_threaded(PredictorKind::None, fracs, &inputs, 1)?;
    let fast = sweep_capacities_threaded(PredictorKind::None, fracs, &inputs, 1)?;
    assert_points_identical(&exact, &fast);

    let time_replay = |reps: usize| {
        min_secs(reps, || {
            let r =
                sweep_capacities_replay_threaded(PredictorKind::None, fracs, &inputs, 1).unwrap();
            std::hint::black_box(r);
        })
    };
    let time_fast = |reps: usize| {
        min_secs(reps, || {
            let r = sweep_capacities_threaded(PredictorKind::None, fracs, &inputs, 1).unwrap();
            std::hint::black_box(r);
        })
    };
    let mut replay_s = time_replay(reps);
    let mut fast_s = time_fast(reps);
    let mut sweep_speedup = replay_s / fast_s.max(1e-12);
    if sweep_speedup < min_speedup {
        // one noise retry before failing the gate: a shared CI runner can
        // starve one side's timing loop; keep each side's best time
        replay_s = replay_s.min(time_replay(reps * 2));
        fast_s = fast_s.min(time_fast(reps * 2));
        sweep_speedup = replay_s / fast_s.max(1e-12);
    }
    println!(
        "  grid: {} prompts x {} tokens x {} fracs ({} sweep tokens)",
        prompts,
        tokens,
        fracs.len(),
        sweep_tokens as u64
    );
    println!(
        "  exact replay:   {:>9.2} ms/sweep  ({:>12.0} tokens/s)",
        replay_s * 1e3,
        sweep_tokens / replay_s
    );
    println!(
        "  stack-distance: {:>9.2} ms/sweep  ({:>12.0} tokens/s)  => {:.1}x",
        fast_s * 1e3,
        sweep_tokens / fast_s,
        sweep_speedup
    );
    assert!(
        sweep_speedup >= min_speedup,
        "stack-distance fast path only {sweep_speedup:.2}x over exact replay (gate: {min_speedup}x)"
    );

    // ---- section 2: tiered no-prefetch sweep, exact vs stack-distance
    println!("\n== tiered no-prefetch sweep: exact replay vs stack-distance bands ==");
    // writeback-free tiers keep the grid inside the analytic path's
    // stall-free gate; integer costs keep float totals bit-comparable
    let tier_base = TierConfig {
        tiers: vec![
            TierSpec::new("gpu", 1, 2.0, 0.0),
            TierSpec::new("host", 1, 1400.0, 0.0),
            TierSpec::new("ssd", N_LAYERS * N_EXPERTS, 22_000.0, 0.0),
        ],
        policy: "lru".into(),
    };
    let gpu_fracs = [0.02, 0.05, 0.10, 0.20];
    let host_fracs = [0.10, 0.30];
    let ssd_costs = [8_000.0, 22_000.0];
    let tier_cells = gpu_fracs.len() * host_fracs.len() * ssd_costs.len();
    let tiered_tokens = (prompts * tokens * tier_cells) as f64;

    let run_tiered_exact = || {
        sweep_tiered_replay_threaded(
            PredictorKind::None,
            &gpu_fracs,
            &host_fracs,
            &ssd_costs,
            &inputs,
            &tier_base,
            1_000.0,
            1,
        )
        .unwrap()
    };
    let run_tiered_fast = || {
        sweep_tiered_threaded(
            PredictorKind::None,
            &gpu_fracs,
            &host_fracs,
            &ssd_costs,
            &inputs,
            &tier_base,
            1_000.0,
            1,
        )
        .unwrap()
    };
    let assert_tiered_identical = |a: &[TierSweepPoint], b: &[TierSweepPoint]| {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.gpu_hit_rate.to_bits(), y.gpu_hit_rate.to_bits());
            assert_eq!(x.deep_miss_rate.to_bits(), y.deep_miss_rate.to_bits());
            assert_eq!(x.critical_path_us.to_bits(), y.critical_path_us.to_bits());
            assert_eq!(x.stats.hits, y.stats.hits);
            assert_eq!(x.stats.misses, y.stats.misses);
            assert_eq!(x.stats.transfer_us.to_bits(), y.stats.transfer_us.to_bits());
            assert_eq!(x.tiers.served, y.tiers.served);
            assert_eq!(x.tiers.cold, y.tiers.cold);
            assert_eq!(x.tiers.demotions, y.tiers.demotions);
            assert_eq!(x.tiers.dropped, y.tiers.dropped);
        }
    };
    assert_tiered_identical(&run_tiered_exact(), &run_tiered_fast());

    let time_tiered_exact = |reps: usize| {
        min_secs(reps, || {
            std::hint::black_box(run_tiered_exact());
        })
    };
    let time_tiered_fast = |reps: usize| {
        min_secs(reps, || {
            std::hint::black_box(run_tiered_fast());
        })
    };
    let mut tiered_exact_s = time_tiered_exact(reps);
    let mut tiered_fast_s = time_tiered_fast(reps);
    let mut tiered_speedup = tiered_exact_s / tiered_fast_s.max(1e-12);
    if tiered_speedup < min_speedup {
        // same one-noise-retry policy as section 1: min-of-best per side
        tiered_exact_s = tiered_exact_s.min(time_tiered_exact(reps * 2));
        tiered_fast_s = tiered_fast_s.min(time_tiered_fast(reps * 2));
        tiered_speedup = tiered_exact_s / tiered_fast_s.max(1e-12);
    }
    println!(
        "  grid: {} prompts x {} tokens x {} cells ({} sweep tokens)",
        prompts, tokens, tier_cells, tiered_tokens as u64
    );
    println!(
        "  exact replay:   {:>9.2} ms/sweep  ({:>12.0} tokens/s)",
        tiered_exact_s * 1e3,
        tiered_tokens / tiered_exact_s
    );
    println!(
        "  stack-distance: {:>9.2} ms/sweep  ({:>12.0} tokens/s)  => {:.1}x",
        tiered_fast_s * 1e3,
        tiered_tokens / tiered_fast_s,
        tiered_speedup
    );
    assert!(
        tiered_speedup >= min_speedup,
        "tiered stack-distance path only {tiered_speedup:.2}x over exact replay (gate: {min_speedup}x)"
    );

    // ---- section 3: predictor-driven replay, scalar vs batched lookups
    println!("\n== predictor-driven replay (oracle): scalar vs batched lookup_set ==");
    let capacity = ((N_LAYERS * N_EXPERTS) as f64 * 0.10).round() as usize;
    let compiled: CompiledCorpus = CompiledCorpus::compile(&test);
    let sim = SimConfig::default();
    let s_scalar = oracle_replay(true, &test, &compiled, capacity, &sim, N_EXPERTS);
    let s_batched = oracle_replay(false, &test, &compiled, capacity, &sim, N_EXPERTS);
    assert_eq!(s_scalar.hits, s_batched.hits);
    assert_eq!(s_scalar.misses, s_batched.misses);
    assert_eq!(s_scalar.prediction_hits, s_batched.prediction_hits);
    assert_eq!(
        s_scalar.transfer_us.to_bits(),
        s_batched.transfer_us.to_bits()
    );

    let replay_tokens = (prompts * tokens) as f64;
    let scalar_s = min_secs(reps, || {
        std::hint::black_box(oracle_replay(true, &test, &compiled, capacity, &sim, N_EXPERTS));
    });
    let batched_s = min_secs(reps, || {
        std::hint::black_box(oracle_replay(false, &test, &compiled, capacity, &sim, N_EXPERTS));
    });
    println!(
        "  scalar path:  {:>9.2} ms/replay  ({:>12.0} tokens/s)",
        scalar_s * 1e3,
        replay_tokens / scalar_s
    );
    println!(
        "  batched path: {:>9.2} ms/replay  ({:>12.0} tokens/s)  => {:.2}x",
        batched_s * 1e3,
        replay_tokens / batched_s,
        scalar_s / batched_s.max(1e-12)
    );

    // ---- section 4: wide-world replay (multi-word ExpertSet)
    // The single-word sections above ARE the N=1 regression gate (any
    // monomorphization slip shows up as a failed ≥3x speedup gate); this
    // section bounds what a 3-word (160-expert) world pays per token
    // relative to the single-word fast path on the same replay shape.
    println!("\n== wide replay: 160 experts / 3-word sets vs single-word per token ==");
    const WIDE_EXPERTS: usize = 160;
    const WIDE_WORDS: usize = 3;
    let wide_max_ratio: f64 = std::env::var("MOEB_REPLAY_WIDE_MAX_RATIO")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2.5);
    let wide_test =
        bench_util::mk_reuse_traces_wide(prompts, tokens, N_LAYERS as u16, 93, WIDE_EXPERTS);
    let wide_compiled: CompiledCorpus<WIDE_WORDS> = CompiledCorpus::compile(&wide_test);
    let wide_capacity = ((N_LAYERS * WIDE_EXPERTS) as f64 * 0.10).round() as usize;
    // parity first: batched set-level lookups vs scalar delegation must
    // stay byte-identical on multi-word sets too
    let w_scalar = oracle_replay::<WIDE_WORDS>(
        true,
        &wide_test,
        &wide_compiled,
        wide_capacity,
        &sim,
        WIDE_EXPERTS,
    );
    let w_batched = oracle_replay::<WIDE_WORDS>(
        false,
        &wide_test,
        &wide_compiled,
        wide_capacity,
        &sim,
        WIDE_EXPERTS,
    );
    assert_eq!(w_scalar.hits, w_batched.hits);
    assert_eq!(w_scalar.misses, w_batched.misses);
    assert_eq!(w_scalar.prediction_hits, w_batched.prediction_hits);
    assert_eq!(
        w_scalar.transfer_us.to_bits(),
        w_batched.transfer_us.to_bits()
    );

    let time_wide = |reps: usize| {
        min_secs(reps, || {
            std::hint::black_box(oracle_replay::<WIDE_WORDS>(
                false,
                &wide_test,
                &wide_compiled,
                wide_capacity,
                &sim,
                WIDE_EXPERTS,
            ));
        })
    };
    let time_narrow = |reps: usize| {
        min_secs(reps, || {
            std::hint::black_box(oracle_replay::<1>(
                false, &test, &compiled, capacity, &sim, N_EXPERTS,
            ));
        })
    };
    let mut wide_s = time_wide(reps);
    let mut narrow_s = time_narrow(reps);
    let mut wide_ratio = wide_s / narrow_s.max(1e-12);
    if wide_ratio > wide_max_ratio {
        // same one-noise-retry policy as sections 1-2: min-of-best per side
        wide_s = wide_s.min(time_wide(reps * 2));
        narrow_s = narrow_s.min(time_narrow(reps * 2));
        wide_ratio = wide_s / narrow_s.max(1e-12);
    }
    println!(
        "  1-word  ({} experts): {:>9.2} ms/replay  ({:>12.0} tokens/s)",
        N_EXPERTS,
        narrow_s * 1e3,
        replay_tokens / narrow_s
    );
    println!(
        "  {}-word ({} experts): {:>9.2} ms/replay  ({:>12.0} tokens/s)  => {:.2}x per token (gate {:.2}x)",
        WIDE_WORDS,
        WIDE_EXPERTS,
        wide_s * 1e3,
        replay_tokens / wide_s,
        wide_ratio,
        wide_max_ratio
    );
    assert!(
        wide_ratio <= wide_max_ratio,
        "{WIDE_WORDS}-word replay costs {wide_ratio:.2}x the single-word path per token \
         (gate: {wide_max_ratio:.2}x)"
    );

    // ---- metrics artifact for the CI perf-gate job
    let out_dir = std::path::Path::new("target/replay");
    std::fs::create_dir_all(out_dir)?;
    let json = format!(
        "{{\"schema\":3,\"prompts\":{},\"tokens_per_prompt\":{},\"layers\":{},\"fracs\":{},\
         \"replay_sweep_s\":{:.6},\"stackdist_sweep_s\":{:.6},\"stackdist_speedup\":{:.3},\
         \"replay_tokens_per_sec\":{:.0},\"stackdist_tokens_per_sec\":{:.0},\
         \"tiered_cells\":{},\"tiered_replay_sweep_s\":{:.6},\"tiered_stackdist_sweep_s\":{:.6},\
         \"tiered_stackdist_speedup\":{:.3},\"tiered_replay_tokens_per_sec\":{:.0},\
         \"tiered_stackdist_tokens_per_sec\":{:.0},\
         \"scalar_replay_s\":{:.6},\"batched_replay_s\":{:.6},\"batched_speedup\":{:.3},\
         \"scalar_tokens_per_sec\":{:.0},\"batched_tokens_per_sec\":{:.0},\
         \"wide_experts\":{},\"wide_words\":{},\"wide_replay_s\":{:.6},\
         \"wide_tokens_per_sec\":{:.0},\"wide_per_token_ratio\":{:.3},\
         \"wide_ratio_gate\":{:.2},\"parity\":true}}",
        prompts,
        tokens,
        N_LAYERS,
        fracs.len(),
        replay_s,
        fast_s,
        sweep_speedup,
        sweep_tokens / replay_s,
        sweep_tokens / fast_s,
        tier_cells,
        tiered_exact_s,
        tiered_fast_s,
        tiered_speedup,
        tiered_tokens / tiered_exact_s,
        tiered_tokens / tiered_fast_s,
        scalar_s,
        batched_s,
        scalar_s / batched_s.max(1e-12),
        replay_tokens / scalar_s,
        replay_tokens / batched_s,
        WIDE_EXPERTS,
        WIDE_WORDS,
        wide_s,
        replay_tokens / wide_s,
        wide_ratio,
        wide_max_ratio,
    );
    std::fs::write(out_dir.join("metrics.json"), &json)?;
    println!("\nmetrics written to target/replay/metrics.json");
    println!("parity + speedup gate: PASS");
    Ok(())
}
