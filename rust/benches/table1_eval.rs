//! Bench: regenerate the paper's Table 1 — predictor accuracy / macro-F1
//! on the held-out (domain-shifted) test split.
//!
//! Paper: accuracy 97.55%, macro F1 86.18% over 100 WebGLM-QA prompts.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, time_block};

use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 40);
    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;

    let t = time_block("table1 eval (AOT predictor via PJRT)", || {
        harness::run_table1(&rt, &arts, n_prompts, "test")
    })?;

    println!("\n== TABLE 1 ({} prompts, {} positions) ==", t.prompts, t.positions);
    println!("  {:<22} {:>10} {:>10}", "metric", "ours", "paper");
    println!("  {:<22} {:>9.2}% {:>9.2}%", "accuracy", t.accuracy_pct, 97.55);
    println!("  {:<22} {:>9.2}% {:>9.2}%", "macro F1", t.macro_f1_pct, 86.18);
    println!("  {:<22} {:>9.2}% {:>10}", "micro F1", t.micro_f1_pct, "-");
    println!("  {:<22} {:>9.2}% {:>10}", "exact top-6 match", t.exact_match_pct, "-");

    // per-layer agreement (paper §3.2.4's TensorBoard analysis)
    use moe_beyond::eval::LayerAgreement;
    use moe_beyond::predictor::{learned, LearnedModel};
    use moe_beyond::trace::store;
    let model = LearnedModel::load(&rt, &arts)?;
    let traces = store::read_traces(arts.path(&arts.split("test")?.path))?;
    let mut la = LayerAgreement::new(27, 6);
    for tr in traces.iter().take(6) {
        let preds = learned::precompute_mode(&model, tr, model.window, 6, true)?;
        la.record_trace(&preds, tr);
    }
    println!("\nper-layer top-6 agreement (6 prompts):");
    for (l, r) in la.rates().iter().enumerate() {
        if l % 3 == 0 {
            println!("  layer {l:>2}: {:.1}%", r * 100.0);
        }
    }

    // shape: high accuracy, F1 far above the all-negative baseline (0)
    assert!(t.accuracy_pct > 90.0, "accuracy shape violated");
    assert!(t.macro_f1_pct > 55.0, "macro F1 shape violated");
    println!("\nshape check: PASS");
    Ok(())
}
