//! Bench: the tiered expert-memory hierarchy (GPU VRAM ↔ host RAM ↔ SSD).
//!
//! Extends Fig 7 into a hit-rate × tier-latency surface: sweeps GPU
//! capacity, host-RAM fraction, and SSD fetch cost, and checks that
//!
//! 1. the tiered path with a full-size host tier at PCIe cost reproduces
//!    the flat (seed) sweep's hit rates exactly — tiered mode is opt-in
//!    and changes nothing until configured,
//! 2. shrinking GPU capacity with a warm host tier degrades modeled
//!    critical-path latency gracefully, while the same shrink over bare
//!    flash blows up,
//! 3. SSD bandwidth moves latency without touching hit rate (why
//!    hit-rate-only evaluation mispredicts edge deployments).
//!
//! Self-contained: synthetic traces, no artifacts/PJRT required.
//! `MOEB_BENCH_PROMPTS` scales the workload.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{env_usize, mk_reuse_traces, time_block};

use moe_beyond::config::{EamConfig, SimConfig, TierConfig};
use moe_beyond::sim::sweep::{sweep_capacities, sweep_tiered, PredictorKind, SweepInputs};
use moe_beyond::tier::TierSpec;

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;

fn base_tiers() -> TierConfig {
    TierConfig {
        tiers: vec![
            TierSpec::gpu(1),
            TierSpec::host(1),
            TierSpec::ssd(N_LAYERS * N_EXPERTS),
        ],
        policy: "lru".into(),
    }
}

fn main() -> moe_beyond::Result<()> {
    let n_prompts = env_usize("MOEB_BENCH_PROMPTS", 24);
    let test = mk_reuse_traces(n_prompts, 40, N_LAYERS as u16, 61);
    let fit = mk_reuse_traces(n_prompts * 2, 40, N_LAYERS as u16, 62);
    let inputs: SweepInputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        compiled: None,
        sim: SimConfig::default(),
        eam: EamConfig::default(),
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let overlap_us = moe_beyond::config::CacheConfig::default().overlap_per_layer(N_LAYERS);
    let gpu_fracs = [0.4, 0.2, 0.1, 0.05];

    // -- 1) flat-path equivalence ------------------------------------------
    let flat = time_block("flat Fig-7 sweep", || {
        sweep_capacities(PredictorKind::None, &gpu_fracs, &inputs)
    })?;
    // full host at PCIe cost == the flat model's infinite host pool
    let equiv_base = base_tiers().with_deepest_fetch_us(1400.0);
    let equiv = time_block("tiered sweep (host=100% @ PCIe)", || {
        sweep_tiered(
            PredictorKind::None,
            &gpu_fracs,
            &[1.0],
            &[1400.0],
            &inputs,
            &equiv_base,
            overlap_us,
        )
    })?;
    println!("\n== flat-path equivalence: GPU hit rate (%) ==");
    println!("{:>10} {:>12} {:>12}", "capacity%", "flat", "tiered");
    for (f, t) in flat.points.iter().zip(equiv.iter()) {
        println!(
            "{:>10.0} {:>12.1} {:>12.1}",
            f.capacity_frac * 100.0,
            f.hit_rate * 100.0,
            t.gpu_hit_rate * 100.0
        );
        assert!(
            (f.hit_rate - t.gpu_hit_rate).abs() < 1e-12,
            "tiered mode changed the seed Fig-7 numbers at {}%",
            f.capacity_frac * 100.0
        );
    }

    // -- 2) GPU shrink × host fraction -------------------------------------
    let host_fracs = [0.01, 0.25, 1.0];
    let surface = time_block("tiered surface (gpu × host)", || {
        sweep_tiered(
            PredictorKind::None,
            &gpu_fracs,
            &host_fracs,
            &[22_000.0],
            &inputs,
            &base_tiers(),
            overlap_us,
        )
    })?;
    println!("\n== modeled critical path (ms) vs GPU capacity × host RAM (ssd = 22 ms/expert) ==");
    print!("{:>10}", "gpu%");
    for hf in &host_fracs {
        print!("{:>14}", format!("host={:.0}%", hf * 100.0));
    }
    println!("{:>14}", "gpu-hit%");
    for (gi, gf) in gpu_fracs.iter().enumerate() {
        print!("{:>10.0}", gf * 100.0);
        let row: Vec<_> = (0..host_fracs.len())
            .map(|hi| &surface[gi * host_fracs.len() + hi])
            .collect();
        for p in &row {
            print!("{:>14.1}", p.critical_path_us / 1e3);
        }
        println!("{:>14.1}", row[0].gpu_hit_rate * 100.0);
        // host fraction moves latency only; the GPU tier is identical
        for p in &row {
            assert!((p.gpu_hit_rate - row[0].gpu_hit_rate).abs() < 1e-12);
        }
        // warm host strictly dominates the starved one at equal GPU size
        assert!(row[2].critical_path_us <= row[0].critical_path_us + 1e-9);
    }
    // graceful degradation: with a full host tier, shrinking the GPU
    // 8x must cost less than the same shrink over bare flash
    let crit = |gi: usize, hi: usize| surface[gi * host_fracs.len() + hi].critical_path_us;
    let warm_blowup = crit(gpu_fracs.len() - 1, 2) / crit(0, 2).max(1e-9);
    let starved_blowup = crit(gpu_fracs.len() - 1, 0) / crit(0, 0).max(1e-9);
    println!(
        "\nshrinking GPU {}% -> {}%: critical path x{:.1} with warm host, x{:.1} over flash",
        gpu_fracs[0] * 100.0,
        gpu_fracs[gpu_fracs.len() - 1] * 100.0,
        warm_blowup,
        starved_blowup
    );
    assert!(
        crit(gpu_fracs.len() - 1, 2) <= crit(gpu_fracs.len() - 1, 0),
        "warm host must not be slower than starved host"
    );

    // -- 3) SSD bandwidth sweep --------------------------------------------
    let ssd_sweep = [8_000.0, 22_000.0, 44_000.0];
    let ssd_pts = time_block("ssd bandwidth sweep", || {
        sweep_tiered(
            PredictorKind::None,
            &[0.05],
            &[0.1],
            &ssd_sweep,
            &inputs,
            &base_tiers(),
            overlap_us,
        )
    })?;
    println!("\n== SSD bandwidth sweep (gpu=5%, host=10%) ==");
    println!(
        "{:>14} {:>18} {:>10} {:>12}",
        "ssd µs/expert", "critical path ms", "gpu-hit%", "deep-miss%"
    );
    for p in &ssd_pts {
        println!(
            "{:>14.0} {:>18.1} {:>10.1} {:>12.1}",
            p.ssd_us_per_expert,
            p.critical_path_us / 1e3,
            p.gpu_hit_rate * 100.0,
            p.deep_miss_rate * 100.0
        );
    }
    for w in ssd_pts.windows(2) {
        assert!((w[0].gpu_hit_rate - w[1].gpu_hit_rate).abs() < 1e-12);
        assert!(w[0].critical_path_us <= w[1].critical_path_us + 1e-9);
    }

    println!("\nshape check: PASS");
    Ok(())
}
