//! Bench + CI perf gate: multi-tenant contention through the workload
//! simulator (`moe_beyond::workload`).
//!
//! Three tenants (chat / bursty agent / batch) share one expert cache
//! while a virtual-time engine interleaves their decode streams.  The
//! bench prints the scheduler-policy × backend headline table and a
//! small load × cache-fraction × predictor sweep, asserts the scheduler
//! invariants (work conservation, round-robin no-starvation, counter
//! conservation), proves byte-identical determinism by running the whole
//! pipeline twice, and then diffs the deterministic metrics JSON against
//! the checked-in golden file `benches/golden/workload.json` — drift
//! fails the bench, and with it the CI `perf-gate` job.
//!
//! Self-contained: synthetic traces, fixed seed, no artifacts, no PJRT.
//! Deliberately NO scale knobs — the golden file pins this exact
//! workload.  To refresh the golden after an intentional behavior
//! change: `MOEB_GOLDEN_BLESS=1 cargo bench --bench workload_contention`
//! and commit the rewritten file (procedure in `rust/BENCHMARKS.md`).
//!
//! Artifacts for CI upload land in `target/workload/` (report JSON +
//! throughput–latency CSV).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::time_block;

use std::path::Path;

use moe_beyond::config::{EamConfig, SimConfig, TierConfig, WorkloadConfig};
use moe_beyond::sim::PredictorKind;
use moe_beyond::tier::TierSpec;
use moe_beyond::util::json::Json;
use moe_beyond::workload::{
    report_json, synthetic_fit_pool, synthetic_pools, Backend, LoadPoint, LoadSweepInputs,
    SchedPolicy, WorkloadSpec,
};
use moe_beyond::Result;

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;
const SEED: u64 = 17;
const HORIZON_SECS: f64 = 8.0;

fn tier_base() -> TierConfig {
    let total = N_LAYERS * N_EXPERTS;
    TierConfig {
        tiers: vec![
            TierSpec::gpu(1), // resized per grid point
            TierSpec::host(total / 4),
            TierSpec::ssd(total),
        ],
        policy: "lru".into(),
    }
}

/// Run the full pipeline once: (headline points, sweep points).
fn run_all() -> Result<(Vec<LoadPoint>, Vec<LoadPoint>)> {
    let spec = WorkloadSpec::example(3, SEED, HORIZON_SECS);
    let pools = synthetic_pools(&spec, 6, N_LAYERS as u16, N_EXPERTS);
    let fit = synthetic_fit_pool(&spec, 4, N_LAYERS as u16, N_EXPERTS);

    let wcfg = WorkloadConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let sim = SimConfig::default();
    let tiers = tier_base();
    let inputs: LoadSweepInputs = LoadSweepInputs {
        spec: &spec,
        pools: &pools,
        fit_traces: &fit,
        learned: None,
        workload: &wcfg,
        sim: &sim,
        eam: &eam,
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
        tier_base: &tiers,
        cluster_base: None,
        engine_shards: 1,
    };

    // headline: every policy × both backends at one contended point
    let headline = moe_beyond::workload::sweep_load(
        &inputs,
        &SchedPolicy::ALL,
        &Backend::ALL,
        &[PredictorKind::Eam],
        &[2.0],
        &[0.10],
    )?;
    // sweep: load × cache fraction × predictor under round-robin
    let sweep = moe_beyond::workload::sweep_load(
        &inputs,
        &[SchedPolicy::RoundRobin],
        &Backend::ALL,
        &[PredictorKind::Eam, PredictorKind::None],
        &[1.0, 4.0],
        &[0.05, 0.20],
    )?;
    Ok((headline, sweep))
}

fn golden_json(headline: &[LoadPoint], sweep: &[LoadPoint]) -> String {
    let enc = |pts: &[LoadPoint]| {
        Json::Arr(
            pts.iter()
                .map(|p| {
                    Json::obj(vec![
                        ("load_mult", Json::num(p.load_mult)),
                        ("cache_frac", Json::num(p.cache_frac)),
                        ("report", report_json(&p.report)),
                    ])
                })
                .collect(),
        )
    };
    let mut s = Json::obj(vec![
        ("blessed", Json::Bool(true)),
        ("schema", Json::num(1.0)),
        ("headline", enc(headline)),
        ("sweep", enc(sweep)),
    ])
    .to_json_string();
    s.push('\n');
    s
}

fn check_invariants(points: &[LoadPoint]) {
    for p in points {
        let r = &p.report;
        let c = &r.counters;
        let a = &r.aggregate;
        assert_eq!(c.idle_while_runnable, 0, "engine idled while runnable");
        assert_eq!(c.completions, c.admissions, "admitted requests were lost");
        assert_eq!(c.steps, a.tokens, "decode steps != decoded tokens");
        assert_eq!(c.prefill_steps, c.admissions, "one prefill per request");
        assert_eq!(a.ttft.count as u64, c.completions);
        assert_eq!(a.request_latency.count as u64, c.completions);
        assert_eq!(a.queue_delay.count as u64, c.admissions);
        assert_eq!(a.tbt.count as u64, a.tokens - c.completions);
        // every decode (token, layer) looks up exactly top_k=2 experts
        assert_eq!(a.cache.lookups(), a.tokens * N_LAYERS as u64 * 2);
        if p.policy == SchedPolicy::RoundRobin {
            assert_eq!(c.repeat_pick_with_waiters, 0, "round-robin starved a stream");
        }
        assert!(a.cache.hit_rate() >= 0.0 && a.cache.hit_rate() <= 1.0);
        assert!(r.virtual_secs > 0.0);
    }
}

fn print_headline(points: &[LoadPoint]) {
    println!("\n== contention headline (load 2.0x, cache 10%, predictor eam) ==");
    println!(
        "{:>12} {:>7} {:>6} {:>9} {:>12} {:>11} {:>13} {:>10}",
        "policy",
        "backend",
        "hit%",
        "done rps",
        "p95 TTFT ms",
        "p95 TBT ms",
        "p95 late ms",
        "stall ms"
    );
    for p in points {
        let a = &p.report.aggregate;
        println!(
            "{:>12} {:>7} {:>6.1} {:>9.2} {:>12.1} {:>11.1} {:>13.1} {:>10.1}",
            p.policy.id(),
            p.backend.id(),
            a.cache.hit_rate() * 100.0,
            p.report.completed_rps,
            a.ttft.p95_us / 1e3,
            a.tbt.p95_us / 1e3,
            a.request_latency.p95_us / 1e3,
            p.report.memory.stall_us / 1e3,
        );
    }
}

fn main() -> Result<()> {
    let (headline, sweep) = time_block("workload pipeline (run 1)", run_all)?;
    check_invariants(&headline);
    check_invariants(&sweep);
    print_headline(&headline);

    // FCFS preserves per-stream locality; print the interleaving cost
    let fcfs = &headline[0];
    let rr = headline
        .iter()
        .find(|p| p.policy == SchedPolicy::RoundRobin && p.backend == fcfs.backend)
        .expect("round-robin headline point");
    println!(
        "\ninterleaving cost (flat backend): hit rate {:.1}% under fcfs vs {:.1}% under round-robin",
        fcfs.report.aggregate.cache.hit_rate() * 100.0,
        rr.report.aggregate.cache.hit_rate() * 100.0
    );

    // ---- determinism: the whole pipeline, byte for byte
    let produced = golden_json(&headline, &sweep);
    let (h2, s2) = time_block("workload pipeline (run 2, determinism)", run_all)?;
    let produced2 = golden_json(&h2, &s2);
    assert_eq!(
        produced, produced2,
        "fixed-seed workload metrics are not byte-identical across runs"
    );
    println!("determinism: two full runs serialized byte-identically");

    // ---- artifacts for CI upload
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let out_dir = manifest.join("target/workload");
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("report.json"), &produced)?;
    std::fs::write(
        out_dir.join("sweep_load.csv"),
        moe_beyond::workload::load_csv(&sweep),
    )?;
    println!("artifacts: {}", out_dir.display());

    // ---- perf gate: diff against the checked-in golden file
    let golden_path = manifest.join("benches/golden/workload.json");
    let existing = std::fs::read_to_string(&golden_path).ok();
    let blessed = existing
        .as_deref()
        .and_then(|s| Json::parse(s).ok())
        .and_then(|j| j.get("blessed").and_then(|b| b.as_bool().ok()))
        .unwrap_or(false);
    let force_bless = std::env::var("MOEB_GOLDEN_BLESS").is_ok_and(|v| v == "1");

    if !blessed || force_bless {
        std::fs::write(&golden_path, &produced)?;
        println!(
            "golden {} — BLESSED a fresh golden file; commit rust/benches/golden/workload.json \
             to arm the perf gate",
            if blessed { "refresh requested" } else { "was a bootstrap placeholder" }
        );
        return Ok(());
    }

    let want = existing.expect("blessed golden file exists");
    if want.trim_end() != produced.trim_end() {
        for (i, (w, p)) in want.lines().zip(produced.lines()).enumerate() {
            if w != p {
                let col = w
                    .bytes()
                    .zip(p.bytes())
                    .position(|(a, b)| a != b)
                    .unwrap_or(w.len().min(p.len()));
                eprintln!("golden drift at line {}, byte {}:", i + 1, col);
                let lo = col.saturating_sub(60);
                eprintln!("  golden  : ...{}", &w[lo..(col + 60).min(w.len())]);
                eprintln!("  produced: ...{}", &p[lo..(col + 60).min(p.len())]);
                break;
            }
        }
        anyhow::bail!(
            "workload_contention metrics drifted from benches/golden/workload.json \
             (produced copy: {}). If the change is intentional, re-bless with \
             MOEB_GOLDEN_BLESS=1 and commit the new golden file.",
            out_dir.join("report.json").display()
        );
    }
    println!("perf gate: metrics match the blessed golden file");
    println!("\nshape check: PASS");
    Ok(())
}
