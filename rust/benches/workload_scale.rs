//! Bench + CI perf gate: workload-engine scale (`moe_beyond::workload`).
//!
//! Drains a single burst of 10⁵⁺ concurrent decode streams through the
//! indexed scheduler (free-slot bitmap + admission ring + remaining-
//! decode buckets) and gates two scale budgets:
//!
//! * **streams/sec** — full-drain throughput per policy must clear
//!   `MOEB_SCALE_MIN_SPS` (best of two runs: one retry absorbs CI
//!   noise; a real O(n) regression in the pick path fails both).
//! * **bytes per stream** — the analytic per-slot in-flight footprint
//!   (`inflight_state_bytes_per_stream`) must stay ≤ 128 bytes, the
//!   budget that makes 10⁶ streams ≈ 128 MB of scheduler state.
//!
//! A small staggered-arrival parity pass then re-checks, in release
//! mode, that the indexed engine and the linear-scan reference serialize
//! byte-identical reports on all three policies (the full suite lives in
//! `tests/workload_determinism.rs`).
//!
//! Self-contained: synthetic traces, fixed seed, no artifacts, no PJRT.
//! Scale knobs (`rust/BENCHMARKS.md`): `MOEB_SCALE_STREAMS` (default
//! 120 000, floor 100 000 for the gate) and `MOEB_SCALE_MIN_SPS`
//! (default 30 000).  Artifact for CI upload:
//! `target/workload/scale.json`.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::env_usize;

use std::path::Path;
use std::time::Instant;

use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, WorkloadConfig};
use moe_beyond::memory::{self, ExpertMemory};
use moe_beyond::sim::PredictorKind;
use moe_beyond::trace::{CompiledCorpus, PromptTrace};
use moe_beyond::util::json::Json;
use moe_beyond::workload::{
    inflight_state_bytes_per_stream, report_json, run_workload_engine, synthetic_pool,
    ArrivalEvent, ArrivalProcess, Schedule, SchedEngine, SchedPolicy, TenantProfile,
    WorkloadInputs, WorkloadReport, WorkloadSpec,
};
use moe_beyond::Result;

const N_LAYERS: usize = 2;
const N_EXPERTS: usize = 64;
const PROMPT: usize = 1;
const DECODE: usize = 2;
const STATE_BUDGET_BYTES: usize = 128;

fn one_tenant_spec() -> WorkloadSpec {
    WorkloadSpec {
        seed: 29,
        horizon_secs: 1.0,
        tenants: vec![TenantProfile {
            name: "scale".into(),
            arrival: ArrivalProcess::Poisson { rate_rps: 1.0 },
            prompt_tokens: (PROMPT, PROMPT),
            decode_tokens: (1, DECODE),
            trace_seed: 29,
        }],
    }
}

/// `n` requests, arriving `gap_us` apart (0 = one burst at t=0), each
/// `PROMPT` prompt + `DECODE` decode tokens over trace 0.
fn schedule(n: usize, gap_us: f64) -> Schedule {
    let arrivals: Vec<ArrivalEvent> = (0..n)
        .map(|i| ArrivalEvent {
            arrival_us: i as f64 * gap_us,
            tenant: 0,
            request_id: i as u64,
            trace_idx: 0,
            prompt_tokens: PROMPT,
            decode_tokens: DECODE,
        })
        .collect();
    Schedule {
        arrivals,
        horizon_us: (n as f64 * gap_us).max(1e6),
        offered_rps: n as f64,
    }
}

fn flat_memory(sim: &SimConfig) -> Box<dyn ExpertMemory> {
    let overlap = WorkloadConfig::default().token_compute_us / N_LAYERS as f64;
    memory::build(
        "lru",
        &CacheConfig::default().with_capacity(25),
        None,
        sim,
        N_EXPERTS,
        overlap,
    )
    .expect("flat lru memory")
}

struct Fixture {
    spec: WorkloadSpec,
    pools: Vec<Vec<PromptTrace>>,
    compiled: Vec<CompiledCorpus>,
    fit: Vec<PromptTrace>,
}

fn fixture() -> Fixture {
    let spec = one_tenant_spec();
    let pools = vec![synthetic_pool(29, 1, PROMPT + DECODE, N_LAYERS as u16, N_EXPERTS)];
    let compiled = pools.iter().map(|p| CompiledCorpus::compile(p)).collect();
    Fixture {
        spec,
        pools,
        fit: vec![],
        compiled,
    }
}

fn drain(
    fx: &Fixture,
    sched: &Schedule,
    policy: SchedPolicy,
    engine: SchedEngine,
    max_concurrency: usize,
) -> Result<WorkloadReport> {
    let cfg = WorkloadConfig {
        max_concurrency,
        policy: policy.id().to_string(),
        ..Default::default()
    };
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let inputs = WorkloadInputs {
        spec: &fx.spec,
        schedule: sched,
        pools: &fx.pools,
        fit_traces: &fx.fit,
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    run_workload_engine(
        &inputs,
        PredictorKind::None,
        flat_memory(&sim),
        &fx.compiled,
        &moe_beyond::obs::ObsSink::default(),
        engine,
    )
}

/// The full-scale burst must conserve every counter — a fast drain that
/// lost work is not a fast drain.
fn check_burst(r: &WorkloadReport, n: usize, policy: SchedPolicy) {
    let c = &r.counters;
    assert_eq!(c.admissions, n as u64, "{policy:?}");
    assert_eq!(c.completions, n as u64, "{policy:?}");
    assert_eq!(c.prefill_steps, n as u64, "{policy:?}");
    assert_eq!(c.steps, (n * DECODE) as u64, "{policy:?}");
    assert_eq!(c.max_inflight, n, "{policy:?} burst must fully overlap");
    assert_eq!(c.max_queue_depth, n, "{policy:?} burst depth pre-admission");
    assert_eq!(c.idle_while_runnable, 0, "{policy:?} idled while runnable");
    assert_eq!(r.aggregate.tokens, (n * DECODE) as u64, "{policy:?}");
}

fn main() -> Result<()> {
    let streams = env_usize("MOEB_SCALE_STREAMS", 120_000).max(100_000);
    let min_sps = env_usize("MOEB_SCALE_MIN_SPS", 30_000) as f64;

    // ---- budget 1: per-stream in-flight state
    let bytes = inflight_state_bytes_per_stream();
    println!(
        "in-flight state: {bytes} bytes/stream (budget {STATE_BUDGET_BYTES}) \
         => {:.0} MB at 10^6 streams",
        bytes as f64 * 1e6 / (1024.0 * 1024.0)
    );
    assert!(
        bytes <= STATE_BUDGET_BYTES,
        "per-stream scheduler state grew to {bytes} bytes (budget {STATE_BUDGET_BYTES})"
    );

    // ---- budget 2: full-burst throughput per policy, best of two runs
    let fx = fixture();
    let burst = schedule(streams, 0.0);
    println!("\n== {streams}-stream burst drain (indexed engine) ==");
    println!("{:>12} {:>10} {:>14} {:>9}", "policy", "secs", "streams/sec", "runs");
    let mut rows: Vec<(SchedPolicy, f64, f64)> = Vec::new();
    for policy in SchedPolicy::ALL {
        let mut best_sps = 0.0f64;
        let mut best_secs = f64::INFINITY;
        let mut runs = 0u32;
        // one retry absorbs a noisy neighbor; a real regression fails both
        while runs < 2 {
            let t0 = Instant::now();
            let r = drain(&fx, &burst, policy, SchedEngine::Indexed, streams)?;
            let secs = t0.elapsed().as_secs_f64();
            runs += 1;
            check_burst(&r, streams, policy);
            let sps = streams as f64 / secs.max(1e-9);
            if sps > best_sps {
                best_sps = sps;
                best_secs = secs;
            }
            if best_sps >= min_sps {
                break;
            }
        }
        println!(
            "{:>12} {:>10.3} {:>14.0} {:>9}",
            policy.id(),
            best_secs,
            best_sps,
            runs
        );
        rows.push((policy, best_secs, best_sps));
    }

    // ---- release-mode engine parity on a staggered schedule
    let parity_n = 3_000.min(streams);
    let staggered = schedule(parity_n, 40.0);
    for policy in SchedPolicy::ALL {
        let a = drain(&fx, &staggered, policy, SchedEngine::Indexed, 64)?;
        let b = drain(&fx, &staggered, policy, SchedEngine::LinearScan, 64)?;
        assert_eq!(
            report_json(&a).to_json_string(),
            report_json(&b).to_json_string(),
            "{policy:?}: indexed engine diverged from the linear-scan reference"
        );
    }
    println!("parity: indexed == linear-scan on {parity_n} staggered streams, all policies");

    // ---- artifact for CI upload
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let out_dir = manifest.join("target/workload");
    std::fs::create_dir_all(&out_dir)?;
    let artifact = Json::obj(vec![
        ("streams", Json::num(streams as f64)),
        ("bytes_per_stream", Json::num(bytes as f64)),
        ("min_streams_per_sec", Json::num(min_sps)),
        (
            "policies",
            Json::Arr(
                rows.iter()
                    .map(|(p, secs, sps)| {
                        Json::obj(vec![
                            ("policy", Json::str(p.id())),
                            ("secs", Json::num(*secs)),
                            ("streams_per_sec", Json::num(*sps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let mut body = artifact.to_json_string();
    body.push('\n');
    std::fs::write(out_dir.join("scale.json"), body)?;
    println!("artifact: {}", out_dir.join("scale.json").display());

    // ---- gate LAST so the artifact exists even on failure
    for (policy, _, sps) in &rows {
        if *sps < min_sps {
            anyhow::bail!(
                "{policy:?} drained {sps:.0} streams/sec at {streams} streams \
                 (floor {min_sps:.0}; override with MOEB_SCALE_MIN_SPS)"
            );
        }
    }
    println!("\nshape check: PASS");
    Ok(())
}
