//! Belady (MIN) cache — the offline-optimal eviction policy, used as the
//! simulator's upper bound on what ANY eviction policy can achieve at a
//! given capacity (prefetching aside).
//!
//! Requires the future reference string, so it only exists inside the
//! trace-driven simulator: `prime` loads the full (token, layer) expert
//! sequence; eviction picks the resident key whose next use is farthest
//! in the future.

use std::collections::HashMap;

use super::policy::{CachePolicy, ExpertKey};

pub struct BeladyCache {
    capacity: usize,
    resident: Vec<ExpertKey>,
    /// For each key, the (sorted) positions at which it will be used.
    uses: HashMap<ExpertKey, Vec<u32>>,
    /// Cursor into the reference string.
    clock: u32,
}

impl BeladyCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            resident: Vec::with_capacity(capacity),
            uses: HashMap::new(),
            clock: 0,
        }
    }

    /// Load the future reference string (keys in lookup order).
    pub fn prime(&mut self, reference: &[ExpertKey]) {
        self.uses.clear();
        for (i, &k) in reference.iter().enumerate() {
            self.uses.entry(k).or_default().push(i as u32);
        }
        self.clock = 0;
        self.resident.clear();
    }

    /// Advance the reference cursor (call once per lookup, after touch).
    pub fn tick(&mut self) {
        self.clock += 1;
    }

    fn next_use(&self, k: ExpertKey) -> u32 {
        match self.uses.get(&k) {
            None => u32::MAX,
            Some(v) => {
                // first use strictly at/after clock
                match v.binary_search(&self.clock) {
                    Ok(i) => v[i],
                    Err(i) if i < v.len() => v[i],
                    _ => u32::MAX,
                }
            }
        }
    }
}

impl CachePolicy for BeladyCache {
    fn contains(&self, k: ExpertKey) -> bool {
        self.resident.contains(&k)
    }

    fn touch(&mut self, k: ExpertKey) -> bool {
        self.contains(k)
    }

    fn insert(&mut self, k: ExpertKey) -> Option<ExpertKey> {
        if self.contains(k) {
            return None;
        }
        let mut evicted = None;
        if self.resident.len() == self.capacity {
            // evict the key with the farthest next use
            let (idx, _) = self
                .resident
                .iter()
                .enumerate()
                .max_by_key(|(_, &r)| self.next_use(r))
                .unwrap();
            evicted = Some(self.resident.swap_remove(idx));
        }
        self.resident.push(k);
        evicted
    }

    fn evict(&mut self, k: ExpertKey) -> bool {
        if let Some(i) = self.resident.iter().position(|&r| r == k) {
            self.resident.swap_remove(i);
            true
        } else {
            false
        }
    }

    fn len(&self) -> usize {
        self.resident.len()
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn clear(&mut self) {
        self.resident.clear();
        self.clock = 0;
    }

    fn resident(&self) -> Vec<ExpertKey> {
        self.resident.clone()
    }

    fn name(&self) -> &'static str {
        "belady"
    }
}

/// Run the Belady-optimal hit rate for a reference string at `capacity`.
pub fn belady_hit_rate(reference: &[ExpertKey], capacity: usize) -> f64 {
    if reference.is_empty() {
        return 0.0;
    }
    let mut c = BeladyCache::new(capacity);
    c.prime(reference);
    let mut hits = 0u64;
    for &k in reference {
        c.tick(); // next_use must look strictly past the current position
        if c.touch(k) {
            hits += 1;
        } else {
            c.insert(k);
        }
    }
    hits as f64 / reference.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::LruCache;
    use crate::util::Rng;

    #[test]
    fn belady_classic_example() {
        // reference 1,2,3,4,1,2,5,1,2,3,4,5 with capacity 3:
        // Belady gives 5 hits (7 faults)
        let r: Vec<u32> = vec![1, 2, 3, 4, 1, 2, 5, 1, 2, 3, 4, 5];
        let hr = belady_hit_rate(&r, 3);
        assert!((hr - 5.0 / 12.0).abs() < 1e-9, "hit rate {hr}");
    }

    #[test]
    fn full_capacity_misses_only_cold() {
        let r: Vec<u32> = vec![1, 2, 3, 1, 2, 3, 1, 2, 3];
        let hr = belady_hit_rate(&r, 10);
        assert!((hr - 6.0 / 9.0).abs() < 1e-9);
    }

    #[test]
    fn prop_belady_dominates_lru() {
        let mut rng = Rng::new(77);
        for _case in 0..80 {
            let cap = rng.range(2, 10);
            let n = rng.range(10, 200);
            let reference: Vec<u32> = (0..n).map(|_| rng.below(20) as u32).collect();
            let opt = belady_hit_rate(&reference, cap);

            let mut lru = LruCache::new(cap);
            let mut hits = 0u64;
            for &k in &reference {
                if lru.touch(k) {
                    hits += 1;
                } else {
                    lru.insert(k);
                }
            }
            let lru_hr = hits as f64 / n as f64;
            assert!(
                opt >= lru_hr - 1e-9,
                "belady {opt} < lru {lru_hr} (cap {cap})"
            );
        }
    }
}
