//! LFU expert cache — frequency-ordered eviction with LRU tie-break,
//! O(capacity) eviction scan over dense slots (capacity ≤ 1728, and
//! eviction is off the fast path, so the scan beats maintaining a heap).

use super::policy::{CachePolicy, ExpertKey};

#[derive(Clone, Copy, Default)]
struct Slot {
    resident: bool,
    freq: u32,
    last_use: u64,
}

pub struct LfuCache {
    slots: Vec<Slot>,
    clock: u64,
    len: usize,
    capacity: usize,
}

impl LfuCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "LFU capacity must be > 0");
        Self {
            slots: Vec::new(),
            clock: 0,
            len: 0,
            capacity,
        }
    }

    fn ensure(&mut self, k: ExpertKey) {
        let need = k as usize + 1;
        if self.slots.len() < need {
            self.slots.resize(need, Slot::default());
        }
    }

    fn victim(&self) -> ExpertKey {
        let mut best: Option<(u32, u64, ExpertKey)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            if !s.resident {
                continue;
            }
            let cand = (s.freq, s.last_use, i as ExpertKey);
            if best.map(|b| (cand.0, cand.1) < (b.0, b.1)).unwrap_or(true) {
                best = Some(cand);
            }
        }
        best.expect("victim() on empty cache").2
    }
}

impl CachePolicy for LfuCache {
    fn contains(&self, k: ExpertKey) -> bool {
        self.slots
            .get(k as usize)
            .map(|s| s.resident)
            .unwrap_or(false)
    }

    fn touch(&mut self, k: ExpertKey) -> bool {
        self.clock += 1;
        if !self.contains(k) {
            return false;
        }
        let s = &mut self.slots[k as usize];
        s.freq += 1;
        s.last_use = self.clock;
        true
    }

    fn insert(&mut self, k: ExpertKey) -> Option<ExpertKey> {
        self.ensure(k);
        self.clock += 1;
        if self.slots[k as usize].resident {
            self.slots[k as usize].freq += 1;
            self.slots[k as usize].last_use = self.clock;
            return None;
        }
        let mut evicted = None;
        if self.len == self.capacity {
            let v = self.victim();
            self.slots[v as usize].resident = false;
            self.slots[v as usize].freq = 0;
            self.len -= 1;
            evicted = Some(v);
        }
        let s = &mut self.slots[k as usize];
        s.resident = true;
        s.freq = 1;
        s.last_use = self.clock;
        self.len += 1;
        evicted
    }

    fn evict(&mut self, k: ExpertKey) -> bool {
        if !self.contains(k) {
            return false;
        }
        self.slots[k as usize].resident = false;
        self.slots[k as usize].freq = 0;
        self.len -= 1;
        true
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn clear(&mut self) {
        self.slots.fill(Slot::default());
        self.len = 0;
        self.clock = 0;
    }

    fn resident(&self) -> Vec<ExpertKey> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.resident)
            .map(|(i, _)| i as ExpertKey)
            .collect()
    }

    fn name(&self) -> &'static str {
        "lfu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_frequent() {
        let mut c = LfuCache::new(2);
        c.insert(1);
        c.insert(2);
        c.touch(1);
        c.touch(1); // freq(1)=3, freq(2)=1
        assert_eq!(c.insert(3), Some(2));
        assert!(c.contains(1));
    }

    #[test]
    fn tie_breaks_by_lru() {
        let mut c = LfuCache::new(2);
        c.insert(1);
        c.insert(2); // equal freq=1, 1 older
        assert_eq!(c.insert(3), Some(1));
    }

    #[test]
    fn reinsert_bumps_freq() {
        let mut c = LfuCache::new(2);
        c.insert(1);
        c.insert(1); // freq 2
        c.insert(2); // freq 1
        assert_eq!(c.insert(3), Some(2));
    }

    #[test]
    fn prop_capacity_never_exceeded() {
        let mut rng = crate::util::Rng::new(41);
        for _case in 0..100 {
            let cap = rng.range(1, 8);
            let mut c = LfuCache::new(cap);
            for _ in 0..rng.range(1, 200) {
                c.insert(rng.below(30) as u32);
                assert!(c.len() <= cap);
                assert_eq!(c.resident().len(), c.len());
            }
        }
    }

    #[test]
    fn prop_eviction_picks_min_freq() {
        let mut rng = crate::util::Rng::new(42);
        for _case in 0..100 {
            let mut c = LfuCache::new(4);
            let mut freqs = std::collections::HashMap::<u32, u32>::new();
            for _ in 0..rng.range(1, 100) {
                let k = rng.below(10) as u32;
                let resident_before: Vec<u32> = c.resident();
                let evicted = c.insert(k);
                if let Some(v) = evicted {
                    // evicted key's frequency must be <= all remaining
                    let fv = freqs.get(&v).copied().unwrap_or(0);
                    for r in c.resident() {
                        if r != k && resident_before.contains(&r) {
                            assert!(fv <= freqs.get(&r).copied().unwrap_or(0));
                        }
                    }
                    freqs.insert(v, 0);
                }
                *freqs.entry(k).or_insert(0) += 1;
            }
        }
    }
}
