//! LRU expert cache — intrusive doubly-linked list over a dense slot
//! table, O(1) for every operation, zero allocation after construction.

use super::policy::{CachePolicy, ExpertKey};

const NIL: u32 = u32::MAX;

/// Per-key node state; `prev`/`next` weave the recency list (head = MRU).
#[derive(Clone, Copy)]
struct Node {
    prev: u32,
    next: u32,
    resident: bool,
}

pub struct LruCache {
    nodes: Vec<Node>,
    head: u32, // most recently used
    tail: u32, // least recently used
    len: usize,
    capacity: usize,
}

impl LruCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "LRU capacity must be > 0");
        Self {
            nodes: Vec::new(),
            head: NIL,
            tail: NIL,
            len: 0,
            capacity,
        }
    }

    fn ensure(&mut self, k: ExpertKey) {
        let need = k as usize + 1;
        if self.nodes.len() < need {
            self.nodes.resize(
                need,
                Node {
                    prev: NIL,
                    next: NIL,
                    resident: false,
                },
            );
        }
    }

    fn unlink(&mut self, k: u32) {
        let (p, n) = (self.nodes[k as usize].prev, self.nodes[k as usize].next);
        if p != NIL {
            self.nodes[p as usize].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.nodes[n as usize].prev = p;
        } else {
            self.tail = p;
        }
        self.nodes[k as usize].prev = NIL;
        self.nodes[k as usize].next = NIL;
    }

    fn push_front(&mut self, k: u32) {
        self.nodes[k as usize].prev = NIL;
        self.nodes[k as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = k;
        }
        self.head = k;
        if self.tail == NIL {
            self.tail = k;
        }
    }
}

impl CachePolicy for LruCache {
    fn contains(&self, k: ExpertKey) -> bool {
        self.nodes
            .get(k as usize)
            .map(|n| n.resident)
            .unwrap_or(false)
    }

    fn touch(&mut self, k: ExpertKey) -> bool {
        if !self.contains(k) {
            return false;
        }
        if self.head != k {
            self.unlink(k);
            self.push_front(k);
        }
        true
    }

    fn insert(&mut self, k: ExpertKey) -> Option<ExpertKey> {
        self.ensure(k);
        if self.nodes[k as usize].resident {
            self.touch(k);
            return None;
        }
        let mut evicted = None;
        if self.len == self.capacity {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.unlink(victim);
            self.nodes[victim as usize].resident = false;
            self.len -= 1;
            evicted = Some(victim);
        }
        self.nodes[k as usize].resident = true;
        self.push_front(k);
        self.len += 1;
        evicted
    }

    fn evict(&mut self, k: ExpertKey) -> bool {
        if !self.contains(k) {
            return false;
        }
        self.unlink(k);
        self.nodes[k as usize].resident = false;
        self.len -= 1;
        true
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn clear(&mut self) {
        for n in &mut self.nodes {
            *n = Node {
                prev: NIL,
                next: NIL,
                resident: false,
            };
        }
        self.head = NIL;
        self.tail = NIL;
        self.len = 0;
    }

    fn resident(&self) -> Vec<ExpertKey> {
        let mut out = Vec::with_capacity(self.len);
        let mut cur = self.head;
        while cur != NIL {
            out.push(cur);
            cur = self.nodes[cur as usize].next;
        }
        out
    }

    fn name(&self) -> &'static str {
        "lru"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        assert_eq!(c.insert(1), None);
        assert_eq!(c.insert(2), None);
        assert_eq!(c.insert(3), Some(1)); // 1 is LRU
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3));
    }

    #[test]
    fn touch_refreshes_recency() {
        let mut c = LruCache::new(2);
        c.insert(1);
        c.insert(2);
        assert!(c.touch(1)); // 1 becomes MRU
        assert_eq!(c.insert(3), Some(2));
        assert!(c.contains(1));
    }

    #[test]
    fn reinsert_is_refresh_not_grow() {
        let mut c = LruCache::new(2);
        c.insert(1);
        c.insert(2);
        assert_eq!(c.insert(1), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.insert(3), Some(2)); // 2 was LRU after 1's refresh
    }

    #[test]
    fn explicit_evict() {
        let mut c = LruCache::new(3);
        c.insert(5);
        assert!(c.evict(5));
        assert!(!c.evict(5));
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn resident_order_is_mru_first() {
        let mut c = LruCache::new(3);
        c.insert(1);
        c.insert(2);
        c.insert(3);
        c.touch(1);
        assert_eq!(c.resident(), vec![1, 3, 2]);
    }

    /// Model-based property test against a reference implementation
    /// (Vec as recency list), seeded random ops.
    #[test]
    fn prop_matches_reference_model() {
        let mut rng = crate::util::Rng::new(31);
        for _case in 0..150 {
            let cap = rng.range(1, 12);
            let n_ops = rng.range(1, 300);
            let mut c = LruCache::new(cap);
            let mut model: Vec<u32> = Vec::new(); // front = MRU
            for _ in 0..n_ops {
                let k = rng.below(40) as u32;
                let is_insert = rng.f64() < 0.5;
                if is_insert {
                    let evicted = c.insert(k);
                    if let Some(pos) = model.iter().position(|&x| x == k) {
                        model.remove(pos);
                        model.insert(0, k);
                        assert_eq!(evicted, None);
                    } else {
                        let mut want = None;
                        if model.len() == cap {
                            want = model.pop();
                        }
                        model.insert(0, k);
                        assert_eq!(evicted, want);
                    }
                } else {
                    let hit = c.touch(k);
                    let mhit = model.contains(&k);
                    assert_eq!(hit, mhit);
                    if mhit {
                        let pos = model.iter().position(|&x| x == k).unwrap();
                        model.remove(pos);
                        model.insert(0, k);
                    }
                }
                assert!(c.len() <= cap);
                assert_eq!(c.len(), model.len());
                assert_eq!(c.resident(), model.clone());
            }
        }
    }
}
