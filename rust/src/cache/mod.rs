//! The simulated GPU-VRAM expert cache (paper §2.3).
//!
//! Experts are identified by a dense id `layer * n_experts + expert`
//! (≤ 27×64 = 1728 for the DeepSeek-V2-Lite topology), so every policy
//! can use flat arrays instead of hash maps on the hot path.

mod belady;
mod lfu;
mod lru;
pub mod policy;
pub mod stackdist;
mod stats;
mod vram;

pub use belady::{belady_hit_rate, BeladyCache};
pub use lfu::LfuCache;
pub use lru::LruCache;
pub use policy::{CachePolicy, EvictionPolicy, ExpertKey};
pub use stackdist::{StackDistCurve, StackDistProfile, TierBands};
pub use stats::CacheStats;
pub use vram::VramModel;

/// Build a policy by name ("lru" | "lfu").
pub fn build_policy(name: &str, capacity: usize) -> crate::Result<Box<dyn CachePolicy>> {
    match name {
        "lru" => Ok(Box::new(LruCache::new(capacity))),
        "lfu" => Ok(Box::new(LfuCache::new(capacity))),
        other => anyhow::bail!("unknown cache policy {other}"),
    }
}
