//! Cache policy trait + expert keying.

/// Dense expert key: `layer * n_experts + expert_id`.
pub type ExpertKey = u32;

/// Compose a dense key.
#[inline]
pub fn key(layer: usize, expert: u8, n_experts: usize) -> ExpertKey {
    (layer * n_experts + expert as usize) as ExpertKey
}

/// Decompose a dense key.
#[inline]
pub fn unkey(k: ExpertKey, n_experts: usize) -> (usize, u8) {
    ((k as usize) / n_experts, ((k as usize) % n_experts) as u8)
}

/// Eviction policy identifier (config / reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    Lru,
    Lfu,
}

/// A bounded set of resident experts with an eviction policy.
///
/// Contract invariants (enforced by proptests in `sim`):
/// * `len() <= capacity()` at all times,
/// * `insert` of a resident key only refreshes recency/frequency,
/// * evictions only happen on insert into a full cache, one per insert.
pub trait CachePolicy: Send {
    /// Is this expert resident? Does NOT update recency.
    fn contains(&self, k: ExpertKey) -> bool;

    /// Record a use of `k` (recency/frequency bump). Returns true if it
    /// was resident (a hit).
    fn touch(&mut self, k: ExpertKey) -> bool;

    /// Make `k` resident, evicting if needed. Returns the evicted key.
    fn insert(&mut self, k: ExpertKey) -> Option<ExpertKey>;

    /// Evict a specific key (used by pinning logic / invalidation).
    fn evict(&mut self, k: ExpertKey) -> bool;

    fn len(&self) -> usize;
    fn capacity(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn clear(&mut self);

    /// Resident keys (unordered); for diagnostics and invariant checks.
    fn resident(&self) -> Vec<ExpertKey>;

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_roundtrip() {
        for layer in [0usize, 5, 26] {
            for expert in [0u8, 17, 63] {
                let k = key(layer, expert, 64);
                assert_eq!(unkey(k, 64), (layer, expert));
            }
        }
    }
}
