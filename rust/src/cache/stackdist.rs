//! Mattson stack-distance profiling — the whole LRU hit-rate-vs-capacity
//! curve from ONE pass over a trace.
//!
//! LRU has the stack (inclusion) property: an access whose stack
//! distance is `d` (the number of *distinct* keys referenced since the
//! previous access to the same key) hits every LRU cache of capacity
//! `> d` and misses every smaller one.  So a single replay that records
//! the histogram of stack distances answers "how many hits at capacity
//! C?" for EVERY C at once — the Fig-7 no-prefetch baseline axis costs
//! one corpus pass instead of one replay per capacity fraction
//! (`sim::sweep` wires this in as a fast path; see
//! `sweep_capacities_replay_threaded` for the retained exact-replay
//! fallback).
//!
//! # Tiered hierarchies from the same histogram
//!
//! The inclusion property generalizes to the *exclusive* multi-tier LRU
//! hierarchy ([`crate::tier::TieredCache`] with the `lru` policy): a
//! lookup promotes to tier 0's MRU slot, each tier's LRU victim demotes
//! to the next tier's MRU slot, and the last tier's victim drops — so
//! the hierarchy always holds exactly the `C_0 + … + C_{n-1}`
//! most-recently-used keys, partitioned by recency rank (tier 0 holds
//! ranks `< C_0`, tier 1 ranks `[C_0, C_0+C_1)`, …).  A reference at
//! stack distance `d` is therefore served from the tier whose
//! capacity-prefix band contains `d`, and the SAME single-corpus
//! histogram yields per-tier serve counts for ANY capacity split
//! ([`StackDistCurve::tier_bands`]).  Demotion traffic falls out too:
//! promoting a key found at depth `f` evicts one key into each of tiers
//! `1..=f` (tiers above a non-empty tier are always full), so an access
//! displaces a key into tier `j` exactly when its recency depth is
//! `>= C_0 + … + C_{j-1}` — for first touches that depth is the number
//! of distinct keys already referenced, recorded in a second histogram.
//!
//! The fast paths only apply to *demand-only* LRU replay
//! ([`crate::predictor::NoPrefetch`]): prefetching inserts keys the
//! reference stream never touched, which breaks the inclusion property
//! (a small cache can evict a prefetched key a big cache keeps), so
//! predictor-driven sweep points always take the exact replay.
//!
//! Distances are computed with a Fenwick tree over access timestamps
//! (the classic O(N log N) Mattson algorithm): each in-stack key is
//! marked at its most recent access position, so the number of marks in
//! `(last[k], now)` is exactly the number of distinct keys referenced
//! since `last[k]`.

use crate::cache::CacheStats;
use crate::trace::CompiledTrace;

/// Fenwick (binary indexed) tree over 1-based positions.
struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Self {
            tree: vec![0; n + 1],
        }
    }

    fn add(&mut self, mut i: usize, delta: i32) {
        while i < self.tree.len() {
            self.tree[i] = (self.tree[i] as i32 + delta) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of marks in positions `1..=i`.
    fn prefix(&self, mut i: usize) -> u32 {
        let mut s = 0u32;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Stack-distance histogram of the *measured* accesses of one or more
/// prompt replays (warm-up accesses shape the distances but are never
/// recorded — exactly the simulator's warm-up epoch semantics).
#[derive(Debug, Clone, Default)]
pub struct StackDistProfile {
    /// `hist[d]` = measured re-references at stack distance `d`; such an
    /// access hits every LRU cache with capacity `> d`.
    hist: Vec<u64>,
    /// `cold_fill[D]` = measured first-touch accesses that happened when
    /// `D` distinct keys had already been referenced (the hierarchy fill
    /// state a tiered evaluation needs); Σ cold_fill == `cold`.
    cold_fill: Vec<u64>,
    /// Measured first-touch accesses — a miss at every capacity.
    pub cold: u64,
    /// Total measured accesses (`hits_at(c) + misses` for any `c`).
    pub measured: u64,
}

impl StackDistProfile {
    pub fn new() -> Self {
        Self::default()
    }

    fn record(&mut self, depth: usize) {
        if self.hist.len() <= depth {
            self.hist.resize(depth + 1, 0);
        }
        self.hist[depth] += 1;
        self.measured += 1;
    }

    fn record_cold(&mut self, fill: usize) {
        if self.cold_fill.len() <= fill {
            self.cold_fill.resize(fill + 1, 0);
        }
        self.cold_fill[fill] += 1;
        self.cold += 1;
        self.measured += 1;
    }

    /// Fold another profile in (capacity curves are additive across
    /// prompts because the sweep replays each prompt on a fresh cache —
    /// fill states reset per prompt too, so `cold_fill` adds likewise).
    pub fn merge(&mut self, other: &StackDistProfile) {
        if self.hist.len() < other.hist.len() {
            self.hist.resize(other.hist.len(), 0);
        }
        for (a, b) in self.hist.iter_mut().zip(other.hist.iter()) {
            *a += b;
        }
        if self.cold_fill.len() < other.cold_fill.len() {
            self.cold_fill.resize(other.cold_fill.len(), 0);
        }
        for (a, b) in self.cold_fill.iter_mut().zip(other.cold_fill.iter()) {
            *a += b;
        }
        self.cold += other.cold;
        self.measured += other.measured;
    }

    /// Measured hits an LRU cache of `capacity` experts would serve.
    pub fn hits_at(&self, capacity: usize) -> u64 {
        self.hist.iter().take(capacity).sum()
    }

    /// The [`CacheStats`] a no-prefetch LRU replay at `capacity` would
    /// produce: every measured access is also a prediction-total count
    /// with zero prediction hits (the `NoPrefetch` predictor), and each
    /// miss is charged `pcie_us_per_expert` of transfer time.
    pub fn cache_stats(&self, capacity: usize, pcie_us_per_expert: f64) -> CacheStats {
        let hits = self.hits_at(capacity);
        let misses = self.measured - hits;
        CacheStats {
            hits,
            misses,
            prefetches: 0,
            wasted_prefetches: 0,
            prediction_hits: 0,
            prediction_total: self.measured,
            // n·cost is bit-identical to the replay's per-miss
            // accumulation whenever partial sums are exactly
            // representable (integer-valued µs costs, as configured
            // throughout this crate)
            transfer_us: misses as f64 * pcie_us_per_expert,
        }
    }

    /// Cumulative view with O(1) band queries — build once per sweep,
    /// then every grid cell is a handful of prefix lookups.
    pub fn curve(&self) -> StackDistCurve {
        let mut cum_hist = Vec::with_capacity(self.hist.len() + 1);
        cum_hist.push(0u64);
        let mut acc = 0u64;
        for &h in &self.hist {
            acc += h;
            cum_hist.push(acc);
        }
        let reref_total = acc;
        let mut cum_fill = Vec::with_capacity(self.cold_fill.len() + 1);
        cum_fill.push(0u64);
        let mut acc = 0u64;
        for &h in &self.cold_fill {
            acc += h;
            cum_fill.push(acc);
        }
        StackDistCurve {
            cum_hist,
            cum_fill,
            reref_total,
            first_total: self.cold,
            measured: self.measured,
        }
    }
}

/// Per-tier outcome counts for one capacity split of an exclusive LRU
/// hierarchy, read off a [`StackDistCurve`] — everything a tiered
/// no-prefetch replay would count, without replaying.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierBands {
    /// `served[d]` = measured references found at depth `d` (`served[0]`
    /// is the GPU hit count).
    pub served: Vec<u64>,
    /// Measured references that missed every tier: first touches plus
    /// re-references whose stack distance exceeds the hierarchy's total
    /// capacity (the key was dropped past the last tier).
    pub cold: u64,
    /// `demotions_into[j]` = evictions that landed in tier `j` (index 0
    /// is never a demotion destination and stays 0).
    pub demotions_into: Vec<u64>,
    /// Evictions that fell past the last tier (copy dropped).
    pub dropped: u64,
}

impl TierBands {
    /// Demand promotions into the GPU tier (every measured non-GPU-hit).
    pub fn promotions(&self) -> u64 {
        self.served.iter().skip(1).sum::<u64>() + self.cold
    }

    /// Total demotion count across all destination tiers.
    pub fn demotions(&self) -> u64 {
        self.demotions_into.iter().sum()
    }
}

/// Prefix-summed [`StackDistProfile`]: `hits_at` and per-tier band
/// extraction in O(tiers) per query instead of O(capacity).
#[derive(Debug, Clone)]
pub struct StackDistCurve {
    /// `cum_hist[i]` = measured re-references with stack distance `< i`.
    cum_hist: Vec<u64>,
    /// `cum_fill[i]` = measured first touches with fill state `< i`.
    cum_fill: Vec<u64>,
    reref_total: u64,
    first_total: u64,
    /// Total measured accesses.
    pub measured: u64,
}

impl StackDistCurve {
    #[inline]
    fn below(cum: &[u64], c: usize) -> u64 {
        cum[c.min(cum.len() - 1)]
    }

    /// Measured hits an LRU cache of `capacity` experts would serve
    /// (O(1); equal to [`StackDistProfile::hits_at`]).
    pub fn hits_at(&self, capacity: usize) -> u64 {
        Self::below(&self.cum_hist, capacity)
    }

    /// Per-tier outcome counts for the exclusive LRU hierarchy with the
    /// given per-tier capacities (`caps[0]` = GPU).
    ///
    /// Band math (see the module docs for why the hierarchy is globally
    /// recency-ordered): with prefix capacities `P_j = C_0 + … +
    /// C_{j-1}`, a re-reference at stack distance `d`
    /// * is served from the tier `j` with `P_j <= d < P_{j+1}` (depth 0
    ///   = a GPU hit), or misses every tier when `d >= P_n`;
    /// * displaces one key into tier `j` for every `j >= 1` with
    ///   `d >= P_j` (those tiers are full and sit above the key), the
    ///   last displacement dropping off the hierarchy when `d >= P_n`.
    ///
    /// First touches behave the same with the fill state (distinct keys
    /// already referenced) in place of `d` — they are always cold, and
    /// they only displace keys into tiers the existing residency has
    /// already filled.
    pub fn tier_bands(&self, caps: &[usize]) -> TierBands {
        assert!(!caps.is_empty(), "tier_bands needs at least one tier");
        let n = caps.len();
        let mut prefix = Vec::with_capacity(n + 1);
        prefix.push(0usize);
        for &c in caps {
            prefix.push(prefix.last().unwrap() + c);
        }
        let mut served = vec![0u64; n];
        let mut prev = 0u64;
        for (d, s) in served.iter_mut().enumerate() {
            let b = Self::below(&self.cum_hist, prefix[d + 1]);
            *s = b - prev;
            prev = b;
        }
        let reref_cold = self.reref_total - prev;
        let mut demotions_into = vec![0u64; n];
        for (j, slot) in demotions_into.iter_mut().enumerate().skip(1) {
            *slot = (self.reref_total - Self::below(&self.cum_hist, prefix[j]))
                + (self.first_total - Self::below(&self.cum_fill, prefix[j]));
        }
        let total = prefix[n];
        let dropped = (self.reref_total - Self::below(&self.cum_hist, total))
            + (self.first_total - Self::below(&self.cum_fill, total));
        TierBands {
            served,
            cold: self.first_total + reref_cold,
            demotions_into,
            dropped,
        }
    }
}

/// Profile one prompt's LRU reference stream (the exact stream
/// `SimEngine::run_prompt` generates: token-major, then layer, then
/// ascending expert id within each ground-truth set) into `out`.
///
/// `warmup_tokens` mirrors `SimConfig::warmup_tokens`: accesses of
/// tokens `< warmup` move the (virtual) residency but are not recorded.
///
/// Width-generic: the reference stream is id-based, so the profile is
/// identical for any [`ExpertSet`](crate::util::ExpertSet) word width
/// `N` that holds `n_experts`.
pub fn profile_prompt<const N: usize>(
    trace: &CompiledTrace<N>,
    n_experts: usize,
    warmup_tokens: usize,
    out: &mut StackDistProfile,
) {
    let n_tokens = trace.n_tokens();
    let n_layers = trace.n_layers();
    let warm = warmup_tokens.min(n_tokens);
    let n_refs = trace.total_activations();
    let mut fen = Fenwick::new(n_refs);
    // last access position per dense key (layer * n_experts + expert);
    // 0 = never accessed (positions are 1-based)
    let mut last = vec![0u32; n_layers * n_experts];
    let mut pos = 0usize;
    // all marks sit at positions < pos, so the full prefix sum is just
    // the number of distinct keys seen so far — one counter instead of a
    // second Fenwick query per access
    let mut in_stack = 0u32;
    for t in 0..n_tokens {
        let measured = t >= warm;
        for l in 0..n_layers {
            for e in trace.set(t, l).iter() {
                pos += 1;
                let k = l * n_experts + e as usize;
                let prev = last[k] as usize;
                if prev == 0 {
                    if measured {
                        // fill state = distinct keys referenced before
                        // this first touch
                        out.record_cold(in_stack as usize);
                    }
                    in_stack += 1;
                } else {
                    // distinct keys referenced since `prev`: every
                    // in-stack key is marked at its latest position, so
                    // count marks in (prev, pos) = in_stack - prefix(prev)
                    let depth = (in_stack - fen.prefix(prev)) as usize;
                    if measured {
                        out.record(depth);
                    }
                    fen.add(prev, -1);
                }
                fen.add(pos, 1);
                last[k] = pos as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CachePolicy, LruCache};
    use crate::tier::TieredCache;
    use crate::trace::{CompiledTrace, PromptTrace};
    use crate::util::Rng;

    fn random_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, pool: u8) -> PromptTrace {
        let mut experts = Vec::new();
        for _ in 0..n_tokens * n_layers as usize {
            let a = rng.below(pool as usize) as u8;
            let b = (a + 1 + rng.below(pool as usize - 2) as u8) % pool;
            experts.push(a);
            experts.push(b);
        }
        PromptTrace {
            prompt_id: 0,
            n_layers,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0; n_tokens],
            embeddings: vec![],
            experts,
        }
    }

    /// Brute-force LRU replay of the same reference stream at one
    /// capacity (the definitionally-correct reference).
    fn brute_force_hits(
        trace: &CompiledTrace,
        n_experts: usize,
        warmup_tokens: usize,
        capacity: usize,
    ) -> (u64, u64) {
        let mut cache = LruCache::new(capacity);
        let (mut hits, mut misses) = (0u64, 0u64);
        let warm = warmup_tokens.min(trace.n_tokens());
        for t in 0..trace.n_tokens() {
            for l in 0..trace.n_layers() {
                for e in trace.set(t, l).iter() {
                    let k = crate::cache::policy::key(l, e, n_experts);
                    if cache.touch(k) {
                        if t >= warm {
                            hits += 1;
                        }
                    } else {
                        if t >= warm {
                            misses += 1;
                        }
                        cache.insert(k);
                    }
                }
            }
        }
        (hits, misses)
    }

    /// Brute-force multi-tier exclusive-LRU replay: the definitionally
    /// correct reference for [`StackDistCurve::tier_bands`], mirroring
    /// `TieredMemory::lookup_one`'s counting exactly.
    fn brute_force_tier_bands(
        trace: &CompiledTrace,
        n_experts: usize,
        warmup_tokens: usize,
        caps: &[usize],
    ) -> TierBands {
        let mut cache = TieredCache::new(
            caps.iter()
                .map(|&c| Box::new(LruCache::new(c)) as Box<dyn CachePolicy>)
                .collect(),
        );
        let mut out = TierBands {
            served: vec![0; caps.len()],
            cold: 0,
            demotions_into: vec![0; caps.len()],
            dropped: 0,
        };
        let warm = warmup_tokens.min(trace.n_tokens());
        for t in 0..trace.n_tokens() {
            let measured = t >= warm;
            for l in 0..trace.n_layers() {
                for e in trace.set(t, l).iter() {
                    let k = crate::cache::policy::key(l, e, n_experts);
                    let promo = cache.promote(k);
                    if !measured {
                        continue;
                    }
                    match promo.found {
                        Some(d) => out.served[d] += 1,
                        None => out.cold += 1,
                    }
                    for d in &promo.demoted {
                        match d.to {
                            Some(dest) => out.demotions_into[dest] += 1,
                            None => out.dropped += 1,
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn single_pass_curve_matches_brute_force_lru() {
        let mut rng = Rng::new(401);
        for _case in 0..30 {
            let n_tokens = rng.range(2, 40);
            let warmup = rng.below(12);
            let tr = random_trace(&mut rng, n_tokens, 3, 16);
            let ct = CompiledTrace::compile(&tr);
            let mut p = StackDistProfile::new();
            profile_prompt(&ct, 16, warmup, &mut p);
            let curve = p.curve();
            for capacity in 1..=40 {
                let (hits, misses) = brute_force_hits(&ct, 16, warmup, capacity);
                assert_eq!(
                    p.hits_at(capacity),
                    hits,
                    "capacity {capacity} warmup {warmup}"
                );
                assert_eq!(curve.hits_at(capacity), hits, "curve at {capacity}");
                assert_eq!(p.measured - p.hits_at(capacity), misses);
            }
        }
    }

    /// The tiered band extraction matches a brute-force exclusive
    /// multi-tier LRU replay — served depths, cold reads, per-tier
    /// demotion traffic, and drops — over random traces, random tier
    /// splits (2–4 tiers), and random warm-up epochs.
    #[test]
    fn tier_bands_match_brute_force_hierarchy() {
        let mut rng = Rng::new(405);
        for _case in 0..40 {
            let n_tokens = rng.range(2, 40);
            let warmup = rng.below(12);
            let tr = random_trace(&mut rng, n_tokens, 3, 16);
            let ct = CompiledTrace::compile(&tr);
            let mut p = StackDistProfile::new();
            profile_prompt(&ct, 16, warmup, &mut p);
            let curve = p.curve();
            for _split in 0..4 {
                let n_tiers = rng.range(2, 5);
                let caps: Vec<usize> = (0..n_tiers).map(|_| rng.range(1, 14)).collect();
                let analytic = curve.tier_bands(&caps);
                let brute = brute_force_tier_bands(&ct, 16, warmup, &caps);
                assert_eq!(analytic, brute, "caps {caps:?} warmup {warmup}");
                // conservation: every measured access is served or cold
                assert_eq!(
                    analytic.served.iter().sum::<u64>() + analytic.cold,
                    p.measured
                );
            }
        }
    }

    /// A single-tier "hierarchy" collapses to the flat curve.
    #[test]
    fn tier_bands_single_tier_matches_flat() {
        let mut rng = Rng::new(406);
        let tr = random_trace(&mut rng, 30, 3, 16);
        let ct = CompiledTrace::compile(&tr);
        let mut p = StackDistProfile::new();
        profile_prompt(&ct, 16, 6, &mut p);
        let curve = p.curve();
        for cap in [1usize, 4, 9, 40] {
            let b = curve.tier_bands(&[cap]);
            assert_eq!(b.served[0], p.hits_at(cap));
            assert_eq!(b.cold, p.measured - p.hits_at(cap));
            assert_eq!(b.demotions(), 0);
            // in a 1-tier hierarchy every capacity-exceeding access drops
            // its victim straight off the bottom
            let brute = brute_force_tier_bands(&ct, 16, 6, &[cap]);
            assert_eq!(b.dropped, brute.dropped);
        }
    }

    #[test]
    fn merged_profiles_add_curves() {
        let mut rng = Rng::new(402);
        let a = random_trace(&mut rng, 20, 2, 12);
        let b = random_trace(&mut rng, 15, 2, 12);
        let (ca, cb): (CompiledTrace, CompiledTrace) =
            (CompiledTrace::compile(&a), CompiledTrace::compile(&b));
        let mut pa = StackDistProfile::new();
        let mut pb = StackDistProfile::new();
        profile_prompt(&ca, 12, 4, &mut pa);
        profile_prompt(&cb, 12, 4, &mut pb);
        let mut merged = pa.clone();
        merged.merge(&pb);
        for c in [1usize, 3, 8, 24] {
            assert_eq!(merged.hits_at(c), pa.hits_at(c) + pb.hits_at(c));
        }
        assert_eq!(merged.measured, pa.measured + pb.measured);
        assert_eq!(merged.cold, pa.cold + pb.cold);
        // tier bands are additive too (fresh hierarchy per prompt)
        let caps = [2usize, 5, 9];
        let (ma, mb, mm) = (pa.curve(), pb.curve(), merged.curve());
        let (ba, bb, bm) = (
            ma.tier_bands(&caps),
            mb.tier_bands(&caps),
            mm.tier_bands(&caps),
        );
        for d in 0..caps.len() {
            assert_eq!(bm.served[d], ba.served[d] + bb.served[d]);
            assert_eq!(
                bm.demotions_into[d],
                ba.demotions_into[d] + bb.demotions_into[d]
            );
        }
        assert_eq!(bm.cold, ba.cold + bb.cold);
        assert_eq!(bm.dropped, ba.dropped + bb.dropped);
    }

    #[test]
    fn cache_stats_shape() {
        let mut rng = Rng::new(403);
        let tr = random_trace(&mut rng, 24, 3, 16);
        let ct: CompiledTrace = CompiledTrace::compile(&tr);
        let mut p = StackDistProfile::new();
        profile_prompt(&ct, 16, 8, &mut p);
        let s = p.cache_stats(6, 1400.0);
        assert_eq!(s.lookups(), p.measured);
        assert_eq!(s.prediction_total, p.measured);
        assert_eq!(s.prediction_hits, 0);
        assert_eq!(s.prefetches, 0);
        assert_eq!(s.transfer_us, s.misses as f64 * 1400.0);
        // monotone non-decreasing hits in capacity
        let mut prev = 0;
        for c in 1..32 {
            let h = p.hits_at(c);
            assert!(h >= prev);
            prev = h;
        }
    }

    #[test]
    fn fully_warm_prompt_records_nothing() {
        let mut rng = Rng::new(404);
        let tr = random_trace(&mut rng, 10, 2, 12);
        let ct: CompiledTrace = CompiledTrace::compile(&tr);
        let mut p = StackDistProfile::new();
        profile_prompt(&ct, 12, 10, &mut p);
        assert_eq!(p.measured, 0);
        assert_eq!(p.cold, 0);
        assert_eq!(p.hits_at(1000), 0);
        let b = p.curve().tier_bands(&[2, 4]);
        assert_eq!(b.served, vec![0, 0]);
        assert_eq!(b.cold, 0);
        assert_eq!(b.demotions(), 0);
    }
}
