//! Mattson stack-distance profiling — the whole LRU hit-rate-vs-capacity
//! curve from ONE pass over a trace.
//!
//! LRU has the stack (inclusion) property: an access whose stack
//! distance is `d` (the number of *distinct* keys referenced since the
//! previous access to the same key) hits every LRU cache of capacity
//! `> d` and misses every smaller one.  So a single replay that records
//! the histogram of stack distances answers "how many hits at capacity
//! C?" for EVERY C at once — the Fig-7 no-prefetch baseline axis costs
//! one corpus pass instead of one replay per capacity fraction
//! (`sim::sweep` wires this in as a fast path; see
//! `sweep_capacities_replay_threaded` for the retained exact-replay
//! fallback).
//!
//! The fast path only applies to *demand-only* LRU replay
//! ([`crate::predictor::NoPrefetch`]): prefetching inserts keys the
//! reference stream never touched, which breaks the inclusion property
//! (a small cache can evict a prefetched key a big cache keeps), so
//! predictor-driven sweep points always take the exact replay.
//!
//! Distances are computed with a Fenwick tree over access timestamps
//! (the classic O(N log N) Mattson algorithm): each in-stack key is
//! marked at its most recent access position, so the number of marks in
//! `(last[k], now)` is exactly the number of distinct keys referenced
//! since `last[k]`.

use crate::cache::CacheStats;
use crate::trace::CompiledTrace;

/// Fenwick (binary indexed) tree over 1-based positions.
struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Self {
            tree: vec![0; n + 1],
        }
    }

    fn add(&mut self, mut i: usize, delta: i32) {
        while i < self.tree.len() {
            self.tree[i] = (self.tree[i] as i32 + delta) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of marks in positions `1..=i`.
    fn prefix(&self, mut i: usize) -> u32 {
        let mut s = 0u32;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Stack-distance histogram of the *measured* accesses of one or more
/// prompt replays (warm-up accesses shape the distances but are never
/// recorded — exactly the simulator's warm-up epoch semantics).
#[derive(Debug, Clone, Default)]
pub struct StackDistProfile {
    /// `hist[d]` = measured accesses at stack distance `d`; such an
    /// access hits every LRU cache with capacity `> d`.
    hist: Vec<u64>,
    /// Measured first-touch accesses — a miss at every capacity.
    pub cold: u64,
    /// Total measured accesses (`hits_at(c) + misses` for any `c`).
    pub measured: u64,
}

impl StackDistProfile {
    pub fn new() -> Self {
        Self::default()
    }

    fn record(&mut self, depth: usize) {
        if self.hist.len() <= depth {
            self.hist.resize(depth + 1, 0);
        }
        self.hist[depth] += 1;
        self.measured += 1;
    }

    fn record_cold(&mut self) {
        self.cold += 1;
        self.measured += 1;
    }

    /// Fold another profile in (capacity curves are additive across
    /// prompts because the sweep replays each prompt on a fresh cache).
    pub fn merge(&mut self, other: &StackDistProfile) {
        if self.hist.len() < other.hist.len() {
            self.hist.resize(other.hist.len(), 0);
        }
        for (a, b) in self.hist.iter_mut().zip(other.hist.iter()) {
            *a += b;
        }
        self.cold += other.cold;
        self.measured += other.measured;
    }

    /// Measured hits an LRU cache of `capacity` experts would serve.
    pub fn hits_at(&self, capacity: usize) -> u64 {
        self.hist.iter().take(capacity).sum()
    }

    /// The [`CacheStats`] a no-prefetch LRU replay at `capacity` would
    /// produce: every measured access is also a prediction-total count
    /// with zero prediction hits (the `NoPrefetch` predictor), and each
    /// miss is charged `pcie_us_per_expert` of transfer time.
    pub fn cache_stats(&self, capacity: usize, pcie_us_per_expert: f64) -> CacheStats {
        let hits = self.hits_at(capacity);
        let misses = self.measured - hits;
        CacheStats {
            hits,
            misses,
            prefetches: 0,
            wasted_prefetches: 0,
            prediction_hits: 0,
            prediction_total: self.measured,
            // n·cost is bit-identical to the replay's per-miss
            // accumulation whenever partial sums are exactly
            // representable (integer-valued µs costs, as configured
            // throughout this crate)
            transfer_us: misses as f64 * pcie_us_per_expert,
        }
    }
}

/// Profile one prompt's LRU reference stream (the exact stream
/// `SimEngine::run_prompt` generates: token-major, then layer, then
/// ascending expert id within each ground-truth set) into `out`.
///
/// `warmup_tokens` mirrors `SimConfig::warmup_tokens`: accesses of
/// tokens `< warmup` move the (virtual) residency but are not recorded.
pub fn profile_prompt(
    trace: &CompiledTrace,
    n_experts: usize,
    warmup_tokens: usize,
    out: &mut StackDistProfile,
) {
    let n_tokens = trace.n_tokens();
    let n_layers = trace.n_layers();
    let warm = warmup_tokens.min(n_tokens);
    let n_refs = trace.total_activations();
    let mut fen = Fenwick::new(n_refs);
    // last access position per dense key (layer * n_experts + expert);
    // 0 = never accessed (positions are 1-based)
    let mut last = vec![0u32; n_layers * n_experts];
    let mut pos = 0usize;
    // all marks sit at positions < pos, so the full prefix sum is just
    // the number of distinct keys seen so far — one counter instead of a
    // second Fenwick query per access
    let mut in_stack = 0u32;
    for t in 0..n_tokens {
        let measured = t >= warm;
        for l in 0..n_layers {
            for e in trace.set(t, l).iter() {
                pos += 1;
                let k = l * n_experts + e as usize;
                let prev = last[k] as usize;
                if prev == 0 {
                    if measured {
                        out.record_cold();
                    }
                    in_stack += 1;
                } else {
                    // distinct keys referenced since `prev`: every
                    // in-stack key is marked at its latest position, so
                    // count marks in (prev, pos) = in_stack - prefix(prev)
                    let depth = (in_stack - fen.prefix(prev)) as usize;
                    if measured {
                        out.record(depth);
                    }
                    fen.add(prev, -1);
                }
                fen.add(pos, 1);
                last[k] = pos as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CachePolicy, LruCache};
    use crate::trace::{CompiledTrace, PromptTrace};
    use crate::util::Rng;

    fn random_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, pool: u8) -> PromptTrace {
        let mut experts = Vec::new();
        for _ in 0..n_tokens * n_layers as usize {
            let a = rng.below(pool as usize) as u8;
            let b = (a + 1 + rng.below(pool as usize - 2) as u8) % pool;
            experts.push(a);
            experts.push(b);
        }
        PromptTrace {
            prompt_id: 0,
            n_layers,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0; n_tokens],
            embeddings: vec![],
            experts,
        }
    }

    /// Brute-force LRU replay of the same reference stream at one
    /// capacity (the definitionally-correct reference).
    fn brute_force_hits(
        trace: &CompiledTrace,
        n_experts: usize,
        warmup_tokens: usize,
        capacity: usize,
    ) -> (u64, u64) {
        let mut cache = LruCache::new(capacity);
        let (mut hits, mut misses) = (0u64, 0u64);
        let warm = warmup_tokens.min(trace.n_tokens());
        for t in 0..trace.n_tokens() {
            for l in 0..trace.n_layers() {
                for e in trace.set(t, l).iter() {
                    let k = crate::cache::policy::key(l, e, n_experts);
                    if cache.touch(k) {
                        if t >= warm {
                            hits += 1;
                        }
                    } else {
                        if t >= warm {
                            misses += 1;
                        }
                        cache.insert(k);
                    }
                }
            }
        }
        (hits, misses)
    }

    #[test]
    fn single_pass_curve_matches_brute_force_lru() {
        let mut rng = Rng::new(401);
        for _case in 0..30 {
            let n_tokens = rng.range(2, 40);
            let warmup = rng.below(12);
            let tr = random_trace(&mut rng, n_tokens, 3, 16);
            let ct = CompiledTrace::compile(&tr);
            let mut p = StackDistProfile::new();
            profile_prompt(&ct, 16, warmup, &mut p);
            for capacity in 1..=40 {
                let (hits, misses) = brute_force_hits(&ct, 16, warmup, capacity);
                assert_eq!(
                    p.hits_at(capacity),
                    hits,
                    "capacity {capacity} warmup {warmup}"
                );
                assert_eq!(p.measured - p.hits_at(capacity), misses);
            }
        }
    }

    #[test]
    fn merged_profiles_add_curves() {
        let mut rng = Rng::new(402);
        let a = random_trace(&mut rng, 20, 2, 12);
        let b = random_trace(&mut rng, 15, 2, 12);
        let (ca, cb) = (CompiledTrace::compile(&a), CompiledTrace::compile(&b));
        let mut pa = StackDistProfile::new();
        let mut pb = StackDistProfile::new();
        profile_prompt(&ca, 12, 4, &mut pa);
        profile_prompt(&cb, 12, 4, &mut pb);
        let mut merged = pa.clone();
        merged.merge(&pb);
        for c in [1usize, 3, 8, 24] {
            assert_eq!(merged.hits_at(c), pa.hits_at(c) + pb.hits_at(c));
        }
        assert_eq!(merged.measured, pa.measured + pb.measured);
        assert_eq!(merged.cold, pa.cold + pb.cold);
    }

    #[test]
    fn cache_stats_shape() {
        let mut rng = Rng::new(403);
        let tr = random_trace(&mut rng, 24, 3, 16);
        let ct = CompiledTrace::compile(&tr);
        let mut p = StackDistProfile::new();
        profile_prompt(&ct, 16, 8, &mut p);
        let s = p.cache_stats(6, 1400.0);
        assert_eq!(s.lookups(), p.measured);
        assert_eq!(s.prediction_total, p.measured);
        assert_eq!(s.prediction_hits, 0);
        assert_eq!(s.prefetches, 0);
        assert_eq!(s.transfer_us, s.misses as f64 * 1400.0);
        // monotone non-decreasing hits in capacity
        let mut prev = 0;
        for c in 1..32 {
            let h = p.hits_at(c);
            assert!(h >= prev);
            prev = h;
        }
    }

    #[test]
    fn fully_warm_prompt_records_nothing() {
        let mut rng = Rng::new(404);
        let tr = random_trace(&mut rng, 10, 2, 12);
        let ct = CompiledTrace::compile(&tr);
        let mut p = StackDistProfile::new();
        profile_prompt(&ct, 12, 10, &mut p);
        assert_eq!(p.measured, 0);
        assert_eq!(p.cold, 0);
        assert_eq!(p.hits_at(1000), 0);
    }
}
