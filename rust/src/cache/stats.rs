//! Cache hit/miss accounting — the paper's primary system-level metric.

/// Counters for one simulation or serving run.
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    /// Ground-truth expert lookups served from cache.
    pub hits: u64,
    /// Lookups that required a host->VRAM fetch.
    pub misses: u64,
    /// Experts prefetched ahead of use.
    pub prefetches: u64,
    /// Prefetched experts that were evicted before first use.
    pub wasted_prefetches: u64,
    /// Prediction hits: ground-truth expert was in the predicted set
    /// (paper's "prediction hit rate").
    pub prediction_hits: u64,
    /// Total predicted-against lookups.
    pub prediction_total: u64,
    /// Modeled transfer time spent on misses (µs).
    pub transfer_us: f64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// GPU cache hit rate in [0, 1] (Fig 7's y-axis).
    pub fn hit_rate(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }

    /// Prediction hit rate in [0, 1].
    pub fn prediction_hit_rate(&self) -> f64 {
        if self.prediction_total == 0 {
            0.0
        } else {
            self.prediction_hits as f64 / self.prediction_total as f64
        }
    }

    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.prefetches += other.prefetches;
        self.wasted_prefetches += other.wasted_prefetches;
        self.prediction_hits += other.prediction_hits;
        self.prediction_total += other.prediction_total;
        self.transfer_us += other.transfer_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let s = CacheStats {
            hits: 3,
            misses: 1,
            prediction_hits: 5,
            prediction_total: 10,
            ..Default::default()
        };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert!((s.prediction_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.lookups(), 4);
    }

    #[test]
    fn empty_rates_are_zero() {
        let s = CacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.prediction_hit_rate(), 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = CacheStats {
            hits: 1,
            misses: 2,
            ..Default::default()
        };
        let b = CacheStats {
            hits: 3,
            misses: 4,
            transfer_us: 10.0,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.hits, 4);
        assert_eq!(a.misses, 6);
        assert_eq!(a.transfer_us, 10.0);
    }
}
