//! VRAM transfer-cost model.
//!
//! The paper evaluates on an A100 where a miss costs a PCIe transfer of
//! one expert's weights.  We model virtual time: each miss adds
//! `pcie_us_per_expert`, each hit `hit_us`; prefetches issued ahead of
//! the layer overlap with the previous layer's compute (paper §5: DMA
//! overlaps the *preceding* layer only), so a prefetched-but-timely
//! expert costs nothing on the critical path.

use crate::config::CacheConfig;

/// Accumulates modeled transfer time.
#[derive(Debug, Clone)]
pub struct VramModel {
    cfg: CacheConfig,
    /// Modeled microseconds spent on demand fetches (critical path).
    pub demand_us: f64,
    /// Modeled microseconds of prefetch DMA (overlapped, off critical path
    /// up to `overlap_budget_us` per layer).
    pub prefetch_us: f64,
    /// Prefetch time that exceeded the overlap window and stalled.
    pub stall_us: f64,
    /// Per-layer compute time available to hide prefetch DMA (µs).
    pub overlap_budget_us: f64,
    layer_prefetch_us: f64,
}

impl VramModel {
    pub fn new(cfg: CacheConfig, overlap_budget_us: f64) -> Self {
        Self {
            cfg,
            demand_us: 0.0,
            prefetch_us: 0.0,
            stall_us: 0.0,
            overlap_budget_us,
            layer_prefetch_us: 0.0,
        }
    }

    /// A cache hit on the critical path.
    pub fn on_hit(&mut self) {
        self.demand_us += self.cfg.hit_us;
    }

    /// A demand miss: the layer stalls for a full PCIe fetch.
    pub fn on_demand_miss(&mut self) {
        self.demand_us += self.cfg.pcie_us_per_expert;
    }

    /// A prefetch issued one layer ahead.
    pub fn on_prefetch(&mut self) {
        self.prefetch_us += self.cfg.pcie_us_per_expert;
        self.layer_prefetch_us += self.cfg.pcie_us_per_expert;
    }

    /// Close out a layer: prefetch DMA beyond the overlap window becomes
    /// stall time.
    pub fn end_layer(&mut self) {
        if self.layer_prefetch_us > self.overlap_budget_us {
            self.stall_us += self.layer_prefetch_us - self.overlap_budget_us;
        }
        self.layer_prefetch_us = 0.0;
    }

    /// Total modeled critical-path microseconds.
    pub fn critical_path_us(&self) -> f64 {
        self.demand_us + self.stall_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CacheConfig {
        CacheConfig {
            capacity_experts: 16,
            pcie_us_per_expert: 100.0,
            hit_us: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn demand_miss_costs_pcie() {
        let mut v = VramModel::new(cfg(), 1000.0);
        v.on_hit();
        v.on_demand_miss();
        assert_eq!(v.demand_us, 101.0);
        assert_eq!(v.critical_path_us(), 101.0);
    }

    #[test]
    fn prefetch_within_budget_is_free() {
        let mut v = VramModel::new(cfg(), 250.0);
        v.on_prefetch();
        v.on_prefetch(); // 200µs <= 250µs budget
        v.end_layer();
        assert_eq!(v.stall_us, 0.0);
        assert_eq!(v.critical_path_us(), 0.0);
    }

    #[test]
    fn prefetch_beyond_budget_stalls() {
        let mut v = VramModel::new(cfg(), 250.0);
        for _ in 0..4 {
            v.on_prefetch(); // 400µs > 250µs
        }
        v.end_layer();
        assert_eq!(v.stall_us, 150.0);
        // budget resets per layer
        v.on_prefetch();
        v.end_layer();
        assert_eq!(v.stall_us, 150.0);
    }
}
