//! Deterministic fault injection for cluster runs: permanent failures,
//! transient down/up windows, link flaps, degraded-bandwidth episodes,
//! fail-slow nodes, and straggler multipliers.
//!
//! Faults are *scheduled*, not sampled — every entry names the
//! measured-lookup index (the cluster's fault clock) at which it starts
//! and, for windows, the half-open index `[from, until)` at which it
//! ends — so a seeded run with faults is exactly as reproducible as one
//! without.  `tests/failure_injection.rs` pins that: two identical
//! faulted runs must produce byte-identical stats.  Even the
//! [`FaultPlan::chaos`] generator is a pure function of its arguments
//! (SplitMix64 over the node index), never an RNG.

use crate::cluster::placement::splitmix64;
use crate::Result;

/// One scheduled node failure: `node` stops serving at the `at_lookup`-th
/// measured lookup (0 = down from the start) and never recovers.
/// Lookups it owned fail over to the next-cheapest alive replica, or the
/// ring when every replica is down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeFailure {
    /// Failing node index.  Node 0 (the front node driving the cluster)
    /// cannot fail — [`FaultPlan::validate`] rejects it.
    pub node: usize,
    /// Measured-lookup index at which the failure takes effect.
    pub at_lookup: u64,
}

/// One degraded node: every network transfer to/from it costs
/// `multiplier`× the healthy link time (a slow radio, a thermally
/// throttled NIC).  Applies for the whole run; use [`SlowLink`] for a
/// bounded episode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    pub node: usize,
    /// Link-time multiplier, `>= 1`.
    pub multiplier: f64,
}

/// A transient outage: `node` is down for measured lookups
/// `[from, until)` and then **recovers with a cold cache** — its staged
/// residency is dropped (crash-restart semantics) while its cost
/// accumulators survive, exactly the `ExpertMemory::clear` contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DownWindow {
    pub node: usize,
    /// First measured-lookup index of the outage.
    pub from: u64,
    /// First measured-lookup index after recovery (half-open).
    pub until: u64,
}

/// A link flap: `node` is unreachable for measured lookups
/// `[from, until)` but its process never died — it **recovers warm**
/// (residency intact).  Routing treats a flapped node exactly like a
/// down one; only the recovery differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkFlap {
    pub node: usize,
    pub from: u64,
    pub until: u64,
}

/// A degraded-bandwidth episode: every transfer to/from `node` costs
/// `multiplier`× for measured lookups `[from, until)`, stacking
/// multiplicatively with any permanent [`Straggler`] on the node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowLink {
    pub node: usize,
    pub from: u64,
    pub until: u64,
    /// Episode link-time multiplier, `>= 1`.
    pub multiplier: f64,
}

/// A fail-slow node: for measured lookups `[from, until)` the node is
/// alive and answers, but serves `multiplier`× slower (a wedged disk, a
/// GC-storming runtime).  The multiplier applies to lookups *served by*
/// the node — not to one-shot promotion pulls, which only see link-level
/// degradation ([`Straggler`], [`SlowLink`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailSlow {
    pub node: usize,
    pub from: u64,
    pub until: u64,
    /// Serve-time multiplier, `>= 1`.
    pub multiplier: f64,
}

/// What one compiled fault event does when the fault clock reaches it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum FaultAction {
    /// Node goes dark (permanent failure or down-window start).
    NodeDown,
    /// Node returns; `cold` drops its staged residency first.
    NodeUp { cold: bool },
    /// Link to the node drops (flap start) — process stays alive.
    LinkDown,
    /// Link returns (flap end).
    LinkUp,
    /// Degraded-bandwidth episode begins: wire multiplier on the node.
    SlowLinkStart { multiplier: f64 },
    SlowLinkEnd,
    /// Fail-slow episode begins: serve multiplier on the node.
    FailSlowStart { multiplier: f64 },
    FailSlowEnd,
}

impl FaultAction {
    /// Sort rank at one clock index: recoveries apply before new
    /// outages, so back-to-back windows `[a,b)` + `[b,c)` hand over
    /// cleanly at `b`.
    fn rank(&self) -> u8 {
        match self {
            FaultAction::NodeUp { .. }
            | FaultAction::LinkUp
            | FaultAction::SlowLinkEnd
            | FaultAction::FailSlowEnd => 0,
            FaultAction::NodeDown
            | FaultAction::LinkDown
            | FaultAction::SlowLinkStart { .. }
            | FaultAction::FailSlowStart { .. } => 1,
        }
    }
}

/// One compiled fault transition, keyed to the measured-lookup clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct FaultEvent {
    pub at: u64,
    pub node: usize,
    pub action: FaultAction,
}

/// The full fault schedule for one cluster run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub failures: Vec<NodeFailure>,
    pub stragglers: Vec<Straggler>,
    pub down_windows: Vec<DownWindow>,
    pub link_flaps: Vec<LinkFlap>,
    pub slow_links: Vec<SlowLink>,
    pub fail_slows: Vec<FailSlow>,
}

impl FaultPlan {
    /// No faults — the default for every sweep unless injected.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_failure(mut self, node: usize, at_lookup: u64) -> Self {
        self.failures.push(NodeFailure { node, at_lookup });
        self
    }

    pub fn with_straggler(mut self, node: usize, multiplier: f64) -> Self {
        self.stragglers.push(Straggler { node, multiplier });
        self
    }

    pub fn with_down_window(mut self, node: usize, from: u64, until: u64) -> Self {
        self.down_windows.push(DownWindow { node, from, until });
        self
    }

    pub fn with_link_flap(mut self, node: usize, from: u64, until: u64) -> Self {
        self.link_flaps.push(LinkFlap { node, from, until });
        self
    }

    pub fn with_slow_link(mut self, node: usize, from: u64, until: u64, multiplier: f64) -> Self {
        self.slow_links.push(SlowLink {
            node,
            from,
            until,
            multiplier,
        });
        self
    }

    pub fn with_fail_slow(mut self, node: usize, from: u64, until: u64, multiplier: f64) -> Self {
        self.fail_slows.push(FailSlow {
            node,
            from,
            until,
            multiplier,
        });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.failures.is_empty()
            && self.stragglers.is_empty()
            && self.down_windows.is_empty()
            && self.link_flaps.is_empty()
            && self.slow_links.is_empty()
            && self.fail_slows.is_empty()
    }

    /// Compile the plan into one event list sorted by
    /// `(clock index, recovery-before-outage, node)` — the order
    /// [`super::ClusterMemory`] replays it in.
    pub(crate) fn events(&self) -> Vec<FaultEvent> {
        let mut ev = Vec::new();
        for f in &self.failures {
            ev.push(FaultEvent {
                at: f.at_lookup,
                node: f.node,
                action: FaultAction::NodeDown,
            });
        }
        for w in &self.down_windows {
            ev.push(FaultEvent {
                at: w.from,
                node: w.node,
                action: FaultAction::NodeDown,
            });
            ev.push(FaultEvent {
                at: w.until,
                node: w.node,
                action: FaultAction::NodeUp { cold: true },
            });
        }
        for w in &self.link_flaps {
            ev.push(FaultEvent {
                at: w.from,
                node: w.node,
                action: FaultAction::LinkDown,
            });
            ev.push(FaultEvent {
                at: w.until,
                node: w.node,
                action: FaultAction::LinkUp,
            });
        }
        for w in &self.slow_links {
            ev.push(FaultEvent {
                at: w.from,
                node: w.node,
                action: FaultAction::SlowLinkStart {
                    multiplier: w.multiplier,
                },
            });
            ev.push(FaultEvent {
                at: w.until,
                node: w.node,
                action: FaultAction::SlowLinkEnd,
            });
        }
        for w in &self.fail_slows {
            ev.push(FaultEvent {
                at: w.from,
                node: w.node,
                action: FaultAction::FailSlowStart {
                    multiplier: w.multiplier,
                },
            });
            ev.push(FaultEvent {
                at: w.until,
                node: w.node,
                action: FaultAction::FailSlowEnd,
            });
        }
        ev.sort_by_key(|e| (e.at, e.action.rank(), e.node));
        ev
    }

    /// Check the plan against a `k`-node cluster.  Every rejection names
    /// the offending entry — its index within its category, the node,
    /// the firing index or window, and the multiplier where one applies.
    pub fn validate(&self, k: usize) -> Result<()> {
        for (i, f) in self.failures.iter().enumerate() {
            anyhow::ensure!(
                f.node < k,
                "failure #{i} (node {}, at lookup {}) names a node out of range \
                 for a {k}-node cluster",
                f.node,
                f.at_lookup
            );
            anyhow::ensure!(
                f.node != 0,
                "failure #{i} (at lookup {}) targets node 0 — the front node \
                 cannot fail (it owns the local hierarchy every degraded \
                 lookup lands on)",
                f.at_lookup
            );
        }
        for (i, s) in self.stragglers.iter().enumerate() {
            anyhow::ensure!(
                s.node < k,
                "straggler #{i} (node {}, multiplier {}) names a node out of \
                 range for a {k}-node cluster",
                s.node,
                s.multiplier
            );
            anyhow::ensure!(
                s.multiplier.is_finite() && s.multiplier >= 1.0,
                "straggler #{i} (node {}): multiplier {} must be finite and >= 1",
                s.node,
                s.multiplier
            );
        }
        validate_windows(
            "down-window",
            k,
            &self
                .down_windows
                .iter()
                .map(|w| (w.node, w.from, w.until, 1.0))
                .collect::<Vec<_>>(),
            &self.failures,
        )?;
        validate_windows(
            "link-flap",
            k,
            &self
                .link_flaps
                .iter()
                .map(|w| (w.node, w.from, w.until, 1.0))
                .collect::<Vec<_>>(),
            &self.failures,
        )?;
        validate_windows(
            "slow-link",
            k,
            &self
                .slow_links
                .iter()
                .map(|w| (w.node, w.from, w.until, w.multiplier))
                .collect::<Vec<_>>(),
            &self.failures,
        )?;
        validate_windows(
            "fail-slow",
            k,
            &self
                .fail_slows
                .iter()
                .map(|w| (w.node, w.from, w.until, w.multiplier))
                .collect::<Vec<_>>(),
            &self.failures,
        )?;
        Ok(())
    }

    /// Parse a `--fault-plan` string: `;`-separated entries, each one of
    ///
    /// * `fail:NODE@AT` — permanent failure at measured lookup `AT`
    /// * `straggle:NODE*MULT` — whole-run link multiplier
    /// * `down:NODE@FROM-UNTIL` — outage window, cold recovery
    /// * `flap:NODE@FROM-UNTIL` — link flap, warm recovery
    /// * `slow:NODE@FROM-UNTIL*MULT` — degraded-bandwidth episode
    /// * `failslow:NODE@FROM-UNTIL*MULT` — fail-slow serve episode
    ///
    /// e.g. `down:1@200-600;slow:2@100-400*3` — node 1 crashes for
    /// lookups 200..600 and node 2's link runs 3× slow for 100..400.
    pub fn parse(s: &str) -> Result<Self> {
        let mut plan = FaultPlan::none();
        for raw in s.split(';') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, rest) = entry.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("fault entry '{entry}' is missing its 'kind:' prefix")
            })?;
            match kind.trim().to_ascii_lowercase().as_str() {
                "fail" => {
                    let (node, at) = parse_at(entry, rest)?;
                    plan = plan.with_failure(node, at);
                }
                "straggle" | "straggler" => {
                    let (node, mult) = parse_mult(entry, rest)?;
                    plan = plan.with_straggler(node, mult);
                }
                "down" => {
                    let (node, from, until) = parse_window(entry, rest)?;
                    plan = plan.with_down_window(node, from, until);
                }
                "flap" => {
                    let (node, from, until) = parse_window(entry, rest)?;
                    plan = plan.with_link_flap(node, from, until);
                }
                "slow" => {
                    let (node, from, until, mult) = parse_window_mult(entry, rest)?;
                    plan = plan.with_slow_link(node, from, until, mult);
                }
                "failslow" => {
                    let (node, from, until, mult) = parse_window_mult(entry, rest)?;
                    plan = plan.with_fail_slow(node, from, until, mult);
                }
                other => anyhow::bail!(
                    "unknown fault kind '{other}' in '{entry}' \
                     (expected fail|straggle|down|flap|slow|failslow)"
                ),
            }
        }
        Ok(plan)
    }

    /// Deterministic chaos plan for a `k`-node cluster: transient
    /// outages, degraded-bandwidth episodes and link flaps over a run of
    /// `horizon` measured lookups, scaled by `intensity` in `[0, 1]`.
    ///
    /// Pure function of its arguments — window positions derive from
    /// SplitMix64 over the node index, so the same `(k, intensity,
    /// horizon)` always yields the same plan, and a node afflicted at
    /// intensity `i` stays afflicted (with the same window) at every
    /// intensity above `i`.  That nesting keeps chaos sweeps comparable
    /// across the intensity axis.
    pub fn chaos(k: usize, intensity: f64, horizon: u64) -> Self {
        let mut plan = FaultPlan::none();
        if k <= 1 || intensity.is_nan() || intensity <= 0.0 || horizon < 8 {
            return plan;
        }
        let level = intensity.min(1.0);
        // Uniform-ish draw in [0, 1) from a hash.
        let frac = |h: u64| (h % 4096) as f64 / 4096.0;
        let span = horizon as f64;
        for node in 1..k {
            let h0 = splitmix64(0xC1A0_5EED ^ node as u64);
            let h1 = splitmix64(h0);
            let h2 = splitmix64(h1);
            let h3 = splitmix64(h2);
            // Transient outage somewhere in the first half, lasting up
            // to a quarter of the run; the node recovers cold.
            if frac(h0) < level {
                let from = (frac(h1) * span * 0.5) as u64;
                let len = 1 + (span * 0.25 * (0.25 + 0.75 * frac(h2)) * level) as u64;
                plan = plan.with_down_window(node, from, from + len);
            }
            // Degraded-bandwidth episode in the second half.
            if frac(h1) < level {
                let from = horizon / 2 + (frac(h3) * span * 0.25) as u64;
                let len = 1 + (span * 0.125) as u64;
                let mult = 1.0 + 3.0 * level;
                plan = plan.with_slow_link(node, from, from + len, mult);
            }
            // Short link flap near the end on a subset of nodes.
            if frac(h2) < level * 0.5 {
                let from = horizon * 3 / 4 + (frac(h0) * span * 0.125) as u64;
                let len = 1 + (span / 16.0) as u64;
                plan = plan.with_link_flap(node, from, from + len);
            }
            // Fail-slow episode on every third node at high intensity.
            if node % 3 == 1 && frac(h3) < level * 0.75 {
                let from = (span * 0.25) as u64 + (frac(h2) * span * 0.25) as u64;
                let len = 1 + (span * 0.1875) as u64;
                plan = plan.with_fail_slow(node, from, from + len, 1.0 + 2.0 * level);
            }
        }
        plan
    }
}

/// Shared window checks: range, front node, non-empty span, multiplier,
/// no same-category overlap on one node, and no window extending past a
/// permanent failure of the same node (the node would have to resurrect).
fn validate_windows(
    what: &str,
    k: usize,
    windows: &[(usize, u64, u64, f64)],
    failures: &[NodeFailure],
) -> Result<()> {
    for (i, &(node, from, until, mult)) in windows.iter().enumerate() {
        anyhow::ensure!(
            node < k,
            "{what} #{i} (node {node}, [{from},{until})) names a node out of \
             range for a {k}-node cluster"
        );
        anyhow::ensure!(
            node != 0,
            "{what} #{i} ([{from},{until})) targets node 0 — the front node \
             cannot fault"
        );
        anyhow::ensure!(
            from < until,
            "{what} #{i} (node {node}) is empty: from {from} must be < until {until}"
        );
        anyhow::ensure!(
            mult.is_finite() && mult >= 1.0,
            "{what} #{i} (node {node}, [{from},{until})): multiplier {mult} \
             must be finite and >= 1"
        );
        for (fi, f) in failures.iter().enumerate() {
            anyhow::ensure!(
                f.node != node || f.at_lookup >= until,
                "{what} #{i} (node {node}, [{from},{until})) outlives permanent \
                 failure #{fi} at lookup {} — a dead node cannot host a window",
                f.at_lookup
            );
        }
        for (j, &(n2, f2, u2, _)) in windows.iter().enumerate().skip(i + 1) {
            anyhow::ensure!(
                n2 != node || until <= f2 || u2 <= from,
                "{what}s #{i} and #{j} overlap on node {node}: \
                 [{from},{until}) vs [{f2},{u2})"
            );
        }
    }
    Ok(())
}

fn parse_node(entry: &str, s: &str) -> Result<usize> {
    s.trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad node index '{s}' in fault entry '{entry}'"))
}

fn parse_clock(entry: &str, s: &str) -> Result<u64> {
    s.trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad lookup index '{s}' in fault entry '{entry}'"))
}

fn parse_multiplier(entry: &str, s: &str) -> Result<f64> {
    s.trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad multiplier '{s}' in fault entry '{entry}'"))
}

/// `NODE@AT`
fn parse_at(entry: &str, rest: &str) -> Result<(usize, u64)> {
    let (node, at) = rest
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("fault entry '{entry}' needs NODE@AT"))?;
    Ok((parse_node(entry, node)?, parse_clock(entry, at)?))
}

/// `NODE*MULT`
fn parse_mult(entry: &str, rest: &str) -> Result<(usize, f64)> {
    let (node, mult) = rest
        .split_once('*')
        .ok_or_else(|| anyhow::anyhow!("fault entry '{entry}' needs NODE*MULT"))?;
    Ok((parse_node(entry, node)?, parse_multiplier(entry, mult)?))
}

/// `NODE@FROM-UNTIL`
fn parse_window(entry: &str, rest: &str) -> Result<(usize, u64, u64)> {
    let (node, span) = rest
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("fault entry '{entry}' needs NODE@FROM-UNTIL"))?;
    let (from, until) = span
        .split_once('-')
        .ok_or_else(|| anyhow::anyhow!("fault entry '{entry}' needs a FROM-UNTIL window"))?;
    Ok((
        parse_node(entry, node)?,
        parse_clock(entry, from)?,
        parse_clock(entry, until)?,
    ))
}

/// `NODE@FROM-UNTIL*MULT`
fn parse_window_mult(entry: &str, rest: &str) -> Result<(usize, u64, u64, f64)> {
    let (span, mult) = rest
        .split_once('*')
        .ok_or_else(|| anyhow::anyhow!("fault entry '{entry}' needs NODE@FROM-UNTIL*MULT"))?;
    let (node, from, until) = parse_window(entry, span)?;
    Ok((node, from, until, parse_multiplier(entry, mult)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_validates_anywhere() {
        assert!(FaultPlan::none().validate(1).is_ok());
        assert!(FaultPlan::none().is_empty());
        assert!(FaultPlan::none().events().is_empty());
    }

    #[test]
    fn validate_rejects_front_node_and_out_of_range() {
        assert!(FaultPlan::none().with_failure(0, 10).validate(3).is_err());
        assert!(FaultPlan::none().with_failure(3, 10).validate(3).is_err());
        assert!(FaultPlan::none().with_failure(2, 10).validate(3).is_ok());
        assert!(FaultPlan::none().with_straggler(5, 2.0).validate(3).is_err());
        assert!(
            FaultPlan::none()
                .with_down_window(0, 1, 5)
                .validate(3)
                .is_err()
        );
        assert!(
            FaultPlan::none()
                .with_slow_link(4, 1, 5, 2.0)
                .validate(3)
                .is_err()
        );
    }

    #[test]
    fn validate_rejects_speedup_stragglers() {
        assert!(FaultPlan::none().with_straggler(1, 0.5).validate(3).is_err());
        assert!(
            FaultPlan::none()
                .with_straggler(1, f64::NAN)
                .validate(3)
                .is_err()
        );
        assert!(FaultPlan::none().with_straggler(1, 1.0).validate(3).is_ok());
        assert!(
            FaultPlan::none()
                .with_fail_slow(1, 0, 10, 0.25)
                .validate(3)
                .is_err()
        );
    }

    #[test]
    fn validate_names_the_offending_entry() {
        let msg = |plan: FaultPlan, k: usize| plan.validate(k).unwrap_err().to_string();
        // entry index + node + firing index
        let m = msg(
            FaultPlan::none().with_failure(1, 5).with_failure(7, 42),
            3,
        );
        assert!(m.contains("#1") && m.contains("node 7") && m.contains("42"), "{m}");
        // multiplier value
        let m = msg(FaultPlan::none().with_straggler(2, 0.25), 3);
        assert!(m.contains("#0") && m.contains("0.25"), "{m}");
        // window span
        let m = msg(FaultPlan::none().with_down_window(2, 9, 9), 3);
        assert!(m.contains("9") && m.contains("down-window #0"), "{m}");
    }

    #[test]
    fn validate_rejects_overlapping_windows_but_allows_touching() {
        let overlap = FaultPlan::none()
            .with_down_window(1, 10, 50)
            .with_down_window(1, 30, 60);
        let m = overlap.validate(3).unwrap_err().to_string();
        assert!(m.contains("overlap") && m.contains("node 1"), "{m}");
        // half-open windows that touch are fine
        assert!(
            FaultPlan::none()
                .with_down_window(1, 10, 20)
                .with_down_window(1, 20, 30)
                .validate(3)
                .is_ok()
        );
        // different nodes never conflict
        assert!(
            FaultPlan::none()
                .with_link_flap(1, 10, 50)
                .with_link_flap(2, 30, 60)
                .validate(3)
                .is_ok()
        );
        // a window outliving a permanent failure of the same node is
        // a resurrection — rejected by name
        let m = FaultPlan::none()
            .with_failure(1, 30)
            .with_down_window(1, 10, 50)
            .validate(3)
            .unwrap_err()
            .to_string();
        assert!(m.contains("failure #0") && m.contains("30"), "{m}");
    }

    #[test]
    fn events_sort_recoveries_before_outages_at_one_index() {
        let plan = FaultPlan::none()
            .with_down_window(1, 10, 20)
            .with_down_window(1, 20, 30)
            .with_slow_link(2, 20, 40, 2.0);
        let ev = plan.events();
        assert_eq!(ev.len(), 6);
        let at20: Vec<_> = ev.iter().filter(|e| e.at == 20).collect();
        assert_eq!(at20.len(), 3);
        // NodeUp first (rank 0), then the two starts
        assert_eq!(at20[0].action, FaultAction::NodeUp { cold: true });
        assert!(matches!(at20[1].action, FaultAction::NodeDown));
        assert!(matches!(
            at20[2].action,
            FaultAction::SlowLinkStart { .. }
        ));
    }

    #[test]
    fn parse_round_trips_every_kind() {
        let plan =
            FaultPlan::parse("fail:2@500; straggle:1*2.5; down:1@200-600; flap:2@100-150; slow:2@100-400*3; failslow:1@50-90*1.5")
                .unwrap();
        let want = FaultPlan::none()
            .with_failure(2, 500)
            .with_straggler(1, 2.5)
            .with_down_window(1, 200, 600)
            .with_link_flap(2, 100, 150)
            .with_slow_link(2, 100, 400, 3.0)
            .with_fail_slow(1, 50, 90, 1.5);
        assert_eq!(plan, want);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("down:1@200-600;").unwrap().validate(3).is_ok());
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        assert!(FaultPlan::parse("explode:1@5").is_err());
        assert!(FaultPlan::parse("fail:1").is_err());
        assert!(FaultPlan::parse("down:1@200").is_err());
        assert!(FaultPlan::parse("slow:1@1-2").is_err());
        assert!(FaultPlan::parse("straggle:x*2").is_err());
        assert!(FaultPlan::parse("no-colon").is_err());
    }

    #[test]
    fn chaos_is_deterministic_and_scales_with_intensity() {
        assert!(FaultPlan::chaos(1, 1.0, 1000).is_empty());
        assert!(FaultPlan::chaos(4, 0.0, 1000).is_empty());
        let a = FaultPlan::chaos(4, 0.7, 1000);
        let b = FaultPlan::chaos(4, 0.7, 1000);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.validate(4).is_ok());
        // full intensity afflicts every non-front node with an outage
        let full = FaultPlan::chaos(4, 1.0, 1000);
        assert_eq!(full.down_windows.len(), 3);
        assert!(full.validate(4).is_ok());
        // higher intensity never loses entries (nested draws)
        for k in [2usize, 3, 5, 8] {
            let lo = FaultPlan::chaos(k, 0.3, 2000);
            let hi = FaultPlan::chaos(k, 0.9, 2000);
            assert!(hi.down_windows.len() >= lo.down_windows.len(), "k={k}");
            assert!(lo.validate(k).is_ok(), "k={k}");
            assert!(hi.validate(k).is_ok(), "k={k}");
        }
    }
}
