//! Deterministic fault injection for cluster runs: scheduled node
//! failures and per-node straggler multipliers.
//!
//! Faults are *scheduled*, not sampled — a failure names the measured
//! lookup index at which the node goes dark, a straggler names a fixed
//! link-time multiplier — so a seeded run with faults is exactly as
//! reproducible as one without.  `tests/failure_injection.rs` pins that:
//! two identical faulted runs must produce byte-identical stats.

use crate::Result;

/// One scheduled node failure: `node` stops serving at the `at_lookup`-th
/// measured lookup (0 = down from the start) and never recovers.
/// Lookups it owned fail over to the next alive node in ring order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeFailure {
    /// Failing node index.  Node 0 (the front node driving the cluster)
    /// cannot fail — [`FaultPlan::validate`] rejects it.
    pub node: usize,
    /// Measured-lookup index at which the failure takes effect.
    pub at_lookup: u64,
}

/// One degraded node: every network transfer to/from it costs
/// `multiplier`× the healthy link time (a slow radio, a thermally
/// throttled NIC).  Applies for the whole run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    pub node: usize,
    /// Link-time multiplier, `>= 1`.
    pub multiplier: f64,
}

/// The full fault schedule for one cluster run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub failures: Vec<NodeFailure>,
    pub stragglers: Vec<Straggler>,
}

impl FaultPlan {
    /// No faults — the default for every sweep unless injected.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_failure(mut self, node: usize, at_lookup: u64) -> Self {
        self.failures.push(NodeFailure { node, at_lookup });
        self
    }

    pub fn with_straggler(mut self, node: usize, multiplier: f64) -> Self {
        self.stragglers.push(Straggler { node, multiplier });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.failures.is_empty() && self.stragglers.is_empty()
    }

    /// Check the plan against a `k`-node cluster.
    pub fn validate(&self, k: usize) -> Result<()> {
        for f in &self.failures {
            anyhow::ensure!(
                f.node < k,
                "failure names node {} but the cluster has {k} nodes",
                f.node
            );
            anyhow::ensure!(
                f.node != 0,
                "node 0 is the front node and cannot fail (it owns the \
                 local hierarchy every failover lands on)"
            );
        }
        for s in &self.stragglers {
            anyhow::ensure!(
                s.node < k,
                "straggler names node {} but the cluster has {k} nodes",
                s.node
            );
            anyhow::ensure!(
                s.multiplier.is_finite() && s.multiplier >= 1.0,
                "straggler multiplier must be finite and >= 1 (got {})",
                s.multiplier
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_validates_anywhere() {
        assert!(FaultPlan::none().validate(1).is_ok());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn validate_rejects_front_node_and_out_of_range() {
        assert!(FaultPlan::none().with_failure(0, 10).validate(3).is_err());
        assert!(FaultPlan::none().with_failure(3, 10).validate(3).is_err());
        assert!(FaultPlan::none().with_failure(2, 10).validate(3).is_ok());
        assert!(FaultPlan::none().with_straggler(5, 2.0).validate(3).is_err());
    }

    #[test]
    fn validate_rejects_speedup_stragglers() {
        assert!(FaultPlan::none().with_straggler(1, 0.5).validate(3).is_err());
        assert!(
            FaultPlan::none()
                .with_straggler(1, f64::NAN)
                .validate(3)
                .is_err()
        );
        assert!(FaultPlan::none().with_straggler(1, 1.0).validate(3).is_ok());
    }
}
