//! [`ClusterMemory`] — K per-node [`ExpertMemory`] hierarchies behind
//! one `ExpertMemory` facade, joined by a priced network link.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::cache::policy::{self, ExpertKey};
use crate::cluster::{ClusterConfig, NodeFailure, PlacementKind};
use crate::memory::{ExpertMemory, Lookup, LookupBatch, MemoryStats, Prefetched};
use crate::metrics::Counter;
use crate::obs::{ObsSink, TraceEvent};
use crate::tier::{NetCostModel, TierStats};
use crate::util::ExpertSet;
use crate::Result;

/// Deterministic K-node edge-cluster residency backend.
///
/// Each node runs its own full single-node backend (flat or tiered —
/// whatever [`crate::memory::build`] produces for the node config);
/// expert ownership comes from a pure [`PlacementKind`] map.  A lookup
/// whose owner is node 0 is a plain delegation — the front node serves
/// it from its local hierarchy at local cost.  A remote owner serves it
/// from *its* hierarchy and the [`NetCostModel`] adds the wire time:
/// activations travel on a remote GPU hit, the expert's weights travel
/// on a remote miss (and that wire time joins the returned
/// [`Lookup::fetch_us`], since a remote miss stalls the token exactly
/// like a local one).
///
/// Two structural invariants keep the backend honest:
///
/// * **K=1 byte-parity** — with one node every owner is 0, every path is
///   pure delegation, and a loopback link prices all transfers at 0 µs,
///   so a 1-node cluster is byte-identical to the wrapped single-node
///   backend (`tests/cluster_parity.rs`).
/// * **Determinism** — routing is a pure function, faults fire at fixed
///   measured-lookup indices, and every f64 accumulates in one fixed
///   order, so seeded runs (including faulted ones) reproduce exactly.
///
/// Hot experts can migrate: after [`ClusterConfig::promote_after`]
/// measured remote serves of one `(layer, expert)`, its weights are
/// shipped to node 0 once ([`crate::tier::NetStats::promotions`]) and it
/// is owned locally from then on — the cluster analogue of a tier
/// promotion.
pub struct ClusterMemory<const N: usize = 1> {
    nodes: Vec<Box<dyn ExpertMemory<N>>>,
    placement: PlacementKind,
    net: NetCostModel,
    n_experts: usize,
    promote_after: u32,
    /// Measured remote serves per expert key (promotion trigger).
    remote_use: HashMap<ExpertKey, u32>,
    /// Expert keys migrated to node 0 — ownership override.
    promoted: HashSet<ExpertKey>,
    /// Failure schedule, sorted by `at_lookup`; `next_failure` indexes
    /// the first not-yet-fired entry.
    failures: Vec<NodeFailure>,
    next_failure: usize,
    /// Per-node down flags (node 0 can never be down).
    down: Vec<bool>,
    /// Per-node link-time multipliers (1.0 = healthy).
    straggler: Vec<f64>,
    /// Measured lookups seen so far — the fault clock.
    measured_lookups: u64,
    obs: ObsSink,
    /// Per-node remote-serve counters, wired on `set_obs`.
    remote_ctrs: Vec<Arc<Counter>>,
    failover_ctr: Option<Arc<Counter>>,
    promotion_ctr: Option<Arc<Counter>>,
}

impl<const N: usize> ClusterMemory<N> {
    /// Wrap `nodes` (one backend per cluster node, already built with
    /// per-node capacities) under `cfg`'s placement, link and faults.
    /// `n_experts` is the per-layer expert count the placement map
    /// shards over.
    pub fn new(
        nodes: Vec<Box<dyn ExpertMemory<N>>>,
        cfg: &ClusterConfig,
        n_experts: usize,
    ) -> Result<Self> {
        anyhow::ensure!(!nodes.is_empty(), "cluster needs at least one node");
        anyhow::ensure!(
            nodes.len() == cfg.nodes,
            "cluster config says {} nodes but {} backends were supplied",
            cfg.nodes,
            nodes.len()
        );
        cfg.validate()?;
        let k = nodes.len();
        let mut failures = cfg.faults.failures.clone();
        failures.sort_by_key(|f| (f.at_lookup, f.node));
        let mut straggler = vec![1.0; k];
        for s in &cfg.faults.stragglers {
            straggler[s.node] = s.multiplier;
        }
        Ok(Self {
            nodes,
            placement: cfg.placement,
            net: NetCostModel::new(cfg.link.clone(), cfg.expert_mb, cfg.act_mb),
            n_experts,
            promote_after: cfg.promote_after,
            remote_use: HashMap::new(),
            promoted: HashSet::new(),
            failures,
            next_failure: 0,
            down: vec![false; k],
            straggler,
            measured_lookups: 0,
            obs: ObsSink::default(),
            remote_ctrs: Vec::new(),
            failover_ctr: None,
            promotion_ctr: None,
        })
    }

    #[inline]
    fn k(&self) -> usize {
        self.nodes.len()
    }

    /// Ring distance from the front node to `owner` — the hop count the
    /// link model charges.
    #[inline]
    fn hops(&self, owner: usize) -> usize {
        owner.min(self.k() - owner)
    }

    /// Fire every scheduled failure whose time has come.  Called before
    /// routing each measured lookup, so a failure at index `n` affects
    /// the `n`-th measured lookup onward.
    fn advance_faults(&mut self) {
        while self.next_failure < self.failures.len()
            && self.failures[self.next_failure].at_lookup <= self.measured_lookups
        {
            let f = self.failures[self.next_failure];
            self.next_failure += 1;
            if !self.down[f.node] {
                self.down[f.node] = true;
                self.obs.emit(|ts| TraceEvent::NodeDown {
                    ts_us: ts,
                    node: f.node as u8,
                });
            }
        }
    }

    /// Placement owner with the promotion override applied, before
    /// failover.
    #[inline]
    fn placed_owner(&self, layer: usize, expert: u8) -> usize {
        let k = policy::key(layer, expert, self.n_experts);
        if self.promoted.contains(&k) {
            0
        } else {
            self.placement.owner(layer, expert, self.n_experts, self.k())
        }
    }

    /// Final routing decision: `(node, failed_over)`.  A down owner
    /// fails over to the next alive node in ring order; node 0 is always
    /// alive, so the scan terminates.
    #[inline]
    fn route(&self, layer: usize, expert: u8) -> (usize, bool) {
        let owner = self.placed_owner(layer, expert);
        if !self.down[owner] {
            return (owner, false);
        }
        let k = self.k();
        let mut n = (owner + 1) % k;
        while self.down[n] {
            n = (n + 1) % k;
        }
        (n, true)
    }

    /// Shared lookup body — `lookup` is one call, `lookup_set` loops it,
    /// so the two paths cannot drift.
    fn lookup_one(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        if measured {
            self.advance_faults();
            self.measured_lookups += 1;
        }
        let (owner, failed_over) = self.route(layer, expert);
        if measured && failed_over {
            self.net.stats.failovers += 1;
            if let Some(c) = &self.failover_ctr {
                c.inc();
            }
        }
        if owner == 0 {
            // Front-node serve: pure delegation, no network charge.
            // This arm is the whole story at K=1, which is what makes
            // the loopback cluster byte-identical to single-node.
            return self.nodes[0].lookup(layer, expert, measured);
        }
        let r = self.nodes[owner].lookup(layer, expert, measured);
        let mut fetch_us = r.fetch_us;
        if measured {
            let hops = self.hops(owner);
            let mult = self.straggler[owner];
            let wire_us = self.net.on_remote(r.hit, hops, mult);
            if !r.hit {
                // A remote weight fetch stalls the token like a local
                // miss: the wire time joins the demand fetch cost.  On a
                // remote hit the activation wire time is charged to the
                // critical path via `cost_marks` only — `Lookup` keeps
                // the "fetch_us is 0 on a hit" contract.
                fetch_us += wire_us;
            }
            if self.obs.is_active() {
                self.obs.emit(|ts| TraceEvent::RemoteFetch {
                    ts_us: ts,
                    node: owner as u8,
                    layer: layer as u16,
                    expert,
                    hit: r.hit,
                    wire_us,
                });
            }
            if let Some(c) = self.remote_ctrs.get(owner) {
                c.inc();
            }
            if self.promote_after > 0 {
                let k = policy::key(layer, expert, self.n_experts);
                let uses = self.remote_use.entry(k).or_insert(0);
                *uses += 1;
                if *uses >= self.promote_after {
                    self.remote_use.remove(&k);
                    self.promoted.insert(k);
                    // Ship the weights once (network charge), then warm
                    // node 0's hierarchy with an unmeasured lookup — the
                    // same costless-residency-move contract warm-up uses.
                    self.net.on_promotion(hops, mult);
                    self.nodes[0].lookup(layer, expert, false);
                    if let Some(c) = &self.promotion_ctr {
                        c.inc();
                    }
                }
            }
        }
        Lookup {
            hit: r.hit,
            fetch_us,
        }
    }
}

impl<const N: usize> ExpertMemory<N> for ClusterMemory<N> {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        self.lookup_one(layer, expert, measured)
    }

    /// Set-level lookup loops the scalar body in ascending-id order —
    /// routing decisions depend on mutable promotion/fault state, so the
    /// scalar sequence *is* the specification (and the default-impl
    /// expansion in the trait matches it exactly).
    fn lookup_set(&mut self, layer: usize, truth: ExpertSet<N>, measured: bool) -> LookupBatch<N> {
        let mut out = LookupBatch::default();
        for e in truth.iter() {
            let r = self.lookup_one(layer, e, measured);
            if r.hit {
                out.hits.insert(e);
            } else {
                out.fetch_us += r.fetch_us;
            }
        }
        out
    }

    /// Prefetch partitions the predicted set by routed owner and hands
    /// each node its shard — predictions warm the hierarchy that will
    /// actually serve the lookup.  Weights rise from each node's *own*
    /// deeper tiers, so no network charge applies here.
    fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>) -> Prefetched {
        let k = self.k();
        if k == 1 {
            return self.nodes[0].prefetch(layer, predicted);
        }
        let mut shards: Vec<ExpertSet<N>> = vec![ExpertSet::new(); k];
        for e in predicted.iter() {
            let (owner, _) = self.route(layer, e);
            shards[owner].insert(e);
        }
        let mut out = Prefetched::default();
        for (node, shard) in shards.into_iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            let p = self.nodes[node].prefetch(layer, shard);
            out.issued += p.issued;
            out.landed += p.landed;
            out.too_late += p.too_late;
        }
        out
    }

    fn end_layer(&mut self) {
        for n in &mut self.nodes {
            n.end_layer();
        }
    }

    /// Demand µs = every node's demand (index order) + all network wire
    /// time; stall µs = every node's stall.  Sums start at 0.0 and
    /// accumulate non-negative terms, so at K=1 over loopback the result
    /// is bit-identical to the single node's marks.
    fn cost_marks(&self) -> (f64, f64) {
        let mut demand = 0.0;
        let mut stall = 0.0;
        for n in &self.nodes {
            let (d, s) = n.cost_marks();
            demand += d;
            stall += s;
        }
        demand += self.net.stats.total_us();
        (demand, stall)
    }

    fn set_prefetch_budget(&mut self, budget: usize) {
        for n in &mut self.nodes {
            n.set_prefetch_budget(budget);
        }
    }

    fn set_batch_share(&mut self, batch: usize) {
        for n in &mut self.nodes {
            n.set_batch_share(batch);
        }
    }

    fn effective_prefetch_budget(&self) -> usize {
        self.nodes[0].effective_prefetch_budget()
    }

    /// GPU-resident experts across the whole cluster (sum of every
    /// node's tier 0).
    fn resident_count(&self) -> usize {
        self.nodes.iter().map(|n| n.resident_count()).sum()
    }

    /// Borrowed per-tier counters exist only at K=1 (delegation); a
    /// multi-node merge is owned data — read it from
    /// [`ExpertMemory::stats`] instead.
    fn tier_stats(&self) -> Option<&TierStats> {
        if self.k() == 1 {
            self.nodes[0].tier_stats()
        } else {
            None
        }
    }

    fn stats(&self) -> MemoryStats {
        let mut demand_us = 0.0;
        let mut prefetch_us = 0.0;
        let mut stall_us = 0.0;
        let mut resident = 0usize;
        let mut resident_per_depth: Vec<usize> = Vec::new();
        let mut tiers: Option<TierStats> = None;
        for n in &self.nodes {
            let s = n.stats();
            demand_us += s.demand_us;
            prefetch_us += s.prefetch_us;
            stall_us += s.stall_us;
            resident += s.resident;
            if resident_per_depth.len() < s.resident_per_depth.len() {
                resident_per_depth.resize(s.resident_per_depth.len(), 0);
            }
            for (d, r) in s.resident_per_depth.iter().enumerate() {
                resident_per_depth[d] += r;
            }
            if let Some(t) = s.tiers {
                match &mut tiers {
                    Some(acc) => acc.merge(&t),
                    None => tiers = Some(t),
                }
            }
        }
        demand_us += self.net.stats.total_us();
        MemoryStats {
            demand_us,
            prefetch_us,
            stall_us,
            resident,
            resident_per_depth,
            tiers,
            net: Some(self.net.stats.clone()),
        }
    }

    /// Drops every node's staged residency plus the promotion state that
    /// shadows it (promoted experts are only warm while node 0 holds
    /// them).  Cost accumulators — node DMA and network wire time — are
    /// cumulative across a run and survive, as the trait requires.
    fn clear(&mut self) {
        for n in &mut self.nodes {
            n.clear();
        }
        self.remote_use.clear();
        self.promoted.clear();
    }

    fn set_obs(&mut self, obs: ObsSink) {
        for n in &mut self.nodes {
            n.set_obs(obs.clone());
        }
        if let Some(reg) = obs.registry() {
            reg.gauge("cluster_nodes", &[]).set(self.k() as f64);
            self.remote_ctrs = (0..self.k())
                .map(|i| {
                    let id = i.to_string();
                    reg.counter("cluster_remote_fetches", &[("node", id.as_str())])
                })
                .collect();
            self.failover_ctr = Some(reg.counter("cluster_failovers", &[]));
            self.promotion_ctr = Some(reg.counter("cluster_promotions", &[]));
        }
        self.obs = obs;
    }
}
