//! [`ClusterMemory`] — K per-node [`ExpertMemory`] hierarchies behind
//! one `ExpertMemory` facade, joined by a priced network link.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::cache::policy::{self, ExpertKey};
use crate::cluster::fault::{FaultAction, FaultEvent};
use crate::cluster::{ClusterConfig, PlacementKind};
use crate::memory::{ExpertMemory, Lookup, LookupBatch, MemoryStats, Prefetched};
use crate::metrics::Counter;
use crate::obs::{Gauge, ObsSink, TraceEvent};
use crate::tier::{NetCostModel, TierStats};
use crate::util::ExpertSet;
use crate::Result;

/// Deterministic K-node edge-cluster residency backend.
///
/// Each node runs its own full single-node backend (flat or tiered —
/// whatever [`crate::memory::build`] produces for the node config);
/// expert ownership comes from a pure [`PlacementKind`] map.  A lookup
/// whose owner is node 0 is a plain delegation — the front node serves
/// it from its local hierarchy at local cost.  A remote owner serves it
/// from *its* hierarchy and the [`NetCostModel`] adds the wire time:
/// activations travel on a remote GPU hit, the expert's weights travel
/// on a remote miss (and that wire time joins the returned
/// [`Lookup::fetch_us`], since a remote miss stalls the token exactly
/// like a local one).
///
/// Two structural invariants keep the backend honest:
///
/// * **K=1 byte-parity** — with one node every owner is 0, every path is
///   pure delegation, and a loopback link prices all transfers at 0 µs,
///   so a 1-node cluster is byte-identical to the wrapped single-node
///   backend (`tests/cluster_parity.rs`).
/// * **Determinism** — routing is a pure function, faults fire at fixed
///   measured-lookup indices, and every f64 accumulates in one fixed
///   order, so seeded runs (including faulted ones) reproduce exactly.
///
/// Hot experts can migrate: after [`ClusterConfig::promote_after`]
/// measured remote serves of one `(layer, expert)`, its weights are
/// shipped to node 0 once ([`crate::tier::NetStats::promotions`]) and it
/// is owned locally from then on — the cluster analogue of a tier
/// promotion.
///
/// With [`ClusterConfig::replicas`] `R > 1` each expert lives on `R`
/// distinct nodes (rank `r` = rotation `(owner + r) % k`) and a lookup
/// is served by the cheapest *reachable* replica (fewest hops, rank
/// breaking ties).  When the rank-0 owner is unreachable but another
/// replica serves, that is a replica failover; when **every** replica is
/// unreachable, the lookup degrades to the ring-scan fallback — a
/// deepest-tier demand load on whatever alive node the scan finds,
/// counted in [`crate::tier::NetStats::degraded_fetches`] and never a
/// panic.  Arm [`crate::tier::LinkSpec::timeout_us`] and a fetch whose
/// priced wire time blows the deadline charges the timeout, backs off
/// exponentially ([`ClusterConfig::retry_backoff_us`]), and retries the
/// next-cheapest alive replica.
pub struct ClusterMemory<const N: usize = 1> {
    nodes: Vec<Box<dyn ExpertMemory<N>>>,
    placement: PlacementKind,
    net: NetCostModel,
    n_experts: usize,
    promote_after: u32,
    /// Replication factor (1 = today's single-owner cluster).
    replicas: usize,
    /// Base backoff after a timed-out fetch attempt (µs).
    retry_backoff_us: f64,
    /// Measured remote serves per expert key (promotion trigger).
    remote_use: HashMap<ExpertKey, u32>,
    /// Expert keys migrated to node 0 — ownership override.
    promoted: HashSet<ExpertKey>,
    /// Compiled fault schedule, sorted by `(at, recovery-first, node)`;
    /// `next_event` indexes the first not-yet-fired entry.
    events: Vec<FaultEvent>,
    next_event: usize,
    /// Per-node down flags (node 0 can never be down).
    down: Vec<bool>,
    /// Per-node link-flap flags: unreachable but warm (the process
    /// never died, so recovery keeps its residency).
    link_down: Vec<bool>,
    /// Permanent per-node link-time multipliers (1.0 = healthy).
    straggler: Vec<f64>,
    /// Windowed degraded-bandwidth multipliers (1.0 outside episodes).
    episode_mult: Vec<f64>,
    /// Windowed fail-slow serve multipliers (1.0 outside episodes).
    serve_mult: Vec<f64>,
    /// Measured lookups seen so far — the fault clock.
    measured_lookups: u64,
    obs: ObsSink,
    /// Per-node remote-serve counters, wired on `set_obs`.
    remote_ctrs: Vec<Arc<Counter>>,
    failover_ctr: Option<Arc<Counter>>,
    promotion_ctr: Option<Arc<Counter>>,
    retry_ctr: Option<Arc<Counter>>,
    degraded_ctr: Option<Arc<Counter>>,
    /// Per-node up/down gauges (1 = reachable), wired on `set_obs`.
    node_up_gauges: Vec<Arc<Gauge>>,
}

impl<const N: usize> ClusterMemory<N> {
    /// Wrap `nodes` (one backend per cluster node, already built with
    /// per-node capacities) under `cfg`'s placement, link and faults.
    /// `n_experts` is the per-layer expert count the placement map
    /// shards over.
    pub fn new(
        nodes: Vec<Box<dyn ExpertMemory<N>>>,
        cfg: &ClusterConfig,
        n_experts: usize,
    ) -> Result<Self> {
        anyhow::ensure!(!nodes.is_empty(), "cluster needs at least one node");
        anyhow::ensure!(
            nodes.len() == cfg.nodes,
            "cluster config says {} nodes but {} backends were supplied",
            cfg.nodes,
            nodes.len()
        );
        cfg.validate()?;
        let k = nodes.len();
        let events = cfg.faults.events();
        let mut straggler = vec![1.0; k];
        for s in &cfg.faults.stragglers {
            straggler[s.node] = s.multiplier;
        }
        Ok(Self {
            nodes,
            placement: cfg.placement,
            net: NetCostModel::new(cfg.link.clone(), cfg.expert_mb, cfg.act_mb),
            n_experts,
            promote_after: cfg.promote_after,
            replicas: cfg.replicas.max(1),
            retry_backoff_us: cfg.retry_backoff_us,
            remote_use: HashMap::new(),
            promoted: HashSet::new(),
            events,
            next_event: 0,
            down: vec![false; k],
            link_down: vec![false; k],
            straggler,
            episode_mult: vec![1.0; k],
            serve_mult: vec![1.0; k],
            measured_lookups: 0,
            obs: ObsSink::default(),
            remote_ctrs: Vec::new(),
            failover_ctr: None,
            promotion_ctr: None,
            retry_ctr: None,
            degraded_ctr: None,
            node_up_gauges: Vec::new(),
        })
    }

    #[inline]
    fn k(&self) -> usize {
        self.nodes.len()
    }

    /// Ring distance from the front node to `owner` — the hop count the
    /// link model charges.
    #[inline]
    fn hops(&self, owner: usize) -> usize {
        owner.min(self.k() - owner)
    }

    /// A node no routing decision may pick: process down or link down.
    #[inline]
    fn unreachable(&self, node: usize) -> bool {
        self.down[node] || self.link_down[node]
    }

    /// Wire-time multiplier for lookups served by `node`: permanent
    /// straggler × degraded-bandwidth episode × fail-slow serve episode.
    /// All three default to 1.0, and `x * 1.0` is a bit-exact identity,
    /// so the healthy path prices exactly as before.
    #[inline]
    fn wire_mult(&self, node: usize) -> f64 {
        self.straggler[node] * self.episode_mult[node] * self.serve_mult[node]
    }

    /// Wire-time multiplier for one-shot promotion pulls from `node`:
    /// link-level degradation only — a fail-slow node's *serve* penalty
    /// does not apply to a bulk weight copy.
    #[inline]
    fn promo_mult(&self, node: usize) -> f64 {
        self.straggler[node] * self.episode_mult[node]
    }

    /// Refresh the node's up/down gauge after a reachability change.
    fn publish_node_gauge(&self, node: usize) {
        if let Some(g) = self.node_up_gauges.get(node) {
            g.set(if self.unreachable(node) { 0.0 } else { 1.0 });
        }
    }

    /// Fire every scheduled fault transition whose time has come.
    /// Called before routing each measured lookup, so an event at index
    /// `n` affects the `n`-th measured lookup onward.  Recovery from a
    /// [`FaultAction::NodeUp`] with `cold` drops the node's staged
    /// residency (crash-restart) while its cost accumulators survive —
    /// the `ExpertMemory::clear` contract; a link flap recovers warm.
    fn advance_faults(&mut self) {
        while self.next_event < self.events.len()
            && self.events[self.next_event].at <= self.measured_lookups
        {
            let e = self.events[self.next_event];
            self.next_event += 1;
            match e.action {
                FaultAction::NodeDown => {
                    if !self.down[e.node] {
                        self.down[e.node] = true;
                        self.obs.emit(|ts| TraceEvent::NodeDown {
                            ts_us: ts,
                            node: e.node as u8,
                        });
                        self.publish_node_gauge(e.node);
                    }
                }
                FaultAction::NodeUp { cold } => {
                    if self.down[e.node] {
                        self.down[e.node] = false;
                        if cold {
                            self.nodes[e.node].clear();
                        }
                        self.obs.emit(|ts| TraceEvent::NodeUp {
                            ts_us: ts,
                            node: e.node as u8,
                        });
                        self.publish_node_gauge(e.node);
                    }
                }
                FaultAction::LinkDown => {
                    if !self.link_down[e.node] {
                        self.link_down[e.node] = true;
                        self.obs.emit(|ts| TraceEvent::LinkFlap {
                            ts_us: ts,
                            node: e.node as u8,
                            up: false,
                        });
                        self.publish_node_gauge(e.node);
                    }
                }
                FaultAction::LinkUp => {
                    if self.link_down[e.node] {
                        self.link_down[e.node] = false;
                        self.obs.emit(|ts| TraceEvent::LinkFlap {
                            ts_us: ts,
                            node: e.node as u8,
                            up: true,
                        });
                        self.publish_node_gauge(e.node);
                    }
                }
                FaultAction::SlowLinkStart { multiplier } => {
                    self.episode_mult[e.node] = multiplier;
                }
                FaultAction::SlowLinkEnd => self.episode_mult[e.node] = 1.0,
                FaultAction::FailSlowStart { multiplier } => {
                    self.serve_mult[e.node] = multiplier;
                }
                FaultAction::FailSlowEnd => self.serve_mult[e.node] = 1.0,
            }
        }
    }

    /// Final routing decision: `(node, failed_over, degraded)`.
    ///
    /// Promoted experts are served by node 0 (always reachable).
    /// Otherwise the cheapest reachable replica serves — fewest hops,
    /// replica rank breaking ties; at `replicas == 1` this is exactly
    /// the old single-owner rule.  `failed_over` flags a serve that
    /// deviated from an unreachable rank-0 owner.  When *every* replica
    /// is unreachable the lookup degrades to the ring scan from the
    /// owner — node 0 is always reachable, so the scan terminates and
    /// the lookup is served (never a panic), flagged `degraded`.
    #[inline]
    fn route(&self, layer: usize, expert: u8) -> (usize, bool, bool) {
        let key = policy::key(layer, expert, self.n_experts);
        if self.promoted.contains(&key) {
            return (0, false, false);
        }
        let k = self.k();
        let owner = self.placement.owner(layer, expert, self.n_experts, k);
        if self.replicas <= 1 {
            if !self.unreachable(owner) {
                return (owner, false, false);
            }
        } else {
            let mut best: Option<(usize, usize)> = None; // (hops, node)
            for rank in 0..self.replicas {
                let n = (owner + rank) % k;
                if self.unreachable(n) {
                    continue;
                }
                let h = self.hops(n);
                if best.map_or(true, |(bh, _)| h < bh) {
                    best = Some((h, n));
                }
            }
            if let Some((_, n)) = best {
                return (n, n != owner && self.unreachable(owner), false);
            }
        }
        let mut n = (owner + 1) % k;
        while self.unreachable(n) {
            n = (n + 1) % k;
        }
        (n, true, true)
    }

    /// Next replica in the deterministic failover order after a timed-out
    /// attempt on `current`: the reachable replica with the smallest
    /// `(hops, rank)` key strictly greater than `current`'s.  `None`
    /// exhausts the chain (the final attempt then waits out its fetch —
    /// with no alternative left, abandoning it buys nothing).
    fn next_replica(&self, layer: usize, expert: u8, current: usize) -> Option<usize> {
        let k = self.k();
        let owner = self.placement.owner(layer, expert, self.n_experts, k);
        let cur_key = (self.hops(current), (current + k - owner) % k);
        let mut best: Option<((usize, usize), usize)> = None;
        for rank in 0..self.replicas {
            let n = (owner + rank) % k;
            if self.unreachable(n) {
                continue;
            }
            let key = (self.hops(n), rank);
            if key <= cur_key {
                continue;
            }
            if best.map_or(true, |(bk, _)| key < bk) {
                best = Some((key, n));
            }
        }
        best.map(|(_, n)| n)
    }

    /// Shared lookup body — `lookup` is one call, `lookup_set` loops it,
    /// so the two paths cannot drift.
    fn lookup_one(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        if measured {
            self.advance_faults();
            self.measured_lookups += 1;
        }
        let (owner, failed_over, degraded) = self.route(layer, expert);
        if measured {
            if failed_over {
                self.net.stats.failovers += 1;
                if let Some(c) = &self.failover_ctr {
                    c.inc();
                }
                if !degraded && self.obs.is_active() {
                    self.obs.emit(|ts| TraceEvent::ReplicaFailover {
                        ts_us: ts,
                        node: owner as u8,
                        layer: layer as u16,
                        expert,
                    });
                }
            }
            if degraded {
                // Every replica unreachable: the ring-scan fallback is
                // a deepest-tier demand load on a node that never held
                // the expert.  Count it — availability is the fraction
                // of lookups served without this arm — and serve it.
                self.net.on_degraded();
                if let Some(c) = &self.degraded_ctr {
                    c.inc();
                }
                if self.obs.is_active() {
                    self.obs.emit(|ts| TraceEvent::DegradedFetch {
                        ts_us: ts,
                        node: owner as u8,
                        layer: layer as u16,
                        expert,
                    });
                }
            }
        }
        if owner == 0 {
            // Front-node serve: pure delegation, no network charge.
            // This arm is the whole story at K=1, which is what makes
            // the loopback cluster byte-identical to single-node.
            return self.nodes[0].lookup(layer, expert, measured);
        }
        let mut serve_node = owner;
        let mut r = self.nodes[serve_node].lookup(layer, expert, measured);
        let mut fetch_us = r.fetch_us;
        if measured {
            // Timeout/retry chain: with the deadline armed, an attempt
            // whose priced wire time blows it charges the timeout plus
            // exponential backoff and retries the next-cheapest alive
            // replica.  Wire time is deterministic, so re-asking the
            // same node would time out identically — the chain only
            // moves forward and terminates.  Degraded serves skip it:
            // there is no replica left to retry.
            let mut penalty_us = 0.0;
            if self.net.link.timeout_us > 0.0 && !degraded {
                let mut attempt = 0u32;
                loop {
                    let priced = self.net.price_remote(
                        r.hit,
                        self.hops(serve_node),
                        self.wire_mult(serve_node),
                    );
                    if !self.net.link.times_out(priced) {
                        break;
                    }
                    let Some(next) = self.next_replica(layer, expert, serve_node) else {
                        // Chain exhausted: the final attempt waits out
                        // its fetch — with no alternative, abandoning
                        // it buys nothing.  Never a panic.
                        break;
                    };
                    attempt += 1;
                    let backoff_us = self.retry_backoff_us * f64::powi(2.0, attempt as i32 - 1);
                    penalty_us += self.net.on_timeout(backoff_us);
                    if let Some(c) = &self.retry_ctr {
                        c.inc();
                    }
                    if self.obs.is_active() {
                        self.obs.emit(|ts| TraceEvent::RemoteRetry {
                            ts_us: ts,
                            node: next as u8,
                            layer: layer as u16,
                            expert,
                            attempt: attempt as u8,
                        });
                    }
                    serve_node = next;
                    r = self.nodes[serve_node].lookup(layer, expert, measured);
                    fetch_us = r.fetch_us;
                }
            }
            let hops = self.hops(serve_node);
            let mult = self.wire_mult(serve_node);
            let wire_us = self.net.price_remote(r.hit, hops, mult);
            self.net.commit_remote(r.hit, wire_us);
            if !r.hit {
                // A remote weight fetch stalls the token like a local
                // miss: the wire time joins the demand fetch cost.  On a
                // remote hit the activation wire time is charged to the
                // critical path via `cost_marks` only — `Lookup` keeps
                // the "fetch_us is 0 on a hit" contract.  Timeout and
                // backoff penalties ride along the same way (they are
                // always on the critical path via `NetStats::total_us`).
                fetch_us += wire_us;
                fetch_us += penalty_us;
            }
            if self.obs.is_active() {
                self.obs.emit(|ts| TraceEvent::RemoteFetch {
                    ts_us: ts,
                    node: serve_node as u8,
                    layer: layer as u16,
                    expert,
                    hit: r.hit,
                    wire_us,
                });
            }
            if let Some(c) = self.remote_ctrs.get(serve_node) {
                c.inc();
            }
            if self.promote_after > 0 {
                let k = policy::key(layer, expert, self.n_experts);
                let uses = self.remote_use.entry(k).or_insert(0);
                *uses += 1;
                if *uses >= self.promote_after {
                    self.remote_use.remove(&k);
                    self.promoted.insert(k);
                    // Ship the weights once (network charge), then warm
                    // node 0's hierarchy with an unmeasured lookup — the
                    // same costless-residency-move contract warm-up uses.
                    let promo = self.promo_mult(serve_node);
                    self.net.on_promotion(hops, promo);
                    self.nodes[0].lookup(layer, expert, false);
                    if let Some(c) = &self.promotion_ctr {
                        c.inc();
                    }
                }
            }
        }
        Lookup {
            hit: r.hit,
            fetch_us,
        }
    }
}

impl<const N: usize> ExpertMemory<N> for ClusterMemory<N> {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        self.lookup_one(layer, expert, measured)
    }

    /// Set-level lookup loops the scalar body in ascending-id order —
    /// routing decisions depend on mutable promotion/fault state, so the
    /// scalar sequence *is* the specification (and the default-impl
    /// expansion in the trait matches it exactly).
    fn lookup_set(&mut self, layer: usize, truth: ExpertSet<N>, measured: bool) -> LookupBatch<N> {
        let mut out = LookupBatch::default();
        for e in truth.iter() {
            let r = self.lookup_one(layer, e, measured);
            if r.hit {
                out.hits.insert(e);
            } else {
                out.fetch_us += r.fetch_us;
            }
        }
        out
    }

    /// Prefetch partitions the predicted set by routed owner and hands
    /// each node its shard — predictions warm the hierarchy that will
    /// actually serve the lookup.  Weights rise from each node's *own*
    /// deeper tiers, so no network charge applies here.
    fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>) -> Prefetched {
        let k = self.k();
        if k == 1 {
            return self.nodes[0].prefetch(layer, predicted);
        }
        let mut shards: Vec<ExpertSet<N>> = vec![ExpertSet::new(); k];
        for e in predicted.iter() {
            let (owner, _, _) = self.route(layer, e);
            shards[owner].insert(e);
        }
        let mut out = Prefetched::default();
        for (node, shard) in shards.into_iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            let p = self.nodes[node].prefetch(layer, shard);
            out.issued += p.issued;
            out.landed += p.landed;
            out.too_late += p.too_late;
        }
        out
    }

    fn end_layer(&mut self) {
        for n in &mut self.nodes {
            n.end_layer();
        }
    }

    /// Demand µs = every node's demand (index order) + all network wire
    /// time; stall µs = every node's stall.  Sums start at 0.0 and
    /// accumulate non-negative terms, so at K=1 over loopback the result
    /// is bit-identical to the single node's marks.
    fn cost_marks(&self) -> (f64, f64) {
        let mut demand = 0.0;
        let mut stall = 0.0;
        for n in &self.nodes {
            let (d, s) = n.cost_marks();
            demand += d;
            stall += s;
        }
        demand += self.net.stats.total_us();
        (demand, stall)
    }

    fn set_prefetch_budget(&mut self, budget: usize) {
        for n in &mut self.nodes {
            n.set_prefetch_budget(budget);
        }
    }

    fn set_batch_share(&mut self, batch: usize) {
        for n in &mut self.nodes {
            n.set_batch_share(batch);
        }
    }

    fn effective_prefetch_budget(&self) -> usize {
        self.nodes[0].effective_prefetch_budget()
    }

    /// GPU-resident experts across the whole cluster (sum of every
    /// node's tier 0).
    fn resident_count(&self) -> usize {
        self.nodes.iter().map(|n| n.resident_count()).sum()
    }

    /// Borrowed per-tier counters exist only at K=1 (delegation); a
    /// multi-node merge is owned data — read it from
    /// [`ExpertMemory::stats`] instead.
    fn tier_stats(&self) -> Option<&TierStats> {
        if self.k() == 1 {
            self.nodes[0].tier_stats()
        } else {
            None
        }
    }

    fn stats(&self) -> MemoryStats {
        let mut demand_us = 0.0;
        let mut prefetch_us = 0.0;
        let mut stall_us = 0.0;
        let mut resident = 0usize;
        let mut resident_per_depth: Vec<usize> = Vec::new();
        let mut tiers: Option<TierStats> = None;
        for n in &self.nodes {
            let s = n.stats();
            demand_us += s.demand_us;
            prefetch_us += s.prefetch_us;
            stall_us += s.stall_us;
            resident += s.resident;
            if resident_per_depth.len() < s.resident_per_depth.len() {
                resident_per_depth.resize(s.resident_per_depth.len(), 0);
            }
            for (d, r) in s.resident_per_depth.iter().enumerate() {
                resident_per_depth[d] += r;
            }
            if let Some(t) = s.tiers {
                match &mut tiers {
                    Some(acc) => acc.merge(&t),
                    None => tiers = Some(t),
                }
            }
        }
        demand_us += self.net.stats.total_us();
        MemoryStats {
            demand_us,
            prefetch_us,
            stall_us,
            resident,
            resident_per_depth,
            tiers,
            net: Some(self.net.stats.clone()),
        }
    }

    /// Drops every node's staged residency plus the promotion state that
    /// shadows it (promoted experts are only warm while node 0 holds
    /// them).  Cost accumulators — node DMA and network wire time — are
    /// cumulative across a run and survive, as the trait requires.
    fn clear(&mut self) {
        for n in &mut self.nodes {
            n.clear();
        }
        self.remote_use.clear();
        self.promoted.clear();
    }

    fn set_obs(&mut self, obs: ObsSink) {
        for n in &mut self.nodes {
            n.set_obs(obs.clone());
        }
        if let Some(reg) = obs.registry() {
            reg.gauge("cluster_nodes", &[]).set(self.k() as f64);
            self.remote_ctrs = (0..self.k())
                .map(|i| {
                    let id = i.to_string();
                    reg.counter("cluster_remote_fetches", &[("node", id.as_str())])
                })
                .collect();
            self.failover_ctr = Some(reg.counter("cluster_failovers", &[]));
            self.promotion_ctr = Some(reg.counter("cluster_promotions", &[]));
            self.retry_ctr = Some(reg.counter("cluster_retries", &[]));
            self.degraded_ctr = Some(reg.counter("cluster_degraded_fetches", &[]));
            self.node_up_gauges = (0..self.k())
                .map(|i| {
                    let id = i.to_string();
                    reg.gauge("cluster_node_up", &[("node", id.as_str())])
                })
                .collect();
            for i in 0..self.k() {
                self.publish_node_gauge(i);
            }
        }
        self.obs = obs;
    }
}
