//! Deterministic multi-node edge-cluster serving simulator.
//!
//! The paper's single-device story — predict the next layer's experts,
//! prefetch them up a GPU ↔ host ↔ SSD hierarchy — has a natural edge
//! extension (OD-MoE, FlashMoE deployments): several small devices pool
//! their memory, expert weights are **sharded across K nodes**, and a
//! token's expert either lives on the front node or must be served
//! across a link.  This module models that cluster as one more
//! [`crate::memory::ExpertMemory`] backend, so every existing driver —
//! replay engines, the multi-tenant workload scheduler, sweeps, the
//! serving CLI — gains multi-node mode without new plumbing:
//!
//! * [`PlacementKind`] — pure expert→node ownership maps (round-robin,
//!   block, layer-hash).
//! * [`crate::tier::LinkSpec`] / [`crate::tier::NetCostModel`] — the
//!   network "tier": per-transfer latency + per-hop cost + payload over
//!   bandwidth, accumulated like per-tier DMA.
//! * [`ClusterMemory`] — K per-node backends (each a full flat or
//!   tiered hierarchy from [`crate::memory::build`]) behind one facade:
//!   local serve on node 0, remote serve + wire charge elsewhere,
//!   optional hot-expert migration to the front node
//!   ([`ClusterConfig::promote_after`]).
//! * [`FaultPlan`] — scheduled node failures (ring failover) and
//!   straggler link multipliers, deterministic by construction.
//!
//! Structural invariant: a **K=1 cluster over a loopback link is
//! byte-identical** to the single-node backend it wraps
//! (`tests/cluster_parity.rs`), exactly as the flat path stays
//! bit-identical when the tier hierarchy is off.  Sweep the K × placement
//! × bandwidth × capacity grid with [`crate::sim::sweep_cluster`], or
//! drive it live via `serve-sim --nodes K`.

mod fault;
mod memory;
mod placement;

pub use fault::{DownWindow, FailSlow, FaultPlan, LinkFlap, NodeFailure, SlowLink, Straggler};
pub use memory::ClusterMemory;
pub use placement::PlacementKind;

use crate::config::{CacheConfig, SimConfig, TierConfig};
use crate::memory::{self, ExpertMemory};
use crate::tier::LinkSpec;
use crate::Result;

/// Configuration of one simulated edge cluster.
///
/// The per-node hierarchies themselves are configured by the same
/// [`CacheConfig`] / [`TierConfig`] every single-node run uses (passed
/// to [`build`]); this struct only adds what the cluster layer owns —
/// topology, link pricing, migration policy, and faults.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of nodes, `>= 1`.  Node 0 is the front node: it drives
    /// decode, absorbs failovers, and receives promoted experts.
    pub nodes: usize,
    /// Expert→node ownership map.
    pub placement: PlacementKind,
    /// Inter-node link pricing.  [`LinkSpec::loopback`] makes every
    /// transfer free (the K=1 parity configuration).
    pub link: LinkSpec,
    /// Payload of one expert's weights in MB (remote miss / promotion).
    pub expert_mb: f64,
    /// Payload of one activation round-trip in MB (remote hit).
    pub act_mb: f64,
    /// Migrate an expert to the front node after this many measured
    /// remote serves; 0 disables migration.
    pub promote_after: u32,
    /// Replication factor: each expert lives on this many distinct nodes
    /// (deterministic rank rotation of the placement map).  `1` is the
    /// classic single-owner cluster; must be `<= nodes`.
    pub replicas: usize,
    /// Base backoff after a timed-out fetch attempt (µs); attempt `a`
    /// waits `retry_backoff_us * 2^(a-1)` before retrying the next
    /// replica.  Only reachable when [`crate::tier::LinkSpec::timeout_us`]
    /// arms the deadline.
    pub retry_backoff_us: f64,
    /// Scheduled failures, transient windows and stragglers
    /// (default: none).
    pub faults: FaultPlan,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 1,
            placement: PlacementKind::RoundRobin,
            link: LinkSpec::loopback(),
            // DeepSeek-V2-Lite regime: ~25 MB of weights per routed
            // expert vs sub-MB activation round-trips.
            expert_mb: 25.0,
            act_mb: 0.5,
            promote_after: 0,
            replicas: 1,
            retry_backoff_us: 50.0,
            faults: FaultPlan::none(),
        }
    }
}

impl ClusterConfig {
    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn with_placement(mut self, placement: PlacementKind) -> Self {
        self.placement = placement;
        self
    }

    pub fn with_link(mut self, link: LinkSpec) -> Self {
        self.link = link;
        self
    }

    pub fn with_promote_after(mut self, promote_after: u32) -> Self {
        self.promote_after = promote_after;
        self
    }

    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    pub fn with_retry_backoff_us(mut self, retry_backoff_us: f64) -> Self {
        self.retry_backoff_us = retry_backoff_us;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.nodes >= 1, "cluster needs at least one node");
        anyhow::ensure!(
            self.nodes <= 64,
            "cluster node count {} exceeds the supported maximum of 64",
            self.nodes
        );
        anyhow::ensure!(
            self.expert_mb >= 0.0 && self.expert_mb.is_finite(),
            "expert payload must be finite and >= 0 MB"
        );
        anyhow::ensure!(
            self.act_mb >= 0.0 && self.act_mb.is_finite(),
            "activation payload must be finite and >= 0 MB"
        );
        anyhow::ensure!(
            self.replicas >= 1 && self.replicas <= self.nodes,
            "replication factor {} must be between 1 and the node count {}",
            self.replicas,
            self.nodes
        );
        anyhow::ensure!(
            self.retry_backoff_us >= 0.0 && self.retry_backoff_us.is_finite(),
            "retry backoff must be finite and >= 0 µs"
        );
        self.link.validate()?;
        self.faults.validate(self.nodes)
    }
}

/// Build a [`ClusterMemory`] of `cfg.nodes` identical per-node backends.
///
/// Each node gets its own backend from [`crate::memory::build`] with the
/// supplied `policy` / `cache` / `tier` configs — callers model a fixed
/// per-device memory budget by dividing capacities by the node count
/// *before* calling (as [`crate::sim::sweep_cluster`] does), so adding
/// nodes grows aggregate capacity but not any single device.
///
/// # Example
///
/// A three-node cluster with layer-hashed ownership behaves like any
/// other [`ExpertMemory`]; the extra [`crate::tier::NetStats`] counters
/// show up under [`crate::memory::MemoryStats::net`]:
///
/// ```
/// use moe_beyond::cluster::{self, ClusterConfig, PlacementKind};
/// use moe_beyond::config::{CacheConfig, SimConfig};
/// use moe_beyond::memory::ExpertMemory;
///
/// let cfg = ClusterConfig::default()
///     .with_nodes(3)
///     .with_placement(PlacementKind::LayerHash);
/// let cache = CacheConfig::default().with_capacity(4);
/// let mut mem =
///     cluster::build::<1>(&cfg, "lru", &cache, None, &SimConfig::default(), 64, 1_000.0)
///         .unwrap();
///
/// assert!(!mem.lookup(0, 9, true).hit); // cold: fetched on the owner node
/// assert!(mem.lookup(0, 9, true).hit); // warm: resident where it is owned
/// let stats = mem.stats();
/// assert_eq!(stats.resident, 1);
/// assert!(stats.net.is_some()); // cluster backends report NetStats
/// ```
pub fn build<const N: usize>(
    cfg: &ClusterConfig,
    policy: &str,
    cache: &CacheConfig,
    tier: Option<&TierConfig>,
    sim: &SimConfig,
    n_experts: usize,
    overlap_budget_us: f64,
) -> Result<Box<dyn ExpertMemory<N>>> {
    cfg.validate()?;
    let mut nodes: Vec<Box<dyn ExpertMemory<N>>> = Vec::with_capacity(cfg.nodes);
    for _ in 0..cfg.nodes {
        nodes.push(memory::build::<N>(
            policy,
            cache,
            tier,
            sim,
            n_experts,
            overlap_budget_us,
        )?);
    }
    Ok(Box::new(ClusterMemory::new(nodes, cfg, n_experts)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ExpertSet;

    fn cache_cfg(cap: usize) -> CacheConfig {
        CacheConfig {
            capacity_experts: cap,
            pcie_us_per_expert: 100.0,
            hit_us: 0.0,
            ..Default::default()
        }
    }

    fn cluster(cfg: &ClusterConfig, cap: usize) -> Box<dyn ExpertMemory> {
        build::<1>(
            cfg,
            "lru",
            &cache_cfg(cap),
            None,
            &SimConfig::default(),
            64,
            250.0,
        )
        .unwrap()
    }

    #[test]
    fn k1_loopback_matches_single_node_bit_for_bit() {
        let mut c = cluster(&ClusterConfig::default(), 4);
        let mut single = memory::build::<1>(
            "lru",
            &cache_cfg(4),
            None,
            &SimConfig::default(),
            64,
            250.0,
        )
        .unwrap();
        assert_eq!(c.name(), "cluster");
        for (layer, e) in [(0usize, 7u8), (0, 9), (1, 7), (0, 7), (2, 33)] {
            let a = c.lookup(layer, e, true);
            let b = single.lookup(layer, e, true);
            assert_eq!(a.hit, b.hit);
            assert_eq!(a.fetch_us.to_bits(), b.fetch_us.to_bits());
        }
        c.prefetch(3, ExpertSet::from_ids([1u8, 2, 3]));
        single.prefetch(3, ExpertSet::from_ids([1u8, 2, 3]));
        c.end_layer();
        single.end_layer();
        let (cd, cs) = c.cost_marks();
        let (sd, ss) = single.cost_marks();
        assert_eq!(cd.to_bits(), sd.to_bits());
        assert_eq!(cs.to_bits(), ss.to_bits());
        assert_eq!(c.resident_count(), single.resident_count());
        let stats = c.stats();
        assert_eq!(stats.net.as_ref().unwrap().remote_lookups, 0);
        assert_eq!(stats.net.as_ref().unwrap().total_us(), 0.0);
    }

    #[test]
    fn remote_miss_adds_wire_time_to_fetch_and_demand() {
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0)); // flat 10 µs/transfer
        let mut c = cluster(&cfg, 4);
        // expert 1 round-robins to node 1: remote miss = 100 µs local
        // fault on node 1 + 10 µs of weights on the wire
        let miss = c.lookup(0, 1, true);
        assert!(!miss.hit);
        assert_eq!(miss.fetch_us, 110.0);
        // second access: remote GPU hit — activations travel, Lookup
        // keeps the fetch_us=0 hit contract, wire goes to cost_marks
        let hit = c.lookup(0, 1, true);
        assert!(hit.hit);
        assert_eq!(hit.fetch_us, 0.0);
        let (demand, _) = c.cost_marks();
        assert_eq!(demand, 120.0); // 100 local + 2 × 10 wire
        let net = c.stats().net.unwrap();
        assert_eq!(net.remote_lookups, 2);
        assert_eq!(net.remote_hits, 1);
        assert_eq!(net.wire_us, 20.0);
        // expert 0 is local to node 0: no network involvement
        let local = c.lookup(0, 0, true);
        assert_eq!(local.fetch_us, 100.0);
        assert_eq!(c.stats().net.unwrap().remote_lookups, 2);
    }

    #[test]
    fn hot_expert_migrates_to_front_node_after_threshold() {
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_promote_after(2);
        let mut c = cluster(&cfg, 4);
        c.lookup(0, 1, true); // remote miss (use 1)
        c.lookup(0, 1, true); // remote hit (use 2) -> promoted
        let net = c.stats().net.unwrap();
        assert_eq!(net.promotions, 1);
        assert_eq!(net.promotion_us, 10.0);
        // now owned (and warm) on node 0: local hit, no new wire time
        let wire_before = c.stats().net.unwrap().total_us();
        let r = c.lookup(0, 1, true);
        assert!(r.hit);
        assert_eq!(c.stats().net.unwrap().total_us(), wire_before);
    }

    #[test]
    fn failed_node_reroutes_in_ring_order_and_counts_failovers() {
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_faults(FaultPlan::none().with_failure(1, 0));
        let mut c = cluster(&cfg, 4);
        // expert 1 is owned by the dead node 1 -> served by node 2
        let r = c.lookup(0, 1, true);
        assert!(!r.hit);
        let net = c.stats().net.unwrap();
        assert_eq!(net.failovers, 1);
        assert_eq!(net.remote_lookups, 1); // node 2 is still remote
        // at R=1 the ring fallback IS the degraded path: no replica held
        // the expert, so the serve counts as a degraded fetch
        assert_eq!(net.degraded_fetches, 1);
        // same expert again: the rerouted copy is warm on node 2
        assert!(c.lookup(0, 1, true).hit);
    }

    #[test]
    fn replica_failover_serves_from_surviving_replica_then_degrades() {
        // k=3, R=2: expert 1's replicas sit on nodes 1 (rank 0) and 2.
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_replicas(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(FaultPlan::none().with_failure(1, 0));
        let mut c = cluster(&cfg, 4);
        let r = c.lookup(0, 1, true);
        assert!(!r.hit);
        assert_eq!(r.fetch_us, 110.0); // node 2 serves at normal wire cost
        let net = c.stats().net.unwrap();
        assert_eq!(net.failovers, 1); // rank 0 was unreachable
        assert_eq!(net.degraded_fetches, 0); // ...but a replica served it
        // warm on the surviving replica now
        assert!(c.lookup(0, 1, true).hit);

        // kill the second replica too: the same expert degrades to the
        // ring scan, which lands on the front node — and never panics
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_replicas(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(FaultPlan::none().with_failure(1, 0).with_failure(2, 0));
        let mut c = cluster(&cfg, 4);
        let r = c.lookup(0, 1, true);
        assert!(!r.hit);
        assert_eq!(r.fetch_us, 100.0); // front-node demand load, no wire
        let net = c.stats().net.unwrap();
        assert_eq!(net.degraded_fetches, 1);
        assert_eq!(net.failovers, 1);
    }

    #[test]
    fn timed_out_fetch_retries_next_replica_with_backoff() {
        // node 1's straggled link prices a miss at 50 µs > the 20 µs
        // deadline; the rank-1 replica on node 2 serves within it.
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_replicas(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0).with_timeout_us(20.0))
            .with_retry_backoff_us(5.0)
            .with_faults(FaultPlan::none().with_straggler(1, 5.0));
        let mut c = cluster(&cfg, 4);
        let r = c.lookup(0, 1, true);
        assert!(!r.hit);
        // 100 local fault on node 2 + 10 wire + (20 timeout + 5 backoff)
        assert_eq!(r.fetch_us, 135.0);
        let net = c.stats().net.unwrap();
        assert_eq!(net.retries, 1);
        assert_eq!(net.timeout_us, 20.0);
        assert_eq!(net.backoff_us, 5.0);
        assert_eq!(net.wire_us, 10.0); // only the serving attempt commits
        assert_eq!(net.degraded_fetches, 0);
    }

    #[test]
    fn exhausted_retry_chain_waits_out_the_final_fetch() {
        // both replicas time out; the chain ends and the last attempt
        // commits its full wire time instead of panicking or looping.
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_replicas(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0).with_timeout_us(20.0))
            .with_retry_backoff_us(5.0)
            .with_faults(
                FaultPlan::none()
                    .with_straggler(1, 5.0)
                    .with_straggler(2, 5.0),
            );
        let mut c = cluster(&cfg, 4);
        let r = c.lookup(0, 1, true);
        assert!(!r.hit);
        // 100 local + 50 slow wire on node 2 + (20 + 5) timeout penalty
        assert_eq!(r.fetch_us, 175.0);
        let net = c.stats().net.unwrap();
        assert_eq!(net.retries, 1);
        assert_eq!(net.wire_us, 50.0);
    }

    #[test]
    fn down_window_recovers_cold_and_link_flap_recovers_warm() {
        // crash-restart: node 1 loses its cache across the outage
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(FaultPlan::none().with_down_window(1, 2, 4));
        let mut c = cluster(&cfg, 4);
        assert!(!c.lookup(0, 1, true).hit); // #0 remote miss, warms node 1
        assert!(c.lookup(0, 1, true).hit); // #1 remote hit
        c.lookup(0, 1, true); // #2 degraded to node 0
        c.lookup(0, 1, true); // #3 degraded to node 0
        assert_eq!(c.stats().net.unwrap().degraded_fetches, 2);
        // #4: node 1 is back but cold — the expert must miss again
        assert!(!c.lookup(0, 1, true).hit);
        assert_eq!(c.stats().net.unwrap().degraded_fetches, 2);

        // link flap: same schedule, but the node keeps its residency
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(FaultPlan::none().with_link_flap(1, 2, 4));
        let mut c = cluster(&cfg, 4);
        assert!(!c.lookup(0, 1, true).hit); // #0 warms node 1
        assert!(c.lookup(0, 1, true).hit); // #1
        c.lookup(0, 1, true); // #2 degraded
        c.lookup(0, 1, true); // #3 degraded
        // #4: the link is back and the cache survived the flap
        assert!(c.lookup(0, 1, true).hit);
    }

    #[test]
    fn slow_link_and_fail_slow_episodes_end_on_schedule() {
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(FaultPlan::none().with_slow_link(1, 1, 2, 3.0));
        let mut c = cluster(&cfg, 4);
        c.lookup(0, 1, true); // #0: healthy wire, 10
        c.lookup(0, 1, true); // #1: episode wire, 30
        c.lookup(0, 1, true); // #2: episode over, 10
        assert_eq!(c.stats().net.unwrap().wire_us, 50.0);

        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(FaultPlan::none().with_fail_slow(1, 1, 2, 2.0));
        let mut c = cluster(&cfg, 4);
        c.lookup(0, 1, true); // #0: 10
        c.lookup(0, 1, true); // #1: 20 (fail-slow serve)
        c.lookup(0, 1, true); // #2: 10
        assert_eq!(c.stats().net.unwrap().wire_us, 40.0);
    }

    #[test]
    fn replicated_cluster_validates_and_r1_matches_builder_default() {
        assert!(ClusterConfig::default()
            .with_nodes(2)
            .with_replicas(3)
            .validate()
            .is_err());
        assert!(ClusterConfig::default().with_replicas(0).validate().is_err());
        assert!(ClusterConfig::default()
            .with_nodes(4)
            .with_replicas(4)
            .validate()
            .is_ok());
        assert!(ClusterConfig {
            retry_backoff_us: f64::NAN,
            ..Default::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn failure_fires_exactly_at_its_lookup_index() {
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_faults(FaultPlan::none().with_failure(1, 2));
        let mut c = cluster(&cfg, 4);
        c.lookup(0, 1, true); // #0: node 1 alive
        c.lookup(0, 1, true); // #1: node 1 alive (remote hit)
        assert_eq!(c.stats().net.unwrap().failovers, 0);
        c.lookup(0, 1, true); // #2: failure fires first -> failover
        assert_eq!(c.stats().net.unwrap().failovers, 1);
    }

    #[test]
    fn straggler_multiplies_wire_time() {
        let base = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0));
        let slow = base
            .clone()
            .with_faults(FaultPlan::none().with_straggler(1, 3.0));
        let mut healthy = cluster(&base, 4);
        let mut degraded = cluster(&slow, 4);
        healthy.lookup(0, 1, true);
        degraded.lookup(0, 1, true);
        assert_eq!(healthy.stats().net.unwrap().wire_us, 10.0);
        assert_eq!(degraded.stats().net.unwrap().wire_us, 30.0);
    }

    #[test]
    fn clear_drops_residency_and_migrations_but_keeps_costs() {
        let cfg = ClusterConfig::default()
            .with_nodes(2)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_promote_after(1);
        let mut c = cluster(&cfg, 4);
        c.lookup(0, 1, true); // remote miss + immediate promotion
        assert!(c.resident_count() > 0);
        let (d0, _) = c.cost_marks();
        assert!(d0 > 0.0);
        c.clear();
        assert_eq!(c.resident_count(), 0);
        let (d1, _) = c.cost_marks();
        assert_eq!(d0.to_bits(), d1.to_bits());
        // the migration was dropped with the residency: the expert is
        // remote-owned (and cold) again
        let r = c.lookup(0, 1, true);
        assert!(!r.hit);
        assert_eq!(r.fetch_us, 110.0);
    }

    #[test]
    fn prefetch_shards_by_owner_and_warms_the_serving_node() {
        let cfg = ClusterConfig::default().with_nodes(2);
        let mut c = cluster(&cfg, 8);
        let p = c.prefetch(0, ExpertSet::from_ids([1u8, 2, 3, 4]));
        assert_eq!(p.issued, 4);
        assert_eq!(p.landed, 4);
        // every prefetched expert now hits on its owner
        for e in [1u8, 2, 3, 4] {
            assert!(c.lookup(0, e, true).hit, "expert {e}");
        }
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(ClusterConfig::default().with_nodes(0).validate().is_err());
        assert!(ClusterConfig::default().with_nodes(65).validate().is_err());
        assert!(ClusterConfig {
            expert_mb: -1.0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(ClusterConfig::default()
            .with_nodes(2)
            .with_faults(FaultPlan::none().with_failure(0, 0))
            .validate()
            .is_err());
        assert!(ClusterConfig::default().with_nodes(4).validate().is_ok());
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_placement(PlacementKind::LayerHash)
            .with_link(LinkSpec::lan())
            .with_promote_after(2)
            .with_faults(FaultPlan::none().with_failure(2, 5).with_straggler(1, 1.5));
        let run = || {
            let mut c = cluster(&cfg, 6);
            for t in 0..40usize {
                let layer = t % 4;
                c.prefetch(layer, ExpertSet::from_ids([(t % 64) as u8]));
                c.lookup(layer, ((t * 7) % 64) as u8, true);
                c.end_layer();
            }
            let s = c.stats();
            (
                s.demand_us.to_bits(),
                s.stall_us.to_bits(),
                s.resident,
                s.net.unwrap(),
            )
        };
        assert_eq!(run(), run());
    }
}
