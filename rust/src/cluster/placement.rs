//! Expert-to-node placement maps: which cluster node owns each
//! `(layer, expert)` weight shard.
//!
//! Ownership is a pure function of the coordinates and the node count —
//! no state, no RNG — so every placement is trivially reproducible and
//! two runs of the same seeded workload route identically.  All
//! placements collapse to node 0 at `k = 1`, which is what lets the K=1
//! cluster parity suite hold the cluster backend byte-identical to the
//! single-node path.

use crate::Result;

/// How expert weights are sharded across the `k` cluster nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// `expert % k`: interleaves expert ids across nodes.  Spreads a
    /// layer's top-k set widely — worst case for locality, best for
    /// per-node load balance.
    RoundRobin,
    /// `expert * k / n_experts`: contiguous id ranges per node.  Models
    /// the "shard the FFN bank in blocks" layout most tensor-parallel
    /// runtimes use; co-activated neighboring ids stay on one node.
    Block,
    /// SplitMix64 hash of `(layer, expert)` mod `k`: decorrelates
    /// ownership across layers so one node is not the owner of the same
    /// expert id in every layer.
    LayerHash,
}

impl PlacementKind {
    /// Grid order for sweeps and reports.
    pub const ALL: [PlacementKind; 3] = [
        PlacementKind::RoundRobin,
        PlacementKind::Block,
        PlacementKind::LayerHash,
    ];

    pub fn id(&self) -> &'static str {
        match self {
            PlacementKind::RoundRobin => "roundrobin",
            PlacementKind::Block => "block",
            PlacementKind::LayerHash => "layerhash",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "roundrobin" | "rr" => Ok(PlacementKind::RoundRobin),
            "block" => Ok(PlacementKind::Block),
            "layerhash" | "hash" => Ok(PlacementKind::LayerHash),
            other => anyhow::bail!(
                "unknown placement '{other}' (expected roundrobin|block|layerhash)"
            ),
        }
    }

    /// Owning node of `(layer, expert)` in a `k`-node cluster.
    /// Always 0 when `k <= 1`.
    #[inline]
    pub fn owner(&self, layer: usize, expert: u8, n_experts: usize, k: usize) -> usize {
        if k <= 1 {
            return 0;
        }
        match self {
            PlacementKind::RoundRobin => expert as usize % k,
            PlacementKind::Block => expert as usize * k / n_experts.max(1),
            PlacementKind::LayerHash => {
                (splitmix64((layer as u64) << 8 | expert as u64) % k as u64) as usize
            }
        }
    }

    /// Node holding replica `rank` of `(layer, expert)`: the rank-0
    /// replica is the primary [`Self::owner`]; rank `r` is a
    /// deterministic rotation `(owner + r) % k`.  Ranks `0..R` therefore
    /// name `R` *distinct* nodes whenever `R <= k`, and rank maps for
    /// different `R` are nested prefixes of each other — which is what
    /// makes availability monotone in the replication factor under a
    /// fixed fault plan.
    #[inline]
    pub fn replica_owner(
        &self,
        layer: usize,
        expert: u8,
        n_experts: usize,
        k: usize,
        rank: usize,
    ) -> usize {
        if k <= 1 {
            return 0;
        }
        (self.owner(layer, expert, n_experts, k) + rank) % k
    }
}

/// SplitMix64 finalizer — the standard avalanche used for seeding
/// elsewhere in this crate's synthetic generators.  `pub(crate)` so the
/// fault-plan chaos generator can derive its windows from the same
/// stateless hash.
#[inline]
pub(crate) fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_placement_collapses_to_node_zero_at_k1() {
        for p in PlacementKind::ALL {
            for layer in 0..8 {
                for e in 0..64u8 {
                    assert_eq!(p.owner(layer, e, 64, 1), 0, "{p:?}");
                }
            }
        }
    }

    #[test]
    fn owners_stay_in_range_and_cover_all_nodes() {
        for p in PlacementKind::ALL {
            for k in [2usize, 3, 4, 7] {
                let mut seen = vec![false; k];
                for layer in 0..16 {
                    for e in 0..64u8 {
                        let o = p.owner(layer, e, 64, k);
                        assert!(o < k, "{p:?} k={k} owner {o}");
                        seen[o] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "{p:?} k={k} left a node empty");
            }
        }
    }

    #[test]
    fn block_placement_is_monotone_in_expert_id() {
        let p = PlacementKind::Block;
        let mut prev = 0usize;
        for e in 0..64u8 {
            let o = p.owner(0, e, 64, 4);
            assert!(o >= prev);
            prev = o;
        }
        assert_eq!(p.owner(0, 0, 64, 4), 0);
        assert_eq!(p.owner(0, 63, 64, 4), 3);
    }

    #[test]
    fn layerhash_varies_owner_across_layers() {
        let p = PlacementKind::LayerHash;
        // deterministic across calls
        assert_eq!(p.owner(3, 17, 64, 5), p.owner(3, 17, 64, 5));
        // the same expert id must not map to one node in every layer
        let owners: Vec<usize> = (0..32).map(|l| p.owner(l, 17, 64, 5)).collect();
        assert!(owners.iter().any(|&o| o != owners[0]));
    }

    #[test]
    fn replica_ranks_are_distinct_rotations_nested_across_r() {
        for p in PlacementKind::ALL {
            for k in [2usize, 3, 5] {
                for layer in 0..8 {
                    for e in 0..64u8 {
                        // rank 0 is the primary owner
                        assert_eq!(p.replica_owner(layer, e, 64, k, 0), p.owner(layer, e, 64, k));
                        // ranks 0..k cover k distinct nodes
                        let mut seen = vec![false; k];
                        for r in 0..k {
                            let o = p.replica_owner(layer, e, 64, k, r);
                            assert!(o < k);
                            assert!(!seen[o], "{p:?} k={k} rank {r} repeats node {o}");
                            seen[o] = true;
                        }
                    }
                }
            }
        }
        // k=1 collapses every rank to node 0
        assert_eq!(PlacementKind::LayerHash.replica_owner(3, 9, 64, 1, 2), 0);
    }

    #[test]
    fn parse_round_trips_ids_and_rejects_junk() {
        for p in PlacementKind::ALL {
            assert_eq!(PlacementKind::parse(p.id()).unwrap(), p);
        }
        assert_eq!(
            PlacementKind::parse("RR").unwrap(),
            PlacementKind::RoundRobin
        );
        assert!(PlacementKind::parse("striped").is_err());
    }
}
