//! `artifacts/` manifest loading.
//!
//! `make artifacts` (the build-time Python path) writes `artifacts.json`
//! describing the world dimensions, the trained predictor, trace splits,
//! and the HLO executables.  This module is the single entry point the
//! rest of the crate uses to locate and sanity-check those files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use crate::util::json::Json;
use crate::Result;

/// World dimensions + provenance (mirrors `world.py::World.manifest`).
#[derive(Debug, Clone)]
pub struct WorldMeta {
    pub format: String,
    pub seed: u64,
    pub n_layers: u16,
    pub n_experts: u16,
    pub top_k: u16,
    pub n_shared: u16,
    pub n_topics: u16,
    pub d_model: u16,
    pub vocab_size: u32,
    pub working_set: u16,
    pub layer_mix: f64,
    pub router_temp: f64,
    pub router_noise: f64,
    pub ctx_alpha: Option<f64>,
    pub route_beta: Option<f64>,
    pub score_floor: f64,
    pub n_heads: u16,
    pub d_head: u16,
    pub d_expert: u16,
    pub d_shared: u16,
    pub max_seq: u32,
    pub fingerprint: String,
}

impl WorldMeta {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            format: j.req("format")?.as_str()?.to_string(),
            seed: j.req("seed")?.as_u64()?,
            n_layers: j.req("n_layers")?.as_u64()? as u16,
            n_experts: j.req("n_experts")?.as_u64()? as u16,
            top_k: j.req("top_k")?.as_u64()? as u16,
            n_shared: j.req("n_shared")?.as_u64()? as u16,
            n_topics: j.req("n_topics")?.as_u64()? as u16,
            d_model: j.req("d_model")?.as_u64()? as u16,
            vocab_size: j.req("vocab_size")?.as_u64()? as u32,
            working_set: j.req("working_set")?.as_u64()? as u16,
            layer_mix: j.req("layer_mix")?.as_f64()?,
            router_temp: j.req("router_temp")?.as_f64()?,
            router_noise: j.req("router_noise")?.as_f64()?,
            ctx_alpha: j.get("ctx_alpha").map(|v| v.as_f64()).transpose()?,
            route_beta: j.get("route_beta").map(|v| v.as_f64()).transpose()?,
            score_floor: j.req("score_floor")?.as_f64()?,
            n_heads: j.req("n_heads")?.as_u64()? as u16,
            d_head: j.req("d_head")?.as_u64()? as u16,
            d_expert: j.req("d_expert")?.as_u64()? as u16,
            d_shared: j.req("d_shared")?.as_u64()? as u16,
            max_seq: j.req("max_seq")?.as_u64()? as u32,
            fingerprint: j.req("fingerprint")?.as_str()?.to_string(),
        })
    }
}

/// Predictor hyper-parameters (mirrors `PredictorConfig`).
#[derive(Debug, Clone)]
pub struct PredictorMeta {
    pub d_tok: u16,
    pub n_model_layers: u16,
    pub n_experts: u16,
    pub d_layer: u16,
    pub d_model: u16,
    pub n_enc_layers: u16,
    pub n_heads: u16,
    pub d_ff: u16,
    pub window: u32,
    pub top_k: u16,
    pub batch: u32,
}

impl PredictorMeta {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            d_tok: j.req("d_tok")?.as_u64()? as u16,
            n_model_layers: j.req("n_model_layers")?.as_u64()? as u16,
            n_experts: j.req("n_experts")?.as_u64()? as u16,
            d_layer: j.req("d_layer")?.as_u64()? as u16,
            d_model: j.req("d_model")?.as_u64()? as u16,
            n_enc_layers: j.req("n_enc_layers")?.as_u64()? as u16,
            n_heads: j.req("n_heads")?.as_u64()? as u16,
            d_ff: j.req("d_ff")?.as_u64()? as u16,
            window: j.req("window")?.as_u64()? as u32,
            top_k: j.req("top_k")?.as_u64()? as u16,
            batch: j.req("batch")?.as_u64()? as u32,
        })
    }
}

/// One trace split (train/val/test/backbone_val).
#[derive(Debug, Clone)]
pub struct SplitMeta {
    pub prompts: u32,
    pub trace_points: u64,
    pub path: String,
}

/// Signature of one AOT-lowered executable.
#[derive(Debug, Clone)]
pub struct ExecutableSig {
    pub path: String,
    pub num_inputs: usize,
    pub input_shapes: Vec<Vec<usize>>,
}

/// A discovered, validated artifact tree.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub root: PathBuf,
    pub world: WorldMeta,
    pub predictor: PredictorMeta,
    pub splits: HashMap<String, SplitMeta>,
    pub executables: HashMap<String, ExecutableSig>,
}

impl Artifacts {
    /// Load and validate `<root>/artifacts.json`.
    pub fn discover<P: AsRef<Path>>(root: P) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("artifacts.json");
        ensure!(
            manifest_path.exists(),
            "no artifacts.json under {root:?}; run `make artifacts` first"
        );
        let j = Json::parse_file(&manifest_path)?;

        let world = WorldMeta::from_json(j.req("world")?)?;
        ensure!(
            (world.n_experts as usize) <= crate::util::MAX_EXPERTS,
            "ExpertSet is a multi-word bitset of at most {} bits ({} u64 words): n_experts={}",
            crate::util::MAX_EXPERTS,
            crate::util::N_MAX,
            world.n_experts
        );
        ensure!(world.top_k < world.n_experts, "top_k must be < n_experts");
        ensure!(
            world.format == "moe-beyond-world-v1",
            "unknown world format {}",
            world.format
        );

        let predictor = PredictorMeta::from_json(j.req("predictor_config")?)?;

        let mut splits = HashMap::new();
        for (name, s) in j.req("splits")?.as_obj()? {
            splits.insert(
                name.clone(),
                SplitMeta {
                    prompts: s.req("prompts")?.as_u64()? as u32,
                    trace_points: s.req("trace_points")?.as_u64()?,
                    path: s.req("path")?.as_str()?.to_string(),
                },
            );
        }

        let mut executables = HashMap::new();
        for (name, e) in j.req("executables")?.as_obj()? {
            executables.insert(
                name.clone(),
                ExecutableSig {
                    path: e.req("path")?.as_str()?.to_string(),
                    num_inputs: e.req("num_inputs")?.as_usize()?,
                    input_shapes: e
                        .req("input_shapes")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize_vec())
                        .collect::<Result<_>>()?,
                },
            );
        }

        let arts = Self {
            root,
            world,
            predictor,
            splits,
            executables,
        };
        // every declared executable must exist on disk
        for (name, sig) in &arts.executables {
            let p = arts.root.join(&sig.path);
            ensure!(p.exists(), "executable {name} missing at {p:?}");
        }
        Ok(arts)
    }

    /// Absolute path of a file inside the artifact tree.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableSig> {
        self.executables
            .get(name)
            .with_context(|| format!("no executable named {name} in artifacts.json"))
    }

    pub fn split(&self, name: &str) -> Result<&SplitMeta> {
        self.splits
            .get(name)
            .with_context(|| format!("no trace split named {name} in artifacts.json"))
    }

    /// The predictor-weights fingerprint must match the world fingerprint
    /// (paper §5: the predictor is tightly coupled to its backbone; a
    /// mismatch is a hard error, not a silent accuracy collapse).
    pub fn check_fingerprint(&self) -> Result<()> {
        let j = Json::parse_file(self.path("predictor_weights.bin.json"))?;
        let fp = j.req("fingerprint")?.as_str()?;
        ensure!(
            fp == self.world.fingerprint,
            "predictor weights were trained for world {} but artifacts hold {}",
            fp,
            self.world.fingerprint
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts_root() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("artifacts.json").exists().then_some(p)
    }

    #[test]
    fn discover_real_artifacts_if_present() {
        let Some(root) = arts_root() else { return };
        let a = Artifacts::discover(&root).unwrap();
        assert_eq!(a.world.n_experts, 64);
        assert_eq!(a.world.top_k, 6);
        assert_eq!(a.world.n_layers, 27);
        assert!(a.executables.contains_key("predictor"));
        assert!(a.predictor.window >= 16);
        a.check_fingerprint().unwrap();
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Artifacts::discover("/nonexistent/nowhere").is_err());
    }
}
