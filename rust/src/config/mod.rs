//! Typed configuration: artifact manifests written by the Python compile
//! path, plus runtime knobs (cache, simulator, serving) with validation.

mod artifacts;
mod runtime_cfg;

pub use artifacts::{Artifacts, ExecutableSig, PredictorMeta, SplitMeta, WorldMeta};
pub use runtime_cfg::{
    CacheConfig, EamConfig, ServeConfig, SimConfig, TierConfig, WorkloadConfig,
};
