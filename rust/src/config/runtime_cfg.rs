//! Runtime configuration knobs (cache, EAM baseline, simulator, serving)
//! with builder-style construction and validation.

use anyhow::ensure;
use crate::tier::TierSpec;
use crate::Result;

/// Expert-cache configuration (the simulated GPU VRAM).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Total experts the cache can hold (across all layers).
    pub capacity_experts: usize,
    /// Modeled cost of fetching one expert host->VRAM over PCIe, in µs.
    /// Default: DeepSeek-V2-Lite expert ≈ 44 MB bf16 over PCIe 4.0 x16
    /// (~32 GB/s sustained) ≈ 1.4 ms; scaled to our backbone's expert
    /// size at the same bandwidth ratio.
    pub pcie_us_per_expert: f64,
    /// Modeled cost of an in-VRAM hit (µs) — effectively free.
    pub hit_us: f64,
    /// Pin shared experts (always resident, not counted against capacity).
    pub pin_shared: bool,
    /// Modeled per-token decode compute available to hide prefetch DMA,
    /// in µs; divided by the layer count for the per-layer overlap
    /// window.  One knob shared by the simulator and the serving engine.
    /// Default: the measured per-token decode wall of the reference
    /// backbone (~30 ms).
    pub overlap_decode_us: f64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            capacity_experts: 172, // 10% of 27*64
            pcie_us_per_expert: 1400.0,
            hit_us: 2.0,
            pin_shared: true,
            overlap_decode_us: 30_000.0,
        }
    }
}

impl CacheConfig {
    pub fn with_capacity(mut self, n: usize) -> Self {
        self.capacity_experts = n;
        self
    }

    /// Capacity as a fraction of the full expert pool (layers × experts).
    pub fn with_capacity_frac(mut self, frac: f64, n_layers: usize, n_experts: usize) -> Self {
        let total = n_layers * n_experts;
        self.capacity_experts = ((total as f64 * frac).round() as usize).max(1);
        self
    }

    /// Per-layer DMA overlap window (µs): one layer's share of the
    /// per-token decode compute.
    pub fn overlap_per_layer(&self, n_layers: usize) -> f64 {
        self.overlap_decode_us / n_layers.max(1) as f64
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.capacity_experts > 0, "cache capacity must be > 0");
        ensure!(self.pcie_us_per_expert >= 0.0, "negative PCIe cost");
        ensure!(self.overlap_decode_us >= 0.0, "negative overlap window");
        Ok(())
    }
}

/// Tiered expert-memory configuration (opt-in; see [`crate::tier`]).
///
/// When present, the expert weights are staged across the listed tiers
/// (index 0 = GPU VRAM, then host RAM, then SSD) instead of the flat
/// `CacheConfig` VRAM-vs-infinite-host model.
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Ordered fastest to slowest.  An access that misses every tier is
    /// charged the deepest tier's fetch cost (cold backing-store read).
    pub tiers: Vec<TierSpec>,
    /// Eviction policy instantiated per tier ("lru" | "lfu").
    pub policy: String,
}

impl Default for TierConfig {
    fn default() -> Self {
        // DeepSeek-V2-Lite topology (27×64 = 1728 experts): 10% in VRAM,
        // 25% in host RAM, everything on flash.
        Self {
            tiers: vec![
                TierSpec::gpu(172),
                TierSpec::host(432),
                TierSpec::ssd(1728),
            ],
            policy: "lru".to_string(),
        }
    }
}

impl TierConfig {
    pub fn with_gpu_capacity(mut self, n: usize) -> Self {
        if let Some(t) = self.tiers.first_mut() {
            t.capacity_experts = n.max(1);
        }
        self
    }

    pub fn with_host_capacity(mut self, n: usize) -> Self {
        if let Some(t) = self.tiers.get_mut(1) {
            t.capacity_experts = n.max(1);
        }
        self
    }

    /// Override the deepest tier's fetch cost (SSD bandwidth sweeps).
    pub fn with_deepest_fetch_us(mut self, us: f64) -> Self {
        if let Some(t) = self.tiers.last_mut() {
            t.fetch_us_per_expert = us;
        }
        self
    }

    /// Size the deepest tier (normally the full expert pool: flash holds
    /// every expert).
    pub fn with_deepest_capacity(mut self, n: usize) -> Self {
        if let Some(t) = self.tiers.last_mut() {
            t.capacity_experts = n.max(1);
        }
        self
    }

    pub fn with_policy(mut self, policy: &str) -> Self {
        self.policy = policy.to_string();
        self
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.tiers.is_empty(), "tier config needs at least one tier");
        for t in &self.tiers {
            t.validate()?;
        }
        for w in self.tiers.windows(2) {
            ensure!(
                w[0].fetch_us_per_expert <= w[1].fetch_us_per_expert,
                "tiers must be ordered fastest to slowest ({} serves faster than {})",
                w[1].name,
                w[0].name
            );
        }
        // defer to build_policy as the single source of truth for which
        // policy names exist (a capacity-1 probe is allocation-free)
        crate::cache::build_policy(&self.policy, 1)?;
        Ok(())
    }
}

/// MoE-Infinity EAM baseline configuration (paper §3.1 / §4.1.4).
#[derive(Debug, Clone)]
pub struct EamConfig {
    /// EAMC capacity: number of request-level sketches retained.
    pub eamc_capacity: usize,
    /// k-means clusters used to compact the EAMC (Fig 4); 0 = keep raw.
    pub kmeans_clusters: usize,
    /// k-means iterations.
    pub kmeans_iters: usize,
    /// Experts prefetched per layer from the matched sketch.
    pub prefetch_per_layer: usize,
}

impl Default for EamConfig {
    fn default() -> Self {
        Self {
            eamc_capacity: 120,
            kmeans_clusters: 24,
            kmeans_iters: 12,
            prefetch_per_layer: 6,
        }
    }
}

impl EamConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.eamc_capacity > 0, "eamc_capacity must be > 0");
        ensure!(self.prefetch_per_layer > 0, "prefetch_per_layer must be > 0");
        Ok(())
    }
}

/// Trace-driven simulator configuration (paper §4.1.4).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Warm-up tokens per prompt: these only warm the LRU cache (and the
    /// partial rEAM) before prediction starts — "the first n tokens".
    pub warmup_tokens: usize,
    /// Experts taken from the predictor per layer (top-k of probs).
    pub predict_top_k: usize,
    /// Refresh the learned predictor every this many tokens (its window
    /// output covers all positions, so reuse between refreshes is sound).
    pub predictor_stride: usize,
    /// Prefetch horizon in layers (paper: 1 — §5 third limitation).
    pub lookahead_layers: usize,
    /// Max experts whose DMA can complete within one layer's compute
    /// window (PCIe-bound; paper §5: transfers overlap only the preceding
    /// layer).  Prefetches beyond this are issued but arrive too late —
    /// this is what makes DeepSpeed-MoE's fetch-everything strategy
    /// "over-fetch badly" (§3.1) instead of trivially winning.
    pub prefetch_budget: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            warmup_tokens: 8,
            predict_top_k: 6,
            predictor_stride: 4,
            lookahead_layers: 1,
            prefetch_budget: 12,
        }
    }
}

impl SimConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.predict_top_k > 0 && self.predict_top_k <= 64, "bad predict_top_k");
        ensure!(self.predictor_stride > 0, "stride must be > 0");
        ensure!(self.lookahead_layers >= 1, "lookahead must be >= 1");
        ensure!(self.prefetch_budget >= 1, "prefetch_budget must be >= 1");
        Ok(())
    }
}

/// Serving-loop configuration (L3 coordinator).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max tokens generated per request.
    pub max_new_tokens: usize,
    /// Micro-batch size; the paper's method assumes 1 (§5), larger values
    /// are supported to reproduce the degradation ablation.
    pub batch_size: usize,
    /// Request queue bound (admission control / backpressure).
    pub queue_depth: usize,
    /// Dynamic-batching window (ms): after the first request of a batch
    /// arrives, the engine worker waits up to this long for co-arriving
    /// requests before launching (vLLM-style).  0 disables the wait.
    pub batch_window_ms: u64,
    /// Sampling temperature for the backbone LM head (0 = greedy).
    pub temperature: f64,
    /// Which predictor drives prefetch: "learned", "eam", "next-layer",
    /// "popularity", "oracle", "none".
    pub predictor: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_new_tokens: 32,
            batch_size: 1,
            queue_depth: 64,
            batch_window_ms: 20,
            temperature: 0.0,
            predictor: "learned".to_string(),
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_new_tokens > 0, "max_new_tokens must be > 0");
        ensure!(self.batch_size >= 1, "batch_size must be >= 1");
        ensure!(self.queue_depth >= 1, "queue_depth must be >= 1");
        ensure!(
            self.batch_window_ms <= 1_000,
            "batch_window_ms above 1s would stall admission"
        );
        // PredictorKind is the single source of truth for which
        // predictor names exist
        ensure!(
            crate::predictor::PredictorKind::parse(&self.predictor).is_some(),
            "unknown predictor {}",
            self.predictor
        );
        Ok(())
    }
}

/// Multi-tenant workload-simulator configuration (see
/// [`crate::workload`]): how the virtual-time engine schedules and what
/// one unit of work costs.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Max concurrently decoding streams; due arrivals beyond this wait
    /// in the FIFO admission queue (modeled queueing delay).
    pub max_concurrency: usize,
    /// Scheduling policy id: "fcfs" | "round-robin" | "srd".
    pub policy: String,
    /// Modeled per-token decode compute (µs) — the engine occupancy of
    /// one decode step.  Default matches
    /// [`CacheConfig::overlap_decode_us`].
    pub token_compute_us: f64,
    /// Modeled prefill compute per prompt token (µs); prefill is one
    /// batched pass, so this is well below the decode-step cost.
    pub prefill_us_per_token: f64,
    /// Cap on the report's `completion_ids` log (request ids in
    /// completion order, kept for scheduler-ordering tests).  A
    /// million-stream drain must not retain every id, so the log stops
    /// growing here; FCFS-order violations are still counted exactly by
    /// the O(1) streaming `SchedCounters::out_of_order_completions`.
    pub completion_log_cap: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            max_concurrency: 4,
            policy: "round-robin".to_string(),
            // one knob: the serving engine's per-token decode wall
            token_compute_us: CacheConfig::default().overlap_decode_us,
            prefill_us_per_token: 3_000.0,
            completion_log_cap: 4_096,
        }
    }
}

impl WorkloadConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_concurrency >= 1, "max_concurrency must be >= 1");
        ensure!(self.token_compute_us >= 0.0, "negative token compute");
        ensure!(self.prefill_us_per_token >= 0.0, "negative prefill cost");
        // SchedPolicy is the single source of truth for policy names
        ensure!(
            crate::workload::SchedPolicy::parse(&self.policy).is_some(),
            "unknown scheduler policy {}",
            self.policy
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        CacheConfig::default().validate().unwrap();
        EamConfig::default().validate().unwrap();
        SimConfig::default().validate().unwrap();
        ServeConfig::default().validate().unwrap();
        TierConfig::default().validate().unwrap();
        WorkloadConfig::default().validate().unwrap();
    }

    #[test]
    fn workload_and_batch_window_bounds() {
        let mut w = WorkloadConfig::default();
        w.policy = "magic".into();
        assert!(w.validate().is_err());
        let mut w = WorkloadConfig::default();
        w.max_concurrency = 0;
        assert!(w.validate().is_err());
        let mut s = ServeConfig::default();
        s.batch_window_ms = 5_000;
        assert!(s.validate().is_err());
        s.batch_window_ms = 0; // disabling the wait is legal
        s.validate().unwrap();
    }

    #[test]
    fn overlap_window_divides_by_layers() {
        let c = CacheConfig::default();
        assert!((c.overlap_per_layer(27) - 30_000.0 / 27.0).abs() < 1e-9);
        assert!(c.overlap_per_layer(0).is_finite()); // clamped divisor
    }

    #[test]
    fn tier_config_builders_and_ordering() {
        let t = TierConfig::default()
            .with_gpu_capacity(86)
            .with_host_capacity(864)
            .with_deepest_fetch_us(44_000.0);
        t.validate().unwrap();
        assert_eq!(t.tiers[0].capacity_experts, 86);
        assert_eq!(t.tiers[1].capacity_experts, 864);
        assert_eq!(t.tiers[2].fetch_us_per_expert, 44_000.0);

        // a "slow" tier above a faster one is a config bug
        let bad = TierConfig::default().with_deepest_fetch_us(1.0);
        assert!(bad.validate().is_err());
        let bad = TierConfig::default().with_policy("magic");
        assert!(bad.validate().is_err());
    }

    #[test]
    fn capacity_frac() {
        let c = CacheConfig::default().with_capacity_frac(0.10, 27, 64);
        assert_eq!(c.capacity_experts, 173); // round(1728 * 0.1)
        let c = CacheConfig::default().with_capacity_frac(0.0, 27, 64);
        assert_eq!(c.capacity_experts, 1); // clamped to at least 1
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(CacheConfig::default().with_capacity(0).validate().is_err());
        let mut s = ServeConfig::default();
        s.predictor = "magic".into();
        assert!(s.validate().is_err());
        let mut sim = SimConfig::default();
        sim.predict_top_k = 0;
        assert!(sim.validate().is_err());
    }

}
