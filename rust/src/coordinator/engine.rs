//! The model engine: a single-owner decode loop over the PJRT backbone
//! with predictor-driven expert prefetch.
//!
//! One engine == one edge accelerator.  All xla handles live here (they
//! are not Send); the async front-end talks to it over channels
//! (`server.rs`).  Per decoded token the engine:
//!
//! 1. refreshes the learned predictor every `predictor_stride` tokens
//!    (one batched PJRT call covering all 27 layers),
//! 2. prefetches the predicted per-layer expert sets into the cache
//!    manager (modeled PCIe DMA, overlapped per layer),
//! 3. runs the backbone decode step (real HLO compute),
//! 4. reconciles the router's actual expert ids against the cache
//!    (hit/miss accounting) and feeds observers (EAM partial sketches),
//! 5. samples the next token.

use std::time::Instant;

use crate::config::{Artifacts, CacheConfig, EamConfig, ServeConfig, SimConfig, TierConfig};
use crate::coordinator::expert_state::ExpertCacheManager;
use crate::coordinator::request::{GenStats, Request, Response};
use crate::coordinator::session::Session;
use crate::memory;
use crate::moe::{sample_token, Backbone};
use crate::predictor::{
    factory, DecodeContext, ExpertPredictor, LearnedModel, PredictorKind, PredictorParams,
};
use crate::runtime::PjrtRuntime;
use crate::trace::PromptTrace;
use crate::util::{ExpertSet, Rng};
use crate::Result;

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub serve: ServeConfig,
    pub cache: CacheConfig,
    pub sim: SimConfig,
    pub eam: EamConfig,
    /// Cache policy name ("lru" | "lfu").
    pub policy: String,
    /// Opt-in tiered expert memory (GPU ↔ host ↔ SSD); `None` keeps the
    /// flat VRAM model.
    pub tier: Option<TierConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            serve: ServeConfig::default(),
            cache: CacheConfig::default(),
            sim: SimConfig::default(),
            eam: EamConfig::default(),
            policy: "lru".into(),
            tier: None,
        }
    }
}

enum EnginePredictor {
    Learned(LearnedModel),
    Heuristic(Box<dyn ExpertPredictor>),
    None,
}

pub struct ModelEngine {
    backbone: Backbone,
    predictor: EnginePredictor,
    cache_mgr: ExpertCacheManager,
    cfg: EngineConfig,
    rng: Rng,
    /// Empty trace handed to heuristic predictors (they only use
    /// observe/predict state, never the trace contents).
    dummy_trace: PromptTrace,
    /// Set when a requested learned predictor failed to load and the
    /// engine degraded to the EAM heuristic instead of refusing to
    /// serve (see [`ModelEngine::predictor_fell_back`]).
    predictor_fallback: bool,
}

/// One in-flight decode stream (session + accounting + cached predictions).
struct Stream {
    sess: Session,
    stats: GenStats,
    logits: Vec<f32>,
    pred_sets: Vec<ExpertSet>,
    started: Instant,
    /// VRAM-model baseline at request start (per-request modeled time).
    vram_mark: (f64, f64),
    /// Device-resident KV state (threads between decode calls).
    decode: crate::moe::DecodeSession,
}

impl ModelEngine {
    /// Build an engine from artifacts (loads backbone + chosen predictor).
    pub fn load(rt: &PjrtRuntime, arts: &Artifacts, cfg: EngineConfig) -> Result<Self> {
        cfg.serve.validate()?;
        cfg.cache.validate()?;
        cfg.sim.validate()?;
        let backbone = Backbone::load(rt, arts)?;
        let w = &arts.world;
        let (n_layers, n_experts) = (w.n_layers as usize, w.n_experts as usize);
        // The serving engine is pinned to the single-word fast path (wide
        // worlds are sim-only; see `for_expert_width!` in the sim CLI).
        anyhow::ensure!(
            n_experts <= 64,
            "serving engine is single-word (<= 64 experts); world has {n_experts} — \
             wide worlds run through the simulator paths"
        );

        let kind = PredictorKind::parse(&cfg.serve.predictor)
            .ok_or_else(|| anyhow::anyhow!("unknown predictor {}", cfg.serve.predictor))?;
        let heuristic = |k: PredictorKind| -> Result<EnginePredictor> {
            Ok(EnginePredictor::Heuristic(factory::build(
                k,
                &PredictorParams {
                    eam: &cfg.eam,
                    predict_top_k: cfg.sim.predict_top_k,
                    n_layers,
                    n_experts,
                    // online serving fits through the observers instead
                    fit_traces: &[],
                },
            )?))
        };
        let mut predictor_fallback = false;
        let predictor = match kind {
            // A broken/missing learned artifact degrades to the EAM
            // heuristic instead of refusing to serve: prefetch quality
            // drops, availability does not.  The fallback is visible via
            // `predictor_fell_back` and the coordinator's
            // `serving_predictor_fallbacks` counter.
            PredictorKind::Learned => match LearnedModel::load(rt, arts) {
                Ok(m) => EnginePredictor::Learned(m),
                Err(e) => {
                    eprintln!(
                        "warning: learned predictor failed to load ({e:#}); \
                         serving with the EAM heuristic predictor instead"
                    );
                    predictor_fallback = true;
                    heuristic(PredictorKind::Eam)?
                }
            },
            PredictorKind::None => EnginePredictor::None,
            PredictorKind::Oracle => {
                anyhow::bail!("predictor oracle not servable (oracle is sim-only)")
            }
            k => heuristic(k)?,
        };

        // overlap budget: one layer's decode compute hides this much DMA
        // (the per-token decode wall is a validated CacheConfig knob).
        // memory::build threads the engine's REAL SimConfig (its
        // prefetch_budget), so sim and serve cannot drift.
        let overlap_us = cfg.cache.overlap_per_layer(n_layers);
        let cache_mgr = ExpertCacheManager::from_memory(memory::build(
            &cfg.policy,
            &cfg.cache,
            cfg.tier.as_ref(),
            &cfg.sim,
            n_experts,
            overlap_us,
        )?);

        let n_layers_u16 = w.n_layers;
        Ok(Self {
            backbone,
            predictor,
            cache_mgr,
            cfg,
            rng: Rng::new(0x5EED),
            dummy_trace: PromptTrace {
                prompt_id: 0,
                n_layers: n_layers_u16,
                top_k: w.top_k,
                d_emb: 0,
                tokens: vec![],
                embeddings: vec![],
                experts: vec![],
            },
            predictor_fallback,
        })
    }

    /// Whether a requested learned predictor failed to load and this
    /// engine is serving on the EAM heuristic fallback instead.
    pub fn predictor_fell_back(&self) -> bool {
        self.predictor_fallback
    }

    pub fn world(&self) -> &crate::config::WorldMeta {
        &self.backbone.world
    }

    /// The engine's validated configuration (the serving front-end reads
    /// the dynamic-batching window from here).
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn predictor_window(&self) -> usize {
        match &self.predictor {
            EnginePredictor::Learned(m) => m.window,
            _ => 32,
        }
    }

    /// Prefill one request into a fresh stream (prompt experts warm the
    /// cache and the heuristic observers).
    fn prefill_stream(&mut self, request: Request) -> Result<Stream> {
        let w = self.backbone.world.clone();
        let (n_layers, d) = (w.n_layers as usize, w.d_model as usize);
        let mut sess = Session::new(request, d, self.predictor_window());
        let mut stats = GenStats::default();
        let started = Instant::now();
        let vram_mark = self.cache_mgr.begin_request();

        if let EnginePredictor::Heuristic(p) = &mut self.predictor {
            p.begin_prompt(&self.dummy_trace);
        }

        let td = Instant::now();
        let pre = self.backbone.prefill(&sess.request.prompt)?;
        stats.decode_time += td.elapsed();
        let n_prompt = sess.request.prompt.len().min(w.max_seq as usize);
        for pos in 0..n_prompt {
            sess.push_embedding(&pre.embeddings[pos * d..(pos + 1) * d]);
            for l in 0..n_layers {
                let ids = self.backbone.prefill_router_ids(&pre, l, pos);
                let set = ExpertSet::from_ids(ids.iter().map(|&e| e as u8));
                self.cache_mgr.observe_actual(l, set, &mut stats);
                if let EnginePredictor::Heuristic(p) = &mut self.predictor {
                    let ctx = DecodeContext {
                        trace: &self.dummy_trace,
                        t: 0,
                    };
                    p.observe(&ctx, l, set);
                }
            }
        }
        let decode = self.backbone.start_decode(&pre.kv)?;
        sess.pos = n_prompt;
        Ok(Stream {
            sess,
            stats,
            logits: pre.logits,
            pred_sets: vec![ExpertSet::EMPTY; n_layers],
            started,
            vram_mark,
            decode,
        })
    }

    /// Decode exactly one token on a stream: predict → prefetch → execute
    /// → reconcile → sample.
    fn step_stream(&mut self, s: &mut Stream) -> Result<()> {
        let w = self.backbone.world.clone();
        let n_layers = w.n_layers as usize;
        let next = sample_token(&s.logits, s.sess.request.temperature, &mut self.rng);

        // 1) predictions
        match &mut self.predictor {
            EnginePredictor::Learned(model) => {
                if s.sess.since_refresh >= self.cfg.sim.predictor_stride {
                    let tp = Instant::now();
                    let (emb, n_real) = s.sess.window();
                    if n_real > 0 {
                        let layers: Vec<usize> = (0..n_layers).collect();
                        let lg = model.predict_window(emb, n_real, &layers)?;
                        let e_n = model.n_experts;
                        for (li, set) in s.pred_sets.iter_mut().enumerate() {
                            let base = (li * n_real + (n_real - 1)) * e_n;
                            *set = model
                                .top_set(&lg[base..base + e_n], self.cfg.sim.predict_top_k);
                        }
                    }
                    s.sess.since_refresh = 0;
                    s.stats.predict_time += tp.elapsed();
                }
            }
            EnginePredictor::Heuristic(p) => {
                let ctx = DecodeContext {
                    trace: &self.dummy_trace,
                    t: 0,
                };
                // one batched call per decode step (the replay engines
                // use the same timing)
                p.predict_layers(&ctx, 0..n_layers, &mut s.pred_sets);
            }
            EnginePredictor::None => {}
        }

        // 2) prefetch (one layer ahead of execution)
        if !matches!(self.predictor, EnginePredictor::None) {
            for l in 0..n_layers {
                self.cache_mgr.prefetch(l, s.pred_sets[l], &mut s.stats);
            }
        }

        // 3) execute the decode step (KV stays device-resident)
        let td = Instant::now();
        let dec = self.backbone.decode_chained(&mut s.decode, s.sess.pos, next)?;
        s.stats.decode_time += td.elapsed();

        // 4) reconcile actual router decisions
        for l in 0..n_layers {
            let ids = &dec.router_ids[l * w.top_k as usize..(l + 1) * w.top_k as usize];
            let set = ExpertSet::from_ids(ids.iter().map(|&e| e as u8));
            self.cache_mgr.observe_phase(l, set, &mut s.stats, true);
            if let EnginePredictor::Heuristic(p) = &mut self.predictor {
                let ctx = DecodeContext {
                    trace: &self.dummy_trace,
                    t: 0,
                };
                p.observe(&ctx, l, set);
            }
        }

        // 5) advance
        s.sess.push_embedding(&dec.embedding);
        s.sess.generated.push(next);
        s.sess.pos += 1;
        s.sess.since_refresh = s.sess.since_refresh.saturating_add(1);
        s.logits = dec.logits;
        Ok(())
    }

    fn finish_stream(&mut self, mut s: Stream) -> Response {
        if let EnginePredictor::Heuristic(p) = &mut self.predictor {
            p.end_prompt(&self.dummy_trace);
        }
        self.cache_mgr.finish_from(s.vram_mark, &mut s.stats);
        s.stats.wall = s.started.elapsed();
        Response {
            id: s.sess.request.id,
            tokens: s.sess.generated,
            stats: s.stats,
        }
    }

    /// Serve one request start-to-finish (batch size 1, the paper's
    /// operating point).
    pub fn process(&mut self, request: Request) -> Result<Response> {
        let max_seq = self.backbone.world.max_seq as usize;
        let mut stream = self.prefill_stream(request)?;
        while !stream.sess.done() && stream.sess.remaining_positions(max_seq) > 0 {
            self.step_stream(&mut stream)?;
        }
        Ok(self.finish_stream(stream))
    }

    /// Token-interleaved micro-batching (paper §5 first limitation): all
    /// streams share the expert cache and the heuristic observers, so
    /// their activation streams superpose — the ablation bench measures
    /// the resulting hit-rate collapse.
    pub fn process_batch(&mut self, requests: Vec<Request>) -> Result<Vec<Response>> {
        // merged decoding computes each layer once for the whole batch, so
        // the per-layer prefetch DMA window is SHARED: each stream gets
        // 1/B of it — the §5 hit-rate collapse under micro-batching.
        // The share MUST be restored on every exit path: a `?` that
        // skipped `set_batch_share(1)` would corrupt the next request's
        // prefetch window.
        self.cache_mgr.set_batch_share(requests.len());
        let out = self.process_batch_inner(requests);
        self.cache_mgr.set_batch_share(1);
        out
    }

    fn process_batch_inner(&mut self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let max_seq = self.backbone.world.max_seq as usize;
        let mut streams = Vec::with_capacity(requests.len());
        for r in requests {
            streams.push(Some(self.prefill_stream(r)?));
        }
        loop {
            let mut progressed = false;
            for slot in streams.iter_mut() {
                if let Some(s) = slot {
                    if !s.sess.done() && s.sess.remaining_positions(max_seq) > 0 {
                        self.step_stream(s)?;
                        progressed = true;
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        Ok(streams
            .into_iter()
            .map(|s| self.finish_stream(s.unwrap()))
            .collect())
    }

    /// Reset cache residency between experiments.
    pub fn reset_cache(&mut self) {
        self.cache_mgr.clear();
    }

    /// Per-tier serve counters (None unless tiered mode is configured).
    pub fn tier_stats(&self) -> Option<&crate::tier::TierStats> {
        self.cache_mgr.tier_stats()
    }
}
