//! Expert residency manager for the serving path: one cache backend (flat
//! VRAM or the tiered GPU↔host↔SSD hierarchy) + the transfer-cost model +
//! per-request accounting, shared by every predictor kind.

use crate::cache::{policy, CachePolicy, VramModel};
use crate::config::{CacheConfig, SimConfig, TierConfig};
use crate::coordinator::request::GenStats;
use crate::tier::{TierCostModel, TierStats, TieredCache};
use crate::util::ExpertSet;

/// The residency/cost backend: the seed's flat VRAM model, or the
/// opt-in tiered hierarchy (see [`crate::tier`]).
enum Backend {
    Flat {
        cache: Box<dyn CachePolicy>,
        vram: VramModel,
    },
    Tiered {
        cache: TieredCache,
        cost: TierCostModel,
        stats: TierStats,
    },
}

pub struct ExpertCacheManager {
    backend: Backend,
    n_experts: usize,
    /// Max DMA transfers that can land within one layer's compute window.
    prefetch_budget: usize,
    base_budget: usize,
}

impl ExpertCacheManager {
    pub fn new(
        cache: Box<dyn CachePolicy>,
        cfg: CacheConfig,
        n_experts: usize,
        overlap_budget_us: f64,
    ) -> Self {
        // sim and serve share one knob: the SimConfig default, overridable
        // via with_prefetch_budget
        let budget = SimConfig::default().prefetch_budget;
        Self {
            backend: Backend::Flat {
                cache,
                vram: VramModel::new(cfg, overlap_budget_us),
            },
            n_experts,
            prefetch_budget: budget,
            base_budget: budget,
        }
    }

    /// Tiered mode: expert weights staged across GPU VRAM, host RAM and
    /// SSD with promotion on miss and demotion on eviction.
    pub fn new_tiered(
        cfg: &TierConfig,
        n_experts: usize,
        overlap_budget_us: f64,
    ) -> crate::Result<Self> {
        cfg.validate()?;
        let budget = SimConfig::default().prefetch_budget;
        Ok(Self {
            backend: Backend::Tiered {
                cache: TieredCache::build(&cfg.policy, &cfg.tiers)?,
                cost: TierCostModel::new(cfg.tiers.clone(), overlap_budget_us),
                stats: TierStats::new(cfg.tiers.len()),
            },
            n_experts,
            prefetch_budget: budget,
            base_budget: budget,
        })
    }

    pub fn with_prefetch_budget(mut self, budget: usize) -> Self {
        self.prefetch_budget = budget.max(1);
        self.base_budget = self.prefetch_budget;
        self
    }

    /// Micro-batching shares the per-layer DMA window across the batch
    /// (each layer computes once for all streams, so its prefetch window
    /// is divided): effective budget = base / batch (paper §5 ablation).
    pub fn set_batch_share(&mut self, batch: usize) {
        self.prefetch_budget = (self.base_budget / batch.max(1)).max(1);
    }

    /// The currently effective per-layer DMA budget (observable so the
    /// engine's restore-after-error guarantee is testable).
    pub fn effective_prefetch_budget(&self) -> usize {
        self.prefetch_budget
    }

    /// Prefetch a predicted set for `layer` (issued before the layer runs;
    /// DMA overlaps the previous layer's compute up to the budget).
    pub fn prefetch(&mut self, layer: usize, predicted: ExpertSet, stats: &mut GenStats) {
        let mut landed = 0usize;
        for e in predicted.iter() {
            let k = policy::key(layer, e, self.n_experts);
            stats.prefetches += 1;
            match &mut self.backend {
                Backend::Flat { cache, vram } => {
                    if cache.contains(k) {
                        cache.touch(k);
                        continue;
                    }
                    if landed >= self.prefetch_budget {
                        continue; // DMA window exhausted: arrives too late
                    }
                    landed += 1;
                    vram.on_prefetch();
                    cache.insert(k);
                }
                Backend::Tiered {
                    cache,
                    cost,
                    stats: ts,
                } => {
                    if cache.locate(k) == Some(0) {
                        cache.touch(k);
                        continue;
                    }
                    if landed >= self.prefetch_budget {
                        continue;
                    }
                    landed += 1;
                    let deepest = cache.deepest();
                    let promo = cache.promote(k);
                    cost.on_prefetch(promo.found.unwrap_or(deepest));
                    ts.prefetch_promotions += 1;
                    cost.charge_demotions(ts, &promo);
                }
            }
        }
    }

    /// Account the ground-truth experts of an executed layer.
    /// `decode_phase` additionally feeds the decode-only counters.
    pub fn observe_actual(&mut self, layer: usize, actual: ExpertSet, stats: &mut GenStats) {
        self.observe_phase(layer, actual, stats, false)
    }

    pub fn observe_phase(
        &mut self,
        layer: usize,
        actual: ExpertSet,
        stats: &mut GenStats,
        decode_phase: bool,
    ) {
        for e in actual.iter() {
            let k = policy::key(layer, e, self.n_experts);
            let hit = match &mut self.backend {
                Backend::Flat { cache, vram } => {
                    if cache.touch(k) {
                        vram.on_hit();
                        true
                    } else {
                        vram.on_demand_miss();
                        cache.insert(k);
                        false
                    }
                }
                Backend::Tiered {
                    cache,
                    cost,
                    stats: ts,
                } => {
                    if cache.locate(k) == Some(0) {
                        cache.touch(k);
                        ts.record_served(0);
                        cost.on_hit();
                        true
                    } else {
                        // a miss in the GPU sense: promote from wherever
                        // the expert was staged, charging the deepest
                        // tier actually reached
                        let deepest = cache.deepest();
                        let promo = cache.promote(k);
                        match promo.found {
                            Some(d) => ts.record_served(d),
                            None => ts.cold += 1,
                        }
                        cost.on_demand_fetch(promo.found.unwrap_or(deepest));
                        ts.promotions += 1;
                        cost.charge_demotions(ts, &promo);
                        false
                    }
                }
            };
            if hit {
                stats.cache_hits += 1;
                if decode_phase {
                    stats.decode_cache_hits += 1;
                }
            } else {
                stats.cache_misses += 1;
                if decode_phase {
                    stats.decode_cache_misses += 1;
                }
            }
        }
        match &mut self.backend {
            Backend::Flat { vram, .. } => vram.end_layer(),
            Backend::Tiered { cost, .. } => cost.end_layer(),
        }
    }

    /// Mark the start of a request (baseline for per-request modeled time).
    pub fn begin_request(&mut self) -> (f64, f64) {
        match &self.backend {
            Backend::Flat { vram, .. } => (vram.demand_us, vram.stall_us),
            Backend::Tiered { cost, .. } => (cost.demand_total(), cost.stall_total()),
        }
    }

    /// Snapshot per-request modeled time into the stats (request end).
    pub fn finish_from(&mut self, mark: (f64, f64), stats: &mut GenStats) {
        let (demand, stall) = match &self.backend {
            Backend::Flat { vram, .. } => (vram.demand_us, vram.stall_us),
            Backend::Tiered { cost, .. } => (cost.demand_total(), cost.stall_total()),
        };
        stats.modeled_miss_us = demand - mark.0;
        stats.modeled_stall_us = stall - mark.1;
    }

    /// Snapshot cumulative modeled time into the stats.
    pub fn finish(&mut self, stats: &mut GenStats) {
        self.finish_from((0.0, 0.0), stats)
    }

    /// Experts resident in GPU VRAM (tier 0 in tiered mode).
    pub fn resident_count(&self) -> usize {
        match &self.backend {
            Backend::Flat { cache, .. } => cache.len(),
            Backend::Tiered { cache, .. } => cache.len_at(0),
        }
    }

    /// Per-tier serve counters (None on the flat backend).
    pub fn tier_stats(&self) -> Option<&TierStats> {
        match &self.backend {
            Backend::Flat { .. } => None,
            Backend::Tiered { stats, .. } => Some(stats),
        }
    }

    pub fn clear(&mut self) {
        match &mut self.backend {
            Backend::Flat { cache, .. } => cache.clear(),
            Backend::Tiered { cache, .. } => cache.clear(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::LruCache;
    use crate::tier::TierSpec;

    fn mgr(cap: usize) -> ExpertCacheManager {
        ExpertCacheManager::new(
            Box::new(LruCache::new(cap)),
            CacheConfig {
                capacity_experts: cap,
                pcie_us_per_expert: 100.0,
                hit_us: 1.0,
                ..Default::default()
            },
            64,
            1000.0,
        )
    }

    fn tiered_mgr(gpu: usize, host: usize) -> ExpertCacheManager {
        let cfg = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", gpu, 1.0, 0.0),
                TierSpec::new("host", host, 100.0, 100.0),
                TierSpec::new("ssd", 1728, 1000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        ExpertCacheManager::new_tiered(&cfg, 64, 1000.0).unwrap()
    }

    #[test]
    fn prefetched_experts_hit() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        let set = ExpertSet::from_ids([1u8, 2, 3]);
        m.prefetch(0, set, &mut stats);
        m.observe_actual(0, set, &mut stats);
        assert_eq!(stats.cache_hits, 3);
        assert_eq!(stats.cache_misses, 0);
        assert_eq!(stats.prefetches, 3);
    }

    #[test]
    fn unprefetched_experts_miss_and_cost_pcie() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        m.observe_actual(5, ExpertSet::from_ids([9u8]), &mut stats);
        m.finish(&mut stats);
        assert_eq!(stats.cache_misses, 1);
        assert!(stats.modeled_miss_us >= 100.0);
    }

    #[test]
    fn keys_are_layer_scoped() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        m.prefetch(0, ExpertSet::from_ids([7u8]), &mut stats);
        // same expert id at a different layer is NOT resident
        m.observe_actual(1, ExpertSet::from_ids([7u8]), &mut stats);
        assert_eq!(stats.cache_misses, 1);
    }

    #[test]
    fn default_budget_comes_from_sim_config() {
        let m = mgr(16);
        assert_eq!(
            m.effective_prefetch_budget(),
            SimConfig::default().prefetch_budget
        );
    }

    /// `set_batch_share(1)` must restore the full window no matter what
    /// share was in effect — the engine relies on this on error paths.
    #[test]
    fn batch_share_restores_after_any_division() {
        let mut m = mgr(16).with_prefetch_budget(12);
        assert_eq!(m.effective_prefetch_budget(), 12);
        m.set_batch_share(4);
        assert_eq!(m.effective_prefetch_budget(), 3);
        m.set_batch_share(1);
        assert_eq!(m.effective_prefetch_budget(), 12);
        // degenerate shares clamp instead of zeroing the window
        m.set_batch_share(100);
        assert_eq!(m.effective_prefetch_budget(), 1);
        m.set_batch_share(0);
        assert_eq!(m.effective_prefetch_budget(), 12);
    }

    #[test]
    fn batch_share_limits_landed_prefetches() {
        let mut m = mgr(16).with_prefetch_budget(8);
        m.set_batch_share(4); // effective window: 2 transfers
        let mut stats = GenStats::default();
        m.prefetch(0, ExpertSet::from_ids([1u8, 2, 3, 4, 5]), &mut stats);
        assert_eq!(stats.prefetches, 5); // all issued ...
        assert_eq!(m.resident_count(), 2); // ... but only 2 land
    }

    #[test]
    fn tiered_miss_promotes_and_demotes() {
        let mut m = tiered_mgr(2, 4);
        let mut stats = GenStats::default();
        // fill the 2-expert GPU tier, then miss a third: the LRU victim
        // must fall to host instead of vanishing
        m.observe_actual(0, ExpertSet::from_ids([1u8, 2, 3]), &mut stats);
        assert_eq!(stats.cache_misses, 3);
        assert_eq!(m.resident_count(), 2);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.cold, 3);
        assert_eq!(ts.demotions, 1);
        // a host hit costs 100µs, not the 1000µs cold read
        m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.served[1], 1);
        m.finish(&mut stats);
        assert!((stats.modeled_miss_us - (3.0 * 1000.0 + 100.0)).abs() < 1e-9);
    }

    #[test]
    fn tiered_prefetch_from_host_is_cheap() {
        let mut m = tiered_mgr(1, 4);
        let mut stats = GenStats::default();
        // 1 lands in GPU, then gets demoted by the next
        m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
        m.observe_actual(0, ExpertSet::from_ids([2u8]), &mut stats);
        // prefetching 1 back promotes from host
        m.prefetch(0, ExpertSet::from_ids([1u8]), &mut stats);
        m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
        assert_eq!(stats.cache_hits, 1);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.prefetch_promotions, 1);
    }
}
