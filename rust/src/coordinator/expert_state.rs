//! Expert residency for the serving path — a thin shim translating the
//! engine's per-request accounting ([`GenStats`]) onto the unified
//! [`ExpertMemory`] contract.  All flat-vs-tiered dispatch lives in
//! [`crate::memory`]; this file no longer contains a backend branch.

use crate::cache::CachePolicy;
use crate::config::{CacheConfig, SimConfig, TierConfig};
use crate::coordinator::request::GenStats;
use crate::memory::{self, ExpertMemory, FlatMemory, TieredMemory};
use crate::tier::TierStats;
use crate::util::ExpertSet;

/// `N` is the set word-width ([`ExpertSet<N>`]); the serving engine pins
/// `N = 1` (≤ 64 experts), wide worlds thread their width through here
/// unchanged.
pub struct ExpertCacheManager<const N: usize = 1> {
    memory: Box<dyn ExpertMemory<N>>,
}

impl<const N: usize> ExpertCacheManager<N> {
    /// Wrap a pre-built residency backend (the engine builds one via
    /// [`memory::build`] from its real config — see
    /// [`crate::coordinator::ModelEngine::load`]).
    pub fn from_memory(memory: Box<dyn ExpertMemory<N>>) -> Self {
        Self { memory }
    }

    /// Flat backend from parts.  The DMA budget comes from the caller's
    /// `SimConfig` (no silent default — the sim-vs-serve drift trap this
    /// signature used to carry); the engine path builds via
    /// [`memory::build`] instead.
    pub fn new(
        cache: Box<dyn CachePolicy>,
        cfg: CacheConfig,
        sim: &SimConfig,
        n_experts: usize,
        overlap_budget_us: f64,
    ) -> Self {
        Self::from_memory(Box::new(FlatMemory::<N>::new(
            cache,
            cfg,
            n_experts,
            sim.prefetch_budget,
            overlap_budget_us,
        )))
    }

    /// Tiered mode: expert weights staged across GPU VRAM, host RAM and
    /// SSD with promotion on miss and demotion on eviction.
    pub fn new_tiered(
        cfg: &TierConfig,
        sim: &SimConfig,
        n_experts: usize,
        overlap_budget_us: f64,
    ) -> crate::Result<Self> {
        Ok(Self::from_memory(Box::new(TieredMemory::<N>::new(
            cfg,
            n_experts,
            sim.prefetch_budget,
            overlap_budget_us,
        )?)))
    }

    pub fn with_prefetch_budget(mut self, budget: usize) -> Self {
        self.memory.set_prefetch_budget(budget);
        self
    }

    /// Micro-batching shares the per-layer DMA window across the batch
    /// (each layer computes once for all streams, so its prefetch window
    /// is divided): effective budget = base / batch (paper §5 ablation).
    pub fn set_batch_share(&mut self, batch: usize) {
        self.memory.set_batch_share(batch);
    }

    /// The currently effective per-layer DMA budget (observable so the
    /// engine's restore-after-error guarantee is testable).
    pub fn effective_prefetch_budget(&self) -> usize {
        self.memory.effective_prefetch_budget()
    }

    /// Prefetch a predicted set for `layer` (issued before the layer runs;
    /// DMA overlaps the previous layer's compute up to the budget).
    pub fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>, stats: &mut GenStats) {
        let pf = self.memory.prefetch(layer, predicted);
        stats.prefetches += pf.issued;
    }

    /// Account the ground-truth experts of an executed layer.
    /// `decode_phase` additionally feeds the decode-only counters.
    pub fn observe_actual(&mut self, layer: usize, actual: ExpertSet<N>, stats: &mut GenStats) {
        self.observe_phase(layer, actual, stats, false)
    }

    pub fn observe_phase(
        &mut self,
        layer: usize,
        actual: ExpertSet<N>,
        stats: &mut GenStats,
        decode_phase: bool,
    ) {
        // one set-level lookup for the whole layer (same residency/cost
        // mutations as ascending-id scalar lookups — see ExpertMemory)
        let batch = self.memory.lookup_set(layer, actual, true);
        let hits = batch.hits.len() as u64;
        let misses = actual.len() as u64 - hits;
        stats.cache_hits += hits;
        stats.cache_misses += misses;
        if decode_phase {
            stats.decode_cache_hits += hits;
            stats.decode_cache_misses += misses;
        }
        self.memory.end_layer();
    }

    /// Mark the start of a request (baseline for per-request modeled time).
    pub fn begin_request(&mut self) -> (f64, f64) {
        self.memory.cost_marks()
    }

    /// Snapshot per-request modeled time into the stats (request end).
    pub fn finish_from(&mut self, mark: (f64, f64), stats: &mut GenStats) {
        let (demand, stall) = self.memory.cost_marks();
        stats.modeled_miss_us = demand - mark.0;
        stats.modeled_stall_us = stall - mark.1;
    }

    /// Snapshot cumulative modeled time into the stats.
    pub fn finish(&mut self, stats: &mut GenStats) {
        self.finish_from((0.0, 0.0), stats)
    }

    /// Experts resident in GPU VRAM (tier 0 in tiered mode).
    pub fn resident_count(&self) -> usize {
        self.memory.resident_count()
    }

    /// Per-tier serve counters (None on the flat backend).
    pub fn tier_stats(&self) -> Option<&TierStats> {
        self.memory.tier_stats()
    }

    /// Unified residency/cost snapshot of the underlying backend.
    pub fn memory_stats(&self) -> memory::MemoryStats {
        self.memory.stats()
    }

    pub fn clear(&mut self) {
        self.memory.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::LruCache;
    use crate::tier::TierSpec;

    fn mgr(cap: usize) -> ExpertCacheManager {
        ExpertCacheManager::new(
            Box::new(LruCache::new(cap)),
            CacheConfig {
                capacity_experts: cap,
                pcie_us_per_expert: 100.0,
                hit_us: 1.0,
                ..Default::default()
            },
            &SimConfig::default(),
            64,
            1000.0,
        )
    }

    fn tiered_mgr(gpu: usize, host: usize) -> ExpertCacheManager {
        let cfg = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", gpu, 1.0, 0.0),
                TierSpec::new("host", host, 100.0, 100.0),
                TierSpec::new("ssd", 1728, 1000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        ExpertCacheManager::new_tiered(&cfg, &SimConfig::default(), 64, 1000.0).unwrap()
    }

    #[test]
    fn prefetched_experts_hit() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        let set = ExpertSet::from_ids([1u8, 2, 3]);
        m.prefetch(0, set, &mut stats);
        m.observe_actual(0, set, &mut stats);
        assert_eq!(stats.cache_hits, 3);
        assert_eq!(stats.cache_misses, 0);
        assert_eq!(stats.prefetches, 3);
    }

    #[test]
    fn unprefetched_experts_miss_and_cost_pcie() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        m.observe_actual(5, ExpertSet::from_ids([9u8]), &mut stats);
        m.finish(&mut stats);
        assert_eq!(stats.cache_misses, 1);
        assert!(stats.modeled_miss_us >= 100.0);
    }

    #[test]
    fn keys_are_layer_scoped() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        m.prefetch(0, ExpertSet::from_ids([7u8]), &mut stats);
        // same expert id at a different layer is NOT resident
        m.observe_actual(1, ExpertSet::from_ids([7u8]), &mut stats);
        assert_eq!(stats.cache_misses, 1);
    }

    #[test]
    fn budget_comes_from_the_callers_sim_config() {
        // the default grabs the shared knob ...
        let m = mgr(16);
        assert_eq!(
            m.effective_prefetch_budget(),
            SimConfig::default().prefetch_budget
        );
        // ... and a custom SimConfig is honored, not silently replaced
        // by the default (the old drift bug)
        let sim = SimConfig {
            prefetch_budget: 3,
            ..Default::default()
        };
        let m = ExpertCacheManager::new(
            Box::new(LruCache::new(16)),
            CacheConfig::default(),
            &sim,
            64,
            1000.0,
        );
        assert_eq!(m.effective_prefetch_budget(), 3);
    }

    /// `set_batch_share(1)` must restore the full window no matter what
    /// share was in effect — the engine relies on this on error paths.
    #[test]
    fn batch_share_restores_after_any_division() {
        let mut m = mgr(16).with_prefetch_budget(12);
        assert_eq!(m.effective_prefetch_budget(), 12);
        m.set_batch_share(4);
        assert_eq!(m.effective_prefetch_budget(), 3);
        m.set_batch_share(1);
        assert_eq!(m.effective_prefetch_budget(), 12);
        // degenerate shares clamp instead of zeroing the window
        m.set_batch_share(100);
        assert_eq!(m.effective_prefetch_budget(), 1);
        m.set_batch_share(0);
        assert_eq!(m.effective_prefetch_budget(), 12);
    }

    #[test]
    fn batch_share_limits_landed_prefetches() {
        let mut m = mgr(16).with_prefetch_budget(8);
        m.set_batch_share(4); // effective window: 2 transfers
        let mut stats = GenStats::default();
        m.prefetch(0, ExpertSet::from_ids([1u8, 2, 3, 4, 5]), &mut stats);
        assert_eq!(stats.prefetches, 5); // all issued ...
        assert_eq!(m.resident_count(), 2); // ... but only 2 land
    }

    #[test]
    fn tiered_miss_promotes_and_demotes() {
        let mut m = tiered_mgr(2, 4);
        let mut stats = GenStats::default();
        // fill the 2-expert GPU tier, then miss a third: the LRU victim
        // must fall to host instead of vanishing
        m.observe_actual(0, ExpertSet::from_ids([1u8, 2, 3]), &mut stats);
        assert_eq!(stats.cache_misses, 3);
        assert_eq!(m.resident_count(), 2);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.cold, 3);
        assert_eq!(ts.demotions, 1);
        // a host hit costs 100µs, not the 1000µs cold read
        m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.served[1], 1);
        m.finish(&mut stats);
        assert!((stats.modeled_miss_us - (3.0 * 1000.0 + 100.0)).abs() < 1e-9);
    }

    #[test]
    fn tiered_prefetch_from_host_is_cheap() {
        let mut m = tiered_mgr(1, 4);
        let mut stats = GenStats::default();
        // 1 lands in GPU, then gets demoted by the next
        m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
        m.observe_actual(0, ExpertSet::from_ids([2u8]), &mut stats);
        // prefetching 1 back promotes from host
        m.prefetch(0, ExpertSet::from_ids([1u8]), &mut stats);
        m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
        assert_eq!(stats.cache_hits, 1);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.prefetch_promotions, 1);
    }
}
