//! Expert residency manager for the serving path: one cache policy + the
//! VRAM transfer model + per-request accounting, shared by every predictor
//! kind.

use crate::cache::{policy, CachePolicy, VramModel};
use crate::config::CacheConfig;
use crate::coordinator::request::GenStats;
use crate::util::ExpertSet;

pub struct ExpertCacheManager {
    cache: Box<dyn CachePolicy>,
    vram: VramModel,
    n_experts: usize,
    /// Max DMA transfers that can land within one layer's compute window.
    prefetch_budget: usize,
    base_budget: usize,
}

impl ExpertCacheManager {
    pub fn new(
        cache: Box<dyn CachePolicy>,
        cfg: CacheConfig,
        n_experts: usize,
        overlap_budget_us: f64,
    ) -> Self {
        Self {
            cache,
            vram: VramModel::new(cfg, overlap_budget_us),
            n_experts,
            prefetch_budget: 12,
            base_budget: 12,
        }
    }

    pub fn with_prefetch_budget(mut self, budget: usize) -> Self {
        self.prefetch_budget = budget.max(1);
        self.base_budget = self.prefetch_budget;
        self
    }

    /// Micro-batching shares the per-layer DMA window across the batch
    /// (each layer computes once for all streams, so its prefetch window
    /// is divided): effective budget = base / batch (paper §5 ablation).
    pub fn set_batch_share(&mut self, batch: usize) {
        self.prefetch_budget = (self.base_budget / batch.max(1)).max(1);
    }

    /// Prefetch a predicted set for `layer` (issued before the layer runs;
    /// DMA overlaps the previous layer's compute up to the budget).
    pub fn prefetch(&mut self, layer: usize, predicted: ExpertSet, stats: &mut GenStats) {
        let mut landed = 0usize;
        for e in predicted.iter() {
            let k = policy::key(layer, e, self.n_experts);
            stats.prefetches += 1;
            if self.cache.contains(k) {
                self.cache.touch(k);
                continue;
            }
            if landed >= self.prefetch_budget {
                continue; // DMA window exhausted: arrives too late
            }
            landed += 1;
            self.vram.on_prefetch();
            self.cache.insert(k);
        }
    }

    /// Account the ground-truth experts of an executed layer.
    /// `decode_phase` additionally feeds the decode-only counters.
    pub fn observe_actual(&mut self, layer: usize, actual: ExpertSet, stats: &mut GenStats) {
        self.observe_phase(layer, actual, stats, false)
    }

    pub fn observe_phase(
        &mut self,
        layer: usize,
        actual: ExpertSet,
        stats: &mut GenStats,
        decode_phase: bool,
    ) {
        for e in actual.iter() {
            let k = policy::key(layer, e, self.n_experts);
            if self.cache.touch(k) {
                stats.cache_hits += 1;
                if decode_phase {
                    stats.decode_cache_hits += 1;
                }
                self.vram.on_hit();
            } else {
                stats.cache_misses += 1;
                if decode_phase {
                    stats.decode_cache_misses += 1;
                }
                self.vram.on_demand_miss();
                self.cache.insert(k);
            }
        }
        self.vram.end_layer();
    }

    /// Mark the start of a request (baseline for per-request modeled time).
    pub fn begin_request(&mut self) -> (f64, f64) {
        (self.vram.demand_us, self.vram.stall_us)
    }

    /// Snapshot per-request modeled time into the stats (request end).
    pub fn finish_from(&mut self, mark: (f64, f64), stats: &mut GenStats) {
        stats.modeled_miss_us = self.vram.demand_us - mark.0;
        stats.modeled_stall_us = self.vram.stall_us - mark.1;
    }

    /// Snapshot cumulative modeled time into the stats.
    pub fn finish(&mut self, stats: &mut GenStats) {
        stats.modeled_miss_us = self.vram.demand_us;
        stats.modeled_stall_us = self.vram.stall_us;
    }

    pub fn resident_count(&self) -> usize {
        self.cache.len()
    }

    pub fn clear(&mut self) {
        self.cache.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::LruCache;

    fn mgr(cap: usize) -> ExpertCacheManager {
        ExpertCacheManager::new(
            Box::new(LruCache::new(cap)),
            CacheConfig {
                capacity_experts: cap,
                pcie_us_per_expert: 100.0,
                hit_us: 1.0,
                pin_shared: true,
            },
            64,
            1000.0,
        )
    }

    #[test]
    fn prefetched_experts_hit() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        let set = ExpertSet::from_ids([1u8, 2, 3]);
        m.prefetch(0, set, &mut stats);
        m.observe_actual(0, set, &mut stats);
        assert_eq!(stats.cache_hits, 3);
        assert_eq!(stats.cache_misses, 0);
        assert_eq!(stats.prefetches, 3);
    }

    #[test]
    fn unprefetched_experts_miss_and_cost_pcie() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        m.observe_actual(5, ExpertSet::from_ids([9u8]), &mut stats);
        m.finish(&mut stats);
        assert_eq!(stats.cache_misses, 1);
        assert!(stats.modeled_miss_us >= 100.0);
    }

    #[test]
    fn keys_are_layer_scoped() {
        let mut m = mgr(16);
        let mut stats = GenStats::default();
        m.prefetch(0, ExpertSet::from_ids([7u8]), &mut stats);
        // same expert id at a different layer is NOT resident
        m.observe_actual(1, ExpertSet::from_ids([7u8]), &mut stats);
        assert_eq!(stats.cache_misses, 1);
    }
}
