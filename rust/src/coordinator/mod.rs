//! L3 serving coordinator — the edge-serving loop MoE-Beyond plugs into.
//!
//! Architecture (vLLM-router-style, scaled to a single edge device):
//!
//! ```text
//!   clients ──► RequestQueue (tokio mpsc, bounded = admission control)
//!                   │
//!                   ▼
//!            ModelEngine thread (owns ALL PJRT state — xla handles are
//!            not Send, and an edge GPU has one execution stream anyway)
//!                   │  per token: predict ► prefetch ► decode ► account
//!                   ▼
//!            ExpertCacheManager (simulated VRAM residency + PCIe model)
//! ```
//!
//! Python never appears: the engine executes AOT HLO through `runtime`.

mod engine;
mod expert_state;
mod request;
mod server;
mod session;

pub use engine::{EngineConfig, ModelEngine};
pub use expert_state::ExpertCacheManager;
pub use request::{GenStats, Request, Response};
pub use server::{serve_requests, serve_requests_obs, ServeReport};
pub use session::Session;
