//! L3 serving coordinator — the edge-serving loop MoE-Beyond plugs into.
//!
//! Architecture (vLLM-router-style, scaled to a single edge device):
//!
//! ```text
//!   clients ──► RequestQueue (tokio mpsc, bounded = admission control)
//!                   │
//!                   ▼
//!            ModelEngine thread (owns ALL PJRT state — xla handles are
//!            not Send, and an edge GPU has one execution stream anyway)
//!                   │  per token: predict ► prefetch ► decode ► account
//!                   ▼
//!            ExpertCacheManager (simulated VRAM residency + PCIe model)
//! ```
//!
//! Python never appears: the engine executes AOT HLO through `runtime`.
//!
//! Scope: this is the *live* single-device loop, pinned to the default
//! [`crate::util::ExpertSet`] width (≤ 64 experts) and to the
//! single-node [`crate::memory`] backends.  Wider worlds and multi-node
//! topologies are simulation-only today — `serve-sim --nodes K` drives
//! [`crate::cluster`] instead of this module (see `ARCHITECTURE.md` at
//! the repo root for the split and the promotion path).

mod engine;
mod expert_state;
mod request;
mod server;
mod session;

pub use engine::{EngineConfig, ModelEngine};
pub use expert_state::ExpertCacheManager;
pub use request::{GenStats, Request, Response};
pub use server::{serve_requests, serve_requests_obs, ServeReport};
pub use session::Session;
