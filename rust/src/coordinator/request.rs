//! Request/response types for the serving loop.

use std::time::Duration;

/// An inference request (batch-size-1 edge semantics).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub temperature: f64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
        }
    }
}

/// Per-request generation statistics.
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    /// Wall time from admission to completion.
    pub wall: Duration,
    /// Wall time spent inside backbone decode calls.
    pub decode_time: Duration,
    /// Wall time spent inside predictor calls.
    pub predict_time: Duration,
    /// Modeled PCIe time for demand misses (µs, virtual).
    pub modeled_miss_us: f64,
    /// Modeled stall from non-overlapped prefetch (µs, virtual).
    pub modeled_stall_us: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Decode-phase-only subset of the above (prefill warms the cache and
    /// dilutes whole-request rates; the §5 batching ablation needs this).
    pub decode_cache_hits: u64,
    pub decode_cache_misses: u64,
    pub prefetches: u64,
}

impl GenStats {
    pub fn hit_rate(&self) -> f64 {
        let n = self.cache_hits + self.cache_misses;
        if n == 0 {
            0.0
        } else {
            self.cache_hits as f64 / n as f64
        }
    }

    /// Hit rate over generated (decode) tokens only.
    pub fn decode_hit_rate(&self) -> f64 {
        let n = self.decode_cache_hits + self.decode_cache_misses;
        if n == 0 {
            0.0
        } else {
            self.decode_cache_hits as f64 / n as f64
        }
    }
}

/// A completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub stats: GenStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_math() {
        let mut s = GenStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s.cache_hits = 9;
        s.cache_misses = 1;
        assert!((s.hit_rate() - 0.9).abs() < 1e-12);
    }
}
