//! Serving front-end: bounded admission queue (backpressure) feeding the
//! engine on a dedicated OS thread.
//!
//! Implemented on std::sync primitives — this build environment has no
//! async runtime, and the engine is a single execution stream anyway
//! (PJRT handles are not Send; one edge accelerator == one worker).
//! `sync_channel(queue_depth)` gives exactly the bounded-queue admission
//! semantics an async version would have.

use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::engine::ModelEngine;
use crate::coordinator::request::{Request, Response};
use crate::metrics::{LatencyReport, ServingMetrics};
use crate::obs::{ObsSink, TraceEvent};
use crate::Result;

/// Aggregate report of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completed: usize,
    /// Admission-pressure events (submissions that found the queue full
    /// and had to block).
    pub backpressured: usize,
    pub total_tokens: usize,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub requests_per_sec: f64,
    pub cache_hit_rate: f64,
    pub request_latency: LatencyReport,
    pub responses: Vec<Response>,
}

type Job = (Request, mpsc::Sender<Response>);

/// Serve a closed set of requests through an engine built on the worker
/// thread by `make_engine`; returns when all requests completed.
///
/// `queue_depth` bounds the admission queue; `batch_size` > 1 enables the
/// token-interleaved micro-batch path (paper §5 ablation).
pub fn serve_requests<F>(
    make_engine: F,
    requests: Vec<Request>,
    queue_depth: usize,
    batch_size: usize,
) -> Result<ServeReport>
where
    F: FnOnce() -> Result<ModelEngine> + Send + 'static,
{
    serve_requests_obs(make_engine, requests, queue_depth, batch_size, &ObsSink::default())
}

/// [`serve_requests`] with an observability sink: the coordinator's
/// counters and latency histograms register in the sink's metric
/// registry, and submissions/completions emit wall-clock request spans
/// (µs since serve start — the one surface where the clock is real
/// time, so traces from here are NOT run-to-run byte-stable).
pub fn serve_requests_obs<F>(
    make_engine: F,
    requests: Vec<Request>,
    queue_depth: usize,
    batch_size: usize,
    obs: &ObsSink,
) -> Result<ServeReport>
where
    F: FnOnce() -> Result<ModelEngine> + Send + 'static,
{
    let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth.max(1));
    let metrics = std::sync::Arc::new(match obs.registry() {
        Some(reg) => ServingMetrics::registered(reg),
        None => ServingMetrics::default(),
    });

    // ---- engine worker thread
    let worker_metrics = metrics.clone();
    let worker = std::thread::spawn(move || -> Result<()> {
        let mut engine = make_engine()?;
        if engine.predictor_fell_back() {
            // graceful degradation (learned artifact failed to load):
            // surface it on the coordinator's metric set so operators
            // see the quality downgrade, not just a stderr line
            worker_metrics.predictor_fallbacks.inc();
        }
        // dynamic-batching window: wait this long for co-arriving
        // requests before launching the batch (vLLM-style).  A validated
        // ServeConfig knob; 0 launches immediately.
        let window = std::time::Duration::from_millis(engine.config().serve.batch_window_ms);
        let mut pending: Vec<Job> = Vec::new();
        loop {
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => break,
            };
            pending.push(first);
            // drain already-queued co-arrivals unconditionally, then
            // block on the channel with the remaining window instead of
            // a 1 ms sleep-poll: no busy-wait, a late co-arrival is
            // batched the instant it lands, and a backlog fills the
            // batch even with a zero window
            let deadline = std::time::Instant::now() + window;
            while pending.len() < batch_size {
                match rx.try_recv() {
                    Ok(j) => {
                        pending.push(j);
                        continue;
                    }
                    Err(mpsc::TryRecvError::Disconnected) => break,
                    Err(mpsc::TryRecvError::Empty) => {}
                }
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => pending.push(j),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            let (reqs, senders): (Vec<_>, Vec<_>) = pending.drain(..).unzip();
            let responses = if reqs.len() == 1 {
                vec![engine.process(reqs.into_iter().next().unwrap())?]
            } else {
                engine.process_batch(reqs)?
            };
            for (resp, sender) in responses.into_iter().zip(senders) {
                let _ = sender.send(resp);
            }
        }
        Ok(())
    });

    // ---- submit everything, respecting the bounded queue
    let t0 = Instant::now();
    let mut waiters = Vec::new();
    let mut backpressured = 0usize;
    for (rid, req) in requests.into_iter().enumerate() {
        let (otx, orx) = mpsc::channel();
        metrics.requests_admitted.inc();
        obs.set_now_us(t0.elapsed().as_secs_f64() * 1e6);
        obs.emit(|ts| TraceEvent::RequestBegin {
            ts_us: ts,
            request: rid as u64,
            tenant: 0,
        });
        match tx.try_send((req, otx)) {
            Ok(()) => waiters.push(orx),
            Err(mpsc::TrySendError::Full(job)) => {
                // backpressure: the submission blocks and IS admitted —
                // that is pressure, not a rejection (requests_rejected
                // stays reserved for actual drops)
                backpressured += 1;
                metrics.requests_backpressured.inc();
                if tx.send(job).is_err() {
                    // worker gone mid-block: this request was dropped
                    metrics.requests_rejected.inc();
                    break;
                }
                waiters.push(orx);
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                // worker gone: the submission is an actual drop
                metrics.requests_rejected.inc();
                break;
            }
        }
    }
    drop(tx);

    // ---- collect
    let mut responses = Vec::new();
    for (rid, w) in waiters.into_iter().enumerate() {
        if let Ok(resp) = w.recv() {
            metrics.requests_completed.inc();
            metrics.tokens_generated.add(resp.tokens.len() as u64);
            metrics.cache_hits.add(resp.stats.cache_hits);
            metrics.cache_misses.add(resp.stats.cache_misses);
            metrics.request_latency.record(resp.stats.wall);
            obs.set_now_us(t0.elapsed().as_secs_f64() * 1e6);
            obs.emit(|ts| TraceEvent::RequestEnd {
                ts_us: ts,
                request: rid as u64,
                tenant: 0,
            });
            responses.push(resp);
        }
    }
    worker
        .join()
        .map_err(|_| anyhow::anyhow!("engine thread panicked"))??;

    let wall = t0.elapsed().as_secs_f64();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    Ok(ServeReport {
        completed: responses.len(),
        backpressured,
        total_tokens,
        wall_secs: wall,
        tokens_per_sec: total_tokens as f64 / wall.max(1e-9),
        requests_per_sec: responses.len() as f64 / wall.max(1e-9),
        cache_hit_rate: metrics.cache_hit_rate(),
        request_latency: metrics.request_latency.report(),
        responses,
    })
}
