//! Per-request decode session: KV state, the embedding ring the learned
//! predictor consumes, and generation progress.

use crate::coordinator::request::Request;

/// Decode state for one in-flight request.
pub struct Session {
    pub request: Request,
    /// Backbone KV state (host copy; only populated by non-chained
    /// callers — the engine threads KV device-side via `DecodeSession`).
    pub kv: Vec<f32>,
    /// Absolute position of the next token to write.
    pub pos: usize,
    /// Generated token ids.
    pub generated: Vec<i32>,
    /// Ring of the most recent token embeddings (predictor window).
    emb_ring: Vec<f32>,
    ring_len: usize,
    ring_cap: usize,
    d_emb: usize,
    /// Tokens decoded since the last predictor refresh.
    pub since_refresh: usize,
}

impl Session {
    pub fn new(request: Request, d_emb: usize, window: usize) -> Self {
        Self {
            request,
            kv: Vec::new(),
            pos: 0,
            generated: Vec::new(),
            emb_ring: vec![0.0; window * d_emb],
            ring_len: 0,
            ring_cap: window,
            d_emb,
            since_refresh: usize::MAX, // force refresh on first token
        }
    }

    /// Append a token embedding to the ring.
    pub fn push_embedding(&mut self, emb: &[f32]) {
        debug_assert_eq!(emb.len(), self.d_emb);
        if self.ring_len < self.ring_cap {
            let off = self.ring_len * self.d_emb;
            self.emb_ring[off..off + self.d_emb].copy_from_slice(emb);
            self.ring_len += 1;
        } else {
            // shift left one row (window is small: 32 * 128 floats)
            self.emb_ring.copy_within(self.d_emb.., 0);
            let off = (self.ring_cap - 1) * self.d_emb;
            self.emb_ring[off..off + self.d_emb].copy_from_slice(emb);
        }
    }

    /// The current window: (embeddings row-major, n_real).
    pub fn window(&self) -> (&[f32], usize) {
        (&self.emb_ring[..self.ring_len * self.d_emb], self.ring_len)
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.request.max_new_tokens
    }

    /// Remaining KV slots (generation must stop at max_seq).
    pub fn remaining_positions(&self, max_seq: usize) -> usize {
        max_seq.saturating_sub(self.pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sess(window: usize) -> Session {
        Session::new(Request::new(1, vec![1, 2, 3], 4), 2, window)
    }

    #[test]
    fn ring_fills_then_slides() {
        let mut s = sess(3);
        s.push_embedding(&[1.0, 1.0]);
        s.push_embedding(&[2.0, 2.0]);
        let (w, n) = s.window();
        assert_eq!(n, 2);
        assert_eq!(w, &[1.0, 1.0, 2.0, 2.0]);
        s.push_embedding(&[3.0, 3.0]);
        s.push_embedding(&[4.0, 4.0]); // evicts [1,1]
        let (w, n) = s.window();
        assert_eq!(n, 3);
        assert_eq!(w, &[2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn done_and_positions() {
        let mut s = sess(4);
        assert!(!s.done());
        s.generated = vec![9, 9, 9, 9];
        assert!(s.done());
        s.pos = 150;
        assert_eq!(s.remaining_positions(160), 10);
        assert_eq!(s.remaining_positions(100), 0);
    }
}
