//! Predictor evaluation — the paper's §3.2.4 metrics behind Table 1:
//! element-wise accuracy, macro-F1 across experts, exact top-k set match
//! ("position-wise accuracy"), plus micro-F1 for completeness.

use crate::predictor::TracePredictions;
use crate::trace::PromptTrace;
use crate::util::ExpertSet;

/// Aggregated evaluation counters.
#[derive(Debug, Clone)]
pub struct EvalAccumulator {
    pub n_experts: usize,
    /// Per-expert true/false positives/negatives (threshold 0.5).
    pub tp: Vec<u64>,
    pub fp: Vec<u64>,
    pub fn_: Vec<u64>,
    pub tn: Vec<u64>,
    /// Exact top-k set matches / total positions.
    pub exact: u64,
    pub positions: u64,
}

impl EvalAccumulator {
    pub fn new(n_experts: usize) -> Self {
        Self {
            n_experts,
            tp: vec![0; n_experts],
            fp: vec![0; n_experts],
            fn_: vec![0; n_experts],
            tn: vec![0; n_experts],
            exact: 0,
            positions: 0,
        }
    }

    /// Record one position: sigmoid(logits) thresholded at 0.5 for the
    /// per-expert confusion counts; `pred_topk` vs `truth` for exact match.
    pub fn record(&mut self, logits: &[f32], pred_topk: ExpertSet, truth: ExpertSet) {
        debug_assert_eq!(logits.len(), self.n_experts);
        for e in 0..self.n_experts {
            // sigmoid(x) > 0.5  <=>  x > 0
            let p = logits[e] > 0.0;
            let a = truth.contains(e as u8);
            match (p, a) {
                (true, true) => self.tp[e] += 1,
                (true, false) => self.fp[e] += 1,
                (false, true) => self.fn_[e] += 1,
                (false, false) => self.tn[e] += 1,
            }
        }
        if pred_topk == truth {
            self.exact += 1;
        }
        self.positions += 1;
    }

    /// Element-wise accuracy over all (position, expert) decisions.
    pub fn accuracy(&self) -> f64 {
        let correct: u64 = self.tp.iter().sum::<u64>() + self.tn.iter().sum::<u64>();
        let total = self.positions * self.n_experts as u64;
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }

    /// Macro-F1: mean per-expert F1 (paper's headline F1).
    pub fn macro_f1(&self) -> f64 {
        let mut sum = 0.0;
        for e in 0..self.n_experts {
            let p = self.tp[e] as f64 / (self.tp[e] + self.fp[e]).max(1) as f64;
            let r = self.tp[e] as f64 / (self.tp[e] + self.fn_[e]).max(1) as f64;
            sum += if p + r > 0.0 { 2.0 * p * r / (p + r) } else { 0.0 };
        }
        sum / self.n_experts as f64
    }

    /// Micro-F1 (pooled counts).
    pub fn micro_f1(&self) -> f64 {
        let tp: u64 = self.tp.iter().sum();
        let fp: u64 = self.fp.iter().sum();
        let fn_: u64 = self.fn_.iter().sum();
        if tp == 0 {
            return 0.0;
        }
        let p = tp as f64 / (tp + fp) as f64;
        let r = tp as f64 / (tp + fn_) as f64;
        2.0 * p * r / (p + r)
    }

    /// Exact top-k set match rate.
    pub fn exact_match(&self) -> f64 {
        if self.positions == 0 {
            0.0
        } else {
            self.exact as f64 / self.positions as f64
        }
    }

    pub fn merge(&mut self, other: &EvalAccumulator) {
        for e in 0..self.n_experts {
            self.tp[e] += other.tp[e];
            self.fp[e] += other.fp[e];
            self.fn_[e] += other.fn_[e];
            self.tn[e] += other.tn[e];
        }
        self.exact += other.exact;
        self.positions += other.positions;
    }
}

/// Evaluate precomputed predictions against a trace's ground truth.
pub fn eval_trace(preds: &TracePredictions, trace: &PromptTrace, acc: &mut EvalAccumulator) {
    let e_n = preds.n_experts;
    for t in 0..trace.n_tokens() {
        let row = &preds.logits[t];
        for l in 0..preds.n_layers {
            let logits = &row[l * e_n..(l + 1) * e_n];
            acc.record(logits, preds.sets[t][l], trace.expert_set(t, l));
        }
    }
}

/// Per-layer expert agreement (paper §3.2.4: "logging the per-layer
/// expert agreement rates"): for each model layer, the mean fraction of
/// the top-k truth set covered by the top-k predicted set.
#[derive(Debug, Clone)]
pub struct LayerAgreement {
    /// overlap(pred, truth) summed, per layer.
    pub overlap: Vec<u64>,
    /// positions counted per layer.
    pub count: Vec<u64>,
    pub top_k: usize,
}

impl LayerAgreement {
    pub fn new(n_layers: usize, top_k: usize) -> Self {
        Self {
            overlap: vec![0; n_layers],
            count: vec![0; n_layers],
            top_k,
        }
    }

    pub fn record_trace(&mut self, preds: &TracePredictions, trace: &PromptTrace) {
        for t in 0..trace.n_tokens() {
            for l in 0..preds.n_layers {
                self.overlap[l] += preds.sets[t][l].overlap(trace.expert_set(t, l)) as u64;
                self.count[l] += 1;
            }
        }
    }

    /// Agreement rate per layer, in [0, 1].
    pub fn rates(&self) -> Vec<f64> {
        self.overlap
            .iter()
            .zip(&self.count)
            .map(|(&o, &c)| {
                if c == 0 {
                    0.0
                } else {
                    o as f64 / (c * self.top_k as u64) as f64
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_scores_one() {
        let mut a = EvalAccumulator::new(4);
        // truth {0,1}; logits positive exactly there; topk matches
        let truth = ExpertSet::from_ids([0u8, 1]);
        a.record(&[5.0, 5.0, -5.0, -5.0], truth, truth);
        assert_eq!(a.accuracy(), 1.0);
        assert_eq!(a.exact_match(), 1.0);
        assert!((a.micro_f1() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_negative_prediction() {
        let mut a = EvalAccumulator::new(4);
        let truth = ExpertSet::from_ids([0u8]);
        a.record(&[-1.0, -1.0, -1.0, -1.0], ExpertSet::EMPTY, truth);
        assert_eq!(a.accuracy(), 0.75); // 3 TN of 4 decisions
        assert_eq!(a.exact_match(), 0.0);
        assert_eq!(a.micro_f1(), 0.0);
    }

    #[test]
    fn macro_vs_micro_weighting() {
        let mut a = EvalAccumulator::new(2);
        // expert 0 always right (10 positives), expert 1 always wrong (1)
        for _ in 0..10 {
            a.record(&[5.0, -5.0], ExpertSet::from_ids([0u8]), ExpertSet::from_ids([0u8]));
        }
        a.record(&[-5.0, -5.0], ExpertSet::EMPTY, ExpertSet::from_ids([1u8]));
        // macro averages the per-expert F1s: (f1_0 + 0) / 2
        assert!(a.macro_f1() < a.micro_f1());
    }

    #[test]
    fn layer_agreement_rates() {
        use crate::predictor::TracePredictions;
        use crate::trace::PromptTrace;
        let trace = PromptTrace {
            prompt_id: 0,
            n_layers: 2,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0],
            embeddings: vec![],
            experts: vec![1, 2, 3, 4], // layer0 {1,2}, layer1 {3,4}
        };
        let preds: TracePredictions = TracePredictions {
            n_layers: 2,
            sets: vec![vec![
                ExpertSet::from_ids([1u8, 9]),  // half right
                ExpertSet::from_ids([3u8, 4]),  // exact
            ]],
            logits: vec![vec![]],
            n_experts: 64,
        };
        let mut la = LayerAgreement::new(2, 2);
        la.record_trace(&preds, &trace);
        let r = la.rates();
        assert!((r[0] - 0.5).abs() < 1e-9);
        assert!((r[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = EvalAccumulator::new(2);
        let mut b = EvalAccumulator::new(2);
        let t = ExpertSet::from_ids([0u8]);
        a.record(&[1.0, -1.0], t, t);
        b.record(&[1.0, -1.0], t, t);
        a.merge(&b);
        assert_eq!(a.positions, 2);
        assert_eq!(a.exact, 2);
    }
}
