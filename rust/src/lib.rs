//! # MoE-Beyond — learning-based expert activation prediction for edge MoE serving
//!
//! Rust reproduction of *MoE-Beyond: Learning-Based Expert Activation
//! Prediction on Edge Devices* (Gavhane et al., 2025), built as a
//! three-layer stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request router, decode
//!   scheduler, the unified [`memory`] expert-residency subsystem (one
//!   `ExpertMemory` contract over the flat simulated-VRAM [`cache`] and
//!   the [`tier`] GPU VRAM ↔ host RAM ↔ SSD hierarchy, with
//!   promotion/demotion and per-tier cost models), prefetch pipeline, the
//!   [`predictor`] factory over the MoE-Infinity / DeepSpeed-MoE /
//!   BrainStorm heuristic baselines, the trace-driven, thread-parallel
//!   cache simulator behind the paper's Fig. 7 (batched set-level replay
//!   over pre-compiled [`trace::CompiledTrace`] tables, with Mattson
//!   stack-distance fast paths for BOTH the flat LRU baseline capacity
//!   axis and the tiered no-prefetch surface — per-tier curves from one
//!   memoized corpus profile; see [`cache::stackdist`]), the [`workload`]
//!   multi-tenant simulator (open-loop arrivals, shared-cache
//!   contention, SLO metrics, throughput–latency load sweeps), the
//!   [`obs`] observability layer (bounded-memory [`obs::Hist`]
//!   percentiles behind every latency report, a labeled metric
//!   registry, and Chrome-trace event tracing via [`obs::ObsSink`]),
//!   the [`cluster`] multi-node edge-cluster simulator (experts sharded
//!   across K nodes, a priced network tier, deterministic fault
//!   injection), and the evaluation harness behind Table 1.
//! * **L2 (JAX, build-time)** — the MoE backbone (DeepSeek-V2-Lite
//!   stand-in) and the MoE-Beyond predictor transformer, AOT-lowered to
//!   HLO text in `artifacts/`.
//! * **L1 (Pallas, build-time)** — fused attention / top-k gate / expert
//!   FFN kernels inside those HLO modules.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! HLO artifacts through PJRT (`xla` crate) and executes them natively.
//!
//! Start with [`config::Artifacts`] to locate a built artifact tree, then:
//!
//! ```no_run
//! use moe_beyond::{config::Artifacts, trace::store};
//! let arts = Artifacts::discover("artifacts").unwrap();
//! let traces = store::read_traces(arts.path("traces/test.bin")).unwrap();
//! println!("{} test prompts", traces.len());
//! ```
//!
//! Every paper figure/table has a bench target under `benches/`; see
//! `rust/BENCHMARKS.md` for what each one reproduces and how to run it.
//! For the module map, the data-flow diagram, and the extension guides
//! ("where do I add a backend / policy / predictor"), start with
//! `ARCHITECTURE.md` at the repository root.

pub mod cache;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod memory;
pub mod metrics;
pub mod moe;
pub mod obs;
pub mod predictor;
pub mod runtime;
pub mod sim;
pub mod tier;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate-wide result type (anyhow for rich error context).
pub type Result<T> = anyhow::Result<T>;
