//! `moe-beyond` — CLI launcher for the MoE-Beyond serving stack and every
//! paper experiment.
//!
//! ```text
//! moe-beyond info                         artifact + model summary
//! moe-beyond serve    [--predictor ...]   E2E edge serving on synthetic prompts
//! moe-beyond sweep    [--predictors ...]  Fig 7: hit rate vs capacity
//! moe-beyond eval     [--split test]      Table 1: accuracy / macro-F1
//! moe-beyond analyze  [--prompts 122]     Figs 1-3: trace sparsity analysis
//! moe-beyond training-report              Figs 5-6: training curves
//! ```
//!
//! Flag parsing is hand-rolled (offline build: no clap); every flag is
//! `--name value`.

use moe_beyond::config::{
    CacheConfig, EamConfig, ServeConfig, SimConfig, TierConfig, WorkloadConfig,
};
use moe_beyond::coordinator::{serve_requests, EngineConfig, ModelEngine, Request};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::harness;
use moe_beyond::sim::PredictorKind;
use moe_beyond::trace::corpus::{CorpusConfig, PromptSampler};
use moe_beyond::trace::generator::TraceGenerator;
use moe_beyond::trace::{PromptTrace, WorldModel};
use moe_beyond::workload;
use moe_beyond::Result;

/// Minimal `--flag value` argument map.
struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?;
                flags.insert(name.to_string(), val);
            } else {
                anyhow::bail!("unexpected argument {a} (flags are --name value)");
            }
        }
        Ok(Self { cmd, flags })
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be an integer")),
        }
    }

    fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be a number")),
        }
    }
}

const HELP: &str = "\
moe-beyond — learning-based expert activation prediction for edge MoE serving

USAGE: moe-beyond <command> [--flag value ...]

COMMANDS:
  info              artifact + world + model summary
  serve             end-to-end edge serving on synthetic prompts
                    --predictor learned|eam|next-layer|popularity|none  (learned)
                    --capacity 0.10   --requests 8   --max-new-tokens 24
                    --batch-size 1    --prompt-tokens 48
  sweep             Fig 7: cache hit rate vs capacity
                    --predictors learned,eam,none   --prompts 40   --out -
                    --fracs 0.05,0.10,...  (default: the paper's Fig-7 grid)
                    --trace-out t.json --metrics-out m.json|m.prom
                      (instrumented replay at the headline capacity)
  serve-sim         multi-tenant contention simulator: throughput-latency CSV
                    over policy x backend x predictor x load x cache fraction
                    --tenants 3        --horizon 12    --seed 7
                    --policies fcfs,round-robin,srd   --backends flat,tiered
                    --predictors eam,none             --loads 0.5,1,2,4
                    --fracs 0.05,0.10,0.20            --max-concurrency 4
                    --shards 1            (tenant-sharded parallel drain per
                                           point: K replica engines, merged in
                                           deterministic shard-index order)
                    --out serve_sim.csv   (synthetic corpora when no artifacts)
                    --experts 64          (synthetic worlds only; up to 256 —
                                           >64 selects a multi-word ExpertSet)
                    --trace-out t.json --metrics-out m.json|m.prom
                      (traced virtual-time re-run of the first grid point;
                       byte-deterministic for a fixed seed)
                    edge-cluster mode (--backends cluster; implied default
                    when --nodes > 1 and --backends is not given):
                    --nodes 3             (shard experts across K nodes; each
                                           node holds a 1/K capacity share)
                    --placement roundrobin|block|layerhash
                    --link-gbps 10  --link-latency-us 100  --link-hop-us 5
                    --promote-after 0     (migrate hot experts to node 0
                                           after N remote serves; 0 = never)
                    --replicas 1          (R-way expert replication: each
                                           expert lives on R distinct nodes
                                           and fetches fail over to the
                                           cheapest alive replica)
                    --fault-plan 'down:1@200-400;slow:2@500-700*3'
                                          (transient-fault DSL, ;-separated:
                                           fail:N@AT  straggle:N*MULT
                                           down:N@FROM-UNTIL   (cold comeback)
                                           flap:N@FROM-UNTIL   (warm comeback)
                                           slow:N@FROM-UNTIL*MULT
                                           failslow:N@FROM-UNTIL*MULT;
                                           indices are measured lookups)
                    --link-timeout-us 0   (remote-fetch deadline: a fetch
                                           priced above it pays the deadline
                                           and retries the next-cheapest
                                           alive replica; 0 = no deadline)
                    --retry-backoff-us 50 (exponential backoff base between
                                           retry attempts)
                    --fail-node 1 --fail-at 500       (deterministic failure:
                                           node 1 dies at measured lookup 500)
                    --straggler 2 --straggler-mult 2.5 (slow link to node 2)
                    e.g. a copy-pasteable 160-expert 3-node cluster run:
                      moe-beyond serve-sim --experts 160 --nodes 3 \\
                        --predictors eam --loads 1,2 --fracs 0.10 \\
                        --out cluster.csv
  eval              Table 1: predictor accuracy/F1
                    --split test   --prompts 100
  analyze           Figs 1-3: activation sparsity analysis
                    --prompts 122  --layer 0
  training-report   Figs 5-6: training curve summary
  export-csv        dump a trace split in the paper's CSV logging format
                    --split test   --out traces.csv

GLOBAL: --artifacts <dir>  (default: $MOEB_ARTIFACTS or ./artifacts)
";

fn main() -> Result<()> {
    let args = Args::parse()?;
    if let Some(a) = args.flags.get("artifacts") {
        std::env::set_var("MOEB_ARTIFACTS", a);
    }
    match args.cmd.as_str() {
        "info" => info(),
        "serve" => serve(&args),
        "serve-sim" => serve_sim(&args),
        "sweep" => sweep(&args),
        "eval" => eval(&args),
        "analyze" => analyze(&args),
        "training-report" => training_report(),
        "export-csv" => export_csv(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{HELP}");
            std::process::exit(2);
        }
    }
}

fn info() -> Result<()> {
    let arts = harness::load_artifacts()?;
    let w = &arts.world;
    println!("MoE-Beyond artifact tree: {}", arts.root.display());
    println!(
        "  world: {} layers x {} experts (top-{} + {} shared), {} topics, vocab {}, d_model {}",
        w.n_layers, w.n_experts, w.top_k, w.n_shared, w.n_topics, w.vocab_size, w.d_model
    );
    println!("  fingerprint: {}", w.fingerprint);
    println!(
        "  predictor: d={} x{} layers, {} heads, ffn {}, window {}",
        arts.predictor.d_model,
        arts.predictor.n_enc_layers,
        arts.predictor.n_heads,
        arts.predictor.d_ff,
        arts.predictor.window
    );
    let mut splits: Vec<_> = arts.splits.iter().collect();
    splits.sort_by(|a, b| a.0.cmp(b.0));
    for (name, s) in splits {
        println!(
            "  split {name}: {} prompts, {} trace points",
            s.prompts, s.trace_points
        );
    }
    let mut exes: Vec<_> = arts.executables.iter().collect();
    exes.sort_by(|a, b| a.0.cmp(b.0));
    for (name, e) in exes {
        println!("  exe {name}: {} inputs ({})", e.num_inputs, e.path);
    }
    arts.check_fingerprint()?;
    println!("  fingerprint check: OK");
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let predictor = args.get("predictor", "learned");
    let capacity = args.get_f64("capacity", 0.10)?;
    let n_requests = args.get_usize("requests", 8)?;
    let max_new_tokens = args.get_usize("max-new-tokens", 24)?;
    let batch_size = args.get_usize("batch-size", 1)?;
    let prompt_tokens = args.get_usize("prompt-tokens", 48)?;

    let arts = harness::load_artifacts()?;
    let world = WorldModel::load(arts.path("world.json"))?;
    let mut sampler = PromptSampler::new(
        &world,
        CorpusConfig {
            test_split: true,
            min_tokens: prompt_tokens.min(100),
            max_tokens: prompt_tokens,
            ..Default::default()
        },
    );
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| Request::new(i as u64, sampler.sample().tokens, max_new_tokens))
        .collect();

    let (nl, ne) = (arts.world.n_layers as usize, arts.world.n_experts as usize);
    let cfg = EngineConfig {
        serve: ServeConfig {
            predictor: predictor.clone(),
            max_new_tokens,
            batch_size,
            ..Default::default()
        },
        cache: CacheConfig::default().with_capacity_frac(capacity, nl, ne),
        sim: SimConfig::default(),
        ..Default::default()
    };
    println!(
        "serving {n_requests} requests (predictor={predictor}, capacity={:.0}%, batch={batch_size}) ...",
        capacity * 100.0
    );
    let arts2 = arts.clone();
    let report = serve_requests(
        move || {
            let rt = PjrtRuntime::cpu()?;
            ModelEngine::load(&rt, &arts2, cfg)
        },
        requests,
        64,
        batch_size,
    )?;

    println!("completed  : {}", report.completed);
    println!(
        "tokens     : {} ({:.1} tok/s)",
        report.total_tokens, report.tokens_per_sec
    );
    println!("requests/s : {:.2}", report.requests_per_sec);
    println!("hit rate   : {:.1}%", report.cache_hit_rate * 100.0);
    println!("latency    : {}", report.request_latency);
    let miss_us: f64 = report.responses.iter().map(|r| r.stats.modeled_miss_us).sum();
    let stall_us: f64 = report.responses.iter().map(|r| r.stats.modeled_stall_us).sum();
    println!(
        "modeled PCIe: {:.1} ms demand-miss + {:.1} ms prefetch-stall across run",
        miss_us / 1e3,
        stall_us / 1e3
    );
    Ok(())
}

/// Multi-tenant contention simulator (see `moe_beyond::workload`):
/// Edge-cluster topology from the serve-sim CLI flags.  With no cluster
/// flags this is the 1-node loopback default, which the `cluster`
/// backend replays byte-identically to `flat` — so threading it through
/// unconditionally is free.
fn cluster_from_args(args: &Args) -> Result<moe_beyond::cluster::ClusterConfig> {
    use moe_beyond::cluster::{ClusterConfig, FaultPlan, PlacementKind};
    use moe_beyond::tier::LinkSpec;

    let nodes = args.get_usize("nodes", 1)?;
    let placement = PlacementKind::parse(&args.get("placement", "roundrobin"))?;
    let link = LinkSpec::new(
        args.get_f64("link-latency-us", 100.0)?,
        args.get_f64("link-gbps", 10.0)?,
        args.get_f64("link-hop-us", 5.0)?,
    )
    .with_timeout_us(args.get_f64("link-timeout-us", 0.0)?);
    // --fault-plan is the general DSL; the legacy --fail-node /
    // --straggler knobs merge into it so old invocations keep working.
    let mut faults = match args.flags.get("fault-plan") {
        Some(s) => FaultPlan::parse(s)?,
        None => FaultPlan::none(),
    };
    if args.flags.contains_key("fail-node") {
        faults = faults.with_failure(
            args.get_usize("fail-node", 0)?,
            args.get_usize("fail-at", 500)? as u64,
        );
    }
    if args.flags.contains_key("straggler") {
        faults = faults.with_straggler(
            args.get_usize("straggler", 0)?,
            args.get_f64("straggler-mult", 2.0)?,
        );
    }
    let cfg = ClusterConfig::default()
        .with_nodes(nodes)
        .with_placement(placement)
        .with_link(link)
        .with_promote_after(args.get_usize("promote-after", 0)? as u32)
        .with_replicas(args.get_usize("replicas", 1)?)
        .with_retry_backoff_us(args.get_f64("retry-backoff-us", 50.0)?)
        .with_faults(faults);
    cfg.validate()?;
    Ok(cfg)
}

/// extends Fig 7 into throughput–latency curves over a scheduler-policy
/// × backend × predictor × offered-load × cache-fraction grid.  Runs
/// self-contained on synthetic per-tenant corpora; with an artifact
/// tree present the corpora come from `trace::corpus` instead.
fn serve_sim(args: &Args) -> Result<()> {
    let n_tenants = args.get_usize("tenants", 3)?;
    let horizon = args.get_f64("horizon", 12.0)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let max_concurrency = args.get_usize("max-concurrency", 4)?;
    let out = args.get("out", "serve_sim.csv");
    let experts_flag = args.get_usize("experts", 64)?;

    let policies: Vec<workload::SchedPolicy> = args
        .get("policies", "fcfs,round-robin")
        .split(',')
        .map(|s| {
            workload::SchedPolicy::parse(s.trim())
                .ok_or_else(|| anyhow::anyhow!("unknown policy {s}"))
        })
        .collect::<Result<_>>()?;
    // --nodes > 1 without an explicit --backends implies the cluster
    // backend: asking for a multi-node run and silently sweeping
    // single-node backends would be a footgun
    let default_backends = if args.get_usize("nodes", 1)? > 1 {
        "cluster"
    } else {
        "flat,tiered"
    };
    let backends: Vec<workload::Backend> = args
        .get("backends", default_backends)
        .split(',')
        .map(|s| {
            workload::Backend::parse(s.trim())
                .ok_or_else(|| anyhow::anyhow!("unknown backend {s}"))
        })
        .collect::<Result<_>>()?;
    let kinds: Vec<PredictorKind> = args
        .get("predictors", "eam,none")
        .split(',')
        .map(|s| {
            PredictorKind::parse(s.trim()).ok_or_else(|| anyhow::anyhow!("unknown predictor {s}"))
        })
        .collect::<Result<_>>()?;
    let loads = parse_f64_list(&args.get("loads", "0.5,1,2,4"), "--loads")?;
    let fracs = parse_f64_list(&args.get("fracs", "0.05,0.10,0.20"), "--fracs")?;

    let spec = workload::WorkloadSpec::example(n_tenants, seed, horizon);
    let want_learned = kinds.contains(&PredictorKind::Learned);

    // tenant corpora: the artifact world's corpus sampler when present,
    // the self-contained reuse-heavy generator otherwise.  The learned
    // predictor additionally needs the PJRT predictor artifact to
    // precompute per-trace predictions (replayed via CachedPredictor).
    // The artifact path stays on the single-word fast path (N = 1); the
    // synthetic path dispatches on --experts so wide worlds (> 64
    // experts, up to 64 * N_MAX) run the same grid end-to-end.
    match harness::load_artifacts() {
        Ok(arts) => {
            let world = WorldModel::load(arts.path("world.json"))?;
            let (nl, ne) = (
                world.meta.n_layers as usize,
                world.meta.n_experts as usize,
            );
            if args.flags.contains_key("experts") {
                println!("--experts ignored: the artifact world fixes n_experts = {ne}");
            }
            let mut pools = Vec::new();
            let mut fit = Vec::new();
            for t in &spec.tenants {
                let need = t.prompt_tokens.1 + t.decode_tokens.1;
                let corpus = CorpusConfig {
                    seed: t.trace_seed,
                    min_tokens: need,
                    max_tokens: need,
                    test_split: true,
                    ..Default::default()
                };
                let mut g = TraceGenerator::new(&world, corpus, t.trace_seed);
                pools.push(g.generate(8));
                fit.extend(g.generate(4));
            }
            println!("tenant corpora: 8 traces/tenant from the artifact world");
            let learned_pools: Option<Vec<Vec<moe_beyond::predictor::TracePredictions>>> =
                if want_learned {
                    let rt = PjrtRuntime::cpu()?;
                    let sim = SimConfig::default();
                    let mut lp = Vec::with_capacity(pools.len());
                    for pool in &pools {
                        lp.push(harness::precompute_learned(
                            &rt,
                            &arts,
                            pool,
                            sim.predictor_stride,
                            sim.predict_top_k,
                            true,
                        )?);
                    }
                    println!("learned predictions precomputed for every tenant pool");
                    Some(lp)
                } else {
                    None
                };
            serve_sim_grid::<1>(
                args,
                &spec,
                &pools,
                &fit,
                learned_pools.as_deref(),
                nl,
                ne,
                horizon,
                max_concurrency,
                &out,
                (&policies, &backends, &kinds, &loads, &fracs),
            )
        }
        Err(e) => {
            anyhow::ensure!(
                !want_learned,
                "--predictors learned needs the artifact tree (PJRT predictor) — {e}"
            );
            let ne = experts_flag;
            anyhow::ensure!(
                (24..=moe_beyond::util::MAX_EXPERTS).contains(&ne),
                "--experts must be in 24..={} (got {ne})",
                moe_beyond::util::MAX_EXPERTS
            );
            println!("artifact tree absent — synthetic tenant corpora (4 layers x {ne} experts)");
            let pools = workload::synthetic_pools(&spec, 8, 4, ne);
            let fit = workload::synthetic_fit_pool(&spec, 4, 4, ne);
            moe_beyond::for_expert_width!(ne, N, {
                serve_sim_grid::<N>(
                    args,
                    &spec,
                    &pools,
                    &fit,
                    None,
                    4,
                    ne,
                    horizon,
                    max_concurrency,
                    &out,
                    (&policies, &backends, &kinds, &loads, &fracs),
                )
            })
        }
    }
}

/// One full serve-sim grid at a fixed set word-width `N` (monomorphized:
/// the 64-expert default runs exactly the single-word code it always
/// did; wide worlds pay only for the words they need).
#[allow(clippy::too_many_arguments)]
fn serve_sim_grid<const N: usize>(
    args: &Args,
    spec: &workload::WorkloadSpec,
    pools: &[Vec<PromptTrace>],
    fit: &[PromptTrace],
    learned_pools: Option<&[Vec<moe_beyond::predictor::TracePredictions<N>>]>,
    n_layers: usize,
    n_experts: usize,
    horizon: f64,
    max_concurrency: usize,
    out: &str,
    grid: (
        &[workload::SchedPolicy],
        &[workload::Backend],
        &[PredictorKind],
        &[f64],
        &[f64],
    ),
) -> Result<()> {
    let (policies, backends, kinds, loads, fracs) = grid;
    let total = n_layers * n_experts;
    let cluster_base = cluster_from_args(args)?;
    let tier_base = TierConfig {
        tiers: vec![
            moe_beyond::tier::TierSpec::gpu(1), // resized per grid point
            moe_beyond::tier::TierSpec::host((total / 4).max(1)),
            moe_beyond::tier::TierSpec::ssd(total.max(1)),
        ],
        policy: "lru".into(),
    };
    let wcfg = WorkloadConfig {
        max_concurrency,
        ..Default::default()
    };
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let inputs = workload::LoadSweepInputs {
        spec,
        pools,
        fit_traces: fit,
        learned: learned_pools,
        workload: &wcfg,
        sim: &SimConfig::default(),
        eam: &eam,
        n_layers,
        n_experts,
        tier_base: &tier_base,
        cluster_base: Some(&cluster_base),
        engine_shards: args.get_usize("shards", 1)?,
    };
    println!(
        "serve-sim: {} tenants, horizon {:.0}s, base offered {:.2} rps; {} grid points",
        spec.tenants.len(),
        horizon,
        spec.offered_rps(),
        policies.len() * backends.len() * kinds.len() * loads.len() * fracs.len()
    );
    let points = workload::sweep_load(&inputs, policies, backends, kinds, loads, fracs)?;

    println!("\n== throughput-latency (aggregate across tenants) ==");
    println!(
        "{:>12} {:>7} {:>11} {:>5} {:>5} {:>10} {:>9} {:>12} {:>11} {:>6}",
        "policy",
        "backend",
        "predictor",
        "load",
        "cap%",
        "offer rps",
        "done rps",
        "p95 TTFT ms",
        "p95 TBT ms",
        "hit%"
    );
    for p in &points {
        let a = &p.report.aggregate;
        println!(
            "{:>12} {:>7} {:>11} {:>5.2} {:>5.0} {:>10.2} {:>9.2} {:>12.1} {:>11.1} {:>6.1}",
            p.policy.id(),
            p.backend.id(),
            p.predictor.id(),
            p.load_mult,
            p.cache_frac * 100.0,
            p.report.offered_rps,
            p.report.completed_rps,
            a.ttft.p95_us / 1e3,
            a.tbt.p95_us / 1e3,
            a.cache.hit_rate() * 100.0
        );
    }
    std::fs::write(&out, workload::load_csv(&points))?;
    println!("\n{} rows written to {out}", points.len());

    // ---- optional observability pass: re-run the FIRST grid point with
    // an active sink on the virtual clock.  The drain is byte-identical
    // to the grid's own run of that point, so two invocations with the
    // same seed produce byte-identical trace + metrics files (the CI obs
    // gate compares exactly that).
    let trace_out = args.get("trace-out", "");
    let metrics_out = args.get("metrics-out", "");
    if !trace_out.is_empty() || !metrics_out.is_empty() {
        let obs = moe_beyond::obs::ObsSink::active(moe_beyond::obs::DEFAULT_RING_CAP, "virtual");
        // shard engines drain with no-op sinks, so the traced re-run
        // always uses the single-engine drain
        let traced_inputs = workload::LoadSweepInputs {
            engine_shards: 1,
            ..inputs
        };
        let pt = workload::run_point_obs(
            &traced_inputs,
            policies[0],
            backends[0],
            kinds[0],
            loads[0],
            fracs[0],
            &obs,
        )?;
        println!(
            "\ntraced re-run: {} x {} x {} @ load {:.2}, cap {:.0}% ({} completions)",
            pt.policy.id(),
            pt.backend.id(),
            pt.predictor.id(),
            pt.load_mult,
            pt.cache_frac * 100.0,
            pt.report.counters.completions
        );
        write_obs_outputs(&obs, &trace_out, &metrics_out)?;
    }
    Ok(())
}

/// Write an active sink's trace and/or metrics to the given paths
/// (empty path = skip).  A `.prom` metrics suffix selects Prometheus
/// text exposition; anything else gets deterministic JSON.
fn write_obs_outputs(
    obs: &moe_beyond::obs::ObsSink,
    trace_out: &str,
    metrics_out: &str,
) -> Result<()> {
    if !trace_out.is_empty() {
        let j = obs.trace_json().expect("active sink");
        std::fs::write(trace_out, j.to_json_string())?;
        println!(
            "trace written to {trace_out} ({} events dropped by the ring)",
            obs.dropped_events()
        );
    }
    if !metrics_out.is_empty() {
        let text = if metrics_out.ends_with(".prom") {
            obs.metrics_prometheus().expect("active sink")
        } else {
            obs.metrics_json().expect("active sink").to_json_string()
        };
        std::fs::write(metrics_out, text)?;
        println!("metrics written to {metrics_out}");
    }
    Ok(())
}

fn parse_f64_list(s: &str, flag: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|x| {
            x.trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("{flag} must be comma-separated numbers"))
        })
        .collect()
}

fn sweep(args: &Args) -> Result<()> {
    let predictors = args.get("predictors", "learned,eam,none");
    let prompts = args.get_usize("prompts", 40)?;
    let out = args.get("out", "-");
    let fracs: Vec<f64> = match args.flags.get("fracs") {
        None => harness::FIG7_FRACS.to_vec(),
        Some(v) => v
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--fracs must be comma-separated numbers"))
            })
            .collect::<Result<_>>()?,
    };

    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let kinds: Vec<PredictorKind> = predictors
        .split(',')
        .map(|s| {
            PredictorKind::parse(s.trim()).ok_or_else(|| anyhow::anyhow!("unknown predictor {s}"))
        })
        .collect::<Result<_>>()?;
    let results = harness::run_fig7(&rt, &arts, &kinds, &fracs, prompts, SimConfig::default())?;
    println!("\nFig 7 — GPU cache hit rate (%) vs expert capacity (%):");
    print!("{:>10}", "capacity%");
    for r in &results {
        print!("{:>22}", r.predictor);
    }
    println!();
    for (i, frac) in fracs.iter().enumerate() {
        print!("{:>10.0}", frac * 100.0);
        for r in &results {
            print!("{:>22.1}", r.points[i].hit_rate * 100.0);
        }
        println!();
    }
    // the paper's headline point is index 1 (10%) on the default grid;
    // a single-point --fracs grid reports its only point
    let headline = 1.min(fracs.len().saturating_sub(1));
    println!(
        "\nprediction hit rate @{:.0}% capacity:",
        fracs[headline] * 100.0
    );
    for r in &results {
        println!(
            "  {:>22}: {:.1}%",
            r.predictor,
            r.points[headline].prediction_hit_rate * 100.0
        );
    }
    if out != "-" {
        let rows = harness::fig7_rows(&results);
        std::fs::write(&out, harness::fig7_rows_json(&rows))?;
        println!("rows written to {out}");
    }

    // ---- optional observability pass: replay a few world-generated
    // traces through an instrumented flat engine at the headline
    // capacity (virtual clock, so the outputs are seed-deterministic).
    let trace_out = args.get("trace-out", "");
    let metrics_out = args.get("metrics-out", "");
    if !trace_out.is_empty() || !metrics_out.is_empty() {
        let world = WorldModel::load(arts.path("world.json"))?;
        let (nl, ne) = (
            arts.world.n_layers as usize,
            arts.world.n_experts as usize,
        );
        let cap = (((nl * ne) as f64 * fracs[headline]).round() as usize).max(1);
        let obs = moe_beyond::obs::ObsSink::active(moe_beyond::obs::DEFAULT_RING_CAP, "virtual");
        let mut engine: moe_beyond::sim::SimEngine = moe_beyond::sim::SimEngine::flat(
            Box::new(moe_beyond::cache::LruCache::new(cap)),
            SimConfig::default(),
            CacheConfig::default().with_capacity(cap),
            ne,
        );
        engine.set_obs(obs.clone());
        let mut g = TraceGenerator::new(&world, CorpusConfig::default(), 17);
        let mut pred = moe_beyond::predictor::NoPrefetch;
        let mut stats = moe_beyond::cache::CacheStats::default();
        for tr in g.generate(4) {
            engine.run_prompt(&tr, &mut pred, &mut stats);
        }
        println!(
            "\ninstrumented replay: 4 traces @ {:.0}% capacity, hit rate {:.1}%",
            fracs[headline] * 100.0,
            stats.hit_rate() * 100.0
        );
        write_obs_outputs(&obs, &trace_out, &metrics_out)?;
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let split = args.get("split", "test");
    let prompts = args.get_usize("prompts", 100)?;
    let arts = harness::load_artifacts()?;
    let rt = PjrtRuntime::cpu()?;
    let t = harness::run_table1(&rt, &arts, prompts, &split)?;
    println!(
        "Table 1 — predictor evaluation on split '{split}' ({} prompts, {} positions):",
        t.prompts, t.positions
    );
    println!("  accuracy     : {:.2}%   (paper: 97.55%)", t.accuracy_pct);
    println!("  macro F1     : {:.2}%   (paper: 86.18%)", t.macro_f1_pct);
    println!("  micro F1     : {:.2}%", t.micro_f1_pct);
    println!("  exact top-{}  : {:.2}%", arts.world.top_k, t.exact_match_pct);
    Ok(())
}

fn analyze(args: &Args) -> Result<()> {
    let prompts = args.get_usize("prompts", 122)?;
    let layer = args.get_usize("layer", 0)?;
    let arts = harness::load_artifacts()?;
    let rep = harness::run_fig123(&arts, prompts, layer)?;
    println!("Figs 1-3 — activation sparsity over {prompts} prompts (layer {layer}):");
    println!(
        "  Fig 1 aggregate histogram: min {} max {} (ratio {:.2}; paper band 800-1400 @122 prompts)",
        rep.fig1_min, rep.fig1_max, rep.fig1_ratio
    );
    println!(
        "  Fig 2 single prompt: working set {} / {} experts; peaks at {:?}",
        rep.fig2_working_set, arts.world.n_experts, rep.fig2_peak_experts
    );
    println!(
        "  Fig 3 heatmap: mean per-layer working set {:.1}, cross-layer reuse {:.2}",
        rep.fig3_working_sets.iter().sum::<usize>() as f64 / rep.fig3_working_sets.len() as f64,
        rep.fig3_cross_layer_reuse
    );
    println!(
        "  sparsity: per-prompt entropy {:.2} nats vs aggregate {:.2} nats; working-set frac {:.1}%",
        rep.sparsity.mean_single_entropy,
        rep.sparsity.aggregate_entropy,
        rep.sparsity.working_set_frac * 100.0
    );
    Ok(())
}

fn export_csv(args: &Args) -> Result<()> {
    let split = args.get("split", "test");
    let out = args.get("out", "traces.csv");
    let arts = harness::load_artifacts()?;
    let (meta, traces) = moe_beyond::trace::store::read_traces_with_meta(
        arts.path(&arts.split(&split)?.path),
    )?;
    moe_beyond::trace::csv::write_csv(&out, &traces)?;
    println!(
        "wrote {} prompts x {} layers (top-{}) to {out}",
        traces.len(),
        meta.n_layers,
        meta.top_k
    );
    Ok(())
}

fn training_report() -> Result<()> {
    let arts = harness::load_artifacts()?;
    let log = harness::load_training_log(&arts)?;
    println!(
        "Figs 5-6 — training/validation curves ({} steps logged, {:.0}s wall):",
        log.train_steps.len(),
        log.wall_seconds
    );
    if let (Some(first), Some(last)) = (log.train_steps.first(), log.train_steps.last()) {
        println!(
            "  train: loss {:.3} -> {:.3}, acc {:.3} -> {:.3}, F1 {:.3} -> {:.3}",
            first.loss, last.loss, first.acc, last.acc, first.f1, last.f1
        );
    }
    for e in &log.val_epochs {
        println!(
            "  val epoch {:>2}: loss {:.4} acc {:.4} f1 {:.3} exact {:.3}",
            e.epoch, e.loss, e.acc, e.f1, e.exact
        );
    }
    Ok(())
}
