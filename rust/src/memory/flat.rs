//! [`FlatMemory`] — the seed residency model: one bounded GPU cache over
//! an infinite host pool, every miss a full PCIe fetch.

use crate::cache::{policy, CachePolicy, VramModel};
use crate::config::CacheConfig;
use crate::memory::{DmaBudget, ExpertMemory, Lookup, LookupBatch, MemoryStats, Prefetched};
use crate::obs::{ObsSink, TierMoveKind, TraceEvent};
use crate::tier::TierStats;
use crate::util::ExpertSet;

/// Flat VRAM residency: a [`CachePolicy`] for what is resident plus a
/// [`VramModel`] for what each access costs.
///
/// Generic over the [`ExpertSet`] word width `N` (default 1); residency
/// itself is keyed per expert id, so only the set-valued call surfaces
/// (`lookup_set` / `prefetch`) change shape with the width.
pub struct FlatMemory<const N: usize = 1> {
    cache: Box<dyn CachePolicy>,
    vram: VramModel,
    /// Demand-fetch cost reported per miss (the config knob, kept out of
    /// the `VramModel`-owned copy of the config).
    pcie_us_per_expert: f64,
    n_experts: usize,
    budget: DmaBudget,
    /// Trace sink — default no-op; measured accesses emit
    /// hit/miss/eviction events when a driver attaches an active sink.
    obs: ObsSink,
}

impl<const N: usize> FlatMemory<N> {
    pub fn new(
        cache: Box<dyn CachePolicy>,
        cfg: CacheConfig,
        n_experts: usize,
        prefetch_budget: usize,
        overlap_budget_us: f64,
    ) -> Self {
        Self {
            pcie_us_per_expert: cfg.pcie_us_per_expert,
            vram: VramModel::new(cfg, overlap_budget_us),
            cache,
            n_experts,
            budget: DmaBudget::new(prefetch_budget),
            obs: ObsSink::default(),
        }
    }

    /// Shared lookup body: `lookup` is one call, `lookup_set` loops it
    /// without re-entering the vtable, so the two paths cannot drift.
    #[inline]
    fn lookup_one(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        let k = policy::key(layer, expert, self.n_experts);
        if self.cache.touch(k) {
            if measured {
                self.vram.on_hit();
                self.obs.emit(|ts| TraceEvent::CacheAccess {
                    ts_us: ts,
                    layer: layer as u16,
                    expert,
                    hit: true,
                    depth: 0,
                });
            }
            Lookup {
                hit: true,
                fetch_us: 0.0,
            }
        } else {
            if measured {
                self.vram.on_demand_miss();
            }
            let evicted = self.cache.insert(k);
            if measured && self.obs.is_active() {
                // depth 1 = the infinite host pool every miss faults from
                self.obs.emit(|ts| TraceEvent::CacheAccess {
                    ts_us: ts,
                    layer: layer as u16,
                    expert,
                    hit: false,
                    depth: 1,
                });
                if let Some(ek) = evicted {
                    let (el, ee) = policy::unkey(ek, self.n_experts);
                    self.obs.emit(|ts| TraceEvent::TierMove {
                        ts_us: ts,
                        kind: TierMoveKind::Demote,
                        layer: el as u16,
                        expert: ee,
                        from: 0,
                        to: 1,
                    });
                }
            }
            Lookup {
                hit: false,
                fetch_us: self.pcie_us_per_expert,
            }
        }
    }
}

impl<const N: usize> ExpertMemory<N> for FlatMemory<N> {
    fn name(&self) -> &'static str {
        "flat"
    }

    fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        self.lookup_one(layer, expert, measured)
    }

    /// Native batched lookup: one virtual call per layer, hit mask built
    /// as a bitmask, same ascending-id mutation order as scalar lookups.
    fn lookup_set(&mut self, layer: usize, truth: ExpertSet<N>, measured: bool) -> LookupBatch<N> {
        let mut out = LookupBatch::default();
        for e in truth.iter() {
            let r = self.lookup_one(layer, e, measured);
            if r.hit {
                out.hits.insert(e);
            } else {
                out.fetch_us += r.fetch_us;
            }
        }
        out
    }

    fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>) -> Prefetched {
        let mut out = Prefetched::default();
        let mut landed = 0usize;
        for e in predicted.iter() {
            out.issued += 1;
            let k = policy::key(layer, e, self.n_experts);
            if self.cache.contains(k) {
                self.cache.touch(k);
                continue;
            }
            if landed >= self.budget.effective() {
                out.too_late += 1;
                continue;
            }
            landed += 1;
            self.vram.on_prefetch();
            if let Some(ek) = self.cache.insert(k) {
                let n = self.n_experts;
                self.obs.emit(|ts| {
                    let (el, ee) = policy::unkey(ek, n);
                    TraceEvent::TierMove {
                        ts_us: ts,
                        kind: TierMoveKind::Demote,
                        layer: el as u16,
                        expert: ee,
                        from: 0,
                        to: 1,
                    }
                });
            }
        }
        out.landed = landed as u64;
        if out.issued > 0 {
            self.obs.emit(|ts| TraceEvent::Prefetch {
                ts_us: ts,
                layer: layer as u16,
                issued: out.issued as u32,
                landed: out.landed as u32,
                too_late: out.too_late as u32,
            });
        }
        out
    }

    fn end_layer(&mut self) {
        self.vram.end_layer();
    }

    fn cost_marks(&self) -> (f64, f64) {
        (self.vram.demand_us, self.vram.stall_us)
    }

    fn set_prefetch_budget(&mut self, budget: usize) {
        self.budget.set_base(budget);
    }

    fn set_batch_share(&mut self, batch: usize) {
        self.budget.set_batch_share(batch);
    }

    fn effective_prefetch_budget(&self) -> usize {
        self.budget.effective()
    }

    fn resident_count(&self) -> usize {
        self.cache.len()
    }

    fn tier_stats(&self) -> Option<&TierStats> {
        None
    }

    fn stats(&self) -> MemoryStats {
        MemoryStats {
            demand_us: self.vram.demand_us,
            prefetch_us: self.vram.prefetch_us,
            stall_us: self.vram.stall_us,
            resident: self.cache.len(),
            resident_per_depth: vec![self.cache.len()],
            tiers: None,
            net: None,
        }
    }

    fn clear(&mut self) {
        self.cache.clear();
    }

    fn set_obs(&mut self, obs: ObsSink) {
        self.obs = obs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::LruCache;

    fn mem(cap: usize, budget: usize) -> FlatMemory {
        FlatMemory::new(
            Box::new(LruCache::new(cap)),
            CacheConfig {
                capacity_experts: cap,
                pcie_us_per_expert: 100.0,
                hit_us: 1.0,
                ..Default::default()
            },
            64,
            budget,
            250.0,
        )
    }

    #[test]
    fn miss_then_hit_with_costs() {
        let mut m = mem(4, 12);
        let miss = m.lookup(0, 7, true);
        assert!(!miss.hit);
        assert_eq!(miss.fetch_us, 100.0);
        let hit = m.lookup(0, 7, true);
        assert!(hit.hit);
        assert_eq!(hit.fetch_us, 0.0);
        let (demand, _) = m.cost_marks();
        assert_eq!(demand, 101.0); // 100µs miss + 1µs hit
    }

    #[test]
    fn unmeasured_lookup_moves_residency_without_cost() {
        let mut m = mem(4, 12);
        assert!(!m.lookup(0, 3, false).hit);
        assert_eq!(m.cost_marks(), (0.0, 0.0));
        assert_eq!(m.resident_count(), 1);
        // the warm-up insert is real: measured phase hits it
        assert!(m.lookup(0, 3, true).hit);
    }

    #[test]
    fn prefetch_respects_budget_and_refreshes_residents() {
        let mut m = mem(16, 2);
        m.lookup(0, 1, false);
        let pf = m.prefetch(0, ExpertSet::from_ids([1u8, 2, 3, 4]));
        assert_eq!(pf.issued, 4);
        assert_eq!(pf.landed, 2); // 2 and 3 land, 1 was resident
        assert_eq!(pf.too_late, 1); // 4 misses the window
        assert_eq!(m.resident_count(), 3);
    }

    #[test]
    fn lookup_set_matches_scalar_sequence() {
        let mut batched = mem(4, 12);
        let mut scalar = mem(4, 12);
        let truth = ExpertSet::from_ids([1u8, 5, 9]);
        scalar.lookup(0, 3, false);
        batched.lookup(0, 3, false);
        scalar.lookup(0, 5, true);
        batched.lookup(0, 5, true);
        let b = batched.lookup_set(0, truth, true);
        let mut hits: ExpertSet = ExpertSet::new();
        let mut fetch = 0.0;
        for e in truth.iter() {
            let r = scalar.lookup(0, e, true);
            if r.hit {
                hits.insert(e);
            } else {
                fetch += r.fetch_us;
            }
        }
        assert_eq!(b.hits, hits);
        assert_eq!(b.fetch_us.to_bits(), fetch.to_bits());
        assert_eq!(b.hits, ExpertSet::from_ids([5u8]));
        assert_eq!(batched.cost_marks(), scalar.cost_marks());
        assert_eq!(batched.resident_count(), scalar.resident_count());
    }

    #[test]
    fn stall_accounting_per_layer() {
        let mut m = mem(16, 12);
        // 4 prefetches x 100µs > 250µs window -> 150µs stall
        m.prefetch(0, ExpertSet::from_ids([1u8, 2, 3, 4]));
        m.end_layer();
        let s = m.stats();
        assert_eq!(s.stall_us, 150.0);
        assert_eq!(s.prefetch_us, 400.0);
        assert_eq!(s.critical_path_us(), 150.0);
    }
}
