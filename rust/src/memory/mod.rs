//! Unified expert-residency subsystem: one [`ExpertMemory`] contract for
//! every way expert weights can be staged for the GPU.
//!
//! Before this module existed, the simulator and the serving path each
//! carried their own flat-vs-tiered dispatch (a `vram`/`tier` field pair
//! in `SimEngine`, a private `enum Backend` in `ExpertCacheManager`) —
//! two hand-synchronized copies of the same lookup/prefetch/cost logic.
//! [`ExpertMemory`] is now the single place that dispatch lives:
//!
//! * [`FlatMemory`] — the seed model: one bounded GPU cache
//!   ([`crate::cache::CachePolicy`]) over an infinite host pool, costs
//!   from [`crate::cache::VramModel`].
//! * [`TieredMemory`] — the GPU ↔ host RAM ↔ SSD hierarchy
//!   ([`crate::tier`]): promotion on miss, demotion on eviction, per-tier
//!   fetch/writeback costs and serve counters.
//! * [`crate::cluster::ClusterMemory`] — K nodes, each wrapping one of
//!   the above, with expert ownership sharded by a placement map and
//!   remote serves priced over a network link ([`crate::tier::net`]);
//!   built by [`crate::cluster::build`] rather than [`build`] so the
//!   single-node construction path stays untouched.
//!
//! Both the trace-driven simulator ([`crate::sim::SimEngine`]) and the
//! serving coordinator ([`crate::coordinator::ExpertCacheManager`]) drive
//! a `Box<dyn ExpertMemory>`, so their hit/miss/cost numbers come from
//! the exact same code path — and every new residency scenario is one
//! new impl of this trait, not two divergent branches.
//!
//! # Adding a third backend
//!
//! A new residency scheme (e.g. an ML-replacement cache over SSD, or a
//! pinned-popular-experts layout) is one file implementing the trait:
//!
//! ```ignore
//! // Generic over the set width N (1 word = 64 experts; N = 1 is the
//! // default everywhere, N = 3 covers 160-expert models).  A backend
//! // that only targets ≤64-expert models can drop the parameter and
//! // implement `ExpertMemory` (i.e. `ExpertMemory<1>`) directly.
//! pub struct PinnedMemory<const N: usize = 1> {
//!     pinned: ExpertSet<N>,
//!     inner: FlatMemory<N>,
//! }
//!
//! impl<const N: usize> ExpertMemory<N> for PinnedMemory<N> {
//!     fn name(&self) -> &'static str { "pinned" }
//!     fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
//!         if self.pinned.contains(expert) {
//!             return Lookup { hit: true, fetch_us: 0.0 }; // always resident
//!         }
//!         self.inner.lookup(layer, expert, measured)
//!     }
//!     // prefetch / end_layer / cost_marks / ... delegate to `inner`.
//!     //
//!     // `lookup_set(&mut self, layer, truth: ExpertSet<N>, measured)`
//!     // is OPTIONAL: the trait's default implementation expands a
//!     // set-level call into scalar `lookup`s, so a minimal backend
//!     // like this one is already correct on the batched replay hot
//!     // path at every width.  Override it only to go faster — the
//!     // override must make the same residency/cost mutations as
//!     // ascending-id scalar lookups (assert that with a
//!     // `ScalarPath`-vs-native parity test like
//!     // `tests/replay_parity.rs` / `tests/wide_parity.rs`).
//! }
//! ```
//!
//! then one arm in [`build`] to make it config-selectable.  The trait
//! invariant suite in `tests/cache_contract.rs` runs against every impl;
//! add the new backend to its constructor list.

mod flat;
mod tiered;

pub use flat::FlatMemory;
pub use tiered::TieredMemory;

use crate::cache::build_policy;
use crate::config::{CacheConfig, SimConfig, TierConfig};
use crate::tier::{NetStats, TierStats};
use crate::util::ExpertSet;
use crate::Result;

/// Outcome of one ground-truth expert lookup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lookup {
    /// Served from GPU residency (tier 0 / the flat cache).
    pub hit: bool,
    /// Demand-fetch cost of this access in µs (0 on a hit): the flat
    /// PCIe cost, or the fetch cost of the deepest tier actually reached.
    pub fetch_us: f64,
}

/// Outcome of one set-level lookup ([`ExpertMemory::lookup_set`]).
///
/// Replaces per-expert [`Lookup`] returns on the replay hot path: the
/// hit mask answers "which of the requested experts were GPU-resident"
/// in one value, and `truth.len() - hits.len()` is the miss count.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LookupBatch<const N: usize = 1> {
    /// Subset of the requested set served from GPU residency (tier 0).
    pub hits: ExpertSet<N>,
    /// Summed demand-fetch cost of the misses in µs, accumulated in
    /// ascending expert-id order (so the sum is bit-identical to the
    /// scalar loop's per-miss accumulation whenever the partial sums are
    /// exactly representable — true for the integer-valued µs costs used
    /// throughout this crate).
    pub fetch_us: f64,
}

/// Outcome of one predicted-set prefetch call.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Prefetched {
    /// Experts the predictor asked for (already-resident ones included).
    pub issued: u64,
    /// DMA transfers that landed within the per-layer budget.
    pub landed: u64,
    /// Transfers issued beyond the budget — they arrive too late to help
    /// this layer (the simulator counts these as wasted).
    pub too_late: u64,
}

/// Unified residency/cost snapshot across every backend.
#[derive(Debug, Clone, Default)]
pub struct MemoryStats {
    /// Modeled µs of demand fetches (critical path), cumulative.
    pub demand_us: f64,
    /// Modeled µs of prefetch DMA (overlapped up to the window).
    pub prefetch_us: f64,
    /// Modeled µs of DMA beyond the overlap window (critical path).
    pub stall_us: f64,
    /// Experts resident in GPU VRAM (tier 0).
    pub resident: usize,
    /// Residents per depth (single entry for flat backends).
    pub resident_per_depth: Vec<usize>,
    /// Per-tier serve/promotion/demotion counters (`None` on backends
    /// without depth structure).
    pub tiers: Option<TierStats>,
    /// Network-transfer counters (`None` on single-node backends; the
    /// cluster backend reports remote fetches, promotions and wire µs
    /// here — see [`crate::tier::NetStats`]).
    pub net: Option<NetStats>,
}

impl MemoryStats {
    /// Total modeled critical-path microseconds.  Network wire time is
    /// already folded into `demand_us` by the cluster backend, so this
    /// stays `demand + stall` for every backend.
    pub fn critical_path_us(&self) -> f64 {
        self.demand_us + self.stall_us
    }
}

/// The full expert-residency contract shared by the simulator and the
/// serving coordinator.
///
/// Call sequence per executed MoE layer:
/// 1. [`prefetch`](ExpertMemory::prefetch) the predicted set (DMA
///    overlapping the previous layer's compute, bounded by the budget),
/// 2. [`lookup`](ExpertMemory::lookup) each ground-truth expert
///    (`measured = false` during cache warm-up: residency moves, but no
///    cost or counter is recorded),
/// 3. [`end_layer`](ExpertMemory::end_layer) to close the DMA overlap
///    window (excess becomes stall time).
///
/// Per-request cost accounting brackets the sequence with
/// [`cost_marks`](ExpertMemory::cost_marks) deltas.
///
/// The trait is generic over the [`ExpertSet`] word width `N` (default
/// 1 = up to 64 experts); expert ids themselves stay `u8` at every
/// width, so the scalar [`lookup`](ExpertMemory::lookup) signature is
/// width-independent.
///
/// # Example
///
/// Drive a flat backend through one cold-miss → warm-hit cycle:
///
/// ```
/// use moe_beyond::config::{CacheConfig, SimConfig};
/// use moe_beyond::memory::{self, ExpertMemory};
///
/// let cache = CacheConfig::default().with_capacity(4);
/// let mut mem =
///     memory::build::<1>("lru", &cache, None, &SimConfig::default(), 64, 1_000.0).unwrap();
///
/// let cold = mem.lookup(0, 7, true);
/// assert!(!cold.hit && cold.fetch_us > 0.0); // demand fetch, priced
/// let warm = mem.lookup(0, 7, true);
/// assert!(warm.hit && warm.fetch_us == 0.0); // hits are always free
/// mem.end_layer();
/// assert_eq!(mem.stats().resident, 1);
/// ```
pub trait ExpertMemory<const N: usize = 1>: Send {
    /// Backend identifier for reports ("flat" | "tiered" | ...).
    fn name(&self) -> &'static str;

    /// Look up one ground-truth expert of an executed layer, admitting
    /// it into GPU residency on miss.  `measured = false` updates
    /// residency only (warm-up epoch): no cost, no counters.
    fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup;

    /// Look up an executed layer's whole ground-truth set in one call,
    /// admitting misses into GPU residency exactly as per-expert
    /// [`lookup`](ExpertMemory::lookup) calls in ascending-id order
    /// would.  The replay engines call this once per layer instead of
    /// `top_k` scalar lookups through the vtable.
    ///
    /// The default implementation delegates to scalar `lookup`, so
    /// third-party backends keep working unchanged; `FlatMemory` and
    /// `TieredMemory` provide native implementations that skip the
    /// per-expert dynamic dispatch while making the identical sequence
    /// of residency/cost mutations (the parity suites in
    /// `tests/replay_parity.rs` hold both to byte-identical stats).
    fn lookup_set(&mut self, layer: usize, truth: ExpertSet<N>, measured: bool) -> LookupBatch<N> {
        let mut out = LookupBatch::default();
        for e in truth.iter() {
            let r = self.lookup(layer, e, measured);
            if r.hit {
                out.hits.insert(e);
            } else {
                out.fetch_us += r.fetch_us;
            }
        }
        out
    }

    /// Prefetch a predicted set for `layer`, issued before the layer
    /// runs.  Already-resident experts are refreshed; at most the
    /// effective DMA budget of transfers land, the rest are too late.
    fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>) -> Prefetched;

    /// Close out a layer: DMA beyond the overlap window becomes stall
    /// time and every per-layer window resets.
    fn end_layer(&mut self);

    /// Cumulative (demand µs, stall µs) — bracket a request with two
    /// calls and subtract for per-request modeled time.
    fn cost_marks(&self) -> (f64, f64);

    /// Replace the base per-layer DMA budget (also resets the effective
    /// budget).  Clamped to at least 1.
    fn set_prefetch_budget(&mut self, budget: usize);

    /// Micro-batching divides the per-layer DMA window across the batch:
    /// effective budget = base / batch (clamped to at least 1).
    /// `set_batch_share(1)` restores the full window from any prior
    /// share — error paths rely on this being exact and idempotent.
    fn set_batch_share(&mut self, batch: usize);

    /// The currently effective per-layer DMA budget.
    fn effective_prefetch_budget(&self) -> usize;

    /// Experts resident in GPU VRAM (tier 0).
    fn resident_count(&self) -> usize;

    /// Per-tier serve counters (`None` on backends without tiers).
    fn tier_stats(&self) -> Option<&TierStats>;

    /// Unified residency/cost snapshot.
    fn stats(&self) -> MemoryStats;

    /// Drop all staged residency (cost accumulators are kept — they are
    /// cumulative across a run).
    fn clear(&mut self);

    /// Attach an observability sink: backends that implement this emit
    /// cache-access / tier-transition / prefetch trace events through
    /// it on measured paths.  The default is a no-op so third-party
    /// backends keep compiling (they simply stay silent).
    fn set_obs(&mut self, _obs: crate::obs::ObsSink) {}
}

/// Adapter that pins any backend to the trait-default scalar lookup
/// path: every `lookup_set` call expands into per-expert `lookup`s on
/// the wrapped backend, never its native batched implementation.
///
/// This is the reference side of the batched-vs-scalar parity suites
/// (`tests/replay_parity.rs`) and the baseline side of
/// `benches/replay_throughput.rs`; it is also handy when bisecting a
/// suspected batched-path bug in a third-party backend.
pub struct ScalarPath<const N: usize = 1>(Box<dyn ExpertMemory<N>>);

impl<const N: usize> ScalarPath<N> {
    pub fn new(inner: Box<dyn ExpertMemory<N>>) -> Self {
        Self(inner)
    }
}

impl<const N: usize> ExpertMemory<N> for ScalarPath<N> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        self.0.lookup(layer, expert, measured)
    }

    // lookup_set deliberately NOT overridden: the trait default expands
    // it into the scalar lookups above.

    fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>) -> Prefetched {
        self.0.prefetch(layer, predicted)
    }

    fn end_layer(&mut self) {
        self.0.end_layer()
    }

    fn cost_marks(&self) -> (f64, f64) {
        self.0.cost_marks()
    }

    fn set_prefetch_budget(&mut self, budget: usize) {
        self.0.set_prefetch_budget(budget)
    }

    fn set_batch_share(&mut self, batch: usize) {
        self.0.set_batch_share(batch)
    }

    fn effective_prefetch_budget(&self) -> usize {
        self.0.effective_prefetch_budget()
    }

    fn resident_count(&self) -> usize {
        self.0.resident_count()
    }

    fn tier_stats(&self) -> Option<&TierStats> {
        self.0.tier_stats()
    }

    fn stats(&self) -> MemoryStats {
        self.0.stats()
    }

    fn clear(&mut self) {
        self.0.clear()
    }

    fn set_obs(&mut self, obs: crate::obs::ObsSink) {
        self.0.set_obs(obs)
    }
}

/// Per-layer DMA-budget bookkeeping shared by every backend — one source
/// of truth for the base/effective clamp semantics.
#[derive(Debug, Clone)]
pub struct DmaBudget {
    base: usize,
    effective: usize,
}

impl DmaBudget {
    pub fn new(budget: usize) -> Self {
        let b = budget.max(1);
        Self {
            base: b,
            effective: b,
        }
    }

    pub fn set_base(&mut self, budget: usize) {
        self.base = budget.max(1);
        self.effective = self.base;
    }

    pub fn set_batch_share(&mut self, batch: usize) {
        self.effective = (self.base / batch.max(1)).max(1);
    }

    #[inline]
    pub fn effective(&self) -> usize {
        self.effective
    }
}

/// Build the configured [`ExpertMemory`] backend.  This is the single
/// flat-vs-tiered dispatch point in the codebase: `tier: Some(_)` selects
/// the hierarchy, otherwise the flat VRAM model.  The DMA budget comes
/// from the caller's real `SimConfig` (not a default), so the simulator
/// and the serving engine can never drift.
///
/// Width-generic: `build::<N>` (or inference from the destination type)
/// selects the [`ExpertSet`] word width; `n_experts` must fit in
/// `64 * N` bits.
pub fn build<const N: usize>(
    policy: &str,
    cache: &CacheConfig,
    tier: Option<&TierConfig>,
    sim: &SimConfig,
    n_experts: usize,
    overlap_budget_us: f64,
) -> Result<Box<dyn ExpertMemory<N>>> {
    match tier {
        Some(cfg) => Ok(Box::new(TieredMemory::<N>::new(
            cfg,
            n_experts,
            sim.prefetch_budget,
            overlap_budget_us,
        )?)),
        None => Ok(Box::new(FlatMemory::<N>::new(
            build_policy(policy, cache.capacity_experts)?,
            cache.clone(),
            n_experts,
            sim.prefetch_budget,
            overlap_budget_us,
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tier::TierSpec;

    #[test]
    fn dma_budget_clamp_and_restore() {
        let mut b = DmaBudget::new(12);
        assert_eq!(b.effective(), 12);
        b.set_batch_share(4);
        assert_eq!(b.effective(), 3);
        b.set_batch_share(1);
        assert_eq!(b.effective(), 12);
        b.set_batch_share(100);
        assert_eq!(b.effective(), 1);
        b.set_batch_share(0);
        assert_eq!(b.effective(), 12);
        b.set_base(0);
        assert_eq!(b.effective(), 1);
    }

    #[test]
    fn build_selects_backend_from_config() {
        let sim = SimConfig::default();
        let flat: Box<dyn ExpertMemory> = build(
            "lru",
            &CacheConfig::default().with_capacity(8),
            None,
            &sim,
            64,
            1_000.0,
        )
        .unwrap();
        assert_eq!(flat.name(), "flat");
        assert!(flat.tier_stats().is_none());
        assert_eq!(flat.effective_prefetch_budget(), sim.prefetch_budget);

        let tcfg = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", 4, 1.0, 0.0),
                TierSpec::new("host", 8, 100.0, 100.0),
            ],
            policy: "lru".into(),
        };
        let tiered: Box<dyn ExpertMemory> = build(
            "lru",
            &CacheConfig::default(),
            Some(&tcfg),
            &sim,
            64,
            1_000.0,
        )
        .unwrap();
        assert_eq!(tiered.name(), "tiered");
        assert!(tiered.tier_stats().is_some());
    }

    #[test]
    fn build_threads_the_callers_sim_config() {
        // the budget must come from the SimConfig actually passed, not
        // from SimConfig::default() (the config-drift bug this module
        // fixed)
        let sim = SimConfig {
            prefetch_budget: 3,
            ..Default::default()
        };
        assert_ne!(sim.prefetch_budget, SimConfig::default().prefetch_budget);
        let m: Box<dyn ExpertMemory> = build(
            "lru",
            &CacheConfig::default().with_capacity(8),
            None,
            &sim,
            64,
            1_000.0,
        )
        .unwrap();
        assert_eq!(m.effective_prefetch_budget(), 3);
    }
}
