//! [`TieredMemory`] — expert weights staged across GPU VRAM ↔ host RAM ↔
//! SSD, with promotion on access and demotion on eviction (see
//! [`crate::tier`] for the hierarchy primitives).

use crate::cache::policy;
use crate::config::TierConfig;
use crate::memory::{DmaBudget, ExpertMemory, Lookup, LookupBatch, MemoryStats, Prefetched};
use crate::obs::{ObsSink, TierMoveKind, TraceEvent};
use crate::tier::{Promotion, TierCostModel, TierStats, TieredCache};
use crate::util::ExpertSet;
use crate::Result;

/// Tiered residency: the [`TieredCache`] hierarchy, its cost model, and
/// the per-depth serve counters.
///
/// Generic over the [`ExpertSet`] word width `N` (default 1); the
/// hierarchy is keyed per expert id, so only the set-valued call
/// surfaces (`lookup_set` / `prefetch`) change shape with the width.
pub struct TieredMemory<const N: usize = 1> {
    cache: TieredCache,
    cost: TierCostModel,
    tstats: TierStats,
    n_experts: usize,
    budget: DmaBudget,
    /// Trace sink — default no-op; measured accesses emit cache-access
    /// and promote/demote/drop events when a driver attaches one.
    obs: ObsSink,
}

impl<const N: usize> TieredMemory<N> {
    pub fn new(
        cfg: &TierConfig,
        n_experts: usize,
        prefetch_budget: usize,
        overlap_budget_us: f64,
    ) -> Result<Self> {
        cfg.validate()?;
        Ok(Self {
            cache: TieredCache::build(&cfg.policy, &cfg.tiers)?,
            cost: TierCostModel::new(cfg.tiers.clone(), overlap_budget_us),
            tstats: TierStats::new(cfg.tiers.len()),
            n_experts,
            budget: DmaBudget::new(prefetch_budget),
            obs: ObsSink::default(),
        })
    }

    /// Emit the tier transitions one promotion caused: the promoted key
    /// rising to tier 0 plus every demotion of its insert chain (a
    /// `None` landing tier is a drop off the hierarchy).
    fn emit_tier_moves(&self, k: policy::ExpertKey, found: Option<usize>, promo: &Promotion) {
        if !self.obs.is_active() {
            return;
        }
        let n = self.n_experts;
        let (pl, pe) = policy::unkey(k, n);
        let from = found.unwrap_or(self.cache.deepest()) as u8;
        self.obs.emit(|ts| TraceEvent::TierMove {
            ts_us: ts,
            kind: TierMoveKind::Promote,
            layer: pl as u16,
            expert: pe,
            from,
            to: 0,
        });
        for d in &promo.demoted {
            let (dl, de) = policy::unkey(d.key, n);
            self.obs.emit(|ts| TraceEvent::TierMove {
                ts_us: ts,
                kind: if d.to.is_some() {
                    TierMoveKind::Demote
                } else {
                    TierMoveKind::Drop
                },
                layer: dl as u16,
                expert: de,
                from: d.from as u8,
                to: d.to.unwrap_or(d.from) as u8,
            });
        }
    }

    /// Shared lookup body: `lookup` is one call, `lookup_set` loops it
    /// without re-entering the vtable, so the two paths cannot drift
    /// (TierStats/TierCostModel mutations happen in the identical order).
    #[inline]
    fn lookup_one(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        let k = policy::key(layer, expert, self.n_experts);
        // promote() already handles the resident-at-GPU case as a pure
        // recency touch (found = Some(0), no demotions), so one call
        // covers both outcomes without a separate locate() scan.
        let promo = self.cache.promote(k);
        if promo.found == Some(0) {
            if measured {
                self.tstats.record_served(0);
                self.cost.on_hit();
                self.obs.emit(|ts| TraceEvent::CacheAccess {
                    ts_us: ts,
                    layer: layer as u16,
                    expert,
                    hit: true,
                    depth: 0,
                });
            }
            return Lookup {
                hit: true,
                fetch_us: 0.0,
            };
        }
        // a miss in the GPU sense: promoted from wherever the expert was
        // staged, charging the deepest tier actually reached.  Unmeasured
        // (warm-up) promotions warm the hierarchy but record nothing, so
        // every TierStats counter shares one epoch.
        let depth = promo.found.unwrap_or(self.cache.deepest());
        if measured {
            match promo.found {
                Some(d) => self.tstats.record_served(d),
                None => self.tstats.cold += 1,
            }
            self.cost.on_demand_fetch(depth);
            self.tstats.promotions += 1;
            self.cost.charge_demotions(&mut self.tstats, &promo);
            if self.obs.is_active() {
                self.obs.emit(|ts| TraceEvent::CacheAccess {
                    ts_us: ts,
                    layer: layer as u16,
                    expert,
                    hit: false,
                    depth: depth as u8,
                });
                self.emit_tier_moves(k, promo.found, &promo);
            }
        }
        Lookup {
            hit: false,
            fetch_us: self.cost.fetch_us(depth),
        }
    }
}

impl<const N: usize> ExpertMemory<N> for TieredMemory<N> {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn lookup(&mut self, layer: usize, expert: u8, measured: bool) -> Lookup {
        self.lookup_one(layer, expert, measured)
    }

    /// Native batched lookup: one virtual call per layer, hit mask built
    /// as a bitmask, same ascending-id promotion order as scalar lookups.
    fn lookup_set(&mut self, layer: usize, truth: ExpertSet<N>, measured: bool) -> LookupBatch<N> {
        let mut out = LookupBatch::default();
        for e in truth.iter() {
            let r = self.lookup_one(layer, e, measured);
            if r.hit {
                out.hits.insert(e);
            } else {
                out.fetch_us += r.fetch_us;
            }
        }
        out
    }

    fn prefetch(&mut self, layer: usize, predicted: ExpertSet<N>) -> Prefetched {
        let mut out = Prefetched::default();
        let mut landed = 0usize;
        for e in predicted.iter() {
            out.issued += 1;
            let k = policy::key(layer, e, self.n_experts);
            if self.cache.locate(k) == Some(0) {
                self.cache.touch(k);
                continue;
            }
            if landed >= self.budget.effective() {
                out.too_late += 1;
                continue;
            }
            landed += 1;
            let deepest = self.cache.deepest();
            let promo = self.cache.promote(k);
            self.cost.on_prefetch(promo.found.unwrap_or(deepest));
            self.tstats.prefetch_promotions += 1;
            self.cost.charge_demotions(&mut self.tstats, &promo);
            self.emit_tier_moves(k, promo.found, &promo);
        }
        out.landed = landed as u64;
        if out.issued > 0 {
            self.obs.emit(|ts| TraceEvent::Prefetch {
                ts_us: ts,
                layer: layer as u16,
                issued: out.issued as u32,
                landed: out.landed as u32,
                too_late: out.too_late as u32,
            });
        }
        out
    }

    fn end_layer(&mut self) {
        self.cost.end_layer();
    }

    fn cost_marks(&self) -> (f64, f64) {
        (self.cost.demand_total(), self.cost.stall_total())
    }

    fn set_prefetch_budget(&mut self, budget: usize) {
        self.budget.set_base(budget);
    }

    fn set_batch_share(&mut self, batch: usize) {
        self.budget.set_batch_share(batch);
    }

    fn effective_prefetch_budget(&self) -> usize {
        self.budget.effective()
    }

    fn resident_count(&self) -> usize {
        self.cache.len_at(0)
    }

    fn tier_stats(&self) -> Option<&TierStats> {
        Some(&self.tstats)
    }

    fn stats(&self) -> MemoryStats {
        MemoryStats {
            demand_us: self.cost.demand_total(),
            prefetch_us: self.cost.tiers.iter().map(|t| t.prefetch_us).sum(),
            stall_us: self.cost.stall_total(),
            resident: self.cache.len_at(0),
            resident_per_depth: (0..self.cache.n_tiers())
                .map(|d| self.cache.len_at(d))
                .collect(),
            tiers: Some(self.tstats.clone()),
            net: None,
        }
    }

    fn clear(&mut self) {
        self.cache.clear();
    }

    fn set_obs(&mut self, obs: ObsSink) {
        self.obs = obs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tier::TierSpec;

    fn mem(gpu: usize, host: usize, budget: usize) -> TieredMemory {
        TieredMemory::new(
            &TierConfig {
                tiers: vec![
                    TierSpec::new("gpu", gpu, 1.0, 0.0),
                    TierSpec::new("host", host, 100.0, 100.0),
                    TierSpec::new("ssd", 1728, 1000.0, 0.0),
                ],
                policy: "lru".into(),
            },
            64,
            budget,
            1_000.0,
        )
        .unwrap()
    }

    #[test]
    fn miss_charges_deepest_tier_reached() {
        let mut m = mem(2, 4, 12);
        // cold read from the backing store below the last tier
        let cold = m.lookup(0, 1, true);
        assert!(!cold.hit);
        assert_eq!(cold.fetch_us, 1000.0);
        // evict 1 to host (gpu cap 2): 2, 3 fill the GPU
        m.lookup(0, 2, true);
        m.lookup(0, 3, true);
        // now 1 is in host: served at host cost, not flash
        let host = m.lookup(0, 1, true);
        assert!(!host.hit);
        assert_eq!(host.fetch_us, 100.0);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.cold, 3);
        assert_eq!(ts.served[1], 1);
        assert!(ts.demotions >= 1);
    }

    #[test]
    fn unmeasured_lookup_warms_without_counters() {
        let mut m = mem(2, 4, 12);
        m.lookup(0, 1, false);
        m.lookup(0, 2, false);
        m.lookup(0, 3, false); // demotes 1 — still uncounted
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.cold, 0);
        assert_eq!(ts.promotions, 0);
        assert_eq!(ts.demotions, 0);
        assert_eq!(m.cost_marks(), (0.0, 0.0));
        // but residency really moved
        assert_eq!(m.resident_count(), 2);
        assert_eq!(m.stats().resident_per_depth, vec![2, 1, 0]);
    }

    #[test]
    fn prefetch_promotes_from_host_cheaply() {
        let mut m = mem(1, 4, 12);
        m.lookup(0, 1, true);
        m.lookup(0, 2, true); // 1 -> host
        let pf = m.prefetch(0, ExpertSet::from_ids([1u8]));
        assert_eq!(pf.landed, 1);
        let ts = m.tier_stats().unwrap();
        assert_eq!(ts.prefetch_promotions, 1);
        assert!(m.lookup(0, 1, true).hit);
    }

    #[test]
    fn lookup_set_matches_scalar_sequence() {
        let mut batched = mem(2, 4, 12);
        let mut scalar = mem(2, 4, 12);
        // stage the hierarchy identically: 1 demoted to host, 2/3 on GPU
        for m in [&mut batched, &mut scalar] {
            m.lookup(0, 1, true);
            m.lookup(0, 2, true);
            m.lookup(0, 3, true);
        }
        let truth = ExpertSet::from_ids([1u8, 3, 7]); // host / gpu / cold
        let b = batched.lookup_set(0, truth, true);
        let mut hits: ExpertSet = ExpertSet::new();
        let mut fetch = 0.0;
        for e in truth.iter() {
            let r = scalar.lookup(0, e, true);
            if r.hit {
                hits.insert(e);
            } else {
                fetch += r.fetch_us;
            }
        }
        assert_eq!(b.hits, hits);
        assert_eq!(b.fetch_us.to_bits(), fetch.to_bits());
        assert_eq!(batched.cost_marks(), scalar.cost_marks());
        let (bt, st) = (batched.tier_stats().unwrap(), scalar.tier_stats().unwrap());
        assert_eq!(bt.served, st.served);
        assert_eq!(bt.cold, st.cold);
        assert_eq!(bt.demotions, st.demotions);
    }

    #[test]
    fn budget_bounds_prefetch_promotions() {
        let mut m = mem(8, 8, 2);
        let pf = m.prefetch(0, ExpertSet::from_ids([1u8, 2, 3, 4, 5]));
        assert_eq!(pf.issued, 5);
        assert_eq!(pf.landed, 2);
        assert_eq!(pf.too_late, 3);
        assert_eq!(m.resident_count(), 2);
    }
}
