//! Serving metrics: lock-free counters + latency recording with
//! percentile reporting, shared across the coordinator's tasks.
//!
//! Latency recording is backed by the bounded-memory
//! [`obs::hist`](crate::obs::hist) histogram (~12.8 KB per recorder
//! regardless of sample count, <2% relative quantile error), so
//! recorders are safe at any request volume.  The exact-percentile
//! path survives as [`LatencyReport::from_samples_us`] for small-n
//! callers that keep their own samples.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::obs::hist::{AtomicHist, Hist};
use crate::obs::Registry;
use crate::util::stats::percentile;

/// Monotonic counter, relaxed ordering (hot-path safe).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency recorder over a lock-free log-bucketed histogram — bounded
/// memory at any sample count (the old mutex-guarded `Vec<f64>` grew
/// without limit, which blocked 10⁵–10⁶-stream workloads).
///
/// The backing histogram is an `Arc`, so a recorder can either own a
/// private histogram ([`Default`]) or view one registered in an
/// [`Registry`] ([`LatencyRecorder::from_handle`]) — recording through
/// either is the same atomic adds.
#[derive(Debug)]
pub struct LatencyRecorder {
    hist: Arc<AtomicHist>,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self {
            hist: Arc::new(AtomicHist::new()),
        }
    }
}

impl LatencyRecorder {
    /// Recorder over an existing histogram handle (registry-backed).
    pub fn from_handle(hist: Arc<AtomicHist>) -> Self {
        Self { hist }
    }

    pub fn record(&self, d: Duration) {
        self.hist.record(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&self, us: f64) {
        self.hist.record(us);
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    pub fn report(&self) -> LatencyReport {
        LatencyReport::from_hist(&self.hist.snapshot())
    }
}

#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencyReport {
    /// Build a report from raw µs samples — exact percentiles for
    /// callers that keep their own (small) sample vectors.
    pub fn from_samples_us(samples: &[f64]) -> Self {
        LatencyReport {
            count: samples.len(),
            mean_us: if samples.is_empty() {
                0.0
            } else {
                samples.iter().sum::<f64>() / samples.len() as f64
            },
            p50_us: percentile(samples, 50.0),
            p95_us: percentile(samples, 95.0),
            p99_us: percentile(samples, 99.0),
            // reduce, not fold(0.0, max): an all-NaN input must not
            // masquerade as 0.0 — empty is the only 0 case
            max_us: samples.iter().copied().reduce(f64::max).unwrap_or(0.0),
        }
    }

    /// Build a report from a histogram snapshot — percentiles within
    /// the bucket error bound, count/mean/max exact.
    pub fn from_hist(h: &Hist) -> Self {
        LatencyReport {
            count: h.count() as usize,
            mean_us: h.mean_us(),
            p50_us: h.quantile(50.0),
            p95_us: h.quantile(95.0),
            p99_us: h.quantile(99.0),
            max_us: h.max_us(),
        }
    }
}

impl std::fmt::Display for LatencyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.0}µs p50={:.0}µs p95={:.0}µs p99={:.0}µs max={:.0}µs",
            self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us, self.max_us
        )
    }
}

/// The coordinator's metric set.  Fields are `Arc` handles so the same
/// metrics can live inside an [`Registry`] (see
/// [`ServingMetrics::registered`]) and show up in snapshots/exposition
/// while the coordinator keeps its direct, lock-free access.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    pub requests_admitted: Arc<Counter>,
    pub requests_completed: Arc<Counter>,
    /// Requests actually dropped (never admitted).  Backpressured
    /// submissions that block and then get in are NOT rejections — they
    /// count under [`requests_backpressured`](Self::requests_backpressured).
    pub requests_rejected: Arc<Counter>,
    /// Submissions that found the queue full, blocked, and were then
    /// admitted (admission-pressure signal, not a failure).
    pub requests_backpressured: Arc<Counter>,
    pub tokens_generated: Arc<Counter>,
    pub cache_hits: Arc<Counter>,
    pub cache_misses: Arc<Counter>,
    pub prefetches: Arc<Counter>,
    /// Engines that asked for the learned predictor but came up on the
    /// EAM heuristic because the artifact failed to load
    /// ([`crate::coordinator::ModelEngine::predictor_fell_back`]).
    pub predictor_fallbacks: Arc<Counter>,
    pub request_latency: LatencyRecorder,
    pub token_latency: LatencyRecorder,
}

impl ServingMetrics {
    /// A metric set whose counters and histograms are registered in
    /// `reg`, so a registry snapshot sees everything the coordinator
    /// records.
    pub fn registered(reg: &Registry) -> Self {
        ServingMetrics {
            requests_admitted: reg.counter("serving_requests_admitted", &[]),
            requests_completed: reg.counter("serving_requests_completed", &[]),
            requests_rejected: reg.counter("serving_requests_rejected", &[]),
            requests_backpressured: reg.counter("serving_requests_backpressured", &[]),
            tokens_generated: reg.counter("serving_tokens_generated", &[]),
            cache_hits: reg.counter("serving_cache_hits", &[]),
            cache_misses: reg.counter("serving_cache_misses", &[]),
            prefetches: reg.counter("serving_prefetches", &[]),
            predictor_fallbacks: reg.counter("serving_predictor_fallbacks", &[]),
            request_latency: LatencyRecorder::from_handle(
                reg.histogram("serving_request_latency_us", &[]),
            ),
            token_latency: LatencyRecorder::from_handle(
                reg.histogram("serving_token_latency_us", &[]),
            ),
        }
    }

    pub fn cache_hit_rate(&self) -> f64 {
        let h = self.cache_hits.get();
        let m = self.cache_misses.get();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_threads() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn latency_report_percentiles() {
        let r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        let rep = r.report();
        assert_eq!(rep.count, 100);
        // exact nearest-rank p50 of 1..=100 is 51; the histogram lands
        // within its 2% bucket error of that
        assert!((rep.p50_us - 51.0).abs() <= 51.0 * 0.02 + 1e-9);
        assert!((rep.p99_us - 99.0).abs() <= 99.0 * 0.02 + 1e-9);
        assert_eq!(rep.max_us, 100.0);
    }

    #[test]
    fn hit_rate() {
        let m = ServingMetrics::default();
        m.cache_hits.add(3);
        m.cache_misses.add(1);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn recorder_tracks_exact_path_within_hist_error() {
        let samples: Vec<f64> = (1..=50).map(|x| x as f64).collect();
        let r = LatencyRecorder::default();
        for &s in &samples {
            r.record_us(s);
        }
        let a = r.report();
        let b = LatencyReport::from_samples_us(&samples);
        assert_eq!(a.count, b.count);
        // count / mean / max are exact in the histogram...
        assert!((a.mean_us - b.mean_us).abs() < 1e-6);
        assert_eq!(a.max_us, b.max_us);
        // ...percentiles are within the bucket error bound
        for (h, e) in [(a.p50_us, b.p50_us), (a.p99_us, b.p99_us)] {
            assert!((h - e).abs() <= e * 0.02 + 1e-9, "hist {h} vs exact {e}");
        }
        assert_eq!(LatencyReport::from_samples_us(&[]).count, 0);
    }

    #[test]
    fn empty_report_max_is_zero_and_nan_is_not_masked() {
        let empty = LatencyReport::from_samples_us(&[]);
        assert_eq!(empty.max_us, 0.0);
        let r = LatencyRecorder::default();
        assert_eq!(r.report().max_us, 0.0);
        // a NaN sample must surface as NaN, not silently become 0.0
        assert!(LatencyReport::from_samples_us(&[f64::NAN]).max_us.is_nan());
    }

    #[test]
    fn registered_metrics_appear_in_snapshots() {
        let reg = Registry::new();
        let m = ServingMetrics::registered(&reg);
        m.requests_admitted.inc();
        m.request_latency.record_us(150.0);
        let snap = reg.snapshot();
        let json = snap.to_json().to_json_string();
        assert!(json.contains("\"serving_requests_admitted\":1"));
        assert!(json.contains("serving_request_latency_us"));
        assert_eq!(m.request_latency.count(), 1);
    }

    #[test]
    fn backpressure_is_not_rejection() {
        let m = ServingMetrics::default();
        m.requests_backpressured.inc();
        m.requests_backpressured.inc();
        assert_eq!(m.requests_backpressured.get(), 2);
        assert_eq!(m.requests_rejected.get(), 0);
    }
}
