//! Serving metrics: lock-free counters + latency recording with
//! percentile reporting, shared across the coordinator's tasks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::percentile;

/// Monotonic counter, relaxed ordering (hot-path safe).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency recorder (mutex-guarded vec; recording happens per request,
/// not per token, so contention is negligible).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples_us: Mutex<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn record(&self, d: Duration) {
        self.samples_us.lock().unwrap().push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&self, us: f64) {
        self.samples_us.lock().unwrap().push(us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.lock().unwrap().len()
    }

    pub fn report(&self) -> LatencyReport {
        let s = self.samples_us.lock().unwrap();
        LatencyReport::from_samples_us(&s)
    }
}

#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencyReport {
    /// Build a report from raw µs samples — the path used by recorders
    /// that never touch a wall clock (the virtual-time workload
    /// simulator) as well as [`LatencyRecorder::report`].
    pub fn from_samples_us(samples: &[f64]) -> Self {
        LatencyReport {
            count: samples.len(),
            mean_us: if samples.is_empty() {
                0.0
            } else {
                samples.iter().sum::<f64>() / samples.len() as f64
            },
            p50_us: percentile(samples, 50.0),
            p95_us: percentile(samples, 95.0),
            p99_us: percentile(samples, 99.0),
            max_us: samples.iter().cloned().fold(0.0, f64::max),
        }
    }
}

impl std::fmt::Display for LatencyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.0}µs p50={:.0}µs p95={:.0}µs p99={:.0}µs max={:.0}µs",
            self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us, self.max_us
        )
    }
}

/// The coordinator's metric set.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    pub requests_admitted: Counter,
    pub requests_completed: Counter,
    /// Requests actually dropped (never admitted).  Backpressured
    /// submissions that block and then get in are NOT rejections — they
    /// count under [`requests_backpressured`](Self::requests_backpressured).
    pub requests_rejected: Counter,
    /// Submissions that found the queue full, blocked, and were then
    /// admitted (admission-pressure signal, not a failure).
    pub requests_backpressured: Counter,
    pub tokens_generated: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub prefetches: Counter,
    pub request_latency: LatencyRecorder,
    pub token_latency: LatencyRecorder,
}

impl ServingMetrics {
    pub fn cache_hit_rate(&self) -> f64 {
        let h = self.cache_hits.get();
        let m = self.cache_misses.get();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_threads() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn latency_report_percentiles() {
        let r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        let rep = r.report();
        assert_eq!(rep.count, 100);
        assert!((rep.p50_us - 50.0).abs() <= 1.0);
        assert!((rep.p99_us - 99.0).abs() <= 1.0);
        assert_eq!(rep.max_us, 100.0);
    }

    #[test]
    fn hit_rate() {
        let m = ServingMetrics::default();
        m.cache_hits.add(3);
        m.cache_misses.add(1);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn from_samples_matches_recorder() {
        let samples: Vec<f64> = (1..=50).map(|x| x as f64).collect();
        let r = LatencyRecorder::default();
        for &s in &samples {
            r.record_us(s);
        }
        let a = r.report();
        let b = LatencyReport::from_samples_us(&samples);
        assert_eq!(a.count, b.count);
        assert_eq!(a.p50_us, b.p50_us);
        assert_eq!(a.p99_us, b.p99_us);
        assert_eq!(a.mean_us, b.mean_us);
        assert_eq!(LatencyReport::from_samples_us(&[]).count, 0);
    }

    #[test]
    fn backpressure_is_not_rejection() {
        let m = ServingMetrics::default();
        m.requests_backpressured.inc();
        m.requests_backpressured.inc();
        assert_eq!(m.requests_backpressured.get(), 2);
        assert_eq!(m.requests_rejected.get(), 0);
    }
}
