//! Backbone executor: wraps the AOT `backbone_prefill` / `backbone_decode`
//! HLO modules.  Weights are device-resident; the KV state round-trips
//! host<->device per step (the §Perf pass measures this; see
//! EXPERIMENTS.md for the resident-buffer follow-up).

use anyhow::ensure;

use crate::config::{Artifacts, WorldMeta};
use crate::runtime::{Executable, PjrtRuntime, StateArg, TensorArg, WeightBlob};
use crate::Result;

/// Output of a prompt prefill.
#[derive(Debug, Clone)]
pub struct PrefillResult {
    /// Number of prompt slots this prefill processed (96 or max_seq).
    pub positions: usize,
    /// KV state [L, 2, S, H*Dh] (flattened).
    pub kv: Vec<f32>,
    /// Router decisions [L, P, top_k] (flattened i32).
    pub router_ids: Vec<i32>,
    /// Token embeddings [P, D] (flattened) — the predictor's input stream.
    pub embeddings: Vec<f32>,
    /// LM logits of the last real token [V].
    pub logits: Vec<f32>,
}

/// Output of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub kv: Vec<f32>,
    pub logits: Vec<f32>,
    /// Router decisions for this token, [L, top_k] (flattened i32).
    pub router_ids: Vec<i32>,
    /// This token's embedding [D].
    pub embedding: Vec<f32>,
}

/// Host view of one chained decode step (the KV stays on device).
#[derive(Debug, Clone)]
pub struct DecodeHead {
    pub logits: Vec<f32>,
    pub router_ids: Vec<i32>,
    pub embedding: Vec<f32>,
}

/// A device-resident decode stream: the [HEAD | KV] state buffer threads
/// from step to step without host round-trips (EXPERIMENTS.md §Perf: the
/// KV transfer dominated per-token latency before this).
pub struct DecodeSession {
    state: xla::PjRtBuffer,
}

pub struct Backbone {
    prefill_exe: Executable,
    /// Short-prompt prefill (96 slots) — fixed-shape HLO pays for padding
    /// compute, so short prompts take the small variant (§Perf).
    prefill_short_exe: Option<Executable>,
    short_len: usize,
    decode_exe: Executable,
    head_exe: Executable,
    pub world: WorldMeta,
}

impl Backbone {
    pub fn load(rt: &PjrtRuntime, arts: &Artifacts) -> Result<Self> {
        let blob = WeightBlob::load(arts.path("backbone_weights.bin"))?;
        if let Some(fp) = &blob.fingerprint {
            ensure!(
                *fp == arts.world.fingerprint,
                "backbone weights fingerprint mismatch"
            );
        }
        let params: Vec<(&[f32], &[usize])> = blob
            .params
            .iter()
            .map(|p| (&blob.data[p.offset..p.offset + p.size], p.shape.as_slice()))
            .collect();

        let mut prefill_exe =
            rt.load_hlo_text(arts.path(&arts.executable("backbone_prefill")?.path))?;
        prefill_exe.set_resident_args(rt, &params)?;
        let prefill_short_exe = match arts.executables.get("backbone_prefill_96") {
            Some(sig) => {
                let mut e = rt.load_hlo_text(arts.path(&sig.path))?;
                e.set_resident_args(rt, &params)?;
                Some(e)
            }
            None => None,
        };
        let mut decode_exe =
            rt.load_hlo_text(arts.path(&arts.executable("backbone_decode")?.path))?;
        decode_exe.set_resident_args(rt, &params)?;
        let head_exe = rt.load_hlo_text(arts.path(&arts.executable("head_extract")?.path))?;

        Ok(Self {
            prefill_exe,
            prefill_short_exe,
            short_len: 96,
            decode_exe,
            head_exe,
            world: arts.world.clone(),
        })
    }

    pub fn kv_len(&self) -> usize {
        let w = &self.world;
        w.n_layers as usize * 2 * w.max_seq as usize * (w.n_heads * w.d_head) as usize
    }

    /// Prefill the prompt (truncated to `max_seq`); returns per-token
    /// router traces + the LM logits for the first generated token.
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillResult> {
        let w = &self.world;
        let n_full = tokens.len().min(w.max_seq as usize);
        let (exe, p) = match &self.prefill_short_exe {
            Some(e) if n_full <= self.short_len => (e, self.short_len),
            _ => (&self.prefill_exe, w.max_seq as usize),
        };
        let n = n_full.min(p);
        let mut padded = vec![0i32; p];
        padded[..n].copy_from_slice(&tokens[..n]);

        let flat = exe.call_flat(&[
            TensorArg::I32(padded, vec![p]),
            TensorArg::ScalarI32(n as i32),
        ])?;
        // layout: kv | ids(as f32) | embeddings | logits (see aot.py)
        let kv_len = self.kv_len();
        let ids_len = w.n_layers as usize * p * w.top_k as usize;
        let emb_len = p * w.d_model as usize;
        let v = w.vocab_size as usize;
        ensure!(flat.len() == kv_len + ids_len + emb_len + v, "prefill output length");
        let ids_f = &flat[kv_len..kv_len + ids_len];
        Ok(PrefillResult {
            positions: p,
            kv: flat[..kv_len].to_vec(),
            router_ids: ids_f.iter().map(|&x| x as i32).collect(),
            embeddings: flat[kv_len + ids_len..kv_len + ids_len + emb_len].to_vec(),
            logits: flat[kv_len + ids_len + emb_len..].to_vec(),
        })
    }

    /// Length of the host-visible head: logits + router ids + embedding.
    pub fn head_len(&self) -> usize {
        let w = &self.world;
        w.vocab_size as usize + w.n_layers as usize * w.top_k as usize + w.d_model as usize
    }

    fn split_head(&self, head: &[f32]) -> DecodeHead {
        let w = &self.world;
        let v = w.vocab_size as usize;
        let ids_len = w.n_layers as usize * w.top_k as usize;
        DecodeHead {
            logits: head[..v].to_vec(),
            router_ids: head[v..v + ids_len].iter().map(|&x| x as i32).collect(),
            embedding: head[v + ids_len..].to_vec(),
        }
    }

    /// Boot a device-resident decode session from a prefilled KV state.
    pub fn start_decode(&self, kv: &[f32]) -> Result<DecodeSession> {
        ensure!(kv.len() == self.kv_len(), "kv state length mismatch");
        // boot state: zero head + kv (the head slots are ignored on input)
        let mut state = vec![0.0f32; self.head_len() + self.kv_len()];
        state[self.head_len()..].copy_from_slice(kv);
        // run a no-op-ish first step? No: the state is only consumed by the
        // next decode_chained call; store it host-side until then.
        Ok(DecodeSession {
            state: self.upload_state(&state)?,
        })
    }

    fn upload_state(&self, state: &[f32]) -> Result<xla::PjRtBuffer> {
        // reuse the executable's client through a tiny probe call path:
        // TensorArg upload requires a client handle, which Executable owns.
        self.decode_exe.upload_f32(state, &[state.len()])
    }

    /// One chained decode step: state stays on device, only the head
    /// (logits, router ids, embedding) is fetched.
    pub fn decode_chained(
        &self,
        sess: &mut DecodeSession,
        pos: usize,
        token: i32,
    ) -> Result<DecodeHead> {
        ensure!((pos as u32) < self.world.max_seq, "pos beyond max_seq");
        let new_state = self.decode_exe.call_chained(
            StateArg::Device(&sess.state),
            &[TensorArg::ScalarI32(pos as i32), TensorArg::ScalarI32(token)],
        )?;
        // fetch only the head, sliced on device (17 KB instead of 4.5 MB)
        let head = self.head_exe.call_on_buffers(&[&new_state])?;
        sess.state = new_state;
        Ok(self.split_head(&head))
    }

    /// One decode step via the host API (tests / non-chained callers):
    /// uploads the KV, fetches the whole new state back.
    pub fn decode_step(&self, kv: &[f32], pos: usize, token: i32) -> Result<DecodeResult> {
        ensure!(kv.len() == self.kv_len(), "kv state length mismatch");
        ensure!((pos as u32) < self.world.max_seq, "pos beyond max_seq");
        let head_len = self.head_len();
        let mut state = vec![0.0f32; head_len + self.kv_len()];
        state[head_len..].copy_from_slice(kv);
        let flat = self.decode_exe.call_flat_with_state(
            TensorArg::F32(state, vec![head_len + self.kv_len()]),
            &[TensorArg::ScalarI32(pos as i32), TensorArg::ScalarI32(token)],
        )?;
        ensure!(flat.len() == head_len + self.kv_len(), "decode output length");
        let head = self.split_head(&flat[..head_len]);
        Ok(DecodeResult {
            kv: flat[head_len..].to_vec(),
            logits: head.logits,
            router_ids: head.router_ids,
            embedding: head.embedding,
        })
    }

    /// Router ids of prefill output for (layer, token position).
    pub fn prefill_router_ids<'a>(
        &self,
        res: &'a PrefillResult,
        layer: usize,
        pos: usize,
    ) -> &'a [i32] {
        let k = self.world.top_k as usize;
        let base = (layer * res.positions + pos) * k;
        &res.router_ids[base..base + k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_and_decode_roundtrip() {
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("backbone_decode.hlo.txt").exists() {
            return;
        }
        let arts = Artifacts::discover(&root).unwrap();
        let rt = PjrtRuntime::cpu().unwrap();
        let bb = Backbone::load(&rt, &arts).unwrap();

        let tokens: Vec<i32> = (0..20).map(|i| (i * 7) % 100).collect();
        let pre = bb.prefill(&tokens).unwrap();
        assert_eq!(pre.kv.len(), bb.kv_len());
        assert_eq!(pre.logits.len(), arts.world.vocab_size as usize);

        // router ids valid + unique per (layer, pos)
        for l in [0usize, 13, 26] {
            let ids = bb.prefill_router_ids(&pre, l, 5);
            assert_eq!(ids.len(), 6);
            let set: std::collections::BTreeSet<_> = ids.iter().collect();
            assert_eq!(set.len(), 6);
            assert!(ids.iter().all(|&e| e >= 0 && e < 64));
        }

        let dec = bb.decode_step(&pre.kv, tokens.len(), 42).unwrap();
        assert_eq!(dec.kv.len(), bb.kv_len());
        assert_eq!(dec.router_ids.len(), 27 * 6);
        assert_eq!(dec.embedding.len(), 128);
        assert!(dec.logits.iter().all(|x| x.is_finite()));
        // KV must change at the written position
        assert_ne!(pre.kv, dec.kv);
    }
}
