//! The MoE backbone (DeepSeek-V2-Lite stand-in) served via PJRT.

mod backbone;
mod sampler;

pub use backbone::{Backbone, DecodeHead, DecodeResult, DecodeSession, PrefillResult};
pub use sampler::sample_token;
