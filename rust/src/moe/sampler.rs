//! LM-head sampling: greedy or temperature sampling over vocab logits.

use crate::util::{math, Rng};

/// Sample the next token id.  `temperature == 0` means greedy argmax.
pub fn sample_token(logits: &[f32], temperature: f64, rng: &mut Rng) -> i32 {
    debug_assert!(!logits.is_empty());
    if temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    let mut scaled: Vec<f32> = logits.iter().map(|&x| x / temperature as f32).collect();
    math::softmax(&mut scaled);
    let weights: Vec<f64> = scaled.iter().map(|&x| x as f64).collect();
    rng.choose_weighted(&weights) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1f32, 5.0, -1.0, 4.9];
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn temperature_samples_high_mass_tokens() {
        let mut rng = Rng::new(1);
        let mut logits = vec![-10.0f32; 16];
        logits[3] = 8.0;
        logits[7] = 7.5;
        let mut counts = [0u32; 16];
        for _ in 0..500 {
            counts[sample_token(&logits, 1.0, &mut rng) as usize] += 1;
        }
        assert!(counts[3] + counts[7] > 480);
        assert!(counts[3] > counts[7]);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(2);
        let logits = [1.0f32, 1.2, 0.8];
        let mut greedy_hits = 0;
        for _ in 0..200 {
            if sample_token(&logits, 0.05, &mut rng) == 1 {
                greedy_hits += 1;
            }
        }
        assert!(greedy_hits > 195);
    }
}
