//! Log-bucketed, mergeable, bounded-memory latency histogram — the one
//! percentile implementation behind [`crate::metrics::LatencyReport`],
//! the workload SLO accumulators, and bench reporting.
//!
//! # Bucketing scheme
//!
//! Buckets are derived branch-free from the f64 bit pattern: the
//! exponent selects a power-of-two octave and the top [`SUB_BITS`]
//! mantissa bits split each octave into [`SUBBUCKETS`] equal-width
//! sub-buckets.  Quantiles report the clamped bucket midpoint, so the
//! worst-case relative error is `1 / (2 * SUBBUCKETS) = 1/64 ≈ 1.6%` —
//! inside the <2% budget.  The covered range is
//! `[2^MIN_EXP, 2^MAX_EXP)` µs (≈ 1 ns to ≈ 12 days); values outside
//! collapse into the first/last bucket, and the exact `min`/`max` are
//! tracked separately so the tails never report an impossible value.
//!
//! The ~1.6% error budget costs more buckets than the naive "~100
//! buckets" target (50 octaves × 32 = [`N_BUCKETS`] = 1600, ≈ 12.8 KB
//! of `u64` counts): memory per recorder is still fixed and small,
//! which is the property that matters at the ROADMAP's 10⁵–10⁶-stream
//! scale — the unbounded `Vec<f64>` recorders this replaces grew
//! linearly with traffic.
//!
//! # Determinism and merge associativity
//!
//! Counts are integers, the running sum is stored in integer
//! **nanoseconds** (`u64`), and min/max are exact sample values —
//! so [`Hist::merge`] is exactly associative and commutative
//! (integer adds), and every derived statistic is a pure function of
//! the bucket state.  Two runs that record the same sample sequence
//! serialize to byte-identical JSON, the discipline the CI perf gate
//! builds on.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the per-octave sub-bucket count.
pub const SUB_BITS: u32 = 5;
/// Sub-buckets per power-of-two octave.
pub const SUBBUCKETS: usize = 1 << SUB_BITS;
/// Smallest distinguishable value (2^-10 µs ≈ 1 ns); below this (and
/// for zero / negative / non-finite inputs) samples land in bucket 0.
pub const MIN_EXP: i32 = -10;
/// Upper bound exponent: values ≥ 2^40 µs (≈ 12.7 days) clamp into the
/// last bucket.
pub const MAX_EXP: i32 = 40;
/// Total bucket count (fixed: bounded memory per recorder).
pub const N_BUCKETS: usize = ((MAX_EXP - MIN_EXP) as usize) << SUB_BITS;

/// 2^MIN_EXP as f64 (exact).
const MIN_VALUE: f64 = 0.0009765625;
/// 2^MAX_EXP as f64 (exact).
const MAX_VALUE: f64 = 1_099_511_627_776.0;

/// Bucket index of a sample (µs).  Non-finite and non-positive inputs
/// map to bucket 0 — recorders feed latencies, which are ≥ 0 by
/// construction, so this is a containment rule rather than a hot case.
#[inline]
pub fn bucket_of(v: f64) -> usize {
    if !(v >= MIN_VALUE) {
        return 0; // also catches NaN (comparison is false)
    }
    if v >= MAX_VALUE {
        return N_BUCKETS - 1;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i32 - 1023;
    let sub = ((bits >> (52 - SUB_BITS)) & (SUBBUCKETS as u64 - 1)) as usize;
    ((((exp - MIN_EXP) as usize) << SUB_BITS) | sub).min(N_BUCKETS - 1)
}

/// `[lo, hi)` bounds of a bucket.  Exact binary fractions (the octave
/// base is built straight from the exponent bits), so bounds and
/// midpoints are bit-deterministic across platforms.
#[inline]
pub fn bucket_bounds(idx: usize) -> (f64, f64) {
    let octave = idx >> SUB_BITS;
    let sub = (idx & (SUBBUCKETS - 1)) as f64;
    let base = f64::from_bits(((1023 + MIN_EXP + octave as i32) as u64) << 52);
    let width = base / SUBBUCKETS as f64;
    let lo = base + sub * width;
    (lo, lo + width)
}

/// Plain (single-threaded) histogram accumulator: the workload
/// simulator's per-tenant SLO series and every snapshot/merge path use
/// this form.  `counts` is lazily allocated so an empty accumulator is
/// one pointer, not 12.8 KB.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Hist {
    counts: Vec<u64>,
    count: u64,
    /// Sample sum in integer nanoseconds: u64 adds keep merge exactly
    /// associative where an f64 sum would not be.
    sum_ns: u64,
    min: f64,
    max: f64,
}

/// Round a µs sample to integer nanoseconds for the associative sum.
#[inline]
fn to_ns(us: f64) -> u64 {
    if us.is_finite() && us > 0.0 {
        (us * 1e3).round() as u64
    } else {
        0
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample (µs).
    #[inline]
    pub fn record(&mut self, us: f64) {
        if self.counts.is_empty() {
            self.counts = vec![0u64; N_BUCKETS];
        }
        let v = if us.is_finite() && us > 0.0 { us } else { 0.0 };
        self.counts[bucket_of(v)] += 1;
        self.sum_ns += to_ns(v);
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            if v < self.min {
                self.min = v;
            }
            if v > self.max {
                self.max = v;
            }
        }
        self.count += 1;
    }

    /// Merge another histogram in (exactly associative: integer adds,
    /// exact min/max).
    pub fn merge(&mut self, other: &Hist) {
        if other.count == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0u64; N_BUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.sum_ns += other.sum_ns;
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.count += other.count;
    }

    /// Per-bucket saturating subtraction of a baseline (snapshot
    /// diffing).  The delta's min/max are not recoverable from bucket
    /// state, so they keep `self`'s values — interpret them as
    /// whole-run extremes, not interval extremes.
    pub fn diff(&self, baseline: &Hist) -> Hist {
        let mut out = self.clone();
        if baseline.count == 0 {
            return out;
        }
        if out.counts.is_empty() {
            out.counts = vec![0u64; N_BUCKETS];
        }
        for (a, b) in out.counts.iter_mut().zip(baseline.counts.iter()) {
            *a = a.saturating_sub(*b);
        }
        out.count = self.count.saturating_sub(baseline.count);
        out.sum_ns = self.sum_ns.saturating_sub(baseline.sum_ns);
        out
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> f64 {
        self.sum_ns as f64 / 1e3
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us() / self.count as f64
        }
    }

    /// Exact smallest sample (0 when empty — explicit, never NaN).
    pub fn min_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest sample (0 when empty — explicit, never NaN).
    pub fn max_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile (same rank convention as
    /// [`crate::util::stats::percentile`]), reported as the bucket
    /// midpoint clamped to the exact observed `[min, max]`.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = (p / 100.0).clamp(0.0, 1.0);
        let rank = (q * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                let (lo, hi) = bucket_bounds(i);
                return (lo + (hi - lo) * 0.5).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Lock-free histogram for `&self` recording across threads (the
/// serving coordinator's [`crate::metrics::LatencyRecorder`] and
/// registry histograms).  All operations are `Relaxed`: recorders are
/// statistically merged counters, not synchronization points.
#[derive(Debug)]
pub struct AtomicHist {
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    /// f64 bit patterns; for non-negative floats the u64 bit order
    /// matches the numeric order, so `fetch_min`/`fetch_max` work.
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHist {
    pub fn new() -> Self {
        let counts: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Self {
            counts: counts.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            min_bits: AtomicU64::new(u64::MAX),
            max_bits: AtomicU64::new(0),
        }
    }

    /// Record one latency sample (µs); lock-free.
    #[inline]
    pub fn record(&self, us: f64) {
        let v = if us.is_finite() && us > 0.0 { us } else { 0.0 };
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(to_ns(v), Ordering::Relaxed);
        self.min_bits.fetch_min(v.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(v.to_bits(), Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy as a plain [`Hist`].  Buckets are loaded
    /// individually (not one atomic cut), which is exact whenever no
    /// recorder is mid-flight — the report/snapshot points in this
    /// crate — and merely approximate under concurrent recording.
    pub fn snapshot(&self) -> Hist {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return Hist::default();
        }
        Hist {
            counts: self
                .counts
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            count,
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            min: f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;
    use crate::util::Rng;

    #[test]
    fn bucket_bounds_contain_their_values() {
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            // span the whole covered range: 2^-10 .. 2^40
            let e = rng.below(50) as i32 - 10;
            let frac = 1.0 + rng.below(1000) as f64 / 1000.0;
            let v = frac * f64::from_bits(((1023 + e) as u64) << 52);
            let idx = bucket_of(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && v < hi, "v={v} idx={idx} lo={lo} hi={hi}");
            // relative midpoint error within the 2% budget
            let mid = lo + (hi - lo) * 0.5;
            assert!((mid - v).abs() / v <= 1.0 / 64.0 + 1e-12);
        }
    }

    #[test]
    fn out_of_range_values_clamp() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-5.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(1e-9), 0);
        assert_eq!(bucket_of(f64::INFINITY), N_BUCKETS - 1);
        assert_eq!(bucket_of(1e300), N_BUCKETS - 1);
    }

    #[test]
    fn empty_hist_reports_zeroes_explicitly() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.min_us(), 0.0);
        assert_eq!(h.max_us(), 0.0);
        assert_eq!(h.quantile(95.0), 0.0);
    }

    #[test]
    fn exact_min_max_and_mean() {
        let mut h = Hist::new();
        for v in [3.0, 7.5, 100.0, 0.25] {
            h.record(v);
        }
        assert_eq!(h.min_us(), 0.25);
        assert_eq!(h.max_us(), 100.0);
        assert!((h.mean_us() - (3.0 + 7.5 + 100.0 + 0.25) / 4.0).abs() < 1e-9);
    }

    /// Quantiles agree with the exact sort-based percentile within the
    /// histogram's error bound — the cross-check the dedupe satellite
    /// asks for.
    #[test]
    fn prop_quantile_error_bound_vs_exact_percentile() {
        let mut rng = Rng::new(17);
        for _case in 0..60 {
            let n = rng.range(1, 400);
            let scale = [1.0, 100.0, 10_000.0][rng.below(3)];
            let samples: Vec<f64> = (0..n)
                .map(|_| (1 + rng.below(100_000)) as f64 / 100.0 * scale)
                .collect();
            let mut h = Hist::new();
            for &s in &samples {
                h.record(s);
            }
            for p in [0.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                let exact = percentile(&samples, p);
                let approx = h.quantile(p);
                assert!(
                    (approx - exact).abs() <= exact * 0.02 + 1e-9,
                    "p{p}: hist {approx} vs exact {exact} (n={n})"
                );
            }
        }
    }

    /// Merge is exactly associative and commutative (integer state).
    #[test]
    fn prop_merge_associative_and_commutative() {
        let mut rng = Rng::new(29);
        for _case in 0..40 {
            let mk = |rng: &mut Rng| {
                let mut h = Hist::new();
                for _ in 0..rng.range(0, 50) {
                    h.record((1 + rng.below(1_000_000)) as f64 / 7.0);
                }
                h
            };
            let (a, b, c) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            // (a + b) + c
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // a + (b + c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_eq!(left, right);
            // b + a == a + b
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba);
        }
    }

    #[test]
    fn diff_subtracts_counts() {
        let mut base = Hist::new();
        base.record(10.0);
        let mut now = base.clone();
        now.record(20.0);
        now.record(30.0);
        let d = now.diff(&base);
        assert_eq!(d.count(), 2);
        assert!((d.sum_us() - 50.0).abs() < 1e-9);
        assert_eq!(now.diff(&now).count(), 0);
    }

    #[test]
    fn atomic_matches_plain() {
        let a = AtomicHist::new();
        let mut p = Hist::new();
        let mut rng = Rng::new(41);
        for _ in 0..500 {
            let v = (1 + rng.below(500_000)) as f64 / 13.0;
            a.record(v);
            p.record(v);
        }
        assert_eq!(a.snapshot(), p);
    }

    #[test]
    fn atomic_recording_is_thread_safe() {
        let h = std::sync::Arc::new(AtomicHist::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.record((t * 1000 + i) as f64 + 1.0);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 4000);
        assert_eq!(s.max_us(), 4000.0);
        assert_eq!(s.min_us(), 1.0);
    }
}
