//! Unified observability layer: bounded-memory histograms
//! ([`hist`]), a labeled metric registry ([`registry`]), and
//! structured event tracing ([`trace`]) behind one cheap façade,
//! [`ObsSink`].
//!
//! Every execution surface (replay engine, workload scheduler, memory
//! backends, serving coordinator) takes an `ObsSink`.  The default
//! sink is a no-op: a `None` behind one pointer-sized `Option`, so the
//! hot path pays a single predictable branch and builds no event
//! values (`emit` takes a closure that is never called).  The active
//! sink carries a [`Registry`] for metrics and a [`TraceRing`] for
//! events, timestamped from a clock cell that the driving loop sets
//! (virtual µs in sim/workload, wall-clock µs in the coordinator).
//!
//! Determinism: with a virtual clock, every recorded value is a pure
//! function of the run's inputs, and both exposition formats iterate
//! sorted maps — two identical seeded runs produce byte-identical
//! trace and metrics JSON.  CI byte-compares exactly that.
//!
//! # Adding a metric
//!
//! Grab a handle once at wiring time, then record through the handle —
//! never look up the registry on the hot path:
//!
//! ```
//! use moe_beyond::obs::ObsSink;
//!
//! let obs = ObsSink::active(1 << 16, "virtual");
//! // wiring time: one lock, one allocation
//! let (evictions, depth_us) = {
//!     let reg = obs.registry().unwrap();
//!     (
//!         reg.counter("evictions", &[("tier", "gpu")]),
//!         reg.histogram("fault_us", &[("tier", "gpu")]),
//!     )
//! };
//! // hot path: lock-free atomics
//! evictions.inc();
//! depth_us.record(137.5);
//! let snap = obs.snapshot().unwrap();
//! assert!(snap.to_json().to_json_string().contains("evictions{tier=gpu}"));
//! ```

pub mod hist;
pub mod registry;
pub mod trace;

pub use hist::{AtomicHist, Hist};
pub use registry::{Gauge, Registry, SnapValue, Snapshot};
pub use trace::{chrome_trace_json, TierMoveKind, TraceEvent, TraceRing};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Default trace-ring capacity (events retained before overwrite).
pub const DEFAULT_RING_CAP: usize = 1 << 16;

/// Shared state behind an active sink.
#[derive(Debug)]
pub struct ActiveObs {
    registry: Registry,
    ring: Mutex<TraceRing>,
    /// Current timestamp (f64 bits) — set by the driving loop, read by
    /// every emission between clock updates.
    now_bits: AtomicU64,
    /// `"virtual"` or `"wall"`; recorded in exported trace metadata.
    clock: &'static str,
}

/// Cloneable observability handle.  `ObsSink::default()` is the no-op
/// sink; [`ObsSink::active`] turns everything on.  Clones share the
/// same registry, ring, and clock.
#[derive(Debug, Clone, Default)]
pub struct ObsSink(Option<Arc<ActiveObs>>);

impl ObsSink {
    /// The no-op sink (same as `default()`): every method early-returns.
    pub fn noop() -> Self {
        Self(None)
    }

    /// An active sink with a `ring_cap`-event trace ring; `clock` names
    /// the timestamp source (`"virtual"` or `"wall"`).
    pub fn active(ring_cap: usize, clock: &'static str) -> Self {
        Self(Some(Arc::new(ActiveObs {
            registry: Registry::new(),
            ring: Mutex::new(TraceRing::new(ring_cap)),
            now_bits: AtomicU64::new(0f64.to_bits()),
            clock,
        })))
    }

    #[inline]
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// The sink's registry, for grabbing metric handles at wiring time.
    pub fn registry(&self) -> Option<&Registry> {
        self.0.as_deref().map(|a| &a.registry)
    }

    /// Advance the sink's clock; subsequent emissions are stamped `t`.
    #[inline]
    pub fn set_now_us(&self, t: f64) {
        if let Some(a) = &self.0 {
            a.now_bits.store(t.to_bits(), Ordering::Relaxed);
        }
    }

    /// Current clock reading (0 when inactive or never set).
    pub fn now_us(&self) -> f64 {
        match &self.0 {
            Some(a) => f64::from_bits(a.now_bits.load(Ordering::Relaxed)),
            None => 0.0,
        }
    }

    /// Push one trace event.  The closure receives the current
    /// timestamp and only runs on an active sink, so the no-op path
    /// constructs nothing.
    #[inline]
    pub fn emit(&self, f: impl FnOnce(f64) -> TraceEvent) {
        if let Some(a) = &self.0 {
            let ts = f64::from_bits(a.now_bits.load(Ordering::Relaxed));
            a.ring.lock().unwrap().push(f(ts));
        }
    }

    /// Events lost to ring overwrites so far (0 when inactive).
    pub fn dropped_events(&self) -> u64 {
        match &self.0 {
            Some(a) => a.ring.lock().unwrap().dropped(),
            None => 0,
        }
    }

    /// Point-in-time metric snapshot.
    pub fn snapshot(&self) -> Option<Snapshot> {
        self.0.as_deref().map(|a| a.registry.snapshot())
    }

    /// Chrome trace-event JSON of the retained events.
    pub fn trace_json(&self) -> Option<Json> {
        self.0
            .as_deref()
            .map(|a| chrome_trace_json(&a.ring.lock().unwrap(), a.clock))
    }

    /// Deterministic JSON exposition of the current metric state.
    pub fn metrics_json(&self) -> Option<Json> {
        self.snapshot().map(|s| s.to_json())
    }

    /// Prometheus text exposition of the current metric state.
    pub fn metrics_prometheus(&self) -> Option<String> {
        self.snapshot().map(|s| s.to_prometheus())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_sink_swallows_everything() {
        let obs = ObsSink::default();
        assert!(!obs.is_active());
        obs.set_now_us(100.0);
        obs.emit(|_| panic!("noop sink must not build events"));
        assert_eq!(obs.now_us(), 0.0);
        assert_eq!(obs.dropped_events(), 0);
        assert!(obs.registry().is_none());
        assert!(obs.trace_json().is_none());
        assert!(obs.metrics_json().is_none());
    }

    #[test]
    fn active_sink_stamps_events_with_the_set_clock() {
        let obs = ObsSink::active(8, "virtual");
        obs.set_now_us(42.0);
        obs.emit(|ts| TraceEvent::Prefetch {
            ts_us: ts,
            layer: 1,
            issued: 2,
            landed: 2,
            too_late: 0,
        });
        obs.set_now_us(99.0);
        obs.emit(|ts| TraceEvent::RequestBegin {
            ts_us: ts,
            request: 0,
            tenant: 0,
        });
        let j = obs.trace_json().unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs[0].get("ts").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(evs[1].get("ts").unwrap().as_f64().unwrap(), 99.0);
        assert_eq!(
            j.get("metadata").unwrap().get("clock").unwrap().as_str().unwrap(),
            "virtual"
        );
    }

    #[test]
    fn clones_share_state() {
        let obs = ObsSink::active(8, "wall");
        let c = obs.registry().unwrap().counter("x", &[]);
        let clone = obs.clone();
        clone.registry().unwrap().counter("x", &[]).add(2);
        assert_eq!(c.get(), 2);
        clone.emit(|ts| TraceEvent::RequestEnd {
            ts_us: ts,
            request: 1,
            tenant: 0,
        });
        let evs = obs.trace_json().unwrap();
        assert_eq!(
            evs.get("metadata")
                .unwrap()
                .get("total_events")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
    }
}
