//! Labeled metric registry: counters, gauges, and histograms keyed by
//! `name{label=value,…}`, with point-in-time snapshots, snapshot
//! diffing, and deterministic JSON + Prometheus-text exposition.
//!
//! Handles are `Arc`s grabbed once at wiring time; the hot path then
//! touches only atomics (no registry lock).  Snapshots are ordered
//! `BTreeMap`s, so both exposition formats are byte-deterministic for
//! identical metric state — the property the CI obs gate byte-compares.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::Counter;
use crate::obs::hist::{AtomicHist, Hist};
use crate::util::json::Json;

/// Last-write-wins f64 cell (resident counts, rates, clock readings).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Metric identity: name plus label pairs sorted by label key.  The
/// `Ord` of the tuple is the exposition order.
pub type MetricKey = (String, Vec<(String, String)>);

fn make_key(name: &str, labels: &[(&str, &str)]) -> MetricKey {
    let mut l: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    l.sort();
    (name.to_string(), l)
}

/// `name{k=v,…}` rendering used as the JSON object key.
pub fn key_string(key: &MetricKey) -> String {
    if key.1.is_empty() {
        return key.0.clone();
    }
    let labels: Vec<String> = key.1.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("{}{{{}}}", key.0, labels.join(","))
}

#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<AtomicHist>),
}

/// The registry proper.  Registration takes a lock (wiring time only);
/// recording through the returned handles is lock-free.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<MetricKey, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Counter handle for `name{labels}`, created on first use.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = make_key(name, labels);
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key)
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered with a different type"),
        }
    }

    /// Gauge handle for `name{labels}`, created on first use.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = make_key(name, labels);
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key)
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered with a different type"),
        }
    }

    /// Histogram handle for `name{labels}`, created on first use.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<AtomicHist> {
        let key = make_key(name, labels);
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key)
            .or_insert_with(|| Metric::Hist(Arc::new(AtomicHist::new())))
        {
            Metric::Hist(h) => h.clone(),
            _ => panic!("metric '{name}' already registered with a different type"),
        }
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> Snapshot {
        let m = self.metrics.lock().unwrap();
        Snapshot {
            entries: m
                .iter()
                .map(|(k, v)| {
                    let val = match v {
                        Metric::Counter(c) => SnapValue::Counter(c.get()),
                        Metric::Gauge(g) => SnapValue::Gauge(g.get()),
                        Metric::Hist(h) => SnapValue::Hist(h.snapshot()),
                    };
                    (k.clone(), val)
                })
                .collect(),
        }
    }
}

/// One metric's value at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapValue {
    Counter(u64),
    Gauge(f64),
    Hist(Hist),
}

/// Point-in-time registry state; the unit of exposition and diffing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub entries: BTreeMap<MetricKey, SnapValue>,
}

impl Snapshot {
    /// Interval view: counters and histogram buckets subtract the
    /// baseline (saturating), gauges keep their current value.  Metrics
    /// absent from the baseline pass through unchanged.
    pub fn diff(&self, baseline: &Snapshot) -> Snapshot {
        Snapshot {
            entries: self
                .entries
                .iter()
                .map(|(k, v)| {
                    let val = match (v, baseline.entries.get(k)) {
                        (SnapValue::Counter(c), Some(SnapValue::Counter(b))) => {
                            SnapValue::Counter(c.saturating_sub(*b))
                        }
                        (SnapValue::Hist(h), Some(SnapValue::Hist(b))) => {
                            SnapValue::Hist(h.diff(b))
                        }
                        _ => v.clone(),
                    };
                    (k.clone(), val)
                })
                .collect(),
        }
    }

    /// Deterministic JSON exposition: one object keyed by
    /// `name{label=value,…}`, histograms expanded to their summary
    /// statistics.  Sorted keys + the crate's canonical number
    /// formatting make the output byte-stable.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (k, v) in &self.entries {
            let val = match v {
                SnapValue::Counter(c) => Json::num(*c as f64),
                SnapValue::Gauge(g) => Json::num(*g),
                SnapValue::Hist(h) => Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("sum_us", Json::num(h.sum_us())),
                    ("mean_us", Json::num(h.mean_us())),
                    ("p50_us", Json::num(h.quantile(50.0))),
                    ("p95_us", Json::num(h.quantile(95.0))),
                    ("p99_us", Json::num(h.quantile(99.0))),
                    ("min_us", Json::num(h.min_us())),
                    ("max_us", Json::num(h.max_us())),
                ]),
            };
            obj.insert(key_string(k), val);
        }
        Json::Obj(obj)
    }

    /// Prometheus text exposition (summary style for histograms:
    /// quantile series plus `_count` and `_sum`).
    pub fn to_prometheus(&self) -> String {
        fn labels_text(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
            let mut parts: Vec<String> = labels
                .iter()
                .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
                .collect();
            if let Some((k, v)) = extra {
                parts.push(format!("{k}=\"{v}\""));
            }
            if parts.is_empty() {
                String::new()
            } else {
                format!("{{{}}}", parts.join(","))
            }
        }
        let mut out = String::new();
        for ((name, labels), v) in &self.entries {
            match v {
                SnapValue::Counter(c) => {
                    let _ = writeln!(out, "{name}{} {c}", labels_text(labels, None));
                }
                SnapValue::Gauge(g) => {
                    let _ = writeln!(out, "{name}{} {g}", labels_text(labels, None));
                }
                SnapValue::Hist(h) => {
                    for (q, qs) in [(50.0, "0.5"), (95.0, "0.95"), (99.0, "0.99")] {
                        let _ = writeln!(
                            out,
                            "{name}{} {}",
                            labels_text(labels, Some(("quantile", qs))),
                            h.quantile(q)
                        );
                    }
                    let _ = writeln!(out, "{name}_count{} {}", labels_text(labels, None), h.count());
                    let _ = writeln!(out, "{name}_sum{} {}", labels_text(labels, None), h.sum_us());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_and_live() {
        let r = Registry::new();
        let a = r.counter("hits", &[("tier", "gpu")]);
        let b = r.counter("hits", &[("tier", "gpu")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let s = r.snapshot();
        assert_eq!(
            s.entries.values().next(),
            Some(&SnapValue::Counter(3))
        );
    }

    #[test]
    fn label_order_is_canonical() {
        let r = Registry::new();
        let a = r.counter("m", &[("b", "2"), ("a", "1")]);
        let b = r.counter("m", &[("a", "1"), ("b", "2")]);
        a.inc();
        assert_eq!(b.get(), 1); // same metric regardless of label order
        let s = r.snapshot();
        let key = s.entries.keys().next().unwrap();
        assert_eq!(key_string(key), "m{a=1,b=2}");
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("x", &[]);
        let _ = r.gauge("x", &[]);
    }

    #[test]
    fn snapshot_diff_subtracts_counters_keeps_gauges() {
        let r = Registry::new();
        let c = r.counter("reqs", &[]);
        let g = r.gauge("resident", &[]);
        let h = r.histogram("lat_us", &[]);
        c.add(5);
        g.set(10.0);
        h.record(100.0);
        let base = r.snapshot();
        c.add(3);
        g.set(20.0);
        h.record(200.0);
        let d = r.snapshot().diff(&base);
        let vals: Vec<&SnapValue> = d.entries.values().collect();
        match vals[1] {
            SnapValue::Counter(n) => assert_eq!(*n, 3),
            v => panic!("unexpected {v:?}"),
        }
        match vals[2] {
            SnapValue::Gauge(v) => assert_eq!(*v, 20.0),
            v => panic!("unexpected {v:?}"),
        }
        match vals[0] {
            SnapValue::Hist(hd) => assert_eq!(hd.count(), 1),
            v => panic!("unexpected {v:?}"),
        }
    }

    #[test]
    fn exposition_is_deterministic() {
        let build = || {
            let r = Registry::new();
            r.counter("b_total", &[("tenant", "chat")]).add(7);
            r.gauge("a_gauge", &[]).set(1.5);
            let h = r.histogram("lat_us", &[("policy", "fcfs")]);
            for v in [10.0, 20.0, 30.0] {
                h.record(v);
            }
            r.snapshot()
        };
        let (s1, s2) = (build(), build());
        assert_eq!(s1.to_json().to_json_string(), s2.to_json().to_json_string());
        assert_eq!(s1.to_prometheus(), s2.to_prometheus());
        let prom = s1.to_prometheus();
        assert!(prom.contains("b_total{tenant=\"chat\"} 7"));
        assert!(prom.contains("lat_us{policy=\"fcfs\",quantile=\"0.5\"}"));
        assert!(prom.contains("lat_us_count{policy=\"fcfs\"} 3"));
        let json = s1.to_json().to_json_string();
        assert!(json.contains("\"b_total{tenant=chat}\":7"));
    }
}
