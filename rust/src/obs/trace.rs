//! Structured event tracing into a bounded ring buffer, exported as
//! Chrome trace-event JSON (loadable in `chrome://tracing` / Perfetto).
//!
//! Events are timestamped by *virtual* microseconds in the sim and
//! workload paths and by wall-clock microseconds in the coordinator —
//! the ring itself is clock-agnostic; the exporter records which clock
//! produced the timestamps in the trace metadata.  When the ring is
//! full the oldest events are overwritten and `dropped()` accounts for
//! every overwrite, so a truncated trace is always detectable.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Direction of a tier transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierMoveKind {
    /// Expert moved to a faster tier (e.g. host → GPU).
    Promote,
    /// Expert displaced to a slower tier.
    Demote,
    /// Expert fell off the deepest bounded tier entirely.
    Drop,
}

impl TierMoveKind {
    pub fn id(&self) -> &'static str {
        match self {
            TierMoveKind::Promote => "promote",
            TierMoveKind::Demote => "demote",
            TierMoveKind::Drop => "drop",
        }
    }
}

/// One traced occurrence.  Timestamps are µs on the emitting surface's
/// clock; `request` ids are stable within a run (prompt id in replay,
/// request id in workload/serving).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request entered execution (admission in workload, dispatch in
    /// serving, prompt start in replay).
    RequestBegin { ts_us: f64, request: u64, tenant: u32 },
    /// The request produced its last token.
    RequestEnd { ts_us: f64, request: u64, tenant: u32 },
    /// One measured decode step; `ts_us` is the step start and
    /// `cost_us` its modeled (or measured) duration.
    DecodeStep {
        ts_us: f64,
        request: u64,
        tenant: u32,
        token: u32,
        cost_us: f64,
    },
    /// A routed expert was looked up: served from `depth` (0 = fastest
    /// tier) on a hit, faulted from `depth` on a miss.
    CacheAccess {
        ts_us: f64,
        layer: u16,
        expert: u8,
        hit: bool,
        depth: u8,
    },
    /// An expert crossed tiers (`from`/`to` are tier depths; `to` is
    /// meaningless for `Drop`).
    TierMove {
        ts_us: f64,
        kind: TierMoveKind,
        layer: u16,
        expert: u8,
        from: u8,
        to: u8,
    },
    /// One prefetch batch: `issued` requested, `landed` arrived in
    /// budget, `too_late` charged a partial stall.
    Prefetch {
        ts_us: f64,
        layer: u16,
        issued: u32,
        landed: u32,
        too_late: u32,
    },
    /// A routed expert was served by a remote cluster node: `hit` means
    /// the owner had it GPU-resident (activations travelled), otherwise
    /// the owner faulted the weights first; `wire_us` is the link time
    /// charged to the critical path.
    RemoteFetch {
        ts_us: f64,
        node: u8,
        layer: u16,
        expert: u8,
        hit: bool,
        wire_us: f64,
    },
    /// A cluster node went down (fault injection); later lookups it
    /// owned fail over to another replica or the ring.
    NodeDown { ts_us: f64, node: u8 },
    /// A cluster node recovered from a transient outage (cold cache).
    NodeUp { ts_us: f64, node: u8 },
    /// The link to a cluster node flapped: `up: false` when it drops,
    /// `up: true` when it returns (the node itself stayed warm).
    LinkFlap { ts_us: f64, node: u8, up: bool },
    /// A lookup whose rank-0 owner was unreachable was served by another
    /// replica (`node` is the replica that served).
    ReplicaFailover {
        ts_us: f64,
        node: u8,
        layer: u16,
        expert: u8,
    },
    /// A remote fetch attempt blew its deadline and was retried on
    /// `node` (the next-cheapest alive replica); `attempt` counts
    /// retries of this lookup, driving the exponential backoff.
    RemoteRetry {
        ts_us: f64,
        node: u8,
        layer: u16,
        expert: u8,
        attempt: u8,
    },
    /// Every replica of the expert was unreachable: the lookup degraded
    /// to a deepest-tier demand load on `node` (the ring-scan fallback).
    DegradedFetch {
        ts_us: f64,
        node: u8,
        layer: u16,
        expert: u8,
    },
}

impl TraceEvent {
    pub fn ts_us(&self) -> f64 {
        match self {
            TraceEvent::RequestBegin { ts_us, .. }
            | TraceEvent::RequestEnd { ts_us, .. }
            | TraceEvent::DecodeStep { ts_us, .. }
            | TraceEvent::CacheAccess { ts_us, .. }
            | TraceEvent::TierMove { ts_us, .. }
            | TraceEvent::Prefetch { ts_us, .. }
            | TraceEvent::RemoteFetch { ts_us, .. }
            | TraceEvent::NodeDown { ts_us, .. }
            | TraceEvent::NodeUp { ts_us, .. }
            | TraceEvent::LinkFlap { ts_us, .. }
            | TraceEvent::ReplicaFailover { ts_us, .. }
            | TraceEvent::RemoteRetry { ts_us, .. }
            | TraceEvent::DegradedFetch { ts_us, .. } => *ts_us,
        }
    }
}

/// Fixed-capacity ring: `push` is O(1), overwrites the oldest event
/// once full, and `total`/`dropped` make overflow visible instead of
/// silent.
#[derive(Debug)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    /// Events ever pushed (monotonic).
    total: u64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        Self {
            buf: Vec::with_capacity(cap.max(1)),
            cap: cap.max(1),
            head: 0,
            total: 0,
        }
    }

    pub fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
        }
        self.total += 1;
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events ever pushed.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Events lost to overwriting.
    pub fn dropped(&self) -> u64 {
        self.total - self.buf.len() as u64
    }

    /// Retained events, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

fn event_json(
    name: &str,
    ph: &str,
    ts: f64,
    pid: u64,
    tid: u64,
    extra: Vec<(&str, Json)>,
) -> Json {
    let mut fields = vec![
        ("name", Json::str(name)),
        ("ph", Json::str(ph)),
        ("ts", Json::num(ts)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

fn args_json(fields: Vec<(&str, Json)>) -> (&'static str, Json) {
    ("args", Json::obj(fields))
}

/// Map the ring onto the Chrome trace-event format:
///
/// * request spans → async begin/end (`ph: "b"/"e"`) with `id` =
///   request, one track per tenant (`pid 0`, `tid` = tenant + 1);
/// * decode steps → complete events (`ph: "X"`) with `dur`;
/// * cache / tier / prefetch events → thread-scoped instants
///   (`ph: "i"`, `s: "t"`) on a dedicated memory track (`pid 1`).
///
/// `clock` names the timestamp source (`"virtual"` or `"wall"`) in the
/// metadata, alongside drop accounting.
pub fn chrome_trace_json(ring: &TraceRing, clock: &str) -> Json {
    let events: Vec<Json> = ring
        .iter()
        .map(|ev| match ev {
            TraceEvent::RequestBegin { ts_us, request, tenant } => event_json(
                "request",
                "b",
                *ts_us,
                0,
                *tenant as u64 + 1,
                vec![
                    ("cat", Json::str("request")),
                    ("id", Json::num(*request as f64)),
                ],
            ),
            TraceEvent::RequestEnd { ts_us, request, tenant } => event_json(
                "request",
                "e",
                *ts_us,
                0,
                *tenant as u64 + 1,
                vec![
                    ("cat", Json::str("request")),
                    ("id", Json::num(*request as f64)),
                ],
            ),
            TraceEvent::DecodeStep {
                ts_us,
                request,
                tenant,
                token,
                cost_us,
            } => event_json(
                "decode_step",
                "X",
                *ts_us,
                0,
                *tenant as u64 + 1,
                vec![
                    ("cat", Json::str("decode")),
                    ("dur", Json::num(*cost_us)),
                    args_json(vec![
                        ("request", Json::num(*request as f64)),
                        ("token", Json::num(*token as f64)),
                    ]),
                ],
            ),
            TraceEvent::CacheAccess {
                ts_us,
                layer,
                expert,
                hit,
                depth,
            } => event_json(
                if *hit { "cache_hit" } else { "cache_miss" },
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("cache")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("layer", Json::num(*layer as f64)),
                        ("expert", Json::num(*expert as f64)),
                        ("depth", Json::num(*depth as f64)),
                    ]),
                ],
            ),
            TraceEvent::TierMove {
                ts_us,
                kind,
                layer,
                expert,
                from,
                to,
            } => event_json(
                kind.id(),
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("tier")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("layer", Json::num(*layer as f64)),
                        ("expert", Json::num(*expert as f64)),
                        ("from", Json::num(*from as f64)),
                        ("to", Json::num(*to as f64)),
                    ]),
                ],
            ),
            TraceEvent::Prefetch {
                ts_us,
                layer,
                issued,
                landed,
                too_late,
            } => event_json(
                "prefetch",
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("prefetch")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("layer", Json::num(*layer as f64)),
                        ("issued", Json::num(*issued as f64)),
                        ("landed", Json::num(*landed as f64)),
                        ("too_late", Json::num(*too_late as f64)),
                    ]),
                ],
            ),
            TraceEvent::RemoteFetch {
                ts_us,
                node,
                layer,
                expert,
                hit,
                wire_us,
            } => event_json(
                if *hit { "remote_hit" } else { "remote_miss" },
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("net")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("node", Json::num(*node as f64)),
                        ("layer", Json::num(*layer as f64)),
                        ("expert", Json::num(*expert as f64)),
                        ("wire_us", Json::num(*wire_us)),
                    ]),
                ],
            ),
            TraceEvent::NodeDown { ts_us, node } => event_json(
                "node_down",
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("fault")),
                    ("s", Json::str("t")),
                    args_json(vec![("node", Json::num(*node as f64))]),
                ],
            ),
            TraceEvent::NodeUp { ts_us, node } => event_json(
                "node_up",
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("fault")),
                    ("s", Json::str("t")),
                    args_json(vec![("node", Json::num(*node as f64))]),
                ],
            ),
            TraceEvent::LinkFlap { ts_us, node, up } => event_json(
                if *up { "link_up" } else { "link_down" },
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("fault")),
                    ("s", Json::str("t")),
                    args_json(vec![("node", Json::num(*node as f64))]),
                ],
            ),
            TraceEvent::ReplicaFailover {
                ts_us,
                node,
                layer,
                expert,
            } => event_json(
                "replica_failover",
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("net")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("node", Json::num(*node as f64)),
                        ("layer", Json::num(*layer as f64)),
                        ("expert", Json::num(*expert as f64)),
                    ]),
                ],
            ),
            TraceEvent::RemoteRetry {
                ts_us,
                node,
                layer,
                expert,
                attempt,
            } => event_json(
                "remote_retry",
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("net")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("node", Json::num(*node as f64)),
                        ("layer", Json::num(*layer as f64)),
                        ("expert", Json::num(*expert as f64)),
                        ("attempt", Json::num(*attempt as f64)),
                    ]),
                ],
            ),
            TraceEvent::DegradedFetch {
                ts_us,
                node,
                layer,
                expert,
            } => event_json(
                "degraded_fetch",
                "i",
                *ts_us,
                1,
                0,
                vec![
                    ("cat", Json::str("net")),
                    ("s", Json::str("t")),
                    args_json(vec![
                        ("node", Json::num(*node as f64)),
                        ("layer", Json::num(*layer as f64)),
                        ("expert", Json::num(*expert as f64)),
                    ]),
                ],
            ),
        })
        .collect();

    let mut meta = BTreeMap::new();
    meta.insert("clock".to_string(), Json::str(clock));
    meta.insert(
        "dropped_events".to_string(),
        Json::num(ring.dropped() as f64),
    );
    meta.insert("total_events".to_string(), Json::num(ring.total() as f64));

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("metadata", Json::Obj(meta)),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instant(ts: f64) -> TraceEvent {
        TraceEvent::CacheAccess {
            ts_us: ts,
            layer: 0,
            expert: 0,
            hit: true,
            depth: 0,
        }
    }

    #[test]
    fn ring_retains_newest_and_accounts_for_drops() {
        let mut r = TraceRing::new(4);
        for i in 0..10 {
            r.push(instant(i as f64));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        assert_eq!(r.dropped(), 6);
        let ts: Vec<f64> = r.iter().map(|e| e.ts_us()).collect();
        assert_eq!(ts, vec![6.0, 7.0, 8.0, 9.0]); // oldest → newest
    }

    #[test]
    fn ring_below_capacity_drops_nothing() {
        let mut r = TraceRing::new(8);
        r.push(instant(1.0));
        r.push(instant(2.0));
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 0);
        let ts: Vec<f64> = r.iter().map(|e| e.ts_us()).collect();
        assert_eq!(ts, vec![1.0, 2.0]);
    }

    #[test]
    fn chrome_export_shapes_every_event_kind() {
        let mut r = TraceRing::new(16);
        r.push(TraceEvent::RequestBegin { ts_us: 0.0, request: 7, tenant: 1 });
        r.push(TraceEvent::DecodeStep {
            ts_us: 5.0,
            request: 7,
            tenant: 1,
            token: 0,
            cost_us: 200.0,
        });
        r.push(TraceEvent::TierMove {
            ts_us: 6.0,
            kind: TierMoveKind::Demote,
            layer: 2,
            expert: 9,
            from: 0,
            to: 1,
        });
        r.push(TraceEvent::Prefetch {
            ts_us: 7.0,
            layer: 2,
            issued: 3,
            landed: 2,
            too_late: 1,
        });
        r.push(TraceEvent::RemoteFetch {
            ts_us: 8.0,
            node: 2,
            layer: 2,
            expert: 9,
            hit: false,
            wire_us: 110.0,
        });
        r.push(TraceEvent::NodeDown { ts_us: 9.0, node: 1 });
        r.push(TraceEvent::NodeUp { ts_us: 10.0, node: 1 });
        r.push(TraceEvent::LinkFlap { ts_us: 11.0, node: 2, up: false });
        r.push(TraceEvent::ReplicaFailover {
            ts_us: 12.0,
            node: 2,
            layer: 2,
            expert: 9,
        });
        r.push(TraceEvent::RemoteRetry {
            ts_us: 13.0,
            node: 1,
            layer: 2,
            expert: 9,
            attempt: 1,
        });
        r.push(TraceEvent::DegradedFetch {
            ts_us: 14.0,
            node: 0,
            layer: 2,
            expert: 9,
        });
        r.push(TraceEvent::RequestEnd { ts_us: 205.0, request: 7, tenant: 1 });

        let j = chrome_trace_json(&r, "virtual");
        let evs = match j.get("traceEvents") {
            Some(Json::Arr(a)) => a,
            other => panic!("traceEvents missing: {other:?}"),
        };
        assert_eq!(evs.len(), 12);
        for ev in evs {
            let ph = ev.get("ph").unwrap().as_str().unwrap();
            assert!(matches!(ph, "b" | "e" | "X" | "i"));
            assert!(ev.get("name").is_some());
            assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
            match ph {
                "X" => assert!(ev.get("dur").is_some()),
                "b" | "e" => assert!(ev.get("id").is_some()),
                _ => {}
            }
        }
        let meta = j.get("metadata").unwrap();
        assert_eq!(meta.get("clock").unwrap().as_str().unwrap(), "virtual");
        assert_eq!(meta.get("total_events").unwrap().as_f64().unwrap(), 12.0);
    }

    #[test]
    fn export_is_deterministic_for_identical_rings() {
        let build = || {
            let mut r = TraceRing::new(4);
            for i in 0..9 {
                r.push(instant(i as f64 * 1.5));
            }
            chrome_trace_json(&r, "virtual").to_json_string()
        };
        assert_eq!(build(), build());
    }
}
