//! MoE-Infinity baseline: request-level Expert Activation Matrix (rEAM)
//! matching (paper §3.1, §4.1.4, Fig 4).
//!
//! Offline (Fig 4 top): each training prompt's per-token iEAMs accumulate
//! into an L×E rEAM histogram; the rEAM collection is compacted with
//! k-means into an EAMC of centroid sketches.
//!
//! Online (Fig 4 bottom): the decode loop accumulates a *partial* rEAM
//! from the tokens seen so far; before each layer executes, the partial
//! sketch is cosine-matched against the EAMC and the matched sketch's
//! strongest experts for that layer are predicted (and prefetched).

use crate::config::EamConfig;
use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::trace::PromptTrace;
use crate::util::{math, ExpertSet, Rng};

/// One stored sketch: a unit-normalized flattened rEAM + cached norm of
/// each layer row (for per-layer top-k extraction we keep raw values too).
#[derive(Clone)]
struct Sketch {
    flat: Vec<f32>, // [L*E], unit L2 norm
}

pub struct EamPredictor {
    cfg: EamConfig,
    n_layers: usize,
    n_experts: usize,
    /// Raw rEAMs collected (ring buffer, capacity = eamc_capacity).
    collection: Vec<Sketch>,
    next_slot: usize,
    /// Compacted EAMC (k-means centroids) — what matching scans.
    eamc: Vec<Sketch>,
    dirty: bool,
    /// Partial rEAM of the in-flight request.
    partial: Vec<f32>,
    partial_tokens: usize,
}

impl EamPredictor {
    pub fn new(cfg: EamConfig, n_layers: usize, n_experts: usize) -> Self {
        Self {
            cfg,
            n_layers,
            n_experts,
            collection: Vec::new(),
            next_slot: 0,
            eamc: Vec::new(),
            dirty: false,
            partial: vec![0.0; n_layers * n_experts],
            partial_tokens: 0,
        }
    }

    /// Build an rEAM sketch from a full prompt trace.
    fn ream_of(&self, tr: &PromptTrace) -> Sketch {
        let mut flat = vec![0.0f32; self.n_layers * self.n_experts];
        for t in 0..tr.n_tokens() {
            for l in 0..self.n_layers {
                for &e in tr.expert_ids(t, l) {
                    flat[l * self.n_experts + e as usize] += 1.0;
                }
            }
        }
        math::normalize(&mut flat);
        Sketch { flat }
    }

    /// Offline EAMC construction from a training trace set (Fig 4 top).
    pub fn fit(&mut self, traces: &[PromptTrace]) {
        for tr in traces {
            self.push_sketch(self.ream_of(tr));
        }
        self.rebuild();
    }

    fn push_sketch(&mut self, s: Sketch) {
        if self.collection.len() < self.cfg.eamc_capacity {
            self.collection.push(s);
        } else {
            // ring replacement of the oldest sketch
            self.collection[self.next_slot] = s;
            self.next_slot = (self.next_slot + 1) % self.cfg.eamc_capacity;
        }
        self.dirty = true;
    }

    /// Recompute the compacted EAMC (k-means; raw copy if clusters == 0).
    fn rebuild(&mut self) {
        self.dirty = false;
        if self.cfg.kmeans_clusters == 0 || self.collection.len() <= self.cfg.kmeans_clusters {
            self.eamc = self.collection.clone();
            return;
        }
        self.eamc = kmeans(
            &self.collection,
            self.cfg.kmeans_clusters,
            self.cfg.kmeans_iters,
        );
    }

    /// Cosine-match the current partial rEAM against the EAMC.
    fn best_match(&self) -> Option<&Sketch> {
        if self.partial_tokens == 0 {
            return None;
        }
        let qn = math::norm(&self.partial);
        if qn == 0.0 {
            return None;
        }
        let mut best: Option<(f32, &Sketch)> = None;
        for s in &self.eamc {
            // sketches are unit-norm, so cosine = dot / |q|
            let c = math::dot(&self.partial, &s.flat) / qn;
            if best.map(|(b, _)| c > b).unwrap_or(true) {
                best = Some((c, s));
            }
        }
        best.map(|(_, s)| s)
    }

    /// Number of sketches the matcher currently scans.
    pub fn eamc_len(&self) -> usize {
        self.eamc.len()
    }

    /// Strongest experts of one layer row of a matched sketch.
    fn layer_top_k<const N: usize>(
        flat: &[f32],
        layer: usize,
        n_experts: usize,
        k: usize,
    ) -> ExpertSet<N> {
        let row = &flat[layer * n_experts..(layer + 1) * n_experts];
        let vals: Vec<f64> = row.iter().map(|&x| x as f64).collect();
        let mut out = ExpertSet::new();
        for i in math::top_k(&vals, k) {
            if vals[i] > 0.0 {
                out.insert(i as u8);
            }
        }
        out
    }
}

impl<const N: usize> ExpertPredictor<N> for EamPredictor {
    fn name(&self) -> &'static str {
        crate::predictor::PredictorKind::Eam.id()
    }

    fn begin_prompt(&mut self, _tr: &PromptTrace) {
        self.partial.fill(0.0);
        self.partial_tokens = 0;
        if self.dirty {
            self.rebuild();
        }
    }

    fn predict(&mut self, _ctx: &DecodeContext<'_>, layer: usize) -> ExpertSet<N> {
        let Some(m) = self.best_match() else {
            return ExpertSet::EMPTY;
        };
        Self::layer_top_k(&m.flat, layer, self.n_experts, self.cfg.prefetch_per_layer)
    }

    /// One EAMC cosine match per TOKEN instead of one per layer: the
    /// partial rEAM only changes on `observe`, so every layer of a token
    /// matches the same sketch — the batched call hoists the O(|EAMC| ×
    /// L × E) scan out of the per-layer loop.
    fn predict_layers(
        &mut self,
        _ctx: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        let Some(m) = self.best_match() else {
            out.fill(ExpertSet::EMPTY);
            return;
        };
        for (slot, l) in out.iter_mut().zip(layers) {
            *slot = Self::layer_top_k(&m.flat, l, self.n_experts, self.cfg.prefetch_per_layer);
        }
    }

    fn observe(&mut self, _ctx: &DecodeContext<'_>, layer: usize, actual: ExpertSet<N>) {
        for e in actual.iter() {
            self.partial[layer * self.n_experts + e as usize] += 1.0;
        }
        if layer == self.n_layers - 1 {
            self.partial_tokens += 1;
        }
    }

    fn end_prompt(&mut self, tr: &PromptTrace) {
        // fold the finished request's rEAM into the collection; in live
        // serving there is no materialized trace (n_tokens == 0), so the
        // online-accumulated partial rEAM is used instead
        let s = if tr.n_tokens() == 0 {
            let mut flat = self.partial.clone();
            math::normalize(&mut flat);
            Sketch { flat }
        } else {
            self.ream_of(tr)
        };
        self.push_sketch(s);
    }
}

/// Lloyd's k-means over unit-norm vectors (euclidean on the sphere ==
/// cosine ordering), k-means++-lite seeding, empty clusters re-seeded.
fn kmeans(points: &[Sketch], k: usize, iters: usize) -> Vec<Sketch> {
    let mut rng = Rng::new(0xEA11C);
    let dim = points[0].flat.len();
    // seed with distinct random points
    let mut idx: Vec<usize> = (0..points.len()).collect();
    rng.shuffle(&mut idx);
    let mut centroids: Vec<Vec<f32>> = idx[..k].iter().map(|&i| points[i].flat.clone()).collect();
    let mut assign = vec![0usize; points.len()];

    for _ in 0..iters {
        // assignment step
        for (pi, p) in points.iter().enumerate() {
            let mut best = (f32::NEG_INFINITY, 0usize);
            for (ci, c) in centroids.iter().enumerate() {
                let d = math::dot(&p.flat, c);
                if d > best.0 {
                    best = (d, ci);
                }
            }
            assign[pi] = best.1;
        }
        // update step
        let mut sums = vec![vec![0.0f32; dim]; k];
        let mut counts = vec![0usize; k];
        for (pi, p) in points.iter().enumerate() {
            let c = assign[pi];
            counts[c] += 1;
            for d in 0..dim {
                sums[c][d] += p.flat[d];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster with a random point
                sums[c] = points[rng.below(points.len())].flat.clone();
            }
            math::normalize(&mut sums[c]);
        }
        centroids = sums;
    }
    centroids.into_iter().map(|flat| Sketch { flat }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace where layer-l experts are always {base, base+1} (top-2).
    fn uniform_trace(id: u32, n_layers: u16, base: u8, n_tokens: usize) -> PromptTrace {
        let top_k = 2u16;
        let mut experts = Vec::new();
        for _ in 0..n_tokens {
            for _ in 0..n_layers {
                experts.push(base);
                experts.push(base + 1);
            }
        }
        PromptTrace {
            prompt_id: id,
            n_layers,
            top_k,
            d_emb: 0,
            tokens: vec![0; n_tokens],
            embeddings: vec![],
            experts,
        }
    }

    fn cfg() -> EamConfig {
        EamConfig {
            eamc_capacity: 16,
            kmeans_clusters: 0,
            kmeans_iters: 4,
            prefetch_per_layer: 2,
        }
    }

    #[test]
    fn matches_similar_request_and_predicts_its_experts() {
        let mut p = EamPredictor::new(cfg(), 3, 64);
        // two distinct request families in the EAMC
        p.fit(&[uniform_trace(0, 3, 10, 8), uniform_trace(1, 3, 40, 8)]);
        assert_eq!(p.eamc_len(), 2);

        // replay a prompt from the {10,11} family
        let tr = uniform_trace(2, 3, 10, 8);
        ExpertPredictor::<1>::begin_prompt(&mut p, &tr);
        let ctx = DecodeContext { trace: &tr, t: 0 };
        // before any observation: no partial sketch -> empty prediction
        let empty: ExpertSet = p.predict(&ctx, 0);
        assert!(empty.is_empty());
        // observe one token's worth of layers
        for l in 0..3 {
            p.observe(&ctx, l, ExpertSet::<1>::from_ids([10u8, 11]));
        }
        let pred: ExpertSet = p.predict(&ctx, 1);
        assert_eq!(pred.to_vec(), vec![10, 11]);
    }

    #[test]
    fn end_prompt_grows_collection() {
        let mut p = EamPredictor::new(cfg(), 2, 64);
        let tr = uniform_trace(0, 2, 5, 4);
        ExpertPredictor::<1>::begin_prompt(&mut p, &tr);
        ExpertPredictor::<1>::end_prompt(&mut p, &tr);
        ExpertPredictor::<1>::begin_prompt(&mut p, &tr); // triggers rebuild
        assert_eq!(p.eamc_len(), 1);
    }

    #[test]
    fn ring_buffer_respects_capacity() {
        let mut cfg = cfg();
        cfg.eamc_capacity = 3;
        let mut p = EamPredictor::new(cfg, 2, 64);
        for i in 0..10 {
            let tr = uniform_trace(i, 2, (i % 30) as u8, 4);
            ExpertPredictor::<1>::end_prompt(&mut p, &tr);
        }
        ExpertPredictor::<1>::begin_prompt(&mut p, &uniform_trace(99, 2, 0, 1));
        assert!(p.eamc_len() <= 3);
    }

    #[test]
    fn kmeans_compacts_families() {
        let mut cfg = cfg();
        cfg.kmeans_clusters = 2;
        let mut p = EamPredictor::new(cfg, 3, 64);
        let mut traces = Vec::new();
        for i in 0..12 {
            let base = if i % 2 == 0 { 10 } else { 40 };
            traces.push(uniform_trace(i, 3, base, 8));
        }
        p.fit(&traces);
        assert_eq!(p.eamc_len(), 2);
        // matching still works through centroids
        let tr = uniform_trace(100, 3, 40, 8);
        ExpertPredictor::<1>::begin_prompt(&mut p, &tr);
        let ctx = DecodeContext { trace: &tr, t: 0 };
        for l in 0..3 {
            p.observe(&ctx, l, ExpertSet::<1>::from_ids([40u8, 41]));
        }
        let pred: ExpertSet = p.predict(&ctx, 2);
        assert_eq!(pred.to_vec(), vec![40, 41]);
    }
}
