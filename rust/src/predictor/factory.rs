//! Predictor selection and construction — the single source of truth
//! for which predictors exist, what they are called, and how they are
//! built.  Shared by the sweep harness ([`crate::sim::sweep`]) and the
//! serving engine ([`crate::coordinator::ModelEngine`]), which previously
//! each carried their own copy of this mapping.

use crate::config::EamConfig;
use crate::predictor::{
    EamPredictor, ExpertPredictor, NextLayerAll, NoPrefetch, OraclePredictor, PopularityPredictor,
};
use crate::trace::PromptTrace;
use crate::Result;

/// Which predictor drives prefetch (config id + paper-facing name).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorKind {
    Learned,
    Eam,
    NextLayer,
    Popularity,
    Oracle,
    None,
}

impl PredictorKind {
    /// Every kind, in report order.
    pub const ALL: [PredictorKind; 6] = [
        PredictorKind::Learned,
        PredictorKind::Eam,
        PredictorKind::NextLayer,
        PredictorKind::Popularity,
        PredictorKind::Oracle,
        PredictorKind::None,
    ];

    /// Config identifier — the string accepted by `ServeConfig.predictor`
    /// and returned by every [`ExpertPredictor::name`] impl.
    pub fn id(&self) -> &'static str {
        match self {
            PredictorKind::Learned => "learned",
            PredictorKind::Eam => "eam",
            PredictorKind::NextLayer => "next-layer",
            PredictorKind::Popularity => "popularity",
            PredictorKind::Oracle => "oracle",
            PredictorKind::None => "none",
        }
    }

    /// Paper-facing display name (sweep tables, bench output).
    pub fn display_name(&self) -> &'static str {
        match self {
            PredictorKind::Learned => "moe-beyond",
            PredictorKind::Eam => "moe-infinity",
            PredictorKind::NextLayer => "deepspeed-next-layer",
            PredictorKind::Popularity => "brainstorm-popularity",
            PredictorKind::Oracle => "oracle",
            PredictorKind::None => "lru-only",
        }
    }

    /// Parse a config id or display name (round-trips with both
    /// [`id`](Self::id) and [`display_name`](Self::display_name)).
    pub fn parse(s: &str) -> Option<Self> {
        if s == "lru" {
            // historical alias for reactive-caching-only
            return Some(PredictorKind::None);
        }
        Self::ALL
            .into_iter()
            .find(|k| s == k.id() || s == k.display_name())
    }
}

/// Everything a heuristic predictor needs at construction time.
pub struct PredictorParams<'a> {
    pub eam: &'a EamConfig,
    /// Experts taken from the predictor per layer.
    pub predict_top_k: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    /// Training traces for offline-fitted baselines (EAMC, popularity).
    /// Empty for online serving, where the observers fit incrementally.
    pub fit_traces: &'a [PromptTrace],
}

/// Build a heuristic predictor.  `Learned` is not constructible here —
/// it is either a precomputed prediction set (sweeps) or a PJRT
/// [`crate::predictor::LearnedModel`] (serving); callers special-case it.
///
/// Generic over the expert-set word width `N` so the same mapping serves
/// both the 64-expert fast path (`N = 1`, the default) and wide worlds.
pub fn build<const N: usize>(
    kind: PredictorKind,
    p: &PredictorParams<'_>,
) -> Result<Box<dyn ExpertPredictor<N>>> {
    Ok(match kind {
        PredictorKind::Learned => anyhow::bail!(
            "the learned predictor is not factory-built (use precomputed predictions or LearnedModel)"
        ),
        PredictorKind::Eam => {
            let mut pr = EamPredictor::new(p.eam.clone(), p.n_layers, p.n_experts);
            pr.fit(p.fit_traces);
            Box::new(pr)
        }
        PredictorKind::NextLayer => Box::new(NextLayerAll::new(p.n_experts as u16)),
        PredictorKind::Popularity => {
            let mut pr = PopularityPredictor::<N>::new(p.n_layers, p.n_experts, p.predict_top_k);
            pr.fit(p.fit_traces);
            Box::new(pr)
        }
        PredictorKind::Oracle => Box::new(OraclePredictor::new()),
        PredictorKind::None => Box::new(NoPrefetch),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `parse` round-trips every kind through BOTH of its names.
    #[test]
    fn parse_round_trips_ids_and_display_names() {
        for k in PredictorKind::ALL {
            assert_eq!(PredictorKind::parse(k.id()), Some(k), "id {}", k.id());
            assert_eq!(
                PredictorKind::parse(k.display_name()),
                Some(k),
                "display {}",
                k.display_name()
            );
        }
        assert_eq!(PredictorKind::parse("lru"), Some(PredictorKind::None));
        assert_eq!(PredictorKind::parse("nope"), None);
    }

    /// Factory-built predictors report the kind's config id — one source
    /// of truth between `PredictorKind` and the trait `name()` methods.
    #[test]
    fn factory_names_match_kind_ids() {
        let eam = EamConfig {
            kmeans_clusters: 0,
            ..Default::default()
        };
        let params = PredictorParams {
            eam: &eam,
            predict_top_k: 6,
            n_layers: 3,
            n_experts: 64,
            fit_traces: &[],
        };
        for k in [
            PredictorKind::Eam,
            PredictorKind::NextLayer,
            PredictorKind::Popularity,
            PredictorKind::Oracle,
            PredictorKind::None,
        ] {
            let p: Box<dyn ExpertPredictor> = build(k, &params).unwrap();
            assert_eq!(p.name(), k.id(), "{k:?}");
        }
        assert!(build::<1>(PredictorKind::Learned, &params).is_err());
    }
}
