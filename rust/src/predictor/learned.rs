//! MoE-Beyond's learned predictor, served from the AOT HLO artifact.
//!
//! `LearnedModel` wraps the batched predictor executable (`predictor_batch`):
//! one call scores a window of up to 32 tokens for 8 layer ids at once.
//! The serving/simulation flow predicts *for the current token* (whose
//! embedding exists before any MoE layer runs — exactly the information
//! the paper's predictor conditions on) and refreshes every
//! `predictor_stride` tokens: within a topically-coherent prompt the
//! per-layer activation set drifts slowly, so the stride trades PJRT
//! calls for marginal staleness (ablated in `ablation_stride`).
//!
//! Because the predictions for a trace do not depend on cache capacity,
//! `precompute` evaluates a whole trace once and `CachedPredictor` replays
//! it across every point of a capacity sweep.

use std::cell::RefCell;
use std::path::Path;

use anyhow::ensure;

use crate::config::Artifacts;
use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::runtime::{Executable, PjrtRuntime, TensorView, WeightBlob};
use crate::trace::PromptTrace;
use crate::util::ExpertSet;
use crate::Result;

/// Reusable staging buffers for `predict_window`: the padded window, the
/// validity mask, and the batch-replicated argument tensors.  Kept in a
/// `RefCell` so `predict_window` stays `&self` (the model is driven from
/// one engine thread); capacity is retained across calls, so the
/// per-chunk `Vec` allocations of the old code disappear after the
/// first window.
#[derive(Default)]
struct PredictScratch {
    padded: Vec<f32>,
    mask: Vec<f32>,
    emb_b: Vec<f32>,
    lid_b: Vec<i32>,
    mask_b: Vec<f32>,
}

/// The loaded predictor model (weights resident on device).
pub struct LearnedModel {
    exe_batch: Executable,
    scratch: RefCell<PredictScratch>,
    pub window: usize,
    pub d_tok: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub batch: usize,
}

impl LearnedModel {
    /// Load from an artifact tree (checks the world fingerprint).
    pub fn load(rt: &PjrtRuntime, arts: &Artifacts) -> Result<Self> {
        arts.check_fingerprint()?;
        let sig = arts.executable("predictor_batch")?;
        let mut exe_batch = rt.load_hlo_text(arts.path(&sig.path))?;
        let blob = WeightBlob::load(arts.path("predictor_weights.bin"))?;
        let params: Vec<(&[f32], &[usize])> = blob
            .params
            .iter()
            .map(|p| (&blob.data[p.offset..p.offset + p.size], p.shape.as_slice()))
            .collect();
        exe_batch.set_resident_args(rt, &params)?;
        Ok(Self {
            exe_batch,
            scratch: RefCell::new(PredictScratch::default()),
            window: arts.predictor.window as usize,
            d_tok: arts.predictor.d_tok as usize,
            n_layers: arts.predictor.n_model_layers as usize,
            n_experts: arts.predictor.n_experts as usize,
            batch: arts.predictor.batch as usize,
        })
    }

    /// Load from explicit paths (tests / tools).
    pub fn load_from_paths<P: AsRef<Path>>(
        rt: &PjrtRuntime,
        hlo_b8: P,
        weights: P,
        window: usize,
        d_tok: usize,
        n_layers: usize,
        n_experts: usize,
        batch: usize,
    ) -> Result<Self> {
        let mut exe_batch = rt.load_hlo_text(hlo_b8)?;
        let blob = WeightBlob::load(weights)?;
        let params: Vec<(&[f32], &[usize])> = blob
            .params
            .iter()
            .map(|p| (&blob.data[p.offset..p.offset + p.size], p.shape.as_slice()))
            .collect();
        exe_batch.set_resident_args(rt, &params)?;
        Ok(Self {
            exe_batch,
            scratch: RefCell::new(PredictScratch::default()),
            window,
            d_tok,
            n_layers,
            n_experts,
            batch,
        })
    }

    /// Score one embedding window for a set of layers.
    ///
    /// `emb` is row-major [n_real, d_tok] (n_real <= window; right-padded
    /// internally).  Returns logits row-major [layers.len(), n_real,
    /// n_experts].
    pub fn predict_window(&self, emb: &[f32], n_real: usize, layers: &[usize]) -> Result<Vec<f32>> {
        ensure!(n_real > 0 && n_real <= self.window, "bad window fill {n_real}");
        ensure!(emb.len() == n_real * self.d_tok, "embedding shape mismatch");
        let (b, t, d) = (self.batch, self.window, self.d_tok);

        // staging buffers persist across calls (capacity retained): the
        // only remaining per-call allocation is the returned logits
        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.padded.clear();
        s.padded.resize(t * d, 0.0);
        s.padded[..n_real * d].copy_from_slice(emb);
        s.mask.clear();
        s.mask.resize(t, 0.0);
        s.mask[..n_real].fill(1.0);

        let mut out = vec![0.0f32; layers.len() * n_real * self.n_experts];
        for (chunk_i, chunk) in layers.chunks(b).enumerate() {
            // batch rows: same window, different layer ids (pad with layer 0)
            s.emb_b.clear();
            s.lid_b.clear();
            s.mask_b.clear();
            for bi in 0..b {
                s.emb_b.extend_from_slice(&s.padded);
                let lid = *chunk.get(bi).unwrap_or(&0) as i32;
                s.lid_b.extend(std::iter::repeat(lid).take(t));
                s.mask_b.extend_from_slice(&s.mask);
            }
            let logits = self.exe_batch.call_flat_views(&[
                TensorView::F32(&s.emb_b, &[b, t, d]),
                TensorView::I32(&s.lid_b, &[b, t]),
                TensorView::F32(&s.mask_b, &[b, t]),
            ])?; // [b, t, E] flattened
            for (bi, &layer) in chunk.iter().enumerate() {
                let li = chunk_i * b + bi;
                debug_assert_eq!(layers[li], layer);
                for pos in 0..n_real {
                    let src = (bi * t + pos) * self.n_experts;
                    let dst = (li * n_real + pos) * self.n_experts;
                    out[dst..dst + self.n_experts]
                        .copy_from_slice(&logits[src..src + self.n_experts]);
                }
            }
        }
        Ok(out)
    }

    /// Top-k expert set from a logit row — selected directly over the
    /// f32 values (no widening copy), tie-breaking identical to
    /// [`crate::util::math::top_k`] on the f64-widened row (asserted in
    /// `util::math::tests::prop_top_k_mask_f32_matches_f64_top_k` and,
    /// for multi-word widths, `util::expert_set`'s top-k parity tests).
    pub fn top_set<const N: usize>(&self, logits: &[f32], k: usize) -> ExpertSet<N> {
        ExpertSet::top_k_mask_f32(logits, k)
    }
}

/// Precomputed per-(token, layer) predicted sets for one trace.
#[derive(Debug, Clone)]
pub struct TracePredictions<const N: usize = 1> {
    pub n_layers: usize,
    /// [token][layer] predicted set.
    pub sets: Vec<Vec<ExpertSet<N>>>,
    /// Raw sigmoid logits at the predicted positions (for Table-1 eval):
    /// [token][layer * n_experts .. ].
    pub logits: Vec<Vec<f32>>,
    pub n_experts: usize,
}

/// Evaluate the model over a full trace with refresh stride.
///
/// Two modes:
/// * `positionwise = false` (simulation): for token `t` the prediction
///   uses the window ending at the most recent refresh point `r <= t`,
///   read at the refresh row — the online prefetcher's behaviour (only
///   embeddings `..= r` exist at prediction time; predictions are reused
///   until the next refresh).
/// * `positionwise = true` (offline eval, the paper's §3.2.4 protocol):
///   every token is scored at ITS OWN row of its window — the standard
///   sequence-labeling evaluation behind Table 1.
pub fn precompute_mode<const N: usize>(
    model: &LearnedModel,
    trace: &PromptTrace,
    stride: usize,
    top_k: usize,
    positionwise: bool,
) -> Result<TracePredictions<N>> {
    let n = trace.n_tokens();
    let d = model.d_tok;
    let layers: Vec<usize> = (0..model.n_layers).collect();
    let mut sets = vec![vec![ExpertSet::EMPTY; model.n_layers]; n];
    let mut logits_out = vec![Vec::new(); n];

    let mut t = 0;
    while t < n {
        // window placement differs by mode: the online prefetcher only
        // has embeddings up to the refresh token t (window ENDS at t);
        // offline eval scores the whole chunk [t, t+window) at once
        // (window starts at t and extends forward, paper §3.2.4).
        let (start, end) = if positionwise {
            (t, (t + model.window).min(n))
        } else {
            ((t + 1).saturating_sub(model.window), t + 1)
        };
        let n_real = end - start;
        let emb = &trace.embeddings[start * d..end * d];
        let win_logits = model.predict_window(emb, n_real, &layers)?;

        // fill tokens t .. t+stride from this window
        let until = (t + stride).min(n);
        for tt in t..until {
            // offline eval reads each token's own row; the online
            // prefetcher only has the refresh row
            let pos = if positionwise {
                (tt - start).min(n_real - 1)
            } else {
                n_real - 1
            };
            let mut row = Vec::with_capacity(model.n_layers * model.n_experts);
            for (li, _l) in layers.iter().enumerate() {
                let base = (li * n_real + pos) * model.n_experts;
                let lrow = &win_logits[base..base + model.n_experts];
                sets[tt][li] = model.top_set(lrow, top_k);
                row.extend_from_slice(lrow);
            }
            logits_out[tt] = row;
        }
        t = until;
    }
    Ok(TracePredictions {
        n_layers: model.n_layers,
        sets,
        logits: logits_out,
        n_experts: model.n_experts,
    })
}

/// Simulation-mode precompute (see `precompute_mode`).
pub fn precompute<const N: usize>(
    model: &LearnedModel,
    trace: &PromptTrace,
    stride: usize,
    top_k: usize,
) -> Result<TracePredictions<N>> {
    precompute_mode(model, trace, stride, top_k, false)
}

/// An `ExpertPredictor` replaying precomputed predictions (sweep reuse).
pub struct CachedPredictor<'a, const N: usize = 1> {
    preds: &'a TracePredictions<N>,
}

impl<'a, const N: usize> CachedPredictor<'a, N> {
    pub fn new(preds: &'a TracePredictions<N>) -> Self {
        Self { preds }
    }
}

impl<const N: usize> ExpertPredictor<N> for CachedPredictor<'_, N> {
    fn name(&self) -> &'static str {
        crate::predictor::PredictorKind::Learned.id()
    }
    fn begin_prompt(&mut self, _: &PromptTrace) {}
    fn predict(&mut self, ctx: &DecodeContext<'_>, layer: usize) -> ExpertSet<N> {
        self.preds.sets[ctx.t][layer]
    }
    fn predict_layers(
        &mut self,
        ctx: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        // one bounds-checked row index per token instead of one per layer
        out.copy_from_slice(&self.preds.sets[ctx.t][layers.start..layers.end]);
    }
    fn observe(&mut self, _: &DecodeContext<'_>, _: usize, _: ExpertSet<N>) {}
    fn end_prompt(&mut self, _: &PromptTrace) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts() -> Option<(PjrtRuntime, Artifacts)> {
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("artifacts.json").exists() {
            return None;
        }
        let arts = Artifacts::discover(&root).ok()?;
        let rt = PjrtRuntime::cpu().ok()?;
        Some((rt, arts))
    }

    #[test]
    fn window_prediction_shapes_and_batching() {
        let Some((rt, arts)) = arts() else { return };
        let model = LearnedModel::load(&rt, &arts).unwrap();
        let n_real = 5usize;
        let emb = vec![0.05f32; n_real * model.d_tok];
        // 10 layers spans two b8 batches
        let layers: Vec<usize> = (0..10).collect();
        let out = model.predict_window(&emb, n_real, &layers).unwrap();
        assert_eq!(out.len(), 10 * n_real * model.n_experts);
        assert!(out.iter().all(|x| x.is_finite()));
        // layer identity must matter (different rows differ)
        let a = &out[..model.n_experts];
        let b = &out[9 * n_real * model.n_experts..9 * n_real * model.n_experts + model.n_experts];
        assert!(a.iter().zip(b).any(|(x, y)| (x - y).abs() > 1e-5));
    }

    #[test]
    fn precompute_covers_every_token() {
        let Some((rt, arts)) = arts() else { return };
        let model = LearnedModel::load(&rt, &arts).unwrap();
        let traces =
            crate::trace::store::read_traces(arts.path("traces/val.bin")).unwrap();
        let tr = &traces[0];
        let preds: TracePredictions = precompute(&model, tr, 8, 6).unwrap();
        assert_eq!(preds.sets.len(), tr.n_tokens());
        for t in (0..tr.n_tokens()).step_by(17) {
            for l in (0..preds.n_layers).step_by(9) {
                assert_eq!(preds.sets[t][l].len(), 6);
            }
        }
    }
}
