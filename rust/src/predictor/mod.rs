//! Expert-activation predictors: the paper's learned model plus every
//! heuristic baseline it compares against (§3.1).
//!
//! | name         | paper reference                                   |
//! |--------------|---------------------------------------------------|
//! | `learned`    | MoE-Beyond (this paper) — AOT transformer via PJRT |
//! | `eam`        | MoE-Infinity: rEAM/EAMC cosine matching + k-means  |
//! | `next-layer` | DeepSpeed-MoE: eagerly fetch whole next layer      |
//! | `popularity` | BrainStorm: global activation counts               |
//! | `oracle`     | ground-truth lookahead (upper bound)               |
//! | `none`       | no prefetch (pure LRU reactive caching)            |

pub mod eam;
pub mod factory;
pub mod learned;
pub mod next_layer;
pub mod oracle;
pub mod popularity;

pub use eam::EamPredictor;
pub use factory::{PredictorKind, PredictorParams};
pub use learned::{CachedPredictor, LearnedModel, TracePredictions};
pub use next_layer::NextLayerAll;
pub use oracle::OraclePredictor;
pub use popularity::PopularityPredictor;

use crate::trace::PromptTrace;
use crate::util::ExpertSet;

/// Online decode context handed to a predictor at each step.
///
/// At simulation/serving time the current token IS known (its embedding
/// exists before any MoE layer runs), so predictors may use everything up
/// to and including token `t` — and nothing after it.
pub struct DecodeContext<'a> {
    /// The trace being decoded (embeddings + ground truth; predictors must
    /// only read tokens `..=t` and ground-truth experts `..t`).
    pub trace: &'a PromptTrace,
    /// Current token position.
    pub t: usize,
}

/// An expert-activation predictor.
///
/// The replay engines call, once per token, [`predict_layers`]
/// (predictions for every layer, issued before the token's first layer
/// runs — the serving engine's timing), then per executed layer
/// `observe(ctx, l, actual)` after the layer "executes".  `begin_prompt`
/// resets per-request state (batch-size-1 semantics, paper §5).
/// Scalar [`predict`] remains the per-layer primitive; the two are held
/// to exact agreement (`predict_layers(ctx, 0..L, out)` ==
/// `[predict(ctx, 0), …, predict(ctx, L-1)]` with no intervening
/// observations) by the parity suite in `tests/replay_parity.rs`.
///
/// The trait is generic over the [`ExpertSet`] word width `N` (default
/// 1 = up to 64 experts).  Stateless heuristics implement it for every
/// width with a blanket `impl<const N: usize> ExpertPredictor<N>`;
/// stateful ones carry the width on the struct.
///
/// [`predict`]: ExpertPredictor::predict
/// [`predict_layers`]: ExpertPredictor::predict_layers
pub trait ExpertPredictor<const N: usize = 1>: Send {
    fn name(&self) -> &'static str;

    /// Reset per-request state at the start of a prompt.
    fn begin_prompt(&mut self, trace: &PromptTrace);

    /// Predict the experts that will fire at (current token, `layer`).
    fn predict(&mut self, ctx: &DecodeContext<'_>, layer: usize) -> ExpertSet<N>;

    /// Predict the experts that will fire at the current token for every
    /// layer in `layers`, writing `out[i]` for layer `layers.start + i`
    /// (`out.len()` must equal the range length).  One virtual call per
    /// token on the replay/workload hot loops, mirroring
    /// [`crate::memory::ExpertMemory::lookup_set`] on the lookup side.
    ///
    /// The default delegates to scalar [`predict`], so third-party
    /// predictors keep working unchanged; the in-crate predictors
    /// override it to hoist per-token work out of the per-layer loop
    /// (most profitably the EAMC cosine match, which is identical for
    /// every layer of one token).
    ///
    /// [`predict`]: ExpertPredictor::predict
    fn predict_layers(
        &mut self,
        ctx: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        for (slot, l) in out.iter_mut().zip(layers) {
            *slot = self.predict(ctx, l);
        }
    }

    /// Observe the ground-truth activation after the layer ran.
    fn observe(&mut self, ctx: &DecodeContext<'_>, layer: usize, actual: ExpertSet<N>);

    /// Finish a prompt (e.g. fold its rEAM into the EAMC).
    fn end_prompt(&mut self, trace: &PromptTrace);
}

/// A no-op predictor: reactive caching only.
pub struct NoPrefetch;

impl<const N: usize> ExpertPredictor<N> for NoPrefetch {
    fn name(&self) -> &'static str {
        PredictorKind::None.id()
    }
    fn begin_prompt(&mut self, _: &PromptTrace) {}
    fn predict(&mut self, _: &DecodeContext<'_>, _: usize) -> ExpertSet<N> {
        ExpertSet::EMPTY
    }
    fn predict_layers(
        &mut self,
        _: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        out.fill(ExpertSet::EMPTY);
    }
    fn observe(&mut self, _: &DecodeContext<'_>, _: usize, _: ExpertSet<N>) {}
    fn end_prompt(&mut self, _: &PromptTrace) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_prefetch_predicts_nothing() {
        let tr = PromptTrace {
            prompt_id: 0,
            n_layers: 2,
            top_k: 1,
            d_emb: 0,
            tokens: vec![1],
            embeddings: vec![],
            experts: vec![0, 1],
        };
        let mut p = NoPrefetch;
        ExpertPredictor::<1>::begin_prompt(&mut p, &tr);
        let ctx = DecodeContext { trace: &tr, t: 0 };
        let s: ExpertSet = p.predict(&ctx, 0);
        assert!(s.is_empty());
    }
}
