//! DeepSpeed-MoE baseline (paper §3.1): eagerly "prefetch" every expert of
//! the next layer, assuming dense-model locality.  Over-fetches badly once
//! routing is sparse — with 64 experts per layer and a 6-expert truth set,
//! 90% of its prefetches are wasted cache pressure.

use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::trace::PromptTrace;
use crate::util::ExpertSet;

pub struct NextLayerAll {
    n_experts: u16,
    /// Optional cap on how many experts fit in the prefetch window; the
    /// real system is PCIe-bound, so fetching "all 64" within one layer's
    /// compute window is physically impossible — `cap` models that.
    cap: Option<usize>,
}

impl NextLayerAll {
    pub fn new(n_experts: u16) -> Self {
        Self {
            n_experts,
            cap: None,
        }
    }

    pub fn with_cap(n_experts: u16, cap: usize) -> Self {
        Self {
            n_experts,
            cap: Some(cap),
        }
    }
}

impl<const N: usize> ExpertPredictor<N> for NextLayerAll {
    fn name(&self) -> &'static str {
        crate::predictor::PredictorKind::NextLayer.id()
    }

    fn begin_prompt(&mut self, _: &PromptTrace) {}

    fn predict(&mut self, _ctx: &DecodeContext<'_>, _layer: usize) -> ExpertSet<N> {
        match self.cap {
            None => ExpertSet::all(self.n_experts),
            Some(c) => ExpertSet::all(self.n_experts.min(c as u16)),
        }
    }

    fn predict_layers(
        &mut self,
        ctx: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        // layer-independent: build the (capped) all-experts mask once
        out.fill(self.predict(ctx, layers.start));
    }

    fn observe(&mut self, _: &DecodeContext<'_>, _: usize, _: ExpertSet<N>) {}
    fn end_prompt(&mut self, _: &PromptTrace) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr() -> PromptTrace {
        PromptTrace {
            prompt_id: 0,
            n_layers: 1,
            top_k: 1,
            d_emb: 0,
            tokens: vec![0],
            embeddings: vec![],
            experts: vec![0],
        }
    }

    #[test]
    fn predicts_everything() {
        let t = tr();
        let mut p = NextLayerAll::new(64);
        ExpertPredictor::<1>::begin_prompt(&mut p, &t);
        let ctx = DecodeContext { trace: &t, t: 0 };
        let s: ExpertSet = p.predict(&ctx, 0);
        assert_eq!(s.len(), 64);
    }

    #[test]
    fn cap_limits_prefetch() {
        let t = tr();
        let mut p = NextLayerAll::with_cap(64, 8);
        let ctx = DecodeContext { trace: &t, t: 0 };
        let s: ExpertSet = p.predict(&ctx, 0);
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn wide_predicts_all_160() {
        let t = tr();
        let mut p = NextLayerAll::new(160);
        let ctx = DecodeContext { trace: &t, t: 0 };
        let s: ExpertSet<3> = p.predict(&ctx, 0);
        assert_eq!(s.len(), 160);
    }
}
