//! Oracle predictor: reads the ground truth for (current token, layer)
//! straight from the trace.  The upper bound every policy is measured
//! against — with enough cache it drives the hit rate to 100%, and the
//! `sim` proptests assert no other predictor beats it.

use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::trace::PromptTrace;
use crate::util::ExpertSet;

pub struct OraclePredictor {
    /// Look this many layers ahead (1 = the layer about to execute).
    pub horizon: usize,
}

impl OraclePredictor {
    pub fn new() -> Self {
        Self { horizon: 1 }
    }
}

impl Default for OraclePredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl OraclePredictor {
    /// Shared body of the scalar and batched entry points.
    fn predict_at<const N: usize>(&self, ctx: &DecodeContext<'_>, layer: usize) -> ExpertSet<N> {
        let mut out = ctx.trace.expert_set_wide::<N>(ctx.t, layer);
        // extended horizon: union of the next horizon-1 layers too
        for h in 1..self.horizon {
            if layer + h < ctx.trace.n_layers as usize {
                out = out.union(ctx.trace.expert_set_wide(ctx.t, layer + h));
            }
        }
        out
    }
}

impl<const N: usize> ExpertPredictor<N> for OraclePredictor {
    fn name(&self) -> &'static str {
        crate::predictor::PredictorKind::Oracle.id()
    }

    fn begin_prompt(&mut self, _: &PromptTrace) {}

    fn predict(&mut self, ctx: &DecodeContext<'_>, layer: usize) -> ExpertSet<N> {
        self.predict_at(ctx, layer)
    }

    fn predict_layers(
        &mut self,
        ctx: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        for (slot, l) in out.iter_mut().zip(layers) {
            *slot = self.predict_at(ctx, l);
        }
    }

    fn observe(&mut self, _: &DecodeContext<'_>, _: usize, _: ExpertSet<N>) {}
    fn end_prompt(&mut self, _: &PromptTrace) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr() -> PromptTrace {
        PromptTrace {
            prompt_id: 0,
            n_layers: 3,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0, 1],
            embeddings: vec![],
            experts: vec![
                1, 2, 3, 4, 5, 6, // token 0, layers 0..3
                7, 8, 9, 10, 11, 12, // token 1
            ],
        }
    }

    #[test]
    fn oracle_is_exact() {
        let t = tr();
        let mut p = OraclePredictor::new();
        let ctx = DecodeContext { trace: &t, t: 1 };
        let a: ExpertSet = p.predict(&ctx, 0);
        assert_eq!(a.to_vec(), vec![7, 8]);
        let b: ExpertSet = p.predict(&ctx, 2);
        assert_eq!(b.to_vec(), vec![11, 12]);
    }

    #[test]
    fn horizon_unions_layers() {
        let t = tr();
        let mut p = OraclePredictor { horizon: 2 };
        let ctx = DecodeContext { trace: &t, t: 0 };
        let a: ExpertSet = p.predict(&ctx, 0);
        assert_eq!(a.to_vec(), vec![1, 2, 3, 4]);
        // horizon clipped at the last layer
        let b: ExpertSet = p.predict(&ctx, 2);
        assert_eq!(b.to_vec(), vec![5, 6]);
    }
}
