//! BrainStorm baseline (paper §3.1): global per-expert activation counts
//! across the workload; prefetch the "popular" experts per layer.  As the
//! paper notes, once many prompts merge these counts flatten and the hit
//! rate collapses — exactly what the Fig 1 uniformity predicts.

use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::trace::PromptTrace;
use crate::util::{math, ExpertSet};

pub struct PopularityPredictor<const N: usize = 1> {
    n_layers: usize,
    n_experts: usize,
    /// Global (workload-lifetime) activation counts per (layer, expert).
    counts: Vec<u64>,
    /// Experts predicted per layer.
    top_k: usize,
    /// Cached per-layer top-k sets, rebuilt lazily.
    cached: Vec<ExpertSet<N>>,
    dirty: bool,
}

impl<const N: usize> PopularityPredictor<N> {
    pub fn new(n_layers: usize, n_experts: usize, top_k: usize) -> Self {
        Self {
            n_layers,
            n_experts,
            counts: vec![0; n_layers * n_experts],
            top_k,
            cached: vec![ExpertSet::EMPTY; n_layers],
            dirty: true,
        }
    }

    /// Pre-train on a workload's traces (how BrainStorm profiles).
    pub fn fit(&mut self, traces: &[PromptTrace]) {
        for tr in traces {
            for t in 0..tr.n_tokens() {
                for l in 0..self.n_layers {
                    for &e in tr.expert_ids(t, l) {
                        self.counts[l * self.n_experts + e as usize] += 1;
                    }
                }
            }
        }
        self.dirty = true;
    }

    fn rebuild(&mut self) {
        for l in 0..self.n_layers {
            let row: Vec<f64> = self.counts[l * self.n_experts..(l + 1) * self.n_experts]
                .iter()
                .map(|&c| c as f64)
                .collect();
            let mut s = ExpertSet::<N>::new();
            for i in math::top_k(&row, self.top_k) {
                if row[i] > 0.0 {
                    s.insert(i as u8);
                }
            }
            self.cached[l] = s;
        }
        self.dirty = false;
    }
}

impl<const N: usize> ExpertPredictor<N> for PopularityPredictor<N> {
    fn name(&self) -> &'static str {
        crate::predictor::PredictorKind::Popularity.id()
    }

    fn begin_prompt(&mut self, _: &PromptTrace) {
        if self.dirty {
            self.rebuild();
        }
    }

    fn predict(&mut self, _ctx: &DecodeContext<'_>, layer: usize) -> ExpertSet<N> {
        if self.dirty {
            self.rebuild();
        }
        self.cached[layer]
    }

    fn predict_layers(
        &mut self,
        _ctx: &DecodeContext<'_>,
        layers: std::ops::Range<usize>,
        out: &mut [ExpertSet<N>],
    ) {
        debug_assert_eq!(layers.len(), out.len());
        // one dirty check per token, then straight copies of the cached
        // per-layer top-k sets
        if self.dirty {
            self.rebuild();
        }
        out.copy_from_slice(&self.cached[layers.start..layers.end]);
    }

    fn observe(&mut self, _ctx: &DecodeContext<'_>, layer: usize, actual: ExpertSet<N>) {
        for e in actual.iter() {
            self.counts[layer * self.n_experts + e as usize] += 1;
        }
        // counts drift slowly; rebuilding per-prompt (begin_prompt) is
        // enough and keeps predict() allocation-free
        self.dirty = true;
    }

    fn end_prompt(&mut self, _: &PromptTrace) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(base: u8) -> PromptTrace {
        // 4 tokens, 2 layers, top-2: layer l uses {base+l, base+l+1}
        let mut experts = Vec::new();
        for _ in 0..4 {
            for l in 0..2u8 {
                experts.push(base + l);
                experts.push(base + l + 1);
            }
        }
        PromptTrace {
            prompt_id: 0,
            n_layers: 2,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0; 4],
            embeddings: vec![],
            experts,
        }
    }

    #[test]
    fn predicts_most_popular() {
        let mut p: PopularityPredictor = PopularityPredictor::new(2, 64, 2);
        p.fit(&[tr(10), tr(10), tr(10), tr(30)]);
        let t = tr(10);
        p.begin_prompt(&t);
        let ctx = DecodeContext { trace: &t, t: 0 };
        assert_eq!(p.predict(&ctx, 0).to_vec(), vec![10, 11]);
        assert_eq!(p.predict(&ctx, 1).to_vec(), vec![11, 12]);
    }

    #[test]
    fn observe_updates_counts() {
        let mut p: PopularityPredictor = PopularityPredictor::new(1, 64, 1);
        let t = tr(0);
        let ctx = DecodeContext { trace: &t, t: 0 };
        for _ in 0..5 {
            p.observe(&ctx, 0, ExpertSet::from_ids([42u8]));
        }
        p.begin_prompt(&t);
        assert_eq!(p.predict(&ctx, 0).to_vec(), vec![42]);
    }

    #[test]
    fn empty_counts_predict_nothing() {
        let mut p: PopularityPredictor = PopularityPredictor::new(1, 64, 4);
        let t = tr(0);
        p.begin_prompt(&t);
        let ctx = DecodeContext { trace: &t, t: 0 };
        assert!(p.predict(&ctx, 0).is_empty());
    }
}
