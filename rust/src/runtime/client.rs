//! PJRT client wrapper: HLO text -> compiled executable -> typed execute.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax≥0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Weights are uploaded to device buffers ONCE (`execute_b` keeps them
//! resident); per-call tensors are converted to literals on the fly.

use std::path::Path;

use anyhow::ensure;

use crate::Result;

/// State input for `call_chained`: host boot tensor or device buffer.
pub enum StateArg<'a> {
    Host(TensorArg),
    Device(&'a xla::PjRtBuffer),
}

/// A host-side tensor argument for one executable call.
#[derive(Debug, Clone)]
pub enum TensorArg {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    /// Scalar i32 (rank 0).
    ScalarI32(i32),
}

impl TensorArg {
    /// Upload as a device buffer via `buffer_from_host_buffer`, which the
    /// TFRT CPU client copies SYNCHRONOUSLY (kImmutableOnlyDuringCall).
    ///
    /// `BufferFromHostLiteral` must NOT be used here: it schedules the
    /// host->device copy asynchronously, so a Rust-side literal dropped
    /// right after the call is read after free (observed as SIGSEGVs and
    /// spurious size-check aborts under load).
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            TensorArg::F32(data, dims) => TensorView::F32(data, dims).to_buffer(client),
            TensorArg::I32(data, dims) => TensorView::I32(data, dims).to_buffer(client),
            TensorArg::ScalarI32(v) => client
                .buffer_from_host_buffer(&[*v], &[], None)
                .map_err(|e| anyhow::anyhow!("scalar arg upload: {e:?}")),
        }
    }
}

/// A borrowed host-side tensor argument: same upload semantics as
/// [`TensorArg`] (synchronous copy, see `TensorArg::to_buffer`) without
/// taking ownership, so hot callers can stage arguments in reusable
/// scratch buffers instead of allocating a `Vec` per call.
#[derive(Debug, Clone, Copy)]
pub enum TensorView<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl TensorView<'_> {
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let buf = match self {
            TensorView::F32(data, dims) => {
                ensure!(
                    data.len() == dims.iter().product::<usize>(),
                    "f32 view shape mismatch"
                );
                client
                    .buffer_from_host_buffer(data, dims, None)
                    .map_err(|e| anyhow::anyhow!("f32 view upload: {e:?}"))?
            }
            TensorView::I32(data, dims) => {
                ensure!(
                    data.len() == dims.iter().product::<usize>(),
                    "i32 view shape mismatch"
                );
                client
                    .buffer_from_host_buffer(data, dims, None)
                    .map_err(|e| anyhow::anyhow!("i32 view upload: {e:?}"))?
            }
        };
        Ok(buf)
    }
}

/// The PJRT CPU client; create once, compile many executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu init: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
        Ok(Executable {
            exe,
            name: path.file_name().unwrap().to_string_lossy().into_owned(),
            resident: Vec::new(),
        })
    }

    /// Upload a set of f32 tensors as device-resident buffers (weights).
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        let dims_i: Vec<usize> = dims.to_vec();
        self.client
            .buffer_from_host_buffer(data, &dims_i, None)
            .map_err(|e| anyhow::anyhow!("uploading buffer: {e:?}"))
    }
}

/// A compiled executable plus optional device-resident leading arguments
/// (the model weights).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    resident: Vec<xla::PjRtBuffer>,
}

impl Executable {
    /// Pin weights device-side as the leading arguments of every call.
    /// `params` is an ordered list of (values, shape).
    pub fn set_resident_args(
        &mut self,
        rt: &PjrtRuntime,
        params: &[(&[f32], &[usize])],
    ) -> Result<()> {
        self.resident = params
            .iter()
            .map(|(vals, shape)| rt.upload_f32(vals, shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    pub fn num_resident(&self) -> usize {
        self.resident.len()
    }

    /// Upload a raw f32 tensor to a device buffer on this executable's
    /// client (used by state-threading callers to boot their state).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.exe
            .client()
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading buffer: {e:?}"))
    }

    /// `call_flat` variant whose first non-weight argument is a host
    /// state tensor (boot path of chained executables).
    pub fn call_flat_with_state(&self, state: TensorArg, rest: &[TensorArg]) -> Result<Vec<f32>> {
        let mut args = Vec::with_capacity(rest.len() + 1);
        args.push(state);
        args.extend_from_slice(rest);
        self.call_flat(&args)
    }

    /// Execute and fetch the SINGLE flat f32 output.
    ///
    /// Every artifact is lowered to exactly one flat f32 result — the CPU
    /// PJRT client in xla_extension 0.5.1 cannot fetch tuple-shaped
    /// output buffers (ToLiteral CHECK-fails on them), so multi-output
    /// model functions concatenate into one vector at the JAX level.
    pub fn call_flat(&self, args: &[TensorArg]) -> Result<Vec<f32>> {
        let client = self.exe.client().clone();
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            bufs.push(a.to_buffer(&client)?);
        }
        self.execute_staged_flat(&bufs)
    }

    /// [`call_flat`](Executable::call_flat) over borrowed tensors: the
    /// caller keeps ownership of the staging buffers and reuses them
    /// across calls (the predictor hot path stages its batch this way).
    pub fn call_flat_views(&self, args: &[TensorView<'_>]) -> Result<Vec<f32>> {
        let client = self.exe.client().clone();
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            bufs.push(a.to_buffer(&client)?);
        }
        self.execute_staged_flat(&bufs)
    }

    /// Shared tail of both `call_flat` paths: resident weights + staged
    /// argument buffers -> execute -> fetch the single flat f32 output.
    fn execute_staged_flat(&self, staged: &[xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let mut all: Vec<&xla::PjRtBuffer> = self.resident.iter().collect();
        all.extend(staged.iter());
        let out = self
            .exe
            .execute_b(&all)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?;
        out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching output: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("output as f32: {e:?}"))
    }

    /// Chained execution for state-threading executables (decode): the
    /// first non-weight argument is either a host tensor (boot) or the
    /// PREVIOUS call's output buffer (steady state — zero host copies of
    /// the state).  Returns the new state buffer plus the first
    /// `head_len` f32s fetched to host.
    pub fn call_chained(
        &self,
        state: StateArg<'_>,
        rest: &[TensorArg],
    ) -> Result<xla::PjRtBuffer> {
        let client = self.exe.client().clone();
        // staged host-state buffer; must outlive the execute call below
        let host_state;
        let mut all: Vec<&xla::PjRtBuffer> = self.resident.iter().collect();
        match state {
            StateArg::Host(t) => {
                host_state = t.to_buffer(&client)?;
                all.push(&host_state);
            }
            StateArg::Device(b) => all.push(b),
        }
        let mut arg_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(rest.len());
        for a in rest {
            arg_bufs.push(a.to_buffer(&client)?);
        }
        all.extend(arg_bufs.iter());
        let mut out = self
            .exe
            .execute_b(&all)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?;
        Ok(out[0].remove(0))
    }

    /// Execute on raw device buffers and fetch the single f32 output
    /// (used by tiny extractor executables over chained state).
    pub fn call_on_buffers(&self, bufs: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let mut all: Vec<&xla::PjRtBuffer> = self.resident.iter().collect();
        all.extend_from_slice(bufs);
        let out = self
            .exe
            .execute_b(&all)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?;
        out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching output: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("output as f32: {e:?}"))
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end PJRT check against the real predictor artifact.
    #[test]
    fn predictor_artifact_runs() {
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("predictor.hlo.txt").exists() {
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let mut exe = rt.load_hlo_text(root.join("predictor.hlo.txt")).unwrap();
        let blob = crate::runtime::WeightBlob::load(root.join("predictor_weights.bin")).unwrap();
        let params: Vec<(&[f32], &[usize])> = blob
            .params
            .iter()
            .map(|p| {
                (
                    &blob.data[p.offset..p.offset + p.size],
                    p.shape.as_slice(),
                )
            })
            .collect();
        exe.set_resident_args(&rt, &params).unwrap();

        let t = 32usize;
        let d = 128usize;
        let emb = vec![0.1f32; t * d];
        let lids = vec![3i32; t];
        let mask = vec![1.0f32; t];
        let probs = exe
            .call_flat(&[
                TensorArg::F32(emb, vec![t, d]),
                TensorArg::I32(lids, vec![t]),
                TensorArg::F32(mask, vec![t]),
            ])
            .unwrap();
        assert_eq!(probs.len(), t * 64);
        assert!(probs.iter().all(|x| x.is_finite()));

        // repeated calls with resident weights must be stable
        let probs2 = exe
            .call_flat(&[
                TensorArg::F32(vec![0.1f32; t * d], vec![t, d]),
                TensorArg::I32(vec![3i32; t], vec![t]),
                TensorArg::F32(vec![1.0f32; t], vec![t]),
            ])
            .unwrap();
        assert_eq!(probs2.len(), t * 64);
    }
}
