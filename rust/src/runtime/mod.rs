//! PJRT runtime: loads the AOT HLO-text artifacts produced by the Python
//! compile path and executes them natively.  This is the only place the
//! crate touches the `xla` FFI; everything above works with plain slices.

mod client;
mod weights;

pub use client::{Executable, PjrtRuntime, StateArg, TensorArg, TensorView};
pub use weights::WeightBlob;
