//! Weight blob loader: flat little-endian f32 file + JSON manifest
//! (`*_weights.bin` / `*_weights.bin.json` written by the Python side).

use std::path::Path;

use anyhow::{ensure, Context};

use crate::util::json::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

/// A loaded weight blob: the raw f32 vector + per-parameter views.
#[derive(Debug, Clone)]
pub struct WeightBlob {
    pub data: Vec<f32>,
    pub params: Vec<ParamEntry>,
    pub fingerprint: Option<String>,
}

impl WeightBlob {
    /// Load `<path>` (+ `<path>.json` manifest).
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let raw = std::fs::read(path).with_context(|| format!("reading weights {path:?}"))?;
        ensure!(raw.len() % 4 == 0, "weight file not a multiple of 4 bytes");
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let man_path = format!("{}.json", path.display());
        let j = Json::parse_file(&man_path).with_context(|| format!("manifest {man_path}"))?;
        let total = j.req("total_f32")?.as_usize()?;
        ensure!(
            total == data.len(),
            "manifest says {} f32s, file holds {}",
            total,
            data.len()
        );
        let params: Vec<ParamEntry> = j
            .req("params")?
            .as_arr()?
            .iter()
            .map(|e| -> Result<ParamEntry> {
                Ok(ParamEntry {
                    name: e.req("name")?.as_str()?.to_string(),
                    offset: e.req("offset")?.as_usize()?,
                    size: e.req("size")?.as_usize()?,
                    shape: e.req("shape")?.as_usize_vec()?,
                })
            })
            .collect::<Result<_>>()?;
        let fingerprint = j
            .get("fingerprint")
            .and_then(|f| f.as_str().ok().map(|s| s.to_string()));
        let mut off = 0;
        for p in &params {
            ensure!(p.offset == off, "param {} offset mismatch", p.name);
            ensure!(
                p.size == p.shape.iter().product::<usize>(),
                "param {} size/shape mismatch",
                p.name
            );
            off += p.size;
        }
        ensure!(off == data.len(), "manifest does not cover the blob");
        Ok(Self {
            data,
            params,
            fingerprint,
        })
    }

    /// View one parameter's values.
    pub fn view(&self, name: &str) -> Result<(&[f32], &[usize])> {
        let p = self
            .params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("no param named {name}"))?;
        Ok((&self.data[p.offset..p.offset + p.size], &p.shape))
    }

    pub fn total_params(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_blob(dir: &Path, params: &[(&str, Vec<usize>, Vec<f32>)]) -> std::path::PathBuf {
        let mut data: Vec<u8> = Vec::new();
        let mut man = Vec::new();
        let mut off = 0;
        for (name, shape, vals) in params {
            for v in vals {
                data.extend_from_slice(&v.to_le_bytes());
            }
            let shape_s = format!("{:?}", shape);
            man.push(format!(
                "{{\"name\": \"{}\", \"offset\": {}, \"size\": {}, \"shape\": {}}}",
                name, off, vals.len(), shape_s
            ));
            off += vals.len();
        }
        let p = dir.join("w.bin");
        std::fs::write(&p, &data).unwrap();
        std::fs::write(
            dir.join("w.bin.json"),
            format!(
                "{{\"total_f32\": {}, \"params\": [{}], \"fingerprint\": \"fp1\"}}",
                off,
                man.join(",")
            ),
        )
        .unwrap();
        p
    }

    #[test]
    fn load_and_view() {
        let dir = std::env::temp_dir().join("moeb_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_blob(
            &dir,
            &[
                ("a", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                ("b", vec![3], vec![5.0, 6.0, 7.0]),
            ],
        );
        let blob = WeightBlob::load(&p).unwrap();
        assert_eq!(blob.total_params(), 7);
        assert_eq!(blob.fingerprint.as_deref(), Some("fp1"));
        let (vals, shape) = blob.view("b").unwrap();
        assert_eq!(vals, &[5.0, 6.0, 7.0]);
        assert_eq!(shape, &[3]);
        assert!(blob.view("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join("moeb_weights_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_blob(&dir, &[("a", vec![3], vec![1.0, 2.0])]); // shape says 3, data 2
        assert!(WeightBlob::load(&p).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn real_artifacts_if_present() {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/predictor_weights.bin");
        if !p.exists() {
            return;
        }
        let blob = WeightBlob::load(&p).unwrap();
        assert!(blob.total_params() > 100_000);
        let (le, shape) = blob.view("layer_emb").unwrap();
        assert_eq!(shape[0], 27);
        assert!(le.iter().all(|x| x.is_finite()));
    }
}
