//! The simulator core (paper §4.1.4):
//!
//! > "each test prompt is replayed token by token.  The first n tokens
//! > simply warm an LRU Expert Cache ...  From token n+1 onward we (i)
//! > flatten the partial REAM ... (iii) select the most similar sketch to
//! > predict which experts will fire in the next layer.  These predicted
//! > experts are prefetched into Expert Cache; the simulator then reveals
//! > the ground-truth expert IDs from the trace.  A prediction hit is
//! > recorded if the ground-truth expert appears in the predicted set,
//! > and a cache hit if it is already resident."
//!
//! Generalized over `ExpertPredictor`, so the same engine scores
//! MoE-Beyond, MoE-Infinity's EAM matching, DeepSpeed-MoE next-layer,
//! BrainStorm popularity, the oracle, and pure LRU — and over
//! [`ExpertMemory`], so the same replay loop drives the flat VRAM model
//! and the tiered GPU ↔ host ↔ SSD hierarchy (or any future residency
//! backend) without a second copy of itself.

use crate::cache::{CachePolicy, CacheStats};
use crate::config::{CacheConfig, SimConfig, TierConfig};
use crate::memory::{ExpertMemory, FlatMemory, TieredMemory};
use crate::obs::{ObsSink, TraceEvent};
use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::trace::{CompiledTrace, PromptTrace};
use crate::util::ExpertSet;

/// Reusable simulation engine (residency persists across prompts unless
/// the caller builds a fresh engine per prompt).
///
/// Generic over the [`ExpertSet`] word width `N` (default 1 = up to 64
/// experts); every replay loop below is monomorphized per width, so the
/// 64-expert fast path compiles exactly as before.
pub struct SimEngine<const N: usize = 1> {
    /// The single residency backend: flat or tiered, the replay loop
    /// cannot tell the difference.
    pub memory: Box<dyn ExpertMemory<N>>,
    pub sim: SimConfig,
    pub n_experts: usize,
    /// Per-token prediction buffer reused across the replay (one
    /// `predict_layers` call per token writes into it).
    pred_scratch: Vec<ExpertSet<N>>,
    /// Trace sink (default no-op).  When active, replay emits a request
    /// span per prompt and a decode-step event per measured token, on a
    /// virtual clock equal to the memory model's cumulative
    /// demand + stall µs.
    obs: ObsSink,
}

impl<const N: usize> SimEngine<N> {
    pub fn new(memory: Box<dyn ExpertMemory<N>>, sim: SimConfig, n_experts: usize) -> Self {
        Self {
            memory,
            sim,
            n_experts,
            pred_scratch: Vec::new(),
            obs: ObsSink::default(),
        }
    }

    /// Attach an observability sink to the engine AND its memory
    /// backend, so replay spans and the backend's cache/tier events land
    /// in the same trace on the same virtual clock.  The world shape is
    /// exported as gauges (`expert_set_width_words`, `n_experts`) so
    /// traces from wide worlds are self-describing.
    pub fn set_obs(&mut self, obs: ObsSink) {
        self.memory.set_obs(obs.clone());
        if let Some(reg) = obs.registry() {
            reg.gauge("expert_set_width_words", &[]).set(N as f64);
            reg.gauge("n_experts", &[]).set(self.n_experts as f64);
        }
        self.obs = obs;
    }

    /// Flat residency over `cache` (the seed Fig-7 configuration): pure
    /// hit-rate counting, costs accumulate off the critical path with an
    /// unbounded overlap window.
    pub fn flat(
        cache: Box<dyn CachePolicy>,
        sim: SimConfig,
        cache_cfg: CacheConfig,
        n_experts: usize,
    ) -> Self {
        let budget = sim.prefetch_budget;
        Self::new(
            Box::new(FlatMemory::<N>::new(
                cache,
                cache_cfg,
                n_experts,
                budget,
                f64::INFINITY,
            )),
            sim,
            n_experts,
        )
    }

    /// Tiered residency (GPU ↔ host ↔ SSD; see [`crate::tier`]).
    pub fn tiered(
        cfg: &TierConfig,
        sim: SimConfig,
        n_experts: usize,
        overlap_budget_us: f64,
    ) -> crate::Result<Self> {
        let budget = sim.prefetch_budget;
        Ok(Self::new(
            Box::new(TieredMemory::<N>::new(cfg, n_experts, budget, overlap_budget_us)?),
            sim,
            n_experts,
        ))
    }

    /// Replay one prompt; counters accumulate into `stats`.
    ///
    /// Warm-up tokens "simply warm" the residency (paper §4.1.4): their
    /// lookups move experts but are entirely unmeasured — no hit/miss
    /// counters, no modeled cost.  `stats.hits`/`misses` keep their
    /// Fig-7 meaning on every backend (served from GPU VRAM or not);
    /// `stats.transfer_us` is the flat PCIe cost or the depth-dependent
    /// tier fetch cost, whichever the backend models.
    pub fn run_prompt(
        &mut self,
        trace: &PromptTrace,
        predictor: &mut dyn ExpertPredictor<N>,
        stats: &mut CacheStats,
    ) {
        let compiled = CompiledTrace::<N>::compile(trace);
        self.run_prompt_compiled(trace, &compiled, predictor, stats)
    }

    /// [`run_prompt`](SimEngine::run_prompt) over a pre-compiled set
    /// table: the sweep harnesses compile a corpus ONCE and replay it at
    /// every grid point, so the inner loop never rebuilds an `ExpertSet`
    /// from raw trace bytes.  `trace` and `compiled` must describe the
    /// same prompt (the raw trace is still what predictors see).
    pub fn run_prompt_compiled(
        &mut self,
        trace: &PromptTrace,
        compiled: &CompiledTrace<N>,
        predictor: &mut dyn ExpertPredictor<N>,
        stats: &mut CacheStats,
    ) {
        debug_assert_eq!(compiled.n_tokens(), trace.n_tokens());
        debug_assert_eq!(compiled.n_layers(), trace.n_layers as usize);
        let n_layers = trace.n_layers as usize;
        let warm = self.sim.warmup_tokens.min(trace.n_tokens());
        predictor.begin_prompt(trace);
        self.pred_scratch.clear();
        self.pred_scratch.resize(n_layers, ExpertSet::EMPTY);

        // replay's virtual clock = the memory model's cumulative
        // demand + stall µs; a pure function of the trace, so traced
        // runs stay byte-deterministic
        let obs_on = self.obs.is_active();
        if obs_on {
            let (d, s) = self.memory.cost_marks();
            self.obs.set_now_us(d + s);
            self.obs.emit(|ts| TraceEvent::RequestBegin {
                ts_us: ts,
                request: trace.prompt_id as u64,
                tenant: 0,
            });
        }

        for t in 0..trace.n_tokens() {
            let ctx = DecodeContext { trace, t };
            let measured = t >= warm;
            if measured && obs_on {
                // stamp the token start: the token's memory events and
                // its decode-step span all carry this timestamp
                let (d, s) = self.memory.cost_marks();
                self.obs.set_now_us(d + s);
            }
            if measured {
                // ONE predictor call per token: predictions for every
                // layer are issued before the token's first layer runs —
                // the serving engine's timing (`ModelEngine::step_stream`
                // refreshes all layers per decode step), so predictors
                // condition on observations up to and including the
                // PREVIOUS token.
                predictor.predict_layers(&ctx, 0..n_layers, &mut self.pred_scratch);
            }
            for l in 0..n_layers {
                let truth = compiled.set(t, l);

                if measured {
                    // prefetch BEFORE the layer "executes"; the prefetch
                    // horizon is `lookahead_layers` (paper: 1, issued
                    // while layer l-1 computes — here equivalently just
                    // before l runs).  Only the DMA budget's worth of
                    // transfers can land within the window; later ones
                    // are issued but arrive too late to help this layer.
                    let predicted = self.pred_scratch[l];
                    let pf = self.memory.prefetch(l, predicted);
                    stats.prefetches += pf.issued;
                    stats.wasted_prefetches += pf.too_late;
                    // prediction hit accounting: set-level overlap is the
                    // per-ground-truth-expert count in one popcount
                    stats.prediction_total += truth.len() as u64;
                    stats.prediction_hits += truth.overlap(predicted) as u64;
                }

                // the layer executes: one batched lookup of the whole
                // ground-truth set (was: one virtual call per expert)
                let batch = self.memory.lookup_set(l, truth, measured);
                if measured {
                    let hits = batch.hits.len() as u64;
                    stats.hits += hits;
                    stats.misses += truth.len() as u64 - hits;
                    stats.transfer_us += batch.fetch_us;
                }
                self.memory.end_layer();
                predictor.observe(&ctx, l, truth);
            }
            if measured && obs_on {
                let (d, s) = self.memory.cost_marks();
                let end = d + s;
                self.obs.emit(|ts| TraceEvent::DecodeStep {
                    ts_us: ts,
                    request: trace.prompt_id as u64,
                    tenant: 0,
                    token: t as u32,
                    cost_us: end - ts,
                });
            }
        }
        if obs_on {
            let (d, s) = self.memory.cost_marks();
            self.obs.set_now_us(d + s);
            self.obs.emit(|ts| TraceEvent::RequestEnd {
                ts_us: ts,
                request: trace.prompt_id as u64,
                tenant: 0,
            });
        }
        predictor.end_prompt(trace);
    }
}

/// Convenience: run one prompt on a fresh LRU cache.
pub fn simulate_prompt(
    trace: &PromptTrace,
    predictor: &mut dyn ExpertPredictor,
    capacity: usize,
    sim: SimConfig,
    n_experts: usize,
) -> CacheStats {
    let mut stats = CacheStats::default();
    let mut engine: SimEngine = SimEngine::flat(
        Box::new(crate::cache::LruCache::new(capacity)),
        sim,
        CacheConfig::default().with_capacity(capacity),
        n_experts,
    );
    engine.run_prompt(trace, predictor, &mut stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::predictor::{NoPrefetch, OraclePredictor};

    /// Deterministic toy trace: token t at layer l activates experts
    /// {(t+l) % 8, (t+l+1) % 8} (top-2, 2 layers).
    fn toy_trace(n_tokens: usize) -> PromptTrace {
        let n_layers = 2u16;
        let mut experts = Vec::new();
        for t in 0..n_tokens {
            for l in 0..n_layers as usize {
                experts.push(((t + l) % 8) as u8);
                experts.push(((t + l + 1) % 8) as u8);
            }
        }
        PromptTrace {
            prompt_id: 0,
            n_layers,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0; n_tokens],
            embeddings: vec![],
            experts,
        }
    }

    #[test]
    fn hits_plus_misses_equals_measured_lookups() {
        let tr = toy_trace(32);
        let sim = SimConfig::default(); // warmup_tokens = 8 are unmeasured
        let stats = simulate_prompt(&tr, &mut NoPrefetch, 4, sim.clone(), 64);
        assert_eq!(stats.lookups(), ((32 - sim.warmup_tokens) * 2 * 2) as u64);
    }

    #[test]
    fn oracle_with_full_capacity_hits_after_warmup() {
        let tr = toy_trace(32);
        let sim = SimConfig {
            warmup_tokens: 0,
            ..Default::default()
        };
        let stats = simulate_prompt(&tr, &mut OraclePredictor::new(), 10_000, sim, 64);
        // oracle prefetches exactly the truth before every layer: 100% hits
        assert_eq!(stats.misses, 0);
        assert_eq!(stats.prediction_hit_rate(), 1.0);
    }

    #[test]
    fn cold_misses_absorbed_by_warmup_with_large_cache() {
        // the 8-token warmup touches the full expert ring (mod-8 pattern),
        // so with ample capacity the measured phase is all hits
        let tr = toy_trace(64);
        let stats = simulate_prompt(&tr, &mut NoPrefetch, 1000, SimConfig::default(), 64);
        assert_eq!(stats.misses, 0);
        assert_eq!(stats.hits, stats.lookups());
    }

    #[test]
    fn warmup_suppresses_prediction_counters() {
        let tr = toy_trace(10);
        let sim = SimConfig {
            warmup_tokens: 10,
            ..Default::default()
        };
        let stats = simulate_prompt(&tr, &mut OraclePredictor::new(), 8, sim, 64);
        assert_eq!(stats.prediction_total, 0);
        assert_eq!(stats.prefetches, 0);
    }

    /// Conservation + capacity invariants under arbitrary traces
    /// (seeded random property loop).
    #[test]
    fn prop_conservation_and_capacity() {
        let mut rng = crate::util::Rng::new(51);
        for _case in 0..120 {
            let n_tokens = rng.range(1, 40);
            let cap = rng.range(1, 32);
            let n_layers = 3u16;
            let top_k = 2u16;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * n_layers as usize {
                let a = rng.below(16) as u8;
                let b = (a + 1 + rng.below(14) as u8) % 16;
                experts.push(a);
                experts.push(b);
            }
            let tr = PromptTrace {
                prompt_id: 0, n_layers, top_k, d_emb: 0,
                tokens: vec![0; n_tokens], embeddings: vec![], experts,
            };
            let mut engine: SimEngine = SimEngine::flat(
                Box::new(crate::cache::LruCache::new(cap)),
                SimConfig::default(),
                crate::config::CacheConfig::default().with_capacity(cap),
                16,
            );
            let mut stats = CacheStats::default();
            engine.run_prompt(&tr, &mut NoPrefetch, &mut stats);
            let measured = n_tokens.saturating_sub(SimConfig::default().warmup_tokens);
            assert_eq!(stats.lookups(), (measured * 3 * 2) as u64);
            assert!(engine.memory.resident_count() <= cap);
        }
    }

    fn tiered_engine(tiers: Vec<crate::tier::TierSpec>) -> SimEngine {
        SimEngine::tiered(
            &TierConfig {
                tiers,
                policy: "lru".into(),
            },
            SimConfig::default(),
            64,
            1_000.0,
        )
        .unwrap()
    }

    /// A GPU tier backed by a host tier that holds everything must make
    /// the same hit/miss decisions as the flat LRU path (tiered mode is
    /// opt-in and must not change Fig-7 numbers).
    #[test]
    fn tiered_gpu_hit_rate_matches_flat_lru() {
        use crate::tier::TierSpec;
        let tr = toy_trace(48);
        let flat = simulate_prompt(&tr, &mut NoPrefetch, 4, SimConfig::default(), 64);

        let mut engine = tiered_engine(vec![
            TierSpec::new("gpu", 4, 2.0, 0.0),
            // same fetch cost as CacheConfig::default().pcie_us_per_expert
            TierSpec::new("host", 2 * 64, 1400.0, 0.0),
        ]);
        let mut stats = CacheStats::default();
        engine.run_prompt(&tr, &mut NoPrefetch, &mut stats);
        assert_eq!(stats.hits, flat.hits);
        assert_eq!(stats.misses, flat.misses);
        assert!((stats.transfer_us - flat.transfer_us).abs() < 1e-9);
        let m = engine.memory.stats();
        assert_eq!(m.tiers.unwrap().served[0], stats.hits);
    }

    /// Shrinking the GPU below the working set degrades gracefully when a
    /// warm host tier absorbs the deep misses at PCIe cost; without it,
    /// every deep miss pays the flash fetch.
    #[test]
    fn warm_host_tier_degrades_gracefully() {
        use crate::tier::TierSpec;
        let tr = toy_trace(64); // 16-key working set (8 experts × 2 layers)
        let mut warm_host = tiered_engine(vec![
            TierSpec::new("gpu", 4, 2.0, 0.0),
            TierSpec::new("host", 16, 1400.0, 0.0),
            TierSpec::new("ssd", 128, 22_000.0, 0.0),
        ]);
        let mut ssd_only = tiered_engine(vec![
            TierSpec::new("gpu", 4, 2.0, 0.0),
            TierSpec::new("ssd", 128, 22_000.0, 0.0),
        ]);
        let mut s1 = CacheStats::default();
        let mut s2 = CacheStats::default();
        warm_host.run_prompt(&tr, &mut NoPrefetch, &mut s1);
        ssd_only.run_prompt(&tr, &mut NoPrefetch, &mut s2);
        // identical GPU tier -> identical hit rate ...
        assert_eq!(s1.hits, s2.hits);
        // ... but very different modeled latency: the host tier serves
        // the deep misses at 1400µs instead of 22000µs
        let warm = warm_host.memory.stats();
        let cold = ssd_only.memory.stats();
        let warm_tiers = warm.tiers.as_ref().unwrap();
        assert!(warm_tiers.served[1] > 0, "host tier never used");
        assert!(
            warm.critical_path_us() < cold.critical_path_us() / 4.0,
            "warm host {} vs ssd-only {}",
            warm.critical_path_us(),
            cold.critical_path_us()
        );
        // demotion-on-eviction keeps copies alive: after warm-up nothing
        // should fall back to a cold backing-store read
        assert_eq!(warm_tiers.cold, 0);
    }

    /// Hierarchy invariants survive a full tiered replay.
    #[test]
    fn tiered_replay_respects_capacities() {
        use crate::tier::TierSpec;
        let tr = toy_trace(40);
        let mut engine = tiered_engine(vec![
            TierSpec::new("gpu", 2, 2.0, 0.0),
            TierSpec::new("host", 5, 1400.0, 1400.0),
            TierSpec::new("ssd", 7, 22_000.0, 0.0),
        ]);
        let mut stats = CacheStats::default();
        engine.run_prompt(&tr, &mut OraclePredictor::new(), &mut stats);
        let m = engine.memory.stats();
        assert!(m.resident_per_depth[0] <= 2);
        assert!(m.resident_per_depth[1] <= 5);
        assert!(m.resident_per_depth[2] <= 7);
        // 16-key working set vs 14 total slots: evictions ripple down and
        // some copies fall off the bottom of the hierarchy
        let t = m.tiers.unwrap();
        assert!(t.demotions > 0);
        assert!(t.dropped > 0);
    }

    /// The oracle dominates no-prefetch at equal capacity.
    #[test]
    fn prop_oracle_dominates_no_prefetch() {
        let mut rng = crate::util::Rng::new(52);
        for _case in 0..120 {
            let cap = rng.range(4, 24);
            let n_tokens = 30usize;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * 2 {
                let a = rng.below(16) as u8;
                experts.push(a);
                experts.push((a + 1) % 16);
            }
            let tr = PromptTrace {
                prompt_id: 0, n_layers: 2, top_k: 2, d_emb: 0,
                tokens: vec![0; n_tokens], embeddings: vec![], experts,
            };
            let s_none = simulate_prompt(&tr, &mut NoPrefetch, cap, SimConfig::default(), 16);
            let s_oracle = simulate_prompt(&tr, &mut OraclePredictor::new(), cap, SimConfig::default(), 16);
            assert!(s_oracle.hit_rate() >= s_none.hit_rate());
        }
    }
}
