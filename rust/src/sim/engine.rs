//! The simulator core (paper §4.1.4):
//!
//! > "each test prompt is replayed token by token.  The first n tokens
//! > simply warm an LRU Expert Cache ...  From token n+1 onward we (i)
//! > flatten the partial REAM ... (iii) select the most similar sketch to
//! > predict which experts will fire in the next layer.  These predicted
//! > experts are prefetched into Expert Cache; the simulator then reveals
//! > the ground-truth expert IDs from the trace.  A prediction hit is
//! > recorded if the ground-truth expert appears in the predicted set,
//! > and a cache hit if it is already resident."
//!
//! Generalized over `ExpertPredictor`, so the same engine scores
//! MoE-Beyond, MoE-Infinity's EAM matching, DeepSpeed-MoE next-layer,
//! BrainStorm popularity, the oracle, and pure LRU.

use crate::cache::{policy, CachePolicy, CacheStats, VramModel};
use crate::config::{CacheConfig, SimConfig, TierConfig};
use crate::predictor::{DecodeContext, ExpertPredictor};
use crate::tier::{TierCostModel, TierStats, TieredCache};
use crate::trace::PromptTrace;

/// Tiered-memory state for the simulator (opt-in via
/// [`SimEngine::with_tiers`]): the hierarchy, its cost model, and the
/// per-depth serve counters.
pub struct TieredSim {
    pub cache: TieredCache,
    pub cost: TierCostModel,
    pub stats: TierStats,
}

/// Reusable simulation engine (cache state persists across prompts unless
/// `reset_between_prompts`).
pub struct SimEngine {
    pub cache: Box<dyn CachePolicy>,
    pub sim: SimConfig,
    pub cache_cfg: CacheConfig,
    pub n_experts: usize,
    /// Model a PCIe/VRAM latency budget (None = pure hit-rate counting).
    pub vram: Option<VramModel>,
    /// Tiered-memory mode: when set, lookups go through the hierarchy
    /// and `cache`/`vram` above are ignored.
    pub tier: Option<TieredSim>,
}

impl SimEngine {
    pub fn new(cache: Box<dyn CachePolicy>, sim: SimConfig, cache_cfg: CacheConfig, n_experts: usize) -> Self {
        Self {
            cache,
            sim,
            cache_cfg,
            n_experts,
            vram: None,
            tier: None,
        }
    }

    pub fn with_vram(mut self, overlap_budget_us: f64) -> Self {
        self.vram = Some(VramModel::new(self.cache_cfg.clone(), overlap_budget_us));
        self
    }

    /// Opt into tiered expert memory (GPU ↔ host ↔ SSD); the flat
    /// `cache`/`vram` pair is bypassed entirely.
    pub fn with_tiers(mut self, cfg: &TierConfig, overlap_budget_us: f64) -> crate::Result<Self> {
        cfg.validate()?;
        self.tier = Some(TieredSim {
            cache: TieredCache::build(&cfg.policy, &cfg.tiers)?,
            cost: TierCostModel::new(cfg.tiers.clone(), overlap_budget_us),
            stats: TierStats::new(cfg.tiers.len()),
        });
        Ok(self)
    }

    /// Replay one prompt; counters accumulate into `stats`.
    pub fn run_prompt(
        &mut self,
        trace: &PromptTrace,
        predictor: &mut dyn ExpertPredictor,
        stats: &mut CacheStats,
    ) {
        if self.tier.is_some() {
            return self.run_prompt_tiered(trace, predictor, stats);
        }
        let n_layers = trace.n_layers as usize;
        let warm = self.sim.warmup_tokens.min(trace.n_tokens());
        predictor.begin_prompt(trace);

        for t in 0..trace.n_tokens() {
            let ctx = DecodeContext { trace, t };
            for l in 0..n_layers {
                let truth = trace.expert_set(t, l);

                if t >= warm {
                    // predict + prefetch BEFORE the layer "executes";
                    // the prefetch horizon is `lookahead_layers` (paper: 1,
                    // issued while layer l-1 computes — here equivalently
                    // just before l runs).  Only `prefetch_budget` DMA
                    // transfers can land within the window; later ones are
                    // issued but arrive too late to help this layer.
                    let predicted = predictor.predict(&ctx, l);
                    let mut landed = 0usize;
                    for e in predicted.iter() {
                        stats.prefetches += 1;
                        let k = policy::key(l, e, self.n_experts);
                        if self.cache.contains(k) {
                            self.cache.touch(k);
                            continue;
                        }
                        if landed >= self.sim.prefetch_budget {
                            stats.wasted_prefetches += 1;
                            continue;
                        }
                        landed += 1;
                        if let Some(v) = &mut self.vram {
                            v.on_prefetch();
                        }
                        self.cache.insert(k);
                    }
                    // prediction hit accounting (per ground-truth expert)
                    for e in truth.iter() {
                        stats.prediction_total += 1;
                        if predicted.contains(e) {
                            stats.prediction_hits += 1;
                        }
                    }
                }

                // the layer executes: look up each ground-truth expert.
                // Warm-up tokens "simply warm" the cache (paper §4.1.4) —
                // their lookups are not measured.
                for e in truth.iter() {
                    let k = policy::key(l, e, self.n_experts);
                    if self.cache.touch(k) {
                        if t >= warm {
                            stats.hits += 1;
                            if let Some(v) = &mut self.vram {
                                v.on_hit();
                            }
                        }
                    } else {
                        if t >= warm {
                            stats.misses += 1;
                            stats.transfer_us += self.cache_cfg.pcie_us_per_expert;
                            if let Some(v) = &mut self.vram {
                                v.on_demand_miss();
                            }
                        }
                        self.cache.insert(k);
                    }
                }
                if let Some(v) = &mut self.vram {
                    v.end_layer();
                }
                predictor.observe(&ctx, l, truth);
            }
        }
        predictor.end_prompt(trace);
    }

    /// The tiered twin of the loop above: same warm-up and prefetch-budget
    /// semantics, but lookups promote through the hierarchy and misses
    /// charge the deepest tier actually reached.  `stats.hits`/`misses`
    /// keep their Fig-7 meaning (served from GPU VRAM or not);
    /// `stats.transfer_us` becomes depth-dependent.
    fn run_prompt_tiered(
        &mut self,
        trace: &PromptTrace,
        predictor: &mut dyn ExpertPredictor,
        stats: &mut CacheStats,
    ) {
        let mut tier = self.tier.take().expect("tiered mode not configured");
        let n_layers = trace.n_layers as usize;
        let warm = self.sim.warmup_tokens.min(trace.n_tokens());
        let budget = self.sim.prefetch_budget;
        let n_experts = self.n_experts;
        let deepest = tier.cache.deepest();
        predictor.begin_prompt(trace);

        for t in 0..trace.n_tokens() {
            let ctx = DecodeContext { trace, t };
            for l in 0..n_layers {
                let truth = trace.expert_set(t, l);

                if t >= warm {
                    let predicted = predictor.predict(&ctx, l);
                    let mut landed = 0usize;
                    for e in predicted.iter() {
                        stats.prefetches += 1;
                        let k = policy::key(l, e, n_experts);
                        if tier.cache.locate(k) == Some(0) {
                            tier.cache.touch(k);
                            continue;
                        }
                        if landed >= budget {
                            stats.wasted_prefetches += 1;
                            continue;
                        }
                        landed += 1;
                        let promo = tier.cache.promote(k);
                        tier.cost.on_prefetch(promo.found.unwrap_or(deepest));
                        tier.stats.prefetch_promotions += 1;
                        tier.cost.charge_demotions(&mut tier.stats, &promo);
                    }
                    for e in truth.iter() {
                        stats.prediction_total += 1;
                        if predicted.contains(e) {
                            stats.prediction_hits += 1;
                        }
                    }
                }

                // the layer executes: each ground-truth expert is served
                // from whatever depth holds it and promoted to the GPU.
                // Warm-up lookups warm the hierarchy but are unmeasured.
                for e in truth.iter() {
                    let k = policy::key(l, e, n_experts);
                    if tier.cache.locate(k) == Some(0) {
                        tier.cache.touch(k);
                        if t >= warm {
                            stats.hits += 1;
                            tier.stats.record_served(0);
                            tier.cost.on_hit();
                        }
                    } else {
                        // warm-up promotions warm the hierarchy but are
                        // entirely unmeasured (no cost, no counters), so
                        // every TierStats counter shares one epoch
                        let promo = tier.cache.promote(k);
                        if t >= warm {
                            let depth = promo.found.unwrap_or(deepest);
                            stats.misses += 1;
                            stats.transfer_us += tier.cost.fetch_us(depth);
                            match promo.found {
                                Some(d) => tier.stats.record_served(d),
                                None => tier.stats.cold += 1,
                            }
                            tier.cost.on_demand_fetch(depth);
                            tier.stats.promotions += 1;
                            tier.cost.charge_demotions(&mut tier.stats, &promo);
                        }
                    }
                }
                tier.cost.end_layer();
                predictor.observe(&ctx, l, truth);
            }
        }
        predictor.end_prompt(trace);
        self.tier = Some(tier);
    }
}

/// Convenience: run one prompt on a fresh LRU cache.
pub fn simulate_prompt(
    trace: &PromptTrace,
    predictor: &mut dyn ExpertPredictor,
    capacity: usize,
    sim: SimConfig,
    n_experts: usize,
) -> CacheStats {
    let mut stats = CacheStats::default();
    let mut engine = SimEngine::new(
        Box::new(crate::cache::LruCache::new(capacity)),
        sim,
        CacheConfig::default().with_capacity(capacity),
        n_experts,
    );
    engine.run_prompt(trace, predictor, &mut stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::predictor::{NoPrefetch, OraclePredictor};

    /// Deterministic toy trace: token t at layer l activates experts
    /// {(t+l) % 8, (t+l+1) % 8} (top-2, 2 layers).
    fn toy_trace(n_tokens: usize) -> PromptTrace {
        let n_layers = 2u16;
        let mut experts = Vec::new();
        for t in 0..n_tokens {
            for l in 0..n_layers as usize {
                experts.push(((t + l) % 8) as u8);
                experts.push(((t + l + 1) % 8) as u8);
            }
        }
        PromptTrace {
            prompt_id: 0,
            n_layers,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0; n_tokens],
            embeddings: vec![],
            experts,
        }
    }

    #[test]
    fn hits_plus_misses_equals_measured_lookups() {
        let tr = toy_trace(32);
        let sim = SimConfig::default(); // warmup_tokens = 8 are unmeasured
        let stats = simulate_prompt(&tr, &mut NoPrefetch, 4, sim.clone(), 64);
        assert_eq!(stats.lookups(), ((32 - sim.warmup_tokens) * 2 * 2) as u64);
    }

    #[test]
    fn oracle_with_full_capacity_hits_after_warmup() {
        let tr = toy_trace(32);
        let sim = SimConfig {
            warmup_tokens: 0,
            ..Default::default()
        };
        let stats = simulate_prompt(&tr, &mut OraclePredictor::new(), 10_000, sim, 64);
        // oracle prefetches exactly the truth before every layer: 100% hits
        assert_eq!(stats.misses, 0);
        assert_eq!(stats.prediction_hit_rate(), 1.0);
    }

    #[test]
    fn cold_misses_absorbed_by_warmup_with_large_cache() {
        // the 8-token warmup touches the full expert ring (mod-8 pattern),
        // so with ample capacity the measured phase is all hits
        let tr = toy_trace(64);
        let stats = simulate_prompt(&tr, &mut NoPrefetch, 1000, SimConfig::default(), 64);
        assert_eq!(stats.misses, 0);
        assert_eq!(stats.hits, stats.lookups());
    }

    #[test]
    fn warmup_suppresses_prediction_counters() {
        let tr = toy_trace(10);
        let sim = SimConfig {
            warmup_tokens: 10,
            ..Default::default()
        };
        let stats = simulate_prompt(&tr, &mut OraclePredictor::new(), 8, sim, 64);
        assert_eq!(stats.prediction_total, 0);
        assert_eq!(stats.prefetches, 0);
    }

    /// Conservation + capacity invariants under arbitrary traces
    /// (seeded random property loop).
    #[test]
    fn prop_conservation_and_capacity() {
        let mut rng = crate::util::Rng::new(51);
        for _case in 0..120 {
            let n_tokens = rng.range(1, 40);
            let cap = rng.range(1, 32);
            let n_layers = 3u16;
            let top_k = 2u16;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * n_layers as usize {
                let a = rng.below(16) as u8;
                let b = (a + 1 + rng.below(14) as u8) % 16;
                experts.push(a);
                experts.push(b);
            }
            let tr = PromptTrace {
                prompt_id: 0, n_layers, top_k, d_emb: 0,
                tokens: vec![0; n_tokens], embeddings: vec![], experts,
            };
            let mut engine = SimEngine::new(
                Box::new(crate::cache::LruCache::new(cap)),
                SimConfig::default(),
                crate::config::CacheConfig::default().with_capacity(cap),
                16,
            );
            let mut stats = CacheStats::default();
            engine.run_prompt(&tr, &mut NoPrefetch, &mut stats);
            let measured = n_tokens.saturating_sub(SimConfig::default().warmup_tokens);
            assert_eq!(stats.lookups(), (measured * 3 * 2) as u64);
            assert!(engine.cache.len() <= cap);
        }
    }

    fn tiered_engine(cap: usize, tiers: Vec<crate::tier::TierSpec>) -> SimEngine {
        SimEngine::new(
            Box::new(crate::cache::LruCache::new(cap)),
            SimConfig::default(),
            crate::config::CacheConfig::default().with_capacity(cap),
            64,
        )
        .with_tiers(
            &TierConfig {
                tiers,
                policy: "lru".into(),
            },
            1_000.0,
        )
        .unwrap()
    }

    /// A GPU tier backed by a host tier that holds everything must make
    /// the same hit/miss decisions as the flat LRU path (tiered mode is
    /// opt-in and must not change Fig-7 numbers).
    #[test]
    fn tiered_gpu_hit_rate_matches_flat_lru() {
        use crate::tier::TierSpec;
        let tr = toy_trace(48);
        let flat = simulate_prompt(&tr, &mut NoPrefetch, 4, SimConfig::default(), 64);

        let mut engine = tiered_engine(
            4,
            vec![
                TierSpec::new("gpu", 4, 2.0, 0.0),
                // same fetch cost as CacheConfig::default().pcie_us_per_expert
                TierSpec::new("host", 2 * 64, 1400.0, 0.0),
            ],
        );
        let mut stats = CacheStats::default();
        engine.run_prompt(&tr, &mut NoPrefetch, &mut stats);
        assert_eq!(stats.hits, flat.hits);
        assert_eq!(stats.misses, flat.misses);
        assert!((stats.transfer_us - flat.transfer_us).abs() < 1e-9);
        let t = engine.tier.as_ref().unwrap();
        assert_eq!(t.stats.served[0], stats.hits);
    }

    /// Shrinking the GPU below the working set degrades gracefully when a
    /// warm host tier absorbs the deep misses at PCIe cost; without it,
    /// every deep miss pays the flash fetch.
    #[test]
    fn warm_host_tier_degrades_gracefully() {
        use crate::tier::TierSpec;
        let tr = toy_trace(64); // 16-key working set (8 experts × 2 layers)
        let mut warm_host = tiered_engine(
            4,
            vec![
                TierSpec::new("gpu", 4, 2.0, 0.0),
                TierSpec::new("host", 16, 1400.0, 0.0),
                TierSpec::new("ssd", 128, 22_000.0, 0.0),
            ],
        );
        let mut ssd_only = tiered_engine(
            4,
            vec![
                TierSpec::new("gpu", 4, 2.0, 0.0),
                TierSpec::new("ssd", 128, 22_000.0, 0.0),
            ],
        );
        let mut s1 = CacheStats::default();
        let mut s2 = CacheStats::default();
        warm_host.run_prompt(&tr, &mut NoPrefetch, &mut s1);
        ssd_only.run_prompt(&tr, &mut NoPrefetch, &mut s2);
        // identical GPU tier -> identical hit rate ...
        assert_eq!(s1.hits, s2.hits);
        // ... but very different modeled latency: the host tier serves
        // the deep misses at 1400µs instead of 22000µs
        let warm = warm_host.tier.as_ref().unwrap();
        let cold = ssd_only.tier.as_ref().unwrap();
        assert!(warm.stats.served[1] > 0, "host tier never used");
        assert!(
            warm.cost.critical_path_us() < cold.cost.critical_path_us() / 4.0,
            "warm host {} vs ssd-only {}",
            warm.cost.critical_path_us(),
            cold.cost.critical_path_us()
        );
        // demotion-on-eviction keeps copies alive: after warm-up nothing
        // should fall back to a cold backing-store read
        assert_eq!(warm.stats.cold, 0);
    }

    /// Hierarchy invariants survive a full tiered replay.
    #[test]
    fn tiered_replay_respects_capacities() {
        use crate::tier::TierSpec;
        let tr = toy_trace(40);
        let mut engine = tiered_engine(
            2,
            vec![
                TierSpec::new("gpu", 2, 2.0, 0.0),
                TierSpec::new("host", 5, 1400.0, 1400.0),
                TierSpec::new("ssd", 7, 22_000.0, 0.0),
            ],
        );
        let mut stats = CacheStats::default();
        engine.run_prompt(&tr, &mut OraclePredictor::new(), &mut stats);
        let t = engine.tier.as_ref().unwrap();
        assert!(t.cache.len_at(0) <= 2);
        assert!(t.cache.len_at(1) <= 5);
        assert!(t.cache.len_at(2) <= 7);
        // 16-key working set vs 14 total slots: evictions ripple down and
        // some copies fall off the bottom of the hierarchy
        assert!(t.stats.demotions > 0);
        assert!(t.stats.dropped > 0);
    }

    /// The oracle dominates no-prefetch at equal capacity.
    #[test]
    fn prop_oracle_dominates_no_prefetch() {
        let mut rng = crate::util::Rng::new(52);
        for _case in 0..120 {
            let cap = rng.range(4, 24);
            let n_tokens = 30usize;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * 2 {
                let a = rng.below(16) as u8;
                experts.push(a);
                experts.push((a + 1) % 16);
            }
            let tr = PromptTrace {
                prompt_id: 0, n_layers: 2, top_k: 2, d_emb: 0,
                tokens: vec![0; n_tokens], embeddings: vec![], experts,
            };
            let s_none = simulate_prompt(&tr, &mut NoPrefetch, cap, SimConfig::default(), 16);
            let s_oracle = simulate_prompt(&tr, &mut OraclePredictor::new(), cap, SimConfig::default(), 16);
            assert!(s_oracle.hit_rate() >= s_none.hit_rate());
        }
    }
}
