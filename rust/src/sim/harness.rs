//! Experiment harness shared by the CLI, the criterion benches, and the
//! examples: one function per paper artifact (Fig 1-3, Fig 5-6, Fig 7,
//! Table 1), all reading the same `artifacts/` tree.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::config::{Artifacts, EamConfig, SimConfig};
use crate::eval::{eval_trace, EvalAccumulator};
use crate::predictor::{learned, LearnedModel, TracePredictions};
use crate::runtime::PjrtRuntime;
use crate::sim::sweep::{sweep_capacities, PredictorKind, SweepInputs, SweepResult};
use crate::trace::{analysis, store, PromptTrace};
use crate::util::ExpertSet;
use crate::Result;

/// Default capacity fractions for the Fig-7 sweep (paper: 10%..100%).
pub const FIG7_FRACS: &[f64] = &[0.05, 0.10, 0.15, 0.20, 0.30, 0.40, 0.60, 0.80, 1.00];

/// Locate the artifact tree: $MOEB_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("MOEB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn load_artifacts() -> Result<Artifacts> {
    Artifacts::discover(artifacts_root())
}

// ---------------------------------------------------------------------------
// Learned-prediction precompute with a binary disk cache
// ---------------------------------------------------------------------------

/// Precompute learned predictions for a trace set, caching the predicted
/// sets on disk (keyed by stride/top-k/count/set-width) so capacity
/// sweeps and repeated bench runs skip the PJRT pass.  The disk cache
/// stores only the sets, not the logits — Table-1 eval recomputes logits
/// in memory.
pub fn precompute_learned<const N: usize>(
    rt: &PjrtRuntime,
    arts: &Artifacts,
    traces: &[PromptTrace],
    stride: usize,
    top_k: usize,
    use_disk_cache: bool,
) -> Result<Vec<TracePredictions<N>>> {
    // cache key includes a cheap content fingerprint so regenerated
    // traces can never silently reuse stale predictions
    let fp: u64 = traces
        .iter()
        .map(|t| {
            t.prompt_id as u64
                ^ ((t.n_tokens() as u64) << 20)
                ^ (t.experts.iter().map(|&e| e as u64).sum::<u64>() << 32)
        })
        .fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b));
    let cache_path = arts.path(&format!(
        "cache/learned_s{}_k{}_n{}_w{}_{:016x}.bin",
        stride,
        top_k,
        traces.len(),
        N,
        fp
    ));
    if use_disk_cache {
        if let Ok(cached) = read_pred_cache(&cache_path, traces) {
            return Ok(cached);
        }
    }
    let model = LearnedModel::load(rt, arts)?;
    let mut out = Vec::with_capacity(traces.len());
    for tr in traces {
        out.push(learned::precompute(&model, tr, stride, top_k)?);
    }
    if use_disk_cache {
        let _ = write_pred_cache(&cache_path, &out);
    }
    Ok(out)
}

/// Pred-cache format: magic + version + word width, then per-trace
/// blocks.  Version 2 added the header and multi-word sets; v1 files
/// (raw count first) fail the magic check and read as a cache miss.
const PRED_CACHE_MAGIC: u32 = 0x4d42_5043; // "MBPC"
const PRED_CACHE_VERSION: u32 = 2;

fn write_pred_cache<const N: usize>(path: &Path, preds: &[TracePredictions<N>]) -> Result<()> {
    std::fs::create_dir_all(path.parent().unwrap())?;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&PRED_CACHE_MAGIC.to_le_bytes())?;
    f.write_all(&PRED_CACHE_VERSION.to_le_bytes())?;
    f.write_all(&(N as u32).to_le_bytes())?;
    f.write_all(&(preds.len() as u32).to_le_bytes())?;
    for p in preds {
        f.write_all(&(p.sets.len() as u32).to_le_bytes())?;
        f.write_all(&(p.n_layers as u32).to_le_bytes())?;
        f.write_all(&(p.n_experts as u32).to_le_bytes())?;
        for row in &p.sets {
            for s in row {
                for w in s.as_words() {
                    f.write_all(&w.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

fn read_pred_cache<const N: usize>(
    path: &Path,
    traces: &[PromptTrace],
) -> Result<Vec<TracePredictions<N>>> {
    use std::io::Read as _;
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b4)?;
    anyhow::ensure!(u32::from_le_bytes(b4) == PRED_CACHE_MAGIC, "not a pred cache");
    f.read_exact(&mut b4)?;
    anyhow::ensure!(
        u32::from_le_bytes(b4) == PRED_CACHE_VERSION,
        "pred cache version mismatch"
    );
    f.read_exact(&mut b4)?;
    anyhow::ensure!(
        u32::from_le_bytes(b4) as usize == N,
        "pred cache word-width mismatch"
    );
    f.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4) as usize;
    anyhow::ensure!(n == traces.len(), "cache count mismatch");
    let mut out = Vec::with_capacity(n);
    for tr in traces {
        f.read_exact(&mut b4)?;
        let n_tokens = u32::from_le_bytes(b4) as usize;
        anyhow::ensure!(n_tokens == tr.n_tokens(), "cache token-count mismatch");
        f.read_exact(&mut b4)?;
        let n_layers = u32::from_le_bytes(b4) as usize;
        f.read_exact(&mut b4)?;
        let n_experts = u32::from_le_bytes(b4) as usize;
        let mut sets = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let mut row = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let mut words = [0u64; N];
                for w in words.iter_mut() {
                    f.read_exact(&mut b8)?;
                    *w = u64::from_le_bytes(b8);
                }
                row.push(ExpertSet::from_words(words));
            }
            sets.push(row);
        }
        out.push(TracePredictions {
            n_layers,
            sets,
            logits: vec![Vec::new(); n_tokens],
            n_experts,
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// FIG 7 — cache hit rate vs capacity
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub predictor: String,
    pub capacity_pct: f64,
    pub hit_rate_pct: f64,
    pub prediction_hit_rate_pct: f64,
}

/// Run the full Fig-7 experiment for the given predictor kinds.
pub fn run_fig7(
    rt: &PjrtRuntime,
    arts: &Artifacts,
    kinds: &[PredictorKind],
    fracs: &[f64],
    max_test_prompts: usize,
    sim: SimConfig,
) -> Result<Vec<SweepResult>> {
    let test = store::read_traces(arts.path(&arts.split("test")?.path))?;
    let test = &test[..test.len().min(max_test_prompts)];
    let fit = store::read_traces(arts.path(&arts.split("train")?.path))?;
    let fit = &fit[..fit.len().min(120)];

    let learned_preds = if kinds.contains(&PredictorKind::Learned) {
        Some(precompute_learned(
            rt,
            arts,
            test,
            sim.predictor_stride,
            sim.predict_top_k,
            true,
        )?)
    } else {
        None
    };

    // compile the test corpus ONCE: every predictor's sweep shares the
    // packed tables and the memoized stack-distance profile
    let corpus: crate::trace::CompiledCorpus = crate::trace::CompiledCorpus::compile(test);
    let inputs: SweepInputs = SweepInputs {
        test_traces: test,
        fit_traces: fit,
        learned: learned_preds.as_deref(),
        compiled: Some(&corpus),
        sim,
        eam: EamConfig::default(),
        n_layers: arts.world.n_layers as usize,
        n_experts: arts.world.n_experts as usize,
    };

    kinds
        .iter()
        .map(|&k| sweep_capacities(k, fracs, &inputs))
        .collect()
}

/// Flatten sweep results into printable/serializable rows.
pub fn fig7_rows(results: &[SweepResult]) -> Vec<Fig7Row> {
    let mut rows = Vec::new();
    for r in results {
        for p in &r.points {
            rows.push(Fig7Row {
                predictor: r.predictor.clone(),
                capacity_pct: p.capacity_frac * 100.0,
                hit_rate_pct: p.hit_rate * 100.0,
                prediction_hit_rate_pct: p.prediction_hit_rate * 100.0,
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// TABLE 1 — predictor accuracy / F1 on the test split
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table1 {
    pub accuracy_pct: f64,
    pub macro_f1_pct: f64,
    pub micro_f1_pct: f64,
    pub exact_match_pct: f64,
    pub positions: u64,
    pub prompts: usize,
}

/// Evaluate the trained predictor on the test split (offline, full
/// windows — the paper's §3.2.4 protocol).
pub fn run_table1(rt: &PjrtRuntime, arts: &Artifacts, max_prompts: usize, split: &str) -> Result<Table1> {
    let traces = store::read_traces(arts.path(&arts.split(split)?.path))?;
    let traces = &traces[..traces.len().min(max_prompts)];
    let model = LearnedModel::load(rt, arts)?;
    let mut acc = EvalAccumulator::new(arts.world.n_experts as usize);
    for tr in traces {
        // offline eval: full-window stride, each token scored at its own
        // window row (the paper's §3.2.4 protocol)
        let preds: TracePredictions = learned::precompute_mode(
            &model,
            tr,
            model.window,
            arts.world.top_k as usize,
            true,
        )?;
        eval_trace(&preds, tr, &mut acc);
    }
    Ok(Table1 {
        accuracy_pct: acc.accuracy() * 100.0,
        macro_f1_pct: acc.macro_f1() * 100.0,
        micro_f1_pct: acc.micro_f1() * 100.0,
        exact_match_pct: acc.exact_match() * 100.0,
        positions: acc.positions,
        prompts: traces.len(),
    })
}

// ---------------------------------------------------------------------------
// FIGS 1-3 — trace analysis
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig123Report {
    pub n_prompts: usize,
    /// Fig 1: layer-1 aggregate histogram (64 counts).
    pub fig1_histogram: Vec<u64>,
    pub fig1_min: u64,
    pub fig1_max: u64,
    pub fig1_ratio: f64,
    /// Fig 2: single-prompt histogram + its peak experts.
    pub fig2_histogram: Vec<u64>,
    pub fig2_peak_experts: Vec<u8>,
    pub fig2_working_set: usize,
    /// Fig 3: heatmap summary (per-layer working-set sizes + reuse score).
    pub fig3_working_sets: Vec<usize>,
    pub fig3_cross_layer_reuse: f64,
    pub sparsity: SparsitySummary,
}

#[derive(Debug, Clone)]
pub struct SparsitySummary {
    pub mean_working_set: f64,
    pub working_set_frac: f64,
    pub mean_single_entropy: f64,
    pub aggregate_entropy: f64,
}

/// Reproduce the paper's §2.2 trace analysis on `n_prompts` test prompts
/// (paper: 122 Puffin prompts, probe layer 1, single prompt #6000).
pub fn run_fig123(arts: &Artifacts, n_prompts: usize, probe_layer: usize) -> Result<Fig123Report> {
    let world = crate::trace::WorldModel::load(arts.path("world.json"))?;
    // analytic generator gives us exactly-n prompts regardless of split sizes
    let mut gen = crate::trace::generator::TraceGenerator::new(
        &world,
        crate::trace::corpus::CorpusConfig::default(),
        6000,
    );
    let traces = gen.generate(n_prompts);
    let n_experts = arts.world.n_experts as usize;

    let fig1 = analysis::aggregate_layer_histogram(&traces, probe_layer, n_experts);
    let single = &traces[traces.len() / 2]; // the paper's "prompt #6000"
    let fig2 = analysis::single_prompt_histogram(single, probe_layer, n_experts);
    let heat = analysis::layer_expert_heatmap(single, n_experts);
    let rep = analysis::sparsity_report(&traces, probe_layer, n_experts);

    let peak_thresh = fig2.iter().max().copied().unwrap_or(0) / 3;
    let peaks: Vec<u8> = fig2
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > peak_thresh.max(1))
        .map(|(i, _)| i as u8)
        .collect();

    Ok(Fig123Report {
        n_prompts,
        fig1_min: *fig1.iter().min().unwrap(),
        fig1_max: *fig1.iter().max().unwrap(),
        fig1_ratio: *fig1.iter().max().unwrap() as f64
            / (*fig1.iter().min().unwrap()).max(1) as f64,
        fig1_histogram: fig1,
        fig2_peak_experts: peaks,
        fig2_working_set: single.layer_working_set(probe_layer).len() as usize,
        fig2_histogram: fig2,
        fig3_working_sets: heat
            .iter()
            .map(|row| row.iter().filter(|&&c| c > 0).count())
            .collect(),
        fig3_cross_layer_reuse: analysis::cross_layer_reuse(
            single,
            &world.layer_perm,
            n_experts,
        ),
        sparsity: SparsitySummary {
            mean_working_set: rep.mean_working_set,
            working_set_frac: rep.working_set_frac,
            mean_single_entropy: rep.mean_single_entropy,
            aggregate_entropy: rep.aggregate_entropy,
        },
    })
}

// ---------------------------------------------------------------------------
// FIGS 5-6 — training/validation curves from training_log.json
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct TrainStep {
    pub step: u64,
    pub loss: f64,
    pub acc: f64,
    pub f1: f64,
    pub exact: f64,
}

#[derive(Debug, Clone)]
pub struct ValEpoch {
    pub epoch: u64,
    pub loss: f64,
    pub acc: f64,
    pub f1: f64,
    pub exact: f64,
}

#[derive(Debug, Clone)]
pub struct TrainingLog {
    pub train_steps: Vec<TrainStep>,
    pub val_epochs: Vec<ValEpoch>,
    pub wall_seconds: f64,
}

pub fn load_training_log(arts: &Artifacts) -> Result<TrainingLog> {
    let j = crate::util::json::Json::parse_file(arts.path("training_log.json"))?;
    let train_steps = j
        .req("train_steps")?
        .as_arr()?
        .iter()
        .map(|e| -> Result<TrainStep> {
            Ok(TrainStep {
                step: e.req("step")?.as_u64()?,
                loss: e.req("loss")?.as_f64()?,
                acc: e.req("acc")?.as_f64()?,
                f1: e.req("f1")?.as_f64()?,
                exact: e.req("exact")?.as_f64()?,
            })
        })
        .collect::<Result<_>>()?;
    let val_epochs = j
        .req("val_epochs")?
        .as_arr()?
        .iter()
        .map(|e| -> Result<ValEpoch> {
            Ok(ValEpoch {
                epoch: e.req("epoch")?.as_u64()?,
                loss: e.req("loss")?.as_f64()?,
                acc: e.req("acc")?.as_f64()?,
                f1: e.req("f1")?.as_f64()?,
                exact: e.req("exact")?.as_f64()?,
            })
        })
        .collect::<Result<_>>()?;
    let wall_seconds = j.get("wall_seconds").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0);
    Ok(TrainingLog {
        train_steps,
        val_epochs,
        wall_seconds,
    })
}

/// Serialize Fig-7 rows as a JSON array (for --out files).
pub fn fig7_rows_json(rows: &[Fig7Row]) -> String {
    use crate::util::json::Json;
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("predictor", Json::str(&r.predictor)),
                    ("capacity_pct", Json::num(r.capacity_pct)),
                    ("hit_rate_pct", Json::num(r.hit_rate_pct)),
                    ("prediction_hit_rate_pct", Json::num(r.prediction_hit_rate_pct)),
                ])
            })
            .collect(),
    )
    .to_json_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pred_cache_roundtrip() {
        let traces = vec![PromptTrace {
            prompt_id: 0,
            n_layers: 3,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0, 1],
            embeddings: vec![],
            experts: vec![0; 12],
        }];
        let preds: Vec<TracePredictions> = vec![TracePredictions {
            n_layers: 3,
            sets: vec![
                vec![
                    ExpertSet::from_words([0b101]),
                    ExpertSet::from_words([0b110]),
                    ExpertSet::from_words([0b011]),
                ],
                vec![
                    ExpertSet::from_words([0b1]),
                    ExpertSet::from_words([0b10]),
                    ExpertSet::from_words([0b100]),
                ],
            ],
            logits: vec![Vec::new(), Vec::new()],
            n_experts: 64,
        }];
        let p = std::env::temp_dir().join("moeb_predcache_test.bin");
        write_pred_cache(&p, &preds).unwrap();
        let back = read_pred_cache(&p, &traces).unwrap();
        assert_eq!(back[0].sets, preds[0].sets);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn fig123_runs_without_pjrt() {
        // only needs world.json + traces, not the runtime
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("artifacts.json").exists() {
            return;
        }
        let arts = Artifacts::discover(&root).unwrap();
        let rep = run_fig123(&arts, 20, 0).unwrap();
        assert_eq!(rep.fig1_histogram.len(), 64);
        // wider per-prompt unions under token-level routing (route_beta)
        assert!(rep.fig2_working_set < 50);
        assert!(rep.sparsity.mean_single_entropy < rep.sparsity.aggregate_entropy);
        assert!(rep.fig3_cross_layer_reuse > 0.3);
    }
}
