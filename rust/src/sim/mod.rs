//! Trace-driven cache simulator (paper §4.1.4), the capacity-sweep
//! harness behind Fig 7, and the tiered-memory extension sweeping
//! host-RAM fraction and SSD bandwidth.  The replay loop drives a
//! [`crate::memory::ExpertMemory`] backend, so flat and tiered residency
//! share one engine; the sweep harness fans grid points out across
//! scoped worker threads with deterministic output.

mod engine;
pub mod harness;
pub mod sweep;

pub use engine::{simulate_prompt, SimEngine};
pub use sweep::{
    sweep_capacities, sweep_capacities_replay, sweep_capacities_replay_threaded,
    sweep_capacities_threaded, sweep_cluster, sweep_cluster_threaded, sweep_threads, sweep_tiered,
    sweep_tiered_replay, sweep_tiered_replay_threaded, sweep_tiered_threaded, ClusterSweepPoint,
    PredictorKind, SweepPoint, SweepResult, TierSweepPoint,
};
