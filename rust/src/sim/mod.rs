//! Trace-driven cache simulator (paper §4.1.4), the capacity-sweep
//! harness behind Fig 7, and the tiered-memory extension sweeping
//! host-RAM fraction and SSD bandwidth.

mod engine;
pub mod harness;
pub mod sweep;

pub use engine::{simulate_prompt, SimEngine, TieredSim};
pub use sweep::{sweep_capacities, sweep_tiered, PredictorKind, SweepPoint, SweepResult, TierSweepPoint};
