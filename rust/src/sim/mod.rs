//! Trace-driven cache simulator (paper §4.1.4) and the capacity-sweep
//! harness behind Fig 7.

mod engine;
pub mod harness;
pub mod sweep;

pub use engine::{simulate_prompt, SimEngine};
pub use sweep::{sweep_capacities, PredictorKind, SweepPoint, SweepResult};
