//! Capacity-sweep harness — regenerates the paper's Fig 7 (cache hit rate
//! vs GPU expert capacity) for every predictor.

use crate::cache::{CacheStats, LruCache};
use crate::config::{CacheConfig, EamConfig, SimConfig};
use crate::predictor::{
    CachedPredictor, EamPredictor, ExpertPredictor, NextLayerAll, NoPrefetch, OraclePredictor,
    PopularityPredictor, TracePredictions,
};
use crate::sim::SimEngine;
use crate::trace::PromptTrace;
use crate::Result;

/// Which predictor drives prefetch in a sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorKind {
    Learned,
    Eam,
    NextLayer,
    Popularity,
    Oracle,
    None,
}

impl PredictorKind {
    pub fn name(&self) -> &'static str {
        match self {
            PredictorKind::Learned => "moe-beyond",
            PredictorKind::Eam => "moe-infinity",
            PredictorKind::NextLayer => "deepspeed-next-layer",
            PredictorKind::Popularity => "brainstorm-popularity",
            PredictorKind::Oracle => "oracle",
            PredictorKind::None => "lru-only",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "learned" | "moe-beyond" => PredictorKind::Learned,
            "eam" | "moe-infinity" => PredictorKind::Eam,
            "next-layer" => PredictorKind::NextLayer,
            "popularity" => PredictorKind::Popularity,
            "oracle" => PredictorKind::Oracle,
            "none" | "lru" => PredictorKind::None,
            _ => return None,
        })
    }
}

/// One (capacity, predictor) measurement.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub capacity_frac: f64,
    pub capacity_experts: usize,
    pub hit_rate: f64,
    pub prediction_hit_rate: f64,
    pub stats: CacheStats,
}

/// A full sweep for one predictor.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub predictor: String,
    pub points: Vec<SweepPoint>,
}

/// Everything a sweep needs besides capacity.
pub struct SweepInputs<'a> {
    pub test_traces: &'a [PromptTrace],
    /// EAMC/popularity training traces (the paper warms the EAMC on the
    /// training corpus).
    pub fit_traces: &'a [PromptTrace],
    /// Precomputed learned predictions, parallel to `test_traces`
    /// (required iff the sweep includes `Learned`).
    pub learned: Option<&'a [TracePredictions]>,
    pub sim: SimConfig,
    pub eam: EamConfig,
    pub n_layers: usize,
    pub n_experts: usize,
}

fn make_predictor<'a>(
    kind: PredictorKind,
    inputs: &SweepInputs<'a>,
) -> Box<dyn ExpertPredictor + 'a> {
    match kind {
        PredictorKind::Learned => unreachable!("learned handled per-trace"),
        PredictorKind::Eam => {
            let mut p = EamPredictor::new(inputs.eam.clone(), inputs.n_layers, inputs.n_experts);
            p.fit(inputs.fit_traces);
            Box::new(p)
        }
        PredictorKind::NextLayer => Box::new(NextLayerAll::new(inputs.n_experts as u16)),
        PredictorKind::Popularity => {
            let mut p = PopularityPredictor::new(inputs.n_layers, inputs.n_experts, inputs.sim.predict_top_k);
            p.fit(inputs.fit_traces);
            Box::new(p)
        }
        PredictorKind::Oracle => Box::new(OraclePredictor::new()),
        PredictorKind::None => Box::new(NoPrefetch),
    }
}

/// Run the Fig-7 sweep: for each capacity fraction, replay every test
/// prompt on a fresh LRU cache and aggregate hit rates.
pub fn sweep_capacities(
    kind: PredictorKind,
    fracs: &[f64],
    inputs: &SweepInputs<'_>,
) -> Result<SweepResult> {
    let total = inputs.n_layers * inputs.n_experts;
    let mut points = Vec::with_capacity(fracs.len());

    for &frac in fracs {
        let capacity = ((total as f64 * frac).round() as usize).max(1);
        let mut stats = CacheStats::default();

        // persistent predictor state across prompts (EAMC grows online,
        // as in the paper); the cache itself restarts per prompt —
        // batch-size-1 edge serving has no cross-request residency.
        let mut predictor = if kind == PredictorKind::Learned {
            None
        } else {
            Some(make_predictor(kind, inputs))
        };

        for (i, tr) in inputs.test_traces.iter().enumerate() {
            let mut engine = SimEngine::new(
                Box::new(LruCache::new(capacity)),
                inputs.sim.clone(),
                CacheConfig::default().with_capacity(capacity),
                inputs.n_experts,
            );
            match (&mut predictor, kind) {
                (None, PredictorKind::Learned) => {
                    let preds = &inputs
                        .learned
                        .ok_or_else(|| anyhow::anyhow!("learned sweep needs precomputed predictions"))?[i];
                    let mut p = CachedPredictor::new(preds);
                    engine.run_prompt(tr, &mut p, &mut stats);
                }
                (Some(p), _) => engine.run_prompt(tr, p.as_mut(), &mut stats),
                _ => unreachable!(),
            }
        }

        points.push(SweepPoint {
            capacity_frac: frac,
            capacity_experts: capacity,
            hit_rate: stats.hit_rate(),
            prediction_hit_rate: stats.prediction_hit_rate(),
            stats,
        });
    }
    Ok(SweepResult {
        predictor: kind.name().to_string(),
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk_traces(n: usize, seed: u64) -> Vec<PromptTrace> {
        // prompts with a per-prompt working set of 4 experts per layer
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let n_tokens = 24;
                let n_layers = 3u16;
                let base = rng.below(12) as u8 * 4;
                let mut experts = Vec::new();
                for _ in 0..n_tokens * n_layers as usize {
                    let a = base + rng.below(4) as u8;
                    let mut b = base + rng.below(4) as u8;
                    if b == a {
                        b = base + ((a - base + 1) % 4);
                    }
                    experts.push(a);
                    experts.push(b);
                }
                PromptTrace {
                    prompt_id: i as u32,
                    n_layers,
                    top_k: 2,
                    d_emb: 0,
                    tokens: vec![0; n_tokens],
                    embeddings: vec![],
                    experts,
                }
            })
            .collect()
    }

    fn inputs<'a>(
        test: &'a [PromptTrace],
        fit: &'a [PromptTrace],
    ) -> SweepInputs<'a> {
        SweepInputs {
            test_traces: test,
            fit_traces: fit,
            learned: None,
            sim: SimConfig::default(),
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: 64,
        }
    }

    #[test]
    fn oracle_beats_everyone_and_rates_monotone_in_capacity() {
        let test = mk_traces(6, 1);
        let fit = mk_traces(10, 2);
        let inp = inputs(&test, &fit);
        let fracs = [0.05, 0.2, 0.8];
        let oracle = sweep_capacities(PredictorKind::Oracle, &fracs, &inp).unwrap();
        let none = sweep_capacities(PredictorKind::None, &fracs, &inp).unwrap();
        let eam = sweep_capacities(PredictorKind::Eam, &fracs, &inp).unwrap();
        for i in 0..fracs.len() {
            assert!(oracle.points[i].hit_rate >= none.points[i].hit_rate);
            assert!(oracle.points[i].hit_rate >= eam.points[i].hit_rate - 1e-9);
        }
        // LRU-only improves with capacity on reuse-heavy traces
        assert!(none.points[2].hit_rate >= none.points[0].hit_rate);
    }

    #[test]
    fn eam_helps_on_repeating_families() {
        // test prompts resemble fit prompts (same generator), so EAM
        // matching should beat pure LRU at small capacity
        let test = mk_traces(8, 3);
        let fit = mk_traces(30, 3); // same seed family
        let inp = inputs(&test, &fit);
        let fracs = [0.05];
        let eam = sweep_capacities(PredictorKind::Eam, &fracs, &inp).unwrap();
        let none = sweep_capacities(PredictorKind::None, &fracs, &inp).unwrap();
        assert!(
            eam.points[0].hit_rate > none.points[0].hit_rate,
            "eam {} vs lru {}",
            eam.points[0].hit_rate,
            none.points[0].hit_rate
        );
    }

    #[test]
    fn predictor_kind_parse() {
        assert_eq!(PredictorKind::parse("learned"), Some(PredictorKind::Learned));
        assert_eq!(PredictorKind::parse("moe-infinity"), Some(PredictorKind::Eam));
        assert_eq!(PredictorKind::parse("nope"), None);
    }
}
