//! Capacity-sweep harness — regenerates the paper's Fig 7 (cache hit rate
//! vs GPU expert capacity) for every predictor, and extends it into the
//! tiered hit-rate × tier-latency surface (host-RAM fraction and SSD
//! bandwidth as new sweep axes).
//!
//! Every grid point is independent (its own predictor state, a fresh
//! residency backend per prompt), so the harness fans the grid out
//! across `std::thread::scope` workers.  Results are written back by
//! grid index, so the output is deterministic and identical to a serial
//! run regardless of scheduling; `MOEB_SWEEP_THREADS` (or the
//! `*_threaded` variants) pins the worker count, `1` forces serial.
//!
//! A third surface sweeps the [`crate::cluster`] simulator over node
//! count × placement × link bandwidth × per-node capacity
//! ([`sweep_cluster`]) — always by exact replay (remote routing has no
//! stack-distance analogue).
//!
//! The no-prefetch (`PredictorKind::None`) baselines of BOTH sweeps are
//! analytic: one memoized Mattson stack-distance pass over the corpus
//! answers every flat capacity (`sweep_capacities*`) and — via per-tier
//! band lookups on the same histogram — every stall-free tiered grid
//! cell (`sweep_tiered*`) without replaying.  `MOEB_SWEEP_EXACT=1`
//! forces the retained exact replays everywhere.

use crate::cache::{CacheStats, LruCache};
use crate::cluster::{self, ClusterConfig, FaultPlan, PlacementKind};
use crate::config::{CacheConfig, EamConfig, SimConfig, TierConfig};
use crate::metrics::LatencyReport;
use crate::obs::Hist;
use crate::predictor::{factory, CachedPredictor, ExpertPredictor, PredictorParams, TracePredictions};
use crate::sim::SimEngine;
use crate::tier::{NetStats, TierCostModel, TierStats};
use crate::trace::{CompiledCorpus, CompiledTrace, PromptTrace};
use crate::util::parallel::parallel_map;
use crate::Result;

pub use crate::predictor::PredictorKind;
pub use crate::util::parallel::sweep_threads;

/// One (capacity, predictor) measurement.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub capacity_frac: f64,
    pub capacity_experts: usize,
    pub hit_rate: f64,
    pub prediction_hit_rate: f64,
    pub stats: CacheStats,
}

/// A full sweep for one predictor.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub predictor: String,
    pub points: Vec<SweepPoint>,
}

/// Everything a sweep needs besides capacity.
///
/// Generic over the [`crate::util::ExpertSet`] word width `N` (default 1
/// = up to 64 experts); wide worlds thread their width through the
/// learned predictions, the compiled corpus, and every replay below.
pub struct SweepInputs<'a, const N: usize = 1> {
    pub test_traces: &'a [PromptTrace],
    /// EAMC/popularity training traces (the paper warms the EAMC on the
    /// training corpus).
    pub fit_traces: &'a [PromptTrace],
    /// Precomputed learned predictions, parallel to `test_traces`
    /// (required iff the sweep includes `Learned`).
    pub learned: Option<&'a [TracePredictions<N>]>,
    /// Optional pre-compiled corpus for `test_traces` (index-parallel).
    /// Callers running several sweeps over one corpus should compile
    /// once and set this: the packed set tables AND the memoized
    /// stack-distance profile are then shared across calls instead of
    /// rebuilt per sweep.  `None` compiles per call.
    pub compiled: Option<&'a CompiledCorpus<N>>,
    pub sim: SimConfig,
    pub eam: EamConfig,
    pub n_layers: usize,
    pub n_experts: usize,
}

/// The shared corpus for a sweep: the caller's pre-compiled tables when
/// provided (an `Arc` bump), a fresh compilation otherwise.  A stale
/// corpus (compiled from different traces) would silently corrupt every
/// point, so the parallelism invariant is a hard error, not a debug
/// assert.
fn corpus_for<const N: usize>(inputs: &SweepInputs<'_, N>) -> Result<CompiledCorpus<N>> {
    match inputs.compiled {
        Some(c) => {
            anyhow::ensure!(
                c.len() == inputs.test_traces.len(),
                "SweepInputs::compiled has {} traces but test_traces has {}",
                c.len(),
                inputs.test_traces.len()
            );
            Ok(c.clone())
        }
        None => Ok(CompiledCorpus::<N>::compile(inputs.test_traces)),
    }
}

/// Derive one tiered grid cell's validated `TierConfig` — shared by the
/// exact replay ([`run_tier_point`]) and the analytic evaluation
/// ([`sweep_tiered_stackdist`]), whose byte-identity contract depends on
/// both paths rounding capacities identically.
fn tier_cfg_for<const N: usize>(
    (gf, hf, ssd): (f64, f64, f64),
    inputs: &SweepInputs<'_, N>,
    base: &TierConfig,
) -> Result<TierConfig> {
    let total = inputs.n_layers * inputs.n_experts;
    let gpu_cap = ((total as f64 * gf).round() as usize).max(1);
    let host_cap = ((total as f64 * hf).round() as usize).max(1);
    let cfg = base
        .clone()
        .with_gpu_capacity(gpu_cap)
        .with_host_capacity(host_cap)
        .with_deepest_fetch_us(ssd);
    cfg.validate()?;
    Ok(cfg)
}

fn make_predictor<const N: usize>(
    kind: PredictorKind,
    inputs: &SweepInputs<'_, N>,
) -> Result<Box<dyn ExpertPredictor<N>>> {
    factory::build::<N>(
        kind,
        &PredictorParams {
            eam: &inputs.eam,
            predict_top_k: inputs.sim.predict_top_k,
            n_layers: inputs.n_layers,
            n_experts: inputs.n_experts,
            fit_traces: inputs.fit_traces,
        },
    )
}

/// `MOEB_SWEEP_EXACT=1` disables the stack-distance fast paths (flat AND
/// tiered) and forces every sweep point through the exact replay (a
/// belt-and-braces escape hatch; the paths are parity-tested
/// bit-identical).
fn stackdist_disabled() -> bool {
    matches!(std::env::var("MOEB_SWEEP_EXACT").ok().as_deref(), Some(v) if !v.is_empty() && v != "0")
}

/// Replay every test prompt through a fresh engine each (batch-size-1
/// edge serving has no cross-request residency; predictor state
/// persists across prompts, as in the paper: the EAMC grows online).
/// `after_prompt` collects per-engine state (tier counters, cost) before
/// the engine is dropped.  The single Learned-vs-heuristic dispatch for
/// both the flat and tiered sweeps.
fn replay_traces<const N: usize>(
    kind: PredictorKind,
    inputs: &SweepInputs<'_, N>,
    compiled: &[CompiledTrace<N>],
    stats: &mut CacheStats,
    mut mk_engine: impl FnMut() -> Result<SimEngine<N>>,
    mut after_prompt: impl FnMut(&mut SimEngine<N>),
) -> Result<()> {
    let mut predictor = if kind == PredictorKind::Learned {
        None
    } else {
        Some(make_predictor(kind, inputs)?)
    };
    for (i, tr) in inputs.test_traces.iter().enumerate() {
        let mut engine = mk_engine()?;
        match (&mut predictor, kind) {
            (None, PredictorKind::Learned) => {
                let preds = &inputs
                    .learned
                    .ok_or_else(|| anyhow::anyhow!("learned sweep needs precomputed predictions"))?[i];
                let mut p = CachedPredictor::new(preds);
                engine.run_prompt_compiled(tr, &compiled[i], &mut p, stats);
            }
            (Some(p), _) => engine.run_prompt_compiled(tr, &compiled[i], p.as_mut(), stats),
            _ => unreachable!(),
        }
        after_prompt(&mut engine);
    }
    Ok(())
}

/// One capacity of the Fig-7 sweep.
fn run_capacity_point<const N: usize>(
    kind: PredictorKind,
    frac: f64,
    inputs: &SweepInputs<'_, N>,
    compiled: &[CompiledTrace<N>],
) -> Result<SweepPoint> {
    let total = inputs.n_layers * inputs.n_experts;
    let capacity = ((total as f64 * frac).round() as usize).max(1);
    let mut stats = CacheStats::default();

    replay_traces(
        kind,
        inputs,
        compiled,
        &mut stats,
        || {
            Ok(SimEngine::<N>::flat(
                Box::new(LruCache::new(capacity)),
                inputs.sim.clone(),
                CacheConfig::default().with_capacity(capacity),
                inputs.n_experts,
            ))
        },
        |_| {},
    )?;

    Ok(SweepPoint {
        capacity_frac: frac,
        capacity_experts: capacity,
        hit_rate: stats.hit_rate(),
        prediction_hit_rate: stats.prediction_hit_rate(),
        stats,
    })
}

/// Run the Fig-7 sweep with the default worker count (see
/// [`sweep_threads`]).
pub fn sweep_capacities<const N: usize>(
    kind: PredictorKind,
    fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
) -> Result<SweepResult> {
    sweep_capacities_threaded(kind, fracs, inputs, sweep_threads())
}

/// Run the Fig-7 sweep on an explicit number of workers (`1` = serial).
/// Output is deterministic: identical to the serial run for any count.
///
/// `PredictorKind::None` (no-prefetch LRU — the baseline axis of Fig 7)
/// takes the Mattson stack-distance fast path: ONE profiling pass over
/// the corpus yields the hit count at every capacity at once, instead
/// of one full replay per fraction (see [`crate::cache::stackdist`] for
/// why prefetching predictors cannot use it).  The exact replay is
/// retained as [`sweep_capacities_replay_threaded`] — parity-tested
/// bit-identical — and `MOEB_SWEEP_EXACT=1` forces it globally.
pub fn sweep_capacities_threaded<const N: usize>(
    kind: PredictorKind,
    fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
    threads: usize,
) -> Result<SweepResult> {
    if kind == PredictorKind::None && !stackdist_disabled() {
        return sweep_capacities_stackdist(fracs, inputs, threads);
    }
    sweep_capacities_replay_threaded(kind, fracs, inputs, threads)
}

/// The exact per-capacity replay sweep with the default worker count.
pub fn sweep_capacities_replay<const N: usize>(
    kind: PredictorKind,
    fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
) -> Result<SweepResult> {
    sweep_capacities_replay_threaded(kind, fracs, inputs, sweep_threads())
}

/// The exact per-capacity replay sweep: every fraction replays the whole
/// corpus.  This is the only correct path for prefetching predictors and
/// the parity reference for the no-prefetch fast path.
pub fn sweep_capacities_replay_threaded<const N: usize>(
    kind: PredictorKind,
    fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
    threads: usize,
) -> Result<SweepResult> {
    // compile (or reuse) the corpus once; every grid point reads the
    // shared tables
    let compiled = corpus_for(inputs)?;
    let points = parallel_map(fracs, threads, |&frac| {
        run_capacity_point(kind, frac, inputs, &compiled)
    })?;
    Ok(SweepResult {
        predictor: kind.display_name().to_string(),
        points,
    })
}

/// Stack-distance fast path for the no-prefetch baseline: read every
/// capacity off the corpus's memoized histogram
/// ([`CompiledCorpus::stackdist_profile`] — one profiling pass per
/// corpus, shared with the tiered sweep and with repeat calls).
fn sweep_capacities_stackdist<const N: usize>(
    fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
    threads: usize,
) -> Result<SweepResult> {
    let compiled = corpus_for(inputs)?;
    let profile =
        compiled.stackdist_profile(inputs.n_experts, inputs.sim.warmup_tokens, threads);

    let total = inputs.n_layers * inputs.n_experts;
    // the replay path charges misses at the default flat PCIe cost (see
    // run_capacity_point's CacheConfig); mirror it exactly
    let pcie = CacheConfig::default().pcie_us_per_expert;
    let points = fracs
        .iter()
        .map(|&frac| {
            let capacity = ((total as f64 * frac).round() as usize).max(1);
            let stats = profile.cache_stats(capacity, pcie);
            SweepPoint {
                capacity_frac: frac,
                capacity_experts: capacity,
                hit_rate: stats.hit_rate(),
                prediction_hit_rate: stats.prediction_hit_rate(),
                stats,
            }
        })
        .collect();
    Ok(SweepResult {
        predictor: PredictorKind::None.display_name().to_string(),
        points,
    })
}

/// One point of the tiered surface: a (GPU capacity, host capacity, SSD
/// bandwidth) combination with both hit-rate and latency outcomes.
#[derive(Debug, Clone)]
pub struct TierSweepPoint {
    pub gpu_frac: f64,
    pub host_frac: f64,
    pub ssd_us_per_expert: f64,
    /// Fraction of lookups served from GPU VRAM (Fig-7's y-axis).
    pub gpu_hit_rate: f64,
    /// Fraction of lookups that had to go below the host tier (flash).
    pub deep_miss_rate: f64,
    /// Modeled critical-path µs summed over all replayed prompts.
    pub critical_path_us: f64,
    pub stats: CacheStats,
    pub tiers: TierStats,
}

fn run_tier_point<const N: usize>(
    kind: PredictorKind,
    (gf, hf, ssd): (f64, f64, f64),
    inputs: &SweepInputs<'_, N>,
    compiled: &[CompiledTrace<N>],
    base: &TierConfig,
    overlap_budget_us: f64,
) -> Result<TierSweepPoint> {
    let cfg = tier_cfg_for((gf, hf, ssd), inputs, base)?;

    let mut stats = CacheStats::default();
    let mut tiers = TierStats::new(cfg.tiers.len());
    let mut critical_path_us = 0.0;

    replay_traces(
        kind,
        inputs,
        compiled,
        &mut stats,
        || SimEngine::<N>::tiered(&cfg, inputs.sim.clone(), inputs.n_experts, overlap_budget_us),
        |engine| {
            let m = engine.memory.stats();
            tiers.merge(m.tiers.as_ref().expect("tiered engine lost its tiers"));
            critical_path_us += m.critical_path_us();
        },
    )?;

    Ok(TierSweepPoint {
        gpu_frac: gf,
        host_frac: hf,
        ssd_us_per_expert: ssd,
        gpu_hit_rate: stats.hit_rate(),
        deep_miss_rate: tiers.below_rate(1),
        critical_path_us,
        stats,
        tiers,
    })
}

/// Sweep the tiered hierarchy over GPU capacity × host-RAM fraction ×
/// SSD fetch cost, replaying every test prompt on a fresh hierarchy per
/// prompt (batch-size-1 edge serving has no cross-request residency).
///
/// At `host_frac >= 1.0` with `ssd_us == pcie` cost this collapses to
/// the flat Fig-7 sweep (see `tiered_matches_flat_at_full_host` below);
/// the interesting region is small GPU + partial host, where hit-rate
/// alone mispredicts latency.
pub fn sweep_tiered<const N: usize>(
    kind: PredictorKind,
    gpu_fracs: &[f64],
    host_fracs: &[f64],
    ssd_us: &[f64],
    inputs: &SweepInputs<'_, N>,
    base: &TierConfig,
    overlap_budget_us: f64,
) -> Result<Vec<TierSweepPoint>> {
    sweep_tiered_threaded(
        kind,
        gpu_fracs,
        host_fracs,
        ssd_us,
        inputs,
        base,
        overlap_budget_us,
        sweep_threads(),
    )
}

/// [`sweep_tiered`] on an explicit number of workers (`1` = serial).
///
/// `PredictorKind::None` over an all-`lru` hierarchy takes the tiered
/// stack-distance fast path when the configuration is provably
/// stall-free: ONE profiling pass over the corpus (memoized on the
/// corpus, shared with the flat sweep) yields every grid cell's per-tier
/// serve/demotion counts as histogram band lookups fed into
/// [`TierCostModel`], instead of one full corpus replay per (host-frac ×
/// SSD-bandwidth × GPU-frac) cell.  The exact replay is retained as
/// [`sweep_tiered_replay_threaded`] — parity-tested byte-identical — and
/// `MOEB_SWEEP_EXACT=1` forces it globally.  Prefetching predictors
/// always replay (prefetch breaks stack inclusion; see
/// [`crate::cache::stackdist`]).
#[allow(clippy::too_many_arguments)]
pub fn sweep_tiered_threaded<const N: usize>(
    kind: PredictorKind,
    gpu_fracs: &[f64],
    host_fracs: &[f64],
    ssd_us: &[f64],
    inputs: &SweepInputs<'_, N>,
    base: &TierConfig,
    overlap_budget_us: f64,
    threads: usize,
) -> Result<Vec<TierSweepPoint>> {
    let grid = tier_grid(gpu_fracs, host_fracs, ssd_us, base)?;
    // compile (or reuse) the corpus once for the whole surface
    let compiled = corpus_for(inputs)?;
    if kind == PredictorKind::None
        && !stackdist_disabled()
        && base.policy == "lru"
        && tiered_stall_free(base, overlap_budget_us, compiled.max_set_len())
    {
        return sweep_tiered_stackdist(&grid, inputs, &compiled, base, overlap_budget_us, threads);
    }
    parallel_map(&grid, threads, |&point| {
        run_tier_point(kind, point, inputs, &compiled, base, overlap_budget_us)
    })
}

/// The exact per-cell tiered replay sweep with the default worker count.
#[allow(clippy::too_many_arguments)]
pub fn sweep_tiered_replay<const N: usize>(
    kind: PredictorKind,
    gpu_fracs: &[f64],
    host_fracs: &[f64],
    ssd_us: &[f64],
    inputs: &SweepInputs<'_, N>,
    base: &TierConfig,
    overlap_budget_us: f64,
) -> Result<Vec<TierSweepPoint>> {
    sweep_tiered_replay_threaded(
        kind,
        gpu_fracs,
        host_fracs,
        ssd_us,
        inputs,
        base,
        overlap_budget_us,
        sweep_threads(),
    )
}

/// The exact tiered sweep: every grid cell replays the whole corpus.
/// The only correct path for prefetching predictors, non-LRU tier
/// policies, and stall-prone writeback configs — and the parity
/// reference for [`sweep_tiered_threaded`]'s analytic fast path.
#[allow(clippy::too_many_arguments)]
pub fn sweep_tiered_replay_threaded<const N: usize>(
    kind: PredictorKind,
    gpu_fracs: &[f64],
    host_fracs: &[f64],
    ssd_us: &[f64],
    inputs: &SweepInputs<'_, N>,
    base: &TierConfig,
    overlap_budget_us: f64,
    threads: usize,
) -> Result<Vec<TierSweepPoint>> {
    let grid = tier_grid(gpu_fracs, host_fracs, ssd_us, base)?;
    let compiled = corpus_for(inputs)?;
    parallel_map(&grid, threads, |&point| {
        run_tier_point(kind, point, inputs, &compiled, base, overlap_budget_us)
    })
}

/// Row-major (gpu × host × ssd) grid; rejects bases too flat for the
/// three sweep axes.
fn tier_grid(
    gpu_fracs: &[f64],
    host_fracs: &[f64],
    ssd_us: &[f64],
    base: &TierConfig,
) -> Result<Vec<(f64, f64, f64)>> {
    // the gpu/host/deepest axes address tiers 0/1/last: a flatter base
    // would silently sweep the wrong tier
    anyhow::ensure!(
        base.tiers.len() >= 3,
        "sweep_tiered needs a gpu/host/deepest base config (got {} tiers)",
        base.tiers.len()
    );
    let mut grid = Vec::with_capacity(gpu_fracs.len() * host_fracs.len() * ssd_us.len());
    for &gf in gpu_fracs {
        for &hf in host_fracs {
            for &ssd in ssd_us {
                grid.push((gf, hf, ssd));
            }
        }
    }
    Ok(grid)
}

/// Whether a no-prefetch tiered replay of this configuration can ever
/// stall: demotion writebacks are the only DMA a demand-only replay
/// issues, one layer execution issues at most one demotion per tier per
/// ground-truth expert, and `end_layer` closes the window every layer —
/// so a tier whose `writeback × max_cell_refs` fits the overlap window
/// can never exceed it.  Stall-free configs make the analytic evaluation
/// exact; anything else falls back to the replay.
fn tiered_stall_free(base: &TierConfig, overlap_budget_us: f64, max_cell_refs: u32) -> bool {
    base.tiers.iter().skip(1).all(|t| {
        t.writeback_us_per_expert == 0.0
            || t.writeback_us_per_expert * max_cell_refs as f64 <= overlap_budget_us
    })
}

/// Analytic tiered sweep: every grid cell is a handful of band lookups
/// on the corpus's stack-distance curve, fed into the same
/// [`TierCostModel`] the replay charges.  Exactness argument (and the
/// demotion/drop band math) lives in [`crate::cache::stackdist`]; the
/// parity suite in `tests/replay_parity.rs` holds every counter and
/// cost to byte-identical agreement with [`run_tier_point`] (float
/// totals under the usual integer-µs-cost caveat).
fn sweep_tiered_stackdist<const N: usize>(
    grid: &[(f64, f64, f64)],
    inputs: &SweepInputs<'_, N>,
    compiled: &CompiledCorpus<N>,
    base: &TierConfig,
    overlap_budget_us: f64,
    threads: usize,
) -> Result<Vec<TierSweepPoint>> {
    let profile =
        compiled.stackdist_profile(inputs.n_experts, inputs.sim.warmup_tokens, threads);
    let curve = profile.curve();
    parallel_map(grid, threads, |&(gf, hf, ssd)| {
        let cfg = tier_cfg_for((gf, hf, ssd), inputs, base)?;
        let caps: Vec<usize> = cfg.tiers.iter().map(|t| t.capacity_experts).collect();
        let deepest = caps.len() - 1;
        let bands = curve.tier_bands(&caps);

        // feed the band counts into the replay's cost model: per-band
        // demand at each tier's fetch cost, cold reads at the deepest
        // tier's, demotion writebacks fully overlapped (the stall-free
        // gate above is what makes that exact)
        let mut cost = TierCostModel::new(cfg.tiers.clone(), overlap_budget_us);
        for (d, &n) in bands.served.iter().enumerate() {
            cost.on_demand_fetch_n(d, n);
        }
        cost.on_demand_fetch_n(deepest, bands.cold);
        for (d, &n) in bands.demotions_into.iter().enumerate().skip(1) {
            cost.on_writeback_overlapped_n(d, n);
        }

        let mut tiers = TierStats::new(caps.len());
        tiers.served = bands.served.clone();
        tiers.cold = bands.cold;
        tiers.promotions = bands.promotions();
        tiers.demotions = bands.demotions();
        tiers.dropped = bands.dropped;

        // transfer_us mirrors the replay's per-miss fetch charging:
        // every non-GPU-hit pays the fetch cost of the depth it reached
        let mut transfer_us = 0.0;
        for d in 1..caps.len() {
            transfer_us += bands.served[d] as f64 * cfg.tiers[d].fetch_us_per_expert;
        }
        transfer_us += bands.cold as f64 * cfg.tiers[deepest].fetch_us_per_expert;
        let stats = CacheStats {
            hits: bands.served[0],
            misses: profile.measured - bands.served[0],
            prefetches: 0,
            wasted_prefetches: 0,
            prediction_hits: 0,
            prediction_total: profile.measured,
            transfer_us,
        };

        Ok(TierSweepPoint {
            gpu_frac: gf,
            host_frac: hf,
            ssd_us_per_expert: ssd,
            gpu_hit_rate: stats.hit_rate(),
            deep_miss_rate: tiers.below_rate(1),
            critical_path_us: cost.critical_path_us(),
            stats,
            tiers,
        })
    })
}

/// One cell of the cluster grid: a (node count, placement, link
/// bandwidth, per-node capacity fraction) combination with hit-rate,
/// network, and latency outcomes.
#[derive(Debug, Clone)]
pub struct ClusterSweepPoint {
    pub nodes: usize,
    pub placement: PlacementKind,
    /// Link bandwidth swept into [`crate::tier::LinkSpec::gbps`]
    /// (`<= 0` = infinite).
    pub gbps: f64,
    /// Per-node GPU capacity as a fraction of the full expert table,
    /// already divided by the node count (fixed per-device budget).
    pub cache_frac: f64,
    pub capacity_per_node: usize,
    /// Fraction of measured lookups served from *some* node's GPU tier.
    pub gpu_hit_rate: f64,
    /// Fraction of measured lookups that crossed the network.
    pub remote_rate: f64,
    /// Modeled critical-path µs summed over all replayed prompts
    /// (per-node DMA + network wire time).
    pub critical_path_us: f64,
    pub stats: CacheStats,
    pub net: NetStats,
}

fn run_cluster_point<const N: usize>(
    kind: PredictorKind,
    (k, placement, gbps, frac): (usize, PlacementKind, f64, f64),
    inputs: &SweepInputs<'_, N>,
    compiled: &[CompiledTrace<N>],
    base: &ClusterConfig,
) -> Result<ClusterSweepPoint> {
    // Fixed per-device memory budget: each node gets 1/k of the swept
    // capacity.  At k = 1 the rounding collapses to the flat sweep's
    // `(total * frac).round().max(1)`, which is what lets the K=1
    // loopback column reproduce `sweep_capacities` bit-for-bit.
    let total = inputs.n_layers * inputs.n_experts;
    let cap = ((total as f64 * frac / k as f64).round() as usize).max(1);
    let mut cfg = base.clone().with_nodes(k).with_placement(placement);
    cfg.link.gbps = gbps;
    let cache_cfg = CacheConfig::default().with_capacity(cap);

    let mut stats = CacheStats::default();
    let mut critical_path_us = 0.0;
    let mut net = NetStats::default();

    replay_traces(
        kind,
        inputs,
        compiled,
        &mut stats,
        || {
            let mem = cluster::build::<N>(
                &cfg,
                "lru",
                &cache_cfg,
                None,
                &inputs.sim,
                inputs.n_experts,
                f64::INFINITY,
            )?;
            Ok(SimEngine::<N>::new(mem, inputs.sim.clone(), inputs.n_experts))
        },
        |engine| {
            let m = engine.memory.stats();
            critical_path_us += m.critical_path_us();
            net.merge(m.net.as_ref().expect("cluster engine lost its net stats"));
        },
    )?;

    let measured = stats.hits + stats.misses;
    Ok(ClusterSweepPoint {
        nodes: k,
        placement,
        gbps,
        cache_frac: frac,
        capacity_per_node: cap,
        gpu_hit_rate: stats.hit_rate(),
        remote_rate: net.remote_lookups as f64 / (measured.max(1)) as f64,
        critical_path_us,
        stats,
        net,
    })
}

/// Sweep the edge-cluster simulator over node count × placement × link
/// bandwidth × per-node capacity with the default worker count.
///
/// Per-node backends are flat LRU hierarchies (the Fig-7 configuration,
/// one per node); `base` supplies everything the grid does not sweep —
/// link latency and per-hop cost, payload sizes, migration threshold,
/// and the fault plan.  Every cell replays the whole corpus on a fresh
/// cluster per prompt; there is no analytic fast path (remote routing
/// breaks stack inclusion the same way prefetching does).
pub fn sweep_cluster<const N: usize>(
    kind: PredictorKind,
    nodes: &[usize],
    placements: &[PlacementKind],
    gbps: &[f64],
    cache_fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
    base: &ClusterConfig,
) -> Result<Vec<ClusterSweepPoint>> {
    sweep_cluster_threaded(
        kind,
        nodes,
        placements,
        gbps,
        cache_fracs,
        inputs,
        base,
        sweep_threads(),
    )
}

/// [`sweep_cluster`] on an explicit number of workers (`1` = serial).
/// Row-major (nodes × placement × gbps × frac) output, deterministic at
/// any worker count (grid-indexed write-back).
#[allow(clippy::too_many_arguments)]
pub fn sweep_cluster_threaded<const N: usize>(
    kind: PredictorKind,
    nodes: &[usize],
    placements: &[PlacementKind],
    gbps: &[f64],
    cache_fracs: &[f64],
    inputs: &SweepInputs<'_, N>,
    base: &ClusterConfig,
    threads: usize,
) -> Result<Vec<ClusterSweepPoint>> {
    let mut grid = Vec::with_capacity(
        nodes.len() * placements.len() * gbps.len() * cache_fracs.len(),
    );
    for &k in nodes {
        anyhow::ensure!(k >= 1, "cluster sweep needs node counts >= 1");
        for &p in placements {
            for &g in gbps {
                for &f in cache_fracs {
                    grid.push((k, p, g, f));
                }
            }
        }
    }
    let compiled = corpus_for(inputs)?;
    parallel_map(&grid, threads, |&point| {
        run_cluster_point(kind, point, inputs, &compiled, base)
    })
}

/// One cell of the chaos grid: a (replication factor, fault intensity,
/// placement) combination under a seeded transient-fault plan, with
/// availability, tail-latency, and recovery outcomes.
#[derive(Debug, Clone)]
pub struct ChaosSweepPoint {
    /// Replication factor R (experts live on R distinct nodes).
    pub replicas: usize,
    /// Fault intensity fed to [`FaultPlan::chaos`] (`0.0` = the healthy
    /// baseline row — always present, see [`sweep_chaos`]).
    pub intensity: f64,
    pub placement: PlacementKind,
    /// Fraction of measured lookups that did NOT have to take the
    /// degraded all-replicas-down path: `1 - degraded_fetches/measured`.
    pub availability: f64,
    pub gpu_hit_rate: f64,
    /// Modeled critical-path µs over the whole replay (one persistent
    /// cluster — residency and the fault clock span all prompts).
    pub critical_path_us: f64,
    /// p99 of per-prompt critical-path deltas (bucketed; see
    /// [`crate::obs::Hist`]).
    pub p99_prompt_us: f64,
    /// `p99_prompt_us` relative to the same (R, placement) group's
    /// intensity-0 baseline (`1.0` when the baseline is free).
    pub p99_inflation: f64,
    /// Per-prompt GPU hit rate, in replay order — the hit-rate-recovery
    /// curve: dips while nodes are down or freshly cold, reconverges as
    /// caches rewarm.
    pub hit_curve: Vec<f64>,
    pub stats: CacheStats,
    pub net: NetStats,
}

/// Exact measured-lookup count of a compiled corpus — the fault-plan
/// horizon [`sweep_chaos`] hands to [`FaultPlan::chaos`], so generated
/// windows land inside the replay regardless of corpus size.
fn chaos_horizon<const N: usize>(compiled: &[CompiledTrace<N>], warmup_tokens: usize) -> u64 {
    let mut horizon = 0u64;
    for c in compiled {
        let warm = warmup_tokens.min(c.n_tokens());
        for t in warm..c.n_tokens() {
            for l in 0..c.n_layers() {
                horizon += c.set(t, l).len() as u64;
            }
        }
    }
    horizon
}

fn run_chaos_point<const N: usize>(
    kind: PredictorKind,
    (replicas, placement, intensity): (usize, PlacementKind, f64),
    cache_frac: f64,
    inputs: &SweepInputs<'_, N>,
    compiled: &[CompiledTrace<N>],
    base: &ClusterConfig,
    horizon: u64,
) -> Result<ChaosSweepPoint> {
    let k = base.nodes;
    let total = inputs.n_layers * inputs.n_experts;
    let cap = ((total as f64 * cache_frac / k as f64).round() as usize).max(1);
    let cfg = base
        .clone()
        .with_placement(placement)
        .with_replicas(replicas)
        .with_faults(FaultPlan::chaos(k, intensity, horizon));
    let cache_cfg = CacheConfig::default().with_capacity(cap);

    // ONE persistent cluster across every prompt (unlike the other
    // sweeps' fresh-backend-per-prompt replays): the fault clock ticks
    // per measured lookup, so outages must span prompt boundaries for
    // the recovery curve to mean anything.
    let mem = cluster::build::<N>(
        &cfg,
        "lru",
        &cache_cfg,
        None,
        &inputs.sim,
        inputs.n_experts,
        f64::INFINITY,
    )?;
    let mut engine = SimEngine::<N>::new(mem, inputs.sim.clone(), inputs.n_experts);

    let mut stats = CacheStats::default();
    let mut hist = Hist::new();
    let mut hit_curve = Vec::with_capacity(inputs.test_traces.len());
    let mut prev_cp = 0.0f64;
    let mut prev_hits = 0u64;
    let mut prev_measured = 0u64;

    let mut predictor = if kind == PredictorKind::Learned {
        None
    } else {
        Some(make_predictor(kind, inputs)?)
    };
    for (i, tr) in inputs.test_traces.iter().enumerate() {
        match (&mut predictor, kind) {
            (None, PredictorKind::Learned) => {
                let preds = &inputs
                    .learned
                    .ok_or_else(|| anyhow::anyhow!("learned sweep needs precomputed predictions"))?[i];
                let mut p = CachedPredictor::new(preds);
                engine.run_prompt_compiled(tr, &compiled[i], &mut p, &mut stats);
            }
            (Some(p), _) => engine.run_prompt_compiled(tr, &compiled[i], p.as_mut(), &mut stats),
            _ => unreachable!(),
        }
        let cp = engine.memory.stats().critical_path_us();
        hist.record(cp - prev_cp);
        prev_cp = cp;
        let measured = stats.hits + stats.misses;
        let (dm, dh) = (measured - prev_measured, stats.hits - prev_hits);
        hit_curve.push(if dm == 0 { 0.0 } else { dh as f64 / dm as f64 });
        prev_measured = measured;
        prev_hits = stats.hits;
    }

    let m = engine.memory.stats();
    let net = m.net.expect("cluster engine lost its net stats");
    let measured = stats.hits + stats.misses;
    Ok(ChaosSweepPoint {
        replicas,
        intensity,
        placement,
        availability: 1.0 - net.degraded_fetches as f64 / measured.max(1) as f64,
        gpu_hit_rate: stats.hit_rate(),
        critical_path_us: prev_cp,
        p99_prompt_us: LatencyReport::from_hist(&hist).p99_us,
        p99_inflation: 1.0, // filled in by the sweep against the group baseline
        hit_curve,
        stats,
        net,
    })
}

/// Chaos sweep with the default worker count: replicate × break ×
/// measure.  See [`sweep_chaos_threaded`].
pub fn sweep_chaos<const N: usize>(
    kind: PredictorKind,
    replicas: &[usize],
    intensities: &[f64],
    placements: &[PlacementKind],
    cache_frac: f64,
    inputs: &SweepInputs<'_, N>,
    base: &ClusterConfig,
) -> Result<Vec<ChaosSweepPoint>> {
    sweep_chaos_threaded(
        kind,
        replicas,
        intensities,
        placements,
        cache_frac,
        inputs,
        base,
        sweep_threads(),
    )
}

/// Sweep the fault-tolerant cluster over replication factor × fault
/// intensity × placement on an explicit worker count (`1` = serial;
/// output is deterministic at any count).
///
/// Every cell replays the whole corpus through ONE persistent
/// `base.nodes`-node cluster under a seeded [`FaultPlan::chaos`] plan
/// sized to the corpus's measured-lookup horizon (replacing whatever
/// fault plan `base` carries).  `cache_frac` is the per-node capacity
/// fraction, divided by the node count exactly as [`sweep_cluster`]
/// does.  The intensity axis always gets a `0.0` healthy-baseline row
/// prepended (deduplicated): `p99_inflation` of every row is measured
/// against its (R, placement) group's baseline.  Output is row-major
/// (replicas × placement × intensity).
#[allow(clippy::too_many_arguments)]
pub fn sweep_chaos_threaded<const N: usize>(
    kind: PredictorKind,
    replicas: &[usize],
    intensities: &[f64],
    placements: &[PlacementKind],
    cache_frac: f64,
    inputs: &SweepInputs<'_, N>,
    base: &ClusterConfig,
    threads: usize,
) -> Result<Vec<ChaosSweepPoint>> {
    anyhow::ensure!(
        cache_frac.is_finite() && cache_frac > 0.0,
        "chaos sweep cache_frac {cache_frac} must be finite and > 0"
    );
    let mut ints = vec![0.0f64];
    for &i in intensities {
        anyhow::ensure!(
            i.is_finite() && i >= 0.0,
            "chaos sweep intensity {i} must be finite and >= 0"
        );
        if i > 0.0 {
            ints.push(i);
        }
    }
    let mut grid = Vec::with_capacity(replicas.len() * placements.len() * ints.len());
    for &r in replicas {
        anyhow::ensure!(
            r >= 1 && r <= base.nodes,
            "chaos sweep replication factor {r} must be in 1..={} (the node count)",
            base.nodes
        );
        for &p in placements {
            for &i in &ints {
                grid.push((r, p, i));
            }
        }
    }
    let compiled = corpus_for(inputs)?;
    let horizon = chaos_horizon(&compiled, inputs.sim.warmup_tokens);
    let mut points = parallel_map(&grid, threads, |&cell| {
        run_chaos_point(kind, cell, cache_frac, inputs, &compiled, base, horizon)
    })?;
    // Tail inflation vs the healthy run of the same (R, placement)
    // group — the first row of each group is its intensity-0 baseline.
    for group in points.chunks_mut(ints.len()) {
        let base_p99 = group[0].p99_prompt_us;
        for pt in group.iter_mut() {
            pt.p99_inflation = if base_p99 > 0.0 {
                pt.p99_prompt_us / base_p99
            } else {
                1.0
            };
        }
    }
    Ok(points)
}

/// Render chaos sweep points as CSV (one row per grid cell; the
/// recovery curve is `|`-joined per-prompt hit rates in the last
/// column).  Pure function of the points, so two seeded runs of the
/// same grid produce byte-identical files — the CI chaos-determinism
/// gate `cmp`s exactly this output.
pub fn chaos_csv(points: &[ChaosSweepPoint]) -> String {
    let mut out = String::from(
        "replicas,intensity,placement,availability,gpu_hit_rate,critical_path_us,\
         p99_prompt_us,p99_inflation,remote_lookups,remote_hits,failovers,retries,\
         degraded_fetches,wire_us,promotion_us,timeout_us,backoff_us,hit_curve\n",
    );
    for p in points {
        let curve: Vec<String> = p.hit_curve.iter().map(|h| format!("{h:.6}")).collect();
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.replicas,
            p.intensity,
            p.placement.id(),
            p.availability,
            p.gpu_hit_rate,
            p.critical_path_us,
            p.p99_prompt_us,
            p.p99_inflation,
            p.net.remote_lookups,
            p.net.remote_hits,
            p.net.failovers,
            p.net.retries,
            p.net.degraded_fetches,
            p.net.wire_us,
            p.net.promotion_us,
            p.net.timeout_us,
            p.net.backoff_us,
            curve.join("|"),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk_traces(n: usize, seed: u64) -> Vec<PromptTrace> {
        // prompts with a per-prompt working set of 4 experts per layer
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let n_tokens = 24;
                let n_layers = 3u16;
                let base = rng.below(12) as u8 * 4;
                let mut experts = Vec::new();
                for _ in 0..n_tokens * n_layers as usize {
                    let a = base + rng.below(4) as u8;
                    let mut b = base + rng.below(4) as u8;
                    if b == a {
                        b = base + ((a - base + 1) % 4);
                    }
                    experts.push(a);
                    experts.push(b);
                }
                PromptTrace {
                    prompt_id: i as u32,
                    n_layers,
                    top_k: 2,
                    d_emb: 0,
                    tokens: vec![0; n_tokens],
                    embeddings: vec![],
                    experts,
                }
            })
            .collect()
    }

    fn inputs<'a>(
        test: &'a [PromptTrace],
        fit: &'a [PromptTrace],
    ) -> SweepInputs<'a> {
        SweepInputs {
            test_traces: test,
            fit_traces: fit,
            learned: None,
            compiled: None,
            sim: SimConfig::default(),
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: 64,
        }
    }

    #[test]
    fn oracle_beats_everyone_and_rates_monotone_in_capacity() {
        let test = mk_traces(6, 1);
        let fit = mk_traces(10, 2);
        let inp = inputs(&test, &fit);
        let fracs = [0.05, 0.2, 0.8];
        let oracle = sweep_capacities(PredictorKind::Oracle, &fracs, &inp).unwrap();
        let none = sweep_capacities(PredictorKind::None, &fracs, &inp).unwrap();
        let eam = sweep_capacities(PredictorKind::Eam, &fracs, &inp).unwrap();
        for i in 0..fracs.len() {
            assert!(oracle.points[i].hit_rate >= none.points[i].hit_rate);
            assert!(oracle.points[i].hit_rate >= eam.points[i].hit_rate - 1e-9);
        }
        // LRU-only improves with capacity on reuse-heavy traces
        assert!(none.points[2].hit_rate >= none.points[0].hit_rate);
    }

    #[test]
    fn eam_helps_on_repeating_families() {
        // test prompts resemble fit prompts (same generator), so EAM
        // matching should beat pure LRU at small capacity
        let test = mk_traces(8, 3);
        let fit = mk_traces(30, 3); // same seed family
        let inp = inputs(&test, &fit);
        let fracs = [0.05];
        let eam = sweep_capacities(PredictorKind::Eam, &fracs, &inp).unwrap();
        let none = sweep_capacities(PredictorKind::None, &fracs, &inp).unwrap();
        assert!(
            eam.points[0].hit_rate > none.points[0].hit_rate,
            "eam {} vs lru {}",
            eam.points[0].hit_rate,
            none.points[0].hit_rate
        );
    }

    fn base_tiers() -> TierConfig {
        use crate::tier::TierSpec;
        TierConfig {
            tiers: vec![
                TierSpec::new("gpu", 1, 2.0, 0.0),
                // fetch matches CacheConfig::default().pcie_us_per_expert
                // so the GPU tier sees exactly the flat model's world
                TierSpec::new("host", 1, 1400.0, 0.0),
                TierSpec::new("ssd", 192, 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        }
    }

    /// With a host tier big enough for every expert, the tiered sweep's
    /// GPU hit rates must reproduce the flat Fig-7 sweep exactly.
    #[test]
    fn tiered_matches_flat_at_full_host() {
        let test = mk_traces(5, 9);
        let fit = mk_traces(8, 10);
        let inp = inputs(&test, &fit);
        let fracs = [0.05, 0.2, 0.8];
        let flat = sweep_capacities(PredictorKind::None, &fracs, &inp).unwrap();
        let tiered = sweep_tiered(
            PredictorKind::None,
            &fracs,
            &[1.0],
            &[22_000.0],
            &inp,
            &base_tiers(),
            1_000.0,
        )
        .unwrap();
        assert_eq!(tiered.len(), fracs.len());
        for (f, t) in flat.points.iter().zip(tiered.iter()) {
            assert!(
                (f.hit_rate - t.gpu_hit_rate).abs() < 1e-12,
                "flat {} vs tiered {} at {}%",
                f.hit_rate,
                t.gpu_hit_rate,
                t.gpu_frac * 100.0
            );
            // full host never evicts, so the flash tier never serves
            // (first-touch cold reads are the only deep accesses)
            assert_eq!(t.tiers.served.get(2).copied().unwrap_or(0), 0);
        }
    }

    /// Shrinking the GPU with a warm host degrades modeled latency far
    /// more gracefully than with flash directly underneath.
    #[test]
    fn host_tier_softens_gpu_shrink() {
        let test = mk_traces(5, 11);
        let fit = mk_traces(8, 12);
        let inp = inputs(&test, &fit);
        let gpu = [0.2, 0.05];
        let warm = sweep_tiered(
            PredictorKind::None,
            &gpu,
            &[0.5],
            &[22_000.0],
            &inp,
            &base_tiers(),
            1_000.0,
        )
        .unwrap();
        let starved = sweep_tiered(
            PredictorKind::None,
            &gpu,
            &[0.01],
            &[22_000.0],
            &inp,
            &base_tiers(),
            1_000.0,
        )
        .unwrap();
        // same GPU capacity -> same hit rate, host fraction only moves
        // the latency surface
        for (w, s) in warm.iter().zip(starved.iter()) {
            assert!((w.gpu_hit_rate - s.gpu_hit_rate).abs() < 1e-12);
            assert!(w.critical_path_us <= s.critical_path_us + 1e-9);
        }
        // at the starved point, the warm host absorbs the extra misses
        // cheaply: the latency gap between big and small GPU is much
        // smaller than without host backing
        let warm_blowup = warm[1].critical_path_us / warm[0].critical_path_us.max(1e-9);
        let starved_blowup = starved[1].critical_path_us / starved[0].critical_path_us.max(1e-9);
        assert!(
            warm_blowup <= starved_blowup + 1e-9,
            "warm {warm_blowup} vs starved {starved_blowup}"
        );
    }

    #[test]
    fn ssd_bandwidth_moves_latency_not_hit_rate() {
        let test = mk_traces(4, 13);
        let fit = mk_traces(6, 14);
        let inp = inputs(&test, &fit);
        let pts = sweep_tiered(
            PredictorKind::None,
            &[0.05],
            &[0.05],
            &[8_000.0, 44_000.0],
            &inp,
            &base_tiers(),
            1_000.0,
        )
        .unwrap();
        assert!((pts[0].gpu_hit_rate - pts[1].gpu_hit_rate).abs() < 1e-12);
        assert!(pts[0].critical_path_us <= pts[1].critical_path_us);
    }

    #[test]
    fn predictor_kind_parse() {
        assert_eq!(PredictorKind::parse("learned"), Some(PredictorKind::Learned));
        assert_eq!(PredictorKind::parse("moe-infinity"), Some(PredictorKind::Eam));
        assert_eq!(PredictorKind::parse("nope"), None);
    }

    fn assert_sweep_eq(a: &SweepResult, b: &SweepResult) {
        assert_eq!(a.predictor, b.predictor);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x.capacity_experts, y.capacity_experts);
            assert_eq!(x.hit_rate.to_bits(), y.hit_rate.to_bits());
            assert_eq!(x.prediction_hit_rate.to_bits(), y.prediction_hit_rate.to_bits());
            assert_eq!(x.stats.hits, y.stats.hits);
            assert_eq!(x.stats.misses, y.stats.misses);
            assert_eq!(x.stats.prefetches, y.stats.prefetches);
            assert_eq!(x.stats.wasted_prefetches, y.stats.wasted_prefetches);
            assert_eq!(x.stats.transfer_us.to_bits(), y.stats.transfer_us.to_bits());
        }
    }

    /// The stack-distance fast path (the default for `None`) is
    /// bit-identical to the exact per-capacity replay across random
    /// corpora and random capacity fractions, at any worker count.
    #[test]
    fn stackdist_fast_path_matches_replay_exactly() {
        let mut rng = Rng::new(77);
        for case in 0..8 {
            let test = mk_traces(rng.range(2, 7), 100 + case);
            let fit = mk_traces(3, 200 + case);
            let inp = inputs(&test, &fit);
            let mut fracs: Vec<f64> = (0..rng.range(2, 8))
                .map(|_| (rng.range(1, 100) as f64) / 100.0)
                .collect();
            fracs.push(1.0);
            for threads in [1usize, 4] {
                let fast = sweep_capacities_threaded(PredictorKind::None, &fracs, &inp, threads)
                    .unwrap();
                let exact =
                    sweep_capacities_replay_threaded(PredictorKind::None, &fracs, &inp, threads)
                        .unwrap();
                assert_sweep_eq(&exact, &fast);
                for (e, f) in exact.points.iter().zip(fast.points.iter()) {
                    assert_eq!(e.stats.prediction_hits, f.stats.prediction_hits);
                    assert_eq!(e.stats.prediction_total, f.stats.prediction_total);
                }
            }
        }
    }

    /// The threaded sweep is bit-identical to the serial sweep for any
    /// worker count (deterministic grid-indexed write-back).
    #[test]
    fn threaded_sweep_matches_serial_exactly() {
        let test = mk_traces(6, 21);
        let fit = mk_traces(12, 22);
        let inp = inputs(&test, &fit);
        let fracs = [0.05, 0.1, 0.2, 0.4, 0.8];
        for kind in [PredictorKind::None, PredictorKind::Eam, PredictorKind::Oracle] {
            let serial = sweep_capacities_threaded(kind, &fracs, &inp, 1).unwrap();
            for threads in [2usize, 4, 16] {
                let par = sweep_capacities_threaded(kind, &fracs, &inp, threads).unwrap();
                assert_sweep_eq(&serial, &par);
            }
        }
    }

    /// The tiered stack-distance fast path (the default for `None` over
    /// an all-LRU, stall-free base) is byte-identical to the exact
    /// per-cell replay (the full random-config suite lives in
    /// `tests/replay_parity.rs`).
    #[test]
    fn tiered_stackdist_matches_replay() {
        let test = mk_traces(5, 41);
        let fit = mk_traces(4, 42);
        let inp = inputs(&test, &fit);
        let gpu = [0.05, 0.2, 0.8];
        let host = [0.02, 0.3];
        let ssd = [8_000.0, 22_000.0];
        let fast = sweep_tiered_threaded(
            PredictorKind::None, &gpu, &host, &ssd, &inp, &base_tiers(), 1_000.0, 4,
        )
        .unwrap();
        let exact = sweep_tiered_replay_threaded(
            PredictorKind::None, &gpu, &host, &ssd, &inp, &base_tiers(), 1_000.0, 4,
        )
        .unwrap();
        assert_eq!(fast.len(), exact.len());
        for (f, e) in fast.iter().zip(exact.iter()) {
            assert_eq!(f.gpu_hit_rate.to_bits(), e.gpu_hit_rate.to_bits());
            assert_eq!(f.deep_miss_rate.to_bits(), e.deep_miss_rate.to_bits());
            assert_eq!(f.critical_path_us.to_bits(), e.critical_path_us.to_bits());
            assert_eq!(f.stats.hits, e.stats.hits);
            assert_eq!(f.stats.misses, e.stats.misses);
            assert_eq!(f.stats.transfer_us.to_bits(), e.stats.transfer_us.to_bits());
            assert_eq!(f.tiers.served, e.tiers.served);
            assert_eq!(f.tiers.cold, e.tiers.cold);
            assert_eq!(f.tiers.promotions, e.tiers.promotions);
            assert_eq!(f.tiers.demotions, e.tiers.demotions);
            assert_eq!(f.tiers.dropped, e.tiers.dropped);
        }
    }

    /// A shared pre-compiled corpus produces the same sweeps as per-call
    /// compilation, and repeat sweeps reuse its memoized profile.
    #[test]
    fn shared_corpus_matches_per_call_compilation() {
        let test = mk_traces(5, 51);
        let fit = mk_traces(4, 52);
        let fresh = inputs(&test, &fit);
        let corpus: crate::trace::CompiledCorpus = crate::trace::CompiledCorpus::compile(&test);
        let mut shared = inputs(&test, &fit);
        shared.compiled = Some(&corpus);
        let fracs = [0.05, 0.2, 0.8];
        let a = sweep_capacities_threaded(PredictorKind::None, &fracs, &fresh, 2).unwrap();
        let b = sweep_capacities_threaded(PredictorKind::None, &fracs, &shared, 2).unwrap();
        assert_sweep_eq(&a, &b);
        let ta = sweep_tiered(
            PredictorKind::None, &fracs, &[0.5], &[22_000.0], &fresh, &base_tiers(), 1_000.0,
        )
        .unwrap();
        let tb = sweep_tiered(
            PredictorKind::None, &fracs, &[0.5], &[22_000.0], &shared, &base_tiers(), 1_000.0,
        )
        .unwrap();
        for (x, y) in ta.iter().zip(tb.iter()) {
            assert_eq!(x.gpu_hit_rate.to_bits(), y.gpu_hit_rate.to_bits());
            assert_eq!(x.critical_path_us.to_bits(), y.critical_path_us.to_bits());
        }
        // both shared-corpus sweeps used ONE memoized profile
        let p1 = corpus.stackdist_profile(64, SimConfig::default().warmup_tokens, 1);
        let p2 = corpus.stackdist_profile(64, SimConfig::default().warmup_tokens, 4);
        assert!(std::sync::Arc::ptr_eq(&p1, &p2));
    }

    /// A 1-node cluster over a loopback link IS the flat Fig-7 sweep:
    /// every counter and every float must agree bit-for-bit with the
    /// exact flat replay, for prefetching and non-prefetching predictors
    /// alike.
    #[test]
    fn k1_loopback_cluster_sweep_matches_flat_sweep_exactly() {
        let test = mk_traces(5, 61);
        let fit = mk_traces(6, 62);
        let inp = inputs(&test, &fit);
        let fracs = [0.05, 0.2, 0.8];
        for kind in [PredictorKind::None, PredictorKind::Eam, PredictorKind::Oracle] {
            let flat =
                sweep_capacities_replay_threaded(kind, &fracs, &inp, 2).unwrap();
            let cluster = sweep_cluster_threaded(
                kind,
                &[1],
                &[PlacementKind::RoundRobin],
                &[0.0],
                &fracs,
                &inp,
                &ClusterConfig::default(),
                2,
            )
            .unwrap();
            assert_eq!(cluster.len(), flat.points.len());
            for (c, f) in cluster.iter().zip(flat.points.iter()) {
                assert_eq!(c.capacity_per_node, f.capacity_experts);
                assert_eq!(c.gpu_hit_rate.to_bits(), f.hit_rate.to_bits());
                assert_eq!(c.stats.hits, f.stats.hits);
                assert_eq!(c.stats.misses, f.stats.misses);
                assert_eq!(c.stats.prefetches, f.stats.prefetches);
                assert_eq!(
                    c.stats.transfer_us.to_bits(),
                    f.stats.transfer_us.to_bits()
                );
                assert_eq!(c.net.remote_lookups, 0);
                assert_eq!(c.net.total_us(), 0.0);
                assert_eq!(c.remote_rate, 0.0);
            }
        }
    }

    /// Link bandwidth moves the modeled latency surface, never the
    /// hit/miss routing.
    #[test]
    fn cluster_bandwidth_moves_latency_not_hit_rate() {
        let test = mk_traces(4, 63);
        let fit = mk_traces(4, 64);
        let inp = inputs(&test, &fit);
        let pts = sweep_cluster(
            PredictorKind::None,
            &[3],
            &[PlacementKind::RoundRobin],
            &[0.1, 10.0],
            &[0.2],
            &inp,
            &ClusterConfig::default(),
        )
        .unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].net.remote_lookups > 0, "k=3 rr must route remotely");
        assert_eq!(pts[0].gpu_hit_rate.to_bits(), pts[1].gpu_hit_rate.to_bits());
        assert_eq!(pts[0].remote_rate.to_bits(), pts[1].remote_rate.to_bits());
        assert!(
            pts[0].critical_path_us > pts[1].critical_path_us,
            "0.1 Gbps {} must cost more than 10 Gbps {}",
            pts[0].critical_path_us,
            pts[1].critical_path_us
        );
    }

    /// Cluster grid: deterministic at any worker count, row-major order.
    #[test]
    fn threaded_cluster_sweep_matches_serial_exactly() {
        let test = mk_traces(4, 65);
        let fit = mk_traces(4, 66);
        let inp = inputs(&test, &fit);
        let run = |threads| {
            sweep_cluster_threaded(
                PredictorKind::Eam,
                &[1, 3],
                &[PlacementKind::RoundRobin, PlacementKind::LayerHash],
                &[1.0],
                &[0.1, 0.4],
                &inp,
                &ClusterConfig::default().with_promote_after(3),
                threads,
            )
            .unwrap()
        };
        let serial = run(1);
        let par = run(8);
        assert_eq!(serial.len(), par.len());
        assert_eq!(serial.len(), 2 * 2 * 2);
        for (s, p) in serial.iter().zip(par.iter()) {
            assert_eq!(s.nodes, p.nodes);
            assert_eq!(s.placement, p.placement);
            assert_eq!(s.gpu_hit_rate.to_bits(), p.gpu_hit_rate.to_bits());
            assert_eq!(s.critical_path_us.to_bits(), p.critical_path_us.to_bits());
            assert_eq!(s.net, p.net);
        }
    }

    /// Tiered surface: same determinism guarantee over the 3-axis grid.
    #[test]
    fn threaded_tiered_sweep_matches_serial_exactly() {
        let test = mk_traces(4, 31);
        let fit = mk_traces(6, 32);
        let inp = inputs(&test, &fit);
        let run = |threads| {
            sweep_tiered_threaded(
                PredictorKind::Eam,
                &[0.05, 0.2],
                &[0.05, 0.5],
                &[8_000.0, 22_000.0],
                &inp,
                &base_tiers(),
                1_000.0,
                threads,
            )
            .unwrap()
        };
        let serial = run(1);
        let par = run(8);
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(par.iter()) {
            assert_eq!(s.gpu_hit_rate.to_bits(), p.gpu_hit_rate.to_bits());
            assert_eq!(s.deep_miss_rate.to_bits(), p.deep_miss_rate.to_bits());
            assert_eq!(s.critical_path_us.to_bits(), p.critical_path_us.to_bits());
            assert_eq!(s.tiers.served, p.tiers.served);
            assert_eq!(s.tiers.cold, p.tiers.cold);
            assert_eq!(s.tiers.demotions, p.tiers.demotions);
            assert_eq!(s.tiers.dropped, p.tiers.dropped);
        }
    }

    /// Chaos grid: the prepended intensity-0 baseline rows are clean
    /// (full availability, no retries, inflation exactly 1), and the
    /// whole sweep — including the seeded fault plans — is bit-identical
    /// at any worker count (and therefore across replays).
    #[test]
    fn chaos_sweep_baselines_are_clean_and_output_is_deterministic() {
        let test = mk_traces(6, 71);
        let fit = mk_traces(4, 72);
        let inp = inputs(&test, &fit);
        let base = ClusterConfig::default().with_nodes(3);
        let run = |threads| {
            sweep_chaos_threaded(
                PredictorKind::None,
                &[1, 2],
                &[0.8],
                &[PlacementKind::RoundRobin],
                0.2,
                &inp,
                &base,
                threads,
            )
            .unwrap()
        };
        let pts = run(1);
        // row-major (R × placement × (baseline + intensities))
        assert_eq!(pts.len(), 4);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(p.replicas, if i < 2 { 1 } else { 2 });
            assert_eq!(p.hit_curve.len(), test.len());
            assert!(
                (0.0..=1.0).contains(&p.availability),
                "availability {} out of range",
                p.availability
            );
        }
        for b in [&pts[0], &pts[2]] {
            assert_eq!(b.intensity, 0.0);
            assert_eq!(b.availability, 1.0);
            assert_eq!(b.p99_inflation, 1.0);
            assert_eq!(b.net.degraded_fetches, 0);
            assert_eq!(b.net.retries, 0);
            assert_eq!(b.net.timeout_us, 0.0);
        }
        // k=3 round-robin must cross the network even when healthy
        assert!(pts[0].net.remote_lookups > 0);
        let par = run(4);
        for (s, p) in pts.iter().zip(par.iter()) {
            assert_eq!(s.replicas, p.replicas);
            assert_eq!(s.intensity.to_bits(), p.intensity.to_bits());
            assert_eq!(s.availability.to_bits(), p.availability.to_bits());
            assert_eq!(s.gpu_hit_rate.to_bits(), p.gpu_hit_rate.to_bits());
            assert_eq!(s.critical_path_us.to_bits(), p.critical_path_us.to_bits());
            assert_eq!(s.p99_prompt_us.to_bits(), p.p99_prompt_us.to_bits());
            assert_eq!(s.net, p.net);
            assert_eq!(s.hit_curve, p.hit_curve);
        }
        assert_eq!(chaos_csv(&pts), chaos_csv(&par));
    }

    /// Under a fixed chaos plan with nested replica rank maps, adding
    /// replicas never reduces availability (the monotonicity the R-column
    /// of `benches/cluster_scale.rs` gates on).
    #[test]
    fn chaos_availability_is_monotone_in_replication() {
        let test = mk_traces(8, 73);
        let fit = mk_traces(4, 74);
        let inp = inputs(&test, &fit);
        let base = ClusterConfig::default().with_nodes(4);
        let pts = sweep_chaos(
            PredictorKind::None,
            &[1, 2, 3, 4],
            &[1.0],
            &[PlacementKind::RoundRobin],
            0.2,
            &inp,
            &base,
        )
        .unwrap();
        // rows: (R, 0.0), (R, 1.0) per R
        let faulted: Vec<&ChaosSweepPoint> =
            pts.iter().filter(|p| p.intensity > 0.0).collect();
        assert_eq!(faulted.len(), 4);
        for w in faulted.windows(2) {
            assert!(
                w[1].availability >= w[0].availability,
                "availability must not drop when R grows: R={} {} vs R={} {}",
                w[0].replicas,
                w[0].availability,
                w[1].replicas,
                w[1].availability
            );
        }
        // the chaos plan at full intensity on 4 nodes actually bites
        assert!(
            faulted[0].net.degraded_fetches > 0,
            "intensity-1.0 chaos on R=1 should force degraded fetches"
        );
        // CSV shape: header + one row per point, recovery curve last
        let csv = chaos_csv(&pts);
        assert_eq!(csv.lines().count(), pts.len() + 1);
        assert!(csv.starts_with("replicas,intensity,placement,availability,"));
    }
}
