//! `TieredCache` — an exclusive multi-level cache composing one
//! [`CachePolicy`] per tier.
//!
//! Residency is exclusive: an expert lives in at most one tier at a
//! time (plus the implicit flash backing store below the last tier).
//! A lookup promotes the expert to tier 0 (GPU); the GPU's eviction
//! victim demotes to tier 1 (host) instead of vanishing, tier 1's
//! victim demotes to tier 2, and the last tier's victim drops — the
//! weights are still on flash, just no longer staged.

use crate::cache::{build_policy, CachePolicy, ExpertKey};
use crate::tier::TierSpec;
use crate::Result;

/// One demotion caused by a promotion's eviction chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Demotion {
    pub key: ExpertKey,
    /// Tier the key was evicted from.
    pub from: usize,
    /// Tier the key landed in; `None` = dropped past the last tier.
    pub to: Option<usize>,
}

/// Outcome of promoting one key to tier 0.
#[derive(Debug, Clone)]
pub struct Promotion {
    /// Depth the key was found at before promotion (`None` = cold, i.e.
    /// fetched from the backing store below the deepest tier).
    pub found: Option<usize>,
    /// Demotions triggered by the insert chain (at most one per tier).
    pub demoted: Vec<Demotion>,
}

pub struct TieredCache {
    tiers: Vec<Box<dyn CachePolicy>>,
}

impl TieredCache {
    /// Compose pre-built per-tier policies (index 0 = GPU).
    pub fn new(tiers: Vec<Box<dyn CachePolicy>>) -> Self {
        assert!(!tiers.is_empty(), "tiered cache needs at least one tier");
        Self { tiers }
    }

    /// Build every tier with the same named policy ("lru" | "lfu") at the
    /// capacities given by `specs`.
    pub fn build(policy: &str, specs: &[TierSpec]) -> Result<Self> {
        anyhow::ensure!(!specs.is_empty(), "tiered cache needs at least one tier");
        let tiers = specs
            .iter()
            .map(|s| build_policy(policy, s.capacity_experts))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self::new(tiers))
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Index of the deepest tier (cold fetches are charged at its cost).
    pub fn deepest(&self) -> usize {
        self.tiers.len() - 1
    }

    /// Depth at which `k` is resident (0 = GPU), or `None` if cold.
    pub fn locate(&self, k: ExpertKey) -> Option<usize> {
        self.tiers.iter().position(|t| t.contains(k))
    }

    /// Bump recency/frequency at whichever tier holds `k`.
    pub fn touch(&mut self, k: ExpertKey) -> Option<usize> {
        let depth = self.locate(k)?;
        self.tiers[depth].touch(k);
        Some(depth)
    }

    /// Move `k` to tier 0, rippling eviction victims down the hierarchy.
    ///
    /// Invariants (checked by the property tests below):
    /// * afterwards `k` is resident in tier 0 and nowhere else,
    /// * each tier evicts at most once per promotion,
    /// * every tier stays within capacity.
    pub fn promote(&mut self, k: ExpertKey) -> Promotion {
        let found = self.locate(k);
        if found == Some(0) {
            self.tiers[0].touch(k);
            return Promotion {
                found,
                demoted: Vec::new(),
            };
        }
        if let Some(d) = found {
            self.tiers[d].evict(k);
        }
        let mut demoted = Vec::new();
        let mut level = 0;
        let mut victim = self.tiers[0].insert(k);
        while let Some(v) = victim {
            let dest = level + 1;
            if dest >= self.tiers.len() {
                demoted.push(Demotion {
                    key: v,
                    from: level,
                    to: None,
                });
                break;
            }
            demoted.push(Demotion {
                key: v,
                from: level,
                to: Some(dest),
            });
            victim = self.tiers[dest].insert(v);
            level = dest;
        }
        Promotion { found, demoted }
    }

    /// Resident count at a depth.
    pub fn len_at(&self, depth: usize) -> usize {
        self.tiers[depth].len()
    }

    pub fn capacity_at(&self, depth: usize) -> usize {
        self.tiers[depth].capacity()
    }

    /// Per-tier view for diagnostics and invariant checks.
    pub fn tier(&self, depth: usize) -> &dyn CachePolicy {
        self.tiers[depth].as_ref()
    }

    pub fn resident_total(&self) -> usize {
        self.tiers.iter().map(|t| t.len()).sum()
    }

    pub fn clear(&mut self) {
        for t in &mut self.tiers {
            t.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::LruCache;

    fn three_tier(caps: [usize; 3]) -> TieredCache {
        TieredCache::new(vec![
            Box::new(LruCache::new(caps[0])),
            Box::new(LruCache::new(caps[1])),
            Box::new(LruCache::new(caps[2])),
        ])
    }

    #[test]
    fn cold_promote_lands_in_gpu() {
        let mut c = three_tier([2, 2, 4]);
        let p = c.promote(7);
        assert_eq!(p.found, None);
        assert!(p.demoted.is_empty());
        assert_eq!(c.locate(7), Some(0));
    }

    #[test]
    fn gpu_eviction_demotes_to_host() {
        let mut c = three_tier([2, 2, 4]);
        c.promote(1);
        c.promote(2);
        let p = c.promote(3); // GPU full: 1 is LRU, falls to host
        assert_eq!(
            p.demoted,
            vec![Demotion {
                key: 1,
                from: 0,
                to: Some(1)
            }]
        );
        assert_eq!(c.locate(1), Some(1));
        assert_eq!(c.locate(3), Some(0));
    }

    #[test]
    fn promotion_from_host_is_a_swap() {
        let mut c = three_tier([2, 2, 4]);
        c.promote(1);
        c.promote(2);
        c.promote(3); // 1 now in host
        let p = c.promote(1); // back up: 2 is the GPU victim
        assert_eq!(p.found, Some(1));
        assert_eq!(c.locate(1), Some(0));
        assert_eq!(c.locate(2), Some(1));
        // exclusive: 1 left the host tier
        assert_eq!(c.len_at(1), 1);
    }

    #[test]
    fn chain_drops_past_last_tier() {
        let mut c = three_tier([1, 1, 1]);
        c.promote(1);
        c.promote(2); // 1 -> host
        c.promote(3); // 2 -> host, 1 -> ssd
        let p = c.promote(4); // 3 -> host, 2 -> ssd, 1 dropped
        assert_eq!(p.demoted.len(), 3);
        assert_eq!(p.demoted[2].to, None);
        assert_eq!(p.demoted[2].key, 1);
        assert_eq!(c.locate(1), None);
        assert_eq!(c.resident_total(), 3);
    }

    #[test]
    fn gpu_hit_only_refreshes() {
        let mut c = three_tier([2, 2, 4]);
        c.promote(1);
        c.promote(2);
        let p = c.promote(2);
        assert_eq!(p.found, Some(0));
        assert!(p.demoted.is_empty());
        assert_eq!(c.len_at(0), 2);
    }

    /// Exclusivity + capacity + one-eviction-per-tier under random
    /// promotion streams.
    #[test]
    fn prop_hierarchy_invariants() {
        let mut rng = crate::util::Rng::new(91);
        for _case in 0..100 {
            let caps = [rng.range(1, 4), rng.range(1, 6), rng.range(1, 8)];
            let mut c = three_tier(caps);
            for _ in 0..rng.range(1, 200) {
                let k = rng.below(24) as u32;
                let p = c.promote(k);
                // promoted key is at the top and nowhere else
                assert_eq!(c.locate(k), Some(0));
                // at most one demotion per tier
                assert!(p.demoted.len() <= 3);
                for (i, d) in p.demoted.iter().enumerate() {
                    assert_eq!(d.from, i);
                }
                for depth in 0..3 {
                    assert!(c.len_at(depth) <= caps[depth]);
                }
                // exclusivity: no key resident in two tiers
                let mut seen = std::collections::HashSet::new();
                for depth in 0..3 {
                    for r in c.tier(depth).resident() {
                        assert!(seen.insert(r), "key {r} resident in two tiers");
                    }
                }
            }
        }
    }
}
