//! Tier-aware transfer-cost accounting — the multi-level generalization
//! of [`crate::cache::VramModel`].
//!
//! A demand miss charges the fetch cost of the *deepest* tier it had to
//! reach (hit-rate alone mispredicts latency once tiers have asymmetric
//! bandwidths).  Prefetch and demotion-writeback DMA overlap compute
//! per tier: the PCIe link and the SSD channel are independent, so each
//! tier gets the full per-layer overlap window; whatever exceeds a
//! tier's window in a layer becomes stall time on the critical path.

use crate::tier::{Promotion, TierSpec, TierStats};

/// Per-tier cost accumulators (all µs, modeled virtual time).
#[derive(Debug, Clone, Default)]
pub struct TierCost {
    /// Demand fetches served from this tier (critical path).
    pub demand_us: f64,
    /// Prefetch DMA reading from this tier (overlapped up to the window).
    pub prefetch_us: f64,
    /// Demotion writeback DMA into this tier (overlapped up to the window).
    pub writeback_us: f64,
    /// DMA beyond this tier's per-layer overlap window (critical path).
    pub stall_us: f64,
    /// This layer's in-flight DMA on this tier's channel.
    layer_dma_us: f64,
}

/// Accumulates modeled transfer time across the hierarchy.
#[derive(Debug, Clone)]
pub struct TierCostModel {
    specs: Vec<TierSpec>,
    pub tiers: Vec<TierCost>,
    /// Per-layer compute window available to hide each tier's DMA (µs).
    pub overlap_budget_us: f64,
}

impl TierCostModel {
    pub fn new(specs: Vec<TierSpec>, overlap_budget_us: f64) -> Self {
        assert!(!specs.is_empty(), "cost model needs at least one tier");
        let tiers = vec![TierCost::default(); specs.len()];
        Self {
            specs,
            tiers,
            overlap_budget_us,
        }
    }

    pub fn n_tiers(&self) -> usize {
        self.specs.len()
    }

    /// Fetch cost of serving one expert from `depth` (µs).
    pub fn fetch_us(&self, depth: usize) -> f64 {
        self.specs[depth].fetch_us_per_expert
    }

    /// A GPU-resident hit on the critical path.
    pub fn on_hit(&mut self) {
        self.on_demand_fetch(0);
    }

    /// A demand fetch served from `depth` (0 = GPU hit; pass the deepest
    /// tier for cold reads from the backing store).  Synchronous: the
    /// layer stalls for the full fetch.
    pub fn on_demand_fetch(&mut self, depth: usize) {
        self.tiers[depth].demand_us += self.specs[depth].fetch_us_per_expert;
    }

    /// `n` demand fetches from `depth` in one charge — the analytic
    /// sweep's bulk entry point.  `n·cost` is bit-identical to `n`
    /// repeated [`on_demand_fetch`](Self::on_demand_fetch) calls
    /// whenever the partial sums are exactly representable
    /// (integer-valued µs costs, as configured throughout this crate).
    pub fn on_demand_fetch_n(&mut self, depth: usize, n: u64) {
        self.tiers[depth].demand_us += n as f64 * self.specs[depth].fetch_us_per_expert;
    }

    /// `n` demotion writebacks into tier `dest`, charged as fully
    /// overlapped DMA (no per-layer window accounting, so no stall can
    /// be produced).  Only valid when the caller has proven no layer's
    /// writeback DMA could exceed the overlap window — the analytic
    /// sweep's stall-free precondition (see `sim::sweep`).
    pub fn on_writeback_overlapped_n(&mut self, dest: usize, n: u64) {
        self.tiers[dest].writeback_us += n as f64 * self.specs[dest].writeback_us_per_expert;
    }

    /// A prefetch reading one expert from `depth`, overlapped with the
    /// previous layer's compute on that tier's channel.
    pub fn on_prefetch(&mut self, depth: usize) {
        let us = self.specs[depth].fetch_us_per_expert;
        self.tiers[depth].prefetch_us += us;
        self.tiers[depth].layer_dma_us += us;
    }

    /// A demotion writing one expert into tier `dest`, sharing that
    /// tier's DMA channel with prefetches.
    pub fn on_writeback(&mut self, dest: usize) {
        let us = self.specs[dest].writeback_us_per_expert;
        self.tiers[dest].writeback_us += us;
        self.tiers[dest].layer_dma_us += us;
    }

    /// Charge a promotion's demotion chain: a writeback into each
    /// destination tier (sharing its DMA window) plus the demotion/drop
    /// counters.  The single accounting point for both the simulator and
    /// the serving path.
    pub fn charge_demotions(&mut self, stats: &mut TierStats, promo: &Promotion) {
        for d in &promo.demoted {
            match d.to {
                Some(dest) => {
                    self.on_writeback(dest);
                    stats.demotions += 1;
                }
                None => stats.dropped += 1,
            }
        }
    }

    /// Close out a layer: per tier, DMA beyond the overlap window becomes
    /// stall time; every window then resets.
    pub fn end_layer(&mut self) {
        for t in &mut self.tiers {
            if t.layer_dma_us > self.overlap_budget_us {
                t.stall_us += t.layer_dma_us - self.overlap_budget_us;
            }
            t.layer_dma_us = 0.0;
        }
    }

    pub fn demand_total(&self) -> f64 {
        self.tiers.iter().map(|t| t.demand_us).sum()
    }

    pub fn stall_total(&self) -> f64 {
        self.tiers.iter().map(|t| t.stall_us).sum()
    }

    /// Total modeled critical-path microseconds.
    pub fn critical_path_us(&self) -> f64 {
        self.demand_total() + self.stall_total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::VramModel;
    use crate::config::CacheConfig;

    fn two_tier() -> TierCostModel {
        // mirrors a flat VramModel: GPU hit 1µs, host fetch 100µs, no
        // writeback cost
        TierCostModel::new(
            vec![
                TierSpec::new("gpu", 16, 1.0, 0.0),
                TierSpec::new("host", 1000, 100.0, 0.0),
            ],
            250.0,
        )
    }

    /// The two-tier model reproduces VramModel trajectories exactly.
    #[test]
    fn matches_flat_vram_model() {
        let cfg = CacheConfig {
            capacity_experts: 16,
            pcie_us_per_expert: 100.0,
            hit_us: 1.0,
            ..Default::default()
        };
        let mut flat = VramModel::new(cfg, 250.0);
        let mut tiered = two_tier();
        // hit, miss, 4 prefetches (2 layers), another layer of 1 prefetch
        flat.on_hit();
        tiered.on_hit();
        flat.on_demand_miss();
        tiered.on_demand_fetch(1);
        for _ in 0..4 {
            flat.on_prefetch();
            tiered.on_prefetch(1);
        }
        flat.end_layer();
        tiered.end_layer();
        flat.on_prefetch();
        tiered.on_prefetch(1);
        flat.end_layer();
        tiered.end_layer();
        assert_eq!(flat.demand_us, tiered.demand_total());
        assert_eq!(flat.stall_us, tiered.stall_total());
        assert_eq!(flat.critical_path_us(), tiered.critical_path_us());
    }

    /// Bulk charges are bit-identical to repeated unit charges for
    /// integer-valued costs, and overlapped writebacks never stall.
    #[test]
    fn bulk_charges_match_repeated_unit_charges() {
        let mut unit = two_tier();
        let mut bulk = two_tier();
        for _ in 0..7 {
            unit.on_demand_fetch(1);
        }
        for _ in 0..3 {
            unit.on_hit();
        }
        bulk.on_demand_fetch_n(1, 7);
        bulk.on_demand_fetch_n(0, 3);
        assert_eq!(unit.demand_total().to_bits(), bulk.demand_total().to_bits());

        bulk.on_writeback_overlapped_n(1, 5);
        assert_eq!(bulk.tiers[1].writeback_us, 0.0); // two_tier has wb = 0
        assert_eq!(bulk.stall_total(), 0.0);
        let mut wb = TierCostModel::new(
            vec![
                TierSpec::new("gpu", 4, 0.0, 0.0),
                TierSpec::new("host", 8, 100.0, 100.0),
            ],
            250.0,
        );
        wb.on_writeback_overlapped_n(1, 5);
        assert_eq!(wb.tiers[1].writeback_us, 500.0);
        // overlapped bulk writebacks bypass the per-layer window
        wb.end_layer();
        assert_eq!(wb.stall_total(), 0.0);
    }

    #[test]
    fn deepest_tier_charged() {
        let mut m = TierCostModel::new(
            vec![
                TierSpec::new("gpu", 4, 1.0, 0.0),
                TierSpec::new("host", 8, 100.0, 50.0),
                TierSpec::new("ssd", 16, 1000.0, 0.0),
            ],
            1_000.0,
        );
        m.on_demand_fetch(2); // cold read: SSD cost, not PCIe
        m.on_demand_fetch(1);
        assert_eq!(m.tiers[2].demand_us, 1000.0);
        assert_eq!(m.tiers[1].demand_us, 100.0);
        assert_eq!(m.demand_total(), 1100.0);
    }

    #[test]
    fn per_tier_windows_are_independent() {
        let mut m = TierCostModel::new(
            vec![
                TierSpec::new("gpu", 4, 0.0, 0.0),
                TierSpec::new("host", 8, 100.0, 100.0),
                TierSpec::new("ssd", 16, 300.0, 0.0),
            ],
            250.0,
        );
        // 3 host prefetches (300 > 250: 50 stalls) + 1 SSD prefetch
        // (300 > 250: 50 stalls) — the channels do NOT share a window
        for _ in 0..3 {
            m.on_prefetch(1);
        }
        m.on_prefetch(2);
        m.end_layer();
        assert_eq!(m.tiers[1].stall_us, 50.0);
        assert_eq!(m.tiers[2].stall_us, 50.0);
        assert_eq!(m.stall_total(), 100.0);
    }

    #[test]
    fn charge_demotions_writes_back_and_counts() {
        use crate::tier::Demotion;
        let mut m = TierCostModel::new(
            vec![
                TierSpec::new("gpu", 4, 0.0, 0.0),
                TierSpec::new("host", 8, 100.0, 100.0),
            ],
            250.0,
        );
        let mut stats = TierStats::new(2);
        let promo = Promotion {
            found: None,
            demoted: vec![
                Demotion {
                    key: 3,
                    from: 0,
                    to: Some(1),
                },
                Demotion {
                    key: 4,
                    from: 1,
                    to: None,
                },
            ],
        };
        m.charge_demotions(&mut stats, &promo);
        assert_eq!(stats.demotions, 1);
        assert_eq!(stats.dropped, 1);
        assert_eq!(m.tiers[1].writeback_us, 100.0);
    }

    #[test]
    fn writeback_shares_the_dest_window() {
        let mut m = TierCostModel::new(
            vec![
                TierSpec::new("gpu", 4, 0.0, 0.0),
                TierSpec::new("host", 8, 100.0, 100.0),
            ],
            250.0,
        );
        // 2 prefetches + 1 demotion writeback on the same PCIe channel:
        // 300µs > 250µs window
        m.on_prefetch(1);
        m.on_prefetch(1);
        m.on_writeback(1);
        m.end_layer();
        assert_eq!(m.tiers[1].stall_us, 50.0);
        assert_eq!(m.tiers[1].writeback_us, 100.0);
    }
}
