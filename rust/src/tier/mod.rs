//! Tiered expert memory hierarchy (GPU VRAM ↔ host RAM ↔ SSD).
//!
//! The flat [`crate::cache`] model treats every miss as one PCIe fetch
//! from an infinite host pool.  Real edge deployments stage expert
//! weights across up to three tiers with wildly asymmetric bandwidths
//! (FlashMoE: SSD I/O dominates MoE inference latency on edge devices),
//! so hit-rate alone mispredicts end-to-end latency.  This module models
//! the hierarchy explicitly:
//!
//! * [`TierSpec`] — one level: capacity in experts, fetch µs/expert
//!   (cost of serving an expert *from* this tier into VRAM), writeback
//!   µs/expert (cost of demoting an expert *into* this tier).
//! * [`TieredCache`] — an exclusive hierarchy composing one
//!   [`crate::cache::CachePolicy`] per tier: a lookup promotes the
//!   expert to tier 0 (GPU), each tier's eviction victim demotes one
//!   level down, and the last tier's victim drops (weights always
//!   remain on flash).
//! * [`TierCostModel`] — generalizes [`crate::cache::VramModel`]:
//!   a demand miss charges the fetch cost of the *deepest* tier it had
//!   to reach, and prefetch/writeback DMA overlaps compute per tier
//!   (the PCIe and SSD links are independent channels).
//! * [`TierStats`] — per-depth serve counters (how many lookups each
//!   tier absorbed), promotions, demotions, drops.
//! * [`net`] — the network "tier": [`LinkSpec`] prices one inter-node
//!   transfer (latency + per-hop cost + payload/bandwidth) the way
//!   [`TierSpec`] prices one tier access, and [`NetCostModel`] /
//!   [`NetStats`] accumulate those charges for the cluster backend
//!   ([`crate::cluster`]).
//!
//! Tiered mode is opt-in everywhere: [`crate::memory::build`] selects
//! [`crate::memory::TieredMemory`] (which composes these primitives)
//! only when a [`crate::config::TierConfig`] is supplied, keeping the
//! flat path bit-identical otherwise.

mod cache;
mod cost;
pub mod net;
mod spec;
mod stats;

pub use cache::{Demotion, Promotion, TieredCache};
pub use cost::{TierCost, TierCostModel};
pub use net::{LinkSpec, NetCostModel, NetStats};
pub use spec::TierSpec;
pub use stats::TierStats;
