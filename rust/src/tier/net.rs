//! Network "tier" of the cost model — the per-link cost primitives the
//! cluster backend charges for crossing node boundaries.
//!
//! The existing hierarchy ([`super::TierCostModel`]) prices a miss by
//! the depth it had to reach (GPU ↔ host ↔ SSD).  A multi-node edge
//! cluster adds one more rung below the local hierarchy: a peer node
//! reachable over a link.  [`LinkSpec`] prices one transfer exactly the
//! way [`super::TierSpec`] prices one tier access — a fixed latency, a
//! per-hop switching cost, and a bandwidth term proportional to the
//! payload — and [`NetCostModel`] accumulates those charges the way
//! [`super::TierCost`] accumulates per-tier DMA, so the cluster's
//! critical-path arithmetic composes with the per-node hierarchies
//! instead of replacing them.
//!
//! All costs are µs-valued and every accumulation is a plain `+=` in a
//! deterministic order, so seeded cluster runs are byte-reproducible
//! (the same `to_bits` discipline the tier parity suites rely on).

use crate::Result;

/// One inter-node link: the network analogue of a [`super::TierSpec`].
///
/// A transfer of `mb` megabytes over `hops` hops costs
/// `latency_us + per_hop_us * hops + mb * 8000 / gbps` microseconds
/// (`gbps <= 0` models an infinitely fast link — only latency and
/// per-hop cost remain, and [`LinkSpec::loopback`] zeroes those too).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    /// Fixed propagation/setup latency per transfer (µs).
    pub latency_us: f64,
    /// Link bandwidth in Gbit/s; `<= 0` means infinite.
    pub gbps: f64,
    /// Fixed switching cost per hop traversed (µs).
    pub per_hop_us: f64,
    /// Per-fetch deadline (µs); `<= 0` disables timeouts.  A remote
    /// fetch whose priced wire time exceeds the deadline charges
    /// exactly `timeout_us` (the fetcher gave up at the deadline) and
    /// the cluster retries the next-cheapest alive replica.
    pub timeout_us: f64,
}

impl LinkSpec {
    pub fn new(latency_us: f64, gbps: f64, per_hop_us: f64) -> Self {
        Self {
            latency_us,
            gbps,
            per_hop_us,
            timeout_us: 0.0,
        }
    }

    /// Arm the per-fetch deadline (builder form; `0` keeps it off).
    pub fn with_timeout_us(mut self, timeout_us: f64) -> Self {
        self.timeout_us = timeout_us;
        self
    }

    /// The zero-cost link: every transfer is free.  A K=1 (or K-node,
    /// zero-distance) cluster over a loopback link must be byte-identical
    /// to the single-node path — the cluster parity suite pins that.
    pub fn loopback() -> Self {
        Self::new(0.0, 0.0, 0.0)
    }

    /// Wired edge LAN: 100 µs latency, 10 Gbit/s, 5 µs per hop.
    pub fn lan() -> Self {
        Self::new(100.0, 10.0, 5.0)
    }

    /// Wireless mesh (the OD-MoE regime): 2 ms latency, 1 Gbit/s,
    /// 20 µs per hop.
    pub fn wifi() -> Self {
        Self::new(2_000.0, 1.0, 20.0)
    }

    /// Cost of moving `mb` megabytes across `hops` hops (µs).
    #[inline]
    pub fn transfer_us(&self, mb: f64, hops: usize) -> f64 {
        let bw_us = if self.gbps > 0.0 {
            mb * 8_000.0 / self.gbps
        } else {
            0.0
        };
        self.latency_us + self.per_hop_us * hops as f64 + bw_us
    }

    /// Whether a transfer priced at `us` would blow the deadline.
    #[inline]
    pub fn times_out(&self, us: f64) -> bool {
        self.timeout_us > 0.0 && us > self.timeout_us
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.latency_us >= 0.0, "link latency must be >= 0");
        anyhow::ensure!(self.per_hop_us >= 0.0, "per-hop cost must be >= 0");
        anyhow::ensure!(self.gbps.is_finite(), "link bandwidth must be finite");
        anyhow::ensure!(
            self.timeout_us.is_finite() && self.timeout_us >= 0.0,
            "link timeout must be finite and >= 0 (0 disables it)"
        );
        Ok(())
    }
}

/// Cumulative network-transfer counters for one cluster run — the
/// cluster-level twin of [`super::TierStats`], snapshotted into
/// [`crate::memory::MemoryStats::net`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetStats {
    /// Measured lookups routed to a remote owner (hits and misses).
    pub remote_lookups: u64,
    /// Remote lookups served from the owner's GPU tier.
    pub remote_hits: u64,
    /// Hot experts migrated into the front node's hierarchy.
    pub promotions: u64,
    /// Measured lookups rerouted around a failed owner.
    pub failovers: u64,
    /// Remote fetch attempts abandoned at the deadline and retried on
    /// another replica.
    pub retries: u64,
    /// Measured lookups served through the degraded path because every
    /// replica was unreachable (deepest-tier demand load; never a panic).
    pub degraded_fetches: u64,
    /// Wire time charged for remote serves (activations + weights), µs.
    pub wire_us: f64,
    /// Wire time charged for promotion weight transfers, µs.
    pub promotion_us: f64,
    /// Deadline time burned by timed-out fetch attempts, µs.
    pub timeout_us: f64,
    /// Exponential-backoff wait folded into retried fetches, µs.
    pub backoff_us: f64,
}

impl NetStats {
    /// Total network µs on the modeled critical path.
    pub fn total_us(&self) -> f64 {
        self.wire_us + self.promotion_us + self.timeout_us + self.backoff_us
    }

    pub fn merge(&mut self, other: &NetStats) {
        self.remote_lookups += other.remote_lookups;
        self.remote_hits += other.remote_hits;
        self.promotions += other.promotions;
        self.failovers += other.failovers;
        self.retries += other.retries;
        self.degraded_fetches += other.degraded_fetches;
        self.wire_us += other.wire_us;
        self.promotion_us += other.promotion_us;
        self.timeout_us += other.timeout_us;
        self.backoff_us += other.backoff_us;
    }
}

/// Accumulates link charges for one cluster backend: the network
/// analogue of [`super::TierCostModel`], kept separate from the
/// per-node models so `cost_marks` can sum node-local DMA and network
/// time without double counting.
#[derive(Debug, Clone)]
pub struct NetCostModel {
    pub link: LinkSpec,
    /// Payload of one expert's weights (MB) — charged on remote misses
    /// and promotions.
    pub expert_mb: f64,
    /// Payload of one activation round-trip (MB) — charged on remote
    /// hits (the expert executes at its owner; activations travel).
    pub act_mb: f64,
    pub stats: NetStats,
}

impl NetCostModel {
    pub fn new(link: LinkSpec, expert_mb: f64, act_mb: f64) -> Self {
        Self {
            link,
            expert_mb,
            act_mb,
            stats: NetStats::default(),
        }
    }

    /// Price one remote serve without committing it: the wire µs a
    /// lookup *would* cost (already scaled by the link `mult`).  The
    /// retry loop prices an attempt first so a deadline blow-through
    /// charges [`Self::on_timeout`] instead of the full transfer.
    #[inline]
    pub fn price_remote(&self, hit: bool, hops: usize, mult: f64) -> f64 {
        let mb = if hit { self.act_mb } else { self.expert_mb };
        self.link.transfer_us(mb, hops) * mult
    }

    /// Commit one measured remote lookup priced at `us` by
    /// [`Self::price_remote`].  `hit` selects the activation payload
    /// (the owner had the expert GPU-resident) vs the weight payload
    /// (the owner faulted it up through its own hierarchy first, which
    /// its backend charged separately).
    pub fn commit_remote(&mut self, hit: bool, us: f64) {
        self.stats.remote_lookups += 1;
        if hit {
            self.stats.remote_hits += 1;
        }
        self.stats.wire_us += us;
    }

    /// Charge one measured remote lookup: price + commit in one step.
    /// Returns the wire µs (already scaled by the link `mult`).
    pub fn on_remote(&mut self, hit: bool, hops: usize, mult: f64) -> f64 {
        let us = self.price_remote(hit, hops, mult);
        self.commit_remote(hit, us);
        us
    }

    /// Charge one abandoned fetch attempt: the fetcher waited out the
    /// full deadline, then backed off `backoff_us` before retrying the
    /// next replica.  Returns the µs folded into the retry path.
    pub fn on_timeout(&mut self, backoff_us: f64) -> f64 {
        self.stats.retries += 1;
        self.stats.timeout_us += self.link.timeout_us;
        self.stats.backoff_us += backoff_us;
        self.link.timeout_us + backoff_us
    }

    /// Record one degraded serve (all replicas unreachable; the lookup
    /// fell back to the deepest-tier demand path).
    pub fn on_degraded(&mut self) {
        self.stats.degraded_fetches += 1;
    }

    /// Charge one expert-weight migration to the front node.  Returns
    /// the wire µs.
    pub fn on_promotion(&mut self, hops: usize, mult: f64) -> f64 {
        let us = self.link.transfer_us(self.expert_mb, hops) * mult;
        self.stats.promotions += 1;
        self.stats.promotion_us += us;
        us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_decomposes_into_latency_hops_bandwidth() {
        let l = LinkSpec::new(100.0, 10.0, 5.0);
        // 25 MB over 2 hops: 100 + 10 + 25*8000/10 = 20110 µs
        assert_eq!(l.transfer_us(25.0, 2), 20_110.0);
        // zero-byte transfer still pays latency + hops
        assert_eq!(l.transfer_us(0.0, 3), 115.0);
    }

    #[test]
    fn loopback_is_free_and_infinite_bandwidth_skips_the_bw_term() {
        assert_eq!(LinkSpec::loopback().transfer_us(1000.0, 7), 0.0);
        let l = LinkSpec::new(50.0, 0.0, 0.0);
        assert_eq!(l.transfer_us(1000.0, 0), 50.0);
    }

    #[test]
    fn net_cost_accumulates_and_merges() {
        let mut m = NetCostModel::new(LinkSpec::new(10.0, 0.0, 0.0), 25.0, 0.5);
        let hit_us = m.on_remote(true, 1, 1.0);
        let miss_us = m.on_remote(false, 1, 2.0);
        assert_eq!(hit_us, 10.0);
        assert_eq!(miss_us, 20.0); // straggler doubles it
        let promo_us = m.on_promotion(1, 1.0);
        assert_eq!(promo_us, 10.0);
        assert_eq!(m.stats.remote_lookups, 2);
        assert_eq!(m.stats.remote_hits, 1);
        assert_eq!(m.stats.promotions, 1);
        assert_eq!(m.stats.total_us(), 40.0);

        let mut a = NetStats::default();
        a.merge(&m.stats);
        a.merge(&m.stats);
        assert_eq!(a.remote_lookups, 4);
        assert_eq!(a.total_us(), 80.0);
    }

    #[test]
    fn validate_rejects_negative_costs() {
        assert!(LinkSpec::new(-1.0, 1.0, 0.0).validate().is_err());
        assert!(LinkSpec::new(0.0, 1.0, -2.0).validate().is_err());
        assert!(LinkSpec::lan().validate().is_ok());
        assert!(LinkSpec::wifi().validate().is_ok());
        assert!(LinkSpec::loopback().validate().is_ok());
        assert!(LinkSpec::lan().with_timeout_us(-5.0).validate().is_err());
        assert!(
            LinkSpec::lan()
                .with_timeout_us(f64::INFINITY)
                .validate()
                .is_err()
        );
        assert!(LinkSpec::lan().with_timeout_us(500.0).validate().is_ok());
    }

    #[test]
    fn zero_timeout_disables_the_deadline() {
        let l = LinkSpec::lan(); // timeout_us == 0
        assert!(!l.times_out(1e12));
        let armed = LinkSpec::lan().with_timeout_us(100.0);
        assert!(!armed.times_out(100.0)); // deadline itself still fits
        assert!(armed.times_out(100.5));
    }

    #[test]
    fn price_then_commit_matches_on_remote_bit_for_bit() {
        let link = LinkSpec::new(100.0, 10.0, 5.0);
        let mut a = NetCostModel::new(link.clone(), 25.0, 0.5);
        let mut b = NetCostModel::new(link, 25.0, 0.5);
        for (hit, hops, mult) in [(true, 1, 1.0), (false, 2, 3.0), (false, 1, 1.0)] {
            let direct = a.on_remote(hit, hops, mult);
            let priced = b.price_remote(hit, hops, mult);
            b.commit_remote(hit, priced);
            assert_eq!(direct.to_bits(), priced.to_bits());
        }
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn timeout_and_degraded_accounting() {
        let link = LinkSpec::new(10.0, 0.0, 0.0).with_timeout_us(40.0);
        let mut m = NetCostModel::new(link, 25.0, 0.5);
        let penalty = m.on_timeout(15.0);
        assert_eq!(penalty, 55.0); // deadline + backoff
        m.on_degraded();
        assert_eq!(m.stats.retries, 1);
        assert_eq!(m.stats.degraded_fetches, 1);
        assert_eq!(m.stats.timeout_us, 40.0);
        assert_eq!(m.stats.backoff_us, 15.0);
        // penalties ride the critical-path total
        assert_eq!(m.stats.total_us(), 55.0);

        let mut merged = NetStats::default();
        merged.merge(&m.stats);
        merged.merge(&m.stats);
        assert_eq!(merged.retries, 2);
        assert_eq!(merged.degraded_fetches, 2);
        assert_eq!(merged.total_us(), 110.0);
    }
}
