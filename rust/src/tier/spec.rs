//! Per-tier specification: capacity and transfer costs.

use anyhow::ensure;

use crate::Result;

/// One level of the expert-weight memory hierarchy.
///
/// Tiers are ordered fastest (index 0 = GPU VRAM) to slowest; an access
/// that misses every tier is charged the deepest tier's fetch cost (a
/// cold read from the backing store, which holds every expert).
#[derive(Debug, Clone)]
pub struct TierSpec {
    /// Human name for reports ("gpu", "host", "ssd").
    pub name: String,
    /// Experts this tier can hold.
    pub capacity_experts: usize,
    /// Modeled cost of serving one expert FROM this tier into GPU VRAM,
    /// in µs.  For tier 0 (the GPU itself) this is the in-VRAM hit cost.
    pub fetch_us_per_expert: f64,
    /// Modeled cost of writing one expert INTO this tier on demotion, in
    /// µs.  0 for tiers that already hold every expert persistently
    /// (flash backing store: demotion is just dropping the cached copy).
    pub writeback_us_per_expert: f64,
}

impl TierSpec {
    pub fn new(
        name: impl Into<String>,
        capacity_experts: usize,
        fetch_us_per_expert: f64,
        writeback_us_per_expert: f64,
    ) -> Self {
        Self {
            name: name.into(),
            capacity_experts,
            fetch_us_per_expert,
            writeback_us_per_expert,
        }
    }

    /// GPU VRAM: residency is the cache itself, a hit is ~free.
    pub fn gpu(capacity_experts: usize) -> Self {
        Self::new("gpu", capacity_experts, 2.0, 0.0)
    }

    /// Host (pinned) RAM behind PCIe 4.0 x16: one expert ≈ 1.4 ms, both
    /// directions (matches `CacheConfig::pcie_us_per_expert`).
    pub fn host(capacity_experts: usize) -> Self {
        Self::new("host", capacity_experts, 1400.0, 1400.0)
    }

    /// Edge flash/NVMe at ~2 GB/s sustained: one ~44 MB expert ≈ 22 ms.
    /// Weights live on flash permanently, so demotion writes nothing.
    pub fn ssd(capacity_experts: usize) -> Self {
        Self::new("ssd", capacity_experts, 22_000.0, 0.0)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "tier needs a name");
        ensure!(
            self.capacity_experts > 0,
            "tier {} capacity must be > 0",
            self.name
        );
        ensure!(
            self.fetch_us_per_expert >= 0.0,
            "tier {} has a negative fetch cost",
            self.name
        );
        ensure!(
            self.writeback_us_per_expert >= 0.0,
            "tier {} has a negative writeback cost",
            self.name
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canned_specs_validate() {
        TierSpec::gpu(172).validate().unwrap();
        TierSpec::host(432).validate().unwrap();
        TierSpec::ssd(1728).validate().unwrap();
    }

    #[test]
    fn zero_capacity_rejected() {
        assert!(TierSpec::gpu(0).validate().is_err());
        assert!(TierSpec::new("x", 4, -1.0, 0.0).validate().is_err());
    }
}
