//! Per-tier serve counters for the hierarchy.

/// Where lookups were served from, plus promotion/demotion traffic.
#[derive(Debug, Clone, Default)]
pub struct TierStats {
    /// Measured lookups served at each depth: `served[0]` = GPU hits,
    /// `served[d]` = found at depth `d` and promoted.
    pub served: Vec<u64>,
    /// Measured lookups that missed every tier (cold backing-store read).
    pub cold: u64,
    /// Demand promotions into the GPU tier (misses in the GPU sense).
    pub promotions: u64,
    /// Prefetch-driven promotions into the GPU tier.
    pub prefetch_promotions: u64,
    /// Evictions that landed one tier down.
    pub demotions: u64,
    /// Evictions that fell past the last tier (copy dropped).
    pub dropped: u64,
}

impl TierStats {
    pub fn new(n_tiers: usize) -> Self {
        Self {
            served: vec![0; n_tiers],
            ..Default::default()
        }
    }

    pub fn record_served(&mut self, depth: usize) {
        if depth >= self.served.len() {
            self.served.resize(depth + 1, 0);
        }
        self.served[depth] += 1;
    }

    /// Measured lookups across every tier plus cold reads.
    pub fn lookups(&self) -> u64 {
        self.served.iter().sum::<u64>() + self.cold
    }

    /// Fraction of lookups served from the GPU tier (Fig-7's y-axis).
    pub fn gpu_hit_rate(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            0.0
        } else {
            self.served.first().copied().unwrap_or(0) as f64 / n as f64
        }
    }

    /// Fraction of lookups that had to go below depth `d` (deep misses).
    pub fn below_rate(&self, d: usize) -> f64 {
        let n = self.lookups();
        if n == 0 {
            return 0.0;
        }
        let deep: u64 = self.served.iter().skip(d + 1).sum::<u64>() + self.cold;
        deep as f64 / n as f64
    }

    pub fn merge(&mut self, other: &TierStats) {
        if self.served.len() < other.served.len() {
            self.served.resize(other.served.len(), 0);
        }
        for (a, b) in self.served.iter_mut().zip(other.served.iter()) {
            *a += b;
        }
        self.cold += other.cold;
        self.promotions += other.promotions;
        self.prefetch_promotions += other.prefetch_promotions;
        self.demotions += other.demotions;
        self.dropped += other.dropped;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let mut s = TierStats::new(3);
        for _ in 0..6 {
            s.record_served(0);
        }
        s.record_served(1);
        s.record_served(1);
        s.record_served(2);
        s.cold = 1;
        assert_eq!(s.lookups(), 10);
        assert!((s.gpu_hit_rate() - 0.6).abs() < 1e-12);
        assert!((s.below_rate(0) - 0.4).abs() < 1e-12);
        assert!((s.below_rate(1) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_elementwise() {
        let mut a = TierStats::new(2);
        a.record_served(0);
        let mut b = TierStats::new(3);
        b.record_served(2);
        b.demotions = 4;
        a.merge(&b);
        assert_eq!(a.served, vec![1, 0, 1]);
        assert_eq!(a.demotions, 4);
    }
}
