//! Trace analysis — the statistics behind the paper's Figs 1-3 and the
//! §2.2 sparsity-insight reproduction (Contribution 1).

use crate::trace::schema::PromptTrace;
use crate::util::stats::entropy;
use crate::util::ExpertSet;

/// Fig 1: per-expert activation counts at one layer, aggregated across
/// many prompts.  The paper reports an even 800-1400 band over 122 prompts.
pub fn aggregate_layer_histogram(traces: &[PromptTrace], layer: usize, n_experts: usize) -> Vec<u64> {
    let mut counts = vec![0u64; n_experts];
    for tr in traces {
        for t in 0..tr.n_tokens() {
            for &e in tr.expert_ids(t, layer) {
                counts[e as usize] += 1;
            }
        }
    }
    counts
}

/// Fig 2: per-expert activation counts for a single prompt at one layer —
/// dramatically sparse, a handful of peaked experts.
pub fn single_prompt_histogram(tr: &PromptTrace, layer: usize, n_experts: usize) -> Vec<u64> {
    let mut counts = vec![0u64; n_experts];
    for t in 0..tr.n_tokens() {
        for &e in tr.expert_ids(t, layer) {
            counts[e as usize] += 1;
        }
    }
    counts
}

/// Fig 3: the full layer × expert activation heatmap for one prompt.
/// Row-major [n_layers][n_experts].
pub fn layer_expert_heatmap(tr: &PromptTrace, n_experts: usize) -> Vec<Vec<u64>> {
    (0..tr.n_layers as usize)
        .map(|l| single_prompt_histogram(tr, l, n_experts))
        .collect()
}

/// Summary of the sparsity insight for reporting.
#[derive(Debug, Clone)]
pub struct SparsityReport {
    /// Mean per-prompt working-set size at the probe layer.
    pub mean_working_set: f64,
    /// Aggregate histogram max/min ratio (uniformity; paper ~1.75).
    pub aggregate_ratio: f64,
    /// Mean single-prompt activation entropy (nats).
    pub mean_single_entropy: f64,
    /// Aggregate activation entropy (nats).
    pub aggregate_entropy: f64,
    /// Fraction of the expert pool a prompt touches on average.
    pub working_set_frac: f64,
}

/// Compute the §2.2 sparsity statistics at `layer`.
pub fn sparsity_report(traces: &[PromptTrace], layer: usize, n_experts: usize) -> SparsityReport {
    let agg = aggregate_layer_histogram(traces, layer, n_experts);
    let mut ws_sum = 0.0;
    let mut ent_sum = 0.0;
    for tr in traces {
        ws_sum += tr.layer_working_set(layer).len() as f64;
        ent_sum += entropy(&single_prompt_histogram(tr, layer, n_experts));
    }
    let n = traces.len().max(1) as f64;
    let min = *agg.iter().filter(|&&c| c > 0).min().unwrap_or(&1) as f64;
    let max = *agg.iter().max().unwrap_or(&1) as f64;
    SparsityReport {
        mean_working_set: ws_sum / n,
        aggregate_ratio: max / min.max(1.0),
        mean_single_entropy: ent_sum / n,
        aggregate_entropy: entropy(&agg),
        working_set_frac: ws_sum / n / n_experts as f64,
    }
}

/// Cross-layer reuse score for Fig 3's vertical bands: mean Jaccard
/// similarity between (permutation-adjusted) adjacent-layer working sets.
pub fn cross_layer_reuse(tr: &PromptTrace, layer_perm: &[i32], n_experts: usize) -> f64 {
    let l_n = tr.n_layers as usize;
    if l_n < 2 {
        return 1.0;
    }
    let mut total = 0.0;
    for l in 0..l_n - 1 {
        let a = tr.layer_working_set(l);
        let b = tr.layer_working_set(l + 1);
        // map layer-l ids through layer (l+1)'s permutation
        let mut mapped: ExpertSet = ExpertSet::new();
        for id in a.iter() {
            let m = layer_perm[(l + 1) * n_experts + id as usize];
            mapped.insert(m as u8);
        }
        total += mapped.jaccard(b);
    }
    total / (l_n - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace(points: &[(usize, usize, [u8; 2])]) -> PromptTrace {
        // build a 4-token, 2-layer, top-2 trace from (token, layer, ids)
        let mut experts = vec![0u8; 4 * 2 * 2];
        for &(t, l, ids) in points {
            experts[(t * 2 + l) * 2] = ids[0];
            experts[(t * 2 + l) * 2 + 1] = ids[1];
        }
        PromptTrace {
            prompt_id: 0,
            n_layers: 2,
            top_k: 2,
            d_emb: 0,
            tokens: vec![1, 2, 3, 4],
            embeddings: vec![],
            experts,
        }
    }

    #[test]
    fn histograms_count_correctly() {
        let tr = mk_trace(&[
            (0, 0, [1, 2]),
            (1, 0, [1, 3]),
            (2, 0, [1, 2]),
            (3, 0, [2, 3]),
        ]);
        let h = single_prompt_histogram(&tr, 0, 8);
        assert_eq!(h[1], 3);
        assert_eq!(h[2], 3);
        assert_eq!(h[3], 2);
        assert_eq!(h[0], 0); // layer 0 fully specified; zeros sit at layer 1
    }

    #[test]
    fn aggregate_sums_prompts() {
        let tr = mk_trace(&[(0, 0, [1, 2])]);
        let h = aggregate_layer_histogram(&[tr.clone(), tr], 0, 8);
        assert_eq!(h[1], 2);
        assert_eq!(h[2], 2);
    }

    #[test]
    fn heatmap_shape() {
        let tr = mk_trace(&[(0, 1, [5, 6])]);
        let hm = layer_expert_heatmap(&tr, 8);
        assert_eq!(hm.len(), 2);
        assert_eq!(hm[0].len(), 8);
        assert_eq!(hm[1][5], 1);
    }

    #[test]
    fn sparsity_report_on_skewed_trace() {
        let tr = mk_trace(&[
            (0, 0, [1, 2]),
            (1, 0, [1, 2]),
            (2, 0, [1, 2]),
            (3, 0, [1, 2]),
        ]);
        let r = sparsity_report(&[tr], 0, 8);
        assert!(r.mean_working_set <= 3.0);
        assert!(r.working_set_frac < 0.5);
    }

    #[test]
    fn cross_layer_reuse_identity_perm() {
        // same experts at both layers + identity permutation => reuse 1.0
        let tr = mk_trace(&[
            (0, 0, [1, 2]),
            (0, 1, [1, 2]),
            (1, 0, [1, 2]),
            (1, 1, [1, 2]),
            (2, 0, [1, 2]),
            (2, 1, [1, 2]),
            (3, 0, [1, 2]),
            (3, 1, [1, 2]),
        ]);
        let perm: Vec<i32> = (0..16).map(|i| (i % 8) as i32).collect();
        let r = cross_layer_reuse(&tr, &perm, 8);
        assert!((r - 1.0).abs() < 1e-9, "reuse {r}");
    }
}
