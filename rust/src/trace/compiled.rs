//! Compiled activation traces — the replay hot path's view of a corpus.
//!
//! `PromptTrace` stores raw expert-id bytes (`[n_tokens, n_layers,
//! top_k]` of `u8`), so every `expert_set(t, l)` call rebuilds a bitmask
//! from those bytes.  That is fine for one replay, but the sweep
//! harnesses visit the *same* (token, layer) cells once per grid point —
//! every Fig-7 capacity, every tiered surface cell, every `serve-sim`
//! load point — paying the rebuild each time.
//!
//! [`CompiledTrace`] packs the whole trace into one flat
//! `Vec<ExpertSet>` (8 bytes per cell), built once, so the inner loop's
//! `expert_set(t, l)` becomes a single indexed load.  [`CompiledCorpus`]
//! wraps a compiled trace list in an `Arc` so sweep and workload workers
//! share one copy across threads without re-compiling or cloning.

use std::sync::{Arc, Mutex};

use crate::cache::stackdist::{self, StackDistProfile};
use crate::trace::PromptTrace;
use crate::util::parallel::parallel_map;
use crate::util::ExpertSet;

/// One prompt's activation sets, packed row-major `[n_tokens, n_layers]`.
///
/// Generic over the [`ExpertSet`] word width `N` (default 1): an
/// `N`-word corpus packs `8 * N` bytes per cell.
#[derive(Debug, Clone)]
pub struct CompiledTrace<const N: usize = 1> {
    n_tokens: usize,
    n_layers: usize,
    sets: Vec<ExpertSet<N>>,
    max_set_len: u32,
}

impl<const N: usize> CompiledTrace<N> {
    /// Build the packed set table from the raw trace (one pass).
    pub fn compile(trace: &PromptTrace) -> Self {
        let n_tokens = trace.n_tokens();
        let n_layers = trace.n_layers as usize;
        let mut sets = Vec::with_capacity(n_tokens * n_layers);
        let mut max_set_len = 0u32;
        for t in 0..n_tokens {
            for l in 0..n_layers {
                let s = trace.expert_set_wide::<N>(t, l);
                max_set_len = max_set_len.max(s.len());
                sets.push(s);
            }
        }
        Self {
            n_tokens,
            n_layers,
            sets,
            max_set_len,
        }
    }

    /// Largest ground-truth set of any (token, layer) cell — the most
    /// lookups one layer execution can issue (the tiered analytic sweep
    /// bounds per-layer demotion DMA with this).
    #[inline]
    pub fn max_set_len(&self) -> u32 {
        self.max_set_len
    }

    #[inline]
    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    #[inline]
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Activated experts for (token, layer) — an indexed load, no
    /// per-visit rebuild from trace bytes.
    #[inline]
    pub fn set(&self, token: usize, layer: usize) -> ExpertSet<N> {
        self.sets[token * self.n_layers + layer]
    }

    /// Total expert activations across the trace (Σ |set(t, l)|) — the
    /// reference-stream length of one replay.
    pub fn total_activations(&self) -> usize {
        self.sets.iter().map(|s| s.len() as usize).sum()
    }
}

/// A compiled corpus shared across sweep/workload workers via `Arc`:
/// cloning is a refcount bump, dereferencing yields `&[CompiledTrace]`
/// parallel to the source trace slice.
///
/// The corpus also memoizes its stack-distance profiles
/// ([`stackdist_profile`](CompiledCorpus::stackdist_profile)): every
/// sweep that shares one `CompiledCorpus` (via `SweepInputs::compiled`)
/// shares the profiling pass too.
#[derive(Debug, Clone)]
pub struct CompiledCorpus<const N: usize = 1> {
    traces: Arc<[CompiledTrace<N>]>,
    /// Lazily-built corpus-level profiles keyed by the inputs that shape
    /// them; `Arc`-shared so clones reuse instead of re-profiling.
    profiles: Arc<Mutex<Vec<((usize, usize), Arc<StackDistProfile>)>>>,
}

impl<const N: usize> CompiledCorpus<N> {
    /// Compile every trace once (index-parallel to the input slice).
    pub fn compile(traces: &[PromptTrace]) -> Self {
        Self {
            traces: traces.iter().map(CompiledTrace::compile).collect(),
            profiles: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Largest ground-truth set of any cell across the corpus.
    pub fn max_set_len(&self) -> u32 {
        self.traces.iter().map(|t| t.max_set_len()).max().unwrap_or(0)
    }

    /// The corpus-level stack-distance profile for `(n_experts,
    /// warmup_tokens)`, built ONCE per key (each prompt profiled on the
    /// shared sweep workers, merged in index order — integer counters,
    /// so merge order cannot change the result) and memoized behind an
    /// `Arc`: `sweep_capacities*` and `sweep_tiered*` calls that share a
    /// corpus stop re-profiling it per call.
    pub fn stackdist_profile(
        &self,
        n_experts: usize,
        warmup_tokens: usize,
        threads: usize,
    ) -> Arc<StackDistProfile> {
        let key = (n_experts, warmup_tokens);
        // hold the lock across the build: a second caller with the same
        // key waits for the result instead of duplicating the pass
        let mut cache = self.profiles.lock().unwrap();
        if let Some((_, p)) = cache.iter().find(|(k, _)| *k == key) {
            return Arc::clone(p);
        }
        let per_prompt = parallel_map(&self.traces[..], threads, |ct| {
            let mut p = StackDistProfile::new();
            stackdist::profile_prompt(ct, n_experts, warmup_tokens, &mut p);
            Ok(p)
        })
        .expect("stack-distance profiling is infallible");
        let mut merged = StackDistProfile::new();
        for p in &per_prompt {
            merged.merge(p);
        }
        let arc = Arc::new(merged);
        cache.push((key, Arc::clone(&arc)));
        arc
    }
}

impl<const N: usize> std::ops::Deref for CompiledCorpus<N> {
    type Target = [CompiledTrace<N>];

    fn deref(&self) -> &[CompiledTrace<N>] {
        &self.traces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> PromptTrace {
        PromptTrace {
            prompt_id: 1,
            n_layers: 3,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0, 1],
            embeddings: vec![],
            experts: vec![
                0, 1, 2, 3, 4, 5, // token 0
                0, 2, 2, 4, 4, 6, // token 1
            ],
        }
    }

    #[test]
    fn compiled_matches_raw_sets() {
        let tr = trace();
        let ct: CompiledTrace = CompiledTrace::compile(&tr);
        assert_eq!(ct.n_tokens(), tr.n_tokens());
        assert_eq!(ct.n_layers(), tr.n_layers as usize);
        for t in 0..tr.n_tokens() {
            for l in 0..tr.n_layers as usize {
                assert_eq!(ct.set(t, l), tr.expert_set(t, l));
            }
        }
        assert_eq!(ct.total_activations(), 12);
    }

    #[test]
    fn corpus_is_shared_not_copied() {
        let traces = vec![trace(), trace()];
        let corpus: CompiledCorpus = CompiledCorpus::compile(&traces);
        let clone = corpus.clone();
        assert_eq!(corpus.len(), 2);
        assert!(std::ptr::eq(&corpus[0], &clone[0]), "clone must share the Arc");
        assert_eq!(corpus[1].set(1, 2), traces[1].expert_set(1, 2));
    }

    #[test]
    fn max_set_len_tracks_dedup() {
        let tr = trace();
        let ct: CompiledTrace = CompiledTrace::compile(&tr);
        // token 1 layer 1 is {2, 4} after dedup of (2, 4); the densest
        // cell in this trace is the top-2 pair
        assert_eq!(ct.max_set_len(), 2);
        let corpus: CompiledCorpus = CompiledCorpus::compile(&[tr]);
        assert_eq!(corpus.max_set_len(), 2);
    }

    /// `stackdist_profile` is built once per (n_experts, warmup) key and
    /// shared across clones; distinct keys get distinct profiles.
    #[test]
    fn stackdist_profile_is_memoized_per_key() {
        let traces = vec![trace(), trace()];
        let corpus: CompiledCorpus = CompiledCorpus::compile(&traces);
        let clone = corpus.clone();
        let a = corpus.stackdist_profile(8, 0, 1);
        let b = clone.stackdist_profile(8, 0, 2);
        assert!(Arc::ptr_eq(&a, &b), "same key must reuse the cached Arc");
        let c = corpus.stackdist_profile(8, 1, 1);
        assert!(!Arc::ptr_eq(&a, &c), "different warm-up is a different profile");
        assert!(c.measured < a.measured);

        // the memoized profile equals a direct per-prompt merge
        let mut direct = crate::cache::StackDistProfile::new();
        for ct in corpus.iter() {
            stackdist::profile_prompt(ct, 8, 0, &mut direct);
        }
        assert_eq!(a.measured, direct.measured);
        assert_eq!(a.cold, direct.cold);
        for cap in 1..20 {
            assert_eq!(a.hits_at(cap), direct.hits_at(cap));
        }
    }

    /// Seeded-random equivalence over irregular shapes.
    #[test]
    fn prop_compiled_equivalence() {
        let mut rng = crate::util::Rng::new(71);
        for _ in 0..60 {
            let n_tokens = rng.range(1, 30);
            let n_layers = rng.range(1, 6) as u16;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * n_layers as usize {
                let a = rng.below(64) as u8;
                experts.push(a);
                experts.push((a + 1 + rng.below(62) as u8) % 64);
            }
            let tr = PromptTrace {
                prompt_id: 0,
                n_layers,
                top_k: 2,
                d_emb: 0,
                tokens: vec![0; n_tokens],
                embeddings: vec![],
                experts,
            };
            let ct: CompiledTrace = CompiledTrace::compile(&tr);
            for t in 0..n_tokens {
                for l in 0..n_layers as usize {
                    assert_eq!(ct.set(t, l), tr.expert_set(t, l));
                }
            }
        }
    }
}
