//! Compiled activation traces — the replay hot path's view of a corpus.
//!
//! `PromptTrace` stores raw expert-id bytes (`[n_tokens, n_layers,
//! top_k]` of `u8`), so every `expert_set(t, l)` call rebuilds a bitmask
//! from those bytes.  That is fine for one replay, but the sweep
//! harnesses visit the *same* (token, layer) cells once per grid point —
//! every Fig-7 capacity, every tiered surface cell, every `serve-sim`
//! load point — paying the rebuild each time.
//!
//! [`CompiledTrace`] packs the whole trace into one flat
//! `Vec<ExpertSet>` (8 bytes per cell), built once, so the inner loop's
//! `expert_set(t, l)` becomes a single indexed load.  [`CompiledCorpus`]
//! wraps a compiled trace list in an `Arc` so sweep and workload workers
//! share one copy across threads without re-compiling or cloning.

use std::sync::Arc;

use crate::trace::PromptTrace;
use crate::util::ExpertSet;

/// One prompt's activation sets, packed row-major `[n_tokens, n_layers]`.
#[derive(Debug, Clone)]
pub struct CompiledTrace {
    n_tokens: usize,
    n_layers: usize,
    sets: Vec<ExpertSet>,
}

impl CompiledTrace {
    /// Build the packed set table from the raw trace (one pass).
    pub fn compile(trace: &PromptTrace) -> Self {
        let n_tokens = trace.n_tokens();
        let n_layers = trace.n_layers as usize;
        let mut sets = Vec::with_capacity(n_tokens * n_layers);
        for t in 0..n_tokens {
            for l in 0..n_layers {
                sets.push(trace.expert_set(t, l));
            }
        }
        Self {
            n_tokens,
            n_layers,
            sets,
        }
    }

    #[inline]
    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    #[inline]
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Activated experts for (token, layer) — an indexed load, no
    /// per-visit rebuild from trace bytes.
    #[inline]
    pub fn set(&self, token: usize, layer: usize) -> ExpertSet {
        self.sets[token * self.n_layers + layer]
    }

    /// Total expert activations across the trace (Σ |set(t, l)|) — the
    /// reference-stream length of one replay.
    pub fn total_activations(&self) -> usize {
        self.sets.iter().map(|s| s.len() as usize).sum()
    }
}

/// A compiled corpus shared across sweep/workload workers via `Arc`:
/// cloning is a refcount bump, dereferencing yields `&[CompiledTrace]`
/// parallel to the source trace slice.
#[derive(Debug, Clone)]
pub struct CompiledCorpus {
    traces: Arc<[CompiledTrace]>,
}

impl CompiledCorpus {
    /// Compile every trace once (index-parallel to the input slice).
    pub fn compile(traces: &[PromptTrace]) -> Self {
        Self {
            traces: traces.iter().map(CompiledTrace::compile).collect(),
        }
    }
}

impl std::ops::Deref for CompiledCorpus {
    type Target = [CompiledTrace];

    fn deref(&self) -> &[CompiledTrace] {
        &self.traces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> PromptTrace {
        PromptTrace {
            prompt_id: 1,
            n_layers: 3,
            top_k: 2,
            d_emb: 0,
            tokens: vec![0, 1],
            embeddings: vec![],
            experts: vec![
                0, 1, 2, 3, 4, 5, // token 0
                0, 2, 2, 4, 4, 6, // token 1
            ],
        }
    }

    #[test]
    fn compiled_matches_raw_sets() {
        let tr = trace();
        let ct = CompiledTrace::compile(&tr);
        assert_eq!(ct.n_tokens(), tr.n_tokens());
        assert_eq!(ct.n_layers(), tr.n_layers as usize);
        for t in 0..tr.n_tokens() {
            for l in 0..tr.n_layers as usize {
                assert_eq!(ct.set(t, l), tr.expert_set(t, l));
            }
        }
        assert_eq!(ct.total_activations(), 12);
    }

    #[test]
    fn corpus_is_shared_not_copied() {
        let traces = vec![trace(), trace()];
        let corpus = CompiledCorpus::compile(&traces);
        let clone = corpus.clone();
        assert_eq!(corpus.len(), 2);
        assert!(std::ptr::eq(&corpus[0], &clone[0]), "clone must share the Arc");
        assert_eq!(corpus[1].set(1, 2), traces[1].expert_set(1, 2));
    }

    /// Seeded-random equivalence over irregular shapes.
    #[test]
    fn prop_compiled_equivalence() {
        let mut rng = crate::util::Rng::new(71);
        for _ in 0..60 {
            let n_tokens = rng.range(1, 30);
            let n_layers = rng.range(1, 6) as u16;
            let mut experts = Vec::new();
            for _ in 0..n_tokens * n_layers as usize {
                let a = rng.below(64) as u8;
                experts.push(a);
                experts.push((a + 1 + rng.below(62) as u8) % 64);
            }
            let tr = PromptTrace {
                prompt_id: 0,
                n_layers,
                top_k: 2,
                d_emb: 0,
                tokens: vec![0; n_tokens],
                embeddings: vec![],
                experts,
            };
            let ct = CompiledTrace::compile(&tr);
            for t in 0..n_tokens {
                for l in 0..n_layers as usize {
                    assert_eq!(ct.set(t, l), tr.expert_set(t, l));
                }
            }
        }
    }
}
