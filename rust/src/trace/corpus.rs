//! Synthetic prompt corpus — Rust mirror of `world.py::PromptSampler`.
//!
//! Streams need not be bit-identical with numpy's; the contract is
//! *distributional*: topic-mixture prompts with multi-turn segment
//! structure, deck-balanced primary topics, and a held-out-topic-weighted
//! test split (the Puffin -> WebGLM-QA domain shift).

use crate::trace::WorldModel;
use crate::util::Rng;

/// Corpus parameters (mirrors `CorpusConfig`).
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    pub min_tokens: usize,
    pub max_tokens: usize,
    pub max_topics_per_prompt: usize,
    pub common_token_prob: f64,
    pub test_split: bool,
    pub held_out_frac: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            seed: 7,
            min_tokens: 48,
            max_tokens: 200,
            max_topics_per_prompt: 3,
            common_token_prob: 0.22,
            test_split: false,
            held_out_frac: 0.25,
        }
    }
}

/// A sampled prompt: token ids + its latent topic mixture.
#[derive(Debug, Clone)]
pub struct Prompt {
    pub tokens: Vec<i32>,
    pub topics: Vec<(usize, f64)>, // (topic id, weight)
}

/// Prompt sampler over a loaded world.
pub struct PromptSampler<'w> {
    world: &'w WorldModel,
    cfg: CorpusConfig,
    rng: Rng,
    deck: Vec<usize>,
    held_out: Vec<usize>,
    main: Vec<usize>,
    common_pool: Vec<i32>,
    topic_pools: Vec<Vec<i32>>,
}

impl<'w> PromptSampler<'w> {
    pub fn new(world: &'w WorldModel, cfg: CorpusConfig) -> Self {
        let k = world.meta.n_topics as usize;
        let n_held = ((k as f64 * cfg.held_out_frac) as usize).max(1);
        let held_out: Vec<usize> = (k - n_held..k).collect();
        let main: Vec<usize> = (0..k - n_held).collect();

        let mut common_pool = Vec::new();
        let mut topic_pools = vec![Vec::new(); k];
        for (tok, &topic) in world.token_topic.iter().enumerate() {
            if topic < 0 {
                common_pool.push(tok as i32);
            } else {
                topic_pools[topic as usize].push(tok as i32);
            }
        }
        let seed = world.meta.seed
            .wrapping_mul(1_000_003)
            ^ cfg.seed.wrapping_mul(97).wrapping_add(cfg.test_split as u64);
        Self {
            world,
            rng: Rng::new(seed),
            cfg,
            deck: Vec::new(),
            held_out,
            main,
            common_pool,
            topic_pools,
        }
    }

    fn next_from_deck(&mut self) -> usize {
        // main topics at fair share, held-out at ~1/3 of fair share
        // (mirrors world.py::PromptSampler, see its comment)
        if self.deck.is_empty() {
            let mut deck: Vec<usize> = Vec::new();
            for _ in 0..3 {
                deck.extend(&self.main);
            }
            deck.extend(&self.held_out);
            self.rng.shuffle(&mut deck);
            self.deck = deck;
        }
        self.deck.pop().unwrap()
    }

    fn draw_topics(&mut self) -> Vec<usize> {
        let n = self.rng.range(1, self.cfg.max_topics_per_prompt + 1);
        if self.cfg.test_split {
            // test prompts mix held-out topics EXCLUSIVELY (the
            // Puffin -> WebGLM-QA domain shift)
            let n = n.min(self.held_out.len());
            let mut out = Vec::new();
            while out.len() < n {
                let t = *self.rng.choose(&self.held_out);
                if !out.contains(&t) {
                    out.push(t);
                }
            }
            return out;
        }
        let primary = self.next_from_deck();
        let mut out = vec![primary];
        while out.len() < n {
            let t = self.rng.below(self.world.meta.n_topics as usize);
            if !out.contains(&t) {
                out.push(t);
            }
        }
        out
    }

    /// Sample one prompt (token ids + topic mixture).
    pub fn sample(&mut self) -> Prompt {
        let topics = self.draw_topics();
        let weights = self.rng.dirichlet(2.0, topics.len());
        let t_total = self.rng.range(self.cfg.min_tokens, self.cfg.max_tokens + 1);

        let mut tokens = Vec::with_capacity(t_total);
        while tokens.len() < t_total {
            // multi-turn: 8-24 token segments biased to one mixture topic
            let seg = self.rng.range(8, 25);
            let t_idx = self.rng.choose_weighted(&weights);
            let pool_id = topics[t_idx];
            for _ in 0..seg {
                if tokens.len() >= t_total {
                    break;
                }
                let tok = if self.rng.f64() < self.cfg.common_token_prob
                    || self.topic_pools[pool_id].is_empty()
                {
                    *self.rng.choose(&self.common_pool)
                } else {
                    *self.rng.choose(&self.topic_pools[pool_id])
                };
                tokens.push(tok);
            }
        }
        Prompt {
            tokens,
            topics: topics.into_iter().zip(weights).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> Option<WorldModel> {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/world.json");
        p.exists().then(|| WorldModel::load(&p).unwrap())
    }

    #[test]
    fn prompts_in_bounds() {
        let Some(w) = world() else { return };
        let mut s = PromptSampler::new(&w, CorpusConfig::default());
        for _ in 0..20 {
            let p = s.sample();
            assert!(p.tokens.len() >= 48 && p.tokens.len() <= 200);
            assert!(p.tokens.iter().all(|&t| t >= 0 && (t as u32) < w.meta.vocab_size));
            let wsum: f64 = p.topics.iter().map(|(_, w)| w).sum();
            assert!((wsum - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn test_split_prefers_held_out() {
        let Some(w) = world() else { return };
        let k = w.meta.n_topics as usize;
        let held_start = k - (k as f64 * 0.25) as usize;
        let mass = |test: bool| {
            let mut s = PromptSampler::new(
                &w,
                CorpusConfig {
                    test_split: test,
                    ..Default::default()
                },
            );
            let mut m = 0.0;
            for _ in 0..80 {
                for (t, wgt) in s.sample().topics {
                    if t >= held_start {
                        m += wgt;
                    }
                }
            }
            m / 80.0
        };
        assert!(mass(true) > mass(false) + 0.2);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(w) = world() else { return };
        let mut a = PromptSampler::new(&w, CorpusConfig::default());
        let mut b = PromptSampler::new(&w, CorpusConfig::default());
        for _ in 0..5 {
            assert_eq!(a.sample().tokens, b.sample().tokens);
        }
    }
}
