//! CSV trace logging — the paper's §4.1.4 interchange ("For every prompt
//! we run DeepSeek-V2-Lite once and log, to a CSV file, each Layer ID
//! together with the list of Activated Expert IDs").
//!
//! Format (one row per (prompt, token, layer) point):
//!
//! ```text
//! prompt_id,token_idx,token,layer_id,expert_ids
//! 42,0,1017,0,"3;17;22;40;51;60"
//! ```
//!
//! Embeddings are not representable in this format (the paper stores them
//! separately too); round-tripping through CSV preserves everything else.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::trace::schema::{PromptTrace, TraceMeta};
use crate::Result;

/// Write traces as CSV (header + one row per trace point).
pub fn write_csv<P: AsRef<Path>>(path: P, traces: &[PromptTrace]) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "prompt_id,token_idx,token,layer_id,expert_ids")?;
    for tr in traces {
        for t in 0..tr.n_tokens() {
            for l in 0..tr.n_layers as usize {
                let ids: Vec<String> = tr
                    .expert_ids(t, l)
                    .iter()
                    .map(|e| e.to_string())
                    .collect();
                writeln!(
                    w,
                    "{},{},{},{},\"{}\"",
                    tr.prompt_id,
                    t,
                    tr.tokens[t],
                    l,
                    ids.join(";")
                )?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a CSV trace file back (embeddings come back empty).
pub fn read_csv<P: AsRef<Path>>(path: P, meta: &TraceMeta) -> Result<Vec<PromptTrace>> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut lines = BufReader::new(f).lines();
    match lines.next() {
        Some(Ok(h)) if h.trim() == "prompt_id,token_idx,token,layer_id,expert_ids" => {}
        _ => bail!("bad CSV header"),
    }

    let (l_n, k_n) = (meta.n_layers as usize, meta.top_k as usize);
    let mut traces: Vec<PromptTrace> = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.splitn(5, ',');
        let pid: u32 = parts.next().context("pid")?.parse()?;
        let t: usize = parts.next().context("token_idx")?.parse()?;
        let tok: i32 = parts.next().context("token")?.parse()?;
        let l: usize = parts.next().context("layer")?.parse()?;
        let ids_raw = parts.next().context("expert_ids")?.trim();
        let ids_raw = ids_raw
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .context("expert_ids not quoted")?;
        let ids: Vec<u8> = ids_raw
            .split(';')
            .map(|s| s.parse::<u8>().context("expert id"))
            .collect::<Result<_>>()?;
        ensure!(ids.len() == k_n, "expected {k_n} experts, got {}", ids.len());
        ensure!(l < l_n, "layer {l} out of range");

        // rows arrive prompt-major, token-major, layer-major
        if traces.last().map(|tr| tr.prompt_id) != Some(pid) {
            traces.push(PromptTrace {
                prompt_id: pid,
                n_layers: meta.n_layers,
                top_k: meta.top_k,
                d_emb: 0,
                tokens: Vec::new(),
                embeddings: Vec::new(),
                experts: Vec::new(),
            });
        }
        let tr = traces.last_mut().unwrap();
        if tr.tokens.len() == t {
            tr.tokens.push(tok);
            tr.experts.resize(tr.experts.len() + l_n * k_n, 0);
        }
        ensure!(t < tr.tokens.len(), "token rows out of order");
        let base = (t * l_n + l) * k_n;
        tr.experts[base..base + k_n].copy_from_slice(&ids);
    }
    Ok(traces)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> TraceMeta {
        TraceMeta {
            n_layers: 3,
            n_experts: 64,
            top_k: 2,
            d_emb: 0,
            has_embeddings: false,
        }
    }

    fn sample() -> PromptTrace {
        PromptTrace {
            prompt_id: 42,
            n_layers: 3,
            top_k: 2,
            d_emb: 0,
            tokens: vec![10, 11],
            embeddings: vec![],
            experts: vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12],
        }
    }

    #[test]
    fn roundtrip() {
        let p = std::env::temp_dir().join("moeb_csv_test.csv");
        let traces = vec![sample()];
        write_csv(&p, &traces).unwrap();
        let back = read_csv(&p, &meta()).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].prompt_id, 42);
        assert_eq!(back[0].tokens, traces[0].tokens);
        assert_eq!(back[0].experts, traces[0].experts);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn header_is_paper_schema() {
        let p = std::env::temp_dir().join("moeb_csv_test2.csv");
        write_csv(&p, &[sample()]).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.starts_with("prompt_id,token_idx,token,layer_id,expert_ids"));
        assert!(content.contains("42,0,10,0,\"1;2\""));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = std::env::temp_dir().join("moeb_csv_test3.csv");
        std::fs::write(&p, "not,a,real,header\n").unwrap();
        assert!(read_csv(&p, &meta()).is_err());
        std::fs::remove_file(p).ok();
    }
}
