//! Analytic trace generator — Rust mirror of
//! `tracegen.py::sample_prompt_trace`: sample a prompt from the corpus,
//! run the EMA routing context over its embeddings, and draw
//! gumbel-perturbed top-k expert activations per (token, layer).
//!
//! Used for large-scale workload sweeps (the Python side only materializes
//! the splits training needs) and by property tests; the distribution is
//! identical to the Python sampler because both consume the same
//! `world.bin` tensors.

use crate::trace::corpus::{CorpusConfig, Prompt, PromptSampler};
use crate::trace::schema::{PromptTrace, TraceMeta};
use crate::trace::WorldModel;
use crate::util::Rng;

/// Generates `PromptTrace`s from the world model.
pub struct TraceGenerator<'w> {
    world: &'w WorldModel,
    sampler: PromptSampler<'w>,
    rng: Rng,
    next_id: u32,
}

impl<'w> TraceGenerator<'w> {
    pub fn new(world: &'w WorldModel, corpus: CorpusConfig, seed: u64) -> Self {
        Self {
            world,
            sampler: PromptSampler::new(world, corpus),
            rng: Rng::new(seed),
            next_id: 0,
        }
    }

    pub fn meta(&self) -> TraceMeta {
        TraceMeta {
            n_layers: self.world.meta.n_layers,
            n_experts: self.world.meta.n_experts,
            top_k: self.world.meta.top_k,
            d_emb: self.world.meta.d_model,
            has_embeddings: true,
        }
    }

    /// Trace the given prompt through the analytic router.
    pub fn trace_prompt(&mut self, prompt: &Prompt) -> PromptTrace {
        let w = self.world;
        let (l_n, k_n, d) = (w.n_layers(), w.top_k(), w.d_model());
        let n = prompt.tokens.len();

        let mut embeddings = Vec::with_capacity(n * d);
        let mut experts = Vec::with_capacity(n * l_n * k_n);
        let mut ctx = w.token_embedding(prompt.tokens[0]).to_vec();
        let beta = w.meta.route_beta.unwrap_or(0.6) as f32;
        let mut route = vec![0.0f32; d];

        for (t, &tok) in prompt.tokens.iter().enumerate() {
            let emb = w.token_embedding(tok);
            embeddings.extend_from_slice(emb);
            if t == 0 {
                ctx.copy_from_slice(emb);
                crate::util::math::normalize(&mut ctx);
            } else {
                w.context_step(&mut ctx, emb);
            }
            // routing vector: token-embedding/context blend (world.py)
            for i in 0..d {
                route[i] = beta * emb[i] + (1.0 - beta) * ctx[i];
            }
            crate::util::math::normalize(&mut route);
            for layer in 0..l_n {
                experts.extend(w.sample_topk(&route, layer, &mut self.rng));
            }
        }

        let id = self.next_id;
        self.next_id += 1;
        PromptTrace {
            prompt_id: id,
            n_layers: w.meta.n_layers,
            top_k: w.meta.top_k,
            d_emb: w.meta.d_model,
            tokens: prompt.tokens.clone(),
            embeddings,
            experts,
        }
    }

    /// Sample + trace `n` fresh prompts.
    pub fn generate(&mut self, n: usize) -> Vec<PromptTrace> {
        (0..n)
            .map(|_| {
                let p = self.sampler.sample();
                self.trace_prompt(&p)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::entropy;

    fn world() -> Option<WorldModel> {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/world.json");
        p.exists().then(|| WorldModel::load(&p).unwrap())
    }

    #[test]
    fn generated_traces_are_well_formed() {
        let Some(w) = world() else { return };
        let mut g = TraceGenerator::new(&w, CorpusConfig::default(), 11);
        for tr in g.generate(3) {
            assert_eq!(tr.embeddings.len(), tr.n_tokens() * tr.d_emb as usize);
            assert_eq!(
                tr.experts.len(),
                tr.n_tokens() * tr.n_layers as usize * tr.top_k as usize
            );
            // unique top-k per point
            for t in (0..tr.n_tokens()).step_by(13) {
                for l in (0..tr.n_layers as usize).step_by(9) {
                    assert_eq!(tr.expert_set(t, l).len() as usize, tr.top_k as usize);
                }
            }
        }
    }

    #[test]
    fn rust_traces_match_python_statistics() {
        // The core no-drift check: single-prompt working sets and
        // activation entropy from the Rust generator must look like the
        // Python-generated artifact traces.
        let Some(w) = world() else { return };
        let arts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/traces/val.bin");
        if !arts.exists() {
            return;
        }
        let py = crate::trace::store::read_traces(&arts).unwrap();
        let mut g = TraceGenerator::new(&w, CorpusConfig::default(), 5);
        let rs = g.generate(py.len().min(20));

        let ws_mean = |trs: &[PromptTrace]| {
            trs.iter()
                .map(|t| t.layer_working_set(13).len() as f64)
                .sum::<f64>()
                / trs.len() as f64
        };
        let (a, b) = (ws_mean(&rs), ws_mean(&py[..rs.len().min(py.len())]));
        assert!(
            (a - b).abs() < 8.0,
            "working-set drift: rust {a:.1} vs python {b:.1}"
        );

        let ent = |trs: &[PromptTrace]| {
            let mut counts = vec![0u64; 64];
            for tr in trs {
                for t in 0..tr.n_tokens() {
                    for &e in tr.expert_ids(t, 13) {
                        counts[e as usize] += 1;
                    }
                }
            }
            entropy(&counts)
        };
        assert!((ent(&rs) - ent(&py[..rs.len().min(py.len())])).abs() < 0.5);
    }

    #[test]
    fn deterministic() {
        let Some(w) = world() else { return };
        let t1 = TraceGenerator::new(&w, CorpusConfig::default(), 42).generate(2);
        let t2 = TraceGenerator::new(&w, CorpusConfig::default(), 42).generate(2);
        assert_eq!(t1[0].experts, t2[0].experts);
        assert_eq!(t1[1].tokens, t2[1].tokens);
    }
}
