//! Expert-activation trace substrate: schema, binary store (MBTR, shared
//! with the Python compile path), the synthetic-world loader + workload
//! generator, the packed replay tables ([`compiled`]) behind the batched
//! simulator hot path, and the statistics behind the paper's Figs 1-3.

pub mod analysis;
pub mod compiled;
pub mod corpus;
pub mod csv;
pub mod generator;
pub mod schema;
pub mod store;
pub mod world;

pub use compiled::{CompiledCorpus, CompiledTrace};
pub use schema::{PromptTrace, TraceMeta};
pub use world::WorldModel;
