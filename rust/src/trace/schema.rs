//! Trace schema — the paper's per-token record (§4.1.2): layer id, token,
//! activated expert ids, token embedding.

use crate::util::ExpertSet;

/// Per-file metadata (mirrors the MBTR header).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceMeta {
    pub n_layers: u16,
    pub n_experts: u16,
    pub top_k: u16,
    pub d_emb: u16,
    pub has_embeddings: bool,
}

/// One prompt's full activation trace.
///
/// `experts[t * n_layers * top_k ..]` stores the activated expert ids for
/// token `t`, layer-major, exactly top_k per (token, layer).
#[derive(Debug, Clone)]
pub struct PromptTrace {
    pub prompt_id: u32,
    pub n_layers: u16,
    pub top_k: u16,
    pub d_emb: u16,
    pub tokens: Vec<i32>,
    /// Row-major [n_tokens, d_emb]; empty if the file had no embeddings.
    pub embeddings: Vec<f32>,
    /// Row-major [n_tokens, n_layers, top_k].
    pub experts: Vec<u8>,
}

impl PromptTrace {
    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// The `top_k` expert ids activated for (token, layer).
    #[inline]
    pub fn expert_ids(&self, token: usize, layer: usize) -> &[u8] {
        let k = self.top_k as usize;
        let l = self.n_layers as usize;
        let base = (token * l + layer) * k;
        &self.experts[base..base + k]
    }

    /// Activated experts for (token, layer) as a bitset.
    #[inline]
    pub fn expert_set(&self, token: usize, layer: usize) -> ExpertSet {
        self.expert_set_wide::<1>(token, layer)
    }

    /// Width-generic variant of [`expert_set`](Self::expert_set) for
    /// traces over more than 64 experts (`N` words = `64 * N` ids).
    #[inline]
    pub fn expert_set_wide<const N: usize>(&self, token: usize, layer: usize) -> ExpertSet<N> {
        ExpertSet::from_ids(self.expert_ids(token, layer).iter().copied())
    }

    /// Token embedding row (empty slice if embeddings were not stored).
    #[inline]
    pub fn embedding(&self, token: usize) -> &[f32] {
        let d = self.d_emb as usize;
        if self.embeddings.is_empty() {
            return &[];
        }
        &self.embeddings[token * d..(token + 1) * d]
    }

    /// Union of experts activated at `layer` across the whole prompt —
    /// the prompt's working set at that layer (Fig 2).
    pub fn layer_working_set(&self, layer: usize) -> ExpertSet {
        self.layer_working_set_wide::<1>(layer)
    }

    /// Width-generic variant of
    /// [`layer_working_set`](Self::layer_working_set).
    pub fn layer_working_set_wide<const N: usize>(&self, layer: usize) -> ExpertSet<N> {
        let mut s = ExpertSet::new();
        for t in 0..self.n_tokens() {
            s = s.union(self.expert_set_wide(t, layer));
        }
        s
    }

    /// Total (token, layer) trace points.
    pub fn trace_points(&self) -> usize {
        self.n_tokens() * self.n_layers as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_trace() -> PromptTrace {
        // 2 tokens, 3 layers, top-2
        PromptTrace {
            prompt_id: 7,
            n_layers: 3,
            top_k: 2,
            d_emb: 4,
            tokens: vec![10, 11],
            embeddings: (0..8).map(|x| x as f32).collect(),
            experts: vec![
                0, 1, 2, 3, 4, 5, // token 0: layers (0,1),(2,3),(4,5)
                0, 2, 2, 4, 4, 6, // token 1
            ],
        }
    }

    #[test]
    fn expert_indexing() {
        let tr = tiny_trace();
        assert_eq!(tr.expert_ids(0, 0), &[0, 1]);
        assert_eq!(tr.expert_ids(0, 2), &[4, 5]);
        assert_eq!(tr.expert_ids(1, 1), &[2, 4]);
        assert_eq!(tr.expert_set(1, 0).to_vec(), vec![0, 2]);
    }

    #[test]
    fn working_set_unions_layers() {
        let tr = tiny_trace();
        assert_eq!(tr.layer_working_set(0).to_vec(), vec![0, 1, 2]);
        assert_eq!(tr.layer_working_set(2).to_vec(), vec![4, 5, 6]);
    }

    #[test]
    fn embedding_rows() {
        let tr = tiny_trace();
        assert_eq!(tr.embedding(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(tr.embedding(1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(tr.trace_points(), 6);
    }
}
