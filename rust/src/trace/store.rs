//! MBTR binary trace store — byte-compatible with `python/compile/tracegen.py`.
//!
//! Layout (little endian):
//! ```text
//! header:  magic   u32 = 0x4D425452
//!          version u32 = 1
//!          n_layers u16, n_experts u16, top_k u16, d_emb u16
//!          n_prompts u32
//!          flags    u32  (bit0: embeddings present)
//! per prompt:
//!          prompt_id u32, n_tokens u32
//!          tokens      i32 [n_tokens]
//!          embeddings  f32 [n_tokens * d_emb]   (iff flags & 1)
//!          experts     u8  [n_tokens * n_layers * top_k]
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{ensure, Context};

use super::schema::{PromptTrace, TraceMeta};
use crate::Result;

pub const MAGIC: u32 = 0x4D42_5452;
pub const VERSION: u32 = 1;

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

/// Read every prompt trace in a MBTR file.
pub fn read_traces<P: AsRef<Path>>(path: P) -> Result<Vec<PromptTrace>> {
    let (_, traces) = read_traces_with_meta(path)?;
    Ok(traces)
}

/// Read a MBTR file, returning header metadata + traces.
pub fn read_traces_with_meta<P: AsRef<Path>>(path: P) -> Result<(TraceMeta, Vec<PromptTrace>)> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).with_context(|| format!("opening trace file {path:?}"))?;
    let mut r = BufReader::new(f);

    let magic = read_u32(&mut r)?;
    ensure!(magic == MAGIC, "bad magic {magic:#x} in {path:?}");
    let version = read_u32(&mut r)?;
    ensure!(version == VERSION, "unsupported trace version {version}");
    let n_layers = read_u16(&mut r)?;
    let n_experts = read_u16(&mut r)?;
    let top_k = read_u16(&mut r)?;
    let d_emb = read_u16(&mut r)?;
    let n_prompts = read_u32(&mut r)?;
    let flags = read_u32(&mut r)?;
    let has_emb = flags & 1 == 1;
    ensure!(
        n_experts as usize <= crate::util::MAX_EXPERTS,
        "n_experts {n_experts} > {} unsupported (u8 expert ids, {}-word ExpertSet max)",
        crate::util::MAX_EXPERTS,
        crate::util::N_MAX
    );

    let meta = TraceMeta {
        n_layers,
        n_experts,
        top_k,
        d_emb,
        has_embeddings: has_emb,
    };

    let mut traces = Vec::with_capacity(n_prompts as usize);
    for _ in 0..n_prompts {
        let prompt_id = read_u32(&mut r)?;
        let n_tokens = read_u32(&mut r)? as usize;

        let mut tok_bytes = vec![0u8; n_tokens * 4];
        r.read_exact(&mut tok_bytes)?;
        let tokens: Vec<i32> = tok_bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let embeddings = if has_emb {
            let mut eb = vec![0u8; n_tokens * d_emb as usize * 4];
            r.read_exact(&mut eb)?;
            eb.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        } else {
            Vec::new()
        };

        let mut experts = vec![0u8; n_tokens * n_layers as usize * top_k as usize];
        r.read_exact(&mut experts)?;
        for &e in &experts {
            ensure!(
                (e as u16) < n_experts,
                "expert id {e} out of range in {path:?}"
            );
        }

        traces.push(PromptTrace {
            prompt_id,
            n_layers,
            top_k,
            d_emb,
            tokens,
            embeddings,
            experts,
        });
    }
    Ok((meta, traces))
}

/// Write traces in MBTR format (exactly what tracegen.py reads back).
pub fn write_traces<P: AsRef<Path>>(
    path: P,
    meta: &TraceMeta,
    traces: &[PromptTrace],
) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&meta.n_layers.to_le_bytes())?;
    w.write_all(&meta.n_experts.to_le_bytes())?;
    w.write_all(&meta.top_k.to_le_bytes())?;
    w.write_all(&meta.d_emb.to_le_bytes())?;
    w.write_all(&(traces.len() as u32).to_le_bytes())?;
    w.write_all(&(meta.has_embeddings as u32).to_le_bytes())?;
    for tr in traces {
        ensure!(tr.n_layers == meta.n_layers && tr.top_k == meta.top_k, "trace/meta mismatch");
        w.write_all(&tr.prompt_id.to_le_bytes())?;
        w.write_all(&(tr.tokens.len() as u32).to_le_bytes())?;
        for t in &tr.tokens {
            w.write_all(&t.to_le_bytes())?;
        }
        if meta.has_embeddings {
            ensure!(
                tr.embeddings.len() == tr.tokens.len() * meta.d_emb as usize,
                "embedding size mismatch"
            );
            for e in &tr.embeddings {
                w.write_all(&e.to_le_bytes())?;
            }
        }
        ensure!(
            tr.experts.len() == tr.tokens.len() * meta.n_layers as usize * meta.top_k as usize,
            "expert array size mismatch"
        );
        w.write_all(&tr.experts)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(meta: &TraceMeta, id: u32, n_tokens: usize) -> PromptTrace {
        let d = meta.d_emb as usize;
        PromptTrace {
            prompt_id: id,
            n_layers: meta.n_layers,
            top_k: meta.top_k,
            d_emb: meta.d_emb,
            tokens: (0..n_tokens as i32).collect(),
            embeddings: if meta.has_embeddings {
                (0..n_tokens * d).map(|x| x as f32 * 0.5).collect()
            } else {
                vec![]
            },
            experts: (0..n_tokens * meta.n_layers as usize * meta.top_k as usize)
                .map(|x| (x % meta.n_experts as usize) as u8)
                .collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let meta = TraceMeta {
            n_layers: 5,
            n_experts: 16,
            top_k: 3,
            d_emb: 8,
            has_embeddings: true,
        };
        let traces = vec![mk(&meta, 1, 4), mk(&meta, 2, 9)];
        let tmp = std::env::temp_dir().join("moeb_store_test.bin");
        write_traces(&tmp, &meta, &traces).unwrap();
        let (m2, back) = read_traces_with_meta(&tmp).unwrap();
        assert_eq!(m2, meta);
        assert_eq!(back.len(), 2);
        for (a, b) in traces.iter().zip(&back) {
            assert_eq!(a.prompt_id, b.prompt_id);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.embeddings, b.embeddings);
            assert_eq!(a.experts, b.experts);
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn roundtrip_no_embeddings() {
        let meta = TraceMeta {
            n_layers: 2,
            n_experts: 8,
            top_k: 2,
            d_emb: 4,
            has_embeddings: false,
        };
        let traces = vec![mk(&meta, 9, 3)];
        let tmp = std::env::temp_dir().join("moeb_store_test2.bin");
        write_traces(&tmp, &meta, &traces).unwrap();
        let (m2, back) = read_traces_with_meta(&tmp).unwrap();
        assert!(!m2.has_embeddings);
        assert!(back[0].embeddings.is_empty());
        assert_eq!(back[0].experts, traces[0].experts);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let tmp = std::env::temp_dir().join("moeb_store_bad.bin");
        std::fs::write(&tmp, [0u8; 64]).unwrap();
        assert!(read_traces(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn python_written_traces_if_present() {
        // integration against the real artifact tree when it exists
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/traces/test.bin");
        if !p.exists() {
            return;
        }
        let (meta, traces) = read_traces_with_meta(&p).unwrap();
        assert_eq!(meta.n_layers, 27);
        assert_eq!(meta.top_k, 6);
        assert!(!traces.is_empty());
        let tr = &traces[0];
        assert!(tr.n_tokens() >= 48);
        // experts per (token, layer) are unique
        let ids = tr.expert_ids(0, 0);
        let set: std::collections::BTreeSet<_> = ids.iter().collect();
        assert_eq!(set.len(), ids.len());
    }
}
