//! Rust-side loader for the synthetic world model (`world.json` +
//! `world.bin` + `world.blobs.json` written by `python/compile/world.py`).
//!
//! The Rust workload generator and the trace simulator use the same
//! parametric world the Python side trained the predictor on — the blobs
//! are shared verbatim, so there is no drift between the two languages'
//! notion of topics, affinities, or the analytic router.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context};

use crate::config::WorldMeta;
use crate::util::json::Json;
use crate::util::{math, Rng};
use crate::Result;

#[derive(Debug, Clone)]
struct BlobEntry {
    offset: usize,
    nbytes: usize,
    #[allow(dead_code)]
    shape: Vec<usize>,
    dtype: String,
}

/// The loaded world: metadata + the tensors the generator needs.
#[derive(Debug, Clone)]
pub struct WorldModel {
    pub meta: WorldMeta,
    /// [L, K, E] row-normalized expert affinities.
    pub affinity: Vec<f32>,
    /// [K, D] orthonormal topic embeddings.
    pub topic_emb: Vec<f32>,
    /// [V, D] token embedding table (backbone `tok_emb`).
    pub token_emb: Vec<f32>,
    /// [V] topic id per token (-1 = common token).
    pub token_topic: Vec<i32>,
    /// [L, E, D] analytic router weights.
    pub router_w: Vec<f32>,
    /// [L, K, W] working-set expert ids.
    pub working_sets: Vec<i32>,
    /// [L, E] per-layer expert permutation.
    pub layer_perm: Vec<i32>,
}

impl WorldModel {
    /// Load from `<artifacts>/world.json` (+ sibling .bin/.blobs.json).
    pub fn load<P: AsRef<Path>>(world_json: P) -> Result<Self> {
        let path = world_json.as_ref();
        let meta = WorldMeta::from_json(&Json::parse_file(path)?)
            .with_context(|| format!("parsing {path:?}"))?;
        let base = path.with_extension(""); // strips .json
        let bj = Json::parse_file(base.with_extension("blobs.json"))
            .context("reading world.blobs.json")?;
        let mut blobs_manifest: HashMap<String, BlobEntry> = HashMap::new();
        for (name, e) in bj.as_obj()? {
            blobs_manifest.insert(
                name.clone(),
                BlobEntry {
                    offset: e.req("offset")?.as_usize()?,
                    nbytes: e.req("nbytes")?.as_usize()?,
                    shape: e.req("shape")?.as_usize_vec()?,
                    dtype: e.req("dtype")?.as_str()?.to_string(),
                },
            );
        }
        let bin = std::fs::read(base.with_extension("bin")).context("reading world.bin")?;

        let f32s = |name: &str| -> Result<Vec<f32>> {
            let e = blobs_manifest
                .get(name)
                .with_context(|| format!("blob {name} missing"))?;
            ensure!(e.dtype == "float32", "blob {name} is {}", e.dtype);
            let raw = &bin[e.offset..e.offset + e.nbytes];
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let i32s = |name: &str| -> Result<Vec<i32>> {
            let e = blobs_manifest
                .get(name)
                .with_context(|| format!("blob {name} missing"))?;
            ensure!(e.dtype == "int32", "blob {name} is {}", e.dtype);
            let raw = &bin[e.offset..e.offset + e.nbytes];
            Ok(raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };

        let w = Self {
            affinity: f32s("affinity")?,
            topic_emb: f32s("topic_emb")?,
            token_emb: f32s("token_emb")?,
            token_topic: i32s("token_topic")?,
            router_w: f32s("router_w")?,
            working_sets: i32s("working_sets")?,
            layer_perm: i32s("layer_perm")?,
            meta,
        };
        let (l, k, e, d, v) = (
            w.meta.n_layers as usize,
            w.meta.n_topics as usize,
            w.meta.n_experts as usize,
            w.meta.d_model as usize,
            w.meta.vocab_size as usize,
        );
        ensure!(w.affinity.len() == l * k * e, "affinity shape mismatch");
        ensure!(w.topic_emb.len() == k * d, "topic_emb shape mismatch");
        ensure!(w.token_emb.len() == v * d, "token_emb shape mismatch");
        ensure!(w.router_w.len() == l * e * d, "router_w shape mismatch");
        ensure!(w.layer_perm.len() == l * e, "layer_perm shape mismatch");
        Ok(w)
    }

    #[inline]
    pub fn n_layers(&self) -> usize {
        self.meta.n_layers as usize
    }
    #[inline]
    pub fn n_experts(&self) -> usize {
        self.meta.n_experts as usize
    }
    #[inline]
    pub fn top_k(&self) -> usize {
        self.meta.top_k as usize
    }
    #[inline]
    pub fn d_model(&self) -> usize {
        self.meta.d_model as usize
    }

    /// Embedding row of a token id.
    pub fn token_embedding(&self, token: i32) -> &[f32] {
        let d = self.d_model();
        let v = token as usize;
        &self.token_emb[v * d..(v + 1) * d]
    }

    /// Analytic router logits for a context embedding at `layer`.
    /// `out` must have length n_experts.
    pub fn router_logits(&self, ctx: &[f32], layer: usize, out: &mut [f64]) {
        let (e_n, d) = (self.n_experts(), self.d_model());
        let temp = self.meta.router_temp;
        let base = layer * e_n * d;
        for e in 0..e_n {
            let w = &self.router_w[base + e * d..base + (e + 1) * d];
            out[e] = math::dot(ctx, w) as f64 / temp;
        }
    }

    /// Sample gumbel-perturbed top-k expert ids for one context embedding
    /// (mirrors `World.sample_topk`).
    pub fn sample_topk(&self, ctx: &[f32], layer: usize, rng: &mut Rng) -> Vec<u8> {
        let e_n = self.n_experts();
        let mut logits = vec![0.0f64; e_n];
        self.router_logits(ctx, layer, &mut logits);
        let noise = self.meta.router_noise;
        for l in logits.iter_mut() {
            *l += rng.gumbel() * noise;
        }
        math::top_k(&logits, self.top_k())
            .into_iter()
            .map(|i| i as u8)
            .collect()
    }

    /// EMA context update (mirrors `World.context_embeddings` step).
    pub fn context_step(&self, ctx: &mut [f32], emb: &[f32]) {
        let a = self.meta.ctx_alpha.unwrap_or(0.75) as f32;
        for i in 0..ctx.len() {
            ctx[i] = a * ctx[i] + (1.0 - a) * emb[i];
        }
        math::normalize(ctx);
    }

    /// Working set of (layer, topic).
    pub fn working_set(&self, layer: usize, topic: usize) -> &[i32] {
        let (k, w) = (self.meta.n_topics as usize, self.meta.working_set as usize);
        let base = (layer * k + topic) * w;
        &self.working_sets[base..base + w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load_if_present() -> Option<WorldModel> {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/world.json");
        p.exists().then(|| WorldModel::load(&p).unwrap())
    }

    #[test]
    fn world_loads_and_validates() {
        let Some(w) = load_if_present() else { return };
        assert_eq!(w.n_layers(), 27);
        assert_eq!(w.n_experts(), 64);
        assert_eq!(w.top_k(), 6);
        // affinity rows normalized
        let (k, e) = (w.meta.n_topics as usize, w.n_experts());
        for l in [0, 13] {
            for t in 0..k {
                let row = &w.affinity[(l * k + t) * e..(l * k + t + 1) * e];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-3, "layer {l} topic {t} sum {s}");
            }
        }
    }

    #[test]
    fn topic_embeddings_orthonormal() {
        let Some(w) = load_if_present() else { return };
        let (k, d) = (w.meta.n_topics as usize, w.d_model());
        for a in (0..k).step_by(7) {
            for b in (0..k).step_by(7) {
                let ea = &w.topic_emb[a * d..(a + 1) * d];
                let eb = &w.topic_emb[b * d..(b + 1) * d];
                let dot = math::dot(ea, eb);
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "{a},{b} dot {dot}");
            }
        }
    }

    #[test]
    fn sampled_topk_lands_in_working_sets() {
        let Some(w) = load_if_present() else { return };
        let mut rng = Rng::new(3);
        // a pure-topic context should route inside that topic's working set
        let d = w.d_model();
        for topic in [0usize, 5, 20] {
            let ctx: Vec<f32> = w.topic_emb[topic * d..(topic + 1) * d].to_vec();
            for layer in [0usize, 13, 26] {
                let ws: std::collections::BTreeSet<i32> =
                    w.working_set(layer, topic).iter().copied().collect();
                let mut hits = 0;
                let mut total = 0;
                for _ in 0..20 {
                    for id in w.sample_topk(&ctx, layer, &mut rng) {
                        total += 1;
                        if ws.contains(&(id as i32)) {
                            hits += 1;
                        }
                    }
                }
                assert!(
                    hits as f64 / total as f64 > 0.7,
                    "layer {layer} topic {topic}: {hits}/{total}"
                );
            }
        }
    }
}
