//! `ExpertSet` — a set over expert ids as a multi-word bitmask.
//!
//! Every hot path in the simulator and cache manager works on these sets
//! (a token activates 6 of 64 experts per layer), so set algebra must be
//! branch-free integer ops, not hash sets.  The set is generic over its
//! word count `N`: `ExpertSet<1>` (the default) is the single-`u64` mask
//! the ≤64-expert paper configuration has always used and monomorphizes
//! to exactly the old code; `ExpertSet<3>` covers 160-expert models such
//! as full DeepSeek-V2.  All per-word loops are written without early
//! exits (SIMD-style word-parallel accumulate) so the compiler can
//! unroll and auto-vectorize them for any fixed `N`.

use std::fmt;

/// Maximum supported word count (`256` experts).  Expert ids stay `u8`
/// everywhere, so this is a hard ceiling, not a tuning knob.
pub const N_MAX: usize = 4;

/// Maximum supported expert count across all widths.
pub const MAX_EXPERTS: usize = 64 * N_MAX;

/// Number of `u64` words needed to hold `n_experts` bits (min 1).
#[inline]
pub const fn words_for(n_experts: usize) -> usize {
    if n_experts <= 64 {
        1
    } else {
        (n_experts + 63) / 64
    }
}

/// Dispatch a block over the const word-width needed for `$n` experts.
///
/// Inside the block, `$N` is a `const usize` in `1..=N_MAX` usable as a
/// const-generic argument (`ExpertSet<$N>`, `memory::build::<$N>`, …).
/// Panics if `$n` exceeds [`MAX_EXPERTS`].
///
/// ```
/// use moe_beyond::for_expert_width;
/// use moe_beyond::util::ExpertSet;
/// let n_experts = 160usize;
/// let len = for_expert_width!(n_experts, N, {
///     ExpertSet::<N>::all(n_experts as u16).len()
/// });
/// assert_eq!(len, 160);
/// ```
#[macro_export]
macro_rules! for_expert_width {
    ($n:expr, $N:ident, $body:block) => {
        match $crate::util::expert_set::words_for($n) {
            1 => {
                const $N: usize = 1;
                $body
            }
            2 => {
                const $N: usize = 2;
                $body
            }
            3 => {
                const $N: usize = 3;
                $body
            }
            4 => {
                const $N: usize = 4;
                $body
            }
            w => panic!(
                "for_expert_width!: {} experts need {} words, max is {}",
                $n,
                w,
                $crate::util::expert_set::N_MAX
            ),
        }
    };
}

/// A set of expert ids in `0..64*N`, represented as an `N`-word bitmask.
///
/// The default width (`ExpertSet` = `ExpertSet<1>`) covers up to 64
/// experts in a single `u64`; wider worlds pick `N` once at the CLI
/// boundary via [`for_expert_width!`](crate::for_expert_width).
///
/// # Example
///
/// ```
/// use moe_beyond::util::ExpertSet;
///
/// let predicted: ExpertSet = ExpertSet::from_ids([3u8, 9, 41]);
/// let actual: ExpertSet = ExpertSet::from_ids([9u8, 41, 63]);
/// assert_eq!(predicted.overlap(actual), 2); // prediction hits
/// assert_eq!(predicted.union(actual).len(), 4);
/// assert!(!predicted.contains(63)); // this miss costs a demand fetch
/// ```
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExpertSet<const N: usize = 1>([u64; N]);

impl<const N: usize> Default for ExpertSet<N> {
    #[inline]
    fn default() -> Self {
        Self::EMPTY
    }
}

impl<const N: usize> ExpertSet<N> {
    pub const EMPTY: ExpertSet<N> = ExpertSet([0; N]);

    /// Bit capacity of this set width.
    pub const CAPACITY: usize = 64 * N;

    #[inline]
    pub fn new() -> Self {
        Self::EMPTY
    }

    /// Build a set directly from its raw words (word 0 = ids `0..64`).
    #[inline]
    pub const fn from_words(words: [u64; N]) -> Self {
        Self(words)
    }

    /// The raw words (word 0 = ids `0..64`).
    #[inline]
    pub const fn as_words(&self) -> &[u64; N] {
        &self.0
    }

    /// Set containing all experts `0..n`.
    ///
    /// Safe at exact word multiples (n = 64, 128, …): the fill is
    /// computed per word with saturating arithmetic, never `1 << 64`.
    #[inline]
    pub fn all(n: u16) -> Self {
        debug_assert!(n as usize <= 64 * N, "all({n}) exceeds {}-bit set", 64 * N);
        let mut s = Self::EMPTY;
        for (w, word) in s.0.iter_mut().enumerate() {
            let filled = (n as usize).saturating_sub(w * 64).min(64);
            *word = if filled == 64 { u64::MAX } else { (1u64 << filled) - 1 };
        }
        s
    }

    #[inline]
    pub fn from_ids<I: IntoIterator<Item = u8>>(ids: I) -> Self {
        let mut s = Self::EMPTY;
        for id in ids {
            s.insert(id);
        }
        s
    }

    #[inline]
    pub fn insert(&mut self, id: u8) {
        debug_assert!((id as usize) < 64 * N, "insert({id}) exceeds {}-bit set", 64 * N);
        self.0[(id >> 6) as usize] |= 1u64 << (id & 63);
    }

    #[inline]
    pub fn remove(&mut self, id: u8) {
        debug_assert!((id as usize) < 64 * N, "remove({id}) exceeds {}-bit set", 64 * N);
        self.0[(id >> 6) as usize] &= !(1u64 << (id & 63));
    }

    #[inline]
    pub fn contains(&self, id: u8) -> bool {
        debug_assert!((id as usize) < 64 * N, "contains({id}) exceeds {}-bit set", 64 * N);
        (self.0[(id >> 6) as usize] >> (id & 63)) & 1 == 1
    }

    #[inline]
    pub fn len(&self) -> u32 {
        // fixed-trip, no-early-exit loop: vectorizes for any const N
        let mut n = 0u32;
        for w in &self.0 {
            n += w.count_ones();
        }
        n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        let mut acc = 0u64;
        for w in &self.0 {
            acc |= w;
        }
        acc == 0
    }

    #[inline]
    pub fn union(&self, other: Self) -> Self {
        let mut out = [0u64; N];
        for ((o, a), b) in out.iter_mut().zip(&self.0).zip(&other.0) {
            *o = a | b;
        }
        Self(out)
    }

    #[inline]
    pub fn intersect(&self, other: Self) -> Self {
        let mut out = [0u64; N];
        for ((o, a), b) in out.iter_mut().zip(&self.0).zip(&other.0) {
            *o = a & b;
        }
        Self(out)
    }

    #[inline]
    pub fn difference(&self, other: Self) -> Self {
        let mut out = [0u64; N];
        for ((o, a), b) in out.iter_mut().zip(&self.0).zip(&other.0) {
            *o = a & !b;
        }
        Self(out)
    }

    /// Number of ids present in both sets.
    #[inline]
    pub fn overlap(&self, other: Self) -> u32 {
        let mut n = 0u32;
        for (a, b) in self.0.iter().zip(&other.0) {
            n += (a & b).count_ones();
        }
        n
    }

    /// Jaccard similarity; 1.0 for two empty sets.
    pub fn jaccard(&self, other: Self) -> f64 {
        let (mut uni, mut inter) = (0u32, 0u32);
        for (a, b) in self.0.iter().zip(&other.0) {
            uni += (a | b).count_ones();
            inter += (a & b).count_ones();
        }
        if uni == 0 {
            return 1.0;
        }
        inter as f64 / uni as f64
    }

    /// Mask of the `k` largest values in `xs` (index = expert id).
    ///
    /// Exact mirror of [`crate::util::math::top_k_mask_f32`] generalized
    /// to `N` words: ties break toward the lower index, `k` saturates at
    /// `xs.len()`, and NaNs never win a slot.
    pub fn top_k_mask_f32(xs: &[f32], k: usize) -> Self {
        debug_assert!(xs.len() <= 64 * N, "{} logits exceed {}-bit set", xs.len(), 64 * N);
        let k = k.min(xs.len());
        let mut mask = Self::EMPTY;
        for _ in 0..k {
            let mut best = usize::MAX;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in xs.iter().enumerate() {
                let taken = (mask.0[i >> 6] >> (i & 63)) & 1 == 1;
                if !taken && v > best_v {
                    best = i;
                    best_v = v;
                }
            }
            if best == usize::MAX {
                break; // all remaining are NaN (or xs shorter than k)
            }
            mask.0[best >> 6] |= 1u64 << (best & 63);
        }
        mask
    }

    /// Iterate over member ids in ascending order.
    pub fn iter(&self) -> ExpertSetIter<N> {
        ExpertSetIter { words: self.0, word: 0 }
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.iter().collect()
    }
}

/// Ascending-order id iterator over a (copied) [`ExpertSet`].
pub struct ExpertSetIter<const N: usize> {
    words: [u64; N],
    word: usize,
}

impl<const N: usize> Iterator for ExpertSetIter<N> {
    type Item = u8;

    #[inline]
    fn next(&mut self) -> Option<u8> {
        while self.word < N {
            let bits = self.words[self.word];
            if bits != 0 {
                let id = (self.word * 64) as u8 + bits.trailing_zeros() as u8;
                self.words[self.word] = bits & (bits - 1);
                return Some(id);
            }
            self.word += 1;
        }
        None
    }
}

impl<const N: usize> FromIterator<u8> for ExpertSet<N> {
    fn from_iter<I: IntoIterator<Item = u8>>(iter: I) -> Self {
        Self::from_ids(iter)
    }
}

impl<const N: usize> fmt::Debug for ExpertSet<N> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ExpertSet{{")?;
        for (i, id) in self.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{id}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let mut s = ExpertSet::<1>::new();
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(17);
        assert_eq!(s.len(), 3);
        assert!(s.contains(17));
        assert!(!s.contains(16));
        s.remove(17);
        assert!(!s.contains(17));
        assert_eq!(s.to_vec(), vec![0, 63]);
    }

    #[test]
    fn basic_ops_wide() {
        let mut s = ExpertSet::<3>::new();
        assert!(s.is_empty());
        s.insert(0);
        s.insert(64);
        s.insert(159);
        assert_eq!(s.len(), 3);
        assert!(s.contains(64));
        assert!(!s.contains(63));
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.to_vec(), vec![0, 159]);
    }

    #[test]
    fn all_n() {
        assert_eq!(ExpertSet::<1>::all(64).len(), 64);
        assert_eq!(ExpertSet::<1>::all(6).to_vec(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(ExpertSet::<1>::all(0).len(), 0);
    }

    // word-boundary audit: exact multiples of 64 must not shift-overflow
    #[test]
    fn all_n_word_boundaries() {
        assert_eq!(ExpertSet::<1>::all(63).len(), 63);
        assert!(!ExpertSet::<1>::all(63).contains(63));
        assert_eq!(ExpertSet::<1>::all(64).len(), 64);
        assert_eq!(ExpertSet::<2>::all(63).len(), 63);
        assert_eq!(ExpertSet::<2>::all(64).len(), 64);
        assert!(!ExpertSet::<2>::all(64).contains(64));
        assert_eq!(ExpertSet::<2>::all(65).len(), 65);
        assert!(ExpertSet::<2>::all(65).contains(64));
        assert_eq!(ExpertSet::<2>::all(128).len(), 128);
        assert_eq!(ExpertSet::<2>::all(128).as_words(), &[u64::MAX, u64::MAX]);
        assert_eq!(ExpertSet::<3>::all(128).len(), 128);
        assert!(!ExpertSet::<3>::all(128).contains(128));
        assert_eq!(ExpertSet::<3>::all(160).len(), 160);
        assert_eq!(ExpertSet::<3>::all(160).to_vec(), (0u8..160).collect::<Vec<_>>());
        assert_eq!(ExpertSet::<4>::all(256).len(), 256);
    }

    #[test]
    fn jaccard_edge_cases() {
        let a = ExpertSet::<1>::from_ids([1, 2, 3]);
        assert_eq!(a.jaccard(a), 1.0);
        assert_eq!(a.jaccard(ExpertSet::EMPTY), 0.0);
        assert_eq!(ExpertSet::<1>::EMPTY.jaccard(ExpertSet::EMPTY), 1.0);
        let w = ExpertSet::<3>::from_ids([1, 70, 150]);
        assert_eq!(w.jaccard(w), 1.0);
        assert_eq!(w.jaccard(ExpertSet::EMPTY), 0.0);
        assert_eq!(ExpertSet::<3>::EMPTY.jaccard(ExpertSet::EMPTY), 1.0);
    }

    // seeded-random property checks (no proptest in the offline build)
    #[test]
    fn prop_union_intersect_laws() {
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..500 {
            let sa = ExpertSet::from_words([rng.next_u64()]);
            let sb = ExpertSet::from_words([rng.next_u64()]);
            assert_eq!(sa.union(sb).len() + sa.intersect(sb).len(), sa.len() + sb.len());
            assert_eq!(sa.difference(sb).union(sa.intersect(sb)), sa);
            assert_eq!(sa.overlap(sb), sa.intersect(sb).len());
        }
    }

    #[test]
    fn prop_union_intersect_laws_wide() {
        let mut rng = crate::util::Rng::new(17);
        for _ in 0..500 {
            let sa = ExpertSet::<3>::from_words([rng.next_u64(), rng.next_u64(), rng.next_u64()]);
            let sb = ExpertSet::<3>::from_words([rng.next_u64(), rng.next_u64(), rng.next_u64()]);
            assert_eq!(sa.union(sb).len() + sa.intersect(sb).len(), sa.len() + sb.len());
            assert_eq!(sa.difference(sb).union(sa.intersect(sb)), sa);
            assert_eq!(sa.overlap(sb), sa.intersect(sb).len());
        }
    }

    #[test]
    fn prop_iter_roundtrip() {
        let mut rng = crate::util::Rng::new(12);
        for _ in 0..200 {
            let mut ids = std::collections::BTreeSet::new();
            for _ in 0..rng.below(20) {
                ids.insert(rng.below(64) as u8);
            }
            let s = ExpertSet::<1>::from_ids(ids.iter().copied());
            assert_eq!(s.to_vec(), ids.into_iter().collect::<Vec<_>>());
        }
    }

    #[test]
    fn prop_iter_roundtrip_wide() {
        let mut rng = crate::util::Rng::new(14);
        for _ in 0..200 {
            let mut ids = std::collections::BTreeSet::new();
            for _ in 0..rng.below(40) {
                ids.insert(rng.below(160) as u8);
            }
            let s = ExpertSet::<3>::from_ids(ids.iter().copied());
            assert_eq!(s.to_vec(), ids.into_iter().collect::<Vec<_>>());
        }
    }

    #[test]
    fn prop_insert_then_contains() {
        let mut rng = crate::util::Rng::new(13);
        for _ in 0..300 {
            let id = rng.below(64) as u8;
            let mut s = ExpertSet::from_words([rng.next_u64()]);
            s.insert(id);
            assert!(s.contains(id));
            s.remove(id);
            assert!(!s.contains(id));
        }
    }

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(0), 1);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(63), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(128), 2);
        assert_eq!(words_for(129), 3);
        assert_eq!(words_for(160), 3);
        assert_eq!(words_for(256), 4);
    }

    #[test]
    fn for_expert_width_dispatches() {
        for (n_experts, want) in [(6usize, 1usize), (64, 1), (65, 2), (160, 3), (256, 4)] {
            let got = for_expert_width!(n_experts, N, { N });
            assert_eq!(got, want, "n_experts={n_experts}");
        }
    }

    #[test]
    fn top_k_mask_matches_scalar_math() {
        let mut rng = crate::util::Rng::new(15);
        for _ in 0..200 {
            let xs: Vec<f32> = (0..64).map(|_| rng.f64() as f32).collect();
            let k = rng.below(10) as usize;
            let wide = ExpertSet::<1>::top_k_mask_f32(&xs, k);
            assert_eq!(wide.as_words()[0], crate::util::math::top_k_mask_f32(&xs, k));
        }
    }
}
