//! `ExpertSet` — a set over ≤64 expert ids as a single `u64` bitmask.
//!
//! Every hot path in the simulator and cache manager works on these sets
//! (a token activates 6 of 64 experts per layer), so set algebra must be
//! branch-free integer ops, not hash sets.

use std::fmt;

/// A set of expert ids in `0..64`, represented as a `u64` bitmask.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct ExpertSet(pub u64);

impl ExpertSet {
    pub const EMPTY: ExpertSet = ExpertSet(0);

    #[inline]
    pub fn new() -> Self {
        Self(0)
    }

    /// Set containing all experts `0..n`.
    #[inline]
    pub fn all(n: u16) -> Self {
        debug_assert!(n <= 64);
        if n == 64 {
            Self(u64::MAX)
        } else {
            Self((1u64 << n) - 1)
        }
    }

    #[inline]
    pub fn from_ids<I: IntoIterator<Item = u8>>(ids: I) -> Self {
        let mut s = Self(0);
        for id in ids {
            s.insert(id);
        }
        s
    }

    #[inline]
    pub fn insert(&mut self, id: u8) {
        debug_assert!(id < 64);
        self.0 |= 1u64 << id;
    }

    #[inline]
    pub fn remove(&mut self, id: u8) {
        self.0 &= !(1u64 << id);
    }

    #[inline]
    pub fn contains(&self, id: u8) -> bool {
        (self.0 >> id) & 1 == 1
    }

    #[inline]
    pub fn len(&self) -> u32 {
        self.0.count_ones()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    #[inline]
    pub fn union(&self, other: Self) -> Self {
        Self(self.0 | other.0)
    }

    #[inline]
    pub fn intersect(&self, other: Self) -> Self {
        Self(self.0 & other.0)
    }

    #[inline]
    pub fn difference(&self, other: Self) -> Self {
        Self(self.0 & !other.0)
    }

    /// Number of ids present in both sets.
    #[inline]
    pub fn overlap(&self, other: Self) -> u32 {
        (self.0 & other.0).count_ones()
    }

    /// Jaccard similarity; 1.0 for two empty sets.
    pub fn jaccard(&self, other: Self) -> f64 {
        let u = (self.0 | other.0).count_ones();
        if u == 0 {
            return 1.0;
        }
        (self.0 & other.0).count_ones() as f64 / u as f64
    }

    /// Iterate over member ids in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = u8> + '_ {
        let mut bits = self.0;
        std::iter::from_fn(move || {
            if bits == 0 {
                None
            } else {
                let id = bits.trailing_zeros() as u8;
                bits &= bits - 1;
                Some(id)
            }
        })
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.iter().collect()
    }
}

impl FromIterator<u8> for ExpertSet {
    fn from_iter<I: IntoIterator<Item = u8>>(iter: I) -> Self {
        Self::from_ids(iter)
    }
}

impl fmt::Debug for ExpertSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ExpertSet{{")?;
        for (i, id) in self.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{id}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let mut s = ExpertSet::new();
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(17);
        assert_eq!(s.len(), 3);
        assert!(s.contains(17));
        assert!(!s.contains(16));
        s.remove(17);
        assert!(!s.contains(17));
        assert_eq!(s.to_vec(), vec![0, 63]);
    }

    #[test]
    fn all_n() {
        assert_eq!(ExpertSet::all(64).len(), 64);
        assert_eq!(ExpertSet::all(6).to_vec(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(ExpertSet::all(0).len(), 0);
    }

    #[test]
    fn jaccard_edge_cases() {
        let a = ExpertSet::from_ids([1, 2, 3]);
        assert_eq!(a.jaccard(a), 1.0);
        assert_eq!(a.jaccard(ExpertSet::EMPTY), 0.0);
        assert_eq!(ExpertSet::EMPTY.jaccard(ExpertSet::EMPTY), 1.0);
    }

    // seeded-random property checks (no proptest in the offline build)
    #[test]
    fn prop_union_intersect_laws() {
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..500 {
            let (sa, sb) = (ExpertSet(rng.next_u64()), ExpertSet(rng.next_u64()));
            assert_eq!(sa.union(sb).len() + sa.intersect(sb).len(), sa.len() + sb.len());
            assert_eq!(sa.difference(sb).union(sa.intersect(sb)), sa);
            assert_eq!(sa.overlap(sb), sa.intersect(sb).len());
        }
    }

    #[test]
    fn prop_iter_roundtrip() {
        let mut rng = crate::util::Rng::new(12);
        for _ in 0..200 {
            let mut ids = std::collections::BTreeSet::new();
            for _ in 0..rng.below(20) {
                ids.insert(rng.below(64) as u8);
            }
            let s = ExpertSet::from_ids(ids.iter().copied());
            assert_eq!(s.to_vec(), ids.into_iter().collect::<Vec<_>>());
        }
    }

    #[test]
    fn prop_insert_then_contains() {
        let mut rng = crate::util::Rng::new(13);
        for _ in 0..300 {
            let id = rng.below(64) as u8;
            let mut s = ExpertSet(rng.next_u64());
            s.insert(id);
            assert!(s.contains(id));
            s.remove(id);
            assert!(!s.contains(id));
        }
    }
}
