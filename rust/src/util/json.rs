//! Minimal JSON parser + writer (this build environment is offline and has
//! no serde).  Covers the full JSON grammar; numbers are f64 (every value
//! the Python side emits fits losslessly below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- parsing ---------------------------------------------------------

    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file<P: AsRef<std::path::Path>>(path: P) -> Result<Json> {
        let s = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&s).with_context(|| format!("parsing {:?}", path.as_ref()))
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required object field (error carries the key name).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .with_context(|| format!("missing JSON field '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Array of numbers -> Vec<usize> (shape fields).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writing ---------------------------------------------------------

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_json_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        // Python emits Infinity/NaN only with allow_nan; we never do.
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .context("truncated \\u escape")?,
                            )?;
                            let mut cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b.get(self.i + 1) == Some(&b'\\')
                                && self.b.get(self.i + 2) == Some(&b'u')
                            {
                                let hex2 = std::str::from_utf8(
                                    self.b
                                        .get(self.i + 3..self.i + 7)
                                        .context("truncated low surrogate")?,
                                )?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    self.i += 6;
                                }
                            }
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected ',' or ']', got {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => bail!("expected ',' or '}}', got {:?}", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\nthere\"").unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").unwrap().as_arr().unwrap()[2].req("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert!(!j.req("d").unwrap().req("e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café 😀 \"q\"""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café 😀 \"q\"");
        let j2 = Json::parse("\"héllo — ünïcode\"").unwrap();
        assert_eq!(j2.as_str().unwrap(), "héllo — ünïcode");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"nested":{"x":-3}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_json_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn roundtrip_escaped_strings() {
        let j = Json::Str("line1\nline2\t\"q\" \\ \u{1}".into());
        let out = j.to_json_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn parses_real_python_manifest() {
        // shape of artifacts.json written by python's json.dump(indent=2)
        let src = "{\n  \"world\": {\n    \"seed\": 20250710,\n    \"layer_mix\": 0.62\n  },\n  \"splits\": {}\n}";
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req("world").unwrap().req("seed").unwrap().as_u64().unwrap(), 20250710);
    }
}
